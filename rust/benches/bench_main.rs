//! Benchmark harness (criterion is unavailable offline — hand-rolled
//! median-of-N timing with warmup; `harness = false`).
//!
//! Sections map to the paper's evaluation (DESIGN.md §4):
//!   gemm_scaling   — the view-kernel matrix: dense gemm_into vs the old
//!                    naive value-returning matmul across kernel kind
//!                    (scalar vs the packed SIMD micro-kernel) × size ×
//!                    thread count, and the kept-column kernels across
//!                    kind × budget × threads on the same shapes
//!                    (kernel-vs-kernel, the honest Eq-6 baseline)
//!   native_bwd     — exact vs sketched layer backward (scores + waterfilling
//!                    + sampling + kept-column GEMMs) across budgets and
//!                    widths: the ρ(V) wall-clock of Eq 6 on real kernels
//!   native_step    — full native train-step wall time, exact vs sketched
//!   native_models  — train-step wall time per model family (mlp, bagnet,
//!                    vit), exact vs l1-sketched, each record carrying its
//!                    workspace footprint
//!   native_memory  — workspace-byte accounting per (model, activation
//!                    policy), including the 2–3× deeper registry models:
//!                    the §7.4 memory claim as a tracked column
//!   serve_throughput — inference serving qps + p50/p99 request latency
//!                    across offered load × batch cap (open-loop clients
//!                    over the dynamic batcher, DESIGN.md §7.5)
//!   dp_scaling     — data-parallel replica-group step time and modeled
//!                    exchange traffic across replica count × reduce mode
//!                    (DESIGN.md §7.6); the `wire_bytes_per_step` column
//!                    is the acceptance bar — sparse tracks the sketch
//!                    budget fraction of dense
//!   step_latency   — AOT train-step wall time per (model, method) through
//!                    PJRT (requires --features pjrt + built artifacts)
//!   eq6_gemm       — dense vs kept-column backward GEMMs (kernel-only view)
//!   pipeline       — simulated pipeline step time vs budget (Fig §1(i))
//!   substrates     — pstar / correlated sampling / JSON parse throughput
//!
//! Run all:  cargo bench    Filter:  cargo bench -- gemm_scaling
//! Machine-readable medians:  cargo bench -- --json results/BENCH_native.json
//! (writes {group, case, median_ms} records — plus a `workspace_bytes`
//! memory column on the trainer-level records — for the perf trajectory;
//! CI uploads the file as a workflow artifact).

use std::sync::Arc;
use std::time::Instant;

use uavjp::config::{Preset, ServeConfig, TrainConfig};
use uavjp::data::{self, DatasetKind};
use uavjp::json::Value;
use uavjp::native::{models, sketched_linear_backward_into, NativeTrainer};
use uavjp::pipeline::{simulate, PipelineConfig};
use uavjp::pool;
use uavjp::rng::Pcg64;
use uavjp::serve::run_server;
use uavjp::sketch::{
    correlated_bernoulli, kept_columns, pstar_from_weights, SketchScratch,
};
use uavjp::tensor::kernels::{self, KernelKind};
use uavjp::tensor::{
    gemm_into, matmul_pr2_reference, sparse_dw_into, sparse_dx_into, Mat,
};

fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// One bench record: median wall time plus, for trainer-level cases, the
/// workspace footprint in bytes (the §7.4 tracked memory column).
struct Record {
    group: String,
    case: String,
    secs: f64,
    workspace_bytes: Option<u64>,
    wire_bytes_per_step: Option<u64>,
}

/// Collected records, printed as we go and optionally dumped as JSON for
/// the perf trajectory.
#[derive(Default)]
struct Report {
    records: Vec<Record>,
}

impl Report {
    fn rec(&mut self, group: &str, case: impl Into<String>, secs: f64) {
        self.records.push(Record {
            group: group.to_string(),
            case: case.into(),
            secs,
            workspace_bytes: None,
            wire_bytes_per_step: None,
        });
    }

    fn rec_mem(
        &mut self,
        group: &str,
        case: impl Into<String>,
        secs: f64,
        bytes: u64,
    ) {
        self.records.push(Record {
            group: group.to_string(),
            case: case.into(),
            secs,
            workspace_bytes: Some(bytes),
            wire_bytes_per_step: None,
        });
    }

    fn rec_wire(
        &mut self,
        group: &str,
        case: impl Into<String>,
        secs: f64,
        bytes: u64,
    ) {
        self.records.push(Record {
            group: group.to_string(),
            case: case.into(),
            secs,
            workspace_bytes: None,
            wire_bytes_per_step: Some(bytes),
        });
    }

    fn to_json(&self) -> Value {
        Value::Arr(
            self.records
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("group", Value::str(&r.group)),
                        ("case", Value::str(&r.case)),
                        ("median_ms", Value::num(r.secs * 1e3)),
                    ];
                    if let Some(b) = r.workspace_bytes {
                        fields.push(("workspace_bytes", Value::num(b as f64)));
                    }
                    if let Some(b) = r.wire_bytes_per_step {
                        fields
                            .push(("wire_bytes_per_step", Value::num(b as f64)));
                    }
                    Value::obj(fields)
                })
                .collect(),
        )
    }
}

/// The dense exact backward on preallocated buffers (dX = G·W, dW = Gᵀ·X)
/// — the baseline every sketched case races.
fn dense_backward_into(g: &Mat, x: &Mat, w: &Mat, dx: &mut Mat, dw: &mut Mat) {
    gemm_into(1.0, g.view(), false, w.view(), false, 0.0, dx.view_mut());
    gemm_into(1.0, g.view(), true, x.view(), false, 0.0, dw.view_mut());
}

/// The view-kernel scaling matrix: dense `gemm_into` vs the old naive
/// matmul across kernel kind × size × threads, then the kept-column
/// backward kernels across kind × budget × threads on the paper's
/// 512-wide backward shapes. The ISSUE-4 acceptance bar reads straight
/// off the records: `n512_simd_t1` vs `n512_scalar_t1` ≥ 3× on AVX2, and
/// `bwd512_{kind}_p*` / `bwd512_{kind}_dense` ratios tracking the FLOP
/// ratio per kind.
fn bench_gemm_scaling(filter: &str, rep: &mut Report) {
    if !"gemm_scaling".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== gemm_scaling (kernel kind × size × threads × budget) ==");
    let kinds = [("scalar", KernelKind::Scalar), ("simd", KernelKind::Simd)];
    for n in [128usize, 256, 512] {
        let mut rng = Pcg64::new(3, n as u64);
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian() as f32);
        let b = Mat::from_fn(n, n, |_, _| rng.gaussian() as f32);
        let reps = if n >= 512 { 5 } else { 9 };
        let naive = time_median(reps, || {
            let _ = matmul_pr2_reference(&a, &b);
        });
        println!("  n={n:<5} old matmul:      {:8.2} ms", naive * 1e3);
        rep.rec("gemm_scaling", format!("n{n}_naive"), naive);
        let mut c = Mat::zeros(n, n);
        // only record t>1 cases that really engage the threaded path —
        // below the cut-off gemm_into runs single-threaded regardless,
        // and a t2/t4 label on it would misrepresent the scaling data
        let threaded = n * n * n >= uavjp::tensor::GEMM_PAR_MIN_FLOPS;
        for (kname, kind) in kinds {
            kernels::set_kernel(kind);
            for threads in [1usize, 2, 4] {
                if threads > 1 && !threaded {
                    continue;
                }
                pool::set_threads(threads);
                let t = time_median(reps, || {
                    gemm_into(1.0, a.view(), false, b.view(), false, 0.0, c.view_mut());
                });
                println!(
                    "  n={n:<5} gemm_into {kname:<6} t={threads}: {:8.2} ms  \
                     (vs old {:.2}x)",
                    t * 1e3,
                    naive / t
                );
                rep.rec("gemm_scaling", format!("n{n}_{kname}_t{threads}"), t);
            }
            pool::set_threads(1);
        }
    }
    // kept-column kernels vs the dense exact backward, kind × budget ×
    // threads — the wall-clock side of Eq. 6's ρ(V)
    let (bsz, dout, din) = (128usize, 512usize, 512usize);
    let mut rng = Pcg64::new(7, 0);
    let g = Mat::from_fn(bsz, dout, |_, _| rng.gaussian() as f32);
    let x = Mat::from_fn(bsz, din, |_, _| rng.gaussian() as f32);
    let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32);
    let mut dx = Mat::zeros(bsz, din);
    let mut dw = Mat::zeros(dout, din);
    for (kname, kind) in kinds {
        kernels::set_kernel(kind);
        for threads in [1usize, 2, 4] {
            pool::set_threads(threads);
            let dense = time_median(5, || {
                dense_backward_into(&g, &x, &w, &mut dx, &mut dw);
            });
            println!(
                "  bwd B={bsz} {dout}x{din} dense {kname} t={threads}: {:8.2} ms",
                dense * 1e3
            );
            rep.rec(
                "gemm_scaling",
                format!("bwd512_{kname}_dense_t{threads}"),
                dense,
            );
            for budget in [0.1, 0.25, 0.5] {
                let scores = uavjp::sketch::column_scores("l1", &g, None);
                let p = pstar_from_weights(&scores, budget * dout as f64);
                let z = correlated_bernoulli(&mut rng, &p);
                let kept = kept_columns(&z, &p);
                // skip t>1 labels for cases the threshold keeps single-threaded
                if threads > 1
                    && bsz * din * kept.len() < uavjp::tensor::GEMM_PAR_MIN_FLOPS
                {
                    continue;
                }
                let t = time_median(5, || {
                    sparse_dx_into(g.view(), &kept, w.view(), dx.view_mut());
                    sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
                });
                println!(
                    "  bwd B={bsz} {dout}x{din} p={budget:<4} {kname} \
                     t={threads}: {:8.2} ms  (vs dense {:.2}x, \
                     flop-ratio ~{budget})",
                    t * 1e3,
                    dense / t
                );
                rep.rec(
                    "gemm_scaling",
                    format!("bwd512_{kname}_p{budget}_t{threads}"),
                    t,
                );
            }
        }
        pool::set_threads(1);
    }
    kernels::set_kernel(KernelKind::Auto);
}

/// Exact vs sketched native layer backward, *including* the sketch overhead
/// (scores, waterfilling, sampling) the analytic model in `sketch::
/// backward_flops` accounts for — the honest ρ wall-clock. Runs on
/// preallocated destination buffers, like the trainer's steady state.
fn bench_native_bwd(filter: &str, rep: &mut Report) {
    if !"native_bwd".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== native_bwd (exact vs sketched layer backward, full path) ==");
    let b = 128usize;
    for dout in [256usize, 512, 1024] {
        let din = dout;
        let mut rng = Pcg64::new(7, dout as u64);
        let g = Mat::from_fn(b, dout, |_, _| rng.gaussian() as f32);
        let x = Mat::from_fn(b, din, |_, _| rng.gaussian() as f32);
        let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32);
        let mut dx = Mat::zeros(b, din);
        let mut dw = Mat::zeros(dout, din);
        let mut db = vec![0.0f32; dout];
        let dense = time_median(5, || {
            dense_backward_into(&g, &x, &w, &mut dx, &mut dw);
        });
        println!("  d_out={dout:<5} exact: {:8.2} ms", dense * 1e3);
        rep.rec("native_bwd", format!("d{dout}_exact"), dense);
        for budget in [0.05, 0.1, 0.2, 0.5] {
            let mut srng = Pcg64::new(11, dout as u64);
            let mut scratch = SketchScratch::new();
            let t = time_median(5, || {
                sketched_linear_backward_into(
                    g.view(),
                    x.view(),
                    &w,
                    "l1",
                    budget,
                    &mut srng,
                    &mut scratch,
                    dw.view_mut(),
                    &mut db,
                    Some(dx.view_mut()),
                );
            });
            println!(
                "  d_out={dout:<5} l1 p={budget:<4}: {:8.2} ms  (speedup {:.2}x, ρ_wall {:.3})",
                t * 1e3,
                dense / t,
                t / dense
            );
            rep.rec("native_bwd", format!("d{dout}_l1_p{budget}"), t);
        }
    }
}

/// Whole native train-step (forward + backward + clip + SGD), exact vs
/// sketched, at the paper's MLP shape.
fn bench_native_step(filter: &str, rep: &mut Report) {
    if !"native_step".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== native_step (full train-step wall time, MLP 784-64-64-10) ==");
    for (method, budget) in [("baseline", 1.0), ("l1", 0.25), ("l1", 0.1)] {
        let mut cfg: TrainConfig = Preset::Smoke.base("mlp").expect("preset");
        cfg.method = method.into();
        cfg.budget = budget;
        cfg.train_size = 512;
        cfg.test_size = 128;
        let mut trainer = NativeTrainer::new(cfg).expect("trainer");
        let (train_ds, _) = trainer.datasets();
        let batch = trainer.batch_size();
        let dim = train_ds.dim;
        let x = Mat {
            rows: batch,
            cols: dim,
            data: train_ds.x[..batch * dim].to_vec(),
        };
        let y = train_ds.y[..batch].to_vec();
        let mut step = 0usize;
        let med = time_median(7, || {
            trainer.step(&x, &y, step).expect("step");
            step += 1;
        });
        println!(
            "  {method:<9} p={budget:<4}: {:8.2} ms/step  ({:6.1} steps/s)",
            med * 1e3,
            1.0 / med
        );
        rep.rec("native_step", format!("mlp_{method}_p{budget}"), med);
    }
}

/// Train-step wall time across the registered model families — the
/// module-API models (BagNet-lite, ViT-lite) next to the MLP.
fn bench_native_models(filter: &str, rep: &mut Report) {
    if !"native_models".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== native_models (train-step wall time per model family) ==");
    for model in ["mlp", "bagnet", "vit"] {
        for (method, budget) in [("baseline", 1.0), ("l1", 0.25)] {
            let mut cfg: TrainConfig = Preset::Smoke.base(model).expect("preset");
            cfg.method = method.into();
            cfg.budget = budget;
            cfg.location =
                if method == "baseline" { "none".into() } else { "all".into() };
            cfg.train_size = 256;
            cfg.test_size = 64;
            cfg.batch = 64;
            let mut trainer = NativeTrainer::new(cfg).expect("trainer");
            let (train_ds, _) = trainer.datasets();
            let batch = trainer.batch_size();
            let dim = train_ds.dim;
            let x = Mat {
                rows: batch,
                cols: dim,
                data: train_ds.x[..batch * dim].to_vec(),
            };
            let y = train_ds.y[..batch].to_vec();
            let mut step = 0usize;
            let med = time_median(5, || {
                trainer.step(&x, &y, step).expect("step");
                step += 1;
            });
            let wb = trainer.workspace_bytes();
            println!(
                "  {model:>7}/{method:<9} p={budget:<4}: {:8.2} ms/step  \
                 ({:6.1} steps/s, workspace {:.2} MiB)",
                med * 1e3,
                1.0 / med,
                wb.total as f64 / (1 << 20) as f64
            );
            rep.rec_mem(
                "native_models",
                format!("{model}_{method}_p{budget}"),
                med,
                wb.total as u64,
            );
        }
    }
}

/// Workspace-byte accounting per (model, activation policy) — the §7.4
/// memory claim as a tracked BENCH_native.json column. Includes the 2–3×
/// deeper registry variants: under `--act-policy kept` their footprint
/// collapses back toward (BagNet: *below*) the shallow exact baseline,
/// which `tests/act_policy.rs` asserts as the acceptance bar.
fn bench_native_memory(filter: &str, rep: &mut Report) {
    if !"native_memory".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== native_memory (workspace bytes per model × activation policy) ==");
    for model in ["mlp", "bagnet", "vit", "bagnet_deep", "vit_deep"] {
        for (policy, method, location) in
            [("exact", "baseline", "none"), ("kept", "l1", "all")]
        {
            let mut cfg: TrainConfig = Preset::Smoke.base(model).expect("preset");
            cfg.method = method.into();
            cfg.budget = 0.25;
            cfg.location = location.into();
            cfg.act_policy = policy.into();
            cfg.train_size = 256;
            cfg.test_size = 64;
            cfg.batch = 64;
            let mut trainer = NativeTrainer::new(cfg).expect("trainer");
            let (train_ds, _) = trainer.datasets();
            let batch = trainer.batch_size();
            let dim = train_ds.dim;
            let x = Mat {
                rows: batch,
                cols: dim,
                data: train_ds.x[..batch * dim].to_vec(),
            };
            let y = train_ds.y[..batch].to_vec();
            let mut step = 0usize;
            let med = time_median(5, || {
                trainer.step(&x, &y, step).expect("step");
                step += 1;
            });
            // steady-state footprint: stash arenas are populated after
            // the timed steps above
            let wb = trainer.workspace_bytes();
            let mib = |b: usize| b as f64 / (1 << 20) as f64;
            println!(
                "  {model:>12}/{policy:<5}: {:8.2} ms/step  workspace \
                 {:7.2} MiB (flow {:.2} + grad-flow {:.2} + stash {:.2} + \
                 caches {:.2} + grads {:.2} + planning {:.2})",
                med * 1e3,
                mib(wb.total),
                mib(wb.flow),
                mib(wb.gflow),
                mib(wb.stash),
                mib(wb.caches),
                mib(wb.grad_slots),
                mib(wb.planning),
            );
            rep.rec_mem(
                "native_memory",
                format!("{model}_{policy}"),
                med,
                wb.total as u64,
            );
        }
    }
}

/// Serving throughput and latency quantiles across offered load × batch
/// cap (open-loop clients, the `serve` CLI's measurement path). Records
/// carry the p50/p99 request latency and the run's wall time per case;
/// sustained qps is `requests / wall` (requests is fixed at 128 here).
fn bench_serve_throughput(filter: &str, rep: &mut Report) {
    if !"serve_throughput".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== serve_throughput (offered load × batch cap, open loop, mlp) ==");
    let model = Arc::new(models::build("mlp", 3).expect("registry model"));
    let kind = DatasetKind::for_model("mlp").expect("dataset kind");
    let ds = data::generate(kind, 64, 1234, "test");
    let mut inputs = uavjp::tensor::Mat::zeros(ds.n, ds.dim);
    inputs.data.copy_from_slice(&ds.x);
    for offered in [100.0f64, 400.0] {
        for max_batch in [1usize, 8] {
            let cfg = ServeConfig {
                max_batch,
                max_wait_us: 200,
                workers: 1,
                requests: 128,
                offered_load: offered,
                concurrency: 4,
                queue_cap: 0,
                request_timeout_us: 0,
            };
            let r = run_server(&model, ds.dim, &inputs, &cfg);
            println!(
                "  load={offered:>5.0} qps cap={max_batch}: {:7.1} qps \
                 sustained, p50 {:7.3} ms, p99 {:7.3} ms, mean batch {:.2}",
                r.throughput_qps, r.p50_ms, r.p99_ms, r.mean_batch
            );
            let case = format!("mlp_q{offered}_b{max_batch}");
            rep.rec("serve_throughput", format!("{case}_p50"), r.p50_ms / 1e3);
            rep.rec("serve_throughput", format!("{case}_p99"), r.p99_ms / 1e3);
            rep.rec("serve_throughput", format!("{case}_wall"), r.wall_seconds);
        }
    }
}

/// Data-parallel replica-group step time and modeled exchange traffic
/// across replica count × reduce mode (DESIGN.md §7.6). Trajectories are
/// replica-invariant by construction (`tests/replicate.rs`), so the
/// replica axis here is pure executor scaling; the reduce axis is the
/// wire story — `wire_bytes_per_step` for sparse should sit near the
/// sketch budget fraction of dense (plus per-row index overhead).
fn bench_dp_scaling(filter: &str, rep: &mut Report) {
    if !"dp_scaling".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== dp_scaling (replicas × reduce mode, mlp, l1 p=0.25) ==");
    for replicas in [1usize, 2, 4] {
        for reduce in ["dense", "sparse"] {
            let mut cfg: TrainConfig = Preset::Smoke.base("mlp").expect("preset");
            cfg.method = "l1".into();
            cfg.budget = 0.25;
            cfg.train_size = 512;
            cfg.test_size = 128;
            cfg.batch = 64;
            cfg.replicas = replicas;
            cfg.reduce = reduce.into();
            let mut trainer = NativeTrainer::new(cfg).expect("trainer");
            let (train_ds, _) = trainer.datasets();
            let batch = trainer.batch_size();
            let dim = train_ds.dim;
            let x = Mat {
                rows: batch,
                cols: dim,
                data: train_ds.x[..batch * dim].to_vec(),
            };
            let y = train_ds.y[..batch].to_vec();
            let mut step = 0usize;
            let med = time_median(5, || {
                trainer.step(&x, &y, step).expect("step");
                step += 1;
            });
            let stats = trainer.exchange_stats().expect("replica stats");
            let wire = if reduce == "dense" {
                stats.dense_per_step()
            } else {
                stats.sparse_per_step()
            };
            println!(
                "  r={replicas} {reduce:<6}: {:8.2} ms/step  ({:6.1} steps/s, \
                 wire {:8.1} KB/step, sparse/dense {:.3})",
                med * 1e3,
                1.0 / med,
                wire / 1024.0,
                stats.ratio()
            );
            rep.rec_wire(
                "dp_scaling",
                format!("mlp_r{replicas}_{reduce}"),
                med,
                wire as u64,
            );
        }
    }
}

#[cfg(feature = "pjrt")]
fn bench_step_latency(filter: &str, rep: &mut Report) {
    use uavjp::coordinator::trainer::layer_mask;
    use uavjp::coordinator::Trainer;
    use uavjp::runtime::Runtime;
    if !"step_latency".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== step_latency (train-step wall time, PJRT CPU) ==");
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("  skipped: no artifacts ({e})");
            return;
        }
    };
    let cases = [
        ("mlp", "baseline", 1.0),
        ("mlp", "per_column", 0.2),
        ("mlp", "l1", 0.2),
        ("mlp", "ds", 0.2),
        ("mlp", "rcs", 0.2),
        ("vit", "baseline", 1.0),
        ("vit", "l1", 0.2),
        ("bagnet", "baseline", 1.0),
        ("bagnet", "l1", 0.2),
    ];
    for (model, method, budget) in cases {
        let mut cfg: TrainConfig = Preset::Smoke.base(model).expect("preset");
        cfg.method = method.into();
        cfg.budget = budget;
        let trainer = match Trainer::new(&rt, cfg.clone()) {
            Ok(t) => t,
            Err(e) => {
                println!("  {model}/{method}: skipped ({e})");
                continue;
            }
        };
        let mut state = trainer.init_state().expect("init");
        let kind = DatasetKind::for_model(model).expect("model");
        let batch = trainer.batch_size();
        let ds = data::generate(kind, batch, 1, "train");
        let spec = rt.manifest.get(&format!("train_{model}_{method}")).unwrap();
        let xspec = spec
            .inputs
            .iter()
            .find(|t| t.name == "x")
            .unwrap()
            .shape
            .clone();
        let n_sk = spec.meta_usize("num_sketched").unwrap();
        let mask = layer_mask("all", n_sk).expect("mask");
        let mut step = 0usize;
        let med = time_median(7, || {
            trainer
                .step(&mut state, &ds.x, &ds.y, &xspec, &mask, step)
                .expect("step");
            step += 1;
        });
        println!(
            "  {model:>7}/{method:<11} p={budget:<4}: {:8.2} ms/step  ({:6.1} steps/s)",
            med * 1e3,
            1.0 / med
        );
        rep.rec("step_latency", format!("{model}_{method}_p{budget}"), med);
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_step_latency(filter: &str, _rep: &mut Report) {
    if !"step_latency".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== step_latency ==");
    println!("  skipped: built without the `pjrt` feature (native benches above cover the CPU path)");
}

fn bench_eq6_gemm(filter: &str, rep: &mut Report) {
    if !"eq6_gemm".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== eq6_gemm (dense vs kept-column backward, rust-native) ==");
    let mut rng = Pcg64::new(7, 0);
    let (b, dout, din) = (128usize, 512usize, 512usize);
    let g = Mat::from_fn(b, dout, |_, _| rng.gaussian() as f32);
    let x = Mat::from_fn(b, din, |_, _| rng.gaussian() as f32);
    let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32);
    let mut dx = Mat::zeros(b, din);
    let mut dw = Mat::zeros(dout, din);

    let dense = time_median(5, || {
        dense_backward_into(&g, &x, &w, &mut dx, &mut dw);
    });
    println!("  dense backward (B={b}, {dout}×{din}): {:.2} ms", dense * 1e3);
    rep.rec("eq6_gemm", "dense", dense);
    for budget in [0.05, 0.1, 0.25, 0.5] {
        let scores = uavjp::sketch::column_scores("l1", &g, None);
        let p = pstar_from_weights(&scores, budget * dout as f64);
        let z = correlated_bernoulli(&mut rng, &p);
        let kept = kept_columns(&z, &p);
        let t = time_median(5, || {
            sparse_dx_into(g.view(), &kept, w.view(), dx.view_mut());
            sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
        });
        println!(
            "  sketched p={budget:<4} ({} cols kept): {:.2} ms  (ρ_wall = {:.3})",
            kept.len(),
            t * 1e3,
            t / dense
        );
        rep.rec("eq6_gemm", format!("sketched_p{budget}"), t);
    }
}

fn bench_pipeline(filter: &str, rep: &mut Report) {
    if !"pipeline".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== pipeline (simulated 4-stage GPipe, comm-bound regime) ==");
    let mut cfg = PipelineConfig::uniform(4, 2048, 64, 8, 1.0);
    cfg.bandwidth = 0.125e9;
    let exact = simulate(&cfg);
    for budget in [0.05, 0.1, 0.2, 0.5, 1.0] {
        cfg.budget = budget;
        let r = simulate(&cfg);
        println!(
            "  p={budget:<4}: step {:8.3} ms, bwd traffic {:7.2} MB, speedup {:.2}x",
            r.total_time * 1e3,
            r.backward_bytes / 1e6,
            exact.total_time / r.total_time
        );
        rep.rec("pipeline", format!("p{budget}"), r.total_time);
    }
}

fn bench_substrates(filter: &str, rep: &mut Report) {
    if !"substrates".contains(filter) && !filter.is_empty() {
        return;
    }
    println!("\n== substrates ==");
    let mut rng = Pcg64::new(9, 0);
    let w: Vec<f32> = (0..4096).map(|_| (rng.gaussian() as f32).abs()).collect();
    let t = time_median(20, || {
        let _ = pstar_from_weights(&w, 409.6);
    });
    println!("  pstar_from_weights(n=4096): {:.1} µs", t * 1e6);
    rep.rec("substrates", "pstar_4096", t);
    let p = pstar_from_weights(&w, 409.6);
    let t = time_median(20, || {
        let _ = correlated_bernoulli(&mut rng, &p);
    });
    println!("  correlated_bernoulli(n=4096): {:.1} µs", t * 1e6);
    rep.rec("substrates", "correlated_4096", t);
    // JSON parse throughput on the manifest
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        let t = time_median(10, || {
            let _ = uavjp::json::parse(&text).unwrap();
        });
        println!(
            "  json parse manifest ({} KiB): {:.2} ms ({:.1} MiB/s)",
            text.len() / 1024,
            t * 1e3,
            text.len() as f64 / t / 1e6
        );
        rep.rec("substrates", "json_parse_manifest", t);
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut json_path: Option<String> = None;
    let mut filter = String::new();
    let mut i = 0;
    while i < argv.len() {
        if argv[i] == "--json" {
            if i + 1 < argv.len() {
                json_path = Some(argv[i + 1].clone());
                i += 2;
                continue;
            }
            eprintln!("--json expects a path, e.g. --json results/BENCH_native.json");
            std::process::exit(2);
        }
        if !argv[i].starts_with('-') && filter.is_empty() {
            filter = argv[i].clone();
        }
        i += 1;
    }
    println!("uavjp bench harness (median-of-N, warmup excluded)");
    let mut rep = Report::default();
    bench_gemm_scaling(&filter, &mut rep);
    bench_native_bwd(&filter, &mut rep);
    bench_native_step(&filter, &mut rep);
    bench_native_models(&filter, &mut rep);
    bench_native_memory(&filter, &mut rep);
    bench_serve_throughput(&filter, &mut rep);
    bench_dp_scaling(&filter, &mut rep);
    bench_step_latency(&filter, &mut rep);
    bench_eq6_gemm(&filter, &mut rep);
    bench_pipeline(&filter, &mut rep);
    bench_substrates(&filter, &mut rep);
    if let Some(path) = json_path {
        if let Some(dir) = std::path::Path::new(&path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).expect("create results dir");
            }
        }
        std::fs::write(&path, uavjp::json::to_string_pretty(&rep.to_json()))
            .expect("write bench json");
        println!("\nwrote {} bench records to {path}", rep.records.len());
    }
}
