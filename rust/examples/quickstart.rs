//! Quickstart: train sketched models on the native backend and compare
//! against the exact-VJP baseline — no artifacts, no python, no setup.
//!
//! Trains the MLP (synth-MNIST) and then BagNet-lite (synth-CIFAR) through
//! the same `Layer`/`SketchPolicy` module API.
//!
//! Run with:  cargo run --release --example quickstart

use anyhow::Result;
use uavjp::config::{Preset, TrainConfig};
use uavjp::native::NativeTrainer;

fn main() -> Result<()> {
    let mut base: TrainConfig = Preset::Smoke.base("mlp")?;
    base.steps = 400;
    base.eval_every = 100;

    println!("— mlp (synth-MNIST) —");
    for (method, budget) in [("baseline", 1.0), ("l1", 0.15)] {
        run_one(&base, method, budget)?;
    }

    let mut bag: TrainConfig = Preset::Smoke.base("bagnet")?;
    bag.train_size = 512;
    bag.test_size = 128;
    bag.steps = 120;
    bag.eval_every = 60;
    bag.batch = 32;
    println!("\n— bagnet (synth-CIFAR, 8×8 patch convs) —");
    for (method, budget) in [("baseline", 1.0), ("l1", 0.25)] {
        run_one(&bag, method, budget)?;
    }

    println!("\nSketched runs keep a fraction of backward columns yet track the exact");
    println!("baseline — the paper's headline effect, here on two of its three");
    println!("architectures. Try `--model vit` via examples/train_native.rs, and");
    println!("`uavjp fig1b` / `uavjp fig3` for the full figure protocol.");
    Ok(())
}

fn run_one(base: &TrainConfig, method: &str, budget: f64) -> Result<()> {
    let mut cfg = base.clone();
    cfg.method = method.to_string();
    cfg.budget = budget;
    cfg.location = if method == "baseline" { "none".into() } else { "all".into() };
    let mut trainer = NativeTrainer::new(cfg)?;
    let t0 = std::time::Instant::now();
    let curve = trainer.run()?;
    println!(
        "{method:>9} (p={budget}): loss {:.3} → {:.3}, test acc {:.3}  [{:.1}s]",
        curve.losses.first().copied().unwrap_or(f64::NAN),
        curve.tail_loss(10).unwrap_or(f64::NAN),
        curve.final_acc().unwrap_or(f64::NAN),
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}
