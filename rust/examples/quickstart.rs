//! Quickstart: train a sketched MLP on the native backend and compare it
//! against the exact-VJP baseline — no artifacts, no python, no setup.
//!
//! Run with:  cargo run --release --example quickstart

use anyhow::Result;
use uavjp::config::{Preset, TrainConfig};
use uavjp::native::NativeTrainer;

fn main() -> Result<()> {
    let mut base: TrainConfig = Preset::Smoke.base("mlp");
    base.steps = 400;
    base.eval_every = 100;

    for (method, budget) in [("baseline", 1.0), ("l1", 0.15)] {
        let mut cfg = base.clone();
        cfg.method = method.to_string();
        cfg.budget = budget;
        cfg.location = if method == "baseline" { "none".into() } else { "all".into() };
        let mut trainer = NativeTrainer::new(cfg)?;
        let t0 = std::time::Instant::now();
        let curve = trainer.run()?;
        println!(
            "{method:>9} (p={budget}): loss {:.3} → {:.3}, test acc {:.3}  [{:.1}s]",
            curve.losses.first().copied().unwrap_or(f64::NAN),
            curve.tail_loss(10).unwrap_or(f64::NAN),
            curve.final_acc().unwrap_or(f64::NAN),
            t0.elapsed().as_secs_f64(),
        );
    }
    println!("\nThe ℓ1 sketch keeps 15% of backward columns yet trains close to baseline —");
    println!("the paper's headline effect. See `uavjp fig1b` for the full comparison,");
    println!("and examples/train_native.rs for the budget sweep.");
    Ok(())
}
