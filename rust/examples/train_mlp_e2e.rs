//! End-to-end driver (deliverable (b)/EXPERIMENTS.md §E2E): train the MLP on
//! synth-MNIST for several hundred steps with the ℓ1 sketch at p = 0.1,
//! logging the loss curve and periodic test evaluations, then verify the
//! run met its acceptance bars (loss decreased, accuracy over 80%).
//!
//! This proves all three layers compose: the Pallas sketched-backward kernel
//! (L1) inside the JAX train-step graph (L2), AOT-compiled to HLO text and
//! driven entirely from rust through PJRT (L3) — python never runs here.
//!
//! Run with:  cargo run --release --example train_mlp_e2e [-- --steps N]

use anyhow::{bail, Result};
use uavjp::cli::Args;
use uavjp::config::{Preset, TrainConfig};
use uavjp::coordinator::Trainer;
use uavjp::json::{self, Value};
use uavjp::runtime::Runtime;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rt = Runtime::open_default()?;

    let mut cfg: TrainConfig = Preset::Ci.base("mlp")?;
    cfg.method = "l1".into();
    cfg.budget = 0.1;
    cfg.steps = args.usize_or("steps", 480)?;
    cfg.eval_every = args.usize_or("eval-every", 96)?;
    cfg.train_size = 4096;
    cfg.test_size = 1024;
    cfg.lr = args.f64_or("lr", 0.1)?;

    eprintln!(
        "[e2e] training {} / {} (p={}) for {} steps on synth-MNIST (4096 train / 1024 test)",
        cfg.model, cfg.method, cfg.budget, cfg.steps
    );
    let trainer = Trainer::new(&rt, cfg.clone())?;
    let t0 = std::time::Instant::now();
    let curve = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("step,loss");
    for (s, l) in curve.steps.iter().zip(&curve.losses) {
        if s % 20 == 0 {
            println!("{s},{l:.4}");
        }
    }
    println!("\nevals (step, test_loss, test_acc):");
    for (s, l, a) in &curve.evals {
        println!("  {s:>5}  {l:.4}  {a:.4}");
    }
    let first = curve.losses.first().copied().unwrap_or(f64::NAN);
    let last = curve.tail_loss(20).unwrap_or(f64::NAN);
    let acc = curve.final_acc().unwrap_or(0.0);
    println!(
        "\nloss {first:.3} → {last:.3}; final test acc {acc:.3}; {:.1} steps/s over {wall:.0}s",
        curve.losses.len() as f64 / wall
    );

    // persist the run record (EXPERIMENTS.md §E2E points at this file)
    std::fs::create_dir_all("results")?;
    let rec = Value::obj(vec![
        ("config", cfg.to_json()),
        ("curve", curve.to_json()),
        ("wall_seconds", Value::num(wall)),
    ]);
    std::fs::write("results/e2e_mlp.json", json::to_string_pretty(&rec))?;
    eprintln!("wrote results/e2e_mlp.json");

    // acceptance bars
    if !(last < 0.6 * first) {
        bail!("loss did not decrease enough: {first:.3} → {last:.3}");
    }
    if acc < 0.8 {
        bail!("final accuracy too low: {acc:.3}");
    }
    println!("E2E OK");
    Ok(())
}
