//! Sketch playground: the paper's §3 math on a concrete matrix, native rust.
//!
//! Walks through Algorithm 1 (waterfilling) and Algorithm 2 (correlated
//! exact-r sampling) on an anisotropic gradient matrix, verifies
//! unbiasedness and the distortion ordering of Lemma 3.4 empirically, and
//! shows the FLOP savings of the kept-column backward (the ρ(V) of Eq. 6).
//!
//! Run with:  cargo run --release --example sketch_playground

use uavjp::rng::Pcg64;
use uavjp::sketch::{
    backward_flops, column_scores, correlated_bernoulli, kept_columns,
    pstar_from_weights,
};
use uavjp::tensor::{dense_backward, sparse_dw, sparse_dx, Mat};

fn main() {
    let mut rng = Pcg64::new(42, 0);
    let (b, dout, din) = (64usize, 32usize, 48usize);

    // anisotropic gradient: a few dominant columns, like real backprop
    let g = Mat::from_fn(b, dout, |_, j| {
        let scale = if j < 4 { 3.0 } else { 0.3 };
        rng.gaussian() as f32 * scale
    });
    let x = Mat::from_fn(b, din, |_, _| rng.gaussian() as f32);
    let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32 * 0.2);

    // Algorithm 1: ℓ1 scores → optimal probabilities at budget p = 0.25
    let scores = column_scores("l1", &g, None);
    let r = 0.25 * dout as f64;
    let p = pstar_from_weights(&scores, r);
    println!("budget r = {r}; top-4 probabilities: {:?}", &p[..4]);
    println!("tail probability (col 20): {:.4}", p[20]);

    // Algorithm 2: exact-r correlated sampling, unbiasedness check
    let trials = 20000;
    let mut freq = vec![0.0f64; dout];
    for _ in 0..trials {
        let z = correlated_bernoulli(&mut rng, &p);
        for (f, zi) in freq.iter_mut().zip(&z) {
            if *zi {
                *f += 1.0;
            }
        }
    }
    let max_dev = freq
        .iter()
        .zip(&p)
        .map(|(f, &pi)| (f / trials as f64 - pi as f64).abs())
        .fold(0.0, f64::max);
    println!("max |empirical freq − p_i| over {trials} trials: {max_dev:.4}");

    // distortion: ℓ1-waterfilled vs uniform per-column masks (Lemma 3.4)
    let (dx_exact, dw_exact) = dense_backward(&g, &x, &w);
    let mut err_l1 = 0.0;
    let mut err_uni = 0.0;
    let p_uni = vec![(r / dout as f64) as f32; dout];
    for _ in 0..200 {
        let z = correlated_bernoulli(&mut rng, &p);
        let kept = kept_columns(&z, &p);
        err_l1 += sparse_dx(&g, &kept, &w).sub(&dx_exact).frob_sq();
        let z = correlated_bernoulli(&mut rng, &p_uni);
        let kept = kept_columns(&z, &p_uni);
        err_uni += sparse_dx(&g, &kept, &w).sub(&dx_exact).frob_sq();
    }
    println!(
        "dX distortion, 200 draws:  ℓ1-waterfilled {:.1}  vs uniform {:.1}  ({:.1}× lower)",
        err_l1 / 200.0,
        err_uni / 200.0,
        err_uni / err_l1
    );

    // FLOP savings (Eq 6's ρ): kept-column backward vs dense
    let kept_n = (r.round() as usize).max(1);
    println!(
        "backward FLOPs: dense {:.2e}  sketched {:.2e}  (ρ = {:.3})",
        backward_flops(b, dout, din, dout),
        backward_flops(b, dout, din, kept_n),
        backward_flops(b, dout, din, kept_n) / backward_flops(b, dout, din, dout)
    );

    // sanity: sparse kernels with all columns kept match the dense backward
    let all: Vec<(usize, f32)> = (0..dout).map(|j| (j, 1.0)).collect();
    let dmax = sparse_dw(&g, &all, &x)
        .sub(&dw_exact)
        .data
        .iter()
        .fold(0.0f32, |m, &v| m.max(v.abs()));
    println!("sparse-vs-dense max |Δ| with full budget: {dmax:e}");
}
