//! Native-backend driver: train any registered model across sketch budgets
//! and report the accuracy/loss/wall-clock trade-off — the paper's headline
//! table, entirely on CPU-native kernels (no artifacts, no python).
//!
//! Run with:  cargo run --release --example train_native
//!            [-- --model mlp|bagnet|vit --method l1 --budgets 0.1,0.25,0.5
//!                --steps 400 --seed 0]

use anyhow::Result;
use uavjp::cli::Args;
use uavjp::config::{Preset, TrainConfig};
use uavjp::native::NativeTrainer;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.str_or("model", "mlp");
    let method = args.str_or("method", "l1");
    let budgets = args.f64_list_or("budgets", &[0.1, 0.25, 0.5])?;

    let mut base: TrainConfig = Preset::Smoke.base(&model)?;
    base.steps = args.usize_or("steps", if model == "mlp" { 400 } else { 120 })?;
    base.eval_every = (base.steps / 4).max(1);
    base.seed = args.usize_or("seed", 0)? as u64;
    base.lr = args.f64_or("lr", base.lr)?;

    // exact-backward reference
    let mut cfg = base.clone();
    cfg.method = "baseline".into();
    cfg.location = "none".into();
    let (exact_curve, exact_secs) = timed_run(cfg)?;
    let exact_loss = exact_curve.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
    println!(
        "model: {model}\n{:>10} {:>8} {:>10} {:>9} {:>9} {:>9}",
        "method", "budget", "eval_loss", "acc", "seconds", "vs exact"
    );
    println!(
        "{:>10} {:>8} {exact_loss:>10.4} {:>9.3} {exact_secs:>9.1} {:>9}",
        "baseline",
        "1.0",
        exact_curve.final_acc().unwrap_or(f64::NAN),
        "1.00x"
    );

    for &budget in &budgets {
        let mut cfg = base.clone();
        cfg.method = method.clone();
        cfg.budget = budget;
        let (curve, secs) = timed_run(cfg)?;
        let eval_loss = curve.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
        println!(
            "{method:>10} {budget:>8} {eval_loss:>10.4} {:>9.3} {secs:>9.1} {:>8.2}x",
            curve.final_acc().unwrap_or(f64::NAN),
            exact_secs / secs
        );
    }
    println!(
        "\nSketched runs track the exact eval loss while the backward touches only\n\
         a p-fraction of gradient columns (Eq 6's ρ(V)); `cargo bench native_bwd`\n\
         isolates the per-layer kernel speedup at larger widths."
    );
    Ok(())
}

fn timed_run(cfg: TrainConfig) -> Result<(uavjp::metrics::RunCurve, f64)> {
    let mut trainer = NativeTrainer::new(cfg)?;
    let t0 = std::time::Instant::now();
    let curve = trainer.run()?;
    Ok((curve, t0.elapsed().as_secs_f64()))
}
