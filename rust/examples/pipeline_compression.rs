//! Pipeline-parallel activation-gradient compression (paper motivation (i)).
//!
//! Sweeps the sketch budget over a simulated 4-stage GPipe pipeline under
//! three bandwidth regimes and prints the step time / traffic / speedup
//! table — the systems-level payoff of unbiased backward compression.
//!
//! Run with:  cargo run --release --example pipeline_compression

use uavjp::pipeline::{budget_sweep, simulate, PipelineConfig};

fn main() {
    let budgets = [0.05, 0.1, 0.2, 0.5, 1.0];
    for (label, bw) in [
        ("datacenter NIC 100 Gb/s", 12.5e9),
        ("commodity 10 Gb/s", 1.25e9),
        ("cross-region 1 Gb/s", 0.125e9),
    ] {
        let mut cfg = PipelineConfig::uniform(4, 2048, 64, 8, 1.0);
        cfg.bandwidth = bw;
        let exact = simulate(&cfg);
        println!("\n=== {label} ===");
        println!(
            "{:>7} {:>12} {:>9} {:>13} {:>9}",
            "budget", "step_time_ms", "bubble", "bwd_traffic_MB", "speedup"
        );
        for (b, rep) in budget_sweep(&cfg, &budgets) {
            println!(
                "{:>7} {:>12.3} {:>9.3} {:>13.3} {:>8.2}x",
                b,
                rep.total_time * 1e3,
                rep.bubble_fraction,
                rep.backward_bytes / 1e6,
                exact.total_time / rep.total_time
            );
        }
    }
    println!(
        "\nBackward compression matters exactly when links are slow relative to \
         compute — the crossover the paper's §1(i) predicts."
    );
}
