//! Offline stub of the `xla` crate (xla_extension PJRT bindings).
//!
//! The real crate links libxla_extension, which this build environment does
//! not ship (DESIGN.md §7). This stub reproduces exactly the API surface the
//! `pjrt` feature of `uavjp` compiles against so the PJRT code paths stay
//! type-checked; every runtime entry point returns an [`Error`] explaining
//! that PJRT is unavailable. Swap this path dependency for the real
//! `xla = "0.5"` on a machine with the toolchain to actually execute AOT
//! artifacts.

use std::fmt;

/// Stub error: carries the "PJRT unavailable" message.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable — uavjp was built against the offline `xla` \
         stub (rust/vendor/xla). Point Cargo at the real xla crate to run \
         AOT artifacts (DESIGN.md §7)."
    )))
}

/// Element dtypes of the artifacts we emit (subset of the real enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Host-side scalar types accepted by [`Literal::vec1`] / [`Literal::to_vec`].
pub trait NativeType: Copy {
    /// dtype tag of this host type.
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}

/// Array shape: dtype + dims.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element dtype.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal (stub: shape metadata only, no buffer).
#[derive(Debug)]
pub struct Literal {
    shape: Option<ArrayShape>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            shape: Some(ArrayShape { ty: T::TY, dims: vec![data.len() as i64] }),
        }
    }

    /// Reshape to `dims` (stub: metadata-only copy).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let ty = self.shape.as_ref().map(|s| s.ty).unwrap_or(ElementType::F32);
        Ok(Literal { shape: Some(ArrayShape { ty, dims: dims.to_vec() }) })
    }

    /// Shape of an array (non-tuple) literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.shape {
            Some(s) => Ok(s.clone()),
            None => unavailable("Literal::array_shape"),
        }
    }

    /// Copy out as a host vector. Always errors in the stub.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// Decompose a tuple literal. Always errors in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO text from a file. Always errors in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Synchronize to a host literal. Always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute on device. Always errors in the stub.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Open the CPU client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation. Always errors in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}
