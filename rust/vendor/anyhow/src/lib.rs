//! Offline stand-in for the `anyhow` crate (DESIGN.md §6).
//!
//! The build environment has no network access and no vendored registry, so
//! this path dependency provides the slice of anyhow's API the crate uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros and the
//! [`Context`] extension trait. Errors are string-backed: source chains are
//! flattened into the message at conversion time, which is all the binaries
//! ever do with them (print and exit).

use std::error::Error as StdError;
use std::fmt;

/// String-backed error type. Like `anyhow::Error` it deliberately does NOT
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context line: `"{context}: {inner}"`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug is what `fn main() -> Result<()>` prints on exit; keep it readable.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut msg = err.to_string();
        let mut src = err.source();
        while let Some(cause) = src {
            msg.push_str(": ");
            msg.push_str(&cause.to_string());
            src = cause.source();
        }
        Error { msg }
    }
}

/// Drop-in for `anyhow::Result`: the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::{Error, StdError};

    /// Sealed conversion helper so [`super::Context`] has a single impl that
    /// covers both `Result<T, impl std::error::Error>` and
    /// `Result<T, Error>` without overlapping.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach human context to a failing `Result` or empty `Option`.
pub trait Context<T, E> {
    /// Eagerly-evaluated context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Lazily-evaluated context (use when formatting is nontrivial).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!`: build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `bail!`: early-return `Err(anyhow!(...))` from the enclosing function.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(s)
    }

    #[test]
    fn from_std_error_and_context() {
        let err = fails_io().context("loading config").unwrap_err();
        let text = format!("{err}");
        assert!(text.starts_with("loading config: "), "{text}");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let base: Result<()> = Err(anyhow!("inner {}", 7));
        let err = base.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{err}"), "outer 1: inner 7");
        let none: Option<u32> = None;
        let err = none.context("missing").unwrap_err();
        assert_eq!(format!("{err}"), "missing");
    }

    #[test]
    fn bail_returns_error() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
    }
}
