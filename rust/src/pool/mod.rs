//! Scoped thread-pool substrate (std::thread; no rayon/tokio offline).
//!
//! Four parallel primitives share it:
//!
//! * [`parallel_map`] — coarse task fan-out (the coordinator's sweeps);
//! * [`run_row_chunks`] — intra-op row partitioning for the tensor
//!   kernels (`tensor::gemm_into` and friends). Each worker owns a
//!   contiguous block of output rows and computes it in exactly the order
//!   the single-threaded path would, so results are bit-identical for
//!   every worker count (the kernel-API contract `tests/gemm_kernels.rs`
//!   pins down);
//! * [`run_row_chunks_with`] — the same partitioning with one mutable
//!   scratch state per worker (the packed SIMD GEMM's A-panel buffers);
//! * [`run_dynamic`] — a work queue for skew-prone item lists
//!   (`tensor::sparse_dw_into`'s kept-row chunks), preserving per-item
//!   determinism while letting fast workers steal the tail;
//! * [`run_source`] — the generalization `run_dynamic` is built on:
//!   workers pull from a caller-provided (possibly blocking) source until
//!   it yields `None`. The inference batcher (`crate::serve`) plugs its
//!   deadline-coalescing request queue in as the source.
//!
//! The intra-op worker count is a process-global set once at startup from
//! `--threads` / `TrainConfig::threads` ([`set_threads`]; `0` = auto).
//! On this single-core testbed both primitives degrade gracefully to
//! near-sequential execution, but the structure matches what a multi-core
//! deployment would use, and the unit tests exercise real concurrency.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Typed error for a worker closure that panicked: the panic is caught
/// at the worker boundary ([`try_run_replicas`] / [`try_parallel_map`])
/// so one dying replica degrades the step instead of unwinding the whole
/// run (DESIGN.md §7.7). When several workers panic, the smallest worker
/// index wins (deterministic reporting).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerPanicked {
    /// Index of the panicking worker (replica index for
    /// [`try_run_replicas`], item index for [`try_parallel_map`]).
    pub worker: usize,
    /// The panic payload, when it was a string (the common case).
    pub msg: String,
}

impl std::fmt::Display for WorkerPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker {} panicked: {}", self.worker, self.msg)
    }
}

impl std::error::Error for WorkerPanicked {}

/// Best-effort string form of a caught panic payload.
fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Intra-op worker count for the tensor kernels (see [`set_threads`]).
static KERNEL_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the intra-op worker count used by the tensor kernels. `0` resolves
/// to [`default_workers`] (auto); any other value is taken literally.
/// Results are bit-identical for every setting — this is purely a
/// wall-clock knob.
pub fn set_threads(n: usize) {
    let resolved = if n == 0 { default_workers() } else { n };
    KERNEL_THREADS.store(resolved.max(1), Ordering::Relaxed);
}

/// Current intra-op worker count (≥ 1).
pub fn threads() -> usize {
    KERNEL_THREADS.load(Ordering::Relaxed).max(1)
}

/// Split a `rows × cols` row-major buffer into up to `workers` contiguous
/// row blocks and run `f(first_row, block)` on each, concurrently when
/// `workers > 1`. Every row is written by exactly one worker, in the same
/// within-row order as the sequential path, so the result is independent
/// of `workers`.
pub fn run_row_chunks<F>(workers: usize, rows: usize, cols: usize, data: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    // one shared implementation (ZST states are free), so the
    // bit-identity-across-worker-counts contract lives in exactly one
    // chunking routine
    let mut states = vec![(); workers.max(1)];
    run_row_chunks_with(workers, rows, cols, data, &mut states, |i0, chunk, _| {
        f(i0, chunk)
    });
}

/// [`run_row_chunks`] with one caller-provided state per worker (e.g. the
/// packed-GEMM A-panel buffers): `f(first_row, block, state)` where each
/// spawned worker owns one entry of `states`. At most
/// `min(workers, states.len())` workers run; the states of unspawned
/// workers are untouched. The bit-identity contract of [`run_row_chunks`]
/// carries over — states must only hold scratch whose contents do not
/// alter results.
pub fn run_row_chunks_with<S, F>(
    workers: usize,
    rows: usize,
    cols: usize,
    data: &mut [f32],
    states: &mut [S],
    f: F,
) where
    S: Send,
    F: Fn(usize, &mut [f32], &mut S) + Sync,
{
    assert_eq!(data.len(), rows * cols, "row-chunk buffer size");
    assert!(!states.is_empty(), "need at least one worker state");
    if rows == 0 || cols == 0 {
        return;
    }
    let workers = workers.clamp(1, rows).min(states.len());
    if workers == 1 {
        f(0, data, &mut states[0]);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        for ((ci, chunk), st) in data
            .chunks_mut(chunk_rows * cols)
            .enumerate()
            .zip(states.iter_mut())
        {
            let f = &f;
            scope.spawn(move || f(ci * chunk_rows, chunk, st));
        }
    });
}

/// Dynamic work queue: `states.len()` workers pull `items` one at a time
/// from a shared queue and run `f(item, state)`. Use when per-item cost is
/// skewed (e.g. waterfilling-budget row chunks) so a slow item can't
/// serialize the whole batch behind one worker.
///
/// `items` is any owned iterable — a `Vec`, or (on the zero-allocation
/// hot paths, DESIGN.md §7.2) a draining iterator over a stack array, so
/// callers never have to materialize a heap-backed work list.
///
/// Determinism contract: which worker processes an item is
/// non-deterministic, so `f` must write only item-owned data and each
/// item's result must not depend on processing order — then results are
/// identical for every worker count and schedule.
pub fn run_dynamic<T, S, F, I>(items: I, states: &mut [S], f: F)
where
    I: IntoIterator<Item = T>,
    I::IntoIter: ExactSizeIterator + Send,
    T: Send,
    S: Send,
    F: Fn(T, &mut S) + Sync,
{
    assert!(!states.is_empty(), "need at least one worker state");
    let items = items.into_iter();
    if items.len() == 0 {
        return;
    }
    let workers = states.len().min(items.len());
    if workers == 1 {
        for it in items {
            f(it, &mut states[0]);
        }
        return;
    }
    let queue = Mutex::new(items);
    run_source(
        || queue.lock().unwrap_or_else(|e| e.into_inner()).next(),
        &mut states[..workers],
        f,
    );
}

/// Source-driven work queue: `states.len()` workers repeatedly pull items
/// from `next` — any shared `Fn() -> Option<T>`, e.g. a lock-guarded
/// iterator ([`run_dynamic`]) or a blocking, deadline-coalescing request
/// queue (`crate::serve::RequestQueue`) — and run `f(item, state)` until
/// the source yields `None`. With a single state everything runs inline
/// on the caller's thread.
///
/// The determinism contract of [`run_dynamic`] carries over: which worker
/// handles an item is non-deterministic, so `f` must write only item-owned
/// data and per-item results must not depend on processing order.
///
/// Termination: `None` must be terminal — once the source returns `None`
/// to any worker it must keep returning `None` promptly to all of them
/// (without blocking), or the scope never joins.
pub fn run_source<T, S, N, F>(next: N, states: &mut [S], f: F)
where
    T: Send,
    S: Send,
    N: Fn() -> Option<T> + Sync,
    F: Fn(T, &mut S) + Sync,
{
    assert!(!states.is_empty(), "need at least one worker state");
    if states.len() == 1 {
        while let Some(it) = next() {
            f(it, &mut states[0]);
        }
        return;
    }
    std::thread::scope(|scope| {
        for st in states.iter_mut() {
            let (f, next) = (&f, &next);
            scope.spawn(move || {
                while let Some(it) = next() {
                    f(it, &mut *st);
                }
            });
        }
    });
}

/// Replica-level fan-out: run `f(index, state)` once per entry of
/// `states`, each on its own scoped thread (inline when there is only
/// one). This is the data-parallel trainer's outer axis — one state per
/// model replica, coarser than the row-chunking the tensor kernels use
/// *inside* each replica's GEMMs. Replica results must be combined by the
/// caller in a fixed order afterwards; the fan-out itself imposes no
/// ordering, so `f` must write only replica-owned data.
pub fn run_replicas<S, F>(states: &mut [S], f: F)
where
    S: Send,
    F: Fn(usize, &mut S) + Sync,
{
    if let Err(e) = try_run_replicas(states, f) {
        panic!("{e}");
    }
}

/// Panic-isolated [`run_replicas`]: every replica closure runs inside
/// `catch_unwind`, so one panicking replica surfaces as a typed
/// [`WorkerPanicked`] (smallest replica index wins) while the other
/// replicas finish their work undisturbed — the hook `ReplicaGroup`'s
/// degraded mode builds on.
pub fn try_run_replicas<S, F>(
    states: &mut [S],
    f: F,
) -> Result<(), WorkerPanicked>
where
    S: Send,
    F: Fn(usize, &mut S) + Sync,
{
    let panics: Vec<Mutex<Option<String>>> =
        states.iter().map(|_| Mutex::new(None)).collect();
    let run = |i: usize, st: &mut S| {
        // AssertUnwindSafe: on panic the caller either aborts the run or
        // discards the replica's lane outputs, so torn state is never read
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i, st))) {
            *panics[i].lock().unwrap_or_else(|e| e.into_inner()) =
                Some(panic_msg(p));
        }
    };
    if states.len() == 1 {
        run(0, &mut states[0]);
    } else {
        std::thread::scope(|scope| {
            for (i, st) in states.iter_mut().enumerate() {
                let run = &run;
                scope.spawn(move || run(i, st));
            }
        });
    }
    for (i, p) in panics.iter().enumerate() {
        if let Some(msg) = p.lock().unwrap_or_else(|e| e.into_inner()).take() {
            return Err(WorkerPanicked { worker: i, msg });
        }
    }
    Ok(())
}

/// Map `f` over `items` with up to `workers` OS threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    match try_parallel_map(items, workers, f) {
        Ok(out) => out,
        Err(e) => panic!("{e}"),
    }
}

/// Panic-isolated [`parallel_map`]: item closures run inside
/// `catch_unwind`, every non-panicking item still completes, and the
/// first panic (smallest item index) comes back as a typed
/// [`WorkerPanicked`] instead of unwinding the caller.
pub fn try_parallel_map<T, R, F>(
    items: Vec<T>,
    workers: usize,
    f: F,
) -> Result<Vec<R>, WorkerPanicked>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1).min(n);
    let results: Vec<Mutex<Option<Result<R, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let run = |i: usize| {
        // AssertUnwindSafe: a panicking item's result slot stays None /
        // Err and is never read as a value
        let r = catch_unwind(AssertUnwindSafe(|| f(&items[i])))
            .map_err(panic_msg);
        *results[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
    };
    if workers == 1 {
        for i in 0..n {
            run(i);
        }
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let (run, next) = (&run, &next);
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    run(i);
                });
            }
        });
    }
    let mut out = Vec::with_capacity(n);
    for (i, m) in results.into_iter().enumerate() {
        match m.into_inner().unwrap_or_else(|e| e.into_inner()) {
            Some(Ok(r)) => out.push(r),
            Some(Err(msg)) => return Err(WorkerPanicked { worker: i, msg }),
            None => unreachable!("item {i} neither completed nor panicked"),
        }
    }
    Ok(out)
}

/// Number of workers to use by default (leave one core for the OS).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map((0..100).collect(), 4, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 8, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(vec![5], 16, |&x| x * x);
        assert_eq!(out, vec![25]);
    }

    #[test]
    fn row_chunks_cover_every_row_once() {
        for workers in [1usize, 2, 3, 8, 100] {
            let rows = 7usize;
            let cols = 3usize;
            let mut data = vec![0.0f32; rows * cols];
            run_row_chunks(workers, rows, cols, &mut data, |row0, chunk| {
                for (li, row) in chunk.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + li) as f32 + 1.0;
                    }
                }
            });
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(data[i * cols + j], i as f32 + 1.0, "w={workers}");
                }
            }
        }
    }

    #[test]
    fn row_chunks_degenerate_shapes_are_noops() {
        let mut empty: Vec<f32> = Vec::new();
        run_row_chunks(4, 0, 5, &mut empty, |_, _| panic!("no rows"));
        run_row_chunks(4, 5, 0, &mut empty, |_, _| panic!("no cols"));
    }

    #[test]
    fn stateful_row_chunks_cover_rows_and_use_worker_states() {
        for workers in [1usize, 2, 3, 8] {
            let rows = 7usize;
            let cols = 3usize;
            let mut data = vec![0.0f32; rows * cols];
            let mut states = vec![0usize; workers];
            run_row_chunks_with(workers, rows, cols, &mut data, &mut states, |row0, chunk, st| {
                *st += chunk.len() / cols;
                for (li, row) in chunk.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v = (row0 + li) as f32;
                    }
                }
            });
            for i in 0..rows {
                assert_eq!(data[i * cols], i as f32, "w={workers}");
            }
            assert_eq!(states.iter().sum::<usize>(), rows, "w={workers}");
        }
    }

    #[test]
    fn dynamic_queue_processes_every_item_exactly_once() {
        for workers in [1usize, 2, 5] {
            let items: Vec<usize> = (0..23).collect();
            let done: Vec<Mutex<usize>> = (0..23).map(|_| Mutex::new(0)).collect();
            let mut states = vec![(); workers];
            run_dynamic(items, &mut states, |i, _| {
                *done[i].lock().unwrap() += 1;
            });
            for (i, d) in done.iter().enumerate() {
                assert_eq!(*d.lock().unwrap(), 1, "item {i} w={workers}");
            }
        }
        // empty input is a no-op
        run_dynamic(Vec::<usize>::new(), &mut [()], |_, _| panic!("no items"));
    }

    #[test]
    fn source_queue_drains_and_terminates() {
        for workers in [1usize, 2, 5] {
            let next_ix = AtomicUsize::new(0);
            let done: Vec<Mutex<usize>> = (0..17).map(|_| Mutex::new(0)).collect();
            let mut states = vec![(); workers];
            run_source(
                || {
                    let i = next_ix.fetch_add(1, Ordering::Relaxed);
                    (i < 17).then_some(i)
                },
                &mut states,
                |i, _| {
                    *done[i].lock().unwrap() += 1;
                },
            );
            for (i, d) in done.iter().enumerate() {
                assert_eq!(*d.lock().unwrap(), 1, "item {i} w={workers}");
            }
        }
        // an immediately-exhausted source is a no-op
        run_source(|| None::<usize>, &mut [()], |_, _| panic!("no items"));
    }

    #[test]
    fn replica_fanout_runs_every_state_once_with_its_index() {
        for n in [1usize, 2, 4, 8] {
            let mut states: Vec<(usize, usize)> =
                (0..n).map(|_| (0, 0)).collect();
            run_replicas(&mut states, |i, st| {
                st.0 += 1;
                st.1 = i * 10;
            });
            for (i, st) in states.iter().enumerate() {
                assert_eq!(*st, (1, i * 10), "n={n}");
            }
        }
    }

    #[test]
    fn panicking_replica_surfaces_typed_and_spares_the_others() {
        for n in [1usize, 4] {
            let mut states: Vec<usize> = vec![0; n];
            let err = try_run_replicas(&mut states, |i, st| {
                if i == n - 1 {
                    panic!("injected panic in replica {i}");
                }
                *st = i + 1;
            })
            .unwrap_err();
            assert_eq!(err.worker, n - 1);
            assert!(err.msg.contains("injected panic"), "{err}");
            // the surviving replicas' work landed
            for (i, st) in states.iter().enumerate().take(n - 1) {
                assert_eq!(*st, i + 1, "n={n}");
            }
        }
        // no panic → Ok, same semantics as run_replicas
        let mut states = vec![0usize; 3];
        try_run_replicas(&mut states, |i, st| *st = i).unwrap();
        assert_eq!(states, vec![0, 1, 2]);
    }

    #[test]
    fn panicking_map_item_surfaces_smallest_index() {
        let err = try_parallel_map((0..10).collect::<Vec<usize>>(), 4, |&x| {
            if x % 4 == 3 {
                panic!("bad item {x}");
            }
            x * 2
        })
        .unwrap_err();
        assert_eq!(err.worker, 3);
        assert!(err.msg.contains("bad item"), "{err}");
        let ok = try_parallel_map((0..10).collect::<Vec<usize>>(), 4, |&x| x)
            .unwrap();
        assert_eq!(ok, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn thread_knob_resolves_auto_and_explicit() {
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
        set_threads(1);
        assert_eq!(threads(), 1);
    }

    #[test]
    fn actually_concurrent() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        let _ = parallel_map((0..8).collect(), 4, |_| {
            let l = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(l, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        // with 4 workers at least 2 tasks should have overlapped
        assert!(PEAK.load(Ordering::SeqCst) >= 2);
    }
}
