//! Scoped thread-pool substrate (std::thread; no rayon/tokio offline).
//!
//! The coordinator uses `parallel_map` for sweep fan-out. On this single-core
//! testbed it degrades gracefully to near-sequential execution, but the
//! structure matches what a multi-core deployment would use, and the unit
//! tests exercise real concurrency.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` with up to `workers` OS threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker panicked"))
        .collect()
}

/// Number of workers to use by default (leave one core for the OS).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map((0..100).collect(), 4, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 8, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(vec![5], 16, |&x| x * x);
        assert_eq!(out, vec![25]);
    }

    #[test]
    fn actually_concurrent() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        let _ = parallel_map((0..8).collect(), 4, |_| {
            let l = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(l, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        // with 4 workers at least 2 tasks should have overlapped
        assert!(PEAK.load(Ordering::SeqCst) >= 2);
    }
}
