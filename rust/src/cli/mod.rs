//! Tiny CLI substrate (`clap` unavailable offline).
//!
//! Supports `binary <subcommand> [--flag value] [--switch] [positional...]`
//! with typed accessors, defaults, and a generated usage string. Typed
//! accessors return `Result` with a usage hint — a typo'd value surfaces
//! as a clean error instead of a panic/unwind.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut args = Args {
            subcommand: None,
            flags: BTreeMap::new(),
            switches: Vec::new(),
            positional: Vec::new(),
        };
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    /// Float flag with default; a non-numeric value is a clean error.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!(
                    "--{name} expects a number, got `{s}` (run with no \
                     arguments for usage)"
                ),
            },
        }
    }

    /// Integer flag with default; a non-integer value is a clean error.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => match s.parse() {
                Ok(v) => Ok(v),
                Err(_) => bail!(
                    "--{name} expects an integer, got `{s}` (run with no \
                     arguments for usage)"
                ),
            },
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Comma-separated list flag → Vec<f64>; a bad element is a clean error.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.str_opt(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|x| {
                    x.trim().parse().map_err(|_| {
                        anyhow::anyhow!(
                            "--{name}: bad number `{x}` (want a \
                             comma-separated list like 0.05,0.1,0.5)"
                        )
                    })
                })
                .collect(),
        }
    }

    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        // note: a bare token right after `--flag` is that flag's value, so
        // switches go last (documented parser semantics)
        let a = parse("train --model mlp --lr 0.1 extra --quiet");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("model", "x"), "mlp");
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
        assert!(a.has("quiet"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("fig1a --budgets=0.05,0.1,0.5");
        assert_eq!(a.f64_list_or("budgets", &[]).unwrap(), vec![0.05, 0.1, 0.5]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.str_or("m", "d"), "d");
        assert_eq!(a.f64_list_or("l", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn bad_values_error_cleanly_with_hint() {
        let a = parse("train --lr fast --steps many --budgets 0.1,zz");
        let err = format!("{}", a.f64_or("lr", 0.0).unwrap_err());
        assert!(err.contains("--lr") && err.contains("fast"), "{err}");
        let err = format!("{}", a.usize_or("steps", 1).unwrap_err());
        assert!(err.contains("integer"), "{err}");
        let err = format!("{}", a.f64_list_or("budgets", &[]).unwrap_err());
        assert!(err.contains("zz"), "{err}");
    }

    #[test]
    fn trailing_switch() {
        let a = parse("run --force");
        assert!(a.has("force"));
        assert!(a.flags.is_empty());
    }

    #[test]
    fn str_list() {
        let a = parse("x --methods l1,ds , --k v");
        assert_eq!(a.str_list_or("methods", &[]), vec!["l1", "ds"]);
    }
}
