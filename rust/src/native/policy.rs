//! Per-layer activation caching policy (DESIGN.md §7.4): what the forward
//! saves for the backward, and in what representation.
//!
//! The sketched backward only ever reads a layer's *input* on the
//! parameter-gradient side (dW = Ĝᵀ·X); the gradient that keeps flowing,
//! dX = Ĝ·W, never touches X. That asymmetry is what an
//! [`ActivationPolicy`] exploits: under the kept-column mode the forward
//! gates the input's own columns — l2 column scores, the same
//! waterfilling as the backward's gate plan
//! ([`crate::sketch::SketchScratch::plan_columns`]), always *correlated*
//! (systematic) sampling so the kept count is deterministic — and stashes
//! only the kept columns with their 1/pᵢ rescales. The backward then
//! forms dW from the doubly-gated product (G-gates from the backward's
//! own stream, X-gates from the forward's), which stays unbiased because
//! the two gate streams are independent and dX never reads the stash:
//! E[dW] = E_G E_X [scatter(Ĝᵀ·X̂)] = Gᵀ·X.
//!
//! Exactness is untouched where the theory requires it: exact (ungated)
//! sites always stash full values, ReLU-style layers that only need the
//! *signs* of their input may compact to a bitset (bit-for-bit identical
//! masking, see [`crate::tensor::kernels::vec::mask_bits_from_pos`]), and
//! layers whose backward never reads the input (LayerNorm re-materializes
//! from its saved x̂/1σ statistics, permutations, pooling) stash nothing.

use crate::rng::Pcg64;
use crate::sketch::SketchScratch;
use crate::tensor::kernels::vec;
use crate::tensor::{Mat, MatView};
use anyhow::{bail, Result};

use super::layer::{Layer, SiteSketch};

/// Score method used to gate stashed input columns. Fixed to `l2` (column
/// energy of X): it minimizes the kept-column estimator's variance for
/// dW = ĜᵀX̂ with no extra state, and — unlike the `*_ind` families — is
/// always sampled with the correlated systematic scheme, so the kept
/// count (and thus the stash footprint) is deterministic: ⌈budget·cols⌉±1.
pub const ACT_METHOD: &str = "l2";

/// What a layer's backward needs of the layer's *input* (not its cache).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputNeed {
    /// Backward never reads the input (permutations, pooling, LayerNorm —
    /// which re-materializes from saved statistics).
    None,
    /// Only the sign pattern matters (ReLU masks) — compactable to a
    /// bitset with bit-identical results.
    Signs,
    /// Full values feed a dW GEMM — the kept-column stash target.
    Values,
}

/// Activation-caching mode (the `--act-policy` axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActMode {
    /// Full-value stashes everywhere: bit-identical to the historical
    /// full-cache path.
    Exact,
    /// Kept-column stashes at gated sketch sites, bitset sign masks, empty
    /// stashes where backward ignores the input.
    Kept,
}

impl ActMode {
    /// Parse `"exact" | "kept" | "auto"`; `"auto"` reads the
    /// `UAVJP_ACTPOLICY` environment knob (the CI matrix axis) and falls
    /// back to `"exact"`.
    pub fn parse(s: &str) -> Result<ActMode> {
        let eff = if s == "auto" {
            match std::env::var("UAVJP_ACTPOLICY") {
                Ok(v) if !v.is_empty() => v,
                _ => "exact".to_string(),
            }
        } else {
            s.to_string()
        };
        match eff.as_str() {
            "exact" => Ok(ActMode::Exact),
            "kept" => Ok(ActMode::Kept),
            other => bail!(
                "unknown activation policy {other} (want exact|kept|auto)"
            ),
        }
    }

    /// Canonical name, inverse of [`ActMode::parse`] for non-auto inputs.
    pub fn as_str(&self) -> &'static str {
        match self {
            ActMode::Exact => "exact",
            ActMode::Kept => "kept",
        }
    }
}

/// Per-run activation-caching configuration, the cache-side sibling of
/// [`crate::native::SketchPolicy`]. Resolved per layer by
/// [`crate::native::Sequential::plan`] into [`ActSite`] decisions.
#[derive(Clone, Debug)]
pub struct ActivationPolicy {
    /// Caching mode.
    pub mode: ActMode,
    /// Kept-column budget for stashed inputs at gated sites; `0.0` means
    /// *inherit* the site's sketch budget (the default — one knob moves
    /// both axes together).
    pub budget: f64,
    /// Optional per-site act budgets (sketch-site order, like
    /// `budget_schedule`); entries of `0.0` inherit that site's sketch
    /// budget. Length must equal the model's site count.
    pub schedule: Option<Vec<f64>>,
}

impl ActivationPolicy {
    /// The full-cache policy (bit-identical to the historical path).
    pub fn exact() -> ActivationPolicy {
        ActivationPolicy { mode: ActMode::Exact, budget: 0.0, schedule: None }
    }

    /// Kept-column policy at an explicit budget (`0.0` inherits per site).
    pub fn kept(budget: f64) -> ActivationPolicy {
        ActivationPolicy { mode: ActMode::Kept, budget, schedule: None }
    }

    /// Policy from a run config (`act_policy` / `act_budget` /
    /// `act_schedule` fields), validating ranges.
    pub fn from_config(cfg: &crate::config::TrainConfig) -> Result<ActivationPolicy> {
        let mode = ActMode::parse(&cfg.act_policy)?;
        if !(0.0..=1.0).contains(&cfg.act_budget) {
            bail!("act_budget {} outside [0, 1]", cfg.act_budget);
        }
        for &b in &cfg.act_schedule {
            if !(0.0..=1.0).contains(&b) {
                bail!("act_schedule entry {b} outside [0, 1]");
            }
        }
        Ok(ActivationPolicy {
            mode,
            budget: cfg.act_budget,
            schedule: if cfg.act_schedule.is_empty() {
                None
            } else {
                Some(cfg.act_schedule.clone())
            },
        })
    }

    /// Act budget for sketch site `site` whose sketch budget is
    /// `sketch_budget` (schedule > global > inherit).
    pub(crate) fn budget_for(&self, site: usize, sketch_budget: f64) -> f64 {
        let b = match &self.schedule {
            Some(s) => s[site],
            None => self.budget,
        };
        if b > 0.0 {
            b
        } else {
            sketch_budget
        }
    }
}

/// The resolved activation-cache decision for one layer.
#[derive(Clone, Debug, PartialEq)]
pub enum ActSite {
    /// Stash nothing (backward ignores the input).
    None,
    /// Stash the full input values (exact path).
    Full,
    /// Stash the sign pattern as a bitset (bit-identical ReLU masking).
    Mask,
    /// Stash only kept columns at this budget, gated by l2 column scores
    /// with correlated sampling at forward time.
    Kept {
        /// Kept-column budget p ∈ (0, 1] for the input columns.
        budget: f64,
    },
}

/// One step's fully-resolved per-layer plan: the sketch decision (backward
/// G-gates) and the activation decision (forward X-stash), always the same
/// length as the layer stack.
#[derive(Clone, Debug)]
pub struct StepPlan {
    /// Per-layer sketch decision (`None` = exact backward).
    pub sketch: Vec<Option<SiteSketch>>,
    /// Per-layer activation-cache decision.
    pub act: Vec<ActSite>,
}

/// One layer's input stash, owned by the workspace: whatever
/// representation the layer's [`ActSite`] selected, with buffers reused
/// across steps (capacities only grow, so steady-state stashing
/// allocates nothing).
#[derive(Debug, Default)]
pub enum Stash {
    /// Nothing stashed.
    #[default]
    None,
    /// Full input copy in the layer's view shape.
    Full(Mat),
    /// Packed sign bitset over the flat input (bit set = kept by ReLU).
    Mask {
        /// One bit per input slot, [`vec::mask_bits_from_pos`] layout.
        bits: Vec<u64>,
        /// Number of input slots the bitset covers.
        len: usize,
    },
    /// Kept input columns in the layer's view shape.
    Kept {
        /// The gathered kept columns, `[view_rows, kept.len()]`.
        xg: Mat,
        /// Kept (column, 1/pᵢ) pairs, strictly increasing columns.
        kept: Vec<(usize, f32)>,
        /// Full input width the kept columns index into.
        cols: usize,
    },
}

impl Stash {
    /// Bytes this stash holds (capacities, not lengths — what the
    /// allocator reserves). Feeds [`crate::native::WorkspaceBytes`].
    pub fn bytes(&self) -> usize {
        use std::mem::size_of;
        match self {
            Stash::None => 0,
            Stash::Full(m) => m.data.capacity() * size_of::<f32>(),
            Stash::Mask { bits, .. } => bits.capacity() * size_of::<u64>(),
            Stash::Kept { xg, kept, .. } => {
                xg.data.capacity() * size_of::<f32>()
                    + kept.capacity() * size_of::<(usize, f32)>()
            }
        }
    }

    /// Borrowed view handed to [`Layer::backward`].
    pub fn as_input(&self) -> StashedInput<'_> {
        match self {
            Stash::None => StashedInput::None,
            Stash::Full(m) => StashedInput::Full(m.view()),
            Stash::Mask { bits, len } => {
                StashedInput::Mask { bits, len: *len }
            }
            Stash::Kept { xg, kept, cols } => {
                StashedInput::Kept { xg: xg.view(), kept, cols: *cols }
            }
        }
    }
}

/// Borrowed form of a [`Stash`], the `x` a [`Layer::backward`] receives.
/// `Copy` so layers with several projections over the same input
/// (attention's Q/K/V) can consume it repeatedly.
#[derive(Clone, Copy, Debug)]
pub enum StashedInput<'a> {
    /// Nothing stashed — the backward must not read the input.
    None,
    /// Full input values in the layer's view shape.
    Full(MatView<'a>),
    /// Sign bitset over the flat input.
    Mask {
        /// One bit per input slot.
        bits: &'a [u64],
        /// Number of input slots covered.
        len: usize,
    },
    /// Kept input columns with their rescales.
    Kept {
        /// Gathered kept columns, `[view_rows, kept.len()]`.
        xg: MatView<'a>,
        /// Kept (column, 1/pᵢ) pairs.
        kept: &'a [(usize, f32)],
        /// Full input width.
        cols: usize,
    },
}

/// Produce layer `layer`'s input stash for this step, per its resolved
/// [`ActSite`]: called by the container *before* the layer's forward runs
/// (gates are decided at production time, so the cache is gathered — never
/// written full and pruned later). Buffers in `slot` are reused across
/// steps. Exact/Full/Mask/None sites consume no randomness from `rng`.
pub(crate) fn stash_input(
    layer: &dyn Layer,
    x: &Mat,
    site: &ActSite,
    slot: &mut Stash,
    scratch: &mut SketchScratch,
    rng: &mut Pcg64,
) {
    match site {
        ActSite::None => {
            if !matches!(slot, Stash::None) {
                *slot = Stash::None;
            }
        }
        ActSite::Full => {
            let (vr, vc) = layer.input_view_shape(x.rows, x.cols);
            debug_assert_eq!(vr * vc, x.data.len(), "view shape");
            if let Stash::Full(m) = slot {
                m.resize_to(vr, vc);
                m.data.copy_from_slice(&x.data);
            } else {
                let mut m = Mat::zeros(vr, vc);
                m.data.copy_from_slice(&x.data);
                *slot = Stash::Full(m);
            }
        }
        ActSite::Mask => {
            if !matches!(slot, Stash::Mask { .. }) {
                *slot = Stash::Mask { bits: Vec::new(), len: 0 };
            }
            let Stash::Mask { bits, len } = slot else { unreachable!() };
            vec::mask_bits_from_pos(&x.data, bits);
            *len = x.data.len();
        }
        ActSite::Kept { budget } => {
            let (vr, vc) = layer.input_view_shape(x.rows, x.cols);
            let plan =
                scratch.plan_columns(ACT_METHOD, *budget, x.reshape(vr, vc), None, rng);
            if !matches!(slot, Stash::Kept { .. }) {
                *slot = Stash::Kept {
                    xg: Mat::zeros(0, 0),
                    kept: Vec::new(),
                    cols: vc,
                };
            }
            let Stash::Kept { xg, kept, cols } = slot else { unreachable!() };
            *cols = vc;
            let m = plan.len();
            xg.resize_to(vr, m);
            for r in 0..vr {
                let row = &x.data[r * vc..(r + 1) * vc];
                let dst = &mut xg.data[r * m..(r + 1) * m];
                for (c, &(j, _)) in plan.iter().enumerate() {
                    dst[c] = row[j];
                }
            }
            kept.clear();
            kept.extend_from_slice(plan);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_mode_parses_explicit_values() {
        assert_eq!(ActMode::parse("exact").unwrap(), ActMode::Exact);
        assert_eq!(ActMode::parse("kept").unwrap(), ActMode::Kept);
        assert!(ActMode::parse("lossy").is_err());
        for m in [ActMode::Exact, ActMode::Kept] {
            assert_eq!(ActMode::parse(m.as_str()).unwrap(), m);
        }
    }

    #[test]
    fn budget_resolution_prefers_schedule_then_global_then_inherit() {
        let p = ActivationPolicy {
            mode: ActMode::Kept,
            budget: 0.5,
            schedule: Some(vec![0.1, 0.0]),
        };
        assert_eq!(p.budget_for(0, 0.25), 0.1); // schedule wins
        assert_eq!(p.budget_for(1, 0.25), 0.25); // 0.0 entry inherits
        let p = ActivationPolicy::kept(0.5);
        assert_eq!(p.budget_for(0, 0.25), 0.5); // global wins
        let p = ActivationPolicy::kept(0.0);
        assert_eq!(p.budget_for(0, 0.25), 0.25); // inherit
    }

    #[test]
    fn mask_bits_replay_matches_mask_nonpos_bit_for_bit() {
        // includes the adversarial f32s: ±0.0 (dropped), NaN (kept, since
        // NaN <= 0 is false), denormals, negatives
        let gate = vec![
            -1.0f32,
            0.0,
            -0.0,
            2.5,
            f32::NAN,
            f32::MIN_POSITIVE / 2.0,
            -3.0,
            1e-30,
            7.0,
        ];
        let g = vec![1.0f32; gate.len()];
        let mut via_mask = g.clone();
        vec::mask_nonpos(&mut via_mask, &gate);
        let mut bits = Vec::new();
        vec::mask_bits_from_pos(&gate, &mut bits);
        let mut via_bits = g.clone();
        vec::apply_mask_bits(&mut via_bits, &bits);
        assert_eq!(via_mask, via_bits);
    }

    #[test]
    fn stash_bytes_track_each_representation() {
        assert_eq!(Stash::None.bytes(), 0);
        let full = Stash::Full(Mat::zeros(4, 8));
        assert!(full.bytes() >= 4 * 8 * 4);
        let mask = Stash::Mask { bits: vec![0u64; 2], len: 128 };
        assert!(mask.bytes() >= 16);
        let kept = Stash::Kept {
            xg: Mat::zeros(4, 2),
            kept: vec![(0, 1.0), (5, 2.0)],
            cols: 8,
        };
        assert!(kept.bytes() >= 4 * 2 * 4);
        assert!(kept.bytes() < full.bytes());
    }
}
