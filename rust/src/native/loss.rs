//! Loss heads for the native trainer: softmax cross-entropy and MSE.
//!
//! The softmax row reductions (max, exp-sum, normalize) run through
//! [`crate::tensor::kernels::vec`] — legacy bit-exact loops under
//! `--kernel scalar`, 8-wide lanes under `--kernel simd`. `exp` itself
//! stays scalar (no vector transcendental without external deps).

use crate::tensor::kernels::vec;
use crate::tensor::Mat;

/// Which loss head the trainer applies to the logits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Mean softmax cross-entropy against integer labels (the paper's
    /// classification head).
    CrossEntropy,
    /// Mean squared error against one-hot targets (regression-style head
    /// for ablations).
    Mse,
}

impl LossKind {
    /// Parse `"ce"` / `"mse"`.
    pub fn parse(s: &str) -> anyhow::Result<LossKind> {
        match s {
            "ce" | "xent" | "cross_entropy" => Ok(LossKind::CrossEntropy),
            "mse" => Ok(LossKind::Mse),
            other => anyhow::bail!("unknown loss {other} (want ce|mse)"),
        }
    }
}

/// Row-wise softmax probabilities (numerically stable), in place.
fn softmax_rows_inplace(out: &mut Mat) {
    for i in 0..out.rows {
        let row = &mut out.data[i * out.cols..(i + 1) * out.cols];
        let m = vec::vmax(row);
        for v in row.iter_mut() {
            *v = (*v - m).exp();
        }
        let sum = vec::vsum(row);
        vec::div_scalar(row, sum);
    }
}

/// Mean loss, with its gradient w.r.t. the logits written into the
/// caller's buffer (same shape as `logits`; fully overwritten) — the
/// workspace path, no allocation.
pub fn loss_and_grad_into(
    kind: LossKind,
    logits: &Mat,
    y: &[i32],
    g: &mut Mat,
) -> f64 {
    let (b, c) = (logits.rows, logits.cols);
    let sum = loss_and_grad_scaled_into(kind, logits, y, g, b);
    match kind {
        LossKind::CrossEntropy => sum / b as f64,
        LossKind::Mse => sum / (b * c) as f64,
    }
}

/// Like [`loss_and_grad_into`] but normalized against a *global* row count
/// `denom_rows` instead of this matrix's own batch — the data-parallel
/// shard path, where each replica holds `b < denom_rows` rows of a global
/// batch and the gradients must sum (not average) across shards into
/// exactly the full-batch mean gradient. Returns the **unnormalized**
/// f64 loss sum over this shard's rows (CE: Σ −ln p; MSE: Σ resid²);
/// the caller divides the cross-shard total by `denom_rows` (CE) or
/// `denom_rows · cols` (MSE). With `denom_rows == logits.rows` the
/// gradient and (post-division) loss are bitwise identical to
/// [`loss_and_grad_into`].
pub fn loss_and_grad_scaled_into(
    kind: LossKind,
    logits: &Mat,
    y: &[i32],
    g: &mut Mat,
    denom_rows: usize,
) -> f64 {
    let (b, c) = (logits.rows, logits.cols);
    assert_eq!(y.len(), b, "label batch size");
    assert_eq!((g.rows, g.cols), (b, c), "loss gradient shape");
    assert!(denom_rows >= b, "global divisor smaller than shard");
    g.data.copy_from_slice(&logits.data);
    match kind {
        LossKind::CrossEntropy => {
            softmax_rows_inplace(g);
            let mut loss = 0.0f64;
            for (i, &yi) in y.iter().enumerate() {
                let p = g.at(i, yi as usize).max(1e-12);
                loss -= (p as f64).ln();
                g.data[i * c + yi as usize] -= 1.0;
            }
            vec::div_scalar(&mut g.data, denom_rows as f32);
            loss
        }
        LossKind::Mse => {
            let mut loss = 0.0f64;
            for (i, &yi) in y.iter().enumerate() {
                g.data[i * c + yi as usize] -= 1.0;
            }
            let n = (denom_rows * c) as f64;
            for v in &g.data {
                loss += (*v as f64) * (*v as f64);
            }
            let scale = 2.0 / n as f32;
            vec::scale(&mut g.data, scale);
            loss
        }
    }
}

/// Mean loss and its gradient w.r.t. the logits (allocating wrapper over
/// [`loss_and_grad_into`]).
pub fn loss_and_grad(kind: LossKind, logits: &Mat, y: &[i32]) -> (f64, Mat) {
    let mut g = Mat::zeros(logits.rows, logits.cols);
    let loss = loss_and_grad_into(kind, logits, y, &mut g);
    (loss, g)
}

/// Mean loss only (no gradient) — the evaluation path, allocation-free.
/// Per-row arithmetic matches the scalar-kind [`loss_and_grad_into`]
/// operation for operation (same `exp`/divide rounding, same clamp)
/// without materializing the gradient; under `--kernel simd` the train
/// path's exp-sum reassociates into lanes, so the two may differ in the
/// reported loss's last ulp (metric-only — gradients are unaffected).
pub fn loss_value(kind: LossKind, logits: &Mat, y: &[i32]) -> f64 {
    let (b, c) = (logits.rows, logits.cols);
    assert_eq!(y.len(), b, "label batch size");
    match kind {
        LossKind::CrossEntropy => {
            let mut loss = 0.0f64;
            for (i, &yi) in y.iter().enumerate() {
                let row = logits.row(i);
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0.0f32;
                for &v in row {
                    sum += (v - m).exp();
                }
                let p = ((row[yi as usize] - m).exp() / sum).max(1e-12);
                loss -= (p as f64).ln();
            }
            loss / b as f64
        }
        LossKind::Mse => {
            let mut loss = 0.0f64;
            for (i, &yi) in y.iter().enumerate() {
                for (j, &v) in logits.row(i).iter().enumerate() {
                    let r = if j == yi as usize { v - 1.0 } else { v };
                    loss += (r as f64) * (r as f64);
                }
            }
            loss / (b * c) as f64
        }
    }
}

/// Fraction of rows whose argmax matches the label.
pub fn accuracy(logits: &Mat, y: &[i32]) -> f64 {
    let mut correct = 0usize;
    for (i, &yi) in y.iter().enumerate() {
        let row = logits.row(i);
        let mut best = (f32::NEG_INFINITY, 0usize);
        for (j, &v) in row.iter().enumerate() {
            if v > best.0 {
                best = (v, j);
            }
        }
        if best.1 == yi as usize {
            correct += 1;
        }
    }
    correct as f64 / y.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ce_uniform_logits_is_ln_c() {
        let logits = Mat::zeros(4, 10);
        let y = vec![0i32, 3, 7, 9];
        let (loss, g) = loss_and_grad(LossKind::CrossEntropy, &logits, &y);
        assert!((loss - (10.0f64).ln()).abs() < 1e-6);
        // gradient rows sum to zero (softmax minus one-hot over batch)
        for i in 0..4 {
            let s: f32 = g.row(i).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn ce_prefers_correct_class() {
        let mut logits = Mat::zeros(1, 3);
        logits.data[1] = 10.0;
        let (good, _) = loss_and_grad(LossKind::CrossEntropy, &logits, &[1]);
        let (bad, _) = loss_and_grad(LossKind::CrossEntropy, &logits, &[0]);
        assert!(good < 1e-3 && bad > 5.0);
    }

    #[test]
    fn mse_gradient_is_two_residual_over_n() {
        let mut logits = Mat::zeros(2, 2);
        logits.data = vec![1.0, 0.0, 0.0, 0.5];
        let (loss, g) = loss_and_grad(LossKind::Mse, &logits, &[0, 1]);
        // residuals: [0,0], [0,-0.5] → loss = 0.25/4
        assert!((loss - 0.0625).abs() < 1e-6);
        assert!((g.at(1, 1) - 2.0 * (-0.5) / 4.0).abs() < 1e-6);
    }

    #[test]
    fn accuracy_counts_argmax() {
        let logits = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 0.0]]);
        assert_eq!(accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(accuracy(&logits, &[0, 0]), 0.5);
    }

    #[test]
    fn scaled_shards_recompose_the_full_batch_loss() {
        // Two 2-row shards with the global divisor reproduce the 4-row
        // mean loss; every gradient row is bitwise what the full-batch
        // call produces for that row (CE grads are row-local).
        let logits = Mat::from_rows(vec![
            vec![1.0, -0.5, 0.25],
            vec![0.0, 2.0, -1.0],
            vec![0.5, 0.5, 0.5],
            vec![-2.0, 1.0, 0.0],
        ]);
        let y = [0i32, 1, 2, 0];
        for kind in [LossKind::CrossEntropy, LossKind::Mse] {
            let (full_loss, full_g) = loss_and_grad(kind, &logits, &y);
            let mut sum = 0.0f64;
            let mut rows = Vec::new();
            for s in 0..2 {
                let shard = Mat::from_rows(
                    (0..2).map(|i| logits.row(2 * s + i).to_vec()).collect(),
                );
                let mut g = Mat::zeros(2, 3);
                sum += loss_and_grad_scaled_into(
                    kind,
                    &shard,
                    &y[2 * s..2 * s + 2],
                    &mut g,
                    4,
                );
                rows.extend_from_slice(&g.data);
            }
            let denom = match kind {
                LossKind::CrossEntropy => 4.0,
                LossKind::Mse => 12.0,
            };
            assert!((sum / denom - full_loss).abs() < 1e-12);
            assert_eq!(rows, full_g.data);
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(LossKind::parse("ce").unwrap(), LossKind::CrossEntropy);
        assert_eq!(LossKind::parse("mse").unwrap(), LossKind::Mse);
        assert!(LossKind::parse("hinge").is_err());
    }
}
