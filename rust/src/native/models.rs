//! Model registry for the native backend: named builders for the paper's
//! three architectures (§5), all expressed as [`Sequential`] stacks over
//! the [`crate::native::Layer`] trait.
//!
//! * **mlp** — 784-64-64-10 with ReLU between linears (synth-MNIST);
//!   every linear is a sketch site. Init streams match the pre-module-API
//!   `Mlp` struct bit-for-bit.
//! * **bagnet** — BagNet-lite on synth-CIFAR: non-overlapping 8×8 patch
//!   convs (lowered to kept-column GEMMs) + bag-of-patches mean pool.
//! * **vit** — ViT-lite on synth-CIFAR: patch embedding + learned
//!   positional embedding + one post-LN transformer encoder block
//!   (residual MHSA and residual FFN sublayers, each followed by
//!   LayerNorm) + mean pool; the QKV/projection and FFN linears are the
//!   sketch sites.
//! * **bagnet_deep / vit_deep** — the same recipes at 2×/3× the trunk
//!   depth (4 conv stages / 3 encoder blocks). These exist to exercise
//!   the §7.4 activation policy: under `--act-policy kept` their
//!   per-layer stashes compact to kept columns, so the deep stacks train
//!   within the shallow exact models' workspace footprint.
//!
//! `supports_model` queries ([`is_supported`]) and trainer construction
//! ([`build`]) both go through [`REGISTRY`] — adding a model here is all
//! it takes to make it trainable, sweepable and figure-eligible.

use anyhow::{bail, Result};

use super::attention::{Attention, FfnBlock, LayerNorm, PosEmbed};
use super::conv::{PatchConv, PatchMeanPool, Patchify};
use super::layer::{Layer, Linear, Relu};
use super::sequential::Sequential;

/// One registry entry: a named model family the native backend can build.
pub struct ModelEntry {
    /// Model name as configs and the CLI spell it.
    pub name: &'static str,
    /// Builder: seed → initialized stack.
    pub build: fn(u64) -> Sequential,
    /// One-line description for `uavjp methods`.
    pub about: &'static str,
}

/// Every model family the native backend implements.
pub const REGISTRY: &[ModelEntry] = &[
    ModelEntry {
        name: "mlp",
        build: build_mlp,
        about: "784-64-64-10 ReLU MLP on synth-MNIST (3 sketch sites)",
    },
    ModelEntry {
        name: "bagnet",
        build: bagnet,
        about: "BagNet-lite: 8x8 patch convs + mean pool on synth-CIFAR \
                (3 sketch sites)",
    },
    ModelEntry {
        name: "vit",
        build: vit,
        about: "ViT-lite: patch embed + post-LN MHSA/FFN block on \
                synth-CIFAR (4 sketch sites)",
    },
    ModelEntry {
        name: "bagnet_deep",
        build: bagnet_deep,
        about: "BagNet-lite at 2x depth: four 8x8 patch conv stages + mean \
                pool on synth-CIFAR (5 sketch sites)",
    },
    ModelEntry {
        name: "vit_deep",
        build: vit_deep,
        about: "ViT-lite at 3x depth: patch embed + three post-LN MHSA/FFN \
                blocks on synth-CIFAR (8 sketch sites)",
    },
];

/// Whether `name` is a registered native model.
pub fn is_supported(name: &str) -> bool {
    REGISTRY.iter().any(|e| e.name == name)
}

/// Registered model names, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Build a registered model at `seed`.
pub fn build(name: &str, seed: u64) -> Result<Sequential> {
    match REGISTRY.iter().find(|e| e.name == name) {
        Some(e) => Ok((e.build)(seed)),
        None => bail!(
            "native backend has no model {name} (registered: {})",
            names().join(" ")
        ),
    }
}

/// The standard MLP dimensions (`build("mlp", …)` shape).
pub const MLP_DIMS: &[usize] = &[784, 64, 64, 10];

fn build_mlp(seed: u64) -> Sequential {
    mlp(MLP_DIMS, seed)
}

/// He-initialized MLP over explicit `dims` (e.g. `[784, 64, 64, 10]`),
/// ReLU between linears, none after the last. The i-th linear draws from
/// stream `300 + i` of `seed ^ 0x1e57` — the exact init the pre-module-API
/// `Mlp` struct used, keeping trained trajectories bit-identical.
pub fn mlp(dims: &[usize], seed: u64) -> Sequential {
    assert!(dims.len() >= 2, "need at least one linear layer");
    let n = dims.len() - 1;
    let mut layers: Vec<Box<dyn Layer>> = Vec::with_capacity(2 * n - 1);
    for (li, pair) in dims.windows(2).enumerate() {
        layers.push(Box::new(Linear::he(pair[0], pair[1], seed, 300 + li as u64)));
        if li + 1 < n {
            layers.push(Box::new(Relu));
        }
    }
    Sequential::new(layers)
}

/// BagNet-lite for 32×32×3 synth-CIFAR: two 8×8-patch conv stages and a
/// bag-of-patches mean-pool head. Sketch sites: both patch convs and the
/// classifier linear.
pub fn bagnet(seed: u64) -> Sequential {
    Sequential::new(vec![
        Box::new(Patchify::new(32, 32, 3, 8)), // 16 patches of 192
        Box::new(PatchConv::he(16, 192, 64, seed, 300)),
        Box::new(Relu),
        Box::new(PatchConv::he(16, 64, 64, seed, 301)),
        Box::new(Relu),
        Box::new(PatchMeanPool { patches: 16, dim: 64 }),
        Box::new(Linear::he(64, 10, seed, 302)),
    ])
}

/// ViT-lite for 32×32×3 synth-CIFAR: 8×8 patch embedding, learned
/// positional embedding, one post-LN transformer encoder block —
/// `LN(x + MHSA(x))` then `LN(x + FFN(x))`, both sublayer residuals
/// internal to [`Attention`] / [`FfnBlock`] — and mean-pool
/// classification. Sketch sites: the patch embedding, the attention
/// block (its QKV + output projections), the FFN block (both
/// projections), and the classifier linear.
pub fn vit(seed: u64) -> Sequential {
    Sequential::new(vec![
        Box::new(Patchify::new(32, 32, 3, 8)), // 16 tokens of 192
        Box::new(PatchConv::he(16, 192, 64, seed, 300)),
        Box::new(PosEmbed::new(16, 64, seed, 301)),
        Box::new(Attention::new(16, 64, 4, seed, 302)), // streams 302..306
        Box::new(LayerNorm::new(64)),
        Box::new(FfnBlock::he(64, 128, seed, 306)), // streams 306..308
        Box::new(LayerNorm::new(64)),
        Box::new(PatchMeanPool { patches: 16, dim: 64 }),
        Box::new(Linear::he(64, 10, seed, 308)),
    ])
}

/// BagNet-lite at twice the trunk depth: four 8×8-patch conv stages
/// instead of two. Sketch sites: every conv plus the classifier (5).
/// Init streams continue the shallow recipe (convs 300…303, classifier
/// 304), so the first two stages match [`bagnet`] bit-for-bit.
pub fn bagnet_deep(seed: u64) -> Sequential {
    Sequential::new(vec![
        Box::new(Patchify::new(32, 32, 3, 8)), // 16 patches of 192
        Box::new(PatchConv::he(16, 192, 64, seed, 300)),
        Box::new(Relu),
        Box::new(PatchConv::he(16, 64, 64, seed, 301)),
        Box::new(Relu),
        Box::new(PatchConv::he(16, 64, 64, seed, 302)),
        Box::new(Relu),
        Box::new(PatchConv::he(16, 64, 64, seed, 303)),
        Box::new(Relu),
        Box::new(PatchMeanPool { patches: 16, dim: 64 }),
        Box::new(Linear::he(64, 10, seed, 304)),
    ])
}

/// ViT-lite at three times the encoder depth: three post-LN transformer
/// blocks instead of one. Sketch sites: the patch embedding, each
/// block's attention and FFN, and the classifier (8). Block k draws its
/// attention from streams `302 + 6k …` and its FFN from `306 + 6k …`;
/// block 0 matches [`vit`] bit-for-bit.
pub fn vit_deep(seed: u64) -> Sequential {
    let mut layers: Vec<Box<dyn Layer>> = vec![
        Box::new(Patchify::new(32, 32, 3, 8)), // 16 tokens of 192
        Box::new(PatchConv::he(16, 192, 64, seed, 300)),
        Box::new(PosEmbed::new(16, 64, seed, 301)),
    ];
    for k in 0..3u64 {
        let s = 302 + 6 * k;
        layers.push(Box::new(Attention::new(16, 64, 4, seed, s)));
        layers.push(Box::new(LayerNorm::new(64)));
        layers.push(Box::new(FfnBlock::he(64, 128, seed, s + 4)));
        layers.push(Box::new(LayerNorm::new(64)));
    }
    layers.push(Box::new(PatchMeanPool { patches: 16, dim: 64 }));
    layers.push(Box::new(Linear::he(64, 10, seed, 320)));
    Sequential::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::Mat;

    #[test]
    fn registry_answers_support_queries() {
        assert!(is_supported("mlp"));
        assert!(is_supported("bagnet"));
        assert!(is_supported("vit"));
        assert!(is_supported("bagnet_deep"));
        assert!(is_supported("vit_deep"));
        assert!(!is_supported("resnet"));
        assert_eq!(
            names(),
            vec!["mlp", "bagnet", "vit", "bagnet_deep", "vit_deep"]
        );
        assert!(build("resnet", 0).is_err());
    }

    #[test]
    fn mlp_forward_shapes() {
        let m = mlp(&[5, 4, 3], 0);
        let mut rng = Pcg64::new(1, 0);
        let x = Mat::from_fn(7, 5, |_, _| rng.gaussian() as f32);
        let mut ws = m.workspace(7, 5);
        m.forward(&x, &mut ws);
        assert_eq!(ws.dims, vec![5, 4, 4, 3]);
        assert_eq!((ws.output().rows, ws.output().cols), (7, 3));
        assert_eq!(m.num_params(), 5 * 4 + 4 + 4 * 3 + 3);
    }

    #[test]
    fn mlp_relu_applied_between_but_not_after() {
        let m = mlp(&[3, 4, 8], 1);
        let mut rng = Pcg64::new(2, 0);
        let x = Mat::from_fn(16, 3, |_, _| rng.gaussian() as f32);
        let mut ws = m.workspace(16, 3);
        m.forward(&x, &mut ws);
        // 3 layers ping-pong as flow[0], flow[1], flow[0]: after the sweep
        // flow[1] still holds the relu output that fed the last linear
        assert!(ws.flow[1].data.iter().all(|&v| v >= 0.0));
        assert!(ws.output().data.iter().any(|&v| v < 0.0));
    }

    #[test]
    fn mlp_init_matches_legacy_streams() {
        // the pre-module-API Mlp drew layer i from Pcg64(seed ^ 0x1e57,
        // 300 + i) with std sqrt(2/din); a regression here would silently
        // break trained-trajectory parity with PR-1 artifacts
        let m = mlp(&[4, 3, 2], 9);
        let mut rng = Pcg64::new(9 ^ 0x1e57, 300);
        let std = (2.0f64 / 4.0).sqrt();
        let expect = (rng.gaussian() * std) as f32;
        assert_eq!(m.layers[0].params()[0][0], expect);
    }

    #[test]
    fn bagnet_and_vit_forward_shapes_and_sites() {
        let mut rng = Pcg64::new(3, 0);
        let x = Mat::from_fn(2, 3072, |_, _| rng.gaussian() as f32);
        let b = bagnet(0);
        let mut wsb = b.workspace(2, 3072);
        b.forward(&x, &mut wsb);
        assert_eq!((wsb.output().rows, wsb.output().cols), (2, 10));
        assert_eq!(b.num_sites(), 3);
        let v = vit(0);
        let mut wsv = v.workspace(2, 3072);
        v.forward(&x, &mut wsv);
        assert_eq!((wsv.output().rows, wsv.output().cols), (2, 10));
        assert_eq!(v.num_sites(), 4);
    }

    #[test]
    fn deep_variants_forward_shapes_and_sites() {
        let mut rng = Pcg64::new(5, 0);
        let x = Mat::from_fn(2, 3072, |_, _| rng.gaussian() as f32);
        let b = bagnet_deep(0);
        let mut wsb = b.workspace(2, 3072);
        b.forward(&x, &mut wsb);
        assert_eq!((wsb.output().rows, wsb.output().cols), (2, 10));
        assert_eq!(b.num_sites(), 5);
        let v = vit_deep(0);
        let mut wsv = v.workspace(2, 3072);
        v.forward(&x, &mut wsv);
        assert_eq!((wsv.output().rows, wsv.output().cols), (2, 10));
        assert_eq!(v.num_sites(), 8);
        // stage 0 of the deep trunks reuses the shallow init streams
        assert_eq!(
            b.layers[1].params()[0][0],
            bagnet(0).layers[1].params()[0][0]
        );
        assert_eq!(v.layers[3].params()[0][0], vit(0).layers[3].params()[0][0]);
    }

    #[test]
    fn backward_matches_finite_differences() {
        use crate::native::loss::{loss_and_grad_into, loss_value, LossKind};
        use crate::native::{ActivationPolicy, SketchPolicy};
        let m = mlp(&[4, 5, 3], 3);
        let mut rng = Pcg64::new(4, 0);
        let x = Mat::from_fn(6, 4, |_, _| rng.gaussian() as f32);
        let y: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let mut ws = m.workspace(6, 4);
        let plan = m
            .plan(&SketchPolicy::exact(), &ActivationPolicy::exact())
            .unwrap();
        m.forward_train(&x, &mut ws, &plan, &mut rng);
        let (logits, gout) = ws.loss_io();
        loss_and_grad_into(LossKind::CrossEntropy, logits, &y, gout);
        m.backward(&mut ws, &plan, &mut rng);
        let grads = &ws.grad_slots;
        // finite-difference a few weight coordinates of each linear
        let eps = 1e-3f32;
        let mut m2 = mlp(&[4, 5, 3], 3);
        let loss_of = |m2: &Sequential, x: &Mat, y: &[i32]| {
            let mut ws = m2.workspace(x.rows, x.cols);
            m2.forward(x, &mut ws);
            loss_value(LossKind::CrossEntropy, ws.output(), y)
        };
        for (slot_w, li) in [(0usize, 0usize), (2, 2)] {
            for &idx in &[0usize, 3, 7] {
                let orig = m2.layers[li].params()[0][idx];
                m2.layers[li].params_mut()[0][idx] = orig + eps;
                let lp = loss_of(&m2, &x, &y);
                m2.layers[li].params_mut()[0][idx] = orig - eps;
                let lm = loss_of(&m2, &x, &y);
                m2.layers[li].params_mut()[0][idx] = orig;
                let fd = (lp - lm) / (2.0 * eps as f64);
                let an = grads.slots[slot_w][idx] as f64;
                // loose bar: f32 forward + ReLU kinks make FD noisy, but a
                // transposed/missing term would be off by O(|fd|)
                assert!(
                    (fd - an).abs() < 1e-2 * (1.0 + fd.abs()),
                    "slot {slot_w} idx {idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }
}
