//! Patch-based convolutional layers for the BagNet-lite / ViT-lite models.
//!
//! The paper (and XConv, Thatipelli et al. 2021) applies the §4.2 column
//! estimator to convolutions by lowering them to GEMMs: a non-overlapping
//! patch conv is exactly a linear layer applied to every patch, so its
//! backward is the same kept-column sketch with `B·P` effective batch rows
//! and the output channels as gated columns. Three layers implement that
//! lowering:
//!
//! * [`Patchify`] — im2col for non-overlapping patches: channel-last image
//!   rows → patch-major rows (pure permutation, exact backward).
//! * [`PatchConv`] — a [`Linear`] applied per patch; the sketch site.
//!   Since the view redesign the `[B, P·d] ↔ [B·P, d]` lowering is a
//!   zero-copy [`crate::tensor::Mat::reshape`] — the row-major buffers
//!   coincide, so neither pass copies the batch.
//! * [`PatchMeanPool`] — mean over patches, the bag-of-features head.

use crate::tensor::kernels::vec;
use crate::tensor::{Mat, MatViewMut};

use super::layer::{affine_into, linear_backward_stash, Cache, Layer, Linear, SketchCtx};
use super::policy::{InputNeed, StashedInput};

/// Non-overlapping-patch im2col: `[B, H·W·C]` channel-last images to
/// `[B, P·(q·q·C)]` patch-major rows (patch index `p = pr·(W/q) + pc`,
/// within-patch offset `(dr·q + dc)·C + ch`). No parameters; the backward
/// is the inverse permutation.
pub struct Patchify {
    /// Number of patches `(H/q)·(W/q)`.
    pub patches: usize,
    /// Flattened per-patch width `q·q·C`.
    pub patch_dim: usize,
    src: Vec<usize>,
}

impl Patchify {
    /// Build the index map for an `h × w × c` image cut into `q × q`
    /// patches (`h` and `w` must be multiples of `q`).
    pub fn new(h: usize, w: usize, c: usize, q: usize) -> Patchify {
        assert!(h % q == 0 && w % q == 0, "image {h}x{w} not divisible by {q}");
        let mut src = Vec::with_capacity(h * w * c);
        for pr in 0..h / q {
            for pc in 0..w / q {
                for dr in 0..q {
                    for dc in 0..q {
                        for ch in 0..c {
                            src.push(((pr * q + dr) * w + (pc * q + dc)) * c + ch);
                        }
                    }
                }
            }
        }
        Patchify { patches: (h / q) * (w / q), patch_dim: q * q * c, src }
    }
}

impl Layer for Patchify {
    fn name(&self) -> &'static str {
        "patchify"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din, self.src.len(), "patchify input width");
        din
    }

    fn forward(&self, x: &Mat, y: &mut Mat, _cache: &mut Cache) {
        let n = self.src.len();
        for i in 0..x.rows {
            let xin = x.row(i);
            let yr = &mut y.data[i * n..(i + 1) * n];
            for (o, &s) in yr.iter_mut().zip(&self.src) {
                *o = xin[s];
            }
        }
    }

    fn backward(
        &self,
        gy: &Mat,
        _x: StashedInput<'_>,
        _cache: &mut Cache,
        _ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        _pg: &mut [Vec<f32>],
    ) {
        let Some(gx) = gx else { return };
        let n = self.src.len();
        for i in 0..gy.rows {
            let grow = gy.row(i);
            let out = &mut gx.data[i * n..(i + 1) * n];
            for (g, &s) in grow.iter().zip(&self.src) {
                out[s] = *g;
            }
        }
    }

    fn params(&self) -> Vec<&[f32]> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        Vec::new()
    }

    fn visit_params_mut(&mut self, _f: &mut dyn FnMut(&mut [f32])) {}
}

/// A linear layer applied independently to each of `P` patches: input
/// `[B, P·d_in]` (patch-major, from [`Patchify`] or a previous
/// `PatchConv`), output `[B, P·d_out]`. Internally one GEMM over the
/// reshaped `[B·P, d_in]` rows, which is where the kept-column sketch
/// plugs in — the output gradient seen by the estimator is `[B·P, d_out]`
/// with output channels as columns.
pub struct PatchConv {
    /// Patches per image `P`.
    pub patches: usize,
    /// The shared per-patch linear map.
    pub lin: Linear,
}

impl PatchConv {
    /// He-initialized patch conv, deterministic given `(seed, stream)`.
    pub fn he(
        patches: usize,
        din: usize,
        dout: usize,
        seed: u64,
        stream: u64,
    ) -> PatchConv {
        PatchConv { patches, lin: Linear::he(din, dout, seed, stream) }
    }
}

impl Layer for PatchConv {
    fn name(&self) -> &'static str {
        "patch_conv"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din, self.patches * self.lin.din(), "patch_conv input width");
        self.patches * self.lin.dout()
    }

    fn forward(&self, x: &Mat, y: &mut Mat, _cache: &mut Cache) {
        let (din, dout) = (self.lin.din(), self.lin.dout());
        let rows = x.rows * self.patches;
        affine_into(
            x.reshape(rows, din),
            &self.lin.w,
            &self.lin.b,
            y.reshape_mut(rows, dout),
        );
    }

    fn input_need(&self) -> InputNeed {
        InputNeed::Values
    }

    fn input_view_shape(&self, batch: usize, _din: usize) -> (usize, usize) {
        (batch * self.patches, self.lin.din())
    }

    fn backward(
        &self,
        gy: &Mat,
        x: StashedInput<'_>,
        _cache: &mut Cache,
        ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        pg: &mut [Vec<f32>],
    ) {
        let (din, dout) = (self.lin.din(), self.lin.dout());
        let rows = gy.rows * self.patches;
        let [dw, db] = pg else { panic!("patch_conv has 2 param slots") };
        linear_backward_stash(
            gy.reshape(rows, dout),
            x,
            &self.lin.w,
            ctx,
            MatViewMut::new(dout, din, dw),
            db,
            gx.map(|m| m.reshape_mut(rows, din)),
        );
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.lin.w.data, &self.lin.b]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![&mut self.lin.w.data, &mut self.lin.b]
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        f(&mut self.lin.w.data);
        f(&mut self.lin.b);
    }

    fn sketchable(&self) -> bool {
        true
    }
}

/// Mean over the patch axis: `[B, P·d] → [B, d]` — the bag-of-local-
/// features head of BagNet and the token pooling of the ViT-lite.
pub struct PatchMeanPool {
    /// Patches per image `P`.
    pub patches: usize,
    /// Per-patch feature width `d`.
    pub dim: usize,
}

impl Layer for PatchMeanPool {
    fn name(&self) -> &'static str {
        "patch_mean_pool"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din, self.patches * self.dim, "pool input width");
        self.dim
    }

    fn forward(&self, x: &Mat, y: &mut Mat, _cache: &mut Cache) {
        let inv = 1.0 / self.patches as f32;
        for i in 0..x.rows {
            let xin = x.row(i);
            let yr = &mut y.data[i * self.dim..(i + 1) * self.dim];
            yr.fill(0.0);
            for p in 0..self.patches {
                vec::add_assign(yr, &xin[p * self.dim..(p + 1) * self.dim]);
            }
            vec::scale(yr, inv);
        }
    }

    fn backward(
        &self,
        gy: &Mat,
        _x: StashedInput<'_>,
        _cache: &mut Cache,
        _ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        _pg: &mut [Vec<f32>],
    ) {
        let Some(gx) = gx else { return };
        let inv = 1.0 / self.patches as f32;
        for i in 0..gy.rows {
            let grow = gy.row(i);
            let out = &mut gx.data
                [i * self.patches * self.dim..(i + 1) * self.patches * self.dim];
            for p in 0..self.patches {
                let chunk = &mut out[p * self.dim..(p + 1) * self.dim];
                chunk.copy_from_slice(grow);
                vec::scale(chunk, inv);
            }
        }
    }

    fn params(&self) -> Vec<&[f32]> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        Vec::new()
    }

    fn visit_params_mut(&mut self, _f: &mut dyn FnMut(&mut [f32])) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::layer::{run_layer_backward, run_layer_forward};
    use crate::rng::Pcg64;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn patchify_is_a_permutation_and_backward_inverts_it() {
        let pf = Patchify::new(4, 4, 3, 2);
        assert_eq!(pf.patches, 4);
        assert_eq!(pf.patch_dim, 12);
        let mut rng = Pcg64::new(1, 0);
        let x = randmat(2, 48, &mut rng);
        let (y, mut cache) = run_layer_forward(&pf, &x);
        // same multiset of values per row
        let mut a = x.row(0).to_vec();
        let mut b = y.row(0).to_vec();
        a.sort_by(|p, q| p.partial_cmp(q).unwrap());
        b.sort_by(|p, q| p.partial_cmp(q).unwrap());
        assert_eq!(a, b);
        // top-left patch of row 0 comes first
        assert_eq!(y.at(0, 0), x.at(0, 0)); // (0,0,ch0)
        assert_eq!(y.at(0, 3), x.at(0, 3)); // (0,1,ch0) = in-index 1*3
        assert_eq!(y.at(0, 6), x.at(0, 12)); // (1,0,ch0) = in-index 4*3
        // backward(forward-output) restores the input ordering
        let mut g = Pcg64::new(0, 0);
        let (gx, _) =
            run_layer_backward(&pf, &y, &x, &mut cache, None, &mut g, true);
        assert_eq!(gx.unwrap().data, x.data);
    }

    #[test]
    fn patch_conv_equals_per_patch_linear() {
        let pc = PatchConv::he(3, 4, 5, 9, 300);
        let mut rng = Pcg64::new(2, 0);
        let x = randmat(2, 12, &mut rng);
        let (y, _) = run_layer_forward(&pc, &x);
        assert_eq!((y.rows, y.cols), (2, 15));
        // manual: patch p of sample i maps through the same linear
        for i in 0..2 {
            for p in 0..3 {
                for o in 0..5 {
                    let mut z = pc.lin.b[o];
                    for k in 0..4 {
                        z += x.at(i, p * 4 + k) * pc.lin.w.at(o, k);
                    }
                    assert!((y.at(i, p * 5 + o) - z).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn patch_conv_full_budget_sketch_matches_exact() {
        let pc = PatchConv::he(4, 6, 8, 3, 300);
        let mut rng = Pcg64::new(5, 0);
        let x = randmat(3, 24, &mut rng);
        let (y, mut cache) = run_layer_forward(&pc, &x);
        let gy = randmat(y.rows, y.cols, &mut rng);
        let mut g1 = Pcg64::new(0, 0);
        let (gx_e, pg_e) =
            run_layer_backward(&pc, &gy, &x, &mut cache, None, &mut g1, true);
        let site = super::super::layer::SiteSketch { method: "l1".into(), budget: 1.0 };
        let mut g2 = Pcg64::new(0, 0);
        let (gx_s, pg_s) = run_layer_backward(
            &pc, &gy, &x, &mut cache, Some(&site), &mut g2, true,
        );
        for (a, b) in pg_e[0].iter().zip(&pg_s[0]) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in gx_e.unwrap().data.iter().zip(&gx_s.unwrap().data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn mean_pool_averages_and_spreads_gradient() {
        let pool = PatchMeanPool { patches: 2, dim: 3 };
        let x = Mat::from_rows(vec![vec![1.0, 2.0, 3.0, 3.0, 4.0, 5.0]]);
        let (y, mut cache) = run_layer_forward(&pool, &x);
        assert_eq!(y.data, vec![2.0, 3.0, 4.0]);
        let gy = Mat::from_rows(vec![vec![2.0, 4.0, 6.0]]);
        let mut g = Pcg64::new(0, 0);
        let (gx, _) =
            run_layer_backward(&pool, &gy, &x, &mut cache, None, &mut g, true);
        assert_eq!(gx.unwrap().data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }
}
