//! MLP with a hand-written per-layer backward: exact or sketched VJPs.
//!
//! Mirrors `python/compile/models/mlp.py` (He init, ReLU between linears,
//! every linear layer sketchable) and `python/compile/layers.py`'s backward
//! semantics: the forward is always exact; a sketched layer replaces its
//! output gradient G by Ĝ = G·diag(z/p) and all three products (dX, dW, db)
//! are computed from Ĝ touching only the kept columns.

use crate::rng::Pcg64;
use crate::sketch::{
    column_scores, correlated_bernoulli, independent_bernoulli, kept_columns,
    pstar_from_weights,
};
use crate::tensor::{matmul, sparse_dw, sparse_dx, Mat};

/// Column-sketch methods the native backward supports (the coordinate and
/// uniform-column families of §4.2; spectral and row/element masks stay
/// PJRT-only).
pub const NATIVE_METHODS: &[&str] = &[
    "baseline", "per_column", "l1", "l1_ind", "l1_sq", "l2", "l2_sq", "var",
    "var_sq", "ds",
];

/// One linear layer: `y = x·Wᵀ + b`, with `W: [d_out, d_in]` row-major.
pub struct Linear {
    /// Weight matrix, one row per output unit.
    pub w: Mat,
    /// Bias, length `d_out`.
    pub b: Vec<f32>,
}

/// Multi-layer perceptron: linears with ReLU between (none after the last).
pub struct Mlp {
    /// The linear layers, input to output.
    pub layers: Vec<Linear>,
}

/// Activations saved by [`Mlp::forward`] for the backward pass.
pub struct ForwardCache {
    /// `acts[0]` is the input batch; `acts[i+1]` the (post-ReLU) output of
    /// layer `i`. The last entry holds the logits.
    pub acts: Vec<Mat>,
    /// Pre-activations `z_i` of each layer (needed for the ReLU derivative).
    pub zs: Vec<Mat>,
}

impl ForwardCache {
    /// The network output (last layer pre-activation = logits).
    pub fn logits(&self) -> &Mat {
        self.acts.last().expect("forward cache is never empty")
    }
}

/// Per-layer parameter gradients, same shapes as the parameters.
pub struct Grads {
    /// `dL/dW` per layer.
    pub dw: Vec<Mat>,
    /// `dL/db` per layer.
    pub db: Vec<Vec<f32>>,
}

impl Grads {
    /// Flatten all gradients (layer order, dW then db) into one vector —
    /// the layout the variance probes reason about.
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for (dw, db) in self.dw.iter().zip(&self.db) {
            out.extend_from_slice(&dw.data);
            out.extend_from_slice(db);
        }
        out
    }

    /// Global ℓ2 norm over every gradient entry.
    pub fn global_norm(&self) -> f64 {
        let mut sq = 0.0f64;
        for (dw, db) in self.dw.iter().zip(&self.db) {
            sq += dw.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
            sq += db.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        }
        sq.sqrt()
    }

    /// Scale every gradient entry by `s` (used by clipping).
    pub fn scale(&mut self, s: f32) {
        for dw in &mut self.dw {
            for v in &mut dw.data {
                *v *= s;
            }
        }
        for db in &mut self.db {
            for v in db.iter_mut() {
                *v *= s;
            }
        }
    }
}

/// How gated layers approximate their backward pass.
#[derive(Clone, Debug)]
pub struct SketchSpec {
    /// One of [`NATIVE_METHODS`]; `"baseline"` means exact everywhere.
    pub method: String,
    /// Kept-column budget p ∈ (0, 1].
    pub budget: f64,
}

impl SketchSpec {
    /// The exact-backward spec.
    pub fn exact() -> SketchSpec {
        SketchSpec { method: "baseline".into(), budget: 1.0 }
    }

    /// True when no sketching happens regardless of the layer mask.
    pub fn is_exact(&self) -> bool {
        self.method == "baseline"
    }
}

/// `z = x·Wᵀ + b` for row-major `W: [d_out, d_in]`.
fn affine(x: &Mat, w: &Mat, b: &[f32]) -> Mat {
    let wt = w.transpose();
    let mut z = matmul(x, &wt);
    for i in 0..z.rows {
        let row = &mut z.data[i * z.cols..(i + 1) * z.cols];
        for (v, bj) in row.iter_mut().zip(b) {
            *v += bj;
        }
    }
    z
}

/// Exact linear backward: (dW, db, dX if requested).
fn exact_linear_backward(
    g: &Mat,
    x: &Mat,
    w: &Mat,
    need_dx: bool,
) -> (Mat, Vec<f32>, Option<Mat>) {
    let dw = matmul(&g.transpose(), x);
    let db = column_sums(g);
    let dx = if need_dx { Some(matmul(g, w)) } else { None };
    (dw, db, dx)
}

fn column_sums(g: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; g.cols];
    for i in 0..g.rows {
        for (o, &v) in out.iter_mut().zip(g.row(i)) {
            *o += v;
        }
    }
    out
}

/// The paper's sketched linear backward on native matrices.
///
/// Draws keep-probabilities from the method's column scores (waterfilling,
/// Algorithm 1), gates columns with correlated (systematic, Algorithm 2) or
/// independent Bernoulli sampling (`per_column` and `*_ind` methods), and
/// computes dX = Ĝ·W, dW = Ĝᵀ·X, db = Ĝᵀ·1 touching only kept columns with
/// the unbiased 1/pᵢ rescale. Returns (dW, db, dX if requested).
pub fn sketched_linear_backward(
    g: &Mat,
    x: &Mat,
    w: &Mat,
    method: &str,
    budget: f64,
    rng: &mut Pcg64,
    need_dx: bool,
) -> (Mat, Vec<f32>, Option<Mat>) {
    let dout = g.cols;
    let p: Vec<f32> = if method == "per_column" {
        vec![budget.clamp(1e-6, 1.0) as f32; dout]
    } else {
        let scores = column_scores(method, g, Some(w));
        pstar_from_weights(&scores, budget * dout as f64)
    };
    let independent = method == "per_column" || method.ends_with("_ind");
    let z = if independent {
        independent_bernoulli(rng, &p)
    } else {
        correlated_bernoulli(rng, &p)
    };
    let kept = kept_columns(&z, &p);
    let dw = sparse_dw(g, &kept, x);
    let mut db = vec![0.0f32; dout];
    for &(j, inv) in &kept {
        let mut s = 0.0f32;
        for i in 0..g.rows {
            s += g.at(i, j);
        }
        db[j] = s * inv;
    }
    let dx = if need_dx { Some(sparse_dx(g, &kept, w)) } else { None };
    (dw, db, dx)
}

impl Mlp {
    /// He-initialized MLP over `dims` (e.g. `[784, 64, 64, 10]`),
    /// deterministic given `seed`.
    pub fn new(dims: &[usize], seed: u64) -> Mlp {
        assert!(dims.len() >= 2, "need at least one linear layer");
        let mut layers = Vec::with_capacity(dims.len() - 1);
        for (li, pair) in dims.windows(2).enumerate() {
            let (din, dout) = (pair[0], pair[1]);
            let mut rng = Pcg64::new(seed ^ 0x1e57, 300 + li as u64);
            let std = (2.0 / din as f64).sqrt();
            let w = Mat::from_fn(dout, din, |_, _| (rng.gaussian() * std) as f32);
            layers.push(Linear { w, b: vec![0.0; dout] });
        }
        Mlp { layers }
    }

    /// Layer widths, input first.
    pub fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.layers[0].w.cols];
        d.extend(self.layers.iter().map(|l| l.w.rows));
        d
    }

    /// Number of linear (sketchable) layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.data.len() + l.b.len()).sum()
    }

    /// Forward pass, caching everything the backward needs.
    pub fn forward(&self, x: &Mat) -> ForwardCache {
        let n = self.layers.len();
        let mut acts = Vec::with_capacity(n + 1);
        let mut zs = Vec::with_capacity(n);
        acts.push(x.clone());
        for (i, layer) in self.layers.iter().enumerate() {
            let z = affine(acts.last().expect("acts nonempty"), &layer.w, &layer.b);
            let h = if i + 1 < n {
                let mut h = z.clone();
                for v in &mut h.data {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                h
            } else {
                z.clone()
            };
            zs.push(z);
            acts.push(h);
        }
        ForwardCache { acts, zs }
    }

    /// Manual backward from the loss gradient `dlogits`.
    ///
    /// `mask[i] > 0` enables the sketch on layer `i` (the Fig 4 location
    /// ablation); a masked-off or `"baseline"` layer takes the exact path
    /// and consumes no randomness, so `location="none"` reproduces the
    /// baseline trajectory bit-for-bit.
    pub fn backward(
        &self,
        cache: &ForwardCache,
        dlogits: &Mat,
        spec: &SketchSpec,
        mask: &[f32],
        rng: &mut Pcg64,
    ) -> Grads {
        let n = self.layers.len();
        assert_eq!(mask.len(), n, "layer mask length");
        let mut dw_rev: Vec<Mat> = Vec::with_capacity(n);
        let mut db_rev: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut g = dlogits.clone();
        for i in (0..n).rev() {
            let x = &cache.acts[i];
            let layer = &self.layers[i];
            let need_dx = i > 0;
            let sketched = mask[i] > 0.0 && !spec.is_exact();
            let (dwi, dbi, dx) = if sketched {
                sketched_linear_backward(
                    &g, x, &layer.w, &spec.method, spec.budget, rng, need_dx,
                )
            } else {
                exact_linear_backward(&g, x, &layer.w, need_dx)
            };
            dw_rev.push(dwi);
            db_rev.push(dbi);
            if let Some(mut dx) = dx {
                // ReLU derivative at the previous layer's pre-activation
                let z = &cache.zs[i - 1];
                for (v, &zv) in dx.data.iter_mut().zip(&z.data) {
                    if zv <= 0.0 {
                        *v = 0.0;
                    }
                }
                g = dx;
            }
        }
        dw_rev.reverse();
        db_rev.reverse();
        Grads { dw: dw_rev, db: db_rev }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn forward_shapes() {
        let m = Mlp::new(&[5, 4, 3], 0);
        let mut rng = Pcg64::new(1, 0);
        let x = randmat(7, 5, &mut rng);
        let cache = m.forward(&x);
        assert_eq!(cache.acts.len(), 3);
        assert_eq!(cache.zs.len(), 2);
        assert_eq!((cache.logits().rows, cache.logits().cols), (7, 3));
        assert_eq!(m.dims(), vec![5, 4, 3]);
        assert_eq!(m.num_params(), 5 * 4 + 4 + 4 * 3 + 3);
    }

    #[test]
    fn relu_applied_between_but_not_after() {
        let m = Mlp::new(&[3, 4, 8], 1);
        let mut rng = Pcg64::new(2, 0);
        let x = randmat(16, 3, &mut rng);
        let cache = m.forward(&x);
        assert!(cache.acts[1].data.iter().all(|&v| v >= 0.0));
        assert!(cache.logits().data.iter().any(|&v| v < 0.0));
    }

    #[test]
    fn backward_matches_finite_differences() {
        let m = Mlp::new(&[4, 5, 3], 3);
        let mut rng = Pcg64::new(4, 0);
        let x = randmat(6, 4, &mut rng);
        let y: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let cache = m.forward(&x);
        let (_, dlogits) =
            crate::native::loss::loss_and_grad(crate::native::LossKind::CrossEntropy, cache.logits(), &y);
        let grads = m.backward(
            &cache,
            &dlogits,
            &SketchSpec::exact(),
            &[0.0, 0.0],
            &mut rng,
        );
        // finite-difference a few weight coordinates of each layer
        let eps = 1e-3f32;
        let mut m2 = Mlp::new(&[4, 5, 3], 3);
        for li in 0..2 {
            for &idx in &[0usize, 3, 7] {
                let orig = m2.layers[li].w.data[idx];
                m2.layers[li].w.data[idx] = orig + eps;
                let lp = loss_of(&m2, &x, &y);
                m2.layers[li].w.data[idx] = orig - eps;
                let lm = loss_of(&m2, &x, &y);
                m2.layers[li].w.data[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps as f64);
                let an = grads.dw[li].data[idx] as f64;
                // loose bar: f32 forward + ReLU kinks make FD noisy, but a
                // transposed/missing term would be off by O(|fd|)
                assert!(
                    (fd - an).abs() < 1e-2 * (1.0 + fd.abs()),
                    "layer {li} idx {idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    fn loss_of(m: &Mlp, x: &Mat, y: &[i32]) -> f64 {
        let cache = m.forward(x);
        crate::native::loss::loss_value(
            crate::native::LossKind::CrossEntropy,
            cache.logits(),
            y,
        )
    }

    #[test]
    fn sketched_full_budget_matches_exact() {
        let mut rng = Pcg64::new(9, 0);
        let g = randmat(8, 6, &mut rng);
        let x = randmat(8, 5, &mut rng);
        let w = randmat(6, 5, &mut rng);
        let (dw_e, db_e, dx_e) = exact_linear_backward(&g, &x, &w, true);
        let (dw_s, db_s, dx_s) =
            sketched_linear_backward(&g, &x, &w, "l1", 1.0, &mut rng, true);
        for (a, b) in dw_e.data.iter().zip(&dw_s.data) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in db_e.iter().zip(&db_s) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dx_e.unwrap().data.iter().zip(&dx_s.unwrap().data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sketched_budget_drops_columns() {
        let mut rng = Pcg64::new(11, 0);
        let g = randmat(16, 32, &mut rng);
        let x = randmat(16, 8, &mut rng);
        let w = randmat(32, 8, &mut rng);
        let (dw, db, _) =
            sketched_linear_backward(&g, &x, &w, "l1", 0.25, &mut rng, false);
        // dropped output units have identically-zero dW rows and db entries
        let zero_rows = (0..32)
            .filter(|&j| dw.data[j * 8..(j + 1) * 8].iter().all(|&v| v == 0.0))
            .count();
        assert!(zero_rows >= 32 - 10, "only {zero_rows} zero rows");
        assert!(db.iter().filter(|&&v| v == 0.0).count() >= 32 - 10);
    }

    #[test]
    fn masked_off_layers_consume_no_rng() {
        let m = Mlp::new(&[4, 6, 3], 5);
        let mut rng = Pcg64::new(6, 0);
        let x = randmat(5, 4, &mut rng);
        let y = vec![0i32, 1, 2, 0, 1];
        let cache = m.forward(&x);
        let (_, dl) = crate::native::loss::loss_and_grad(
            crate::native::LossKind::CrossEntropy,
            cache.logits(),
            &y,
        );
        let spec = SketchSpec { method: "l1".into(), budget: 0.3 };
        let mut r1 = Pcg64::new(77, 0);
        let g1 = m.backward(&cache, &dl, &spec, &[0.0, 0.0], &mut r1);
        let mut r2 = Pcg64::new(77, 0);
        let g2 = m.backward(&cache, &dl, &SketchSpec::exact(), &[1.0, 1.0], &mut r2);
        for (a, b) in g1.dw[0].data.iter().zip(&g2.dw[0].data) {
            assert!((a - b).abs() < 1e-5);
        }
        // and the rng stream was untouched by the masked run
        assert_eq!(r1.next_u64(), Pcg64::new(77, 0).next_u64());
    }

    #[test]
    fn grads_flatten_and_norm() {
        let g = Grads {
            dw: vec![Mat::from_rows(vec![vec![3.0, 0.0]])],
            db: vec![vec![4.0]],
        };
        assert_eq!(g.flatten(), vec![3.0, 0.0, 4.0]);
        assert!((g.global_norm() - 5.0).abs() < 1e-9);
    }
}
