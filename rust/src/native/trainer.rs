//! The native training loop: same protocol as the PJRT trainer
//! ([`crate::coordinator::trainer`]) — same datasets, batch order, LR
//! schedule and curve format — but every step runs on [`crate::tensor`]
//! kernels through the [`Sequential`] module API, so it needs no AOT
//! artifacts and the sketched backward's FLOP saving is real wall-clock.
//! All registered models ([`crate::native::models`]) train here: MLP,
//! BagNet-lite and ViT-lite.
//!
//! The trainer owns one [`Workspace`] sized at construction; every
//! forward/backward of a run streams through those arenas, so the
//! steady-state step performs no heap allocation (DESIGN.md §7.2).
//! `cfg.threads` (the `--threads` flag) sets the kernels' intra-op worker
//! count — a pure wall-clock knob, bit-identical results at any value.
//! `cfg.act_policy` (`--act-policy`) picks the activation stash policy
//! (§7.4): `exact` keeps full input copies, `kept` compacts sketched
//! sites to kept columns and ReLU inputs to sign bitsets;
//! [`NativeTrainer::workspace_bytes`] reports the resulting footprint.

use crate::config::TrainConfig;
use crate::data::{self, BatchIter, Dataset, DatasetKind};
use crate::metrics::RunCurve;
use crate::pool;
use crate::replicate::{ExchangeStats, ReplicaGroup};
use crate::rng::Pcg64;
use crate::tensor::kernels;
use crate::tensor::Mat;
use anyhow::{bail, Result};

use super::checkpoint;
use super::loss::{accuracy, loss_and_grad_into, loss_value, LossKind};
use super::models;
use super::optim::{clip_global_norm, Optim};
use super::policy::{ActivationPolicy, StepPlan};
use super::sequential::{Sequential, SketchPolicy, Workspace, WorkspaceBytes};

/// Max global gradient norm for every native recipe (§B.2: clip 1.0;
/// ≤ 0 disables).
pub const CLIP_NORM: f64 = 1.0;

/// CPU-native trainer over a [`Sequential`] model stack.
pub struct NativeTrainer {
    /// The run configuration (steps, LR schedule, sketch policy, …).
    pub cfg: TrainConfig,
    model: Sequential,
    ws: Workspace,
    plan: StepPlan,
    opt: Optim,
    loss: LossKind,
    data_kind: DatasetKind,
    sk_rng: Pcg64,
    act_rng: Pcg64,
    /// Data-parallel step engine when `cfg.replicas ≥ 1` (DESIGN.md
    /// §7.6); `None` runs the plain single-stream step.
    group: Option<ReplicaGroup>,
}

impl NativeTrainer {
    /// Build a trainer for `cfg.model` from the model registry.
    pub fn new(cfg: TrainConfig) -> Result<NativeTrainer> {
        let model = models::build(&cfg.model, cfg.seed)?;
        NativeTrainer::with_model(cfg, model)
    }

    /// Build a trainer over an MLP with explicit layer widths (tests
    /// shrink the net).
    pub fn with_dims(cfg: TrainConfig, dims: &[usize]) -> Result<NativeTrainer> {
        let model = models::mlp(dims, cfg.seed);
        NativeTrainer::with_model(cfg, model)
    }

    /// Build a trainer over an explicit model stack.
    pub fn with_model(mut cfg: TrainConfig, model: Sequential) -> Result<NativeTrainer> {
        if cfg.eval_every == 0 {
            // avoid a remainder-by-zero in the step loop; "never" → run end
            cfg.eval_every = cfg.steps.max(1);
        }
        if cfg.batch == 0 || cfg.train_size < cfg.batch {
            bail!(
                "train_size {} must cover at least one batch of {}",
                cfg.train_size,
                cfg.batch
            );
        }
        let plan = model.plan(
            &SketchPolicy::from_config(&cfg),
            &ActivationPolicy::from_config(&cfg)?,
        )?;
        let opt = Optim::parse(&cfg.optimizer)?;
        let loss = LossKind::parse(&cfg.loss)?;
        let data_kind = DatasetKind::for_model(&cfg.model)?;
        let sk_rng = Pcg64::new(cfg.seed ^ 0x9e3779b9, 11);
        // Distinct stream for the forward-side activation gates: the
        // §7.4 unbiasedness argument needs them independent of the
        // backward's G-gates. Exact/full stashes consume none of it.
        let act_rng = Pcg64::new(cfg.seed ^ 0x51ac7, 13);
        if cfg.threads > 0 {
            pool::set_threads(cfg.threads);
        }
        // Validate the kernel kind; an explicit scalar/simd pins the
        // process knob (like --threads), "auto" inherits it.
        let kernel_kind = kernels::KernelKind::parse(&cfg.kernel)?;
        if kernel_kind != kernels::KernelKind::Auto {
            kernels::set_kernel(kernel_kind);
        }
        let ws = model.workspace(cfg.batch, data_kind.dim());
        // `--replicas ≥ 1` builds the data-parallel group; it revalidates
        // the lane grid (batch % 8, replicas | 8) and that the stack is
        // the registry model `cfg.model` names, with loud bails.
        let group = if cfg.replicas > 0 {
            Some(ReplicaGroup::new(&cfg, &model)?)
        } else {
            None
        };
        Ok(NativeTrainer {
            cfg,
            model,
            ws,
            plan,
            opt,
            loss,
            data_kind,
            sk_rng,
            act_rng,
            group,
        })
    }

    /// Batch size of this run.
    pub fn batch_size(&self) -> usize {
        self.cfg.batch
    }

    /// The model stack (e.g. for benches driving steps manually).
    pub fn model(&self) -> &Sequential {
        &self.model
    }

    /// The resolved step plan (sketch + activation decisions per layer).
    pub fn plan(&self) -> &StepPlan {
        &self.plan
    }

    /// Arena-by-arena byte accounting of the trainer's workspace — the
    /// tracked memory column in `BENCH_native.json`. Call after at least
    /// one step for steady-state stash sizes (before the first step the
    /// stash arena is empty).
    pub fn workspace_bytes(&self) -> WorkspaceBytes {
        self.ws.workspace_bytes()
    }

    /// Persist the trained parameters as a versioned binary checkpoint
    /// (DESIGN.md §7.5): the registry key + seed in the header let
    /// [`checkpoint::load`] rebuild this exact architecture in a fresh
    /// process and refill it bit-for-bit. Only registry-built trainers
    /// produce loadable checkpoints — a [`NativeTrainer::with_dims`]
    /// model under a registry key whose shapes differ is rejected at
    /// *load* time by the arch digest.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        checkpoint::save(path, &self.cfg.model, self.cfg.seed, &self.model)?;
        Ok(())
    }

    /// Generate this run's datasets — identical protocol to the PJRT
    /// trainer: contents share a fixed generator seed so method comparisons
    /// are paired; batch order varies with `cfg.seed`.
    pub fn datasets(&self) -> (Dataset, Dataset) {
        let train = data::generate(self.data_kind, self.cfg.train_size, 1234, "train");
        let test = data::generate(self.data_kind, self.cfg.test_size, 1234, "test");
        (train, test)
    }

    /// Modeled gradient-exchange traffic accumulated so far; `None`
    /// unless the trainer runs data-parallel (`cfg.replicas ≥ 1`).
    pub fn exchange_stats(&self) -> Option<ExchangeStats> {
        self.group.as_ref().map(|g| g.stats())
    }

    /// One optimizer step on a batch; returns the training loss. Runs
    /// entirely in the trainer's preallocated workspace.
    pub fn step(&mut self, x: &Mat, y: &[i32], step: usize) -> f64 {
        if let Some(group) = self.group.as_mut() {
            // data-parallel path: the group shards the batch across its
            // lane grid and reduces into the master gradient slots;
            // clip / LR / apply stay identical to the plain path.
            let loss = group.step(&self.model, x, y, &mut self.ws.grad_slots);
            clip_global_norm(&mut self.ws.grad_slots, CLIP_NORM);
            let lr = self.cfg.lr_at(step);
            self.model
                .apply_grads(&mut self.opt, &self.ws.grad_slots, lr);
            return loss;
        }
        self.model
            .forward_train(x, &mut self.ws, &self.plan, &mut self.act_rng);
        let (logits, gout) = self.ws.loss_io();
        let loss = loss_and_grad_into(self.loss, logits, y, gout);
        self.model.backward(&mut self.ws, &self.plan, &mut self.sk_rng);
        clip_global_norm(&mut self.ws.grad_slots, CLIP_NORM);
        let lr = self.cfg.lr_at(step);
        self.model
            .apply_grads(&mut self.opt, &self.ws.grad_slots, lr);
        loss
    }

    /// Evaluate on the full test set; returns (mean loss, accuracy).
    /// Reuses the training workspace (one staged batch buffer per call).
    pub fn evaluate(&mut self, test: &Dataset) -> Result<(f64, f64)> {
        let batch = self.cfg.batch;
        let nb = test.n / batch;
        if nb == 0 {
            bail!("test set smaller than one batch");
        }
        let dim = test.dim;
        let mut x = Mat::zeros(batch, dim);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for b in 0..nb {
            x.data
                .copy_from_slice(&test.x[b * batch * dim..(b + 1) * batch * dim]);
            let y = &test.y[b * batch..(b + 1) * batch];
            self.model.forward(&x, &mut self.ws);
            let logits = self.ws.output();
            loss_sum += loss_value(self.loss, logits, y) * batch as f64;
            correct += accuracy(logits, y) * batch as f64;
        }
        let seen = (nb * batch) as f64;
        Ok((loss_sum / seen, correct / seen))
    }

    /// Full training run; returns the loss/eval curve (same shape as the
    /// PJRT trainer's so sweeps and experiments are backend-agnostic).
    pub fn run(&mut self) -> Result<RunCurve> {
        let (train_ds, test_ds) = self.datasets();
        let mut curve = RunCurve::default();
        let mut rng = Pcg64::new(self.cfg.seed.wrapping_add(77), 3);

        let batch = self.cfg.batch;
        let dim = train_ds.dim;
        // staged batch reused across steps (no per-step allocation)
        let mut xmat = Mat::zeros(batch, dim);
        let mut ybuf = vec![0i32; batch];

        let mut step = 0usize;
        'outer: loop {
            let mut iter = BatchIter::new(&train_ds, batch, &mut rng);
            while iter.next_into(&mut xmat.data, &mut ybuf) {
                if step >= self.cfg.steps {
                    break 'outer;
                }
                let loss = self.step(&xmat, &ybuf, step);
                if !loss.is_finite() {
                    curve.record_loss(step, f64::INFINITY);
                    break 'outer;
                }
                curve.record_loss(step, loss);
                step += 1;
                if step % self.cfg.eval_every == 0 || step == self.cfg.steps {
                    let (el, ea) = self.evaluate(&test_ds)?;
                    curve.record_eval(step, el, ea);
                }
            }
            if step >= self.cfg.steps {
                break;
            }
        }
        if curve.evals.is_empty() {
            let (el, ea) = self.evaluate(&test_ds)?;
            curve.record_eval(step, el, ea);
        }
        Ok(curve)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    fn tiny_cfg(method: &str, budget: f64) -> TrainConfig {
        let mut cfg = Preset::Smoke.base("mlp").unwrap();
        cfg.method = method.into();
        cfg.budget = budget;
        cfg.train_size = 256;
        cfg.test_size = 128;
        cfg.steps = 24;
        cfg.eval_every = 24;
        cfg.batch = 32;
        cfg
    }

    #[test]
    fn rejects_unknown_method_and_model() {
        let mut cfg = tiny_cfg("rcs", 0.2);
        assert!(NativeTrainer::new(cfg.clone()).is_err());
        cfg.method = "l1".into();
        cfg.model = "resnet".into();
        assert!(NativeTrainer::new(cfg).is_err());
    }

    #[test]
    fn rejects_bad_location_and_schedule() {
        let mut cfg = tiny_cfg("l1", 0.2);
        cfg.location = "middle".into();
        assert!(NativeTrainer::new(cfg).is_err());
        let mut cfg = tiny_cfg("l1", 0.2);
        cfg.budget_schedule = vec![0.5, 0.1]; // mlp has 3 sites
        assert!(NativeTrainer::new(cfg).is_err());
    }

    #[test]
    fn rejects_bad_act_policy_values() {
        let mut cfg = tiny_cfg("l1", 0.3);
        cfg.act_policy = "compressed".into();
        assert!(NativeTrainer::new(cfg).is_err());
        let mut cfg = tiny_cfg("l1", 0.3);
        cfg.act_budget = 1.5;
        assert!(NativeTrainer::new(cfg).is_err());
        let mut cfg = tiny_cfg("l1", 0.3);
        cfg.act_schedule = vec![0.5]; // mlp has 3 sites
        assert!(NativeTrainer::new(cfg).is_err());
    }

    #[test]
    fn budget_schedule_trains_when_sized_right() {
        let mut cfg = tiny_cfg("l1", 0.2);
        cfg.budget_schedule = vec![0.5, 0.25, 0.1];
        let mut t = NativeTrainer::new(cfg).unwrap();
        let curve = t.run().unwrap();
        assert!(curve.tail_loss(6).unwrap() < curve.losses[0]);
    }

    #[test]
    fn loss_decreases_exact_and_sketched() {
        for (method, budget) in [("baseline", 1.0), ("l1", 0.3)] {
            let mut t = NativeTrainer::with_dims(
                tiny_cfg(method, budget),
                &[784, 16, 10],
            )
            .unwrap();
            let curve = t.run().unwrap();
            let first = curve.losses[0];
            let last = curve.tail_loss(6).unwrap();
            assert!(
                last < first,
                "{method}: loss {first} → {last} did not decrease"
            );
            assert!(curve.final_acc().is_some());
        }
    }

    #[test]
    fn determinism_same_seed_same_curve() {
        let cfg = tiny_cfg("l1", 0.25);
        let c1 = NativeTrainer::with_dims(cfg.clone(), &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        let c2 = NativeTrainer::with_dims(cfg, &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(c1.losses, c2.losses);
    }

    #[test]
    fn location_none_matches_baseline_exactly() {
        let mut cfg = tiny_cfg("l1", 0.1);
        cfg.location = "none".into();
        let sketched = NativeTrainer::with_dims(cfg.clone(), &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        cfg.method = "baseline".into();
        cfg.location = "all".into();
        let baseline = NativeTrainer::with_dims(cfg, &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(sketched.losses, baseline.losses);
    }

    #[test]
    fn adam_and_mse_paths_train() {
        let mut cfg = tiny_cfg("l1", 0.5);
        cfg.optimizer = "adam".into();
        cfg.loss = "mse".into();
        cfg.lr = 1e-2;
        cfg.steps = 48;
        cfg.eval_every = 48;
        let mut t = NativeTrainer::with_dims(cfg, &[784, 12, 10]).unwrap();
        let curve = t.run().unwrap();
        assert!(
            curve.tail_loss(8).unwrap() < curve.losses[0],
            "MSE/Adam loss {} → {}",
            curve.losses[0],
            curve.tail_loss(8).unwrap()
        );
    }
}
