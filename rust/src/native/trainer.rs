//! The native training loop: same protocol as the PJRT trainer
//! ([`crate::coordinator::trainer`]) — same datasets, batch order, LR
//! schedule and curve format — but every step runs on [`crate::tensor`]
//! kernels through the [`Sequential`] module API, so it needs no AOT
//! artifacts and the sketched backward's FLOP saving is real wall-clock.
//! All registered models ([`crate::native::models`]) train here: MLP,
//! BagNet-lite and ViT-lite.
//!
//! The trainer owns one [`Workspace`] sized at construction; every
//! forward/backward of a run streams through those arenas, so the
//! steady-state step performs no heap allocation (DESIGN.md §7.2).
//! `cfg.threads` (the `--threads` flag) sets the kernels' intra-op worker
//! count — a pure wall-clock knob, bit-identical results at any value.
//! `cfg.act_policy` (`--act-policy`) picks the activation stash policy
//! (§7.4): `exact` keeps full input copies, `kept` compacts sketched
//! sites to kept columns and ReLU inputs to sign bitsets;
//! [`NativeTrainer::workspace_bytes`] reports the resulting footprint.

use crate::config::TrainConfig;
use crate::data::{self, BatchIter, Dataset, DatasetKind};
use crate::faults::{FaultPlan, InjectedKill, NonFiniteLoss, MAX_CONSECUTIVE_SKIPS};
use crate::metrics::RunCurve;
use crate::pool;
use crate::replicate::{ExchangeStats, ReplicaGroup, StepFaults};
use crate::rng::{streams, Pcg64};
use crate::tensor::kernels;
use crate::tensor::Mat;
use anyhow::{bail, Result};

use super::checkpoint;
use super::loss::{accuracy, loss_and_grad_into, loss_value, LossKind};
use super::models;
use super::optim::{clip_global_norm, Optim};
use super::policy::{ActivationPolicy, StepPlan};
use super::sequential::{Sequential, SketchPolicy, Workspace, WorkspaceBytes};

/// Max global gradient norm for every native recipe (§B.2: clip 1.0;
/// ≤ 0 disables).
pub const CLIP_NORM: f64 = 1.0;

/// The checkpoint's optimizer-kind tag (0 = sgd, 1 = momentum, 2 = adam).
fn opt_kind_tag(opt: &Optim) -> u8 {
    match opt {
        Optim::Sgd { momentum, .. } => u8::from(*momentum != 0.0),
        Optim::Adam { .. } => 2,
    }
}

/// Human name for an optimizer-kind tag (resume-mismatch messages).
fn opt_kind_name(tag: u8) -> &'static str {
    match tag {
        0 => "sgd",
        1 => "momentum",
        2 => "adam",
        _ => "unknown",
    }
}

/// CPU-native trainer over a [`Sequential`] model stack.
pub struct NativeTrainer {
    /// The run configuration (steps, LR schedule, sketch policy, …).
    pub cfg: TrainConfig,
    model: Sequential,
    ws: Workspace,
    plan: StepPlan,
    opt: Optim,
    loss: LossKind,
    data_kind: DatasetKind,
    sk_rng: Pcg64,
    act_rng: Pcg64,
    /// Data-parallel step engine when `cfg.replicas ≥ 1` (DESIGN.md
    /// §7.6); `None` runs the plain single-stream step.
    group: Option<ReplicaGroup>,
    /// Parsed fault schedule (`--fault-spec` / `UAVJP_FAULTS`, §7.7);
    /// the default plan injects nothing and costs nothing.
    fault_plan: FaultPlan,
    /// The dedicated fault stream — disjoint from every training stream,
    /// checkpointed like them so chaos runs resume bit-identically.
    fault_rng: Pcg64,
    /// Steps whose non-finite gradient was skipped instead of applied.
    steps_skipped: u64,
    /// Current consecutive-skip streak (≥ [`MAX_CONSECUTIVE_SKIPS`] aborts
    /// with [`NonFiniteLoss`]).
    consecutive_skips: u32,
    /// Steps already executed by the run this trainer resumes
    /// (`--resume`); [`NativeTrainer::run`] fast-forwards the batch
    /// stream past them by replay.
    start_step: usize,
    /// Steps executed so far (start + this process); what the v2
    /// checkpoint records as its step counter.
    steps_done: usize,
}

impl NativeTrainer {
    /// Build a trainer for `cfg.model` from the model registry.
    pub fn new(cfg: TrainConfig) -> Result<NativeTrainer> {
        let model = models::build(&cfg.model, cfg.seed)?;
        NativeTrainer::with_model(cfg, model)
    }

    /// Build a trainer over an MLP with explicit layer widths (tests
    /// shrink the net).
    pub fn with_dims(cfg: TrainConfig, dims: &[usize]) -> Result<NativeTrainer> {
        let model = models::mlp(dims, cfg.seed);
        NativeTrainer::with_model(cfg, model)
    }

    /// Build a trainer over an explicit model stack.
    pub fn with_model(mut cfg: TrainConfig, model: Sequential) -> Result<NativeTrainer> {
        if cfg.eval_every == 0 {
            // avoid a remainder-by-zero in the step loop; "never" → run end
            cfg.eval_every = cfg.steps.max(1);
        }
        if cfg.batch == 0 || cfg.train_size < cfg.batch {
            bail!(
                "train_size {} must cover at least one batch of {}",
                cfg.train_size,
                cfg.batch
            );
        }
        let plan = model.plan(
            &SketchPolicy::from_config(&cfg),
            &ActivationPolicy::from_config(&cfg)?,
        )?;
        let opt = Optim::parse(&cfg.optimizer)?;
        let loss = LossKind::parse(&cfg.loss)?;
        let data_kind = DatasetKind::for_model(&cfg.model)?;
        let sk_rng = streams::sketch_gates(cfg.seed);
        // Distinct stream for the forward-side activation gates: the
        // §7.4 unbiasedness argument needs them independent of the
        // backward's G-gates. Exact/full stashes consume none of it.
        let act_rng = streams::act_gates(cfg.seed);
        if cfg.threads > 0 {
            pool::set_threads(cfg.threads);
        }
        // Validate the kernel kind; an explicit scalar/simd pins the
        // process knob (like --threads), "auto" inherits it.
        let kernel_kind = kernels::KernelKind::parse(&cfg.kernel)?;
        if kernel_kind != kernels::KernelKind::Auto {
            kernels::set_kernel(kernel_kind);
        }
        let ws = model.workspace(cfg.batch, data_kind.dim());
        // `--replicas ≥ 1` builds the data-parallel group; it revalidates
        // the lane grid (batch % 8, replicas | 8) and that the stack is
        // the registry model `cfg.model` names, with loud bails.
        let group = if cfg.replicas > 0 {
            Some(ReplicaGroup::new(&cfg, &model)?)
        } else {
            None
        };
        let fault_plan = FaultPlan::from_config(&cfg.fault_spec)?;
        if fault_plan.lane_drop_p > 0.0 && group.is_none() {
            bail!(
                "fault `lane_drop` drops reduce lanes, which need a replica \
                 group: add --replicas (1|2|4|8)"
            );
        }
        if cfg.ckpt_every > 0 && cfg.ckpt_path.is_empty() {
            bail!("--ckpt-every needs a checkpoint path (--save-ckpt <path>)");
        }
        let fault_rng = FaultPlan::stream(cfg.seed);
        let mut trainer = NativeTrainer {
            cfg,
            model,
            ws,
            plan,
            opt,
            loss,
            data_kind,
            sk_rng,
            act_rng,
            group,
            fault_plan,
            fault_rng,
            steps_skipped: 0,
            consecutive_skips: 0,
            start_step: 0,
            steps_done: 0,
        };
        if !trainer.cfg.resume.is_empty() {
            let path = std::path::PathBuf::from(&trainer.cfg.resume);
            trainer.restore_from(&path)?;
        }
        Ok(trainer)
    }

    /// Restore the mid-run state a `--resume` checkpoint carries:
    /// parameters, optimizer slots, step counters and the raw words of
    /// every RNG stream — after which [`NativeTrainer::run`] continues
    /// the interrupted trajectory bit-identically (DESIGN.md §7.7).
    fn restore_from(&mut self, path: &std::path::Path) -> Result<()> {
        let ckpt = checkpoint::load(path)?;
        if ckpt.model_name != self.cfg.model {
            bail!(
                "--resume checkpoint is for model {:?}, this run trains {:?}",
                ckpt.model_name,
                self.cfg.model
            );
        }
        let Some(state) = ckpt.train.clone() else {
            bail!(
                "--resume needs a resumable (v2) checkpoint; {} is a \
                 param-only (v1) file",
                path.display()
            );
        };
        // params: fill the live stack through the same slot walk
        // `Checkpoint::build_model` uses, with the same shape checks
        let mut slot = 0usize;
        for layer in &mut self.model.layers {
            for p in layer.params_mut() {
                let src = ckpt.slots.get(slot).ok_or_else(|| {
                    anyhow::anyhow!("--resume checkpoint is missing slot {slot}")
                })?;
                if src.len() != p.len() {
                    bail!(
                        "--resume slot {slot} length {} != model's {}",
                        src.len(),
                        p.len()
                    );
                }
                p.copy_from_slice(src);
                slot += 1;
            }
        }
        if slot != ckpt.slots.len() {
            bail!(
                "--resume checkpoint has {} slots, model wants {slot}",
                ckpt.slots.len()
            );
        }
        // optimizer: the stored kind must match this run's config —
        // resuming sgd state into adam would be a silent divergence
        let kind = opt_kind_tag(&self.opt);
        if kind != state.opt_kind {
            bail!(
                "--resume optimizer mismatch: checkpoint stores kind {} \
                 ({}), config asks for {} ({})",
                state.opt_kind,
                opt_kind_name(state.opt_kind),
                kind,
                opt_kind_name(kind)
            );
        }
        match &mut self.opt {
            Optim::Sgd { vel, .. } => *vel = state.opt_m.clone(),
            Optim::Adam { t, m, v, .. } => {
                *t = state.opt_t.clone();
                *m = state.opt_m.clone();
                *v = state.opt_v.clone();
            }
        }
        // RNG streams: raw-word restore puts every generator exactly
        // where the interrupted run left it
        self.sk_rng = Pcg64::from_state_words(state.sk);
        self.act_rng = Pcg64::from_state_words(state.act);
        self.fault_rng = Pcg64::from_state_words(state.fault);
        match (&mut self.group, state.lanes.is_empty()) {
            (Some(group), false) => group.restore_lane_streams(&state.lanes)?,
            (Some(_), true) => bail!(
                "--resume checkpoint was written by a plain run; \
                 it cannot resume under --replicas"
            ),
            (None, false) => bail!(
                "--resume checkpoint was written under --replicas; \
                 add --replicas (1|2|4|8) to resume it"
            ),
            (None, true) => {}
        }
        self.steps_skipped = state.steps_skipped;
        self.consecutive_skips = state.consecutive_skips;
        self.start_step = state.step as usize;
        self.steps_done = self.start_step;
        Ok(())
    }

    /// Batch size of this run.
    pub fn batch_size(&self) -> usize {
        self.cfg.batch
    }

    /// The model stack (e.g. for benches driving steps manually).
    pub fn model(&self) -> &Sequential {
        &self.model
    }

    /// The resolved step plan (sketch + activation decisions per layer).
    pub fn plan(&self) -> &StepPlan {
        &self.plan
    }

    /// Arena-by-arena byte accounting of the trainer's workspace — the
    /// tracked memory column in `BENCH_native.json`. Call after at least
    /// one step for steady-state stash sizes (before the first step the
    /// stash arena is empty).
    pub fn workspace_bytes(&self) -> WorkspaceBytes {
        self.ws.workspace_bytes()
    }

    /// Persist the trained parameters as a versioned binary checkpoint
    /// (DESIGN.md §7.5): the registry key + seed in the header let
    /// [`checkpoint::load`] rebuild this exact architecture in a fresh
    /// process and refill it bit-for-bit. Only registry-built trainers
    /// produce loadable checkpoints — a [`NativeTrainer::with_dims`]
    /// model under a registry key whose shapes differ is rejected at
    /// *load* time by the arch digest. Since the fault-tolerance work
    /// (§7.7) this writes a resumable version-2 file — the [`TrainState`]
    /// block is transparent to serving, and the write is atomic
    /// (staged at `<path>.tmp`, then renamed).
    ///
    /// [`TrainState`]: checkpoint::TrainState
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        checkpoint::save_with_state(
            path,
            &self.cfg.model,
            self.cfg.seed,
            &self.model,
            &self.train_state(),
        )?;
        Ok(())
    }

    /// Snapshot the mid-run state a resumable checkpoint persists: step
    /// counters, optimizer slots and the raw words of every RNG stream.
    fn train_state(&self) -> checkpoint::TrainState {
        let (opt_t, opt_m, opt_v) = match &self.opt {
            Optim::Sgd { vel, .. } => (Vec::new(), vel.clone(), Vec::new()),
            Optim::Adam { t, m, v, .. } => (t.clone(), m.clone(), v.clone()),
        };
        checkpoint::TrainState {
            step: self.steps_done as u64,
            steps_skipped: self.steps_skipped,
            consecutive_skips: self.consecutive_skips,
            opt_kind: opt_kind_tag(&self.opt),
            opt_t,
            opt_m,
            opt_v,
            sk: self.sk_rng.state_words(),
            act: self.act_rng.state_words(),
            fault: self.fault_rng.state_words(),
            lanes: self
                .group
                .as_ref()
                .map_or_else(Vec::new, |g| g.lane_stream_words()),
        }
    }

    /// Write the periodic checkpoint scheduled after `steps_done` steps —
    /// or, under an armed `ckpt_truncate` fault, tear the write exactly
    /// where a kill mid-`fs::write` would: half the payload lands in the
    /// staging file, the rename never happens, and the previous
    /// checkpoint survives untouched.
    fn periodic_checkpoint(&self) -> Result<()> {
        let path = std::path::PathBuf::from(&self.cfg.ckpt_path);
        if self.fault_plan.truncate_ckpt_at(self.steps_done) {
            let bytes = checkpoint::save_state_bytes(
                &self.cfg.model,
                self.cfg.seed,
                &self.model,
                &self.train_state(),
            );
            std::fs::write(checkpoint::tmp_path(&path), &bytes[..bytes.len() / 2])?;
            return Ok(());
        }
        self.save_checkpoint(&path)
    }

    /// Generate this run's datasets — identical protocol to the PJRT
    /// trainer: contents share a fixed generator seed so method comparisons
    /// are paired; batch order varies with `cfg.seed`.
    pub fn datasets(&self) -> (Dataset, Dataset) {
        let train = data::generate(self.data_kind, self.cfg.train_size, 1234, "train");
        let test = data::generate(self.data_kind, self.cfg.test_size, 1234, "test");
        (train, test)
    }

    /// Modeled gradient-exchange traffic accumulated so far; `None`
    /// unless the trainer runs data-parallel (`cfg.replicas ≥ 1`).
    pub fn exchange_stats(&self) -> Option<ExchangeStats> {
        self.group.as_ref().map(|g| g.stats())
    }

    /// Steps whose non-finite gradient was skipped instead of applied
    /// (the train report's `steps_skipped`).
    pub fn steps_skipped(&self) -> u64 {
        self.steps_skipped
    }

    /// Steps the `--resume` checkpoint had already executed (0 for a
    /// fresh run); [`NativeTrainer::run`] fast-forwards past them.
    pub fn start_step(&self) -> usize {
        self.start_step
    }

    /// One optimizer step on a batch; returns the training loss. Runs
    /// entirely in the trainer's preallocated workspace. Errors are
    /// fault-path only — a fresh trainer with no `--fault-spec` never
    /// returns one: an armed plan can poison the gradient (skipped, and
    /// [`NonFiniteLoss`] after [`MAX_CONSECUTIVE_SKIPS`] in a row), drop
    /// reduce lanes (survivors rescaled, see [`StepFaults`]), or panic a
    /// replica worker (caught; fatal only if every replica dies).
    pub fn step(&mut self, x: &Mat, y: &[i32], step: usize) -> Result<f64> {
        let loss = if let Some(group) = self.group.as_mut() {
            // data-parallel path: the group shards the batch across its
            // lane grid and reduces into the master gradient slots;
            // clip / LR / apply stay identical to the plain path.
            if self.fault_plan.is_armed() {
                let faults = StepFaults {
                    drops: self.fault_plan.draw_lane_drops(&mut self.fault_rng),
                    gain: self.fault_plan.lane_gain(),
                    panic_replica: self.fault_plan.worker_panic_at(step),
                };
                group.step_faulted(&self.model, x, y, &mut self.ws.grad_slots, &faults)?
            } else {
                group.step(&self.model, x, y, &mut self.ws.grad_slots)
            }
        } else {
            self.model
                .forward_train(x, &mut self.ws, &self.plan, &mut self.act_rng);
            let (logits, gout) = self.ws.loss_io();
            let loss = loss_and_grad_into(self.loss, logits, y, gout);
            self.model.backward(&mut self.ws, &self.plan, &mut self.sk_rng);
            loss
        };
        if self.fault_plan.nan_grad_at(step) {
            if let Some(v) = self.ws.grad_slots.slots.iter_mut().flatten().next() {
                *v = f32::NAN;
            }
        }
        // Non-finite guard: clip's pre-clip norm is a free global scan of
        // the reduced gradient. A NaN norm compares false against the
        // cap, so the clip itself never rescales a poisoned gradient.
        let norm = clip_global_norm(&mut self.ws.grad_slots, CLIP_NORM);
        if !norm.is_finite() {
            self.steps_skipped += 1;
            self.consecutive_skips += 1;
            if self.consecutive_skips >= MAX_CONSECUTIVE_SKIPS {
                return Err(NonFiniteLoss {
                    step,
                    skips: self.consecutive_skips,
                }
                .into());
            }
            return Ok(loss);
        }
        self.consecutive_skips = 0;
        let lr = self.cfg.lr_at(step);
        self.model
            .apply_grads(&mut self.opt, &self.ws.grad_slots, lr);
        Ok(loss)
    }

    /// Evaluate on the full test set; returns (mean loss, accuracy).
    /// Reuses the training workspace (one staged batch buffer per call).
    pub fn evaluate(&mut self, test: &Dataset) -> Result<(f64, f64)> {
        let batch = self.cfg.batch;
        let nb = test.n / batch;
        if nb == 0 {
            bail!("test set smaller than one batch");
        }
        let dim = test.dim;
        let mut x = Mat::zeros(batch, dim);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for b in 0..nb {
            x.data
                .copy_from_slice(&test.x[b * batch * dim..(b + 1) * batch * dim]);
            let y = &test.y[b * batch..(b + 1) * batch];
            self.model.forward(&x, &mut self.ws);
            let logits = self.ws.output();
            loss_sum += loss_value(self.loss, logits, y) * batch as f64;
            correct += accuracy(logits, y) * batch as f64;
        }
        let seen = (nb * batch) as f64;
        Ok((loss_sum / seen, correct / seen))
    }

    /// Full training run; returns the loss/eval curve (same shape as the
    /// PJRT trainer's so sweeps and experiments are backend-agnostic).
    ///
    /// Under `--resume` the first `start_step` batches are *replayed*
    /// without stepping — the batch stream is a pure function of the
    /// seed, so skipping exactly that many draws lands the iterator where
    /// the interrupted run left it (the params/optimizer/gate streams
    /// come from the checkpoint). With `--ckpt-every N` a resumable
    /// checkpoint lands atomically at `cfg.ckpt_path` after every N-th
    /// executed step; an armed `kill@step=K` fault then aborts with
    /// [`InjectedKill`] right after step `K` (and its save, if
    /// scheduled), which is what the CI chaos leg resumes from.
    pub fn run(&mut self) -> Result<RunCurve> {
        let (train_ds, test_ds) = self.datasets();
        let mut curve = RunCurve::default();
        let mut rng = streams::train_batch(self.cfg.seed);

        let batch = self.cfg.batch;
        let dim = train_ds.dim;
        // staged batch reused across steps (no per-step allocation)
        let mut xmat = Mat::zeros(batch, dim);
        let mut ybuf = vec![0i32; batch];

        let mut step = 0usize;
        'outer: loop {
            let mut iter = BatchIter::new(&train_ds, batch, &mut rng);
            while iter.next_into(&mut xmat.data, &mut ybuf) {
                if step >= self.cfg.steps {
                    break 'outer;
                }
                if step < self.start_step {
                    // resume fast-forward: consume the batch, don't step
                    step += 1;
                    continue;
                }
                let loss = self.step(&xmat, &ybuf, step)?;
                if !loss.is_finite() {
                    curve.record_loss(step, f64::INFINITY);
                    break 'outer;
                }
                curve.record_loss(step, loss);
                step += 1;
                self.steps_done = step;
                if step % self.cfg.eval_every == 0 || step == self.cfg.steps {
                    let (el, ea) = self.evaluate(&test_ds)?;
                    curve.record_eval(step, el, ea);
                }
                if self.cfg.ckpt_every > 0 && step % self.cfg.ckpt_every == 0 {
                    self.periodic_checkpoint()?;
                }
                if self.fault_plan.kill_after(step - 1) {
                    return Err(InjectedKill { step: step - 1 }.into());
                }
            }
            if step >= self.cfg.steps {
                break;
            }
        }
        if curve.evals.is_empty() {
            let (el, ea) = self.evaluate(&test_ds)?;
            curve.record_eval(step, el, ea);
        }
        Ok(curve)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    fn tiny_cfg(method: &str, budget: f64) -> TrainConfig {
        let mut cfg = Preset::Smoke.base("mlp").unwrap();
        cfg.method = method.into();
        cfg.budget = budget;
        cfg.train_size = 256;
        cfg.test_size = 128;
        cfg.steps = 24;
        cfg.eval_every = 24;
        cfg.batch = 32;
        cfg
    }

    #[test]
    fn rejects_unknown_method_and_model() {
        let mut cfg = tiny_cfg("rcs", 0.2);
        assert!(NativeTrainer::new(cfg.clone()).is_err());
        cfg.method = "l1".into();
        cfg.model = "resnet".into();
        assert!(NativeTrainer::new(cfg).is_err());
    }

    #[test]
    fn rejects_bad_location_and_schedule() {
        let mut cfg = tiny_cfg("l1", 0.2);
        cfg.location = "middle".into();
        assert!(NativeTrainer::new(cfg).is_err());
        let mut cfg = tiny_cfg("l1", 0.2);
        cfg.budget_schedule = vec![0.5, 0.1]; // mlp has 3 sites
        assert!(NativeTrainer::new(cfg).is_err());
    }

    #[test]
    fn rejects_bad_act_policy_values() {
        let mut cfg = tiny_cfg("l1", 0.3);
        cfg.act_policy = "compressed".into();
        assert!(NativeTrainer::new(cfg).is_err());
        let mut cfg = tiny_cfg("l1", 0.3);
        cfg.act_budget = 1.5;
        assert!(NativeTrainer::new(cfg).is_err());
        let mut cfg = tiny_cfg("l1", 0.3);
        cfg.act_schedule = vec![0.5]; // mlp has 3 sites
        assert!(NativeTrainer::new(cfg).is_err());
    }

    #[test]
    fn budget_schedule_trains_when_sized_right() {
        let mut cfg = tiny_cfg("l1", 0.2);
        cfg.budget_schedule = vec![0.5, 0.25, 0.1];
        let mut t = NativeTrainer::new(cfg).unwrap();
        let curve = t.run().unwrap();
        assert!(curve.tail_loss(6).unwrap() < curve.losses[0]);
    }

    #[test]
    fn loss_decreases_exact_and_sketched() {
        for (method, budget) in [("baseline", 1.0), ("l1", 0.3)] {
            let mut t = NativeTrainer::with_dims(
                tiny_cfg(method, budget),
                &[784, 16, 10],
            )
            .unwrap();
            let curve = t.run().unwrap();
            let first = curve.losses[0];
            let last = curve.tail_loss(6).unwrap();
            assert!(
                last < first,
                "{method}: loss {first} → {last} did not decrease"
            );
            assert!(curve.final_acc().is_some());
        }
    }

    #[test]
    fn determinism_same_seed_same_curve() {
        let cfg = tiny_cfg("l1", 0.25);
        let c1 = NativeTrainer::with_dims(cfg.clone(), &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        let c2 = NativeTrainer::with_dims(cfg, &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(c1.losses, c2.losses);
    }

    #[test]
    fn location_none_matches_baseline_exactly() {
        let mut cfg = tiny_cfg("l1", 0.1);
        cfg.location = "none".into();
        let sketched = NativeTrainer::with_dims(cfg.clone(), &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        cfg.method = "baseline".into();
        cfg.location = "all".into();
        let baseline = NativeTrainer::with_dims(cfg, &[784, 12, 10])
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(sketched.losses, baseline.losses);
    }

    #[test]
    fn adam_and_mse_paths_train() {
        let mut cfg = tiny_cfg("l1", 0.5);
        cfg.optimizer = "adam".into();
        cfg.loss = "mse".into();
        cfg.lr = 1e-2;
        cfg.steps = 48;
        cfg.eval_every = 48;
        let mut t = NativeTrainer::with_dims(cfg, &[784, 12, 10]).unwrap();
        let curve = t.run().unwrap();
        assert!(
            curve.tail_loss(8).unwrap() < curve.losses[0],
            "MSE/Adam loss {} → {}",
            curve.losses[0],
            curve.tail_loss(8).unwrap()
        );
    }
}
