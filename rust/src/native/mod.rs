//! CPU-native training backend: the paper's sketched backward, end to end.
//!
//! The PJRT path ([`crate::runtime`]) executes AOT-compiled JAX graphs; this
//! module is the self-contained alternative (DESIGN.md §7): an MLP whose
//! forward runs on [`crate::tensor::Mat`] and whose backward is written by
//! hand per layer, so the paper's randomized VJP estimators plug in exactly
//! where the math says they do —
//!
//! 1. column scores on the output gradient ([`crate::sketch::column_scores`]),
//! 2. waterfilled keep-probabilities ([`crate::sketch::pstar_from_weights`]),
//! 3. correlated (systematic) or independent Bernoulli gates,
//! 4. 1/pᵢ-rescaled kept-column GEMMs ([`crate::tensor::sparse_dx`] /
//!    [`crate::tensor::sparse_dw`]).
//!
//! Because the sparse GEMMs really skip dropped columns, wall-clock shrinks
//! with the budget (Eq. 6's ρ(V)) — `cargo bench native_bwd` measures it —
//! while unbiasedness keeps SGD convergent (`tests/native_unbiased.rs`
//! checks E[ĝ] = g by Monte Carlo).
//!
//! Submodules: [`mlp`] (model + manual backward), [`loss`] (cross-entropy /
//! MSE heads), [`optim`] (SGD, momentum, Adam, gradient clipping),
//! [`trainer`] (the training loop behind `--backend native`).

pub mod loss;
pub mod mlp;
pub mod optim;
pub mod trainer;

pub use loss::{accuracy, loss_and_grad, loss_value, LossKind};
pub use mlp::{
    sketched_linear_backward, ForwardCache, Grads, Linear, Mlp, SketchSpec,
    NATIVE_METHODS,
};
pub use optim::{clip_global_norm, Optim};
pub use trainer::NativeTrainer;
