//! CPU-native training backend: the paper's sketched backward, end to end,
//! on a composable module API.
//!
//! The PJRT path ([`crate::runtime`]) executes AOT-compiled JAX graphs;
//! this module is the self-contained alternative (DESIGN.md §7): models are
//! [`Sequential`] stacks of [`Layer`] modules whose forwards run on
//! [`crate::tensor::Mat`] and whose backwards are written by hand per
//! layer, so the paper's randomized VJP estimators plug in exactly where
//! the math says they do —
//!
//! 1. column scores on the output gradient ([`crate::sketch::column_scores`]),
//! 2. waterfilled keep-probabilities ([`crate::sketch::pstar_from_weights`]),
//! 3. correlated (systematic) or independent Bernoulli gates,
//! 4. 1/pᵢ-rescaled kept-column GEMMs ([`crate::tensor::sparse_dx`] /
//!    [`crate::tensor::sparse_dw`]).
//!
//! Because the sparse GEMMs really skip dropped columns, wall-clock shrinks
//! with the budget (Eq. 6's ρ(V)) — `cargo bench native_bwd` measures it —
//! while unbiasedness keeps SGD convergent (`tests/native_unbiased.rs`
//! checks E[ĝ] = g by Monte Carlo).
//!
//! Submodules: [`layer`] (the `Layer` trait, `Linear`/`Relu`, the sketched
//! linear backward), [`conv`] (BagNet-lite patch layers), [`attention`]
//! (ViT-lite blocks), [`sequential`] (the container + `SketchPolicy`),
//! [`models`] (the registry of named architectures), [`loss`]
//! (cross-entropy / MSE heads), [`optim`] (SGD, momentum, Adam, gradient
//! clipping), [`trainer`] (the training loop behind `--backend native`).

pub mod attention;
pub mod conv;
pub mod layer;
pub mod loss;
pub mod models;
pub mod optim;
pub mod sequential;
pub mod trainer;

pub use attention::{Attention, FfnBlock, LayerNorm, PosEmbed};
pub use conv::{PatchConv, PatchMeanPool, Patchify};
pub use layer::{
    affine, exact_linear_backward, sketched_linear_backward, Cache, Grads,
    Layer, Linear, Relu, SiteSketch, SketchCtx, NATIVE_METHODS,
};
pub use loss::{accuracy, loss_and_grad, loss_value, LossKind};
pub use optim::{clip_global_norm, Optim};
pub use sequential::{Sequential, SketchPolicy, Tape};
pub use trainer::NativeTrainer;
