//! CPU-native training backend: the paper's sketched backward, end to end,
//! on a composable module API over view-based, destination-passing
//! kernels.
//!
//! The PJRT path ([`crate::runtime`]) executes AOT-compiled JAX graphs;
//! this module is the self-contained alternative (DESIGN.md §7): models are
//! [`Sequential`] stacks of [`Layer`] modules whose forwards write into a
//! preallocated [`Workspace`] and whose backwards are written by hand per
//! layer, so the paper's randomized VJP estimators plug in exactly where
//! the math says they do —
//!
//! 1. column scores on the output gradient
//!    ([`crate::sketch::SketchScratch::plan_columns`]),
//! 2. waterfilled keep-probabilities (Algorithm 1),
//! 3. correlated (systematic) or independent Bernoulli gates,
//! 4. 1/pᵢ-rescaled kept-column GEMMs ([`crate::tensor::sparse_dx_into`] /
//!    [`crate::tensor::sparse_dw_into`]).
//!
//! Because the sparse GEMMs really skip dropped columns — against a
//! blocked, multi-threaded dense baseline with no data-dependent
//! shortcuts — wall-clock shrinks with the budget (Eq. 6's ρ(V));
//! `cargo bench gemm_scaling` measures it kernel-vs-kernel while
//! unbiasedness keeps SGD convergent (`tests/native_unbiased.rs` checks
//! E[ĝ] = g by Monte Carlo).
//!
//! The forward side mirrors this with a per-layer activation policy
//! (DESIGN.md §7.4): what each layer's backward will read of its input is
//! captured into a per-layer [`Stash`] — a full copy under
//! [`ActivationPolicy::exact`], a sign bitset or the gathered kept
//! columns under the kept policy — so activation memory stops scaling
//! with depth ([`Workspace::workspace_bytes`] accounts it arena by
//! arena).
//!
//! Submodules: [`layer`] (the `Layer` trait, `Linear`/`Relu`, the sketched
//! linear backward), [`conv`] (BagNet-lite patch layers), [`attention`]
//! (ViT-lite blocks), [`policy`] (the activation policy: `ActivationPolicy`,
//! `ActSite`, `Stash`, the kept-column backward), [`sequential`] (the
//! container + `Workspace` + `SketchPolicy` + `StepPlan`), [`models`] (the
//! registry of named architectures), [`loss`] (cross-entropy / MSE heads),
//! [`optim`] (SGD, momentum, Adam, gradient clipping), [`trainer`] (the
//! training loop behind `--backend native`), [`checkpoint`] (versioned
//! binary save/load of the flat parameter registry — what `serve` loads).

pub mod attention;
pub mod checkpoint;
pub mod conv;
pub mod layer;
pub mod loss;
pub mod models;
pub mod optim;
pub mod policy;
pub mod sequential;
pub mod trainer;

pub use attention::{Attention, FfnBlock, LayerNorm, PosEmbed};
pub use checkpoint::{Checkpoint, CkptError};
pub use conv::{PatchConv, PatchMeanPool, Patchify};
pub use layer::{
    affine, affine_into, exact_linear_backward, exact_linear_backward_into,
    kept_linear_backward_into, run_layer_backward, run_layer_forward,
    sketched_linear_backward, sketched_linear_backward_into, Cache, Grads,
    Layer, Linear, Relu, SiteSketch, SketchCtx, NATIVE_METHODS,
};
pub use loss::{
    accuracy, loss_and_grad, loss_and_grad_into, loss_and_grad_scaled_into,
    loss_value, LossKind,
};
pub use optim::{clip_global_norm, Optim};
pub use policy::{
    ActMode, ActSite, ActivationPolicy, InputNeed, Stash, StashedInput,
    StepPlan, ACT_METHOD,
};
pub use sequential::{Sequential, SketchPolicy, Workspace, WorkspaceBytes};
pub use trainer::NativeTrainer;
