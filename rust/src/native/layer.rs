//! The composable module API of the native backend: the [`Layer`] trait,
//! its per-layer forward [`Cache`], the [`SketchCtx`] handed to every
//! backward call, the flat [`Grads`] parameter-gradient registry, and the
//! two primitive layers everything else is built from ([`Linear`],
//! [`Relu`]).
//!
//! A layer is a pure function plus parameters: `forward` maps a batch
//! matrix to a batch matrix and records whatever the backward needs in a
//! [`Cache`]; `backward` maps the output gradient back to an input gradient
//! and per-parameter gradients. Layers that support the paper's column
//! sketch report `sketchable() == true` and read their per-site decision
//! from the [`SketchCtx`] — exact when `ctx.sketch` is `None`, the §4.2
//! column estimator otherwise. [`crate::native::Sequential`] owns the tape
//! and drives the reverse sweep.

use crate::rng::Pcg64;
use crate::sketch::{
    column_scores, correlated_bernoulli, independent_bernoulli, kept_columns,
    pstar_from_weights,
};
use crate::tensor::{matmul, sparse_dw, sparse_dx, Mat};

/// Column-sketch methods the native backward supports (the coordinate and
/// uniform-column families of §4.2; spectral and row/element masks stay
/// PJRT-only).
pub const NATIVE_METHODS: &[&str] = &[
    "baseline", "per_column", "l1", "l1_ind", "l1_sq", "l2", "l2_sq", "var",
    "var_sq", "ds",
];

/// Forward intermediates one layer saves for its backward pass. A plain bag
/// of matrices: each layer documents what it stores at which index.
#[derive(Default)]
pub struct Cache {
    /// The cached matrices, in the order the layer's `forward` pushed them.
    pub mats: Vec<Mat>,
}

/// The resolved sketch decision for one backward site: which score method
/// gates the columns and at what kept-column budget. Gate coupling
/// (correlated vs independent Bernoulli) is carried by the method name
/// (`per_column` and `*_ind` sample independently, Lemma 3.4).
#[derive(Clone, Debug, PartialEq)]
pub struct SiteSketch {
    /// One of [`NATIVE_METHODS`] (never `"baseline"` — exact sites resolve
    /// to `None` instead).
    pub method: String,
    /// Kept-column budget p ∈ (0, 1] for this site.
    pub budget: f64,
}

/// Per-layer context for one backward call: the site's sketch decision (or
/// `None` for the exact path) and the run's gate-randomness stream. Exact
/// sites consume no randomness, which is what keeps `location="none"` runs
/// bit-identical to the baseline.
pub struct SketchCtx<'a> {
    /// Sketch decision for this site; `None` means exact backward.
    pub sketch: Option<&'a SiteSketch>,
    /// The trainer's gate-randomness stream.
    pub rng: &'a mut Pcg64,
}

/// One differentiable module in a [`crate::native::Sequential`] stack.
///
/// Implementations must uphold two contracts the container relies on:
/// the order of tensors returned by [`Layer::params`],
/// [`Layer::params_mut`] and the param-gradient list of
/// [`Layer::backward`] must agree, and a backward with `ctx.sketch ==
/// None` must consume no randomness from `ctx.rng`.
pub trait Layer {
    /// Short name for logs and debugging ("linear", "attention", …).
    fn name(&self) -> &'static str;

    /// Forward pass on a batch: returns the output and the cache the
    /// backward needs.
    fn forward(&self, x: &Mat) -> (Mat, Cache);

    /// Backward pass: maps the output gradient `gy` to the input gradient
    /// (when `need_gx`; the first layer of a stack skips it) and one flat
    /// gradient per parameter tensor, in [`Layer::params`] order.
    fn backward(
        &self,
        gy: &Mat,
        cache: &Cache,
        ctx: &mut SketchCtx<'_>,
        need_gx: bool,
    ) -> (Option<Mat>, Vec<Vec<f32>>);

    /// Flat views of this layer's parameter tensors (empty if none).
    fn params(&self) -> Vec<&[f32]>;

    /// Mutable flat views, same order as [`Layer::params`].
    fn params_mut(&mut self) -> Vec<&mut [f32]>;

    /// Whether this layer is a sketch site (reads `ctx.sketch`).
    fn sketchable(&self) -> bool {
        false
    }

    /// Total parameter count.
    fn num_params(&self) -> usize {
        self.params().iter().map(|p| p.len()).sum()
    }
}

/// Per-parameter-tensor gradients in the model's global slot order (layer
/// order, each layer's tensors in [`Layer::params`] order) — the one flat
/// layout optimizers, clipping and the variance probes see.
pub struct Grads {
    /// One flat gradient per parameter tensor.
    pub slots: Vec<Vec<f32>>,
}

impl Grads {
    /// Concatenate every slot into one vector (the layout the variance
    /// probes reason about).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for s in &self.slots {
            out.extend_from_slice(s);
        }
        out
    }

    /// Global ℓ2 norm over every gradient entry.
    pub fn global_norm(&self) -> f64 {
        let mut sq = 0.0f64;
        for s in &self.slots {
            sq += s.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        }
        sq.sqrt()
    }

    /// Scale every gradient entry by `s` (used by clipping).
    pub fn scale(&mut self, s: f32) {
        for slot in &mut self.slots {
            for v in slot.iter_mut() {
                *v *= s;
            }
        }
    }
}

/// `z = x·Wᵀ + b` for row-major `W: [d_out, d_in]`.
pub fn affine(x: &Mat, w: &Mat, b: &[f32]) -> Mat {
    let wt = w.transpose();
    let mut z = matmul(x, &wt);
    for i in 0..z.rows {
        let row = &mut z.data[i * z.cols..(i + 1) * z.cols];
        for (v, bj) in row.iter_mut().zip(b) {
            *v += bj;
        }
    }
    z
}

/// Exact linear backward: (dW, db, dX if requested).
pub fn exact_linear_backward(
    g: &Mat,
    x: &Mat,
    w: &Mat,
    need_dx: bool,
) -> (Mat, Vec<f32>, Option<Mat>) {
    let dw = matmul(&g.transpose(), x);
    let db = column_sums(g);
    let dx = if need_dx { Some(matmul(g, w)) } else { None };
    (dw, db, dx)
}

fn column_sums(g: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; g.cols];
    for i in 0..g.rows {
        for (o, &v) in out.iter_mut().zip(g.row(i)) {
            *o += v;
        }
    }
    out
}

/// The paper's sketched linear backward on native matrices.
///
/// Draws keep-probabilities from the method's column scores (waterfilling,
/// Algorithm 1), gates columns with correlated (systematic, Algorithm 2) or
/// independent Bernoulli sampling (`per_column` and `*_ind` methods), and
/// computes dX = Ĝ·W, dW = Ĝᵀ·X, db = Ĝᵀ·1 touching only kept columns with
/// the unbiased 1/pᵢ rescale. Returns (dW, db, dX if requested).
pub fn sketched_linear_backward(
    g: &Mat,
    x: &Mat,
    w: &Mat,
    method: &str,
    budget: f64,
    rng: &mut Pcg64,
    need_dx: bool,
) -> (Mat, Vec<f32>, Option<Mat>) {
    let dout = g.cols;
    let p: Vec<f32> = if method == "per_column" {
        vec![budget.clamp(1e-6, 1.0) as f32; dout]
    } else {
        let scores = column_scores(method, g, Some(w));
        pstar_from_weights(&scores, budget * dout as f64)
    };
    let independent = method == "per_column" || method.ends_with("_ind");
    let z = if independent {
        independent_bernoulli(rng, &p)
    } else {
        correlated_bernoulli(rng, &p)
    };
    let kept = kept_columns(&z, &p);
    let dw = sparse_dw(g, &kept, x);
    let mut db = vec![0.0f32; dout];
    for &(j, inv) in &kept {
        let mut s = 0.0f32;
        for i in 0..g.rows {
            s += g.at(i, j);
        }
        db[j] = s * inv;
    }
    let dx = if need_dx { Some(sparse_dx(g, &kept, w)) } else { None };
    (dw, db, dx)
}

/// Dispatch one linear backward through the context: exact when the site is
/// ungated, sketched otherwise. Shared by every sketchable layer.
pub(crate) fn linear_backward_ctx(
    g: &Mat,
    x: &Mat,
    w: &Mat,
    ctx: &mut SketchCtx<'_>,
    need_dx: bool,
) -> (Mat, Vec<f32>, Option<Mat>) {
    match ctx.sketch {
        Some(s) => {
            sketched_linear_backward(g, x, w, &s.method, s.budget, ctx.rng, need_dx)
        }
        None => exact_linear_backward(g, x, w, need_dx),
    }
}

/// One dense layer `y = x·Wᵀ + b` with `W: [d_out, d_in]` row-major — the
/// canonical sketch site (§4.2 column estimator on the output gradient).
pub struct Linear {
    /// Weight matrix, one row per output unit.
    pub w: Mat,
    /// Bias, length `d_out`.
    pub b: Vec<f32>,
}

impl Linear {
    /// He-initialized layer (std √(2/d_in)), deterministic given
    /// `(seed, stream)` — stream `300 + i` for the i-th weight-bearing
    /// layer keeps MLP inits bit-identical across API generations.
    pub fn he(din: usize, dout: usize, seed: u64, stream: u64) -> Linear {
        Linear::init(din, dout, (2.0 / din as f64).sqrt(), seed, stream)
    }

    /// Layer with gaussian(0, std²) weights and zero bias.
    pub fn init(din: usize, dout: usize, std: f64, seed: u64, stream: u64) -> Linear {
        let mut rng = Pcg64::new(seed ^ 0x1e57, stream);
        let w = Mat::from_fn(dout, din, |_, _| (rng.gaussian() * std) as f32);
        Linear { w, b: vec![0.0; dout] }
    }

    /// Input width d_in.
    pub fn din(&self) -> usize {
        self.w.cols
    }

    /// Output width d_out.
    pub fn dout(&self) -> usize {
        self.w.rows
    }
}

impl Layer for Linear {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn forward(&self, x: &Mat) -> (Mat, Cache) {
        let y = affine(x, &self.w, &self.b);
        (y, Cache { mats: vec![x.clone()] })
    }

    fn backward(
        &self,
        gy: &Mat,
        cache: &Cache,
        ctx: &mut SketchCtx<'_>,
        need_gx: bool,
    ) -> (Option<Mat>, Vec<Vec<f32>>) {
        let x = &cache.mats[0];
        let (dw, db, gx) = linear_backward_ctx(gy, x, &self.w, ctx, need_gx);
        (gx, vec![dw.data, db])
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.w.data, &self.b]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![&mut self.w.data, &mut self.b]
    }

    fn sketchable(&self) -> bool {
        true
    }
}

/// Elementwise rectifier; caches its input for the derivative mask.
pub struct Relu;

impl Layer for Relu {
    fn name(&self) -> &'static str {
        "relu"
    }

    fn forward(&self, x: &Mat) -> (Mat, Cache) {
        let mut y = x.clone();
        for v in &mut y.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        (y, Cache { mats: vec![x.clone()] })
    }

    fn backward(
        &self,
        gy: &Mat,
        cache: &Cache,
        _ctx: &mut SketchCtx<'_>,
        _need_gx: bool,
    ) -> (Option<Mat>, Vec<Vec<f32>>) {
        let mut gx = gy.clone();
        for (v, &zv) in gx.data.iter_mut().zip(&cache.mats[0].data) {
            if zv <= 0.0 {
                *v = 0.0;
            }
        }
        (Some(gx), Vec::new())
    }

    fn params(&self) -> Vec<&[f32]> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dense_backward;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn sketched_full_budget_matches_exact() {
        let mut rng = Pcg64::new(9, 0);
        let g = randmat(8, 6, &mut rng);
        let x = randmat(8, 5, &mut rng);
        let w = randmat(6, 5, &mut rng);
        let (dw_e, db_e, dx_e) = exact_linear_backward(&g, &x, &w, true);
        let (dw_s, db_s, dx_s) =
            sketched_linear_backward(&g, &x, &w, "l1", 1.0, &mut rng, true);
        for (a, b) in dw_e.data.iter().zip(&dw_s.data) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in db_e.iter().zip(&db_s) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dx_e.unwrap().data.iter().zip(&dx_s.unwrap().data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sketched_budget_drops_columns() {
        let mut rng = Pcg64::new(11, 0);
        let g = randmat(16, 32, &mut rng);
        let x = randmat(16, 8, &mut rng);
        let w = randmat(32, 8, &mut rng);
        let (dw, db, _) =
            sketched_linear_backward(&g, &x, &w, "l1", 0.25, &mut rng, false);
        // dropped output units have identically-zero dW rows and db entries
        let zero_rows = (0..32)
            .filter(|&j| dw.data[j * 8..(j + 1) * 8].iter().all(|&v| v == 0.0))
            .count();
        assert!(zero_rows >= 32 - 10, "only {zero_rows} zero rows");
        assert!(db.iter().filter(|&&v| v == 0.0).count() >= 32 - 10);
    }

    #[test]
    fn linear_layer_backward_matches_dense() {
        let mut rng = Pcg64::new(3, 0);
        let lin = Linear::he(5, 4, 7, 300);
        let x = randmat(6, 5, &mut rng);
        let (y, cache) = lin.forward(&x);
        assert_eq!((y.rows, y.cols), (6, 4));
        let gy = randmat(6, 4, &mut rng);
        let mut gate = Pcg64::new(0, 0);
        let mut ctx = SketchCtx { sketch: None, rng: &mut gate };
        let (gx, pg) = lin.backward(&gy, &cache, &mut ctx, true);
        let (dx_ref, dw_ref) = dense_backward(&gy, &x, &lin.w);
        for (a, b) in pg[0].iter().zip(&dw_ref.data) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in gx.unwrap().data.iter().zip(&dx_ref.data) {
            assert!((a - b).abs() < 1e-5);
        }
        // bias gradient = column sums of gy
        for j in 0..4 {
            let s: f32 = (0..6).map(|i| gy.at(i, j)).sum();
            assert!((pg[1][j] - s).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_masks_gradient_at_nonpositive_inputs() {
        let x = Mat::from_rows(vec![vec![-1.0, 0.0, 2.0]]);
        let (y, cache) = Relu.forward(&x);
        assert_eq!(y.data, vec![0.0, 0.0, 2.0]);
        let gy = Mat::from_rows(vec![vec![1.0, 1.0, 1.0]]);
        let mut gate = Pcg64::new(0, 0);
        let mut ctx = SketchCtx { sketch: None, rng: &mut gate };
        let (gx, pg) = Relu.backward(&gy, &cache, &mut ctx, true);
        assert_eq!(gx.unwrap().data, vec![0.0, 0.0, 1.0]);
        assert!(pg.is_empty());
    }

    #[test]
    fn grads_flatten_and_norm() {
        let mut g = Grads { slots: vec![vec![3.0, 0.0], vec![4.0]] };
        assert_eq!(g.flatten(), vec![3.0, 0.0, 4.0]);
        assert!((g.global_norm() - 5.0).abs() < 1e-9);
        g.scale(0.5);
        assert!((g.global_norm() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn he_init_is_deterministic_per_stream() {
        let a = Linear::he(8, 4, 5, 300);
        let b = Linear::he(8, 4, 5, 300);
        let c = Linear::he(8, 4, 5, 301);
        assert_eq!(a.w.data, b.w.data);
        assert_ne!(a.w.data, c.w.data);
        assert_eq!((a.din(), a.dout()), (8, 4));
    }
}
