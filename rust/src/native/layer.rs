//! The composable module API of the native backend: the [`Layer`] trait,
//! its per-layer scratch [`Cache`], the [`SketchCtx`] handed to every
//! backward call, the flat [`Grads`] parameter-gradient registry, and the
//! two primitive layers everything else is built from ([`Linear`],
//! [`Relu`]).
//!
//! Since the view-based kernel redesign (DESIGN.md §7.2) every layer is a
//! *destination-passing* function: `forward` writes its output into a
//! caller-provided matrix and records extra intermediates in a
//! preallocated [`Cache`]; `backward` maps the output gradient back into a
//! caller-provided input-gradient buffer and overwrites its
//! parameter-gradient slots. The caller (a
//! [`crate::native::Workspace`] owned by [`crate::native::Sequential`])
//! sizes every buffer once at build via [`Layer::out_dim`] /
//! [`Layer::cache_shapes`], so a steady-state training step allocates
//! nothing.
//!
//! Layers that support the paper's column sketch report
//! `sketchable() == true` and read their per-site decision from the
//! [`SketchCtx`] — exact when `ctx.sketch` is `None`, the §4.2 column
//! estimator otherwise. Exact backwards consume no gate randomness.

use crate::rng::Pcg64;
use crate::sketch::SketchScratch;
use crate::tensor::kernels::vec;
use crate::tensor::{
    gemm_into, sparse_dw_into, sparse_dx_into, Mat, MatView, MatViewMut,
};

use super::policy::{InputNeed, StashedInput};

/// Column-sketch methods the native backward supports (the coordinate and
/// uniform-column families of §4.2; spectral and row/element masks stay
/// PJRT-only).
pub const NATIVE_METHODS: &[&str] = &[
    "baseline", "per_column", "l1", "l1_ind", "l1_sq", "l2", "l2_sq", "var",
    "var_sq", "ds",
];

/// Per-layer scratch arena: the matrices a layer's forward saves for its
/// backward plus the backward's own temporaries, preallocated from
/// [`Layer::cache_shapes`] and reused every step. Each layer documents
/// what it stores at which index.
#[derive(Default)]
pub struct Cache {
    /// The cached matrices, in the layer's documented order.
    pub mats: Vec<Mat>,
}

impl Cache {
    /// Allocate the cache `layer` needs for a `batch × din` input.
    pub fn for_layer(layer: &dyn Layer, batch: usize, din: usize) -> Cache {
        Cache {
            mats: layer
                .cache_shapes(batch, din)
                .into_iter()
                .map(|(r, c)| Mat::zeros(r, c))
                .collect(),
        }
    }
}

/// The resolved sketch decision for one backward site: which score method
/// gates the columns and at what kept-column budget. Gate coupling
/// (correlated vs independent Bernoulli) is carried by the method name
/// (`per_column` and `*_ind` sample independently, Lemma 3.4).
#[derive(Clone, Debug, PartialEq)]
pub struct SiteSketch {
    /// One of [`NATIVE_METHODS`] (never `"baseline"` — exact sites resolve
    /// to `None` instead).
    pub method: String,
    /// Kept-column budget p ∈ (0, 1] for this site.
    pub budget: f64,
}

/// Per-layer context for one backward call: the site's sketch decision (or
/// `None` for the exact path), the run's gate-randomness stream, and the
/// shared column-planning scratch. Exact sites consume no randomness,
/// which is what keeps `location="none"` runs bit-identical to the
/// baseline.
pub struct SketchCtx<'a> {
    /// Sketch decision for this site; `None` means exact backward.
    pub sketch: Option<&'a SiteSketch>,
    /// The trainer's gate-randomness stream.
    pub rng: &'a mut Pcg64,
    /// Reused buffers for scores / waterfilling / gates / kept columns.
    pub scratch: &'a mut SketchScratch,
}

/// One differentiable module in a [`crate::native::Sequential`] stack.
///
/// Implementations must uphold the contracts the container relies on:
///
/// * the tensor order of [`Layer::params`], [`Layer::params_mut`] and the
///   `pg` slots of [`Layer::backward`] agree;
/// * `backward` with `ctx.sketch == None` consumes no randomness from
///   `ctx.rng`;
/// * `forward` fully overwrites `y` and `backward` fully overwrites `gx`
///   (when given) and every `pg` slot — buffers are reused across steps
///   and arrive dirty.
/// (Layers are plain owned data, so the `Send + Sync` supertrait is free;
/// it lets serving workers share one `Sequential` across threads, each
/// running forward sweeps in its own workspace.)
pub trait Layer: Send + Sync {
    /// Short name for logs and debugging ("linear", "attention", …).
    fn name(&self) -> &'static str;

    /// Output width for an input of width `din` (also validates `din`);
    /// the workspace uses it to size activation/gradient buffers.
    fn out_dim(&self, din: usize) -> usize;

    /// Shapes of the scratch matrices this layer needs in its [`Cache`]
    /// for a `batch × din` input (empty by default).
    fn cache_shapes(&self, batch: usize, din: usize) -> Vec<(usize, usize)> {
        let _ = (batch, din);
        Vec::new()
    }

    /// What the backward needs of this layer's *input* (as distinct from
    /// its [`Cache`]) — drives the per-layer
    /// [`crate::native::ActivationPolicy`] resolution. The container
    /// stashes the input accordingly *before* calling `forward`.
    fn input_need(&self) -> InputNeed {
        InputNeed::None
    }

    /// Shape the backward consumes the input in — the GEMM-lowering view
    /// (e.g. `[B·P, d]` for patch/token layers). The row-major buffers
    /// must coincide: `rows · cols == batch · din`. Kept-column stashes
    /// gate the *view's* columns, so this is also the axis the activation
    /// budget applies to.
    fn input_view_shape(&self, batch: usize, din: usize) -> (usize, usize) {
        (batch, din)
    }

    /// Forward pass on a batch: write the output into `y`
    /// (`batch × out_dim`) and record whatever the backward needs in
    /// `cache`.
    fn forward(&self, x: &Mat, y: &mut Mat, cache: &mut Cache);

    /// Backward pass: map the output gradient `gy` to the input gradient
    /// (written into `gx` when present; the first layer of a stack passes
    /// `None`) and overwrite one flat gradient slot per parameter tensor,
    /// in [`Layer::params`] order. `x` is the input stash the container
    /// gathered before the forward per this layer's [`Layer::input_need`]
    /// and the run's activation policy — full values, a sign bitset, or
    /// kept columns with 1/pᵢ rescales.
    fn backward(
        &self,
        gy: &Mat,
        x: StashedInput<'_>,
        cache: &mut Cache,
        ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        pg: &mut [Vec<f32>],
    );

    /// Flat views of this layer's parameter tensors (empty if none).
    fn params(&self) -> Vec<&[f32]>;

    /// Mutable flat views, same order as [`Layer::params`].
    fn params_mut(&mut self) -> Vec<&mut [f32]>;

    /// Visit every parameter tensor in [`Layer::params`] order without
    /// building a `Vec` — the optimizer's per-step walk, kept
    /// allocation-free.
    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&mut [f32]));

    /// Whether this layer is a sketch site (reads `ctx.sketch`).
    fn sketchable(&self) -> bool {
        false
    }

    /// Map from this layer's gated-GEMM `plan_columns` call order to its
    /// *local* `(weight, bias)` slot indices in [`Layer::params`] order —
    /// entry k describes the k-th kept list a gated backward appends to
    /// the [`SketchScratch`] kept log. Only meaningful when
    /// [`Layer::sketchable`]; single-GEMM layers keep the default
    /// `[(0, 1)]`, multi-GEMM layers (attention, FFN) override to their
    /// backward's planning order. The sparse data-parallel reducer uses
    /// this to attribute logged kept lists to gradient slots.
    fn sketch_gemm_slots(&self) -> Vec<(usize, usize)> {
        vec![(0, 1)]
    }

    /// Total parameter count.
    fn num_params(&self) -> usize {
        self.params().iter().map(|p| p.len()).sum()
    }
}

/// Run a layer's forward through freshly allocated buffers — convenience
/// for tests, probes and offline tools; the training path goes through a
/// [`crate::native::Workspace`] instead.
pub fn run_layer_forward(layer: &dyn Layer, x: &Mat) -> (Mat, Cache) {
    let mut y = Mat::zeros(x.rows, layer.out_dim(x.cols));
    let mut cache = Cache::for_layer(layer, x.rows, x.cols);
    layer.forward(x, &mut y, &mut cache);
    (y, cache)
}

/// Run a layer's backward through freshly allocated buffers (see
/// [`run_layer_forward`]). Returns the input gradient (when `need_gx`)
/// and one flat gradient per parameter tensor. Uses the exact activation
/// path — the input is handed to the layer as a full-value stash in its
/// view shape (or no stash at all when the backward ignores it).
pub fn run_layer_backward(
    layer: &dyn Layer,
    gy: &Mat,
    x: &Mat,
    cache: &mut Cache,
    sketch: Option<&SiteSketch>,
    rng: &mut Pcg64,
    need_gx: bool,
) -> (Option<Mat>, Vec<Vec<f32>>) {
    let mut scratch = SketchScratch::new();
    let mut ctx = SketchCtx { sketch, rng, scratch: &mut scratch };
    let mut pg: Vec<Vec<f32>> =
        layer.params().iter().map(|p| vec![0.0; p.len()]).collect();
    let mut gx = if need_gx { Some(Mat::zeros(x.rows, x.cols)) } else { None };
    let (vr, vc) = layer.input_view_shape(x.rows, x.cols);
    let stash = match layer.input_need() {
        InputNeed::None => StashedInput::None,
        InputNeed::Signs | InputNeed::Values => {
            StashedInput::Full(x.reshape(vr, vc))
        }
    };
    layer.backward(gy, stash, cache, &mut ctx, gx.as_mut(), &mut pg);
    (gx, pg)
}

/// Per-parameter-tensor gradients in the model's global slot order (layer
/// order, each layer's tensors in [`Layer::params`] order) — the one flat
/// layout optimizers, clipping and the variance probes see.
pub struct Grads {
    /// One flat gradient per parameter tensor.
    pub slots: Vec<Vec<f32>>,
}

impl Grads {
    /// Concatenate every slot into one vector (the layout the variance
    /// probes reason about).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for s in &self.slots {
            out.extend_from_slice(s);
        }
        out
    }

    /// Global ℓ2 norm over every gradient entry.
    pub fn global_norm(&self) -> f64 {
        let mut sq = 0.0f64;
        for s in &self.slots {
            sq += s.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        }
        sq.sqrt()
    }

    /// Scale every gradient entry by `s` (used by clipping).
    pub fn scale(&mut self, s: f32) {
        for slot in &mut self.slots {
            vec::scale(slot, s);
        }
    }
}

/// `y = x·Wᵀ + b` for row-major `W: [d_out, d_in]`, written into `y` —
/// one transpose-flagged GEMM, no materialized `Wᵀ`.
pub fn affine_into(x: MatView<'_>, w: &Mat, b: &[f32], mut y: MatViewMut<'_>) {
    gemm_into(1.0, x, false, w.view(), true, 0.0, y.rb());
    for i in 0..y.rows {
        let row = &mut y.data[i * y.cols..(i + 1) * y.cols];
        vec::add_assign(row, b);
    }
}

/// `z = x·Wᵀ + b` (allocating wrapper over [`affine_into`]).
pub fn affine(x: &Mat, w: &Mat, b: &[f32]) -> Mat {
    let mut y = Mat::zeros(x.rows, w.rows);
    affine_into(x.view(), w, b, y.view_mut());
    y
}

/// Column sums of `g` into `db` (the bias gradient), overwriting.
fn column_sums_into(g: MatView<'_>, db: &mut [f32]) {
    db.fill(0.0);
    for i in 0..g.rows {
        vec::add_assign(db, g.row(i));
    }
}

/// Exact linear backward into caller buffers: dW = Gᵀ·X, db = Gᵀ·1 and
/// (when `dx` is given) dX = G·W.
pub fn exact_linear_backward_into(
    g: MatView<'_>,
    x: MatView<'_>,
    w: &Mat,
    dw: MatViewMut<'_>,
    db: &mut [f32],
    dx: Option<MatViewMut<'_>>,
) {
    gemm_into(1.0, g, true, x, false, 0.0, dw);
    column_sums_into(g, db);
    if let Some(dx) = dx {
        gemm_into(1.0, g, false, w.view(), false, 0.0, dx);
    }
}

/// Exact linear backward (allocating wrapper): (dW, db, dX if requested).
pub fn exact_linear_backward(
    g: &Mat,
    x: &Mat,
    w: &Mat,
    need_dx: bool,
) -> (Mat, Vec<f32>, Option<Mat>) {
    let mut dw = Mat::zeros(w.rows, w.cols);
    let mut db = vec![0.0f32; g.cols];
    let mut dx = if need_dx { Some(Mat::zeros(g.rows, w.cols)) } else { None };
    exact_linear_backward_into(
        g.view(),
        x.view(),
        w,
        dw.view_mut(),
        &mut db,
        dx.as_mut().map(|m| m.view_mut()),
    );
    (dw, db, dx)
}

/// The paper's sketched linear backward into caller buffers.
///
/// Draws keep-probabilities from the method's column scores (waterfilling,
/// Algorithm 1), gates columns with correlated (systematic, Algorithm 2) or
/// independent Bernoulli sampling (`per_column` and `*_ind` methods), and
/// computes dX = Ĝ·W, dW = Ĝᵀ·X, db = Ĝᵀ·1 touching only kept columns with
/// the unbiased 1/pᵢ rescale. All planning buffers come from `scratch`.
#[allow(clippy::too_many_arguments)]
pub fn sketched_linear_backward_into(
    g: MatView<'_>,
    x: MatView<'_>,
    w: &Mat,
    method: &str,
    budget: f64,
    rng: &mut Pcg64,
    scratch: &mut SketchScratch,
    dw: MatViewMut<'_>,
    db: &mut [f32],
    dx: Option<MatViewMut<'_>>,
) {
    let kept = scratch.plan_columns(method, budget, g, Some(w), rng);
    sparse_dw_into(g, kept, x, dw);
    db.fill(0.0);
    for &(j, inv) in kept {
        let mut s = 0.0f32;
        for i in 0..g.rows {
            s += g.at(i, j);
        }
        db[j] = s * inv;
    }
    if let Some(dx) = dx {
        sparse_dx_into(g, kept, w.view(), dx);
    }
}

/// Sketched linear backward (allocating wrapper): (dW, db, dX if
/// requested).
pub fn sketched_linear_backward(
    g: &Mat,
    x: &Mat,
    w: &Mat,
    method: &str,
    budget: f64,
    rng: &mut Pcg64,
    need_dx: bool,
) -> (Mat, Vec<f32>, Option<Mat>) {
    let mut scratch = SketchScratch::new();
    let mut dw = Mat::zeros(w.rows, w.cols);
    let mut db = vec![0.0f32; g.cols];
    let mut dx = if need_dx { Some(Mat::zeros(g.rows, w.cols)) } else { None };
    sketched_linear_backward_into(
        g.view(),
        x.view(),
        w,
        method,
        budget,
        rng,
        &mut scratch,
        dw.view_mut(),
        &mut db,
        dx.as_mut().map(|m| m.view_mut()),
    );
    (dw, db, dx)
}

/// Dispatch one linear backward through the context: exact when the site is
/// ungated, sketched otherwise. Shared by every sketchable layer.
pub(crate) fn linear_backward_ctx(
    g: MatView<'_>,
    x: MatView<'_>,
    w: &Mat,
    ctx: &mut SketchCtx<'_>,
    dw: MatViewMut<'_>,
    db: &mut [f32],
    dx: Option<MatViewMut<'_>>,
) {
    match ctx.sketch {
        Some(s) => sketched_linear_backward_into(
            g, x, w, &s.method, s.budget, ctx.rng, ctx.scratch, dw, db, dx,
        ),
        None => exact_linear_backward_into(g, x, w, dw, db, dx),
    }
}

/// Doubly-gated linear backward over a kept-column input stash. The
/// forward stored only the kept input columns `xg` (gathered under the
/// activation policy's l2 gates, 1/pᵢ rescales in `xkept`, full input
/// width `din`); the backward draws its own G-gates from the site's
/// method and forms dW = scatter(Ĝᵀ·X̂) — rows rescaled by the G-gates
/// inside [`sparse_dw_into`], columns rescaled by the X-gates at scatter
/// time. Unbiased because the two gate streams are independent
/// (E_X E_G [dŴ] = Gᵀ·X entrywise). db and dX never touch X, so they are
/// computed exactly as in the singly-gated estimator.
#[allow(clippy::too_many_arguments)]
pub fn kept_linear_backward_into(
    g: MatView<'_>,
    xg: MatView<'_>,
    xkept: &[(usize, f32)],
    din: usize,
    w: &Mat,
    method: &str,
    budget: f64,
    rng: &mut Pcg64,
    scratch: &mut SketchScratch,
    mut dw: MatViewMut<'_>,
    db: &mut [f32],
    dx: Option<MatViewMut<'_>>,
) {
    debug_assert_eq!(din, w.cols, "kept stash full width");
    debug_assert_eq!(xg.cols, xkept.len(), "kept stash column count");
    debug_assert_eq!(xg.rows, g.rows, "kept stash rows");
    let m = xkept.len();
    // the kept-G list below borrows `scratch`, so the dW staging buffer
    // is temporarily taken out of it
    let mut dwg = std::mem::take(&mut scratch.dwg);
    dwg.resize(w.rows * m, 0.0);
    let kept_g = scratch.plan_columns(method, budget, g, Some(w), rng);
    sparse_dw_into(g, kept_g, xg, MatViewMut::new(w.rows, m, &mut dwg));
    dw.data.fill(0.0);
    for &(j, _) in kept_g {
        let src = &dwg[j * m..(j + 1) * m];
        let drow = &mut dw.data[j * din..(j + 1) * din];
        for (c, &(sx, invx)) in xkept.iter().enumerate() {
            drow[sx] = src[c] * invx;
        }
    }
    db.fill(0.0);
    for &(j, inv) in kept_g {
        let mut s = 0.0f32;
        for i in 0..g.rows {
            s += g.at(i, j);
        }
        db[j] = s * inv;
    }
    if let Some(dx) = dx {
        sparse_dx_into(g, kept_g, w.view(), dx);
    }
    scratch.dwg = dwg;
}

/// Dispatch one linear backward over a stashed input: full stashes go
/// through the exact/sketched split of [`linear_backward_ctx`]; kept
/// stashes only exist at gated sites (the plan resolution guarantees it)
/// and take the doubly-gated [`kept_linear_backward_into`] path. Shared
/// by every layer whose dW reads its input.
pub(crate) fn linear_backward_stash(
    g: MatView<'_>,
    x: StashedInput<'_>,
    w: &Mat,
    ctx: &mut SketchCtx<'_>,
    dw: MatViewMut<'_>,
    db: &mut [f32],
    dx: Option<MatViewMut<'_>>,
) {
    match x {
        StashedInput::Full(xv) => {
            linear_backward_ctx(g, xv, w, ctx, dw, db, dx)
        }
        StashedInput::Kept { xg, kept, cols } => {
            let s = ctx.sketch.expect("kept stash implies a gated site");
            kept_linear_backward_into(
                g, xg, kept, cols, w, &s.method, s.budget, ctx.rng,
                ctx.scratch, dw, db, dx,
            );
        }
        StashedInput::None | StashedInput::Mask { .. } => {
            panic!("linear backward needs stashed input values")
        }
    }
}

/// One dense layer `y = x·Wᵀ + b` with `W: [d_out, d_in]` row-major — the
/// canonical sketch site (§4.2 column estimator on the output gradient).
pub struct Linear {
    /// Weight matrix, one row per output unit.
    pub w: Mat,
    /// Bias, length `d_out`.
    pub b: Vec<f32>,
}

impl Linear {
    /// He-initialized layer (std √(2/d_in)), deterministic given
    /// `(seed, stream)` — stream `300 + i` for the i-th weight-bearing
    /// layer keeps MLP inits bit-identical across API generations.
    pub fn he(din: usize, dout: usize, seed: u64, stream: u64) -> Linear {
        Linear::init(din, dout, (2.0 / din as f64).sqrt(), seed, stream)
    }

    /// Layer with gaussian(0, std²) weights and zero bias.
    pub fn init(din: usize, dout: usize, std: f64, seed: u64, stream: u64) -> Linear {
        let mut rng = crate::rng::streams::layer_init(seed, stream);
        let w = Mat::from_fn(dout, din, |_, _| (rng.gaussian() * std) as f32);
        Linear { w, b: vec![0.0; dout] }
    }

    /// Input width d_in.
    pub fn din(&self) -> usize {
        self.w.cols
    }

    /// Output width d_out.
    pub fn dout(&self) -> usize {
        self.w.rows
    }
}

impl Layer for Linear {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din, self.din(), "linear input width");
        self.dout()
    }

    fn input_need(&self) -> InputNeed {
        InputNeed::Values
    }

    fn forward(&self, x: &Mat, y: &mut Mat, _cache: &mut Cache) {
        affine_into(x.view(), &self.w, &self.b, y.view_mut());
    }

    fn backward(
        &self,
        gy: &Mat,
        x: StashedInput<'_>,
        _cache: &mut Cache,
        ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        pg: &mut [Vec<f32>],
    ) {
        let [dw, db] = pg else { panic!("linear has 2 param slots") };
        linear_backward_stash(
            gy.view(),
            x,
            &self.w,
            ctx,
            MatViewMut::new(self.w.rows, self.w.cols, dw),
            db,
            gx.map(|m| m.view_mut()),
        );
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.w.data, &self.b]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![&mut self.w.data, &mut self.b]
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        f(&mut self.w.data);
        f(&mut self.b);
    }

    fn sketchable(&self) -> bool {
        true
    }
}

/// Elementwise rectifier; the derivative mask replays the input's sign
/// pattern from the stash — full values under the exact policy, a packed
/// bitset (32× smaller, bit-identical masking) under the kept policy.
pub struct Relu;

impl Layer for Relu {
    fn name(&self) -> &'static str {
        "relu"
    }

    fn out_dim(&self, din: usize) -> usize {
        din
    }

    fn input_need(&self) -> InputNeed {
        InputNeed::Signs
    }

    fn forward(&self, x: &Mat, y: &mut Mat, _cache: &mut Cache) {
        vec::relu_into(&mut y.data, &x.data);
    }

    fn backward(
        &self,
        gy: &Mat,
        x: StashedInput<'_>,
        _cache: &mut Cache,
        _ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        _pg: &mut [Vec<f32>],
    ) {
        if let Some(gx) = gx {
            gx.data.copy_from_slice(&gy.data);
            match x {
                StashedInput::Full(xv) => {
                    vec::mask_nonpos(&mut gx.data, xv.data)
                }
                StashedInput::Mask { bits, len } => {
                    debug_assert_eq!(len, gx.data.len(), "mask length");
                    vec::apply_mask_bits(&mut gx.data, bits);
                }
                StashedInput::None | StashedInput::Kept { .. } => {
                    panic!("relu backward needs stashed input signs")
                }
            }
        }
    }

    fn params(&self) -> Vec<&[f32]> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        Vec::new()
    }

    fn visit_params_mut(&mut self, _f: &mut dyn FnMut(&mut [f32])) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dense_backward;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn sketched_full_budget_matches_exact() {
        let mut rng = Pcg64::new(9, 0);
        let g = randmat(8, 6, &mut rng);
        let x = randmat(8, 5, &mut rng);
        let w = randmat(6, 5, &mut rng);
        let (dw_e, db_e, dx_e) = exact_linear_backward(&g, &x, &w, true);
        let (dw_s, db_s, dx_s) =
            sketched_linear_backward(&g, &x, &w, "l1", 1.0, &mut rng, true);
        for (a, b) in dw_e.data.iter().zip(&dw_s.data) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in db_e.iter().zip(&db_s) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dx_e.unwrap().data.iter().zip(&dx_s.unwrap().data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sketched_budget_drops_columns() {
        let mut rng = Pcg64::new(11, 0);
        let g = randmat(16, 32, &mut rng);
        let x = randmat(16, 8, &mut rng);
        let w = randmat(32, 8, &mut rng);
        let (dw, db, _) =
            sketched_linear_backward(&g, &x, &w, "l1", 0.25, &mut rng, false);
        // dropped output units have identically-zero dW rows and db entries
        let zero_rows = (0..32)
            .filter(|&j| dw.data[j * 8..(j + 1) * 8].iter().all(|&v| v == 0.0))
            .count();
        assert!(zero_rows >= 32 - 10, "only {zero_rows} zero rows");
        assert!(db.iter().filter(|&&v| v == 0.0).count() >= 32 - 10);
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        // the workspace reuses gradient slots across steps; a backward must
        // not accumulate into stale contents
        let mut rng = Pcg64::new(21, 0);
        let g = randmat(6, 4, &mut rng);
        let x = randmat(6, 3, &mut rng);
        let w = randmat(4, 3, &mut rng);
        let (dw_ref, db_ref, dx_ref) = exact_linear_backward(&g, &x, &w, true);
        let mut dw = Mat::from_fn(4, 3, |_, _| f32::NAN);
        let mut db = vec![f32::NAN; 4];
        let mut dx = Mat::from_fn(6, 3, |_, _| f32::NAN);
        exact_linear_backward_into(
            g.view(),
            x.view(),
            &w,
            dw.view_mut(),
            &mut db,
            Some(dx.view_mut()),
        );
        assert_eq!(dw.data, dw_ref.data);
        assert_eq!(db, db_ref);
        assert_eq!(dx.data, dx_ref.unwrap().data);
    }

    #[test]
    fn linear_layer_backward_matches_dense() {
        let mut rng = Pcg64::new(3, 0);
        let lin = Linear::he(5, 4, 7, 300);
        let x = randmat(6, 5, &mut rng);
        let (y, mut cache) = run_layer_forward(&lin, &x);
        assert_eq!((y.rows, y.cols), (6, 4));
        let gy = randmat(6, 4, &mut rng);
        let mut gate = Pcg64::new(0, 0);
        let (gx, pg) =
            run_layer_backward(&lin, &gy, &x, &mut cache, None, &mut gate, true);
        let (dx_ref, dw_ref) = dense_backward(&gy, &x, &lin.w);
        for (a, b) in pg[0].iter().zip(&dw_ref.data) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in gx.unwrap().data.iter().zip(&dx_ref.data) {
            assert!((a - b).abs() < 1e-5);
        }
        // bias gradient = column sums of gy
        for j in 0..4 {
            let s: f32 = (0..6).map(|i| gy.at(i, j)).sum();
            assert!((pg[1][j] - s).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_masks_gradient_at_nonpositive_inputs() {
        let x = Mat::from_rows(vec![vec![-1.0, 0.0, 2.0]]);
        let (y, mut cache) = run_layer_forward(&Relu, &x);
        assert_eq!(y.data, vec![0.0, 0.0, 2.0]);
        let gy = Mat::from_rows(vec![vec![1.0, 1.0, 1.0]]);
        let mut gate = Pcg64::new(0, 0);
        let (gx, pg) =
            run_layer_backward(&Relu, &gy, &x, &mut cache, None, &mut gate, true);
        assert_eq!(gx.unwrap().data, vec![0.0, 0.0, 1.0]);
        assert!(pg.is_empty());
    }

    #[test]
    fn grads_flatten_and_norm() {
        let mut g = Grads { slots: vec![vec![3.0, 0.0], vec![4.0]] };
        assert_eq!(g.flatten(), vec![3.0, 0.0, 4.0]);
        assert!((g.global_norm() - 5.0).abs() < 1e-9);
        g.scale(0.5);
        assert!((g.global_norm() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn he_init_is_deterministic_per_stream() {
        let a = Linear::he(8, 4, 5, 300);
        let b = Linear::he(8, 4, 5, 300);
        let c = Linear::he(8, 4, 5, 301);
        assert_eq!(a.w.data, b.w.data);
        assert_ne!(a.w.data, c.w.data);
        assert_eq!((a.din(), a.dout()), (8, 4));
    }
}
