//! Transformer blocks for the ViT-lite model: token-wise [`LayerNorm`],
//! learned [`PosEmbed`], multi-head self-[`Attention`] whose QKV and
//! output-projection linears are the sketch sites, and the residual
//! feed-forward sublayer [`FfnBlock`].
//!
//! Token layout: a `[B, P·d]` batch matrix is reinterpreted as `B·P` token
//! rows of width `d` (row-major buffers coincide, no copies). The four
//! attention projections run as single GEMMs over the stacked tokens, so
//! their backward gradients are `[B·P, d]` matrices — exactly the shape
//! the §4.2 column estimator gates, with model channels as columns. The
//! softmax core stays exact: it holds no parameters and its FLOPs are
//! `O(P²d)` per image versus the projections' `O(P d²)`.

use crate::tensor::Mat;

use super::layer::{affine, linear_backward_ctx, Cache, Layer, Linear, SketchCtx};

/// Per-token layer normalization over the channel axis with learned scale
/// and shift: rows of width `dim` are normalized to zero mean / unit
/// variance, then mapped through `γ ⊙ x̂ + β`.
pub struct LayerNorm {
    /// Channel width `d` each token row is normalized over.
    pub dim: usize,
    /// Learned scale γ, length `d` (init 1).
    pub gamma: Vec<f32>,
    /// Learned shift β, length `d` (init 0).
    pub beta: Vec<f32>,
}

/// Variance fuzz of [`LayerNorm`].
const LN_EPS: f32 = 1e-5;

impl LayerNorm {
    /// Identity-initialized layer norm over `dim` channels.
    pub fn new(dim: usize) -> LayerNorm {
        LayerNorm { dim, gamma: vec![1.0; dim], beta: vec![0.0; dim] }
    }
}

impl Layer for LayerNorm {
    fn name(&self) -> &'static str {
        "layer_norm"
    }

    fn forward(&self, x: &Mat) -> (Mat, Cache) {
        assert_eq!(x.cols % self.dim, 0, "layer_norm input width");
        let d = self.dim;
        let rows = x.rows * (x.cols / d);
        let mut xhat = Mat::zeros(rows, d);
        let mut invstd = Mat::zeros(rows, 1);
        let mut y = Mat::zeros(x.rows, x.cols);
        for r in 0..rows {
            let xin = &x.data[r * d..(r + 1) * d];
            let mut mu = 0.0f32;
            for &v in xin {
                mu += v;
            }
            mu /= d as f32;
            let mut var = 0.0f32;
            for &v in xin {
                var += (v - mu) * (v - mu);
            }
            var /= d as f32;
            let is = 1.0 / (var + LN_EPS).sqrt();
            invstd.data[r] = is;
            let xh = &mut xhat.data[r * d..(r + 1) * d];
            let yr = &mut y.data[r * d..(r + 1) * d];
            for j in 0..d {
                xh[j] = (xin[j] - mu) * is;
                yr[j] = self.gamma[j] * xh[j] + self.beta[j];
            }
        }
        (y, Cache { mats: vec![xhat, invstd] })
    }

    fn backward(
        &self,
        gy: &Mat,
        cache: &Cache,
        _ctx: &mut SketchCtx<'_>,
        need_gx: bool,
    ) -> (Option<Mat>, Vec<Vec<f32>>) {
        let d = self.dim;
        let (xhat, invstd) = (&cache.mats[0], &cache.mats[1]);
        let rows = xhat.rows;
        let mut dgamma = vec![0.0f32; d];
        let mut dbeta = vec![0.0f32; d];
        let mut gx = if need_gx { Some(Mat::zeros(gy.rows, gy.cols)) } else { None };
        for r in 0..rows {
            let g = &gy.data[r * d..(r + 1) * d];
            let xh = &xhat.data[r * d..(r + 1) * d];
            for j in 0..d {
                dgamma[j] += g[j] * xh[j];
                dbeta[j] += g[j];
            }
            if let Some(gx) = gx.as_mut() {
                // gx = invstd · (ĝ − mean(ĝ) − x̂ · mean(ĝ ⊙ x̂)), ĝ = γ ⊙ g
                let mut m1 = 0.0f32;
                let mut m2 = 0.0f32;
                for j in 0..d {
                    let gh = self.gamma[j] * g[j];
                    m1 += gh;
                    m2 += gh * xh[j];
                }
                m1 /= d as f32;
                m2 /= d as f32;
                let is = invstd.data[r];
                let out = &mut gx.data[r * d..(r + 1) * d];
                for j in 0..d {
                    let gh = self.gamma[j] * g[j];
                    out[j] = is * (gh - m1 - xh[j] * m2);
                }
            }
        }
        (gx, vec![dgamma, dbeta])
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.gamma, &self.beta]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![&mut self.gamma, &mut self.beta]
    }
}

/// Learned additive positional embedding over `P` token slots of width `d`.
pub struct PosEmbed {
    /// The embedding table, flattened `[P·d]` (one row per token slot).
    pub table: Vec<f32>,
}

impl PosEmbed {
    /// Gaussian(0, 0.02²)-initialized table, deterministic given
    /// `(seed, stream)`.
    pub fn new(patches: usize, dim: usize, seed: u64, stream: u64) -> PosEmbed {
        let mut rng = crate::rng::Pcg64::new(seed ^ 0x1e57, stream);
        let table =
            (0..patches * dim).map(|_| (rng.gaussian() * 0.02) as f32).collect();
        PosEmbed { table }
    }
}

impl Layer for PosEmbed {
    fn name(&self) -> &'static str {
        "pos_embed"
    }

    fn forward(&self, x: &Mat) -> (Mat, Cache) {
        assert_eq!(x.cols, self.table.len(), "pos_embed input width");
        let mut y = x.clone();
        for i in 0..y.rows {
            let row = &mut y.data[i * y.cols..(i + 1) * y.cols];
            for (v, &t) in row.iter_mut().zip(&self.table) {
                *v += t;
            }
        }
        (y, Cache::default())
    }

    fn backward(
        &self,
        gy: &Mat,
        _cache: &Cache,
        _ctx: &mut SketchCtx<'_>,
        need_gx: bool,
    ) -> (Option<Mat>, Vec<Vec<f32>>) {
        let mut dt = vec![0.0f32; self.table.len()];
        for i in 0..gy.rows {
            for (d, &g) in dt.iter_mut().zip(gy.row(i)) {
                *d += g;
            }
        }
        let gx = if need_gx { Some(gy.clone()) } else { None };
        (gx, vec![dt])
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.table]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![&mut self.table]
    }
}

/// Multi-head self-attention over `P` tokens of width `d` with a residual
/// connection: `y = x + W_o·MHSA(x)`. The QKV and output projections are
/// the sketch sites; when the site is gated, all four backward GEMMs use
/// the kept-column estimator at the site's budget.
pub struct Attention {
    /// Tokens per image `P`.
    pub patches: usize,
    /// Model width `d` (must be divisible by `heads`).
    pub dim: usize,
    /// Number of attention heads.
    pub heads: usize,
    /// Query projection.
    pub q: Linear,
    /// Key projection.
    pub k: Linear,
    /// Value projection.
    pub v: Linear,
    /// Output projection.
    pub o: Linear,
}

impl Attention {
    /// Gaussian(0, 1/d)-initialized attention block; the four projections
    /// draw from consecutive streams `stream0..stream0+4`.
    pub fn new(
        patches: usize,
        dim: usize,
        heads: usize,
        seed: u64,
        stream0: u64,
    ) -> Attention {
        assert!(dim % heads == 0, "dim {dim} not divisible by {heads} heads");
        let std = (1.0 / dim as f64).sqrt();
        Attention {
            patches,
            dim,
            heads,
            q: Linear::init(dim, dim, std, seed, stream0),
            k: Linear::init(dim, dim, std, seed, stream0 + 1),
            v: Linear::init(dim, dim, std, seed, stream0 + 2),
            o: Linear::init(dim, dim, std, seed, stream0 + 3),
        }
    }

    fn head_dim(&self) -> usize {
        self.dim / self.heads
    }
}

impl Layer for Attention {
    fn name(&self) -> &'static str {
        "attention"
    }

    fn forward(&self, x: &Mat) -> (Mat, Cache) {
        let (p, d, h) = (self.patches, self.dim, self.heads);
        assert_eq!(x.cols, p * d, "attention input width");
        let bsz = x.rows;
        let xs = Mat { rows: bsz * p, cols: d, data: x.data.clone() };
        let q = affine(&xs, &self.q.w, &self.q.b);
        let k = affine(&xs, &self.k.w, &self.k.b);
        let v = affine(&xs, &self.v.w, &self.v.b);
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut o = Mat::zeros(bsz * p, d);
        // attention probabilities, stacked [(b·h + head)·P, P]
        let mut attn = Mat::zeros(bsz * h * p, p);
        for b in 0..bsz {
            let r0 = b * p;
            for head in 0..h {
                let c0 = head * dh;
                let a0 = (b * h + head) * p;
                // scores s[i][j] = <q_i, k_j> · scale, softmaxed per row
                for i in 0..p {
                    let arow = &mut attn.data[(a0 + i) * p..(a0 + i + 1) * p];
                    let mut m = f32::NEG_INFINITY;
                    for (j, aj) in arow.iter_mut().enumerate() {
                        let mut s = 0.0f32;
                        for c in 0..dh {
                            s += q.at(r0 + i, c0 + c) * k.at(r0 + j, c0 + c);
                        }
                        *aj = s * scale;
                        if *aj > m {
                            m = *aj;
                        }
                    }
                    let mut sum = 0.0f32;
                    for aj in arow.iter_mut() {
                        *aj = (*aj - m).exp();
                        sum += *aj;
                    }
                    for aj in arow.iter_mut() {
                        *aj /= sum;
                    }
                }
                // o_i = Σ_j a[i][j] · v_j  (head slice)
                for i in 0..p {
                    let arow = &attn.data[(a0 + i) * p..(a0 + i + 1) * p];
                    for c in 0..dh {
                        let mut s = 0.0f32;
                        for (j, &aij) in arow.iter().enumerate() {
                            s += aij * v.at(r0 + j, c0 + c);
                        }
                        o.data[(r0 + i) * d + c0 + c] = s;
                    }
                }
            }
        }
        let mut y = affine(&o, &self.o.w, &self.o.b);
        for (yv, &xv) in y.data.iter_mut().zip(&xs.data) {
            *yv += xv; // residual
        }
        let out = Mat { rows: bsz, cols: p * d, data: y.data };
        (out, Cache { mats: vec![xs, q, k, v, o, attn] })
    }

    fn backward(
        &self,
        gy: &Mat,
        cache: &Cache,
        ctx: &mut SketchCtx<'_>,
        need_gx: bool,
    ) -> (Option<Mat>, Vec<Vec<f32>>) {
        let (p, d, h) = (self.patches, self.dim, self.heads);
        let bsz = gy.rows;
        let (xs, q, k, v, o, attn) = (
            &cache.mats[0],
            &cache.mats[1],
            &cache.mats[2],
            &cache.mats[3],
            &cache.mats[4],
            &cache.mats[5],
        );
        let g = Mat { rows: bsz * p, cols: d, data: gy.data.clone() };
        let (dwo, dbo, go) = linear_backward_ctx(&g, o, &self.o.w, ctx, true);
        let go = go.expect("attention output projection always needs dX");
        let mut gx = g; // residual path
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut gq = Mat::zeros(bsz * p, d);
        let mut gk = Mat::zeros(bsz * p, d);
        let mut gv = Mat::zeros(bsz * p, d);
        let mut ga = vec![0.0f32; p * p];
        let mut gs = vec![0.0f32; p * p];
        for b in 0..bsz {
            let r0 = b * p;
            for head in 0..h {
                let c0 = head * dh;
                let a0 = (b * h + head) * p;
                // gA[i][j] = <go_i, v_j>;  gV_j += Σ_i a[i][j]·go_i
                for i in 0..p {
                    for j in 0..p {
                        let mut s = 0.0f32;
                        for c in 0..dh {
                            s += go.at(r0 + i, c0 + c) * v.at(r0 + j, c0 + c);
                        }
                        ga[i * p + j] = s;
                    }
                }
                for j in 0..p {
                    for c in 0..dh {
                        let mut s = 0.0f32;
                        for i in 0..p {
                            s += attn.at(a0 + i, j) * go.at(r0 + i, c0 + c);
                        }
                        gv.data[(r0 + j) * d + c0 + c] = s;
                    }
                }
                // softmax backward: gS = A ⊙ (gA − rowsum(gA ⊙ A))
                for i in 0..p {
                    let arow = &attn.data[(a0 + i) * p..(a0 + i + 1) * p];
                    let mut dot = 0.0f32;
                    for j in 0..p {
                        dot += ga[i * p + j] * arow[j];
                    }
                    for j in 0..p {
                        gs[i * p + j] = arow[j] * (ga[i * p + j] - dot);
                    }
                }
                // gQ_i = scale · Σ_j gS[i][j]·k_j;  gK_j = scale · Σ_i gS[i][j]·q_i
                for i in 0..p {
                    for c in 0..dh {
                        let mut s = 0.0f32;
                        for j in 0..p {
                            s += gs[i * p + j] * k.at(r0 + j, c0 + c);
                        }
                        gq.data[(r0 + i) * d + c0 + c] = s * scale;
                    }
                }
                for j in 0..p {
                    for c in 0..dh {
                        let mut s = 0.0f32;
                        for i in 0..p {
                            s += gs[i * p + j] * q.at(r0 + i, c0 + c);
                        }
                        gk.data[(r0 + j) * d + c0 + c] = s * scale;
                    }
                }
            }
        }
        let (dwq, dbq, gxq) = linear_backward_ctx(&gq, xs, &self.q.w, ctx, need_gx);
        let (dwk, dbk, gxk) = linear_backward_ctx(&gk, xs, &self.k.w, ctx, need_gx);
        let (dwv, dbv, gxv) = linear_backward_ctx(&gv, xs, &self.v.w, ctx, need_gx);
        let gx = if need_gx {
            for part in [gxq, gxk, gxv].into_iter().flatten() {
                for (a, &b) in gx.data.iter_mut().zip(&part.data) {
                    *a += b;
                }
            }
            Some(Mat { rows: bsz, cols: p * d, data: gx.data })
        } else {
            None
        };
        (
            gx,
            vec![dwq.data, dbq, dwk.data, dbk, dwv.data, dbv, dwo.data, dbo],
        )
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![
            &self.q.w.data,
            &self.q.b,
            &self.k.w.data,
            &self.k.b,
            &self.v.w.data,
            &self.v.b,
            &self.o.w.data,
            &self.o.b,
        ]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![
            &mut self.q.w.data,
            &mut self.q.b,
            &mut self.k.w.data,
            &mut self.k.b,
            &mut self.v.w.data,
            &mut self.v.b,
            &mut self.o.w.data,
            &mut self.o.b,
        ]
    }

    fn sketchable(&self) -> bool {
        true
    }
}

/// Per-token feed-forward sublayer with its own residual:
/// `y = x + W₂·relu(W₁·x)` applied to every token row of width `d`.
/// One sketch site; when gated, both backward GEMMs use the kept-column
/// estimator. Together with [`Attention`] (whose residual is internal too)
/// and a following [`LayerNorm`], this composes the standard post-LN
/// transformer encoder block `LN(x + sublayer(x))`.
pub struct FfnBlock {
    /// Up projection `d → hidden`.
    pub w1: Linear,
    /// Down projection `hidden → d`.
    pub w2: Linear,
}

impl FfnBlock {
    /// He-initialized FFN; the two projections draw from streams
    /// `stream0` and `stream0 + 1`.
    pub fn he(dim: usize, hidden: usize, seed: u64, stream0: u64) -> FfnBlock {
        FfnBlock {
            w1: Linear::he(dim, hidden, seed, stream0),
            w2: Linear::he(hidden, dim, seed, stream0 + 1),
        }
    }
}

impl Layer for FfnBlock {
    fn name(&self) -> &'static str {
        "ffn_block"
    }

    fn forward(&self, x: &Mat) -> (Mat, Cache) {
        let d = self.w1.din();
        assert_eq!(x.cols % d, 0, "ffn_block input width");
        let rows = x.rows * (x.cols / d);
        let xs = Mat { rows, cols: d, data: x.data.clone() };
        let h = affine(&xs, &self.w1.w, &self.w1.b);
        let mut hr = h.clone();
        for v in &mut hr.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let mut y = affine(&hr, &self.w2.w, &self.w2.b);
        for (yv, &xv) in y.data.iter_mut().zip(&xs.data) {
            *yv += xv; // residual
        }
        let out = Mat { rows: x.rows, cols: x.cols, data: y.data };
        (out, Cache { mats: vec![xs, h, hr] })
    }

    fn backward(
        &self,
        gy: &Mat,
        cache: &Cache,
        ctx: &mut SketchCtx<'_>,
        need_gx: bool,
    ) -> (Option<Mat>, Vec<Vec<f32>>) {
        let (xs, h, hr) = (&cache.mats[0], &cache.mats[1], &cache.mats[2]);
        let g = Mat { rows: xs.rows, cols: xs.cols, data: gy.data.clone() };
        let (dw2, db2, gh) = linear_backward_ctx(&g, hr, &self.w2.w, ctx, true);
        let mut gh = gh.expect("ffn down projection always needs dX");
        for (v, &hv) in gh.data.iter_mut().zip(&h.data) {
            if hv <= 0.0 {
                *v = 0.0;
            }
        }
        let (dw1, db1, gx1) = linear_backward_ctx(&gh, xs, &self.w1.w, ctx, need_gx);
        let gx = gx1.map(|gx1| {
            let mut data = g.data;
            for (a, &b) in data.iter_mut().zip(&gx1.data) {
                *a += b; // residual
            }
            Mat { rows: gy.rows, cols: gy.cols, data }
        });
        (gx, vec![dw1.data, db1, dw2.data, db2])
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.w1.w.data, &self.w1.b, &self.w2.w.data, &self.w2.b]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![
            &mut self.w1.w.data,
            &mut self.w1.b,
            &mut self.w2.w.data,
            &mut self.w2.b,
        ]
    }

    fn sketchable(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn layer_norm_rows_are_normalized() {
        let ln = LayerNorm::new(6);
        let mut rng = Pcg64::new(4, 0);
        let x = randmat(3, 12, &mut rng); // 6 token rows of width 6
        let (y, _) = ln.forward(&x);
        for r in 0..6 {
            let row = &y.data[r * 6..(r + 1) * 6];
            let mu: f32 = row.iter().sum::<f32>() / 6.0;
            let var: f32 =
                row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / 6.0;
            assert!(mu.abs() < 1e-5, "row {r} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn layer_norm_param_grads_accumulate_over_tokens() {
        let ln = LayerNorm::new(4);
        let mut rng = Pcg64::new(7, 0);
        let x = randmat(2, 8, &mut rng);
        let (_, cache) = ln.forward(&x);
        let gy = Mat::from_fn(2, 8, |_, _| 1.0);
        let mut g = Pcg64::new(0, 0);
        let mut ctx = SketchCtx { sketch: None, rng: &mut g };
        let (_, pg) = ln.backward(&gy, &cache, &mut ctx, false);
        // dbeta sums gy over all 4 token rows
        for &v in &pg[1] {
            assert!((v - 4.0).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_rows_mix_tokens_and_residual_passes_through() {
        let at = Attention::new(3, 8, 2, 1, 302);
        let mut rng = Pcg64::new(9, 0);
        let x = randmat(2, 24, &mut rng);
        let (y, cache) = at.forward(&x);
        assert_eq!((y.rows, y.cols), (2, 24));
        // attention probabilities are a distribution per row
        let attn = &cache.mats[5];
        for r in 0..attn.rows {
            let s: f32 = attn.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(attn.row(r).iter().all(|&a| a >= 0.0));
        }
    }

    #[test]
    fn pos_embed_adds_table_and_sums_gradient() {
        let pe = PosEmbed::new(2, 3, 1, 301);
        let x = Mat::zeros(4, 6);
        let (y, cache) = pe.forward(&x);
        for i in 0..4 {
            for (a, b) in y.row(i).iter().zip(&pe.table) {
                assert_eq!(a, b);
            }
        }
        let gy = Mat::from_fn(4, 6, |_, _| 0.5);
        let mut g = Pcg64::new(0, 0);
        let mut ctx = SketchCtx { sketch: None, rng: &mut g };
        let (gx, pg) = pe.backward(&gy, &cache, &mut ctx, true);
        assert_eq!(gx.unwrap().data, gy.data);
        for &v in &pg[0] {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }
}
