//! Transformer blocks for the ViT-lite model: token-wise [`LayerNorm`],
//! learned [`PosEmbed`], multi-head self-[`Attention`] whose QKV and
//! output-projection linears are the sketch sites, and the residual
//! feed-forward sublayer [`FfnBlock`].
//!
//! Token layout: a `[B, P·d]` batch matrix is reinterpreted as `B·P` token
//! rows of width `d` via zero-copy [`crate::tensor::Mat::reshape`] (the
//! row-major buffers coincide). The four attention projections run as
//! single GEMMs over the stacked tokens, so their backward gradients are
//! `[B·P, d]` matrices — exactly the shape the §4.2 column estimator
//! gates, with model channels as columns. The softmax core stays exact:
//! it holds no parameters and its FLOPs are `O(P²d)` per image versus the
//! projections' `O(P d²)`. Every intermediate (Q/K/V/O, the attention
//! probabilities, and all backward temporaries) lives in the layer's
//! preallocated [`Cache`], so neither pass allocates.

use crate::tensor::kernels::vec;
use crate::tensor::{Mat, MatViewMut};

use super::layer::{
    affine_into, linear_backward_ctx, linear_backward_stash, Cache, Layer,
    Linear, SketchCtx,
};
use super::policy::{InputNeed, StashedInput};

/// Per-token layer normalization over the channel axis with learned scale
/// and shift: rows of width `dim` are normalized to zero mean / unit
/// variance, then mapped through `γ ⊙ x̂ + β`.
///
/// Cache layout: `mats[0]` = x̂ (normalized inputs, `[tokens, d]`),
/// `mats[1]` = 1/σ per token (`[tokens, 1]`).
pub struct LayerNorm {
    /// Channel width `d` each token row is normalized over.
    pub dim: usize,
    /// Learned scale γ, length `d` (init 1).
    pub gamma: Vec<f32>,
    /// Learned shift β, length `d` (init 0).
    pub beta: Vec<f32>,
}

/// Variance fuzz of [`LayerNorm`].
const LN_EPS: f32 = 1e-5;

impl LayerNorm {
    /// Identity-initialized layer norm over `dim` channels.
    pub fn new(dim: usize) -> LayerNorm {
        LayerNorm { dim, gamma: vec![1.0; dim], beta: vec![0.0; dim] }
    }
}

impl Layer for LayerNorm {
    fn name(&self) -> &'static str {
        "layer_norm"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din % self.dim, 0, "layer_norm input width");
        din
    }

    fn cache_shapes(&self, batch: usize, din: usize) -> Vec<(usize, usize)> {
        let tokens = batch * (din / self.dim);
        vec![(tokens, self.dim), (tokens, 1)]
    }

    fn forward(&self, x: &Mat, y: &mut Mat, cache: &mut Cache) {
        let d = self.dim;
        let rows = x.rows * (x.cols / d);
        let (xh_m, rest) = cache.mats.split_at_mut(1);
        let (xhat, invstd) = (&mut xh_m[0], &mut rest[0]);
        for r in 0..rows {
            let xin = &x.data[r * d..(r + 1) * d];
            let mu = vec::vsum(xin) / d as f32;
            let var = vec::vsq_diff(xin, mu) / d as f32;
            let is = 1.0 / (var + LN_EPS).sqrt();
            invstd.data[r] = is;
            let xh = &mut xhat.data[r * d..(r + 1) * d];
            let yr = &mut y.data[r * d..(r + 1) * d];
            vec::ln_forward_row(xin, mu, is, &self.gamma, &self.beta, xh, yr);
        }
    }

    fn backward(
        &self,
        gy: &Mat,
        _x: StashedInput<'_>,
        cache: &mut Cache,
        _ctx: &mut SketchCtx<'_>,
        mut gx: Option<&mut Mat>,
        pg: &mut [Vec<f32>],
    ) {
        let d = self.dim;
        let (xhat, invstd) = (&cache.mats[0], &cache.mats[1]);
        let rows = xhat.rows;
        let [dgamma, dbeta] = pg else { panic!("layer_norm has 2 param slots") };
        dgamma.fill(0.0);
        dbeta.fill(0.0);
        for r in 0..rows {
            let g = &gy.data[r * d..(r + 1) * d];
            let xh = &xhat.data[r * d..(r + 1) * d];
            vec::ln_grad_params(g, xh, dgamma, dbeta);
            if let Some(gx) = gx.as_mut() {
                // gx = invstd · (ĝ − mean(ĝ) − x̂ · mean(ĝ ⊙ x̂)), ĝ = γ ⊙ g
                let m1 = vec::vdot(&self.gamma, g) / d as f32;
                let m2 = vec::vdot3(&self.gamma, g, xh) / d as f32;
                let is = invstd.data[r];
                let out = &mut gx.data[r * d..(r + 1) * d];
                vec::ln_backward_row(g, xh, &self.gamma, m1, m2, is, out);
            }
        }
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.gamma, &self.beta]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![&mut self.gamma, &mut self.beta]
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        f(&mut self.gamma);
        f(&mut self.beta);
    }
}

/// Learned additive positional embedding over `P` token slots of width `d`.
pub struct PosEmbed {
    /// The embedding table, flattened `[P·d]` (one row per token slot).
    pub table: Vec<f32>,
}

impl PosEmbed {
    /// Gaussian(0, 0.02²)-initialized table, deterministic given
    /// `(seed, stream)`.
    pub fn new(patches: usize, dim: usize, seed: u64, stream: u64) -> PosEmbed {
        let mut rng = crate::rng::streams::layer_init(seed, stream);
        let table =
            (0..patches * dim).map(|_| (rng.gaussian() * 0.02) as f32).collect();
        PosEmbed { table }
    }
}

impl Layer for PosEmbed {
    fn name(&self) -> &'static str {
        "pos_embed"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din, self.table.len(), "pos_embed input width");
        din
    }

    fn forward(&self, x: &Mat, y: &mut Mat, _cache: &mut Cache) {
        for i in 0..y.rows {
            let row = &mut y.data[i * y.cols..(i + 1) * y.cols];
            row.copy_from_slice(x.row(i));
            vec::add_assign(row, &self.table);
        }
    }

    fn backward(
        &self,
        gy: &Mat,
        _x: StashedInput<'_>,
        _cache: &mut Cache,
        _ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        pg: &mut [Vec<f32>],
    ) {
        let [dt] = pg else { panic!("pos_embed has 1 param slot") };
        dt.fill(0.0);
        for i in 0..gy.rows {
            vec::add_assign(dt, gy.row(i));
        }
        if let Some(gx) = gx {
            gx.data.copy_from_slice(&gy.data);
        }
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.table]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![&mut self.table]
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        f(&mut self.table);
    }
}

/// Multi-head self-attention over `P` tokens of width `d` with a residual
/// connection: `y = x + W_o·MHSA(x)`. The QKV and output projections are
/// the sketch sites; when the site is gated, all four backward GEMMs use
/// the kept-column estimator at the site's budget.
///
/// Cache layout (all preallocated): `mats[0..3]` = Q, K, V (`[B·P, d]`),
/// `mats[3]` = head-mixed values O, `mats[4]` = attention probabilities
/// (`[(b·h + head)·P, P]` stacked), `mats[5..9]` = backward temporaries
/// gQ, gK, gV and the shared dX scratch, `mats[9..11]` = per-head `P × P`
/// score scratch (gA, gS).
pub struct Attention {
    /// Tokens per image `P`.
    pub patches: usize,
    /// Model width `d` (must be divisible by `heads`).
    pub dim: usize,
    /// Number of attention heads.
    pub heads: usize,
    /// Query projection.
    pub q: Linear,
    /// Key projection.
    pub k: Linear,
    /// Value projection.
    pub v: Linear,
    /// Output projection.
    pub o: Linear,
}

impl Attention {
    /// Gaussian(0, 1/d)-initialized attention block; the four projections
    /// draw from consecutive streams `stream0..stream0+4`.
    pub fn new(
        patches: usize,
        dim: usize,
        heads: usize,
        seed: u64,
        stream0: u64,
    ) -> Attention {
        assert!(dim % heads == 0, "dim {dim} not divisible by {heads} heads");
        let std = (1.0 / dim as f64).sqrt();
        Attention {
            patches,
            dim,
            heads,
            q: Linear::init(dim, dim, std, seed, stream0),
            k: Linear::init(dim, dim, std, seed, stream0 + 1),
            v: Linear::init(dim, dim, std, seed, stream0 + 2),
            o: Linear::init(dim, dim, std, seed, stream0 + 3),
        }
    }

    fn head_dim(&self) -> usize {
        self.dim / self.heads
    }
}

impl Layer for Attention {
    fn name(&self) -> &'static str {
        "attention"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din, self.patches * self.dim, "attention input width");
        din
    }

    fn input_need(&self) -> InputNeed {
        InputNeed::Values
    }

    fn input_view_shape(&self, batch: usize, _din: usize) -> (usize, usize) {
        (batch * self.patches, self.dim)
    }

    fn cache_shapes(&self, batch: usize, _din: usize) -> Vec<(usize, usize)> {
        let (p, d, h) = (self.patches, self.dim, self.heads);
        let rows = batch * p;
        vec![
            (rows, d),           // 0: Q
            (rows, d),           // 1: K
            (rows, d),           // 2: V
            (rows, d),           // 3: O (head-mixed values)
            (batch * h * p, p),  // 4: attention probabilities
            (rows, d),           // 5: gQ
            (rows, d),           // 6: gK
            (rows, d),           // 7: gV
            (rows, d),           // 8: projection-dX scratch
            (p, p),              // 9: gA (per-head)
            (p, p),              // 10: gS (per-head)
        ]
    }

    fn forward(&self, x: &Mat, y: &mut Mat, cache: &mut Cache) {
        let (p, d, h) = (self.patches, self.dim, self.heads);
        let bsz = x.rows;
        let rows = bsz * p;
        let xs = x.reshape(rows, d);
        affine_into(xs, &self.q.w, &self.q.b, cache.mats[0].view_mut());
        affine_into(xs, &self.k.w, &self.k.b, cache.mats[1].view_mut());
        affine_into(xs, &self.v.w, &self.v.b, cache.mats[2].view_mut());
        {
            let (qkv, rest) = cache.mats.split_at_mut(3);
            let (q, k, v) = (&qkv[0], &qkv[1], &qkv[2]);
            let (o_m, attn_m) = rest.split_at_mut(1);
            let (o, attn) = (&mut o_m[0], &mut attn_m[0]);
            let dh = self.head_dim();
            let scale = 1.0 / (dh as f32).sqrt();
            for b in 0..bsz {
                let r0 = b * p;
                for head in 0..h {
                    let c0 = head * dh;
                    let a0 = (b * h + head) * p;
                    // scores s[i][j] = <q_i, k_j> · scale, softmaxed per
                    // row; head slices are contiguous, so each score is a
                    // vec::vdot over dh channels
                    for i in 0..p {
                        let q0 = (r0 + i) * d + c0;
                        let qrow = &q.data[q0..q0 + dh];
                        let arow = &mut attn.data[(a0 + i) * p..(a0 + i + 1) * p];
                        for (j, aj) in arow.iter_mut().enumerate() {
                            let k0 = (r0 + j) * d + c0;
                            *aj = vec::vdot(qrow, &k.data[k0..k0 + dh]) * scale;
                        }
                        let m = vec::vmax(arow);
                        for aj in arow.iter_mut() {
                            *aj = (*aj - m).exp();
                        }
                        let sum = vec::vsum(arow);
                        vec::div_scalar(arow, sum);
                    }
                    // o_i = Σ_j a[i][j] · v_j  (head slice)
                    for i in 0..p {
                        let arow = &attn.data[(a0 + i) * p..(a0 + i + 1) * p];
                        for c in 0..dh {
                            let mut s = 0.0f32;
                            for (j, &aij) in arow.iter().enumerate() {
                                s += aij * v.at(r0 + j, c0 + c);
                            }
                            o.data[(r0 + i) * d + c0 + c] = s;
                        }
                    }
                }
            }
        }
        affine_into(
            cache.mats[3].view(),
            &self.o.w,
            &self.o.b,
            y.reshape_mut(rows, d),
        );
        vec::add_assign(&mut y.data, &x.data); // residual
    }

    fn backward(
        &self,
        gy: &Mat,
        x: StashedInput<'_>,
        cache: &mut Cache,
        ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        pg: &mut [Vec<f32>],
    ) {
        let (p, d, h) = (self.patches, self.dim, self.heads);
        let bsz = gy.rows;
        let rows = bsz * p;
        let g = gy.reshape(rows, d);
        let [dwq, dbq, dwk, dbk, dwv, dbv, dwo, dbo] = pg else {
            panic!("attention has 8 param slots")
        };
        let (ro, rw) = cache.mats.split_at_mut(5);
        let (q, k, v, o, attn) = (&ro[0], &ro[1], &ro[2], &ro[3], &ro[4]);
        let [gq, gk, gv, dxs, ga, gs] = rw else {
            panic!("attention cache has 11 mats")
        };
        // output projection backward; its dX (`dxs`) feeds the core.
        linear_backward_ctx(
            g,
            o.view(),
            &self.o.w,
            ctx,
            MatViewMut::new(d, d, dwo),
            dbo,
            Some(dxs.view_mut()),
        );
        let go = &*dxs;
        let dh = self.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        for b in 0..bsz {
            let r0 = b * p;
            for head in 0..h {
                let c0 = head * dh;
                let a0 = (b * h + head) * p;
                // gA[i][j] = <go_i, v_j>;  gV_j = Σ_i a[i][j]·go_i
                for i in 0..p {
                    let go0 = (r0 + i) * d + c0;
                    let gorow = &go.data[go0..go0 + dh];
                    for j in 0..p {
                        let v0 = (r0 + j) * d + c0;
                        ga.data[i * p + j] = vec::vdot(gorow, &v.data[v0..v0 + dh]);
                    }
                }
                for j in 0..p {
                    for c in 0..dh {
                        let mut s = 0.0f32;
                        for i in 0..p {
                            s += attn.at(a0 + i, j) * go.at(r0 + i, c0 + c);
                        }
                        gv.data[(r0 + j) * d + c0 + c] = s;
                    }
                }
                // softmax backward: gS = A ⊙ (gA − rowsum(gA ⊙ A))
                for i in 0..p {
                    let arow = &attn.data[(a0 + i) * p..(a0 + i + 1) * p];
                    let garow = &ga.data[i * p..(i + 1) * p];
                    let dot = vec::vdot(garow, arow);
                    vec::softmax_bwd_row(&mut gs.data[i * p..(i + 1) * p], arow, garow, dot);
                }
                // gQ_i = scale · Σ_j gS[i][j]·k_j;  gK_j = scale · Σ_i gS[i][j]·q_i
                for i in 0..p {
                    for c in 0..dh {
                        let mut s = 0.0f32;
                        for j in 0..p {
                            s += gs.data[i * p + j] * k.at(r0 + j, c0 + c);
                        }
                        gq.data[(r0 + i) * d + c0 + c] = s * scale;
                    }
                }
                for j in 0..p {
                    for c in 0..dh {
                        let mut s = 0.0f32;
                        for i in 0..p {
                            s += gs.data[i * p + j] * q.at(r0 + i, c0 + c);
                        }
                        gk.data[(r0 + j) * d + c0 + c] = s * scale;
                    }
                }
            }
        }
        // QKV projection backwards; each dX lands in the shared scratch and
        // is folded into gx on top of the residual path (gx starts as gy).
        // `x` is the stashed projection input — full token matrix under
        // ActivationPolicy::Exact, gathered kept columns under Kept.
        let need_gx = gx.is_some();
        let mut gx = gx;
        if let Some(gxm) = gx.as_mut() {
            gxm.data.copy_from_slice(&gy.data);
        }
        for (proj, gproj, dw, db) in [
            (&self.q, &*gq, &mut *dwq, &mut *dbq),
            (&self.k, &*gk, &mut *dwk, &mut *dbk),
            (&self.v, &*gv, &mut *dwv, &mut *dbv),
        ] {
            let dx_dest = if need_gx { Some(dxs.view_mut()) } else { None };
            linear_backward_stash(
                gproj.view(),
                x,
                &proj.w,
                ctx,
                MatViewMut::new(d, d, dw),
                db,
                dx_dest,
            );
            if let Some(gxm) = gx.as_mut() {
                vec::add_assign(&mut gxm.data, &dxs.data);
            }
        }
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![
            &self.q.w.data,
            &self.q.b,
            &self.k.w.data,
            &self.k.b,
            &self.v.w.data,
            &self.v.b,
            &self.o.w.data,
            &self.o.b,
        ]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![
            &mut self.q.w.data,
            &mut self.q.b,
            &mut self.k.w.data,
            &mut self.k.b,
            &mut self.v.w.data,
            &mut self.v.b,
            &mut self.o.w.data,
            &mut self.o.b,
        ]
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        f(&mut self.q.w.data);
        f(&mut self.q.b);
        f(&mut self.k.w.data);
        f(&mut self.k.b);
        f(&mut self.v.w.data);
        f(&mut self.v.b);
        f(&mut self.o.w.data);
        f(&mut self.o.b);
    }

    fn sketchable(&self) -> bool {
        true
    }

    fn sketch_gemm_slots(&self) -> Vec<(usize, usize)> {
        // backward plans columns for o first, then q, k, v — see the
        // `linear_backward_ctx` / `linear_backward_stash` calls above
        vec![(6, 7), (0, 1), (2, 3), (4, 5)]
    }
}

/// Per-token feed-forward sublayer with its own residual:
/// `y = x + W₂·relu(W₁·x)` applied to every token row of width `d`.
/// One sketch site; when gated, both backward GEMMs use the kept-column
/// estimator. Together with [`Attention`] (whose residual is internal too)
/// and a following [`LayerNorm`], this composes the standard post-LN
/// transformer block `LN(x + sublayer(x))`.
///
/// Cache layout: `mats[0]` = relu(H), `mats[1]` = forward staging for the
/// pre-activation H, reused in backward as the hidden-gradient scratch.
/// The pre-activation itself is never kept: `relu(h) ≤ 0` exactly where
/// `h ≤ 0` (NaN compares false and stays, ±0.0 maps to +0.0 and is
/// dropped either way), so the backward ReLU mask replayed from `relu(H)`
/// is bit-identical to the one the full cache would produce.
pub struct FfnBlock {
    /// Up projection `d → hidden`.
    pub w1: Linear,
    /// Down projection `hidden → d`.
    pub w2: Linear,
}

impl FfnBlock {
    /// He-initialized FFN; the two projections draw from streams
    /// `stream0` and `stream0 + 1`.
    pub fn he(dim: usize, hidden: usize, seed: u64, stream0: u64) -> FfnBlock {
        FfnBlock {
            w1: Linear::he(dim, hidden, seed, stream0),
            w2: Linear::he(hidden, dim, seed, stream0 + 1),
        }
    }
}

impl Layer for FfnBlock {
    fn name(&self) -> &'static str {
        "ffn_block"
    }

    fn out_dim(&self, din: usize) -> usize {
        assert_eq!(din % self.w1.din(), 0, "ffn_block input width");
        din
    }

    fn input_need(&self) -> InputNeed {
        InputNeed::Values
    }

    fn input_view_shape(&self, batch: usize, din: usize) -> (usize, usize) {
        (batch * (din / self.w1.din()), self.w1.din())
    }

    fn cache_shapes(&self, batch: usize, din: usize) -> Vec<(usize, usize)> {
        let rows = batch * (din / self.w1.din());
        let hidden = self.w1.dout();
        vec![(rows, hidden), (rows, hidden)]
    }

    fn forward(&self, x: &Mat, y: &mut Mat, cache: &mut Cache) {
        let d = self.w1.din();
        let rows = x.rows * (x.cols / d);
        let xs = x.reshape(rows, d);
        {
            let (hr_m, rest) = cache.mats.split_at_mut(1);
            let (hr, hstage) = (&mut hr_m[0], &mut rest[0]);
            affine_into(xs, &self.w1.w, &self.w1.b, hstage.view_mut());
            vec::relu_into(&mut hr.data, &hstage.data);
        }
        affine_into(
            cache.mats[0].view(),
            &self.w2.w,
            &self.w2.b,
            y.reshape_mut(rows, d),
        );
        vec::add_assign(&mut y.data, &x.data); // residual
    }

    fn backward(
        &self,
        gy: &Mat,
        x: StashedInput<'_>,
        cache: &mut Cache,
        ctx: &mut SketchCtx<'_>,
        gx: Option<&mut Mat>,
        pg: &mut [Vec<f32>],
    ) {
        let d = self.w1.din();
        let rows = gy.rows * (gy.cols / d);
        let g = gy.reshape(rows, d);
        let [dw1, db1, dw2, db2] = pg else { panic!("ffn has 4 param slots") };
        let (ro, rw) = cache.mats.split_at_mut(1);
        let hr = &ro[0];
        let gh = &mut rw[0];
        linear_backward_ctx(
            g,
            hr.view(),
            &self.w2.w,
            ctx,
            MatViewMut::new(self.w2.w.rows, self.w2.w.cols, dw2),
            db2,
            Some(gh.view_mut()),
        );
        // ReLU mask replayed from relu(H): bit-identical to masking on the
        // dropped pre-activation (see the struct doc).
        vec::mask_nonpos(&mut gh.data, &hr.data);
        let mut gx = gx;
        linear_backward_stash(
            gh.view(),
            x,
            &self.w1.w,
            ctx,
            MatViewMut::new(self.w1.w.rows, self.w1.w.cols, dw1),
            db1,
            gx.as_mut().map(|m| m.reshape_mut(rows, d)),
        );
        if let Some(gx) = gx {
            vec::add_assign(&mut gx.data, &gy.data); // residual
        }
    }

    fn params(&self) -> Vec<&[f32]> {
        vec![&self.w1.w.data, &self.w1.b, &self.w2.w.data, &self.w2.b]
    }

    fn params_mut(&mut self) -> Vec<&mut [f32]> {
        vec![
            &mut self.w1.w.data,
            &mut self.w1.b,
            &mut self.w2.w.data,
            &mut self.w2.b,
        ]
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&mut [f32])) {
        f(&mut self.w1.w.data);
        f(&mut self.w1.b);
        f(&mut self.w2.w.data);
        f(&mut self.w2.b);
    }

    fn sketchable(&self) -> bool {
        true
    }

    fn sketch_gemm_slots(&self) -> Vec<(usize, usize)> {
        // backward plans columns for w2 first, then w1
        vec![(2, 3), (0, 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::layer::{run_layer_backward, run_layer_forward};
    use crate::rng::Pcg64;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn layer_norm_rows_are_normalized() {
        let ln = LayerNorm::new(6);
        let mut rng = Pcg64::new(4, 0);
        let x = randmat(3, 12, &mut rng); // 6 token rows of width 6
        let (y, _) = run_layer_forward(&ln, &x);
        for r in 0..6 {
            let row = &y.data[r * 6..(r + 1) * 6];
            let mu: f32 = row.iter().sum::<f32>() / 6.0;
            let var: f32 =
                row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / 6.0;
            assert!(mu.abs() < 1e-5, "row {r} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn layer_norm_param_grads_accumulate_over_tokens() {
        let ln = LayerNorm::new(4);
        let mut rng = Pcg64::new(7, 0);
        let x = randmat(2, 8, &mut rng);
        let (_, mut cache) = run_layer_forward(&ln, &x);
        let gy = Mat::from_fn(2, 8, |_, _| 1.0);
        let mut g = Pcg64::new(0, 0);
        let (_, pg) =
            run_layer_backward(&ln, &gy, &x, &mut cache, None, &mut g, false);
        // dbeta sums gy over all 4 token rows
        for &v in &pg[1] {
            assert!((v - 4.0).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_rows_mix_tokens_and_residual_passes_through() {
        let at = Attention::new(3, 8, 2, 1, 302);
        let mut rng = Pcg64::new(9, 0);
        let x = randmat(2, 24, &mut rng);
        let (y, cache) = run_layer_forward(&at, &x);
        assert_eq!((y.rows, y.cols), (2, 24));
        // attention probabilities are a distribution per row
        let attn = &cache.mats[4];
        for r in 0..attn.rows {
            let s: f32 = attn.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(attn.row(r).iter().all(|&a| a >= 0.0));
        }
    }

    #[test]
    fn pos_embed_adds_table_and_sums_gradient() {
        let pe = PosEmbed::new(2, 3, 1, 301);
        let x = Mat::zeros(4, 6);
        let (y, mut cache) = run_layer_forward(&pe, &x);
        for i in 0..4 {
            for (a, b) in y.row(i).iter().zip(&pe.table) {
                assert_eq!(a, b);
            }
        }
        let gy = Mat::from_fn(4, 6, |_, _| 0.5);
        let mut g = Pcg64::new(0, 0);
        let (gx, pg) =
            run_layer_backward(&pe, &gy, &x, &mut cache, None, &mut g, true);
        assert_eq!(gx.unwrap().data, gy.data);
        for &v in &pg[0] {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn ffn_residual_identity_at_zero_weights() {
        let mut layer = FfnBlock::he(4, 6, 1, 306);
        for t in layer.params_mut() {
            for v in t.iter_mut() {
                *v = 0.0;
            }
        }
        let mut rng = Pcg64::new(6, 0);
        let x = randmat(3, 8, &mut rng);
        let (y, _) = run_layer_forward(&layer, &x);
        assert_eq!(y.data, x.data);
    }
}
