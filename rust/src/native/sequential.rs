//! [`Sequential`]: the container that owns the layer stack and the tape,
//! and [`SketchPolicy`]: the per-layer sketch configuration that replaces
//! the old single global `SketchSpec`.
//!
//! The container drives the forward sweep (recording one [`Cache`] per
//! layer into a [`Tape`]), the reverse sweep (handing each layer its
//! resolved sketch decision through a [`SketchCtx`]), and the flat
//! parameter registry (global slot order = layer order × tensor order)
//! that optimizers, gradient clipping and the variance probes share.
//!
//! Sketch *sites* are the layers reporting [`Layer::sketchable`], numbered
//! in forward order; [`SketchPolicy::resolve`] maps the config's
//! `location` mask (`all|first|last|none`) and optional per-depth budget
//! schedule onto those sites. Exact sites consume no gate randomness, so
//! a `location="none"` run is bit-identical to the baseline.

use crate::rng::Pcg64;
use crate::tensor::Mat;
use anyhow::{bail, Result};

use super::layer::{Cache, Grads, Layer, SiteSketch, SketchCtx, NATIVE_METHODS};
use super::optim::Optim;

/// Per-layer sketch configuration: one method, a default budget, the
/// `location` site mask, and an optional per-site budget schedule (the
/// Fig. 3-style depth sweeps).
#[derive(Clone, Debug)]
pub struct SketchPolicy {
    /// One of [`NATIVE_METHODS`]; `"baseline"` means exact everywhere.
    pub method: String,
    /// Default kept-column budget p ∈ (0, 1] for every gated site.
    pub budget: f64,
    /// Which sites are gated: `"all" | "first" | "last" | "none"`.
    pub location: String,
    /// Optional per-site budgets (forward order); when set, its length
    /// must equal the model's site count and it overrides `budget`.
    pub schedule: Option<Vec<f64>>,
}

impl SketchPolicy {
    /// The exact-backward policy.
    pub fn exact() -> SketchPolicy {
        SketchPolicy {
            method: "baseline".into(),
            budget: 1.0,
            location: "none".into(),
            schedule: None,
        }
    }

    /// Policy from a run config (`method` / `budget` / `location` /
    /// `budget_schedule` fields).
    pub fn from_config(cfg: &crate::config::TrainConfig) -> SketchPolicy {
        SketchPolicy {
            method: cfg.method.clone(),
            budget: cfg.budget,
            location: cfg.location.clone(),
            schedule: if cfg.budget_schedule.is_empty() {
                None
            } else {
                Some(cfg.budget_schedule.clone())
            },
        }
    }

    /// True when no sketching happens regardless of the site mask.
    pub fn is_exact(&self) -> bool {
        self.method == "baseline"
    }

    /// Per-site gate mask from a `location` string over `n` sites.
    pub fn site_mask(location: &str, n: usize) -> Result<Vec<bool>> {
        let mut m = vec![false; n];
        match location {
            "all" => m.iter_mut().for_each(|v| *v = true),
            "first" | "last" if n == 0 => {
                bail!("location {location} needs at least one sketchable layer")
            }
            "first" => m[0] = true,
            "last" => m[n - 1] = true,
            "none" => {}
            other => bail!(
                "unknown sketch location {other} (want all|first|last|none)"
            ),
        }
        Ok(m)
    }

    /// Resolve into one decision per site (forward order): `None` for
    /// exact sites, the method + per-site budget otherwise.
    pub fn resolve(&self, n_sites: usize) -> Result<Vec<Option<SiteSketch>>> {
        if !NATIVE_METHODS.contains(&self.method.as_str()) {
            bail!(
                "native backend does not implement method {} (supported: {})",
                self.method,
                NATIVE_METHODS.join(" ")
            );
        }
        let mask = Self::site_mask(&self.location, n_sites)?;
        if let Some(s) = &self.schedule {
            if s.len() != n_sites {
                bail!(
                    "budget schedule has {} entries but the model has {} \
                     sketchable layers",
                    s.len(),
                    n_sites
                );
            }
        }
        Ok((0..n_sites)
            .map(|i| {
                if !mask[i] || self.is_exact() {
                    return None;
                }
                let budget =
                    self.schedule.as_ref().map_or(self.budget, |s| s[i]);
                Some(SiteSketch { method: self.method.clone(), budget })
            })
            .collect())
    }
}

/// The forward tape: one cache per layer plus the stack output.
pub struct Tape {
    /// `caches[i]` is what layer `i` recorded for its backward.
    pub caches: Vec<Cache>,
    /// Output of the last layer (the logits for a classifier stack).
    pub output: Mat,
}

/// A stack of [`Layer`]s applied in order; owns the tape and the flat
/// parameter registry.
pub struct Sequential {
    /// The layers, input to output.
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    /// Wrap an ordered layer stack.
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Sequential {
        assert!(!layers.is_empty(), "need at least one layer");
        Sequential { layers }
    }

    /// Number of layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Indices of the sketchable layers, forward order.
    pub fn sketch_sites(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.sketchable())
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of sketch sites.
    pub fn num_sites(&self) -> usize {
        self.layers.iter().filter(|l| l.sketchable()).count()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    /// Number of parameter tensors (the optimizer slot count).
    pub fn num_slots(&self) -> usize {
        self.layers.iter().map(|l| l.params().len()).sum()
    }

    /// Resolve a policy into one decision per *layer* (`None` everywhere
    /// except gated sketch sites).
    pub fn plan(&self, policy: &SketchPolicy) -> Result<Vec<Option<SiteSketch>>> {
        let sites = self.sketch_sites();
        let per_site = policy.resolve(sites.len())?;
        let mut plan: Vec<Option<SiteSketch>> = vec![None; self.layers.len()];
        for (site, layer_idx) in sites.into_iter().enumerate() {
            plan[layer_idx] = per_site[site].clone();
        }
        Ok(plan)
    }

    /// Forward sweep, recording every layer's cache.
    pub fn forward(&self, x: &Mat) -> Tape {
        let mut caches = Vec::with_capacity(self.layers.len());
        let mut h: Option<Mat> = None;
        for layer in &self.layers {
            let (y, c) = layer.forward(h.as_ref().unwrap_or(x));
            caches.push(c);
            h = Some(y);
        }
        Tape { caches, output: h.expect("stack is never empty") }
    }

    /// Reverse sweep from the loss gradient `dout`, under a per-layer
    /// `plan` from [`Sequential::plan`]. Exact layers consume no
    /// randomness from `rng`.
    pub fn backward(
        &self,
        tape: &Tape,
        dout: &Mat,
        plan: &[Option<SiteSketch>],
        rng: &mut Pcg64,
    ) -> Grads {
        let n = self.layers.len();
        assert_eq!(plan.len(), n, "plan length");
        let mut per_layer: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
        per_layer.resize_with(n, Vec::new);
        let mut g = dout.clone();
        for i in (0..n).rev() {
            let need_gx = i > 0;
            let mut ctx =
                SketchCtx { sketch: plan[i].as_ref(), rng: &mut *rng };
            let (gx, pg) =
                self.layers[i].backward(&g, &tape.caches[i], &mut ctx, need_gx);
            per_layer[i] = pg;
            if let Some(gx) = gx {
                g = gx;
            }
        }
        let mut slots = Vec::with_capacity(self.num_slots());
        for pg in per_layer {
            slots.extend(pg);
        }
        Grads { slots }
    }

    /// One optimizer update over every parameter tensor, global slot order.
    pub fn apply_grads(&mut self, opt: &mut Optim, grads: &Grads, lr: f64) {
        let mut slot = 0;
        for layer in &mut self.layers {
            for p in layer.params_mut() {
                opt.update(slot, p, &grads.slots[slot], lr);
                slot += 1;
            }
        }
        debug_assert_eq!(slot, grads.slots.len(), "grad slot count");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::models;

    #[test]
    fn policy_masks_resolve_per_site() {
        let m = SketchPolicy::site_mask("all", 3).unwrap();
        assert_eq!(m, vec![true, true, true]);
        assert_eq!(
            SketchPolicy::site_mask("first", 3).unwrap(),
            vec![true, false, false]
        );
        assert_eq!(
            SketchPolicy::site_mask("last", 3).unwrap(),
            vec![false, false, true]
        );
        assert_eq!(
            SketchPolicy::site_mask("none", 2).unwrap(),
            vec![false, false]
        );
        assert!(SketchPolicy::site_mask("middle", 3).is_err());
        assert!(SketchPolicy::site_mask("first", 0).is_err());
    }

    #[test]
    fn policy_resolves_budget_schedule() {
        let p = SketchPolicy {
            method: "l1".into(),
            budget: 0.5,
            location: "all".into(),
            schedule: Some(vec![0.5, 0.25, 0.1]),
        };
        let r = p.resolve(3).unwrap();
        assert_eq!(r[0].as_ref().unwrap().budget, 0.5);
        assert_eq!(r[1].as_ref().unwrap().budget, 0.25);
        assert_eq!(r[2].as_ref().unwrap().budget, 0.1);
        // wrong length errors with both counts in the message
        let bad = SketchPolicy { schedule: Some(vec![0.5]), ..p.clone() };
        let err = format!("{}", bad.resolve(3).unwrap_err());
        assert!(err.contains("1 entries") && err.contains('3'), "{err}");
    }

    #[test]
    fn baseline_and_masked_sites_resolve_to_exact() {
        let p = SketchPolicy::exact();
        assert!(p.resolve(3).unwrap().iter().all(|s| s.is_none()));
        let p = SketchPolicy {
            method: "l1".into(),
            budget: 0.2,
            location: "last".into(),
            schedule: None,
        };
        let r = p.resolve(3).unwrap();
        assert!(r[0].is_none() && r[1].is_none());
        assert_eq!(r[2].as_ref().unwrap().method, "l1");
    }

    #[test]
    fn unknown_method_is_rejected() {
        let p = SketchPolicy {
            method: "rcs".into(),
            budget: 0.2,
            location: "all".into(),
            schedule: None,
        };
        assert!(p.resolve(2).is_err());
    }

    #[test]
    fn mlp_stack_counts_sites_and_slots() {
        let m = models::mlp(&[5, 4, 3], 0);
        assert_eq!(m.num_layers(), 3); // lin relu lin (relu only between)
        assert_eq!(m.sketch_sites(), vec![0, 2]);
        assert_eq!(m.num_sites(), 2);
        assert_eq!(m.num_slots(), 4);
        assert_eq!(m.num_params(), 5 * 4 + 4 + 4 * 3 + 3);
    }

    #[test]
    fn masked_off_layers_consume_no_rng() {
        use crate::native::loss::{loss_and_grad, LossKind};
        use crate::rng::Pcg64;
        use crate::tensor::Mat;
        let m = models::mlp(&[4, 6, 3], 5);
        let mut rng = Pcg64::new(6, 0);
        let x = Mat::from_fn(5, 4, |_, _| rng.gaussian() as f32);
        let y = vec![0i32, 1, 2, 0, 1];
        let tape = m.forward(&x);
        let (_, dl) = loss_and_grad(LossKind::CrossEntropy, &tape.output, &y);
        let masked = SketchPolicy {
            method: "l1".into(),
            budget: 0.3,
            location: "none".into(),
            schedule: None,
        };
        let mut r1 = Pcg64::new(77, 0);
        let g1 = m.backward(&tape, &dl, &m.plan(&masked).unwrap(), &mut r1);
        let mut r2 = Pcg64::new(77, 0);
        let g2 =
            m.backward(&tape, &dl, &m.plan(&SketchPolicy::exact()).unwrap(), &mut r2);
        for (a, b) in g1.slots[0].iter().zip(&g2.slots[0]) {
            assert!((a - b).abs() < 1e-5);
        }
        // and the rng stream was untouched by the masked run
        assert_eq!(r1.next_u64(), Pcg64::new(77, 0).next_u64());
    }
}
