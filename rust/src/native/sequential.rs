//! [`Sequential`]: the container that owns the layer stack, and
//! [`Workspace`]: the preallocated arenas one training step runs in.
//! [`SketchPolicy`] is the per-layer sketch configuration that replaces
//! the old single global `SketchSpec`; [`super::policy::ActivationPolicy`]
//! is its forward-side twin deciding what each layer's input stash keeps.
//!
//! Since the view-based kernel redesign (DESIGN.md §7.2) the container is
//! destination-passing end to end, and since the activation-policy
//! redesign (§7.4) its memory model is depth-independent: instead of one
//! activation and one gradient buffer per layer, [`Sequential::workspace`]
//! sizes two ping-pong *flow* buffers (forward) and two *gradient-flow*
//! buffers (backward) at the widest activation in the stack, plus one
//! input [`Stash`] slot per layer. Layer `i` reads its input from
//! `flow[(i−1) % 2]` and writes its output into `flow[i % 2]`; what the
//! backward pass will need of that input is captured in the layer's stash
//! slot *before* the forward call overwrites the other buffer. Under
//! [`super::policy::ActivationPolicy::exact`] the stash is a bit-copy of
//! the input (bit-identical semantics to the old per-depth arenas); under
//! the kept policy, sketched sites store only the gathered kept columns
//! and ReLU stores a sign bitset, so growing the stack deeper grows the
//! footprint by the compact stashes only.
//!
//! Sketch *sites* are the layers reporting [`Layer::sketchable`], numbered
//! in forward order; [`SketchPolicy::resolve`] maps the config's
//! `location` mask (`all|first|last|none`) and optional per-depth budget
//! schedule onto those sites, and [`Sequential::plan`] combines that with
//! an activation policy into one [`StepPlan`]. Exact sites consume no
//! gate randomness, so a `location="none"` run is bit-identical to the
//! baseline, and an exact activation policy consumes no stash randomness.

use crate::pool;
use crate::rng::Pcg64;
use crate::sketch::SketchScratch;
use crate::tensor::kernels;
use crate::tensor::Mat;
use anyhow::{bail, Result};

use super::layer::{Cache, Grads, Layer, SiteSketch, SketchCtx, NATIVE_METHODS};
use super::optim::Optim;
use super::policy::{
    stash_input, ActMode, ActSite, ActivationPolicy, InputNeed, Stash, StepPlan,
};

/// Per-layer sketch configuration: one method, a default budget, the
/// `location` site mask, and an optional per-site budget schedule (the
/// Fig. 3-style depth sweeps).
#[derive(Clone, Debug)]
pub struct SketchPolicy {
    /// One of [`NATIVE_METHODS`]; `"baseline"` means exact everywhere.
    pub method: String,
    /// Default kept-column budget p ∈ (0, 1] for every gated site.
    pub budget: f64,
    /// Which sites are gated: `"all" | "first" | "last" | "none"`.
    pub location: String,
    /// Optional per-site budgets (forward order); when set, its length
    /// must equal the model's site count and it overrides `budget`.
    pub schedule: Option<Vec<f64>>,
}

impl SketchPolicy {
    /// The exact-backward policy.
    pub fn exact() -> SketchPolicy {
        SketchPolicy {
            method: "baseline".into(),
            budget: 1.0,
            location: "none".into(),
            schedule: None,
        }
    }

    /// Policy from a run config (`method` / `budget` / `location` /
    /// `budget_schedule` fields).
    pub fn from_config(cfg: &crate::config::TrainConfig) -> SketchPolicy {
        SketchPolicy {
            method: cfg.method.clone(),
            budget: cfg.budget,
            location: cfg.location.clone(),
            schedule: if cfg.budget_schedule.is_empty() {
                None
            } else {
                Some(cfg.budget_schedule.clone())
            },
        }
    }

    /// True when no sketching happens regardless of the site mask.
    pub fn is_exact(&self) -> bool {
        self.method == "baseline"
    }

    /// Per-site gate mask from a `location` string over `n` sites.
    pub fn site_mask(location: &str, n: usize) -> Result<Vec<bool>> {
        let mut m = vec![false; n];
        match location {
            "all" => m.iter_mut().for_each(|v| *v = true),
            "first" | "last" if n == 0 => {
                bail!("location {location} needs at least one sketchable layer")
            }
            "first" => m[0] = true,
            "last" => m[n - 1] = true,
            "none" => {}
            other => bail!(
                "unknown sketch location {other} (want all|first|last|none)"
            ),
        }
        Ok(m)
    }

    /// Resolve into one decision per site (forward order): `None` for
    /// exact sites, the method + per-site budget otherwise.
    pub fn resolve(&self, n_sites: usize) -> Result<Vec<Option<SiteSketch>>> {
        if !NATIVE_METHODS.contains(&self.method.as_str()) {
            bail!(
                "native backend does not implement method {} (supported: {})",
                self.method,
                NATIVE_METHODS.join(" ")
            );
        }
        let mask = Self::site_mask(&self.location, n_sites)?;
        if let Some(s) = &self.schedule {
            if s.len() != n_sites {
                bail!(
                    "budget schedule has {} entries but the model has {} \
                     sketchable layers",
                    s.len(),
                    n_sites
                );
            }
        }
        Ok((0..n_sites)
            .map(|i| {
                if !mask[i] || self.is_exact() {
                    return None;
                }
                let budget =
                    self.schedule.as_ref().map_or(self.budget, |s| s[i]);
                Some(SiteSketch { method: self.method.clone(), budget })
            })
            .collect())
    }
}

/// Arena-by-arena byte accounting of a [`Workspace`], by *capacity* (what
/// the allocator actually holds, not the current logical shapes). This is
/// the tracked memory column in `BENCH_native.json` and the quantity the
/// memory-regression suite pins: under the kept activation policy `stash`
/// shrinks with the budget while every other arena is policy-independent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspaceBytes {
    /// The two ping-pong forward activation buffers.
    pub flow: usize,
    /// The two ping-pong backward gradient buffers.
    pub gflow: usize,
    /// Per-layer input stashes — the only arena the activation policy
    /// scales.
    pub stash: usize,
    /// Per-layer intermediate caches ([`Layer::cache_shapes`]).
    pub caches: usize,
    /// Flat parameter-gradient slots.
    pub grad_slots: usize,
    /// Column-planning scratch (scores, gate probabilities, kept lists).
    pub planning: usize,
    /// Sum of every arena above.
    pub total: usize,
}

/// Bytes held by one matrix's allocation.
fn mat_bytes(m: &Mat) -> usize {
    m.data.capacity() * std::mem::size_of::<f32>()
}

/// The preallocated arenas one training step runs in: two ping-pong
/// activation buffers, two ping-pong gradient buffers, one input stash
/// slot per layer, per-layer caches, the flat parameter-gradient slots,
/// and the column-planning scratch. Built once by
/// [`Sequential::workspace`] for a fixed `(batch, in_dim)`; every buffer
/// is overwritten each step (never read before written), so reuse across
/// steps is safe and steady-state training allocates nothing.
///
/// Lifetime rules: a workspace is only valid for the stack that built it
/// (buffer shapes are per-layer) and for inputs of exactly
/// `batch × in_dim`. After [`Sequential::forward_train`], `stash[i]`
/// holds what layer i's backward needs of its input and the flow buffers
/// hold the last two activations, so the workspace must not be touched
/// between the two sweeps of one step.
pub struct Workspace {
    /// Batch size every buffer is sized for.
    pub batch: usize,
    /// Input width the stack was sized for.
    pub in_dim: usize,
    /// `dims[i]` = layer i's input width; `dims[n]` = the output width.
    pub dims: Vec<usize>,
    /// Ping-pong forward buffers: layer i writes `flow[i % 2]`, sized at
    /// the widest activation so `resize_to` never reallocates.
    pub flow: [Mat; 2],
    /// Ping-pong backward buffers, mirroring `flow`.
    pub gflow: [Mat; 2],
    /// Which flow/gflow buffer holds the stack output (`(n−1) % 2`).
    pub out_ix: usize,
    /// `stash[i]` = what layer i's backward will read of its forward
    /// input, captured per the step's [`ActSite`] before the forward
    /// overwrote the previous flow buffer.
    pub stash: Vec<Stash>,
    /// Per-layer scratch ([`Layer::cache_shapes`]).
    pub caches: Vec<Cache>,
    /// Flat parameter-gradient slots, global slot order.
    pub grad_slots: Grads,
    /// `slot_offsets[i]..slot_offsets[i+1]` = layer i's slot range (so the
    /// backward walk never rebuilds the parameter registry).
    pub slot_offsets: Vec<usize>,
    /// Reused column-planning buffers for the sketched sites and the
    /// kept-column activation gates.
    pub scratch: SketchScratch,
    /// Handle to the pack-buffer pool the SIMD kernels draw from. The
    /// pool is process-wide (`PackArena::global()` — kernels reach it
    /// directly, not through this field); the workspace holds a handle
    /// after pre-warming it at build for this model's worst-case panel
    /// sizes, so callers can extend the reserve or inspect pooling, and
    /// so the first step packs without allocating (`--kernel simd`; the
    /// pool recycles, so steady state never allocates regardless).
    pub pack: kernels::PackArena,
}

impl Workspace {
    /// The stack output (logits) after a forward sweep.
    pub fn output(&self) -> &Mat {
        &self.flow[self.out_ix]
    }

    /// The output activations and the loss-gradient destination read by
    /// [`Sequential::backward`], as one disjoint borrow (the gradient
    /// buffer is resized to the logits' shape before the split).
    pub fn loss_io(&mut self) -> (&Mat, &mut Mat) {
        let ix = self.out_ix;
        let (r, c) = (self.flow[ix].rows, self.flow[ix].cols);
        self.gflow[ix].resize_to(r, c);
        (&self.flow[ix], &mut self.gflow[ix])
    }

    /// The loss-gradient destination read by [`Sequential::backward`].
    pub fn grad_out_mut(&mut self) -> &mut Mat {
        let ix = self.out_ix;
        let (r, c) = (self.flow[ix].rows, self.flow[ix].cols);
        self.gflow[ix].resize_to(r, c);
        &mut self.gflow[ix]
    }

    /// Arena-by-arena byte accounting (allocator capacities).
    pub fn workspace_bytes(&self) -> WorkspaceBytes {
        let flow: usize = self.flow.iter().map(mat_bytes).sum();
        let gflow: usize = self.gflow.iter().map(mat_bytes).sum();
        let stash: usize = self.stash.iter().map(|s| s.bytes()).sum();
        let caches: usize = self
            .caches
            .iter()
            .map(|c| c.mats.iter().map(mat_bytes).sum::<usize>())
            .sum();
        let grad_slots: usize = self
            .grad_slots
            .slots
            .iter()
            .map(|s| s.capacity() * std::mem::size_of::<f32>())
            .sum();
        let planning = self.scratch.bytes();
        WorkspaceBytes {
            flow,
            gflow,
            stash,
            caches,
            grad_slots,
            planning,
            total: flow + gflow + stash + caches + grad_slots + planning,
        }
    }
}

/// A stack of [`Layer`]s applied in order; owns the layers and the flat
/// parameter registry. Per-step state lives in a caller-owned
/// [`Workspace`].
pub struct Sequential {
    /// The layers, input to output.
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    /// Wrap an ordered layer stack.
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Sequential {
        assert!(!layers.is_empty(), "need at least one layer");
        Sequential { layers }
    }

    /// Number of layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Indices of the sketchable layers, forward order.
    pub fn sketch_sites(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.sketchable())
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of sketch sites.
    pub fn num_sites(&self) -> usize {
        self.layers.iter().filter(|l| l.sketchable()).count()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    /// Number of parameter tensors (the optimizer slot count).
    pub fn num_slots(&self) -> usize {
        self.layers.iter().map(|l| l.params().len()).sum()
    }

    /// Allocate every arena one training step needs for `batch × in_dim`
    /// inputs: the ping-pong flow/gradient buffers (sized at the widest
    /// activation), empty stash slots, caches per depth
    /// ([`Layer::out_dim`] / [`Layer::cache_shapes`] size them), the
    /// parameter-gradient slots, and the sketch scratch.
    pub fn workspace(&self, batch: usize, in_dim: usize) -> Workspace {
        self.build_workspace(batch, in_dim, true)
    }

    /// Forward-only arenas for inference serving: like
    /// [`Sequential::workspace`] but with no gradient-flow buffers and no
    /// parameter-gradient slots (a plain [`Sequential::forward`] touches
    /// neither — every layer's `input_need` is effectively `None`), so
    /// the footprint is the two flow buffers plus the per-layer caches.
    /// `batch` is the *largest* batch the workspace will serve;
    /// [`Sequential::retarget_batch`] re-points it at any smaller batch
    /// (0 included) without allocating. Only forward sweeps are valid on
    /// it — `forward_train`/`backward` need the training arenas.
    pub fn inference_workspace(&self, batch: usize, in_dim: usize) -> Workspace {
        self.build_workspace(batch, in_dim, false)
    }

    fn build_workspace(&self, batch: usize, in_dim: usize, training: bool) -> Workspace {
        let n = self.layers.len();
        let mut dims = Vec::with_capacity(n + 1);
        dims.push(in_dim);
        let mut caches = Vec::with_capacity(n);
        let mut din = in_dim;
        for layer in &self.layers {
            let dout = layer.out_dim(din);
            caches.push(Cache::for_layer(layer.as_ref(), batch, din));
            dims.push(dout);
            din = dout;
        }
        // flow/gflow hold layer *outputs* only (layer 0 reads the caller's
        // input directly), so the widest output bounds all four buffers.
        let width = dims[1..].iter().copied().max().unwrap_or(1);
        let flow = [Mat::zeros(batch, width), Mat::zeros(batch, width)];
        // Inference never reads the gradient arenas: leave them empty so a
        // serving engine's footprint is flow + caches only.
        let gflow = if training {
            [Mat::zeros(batch, width), Mat::zeros(batch, width)]
        } else {
            [Mat::zeros(0, 0), Mat::zeros(0, 0)]
        };
        let stash: Vec<Stash> = (0..n).map(|_| Stash::default()).collect();
        let mut slots = Vec::with_capacity(self.num_slots());
        let mut slot_offsets = Vec::with_capacity(n + 1);
        slot_offsets.push(0);
        let mut max_param = 0usize;
        for layer in &self.layers {
            for p in layer.params() {
                max_param = max_param.max(p.len());
                if training {
                    slots.push(vec![0.0f32; p.len()]);
                }
            }
            slot_offsets.push(slots.len());
        }
        // Pre-warm the pack arena: a packed GEMM takes one B panel plus
        // one A panel per worker, each bounded by the largest operand this
        // stack can hand a kernel (activations/gradients or a parameter
        // tensor) plus micro-tile padding. Best-effort — the arena grows
        // on demand — but it makes the *first* step's packing
        // allocation-free too.
        let pack = kernels::PackArena::global();
        let max_act = batch * dims.iter().copied().max().unwrap_or(in_dim);
        let panel = max_act.max(max_param);
        pack.reserve(pool::threads() + 1, panel + panel / 4 + 1024);
        Workspace {
            batch,
            in_dim,
            dims,
            flow,
            gflow,
            out_ix: (n - 1) % 2,
            stash,
            caches,
            grad_slots: Grads { slots },
            slot_offsets,
            scratch: SketchScratch::new(),
            pack,
        }
    }

    /// Re-point a workspace at a different batch size for forward sweeps:
    /// updates the logical batch and resizes every layer cache to
    /// [`Layer::cache_shapes`] at the new batch (attention/LayerNorm
    /// forwards read their cache mats at the mats' own shapes, so stale
    /// shapes would compute the wrong thing). The flow buffers are
    /// resized by the forward sweep itself. `Mat::resize_to` keeps
    /// capacity, so retargeting at or below the batch the workspace was
    /// built for never allocates — the serving engine's steady-state
    /// contract — and `batch == 0` is valid, yielding empty logits.
    /// Forward-only: the gradient arenas and stashes are left at their
    /// old shapes, so retarget + `backward` is invalid.
    pub fn retarget_batch(&self, ws: &mut Workspace, batch: usize) {
        if ws.batch == batch {
            return;
        }
        ws.batch = batch;
        for (i, layer) in self.layers.iter().enumerate() {
            let shapes = layer.cache_shapes(batch, ws.dims[i]);
            for (mat, (r, c)) in ws.caches[i].mats.iter_mut().zip(shapes) {
                mat.resize_to(r, c);
            }
        }
    }

    /// Inference forward sweep: stream `x` through every layer, layer i
    /// writing `ws.flow[i % 2]`. Captures no input stashes and consumes
    /// no randomness — [`Sequential::backward`] is only valid after
    /// [`Sequential::forward_train`].
    pub fn forward(&self, x: &Mat, ws: &mut Workspace) {
        assert_eq!(
            (x.rows, x.cols),
            (ws.batch, ws.in_dim),
            "workspace sized for a different input shape"
        );
        let n = self.layers.len();
        for i in 0..n {
            let [f0, f1] = &mut ws.flow;
            let (input, out): (&Mat, &mut Mat) = if i == 0 {
                (x, f0)
            } else if i % 2 == 0 {
                (&*f1, f0)
            } else {
                (&*f0, f1)
            };
            out.resize_to(ws.batch, ws.dims[i + 1]);
            self.layers[i].forward(input, out, &mut ws.caches[i]);
        }
        ws.out_ix = (n - 1) % 2;
    }

    /// Training forward sweep: like [`Sequential::forward`], but before
    /// each layer runs, its input is captured into `ws.stash[i]` per the
    /// step plan's [`ActSite`] — a bit-copy under the exact policy, a
    /// sign bitset for ReLU, or the gathered kept columns (gates drawn
    /// from `rng`) at sketched sites under the kept policy. The gates are
    /// decided at production time, before the ping-pong overwrites the
    /// input. Exact/Full/Mask/None sites consume no randomness.
    pub fn forward_train(
        &self,
        x: &Mat,
        ws: &mut Workspace,
        plan: &StepPlan,
        rng: &mut Pcg64,
    ) {
        assert_eq!(
            (x.rows, x.cols),
            (ws.batch, ws.in_dim),
            "workspace sized for a different input shape"
        );
        let n = self.layers.len();
        assert_eq!(plan.act.len(), n, "plan length");
        for i in 0..n {
            let [f0, f1] = &mut ws.flow;
            let (input, out): (&Mat, &mut Mat) = if i == 0 {
                (x, f0)
            } else if i % 2 == 0 {
                (&*f1, f0)
            } else {
                (&*f0, f1)
            };
            stash_input(
                self.layers[i].as_ref(),
                input,
                &plan.act[i],
                &mut ws.stash[i],
                &mut ws.scratch,
                rng,
            );
            out.resize_to(ws.batch, ws.dims[i + 1]);
            self.layers[i].forward(input, out, &mut ws.caches[i]);
        }
        ws.out_ix = (n - 1) % 2;
    }

    /// Reverse sweep under a [`StepPlan`] from [`Sequential::plan`],
    /// starting from the loss gradient the caller wrote into
    /// [`Workspace::loss_io`]'s gradient buffer. Layer i reads its
    /// upstream gradient from `ws.gflow[i % 2]`, its stashed input from
    /// `ws.stash[i]`, and writes its input gradient into
    /// `ws.gflow[(i−1) % 2]`. Parameter gradients land in
    /// `ws.grad_slots`; exact layers consume no randomness from `rng`.
    /// Only valid right after the [`Sequential::forward_train`] that
    /// captured the stashes under the same plan.
    pub fn backward(&self, ws: &mut Workspace, plan: &StepPlan, rng: &mut Pcg64) {
        let n = self.layers.len();
        assert_eq!(plan.sketch.len(), n, "plan length");
        for i in (0..n).rev() {
            let (slot_start, slot_end) =
                (ws.slot_offsets[i], ws.slot_offsets[i + 1]);
            let [g0, g1] = &mut ws.gflow;
            let (gy, gx): (&Mat, Option<&mut Mat>) = if i == 0 {
                (&*g0, None)
            } else if i % 2 == 0 {
                g1.resize_to(ws.batch, ws.dims[i]);
                (&*g0, Some(g1))
            } else {
                g0.resize_to(ws.batch, ws.dims[i]);
                (&*g1, Some(g0))
            };
            let stash = ws.stash[i].as_input();
            let mut ctx = SketchCtx {
                sketch: plan.sketch[i].as_ref(),
                rng: &mut *rng,
                scratch: &mut ws.scratch,
            };
            self.layers[i].backward(
                gy,
                stash,
                &mut ws.caches[i],
                &mut ctx,
                gx,
                &mut ws.grad_slots.slots[slot_start..slot_end],
            );
        }
    }

    /// Resolve a sketch policy and an activation policy into one
    /// [`StepPlan`]: per-layer sketch decisions (`None` everywhere except
    /// gated sketch sites) and per-layer stash decisions. A layer's
    /// [`ActSite`] follows its [`Layer::input_need`]: `None` stays
    /// `None`; `Signs` compacts to a bitset under the kept policy;
    /// `Values` compacts to kept columns only where the layer is a
    /// *gated* sketch site (the gated backward already rescales, so
    /// unbiasedness is preserved — see `policy.rs`), at the activation
    /// budget resolved per site (schedule > global > inherit the site's
    /// sketch budget).
    pub fn plan(
        &self,
        policy: &SketchPolicy,
        act: &ActivationPolicy,
    ) -> Result<StepPlan> {
        let sites = self.sketch_sites();
        let per_site = policy.resolve(sites.len())?;
        let mut sketch: Vec<Option<SiteSketch>> = vec![None; self.layers.len()];
        for (site, &layer_idx) in sites.iter().enumerate() {
            sketch[layer_idx] = per_site[site].clone();
        }
        if let Some(s) = &act.schedule {
            if s.len() != sites.len() {
                bail!(
                    "activation budget schedule has {} entries but the model \
                     has {} sketchable layers",
                    s.len(),
                    sites.len()
                );
            }
        }
        let kept_mode = act.mode == ActMode::Kept;
        let mut site_no = 0usize;
        let mut act_sites = Vec::with_capacity(self.layers.len());
        for (i, layer) in self.layers.iter().enumerate() {
            let this_site = if layer.sketchable() {
                let s = site_no;
                site_no += 1;
                Some(s)
            } else {
                None
            };
            act_sites.push(match layer.input_need() {
                InputNeed::None => ActSite::None,
                InputNeed::Signs => {
                    if kept_mode {
                        ActSite::Mask
                    } else {
                        ActSite::Full
                    }
                }
                InputNeed::Values => match (kept_mode, this_site, &sketch[i]) {
                    (true, Some(site), Some(sk)) => ActSite::Kept {
                        budget: act.budget_for(site, sk.budget),
                    },
                    _ => ActSite::Full,
                },
            });
        }
        Ok(StepPlan { sketch, act: act_sites })
    }

    /// One optimizer update over every parameter tensor, global slot order
    /// (allocation-free: walks [`Layer::visit_params_mut`]).
    pub fn apply_grads(&mut self, opt: &mut Optim, grads: &Grads, lr: f64) {
        let mut slot = 0;
        for layer in &mut self.layers {
            layer.visit_params_mut(&mut |p| {
                opt.update(slot, p, &grads.slots[slot], lr);
                slot += 1;
            });
        }
        debug_assert_eq!(slot, grads.slots.len(), "grad slot count");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::models;

    #[test]
    fn policy_masks_resolve_per_site() {
        let m = SketchPolicy::site_mask("all", 3).unwrap();
        assert_eq!(m, vec![true, true, true]);
        assert_eq!(
            SketchPolicy::site_mask("first", 3).unwrap(),
            vec![true, false, false]
        );
        assert_eq!(
            SketchPolicy::site_mask("last", 3).unwrap(),
            vec![false, false, true]
        );
        assert_eq!(
            SketchPolicy::site_mask("none", 2).unwrap(),
            vec![false, false]
        );
        assert!(SketchPolicy::site_mask("middle", 3).is_err());
        assert!(SketchPolicy::site_mask("first", 0).is_err());
    }

    #[test]
    fn policy_resolves_budget_schedule() {
        let p = SketchPolicy {
            method: "l1".into(),
            budget: 0.5,
            location: "all".into(),
            schedule: Some(vec![0.5, 0.25, 0.1]),
        };
        let r = p.resolve(3).unwrap();
        assert_eq!(r[0].as_ref().unwrap().budget, 0.5);
        assert_eq!(r[1].as_ref().unwrap().budget, 0.25);
        assert_eq!(r[2].as_ref().unwrap().budget, 0.1);
        // wrong length errors with both counts in the message
        let bad = SketchPolicy { schedule: Some(vec![0.5]), ..p.clone() };
        let err = format!("{}", bad.resolve(3).unwrap_err());
        assert!(err.contains("1 entries") && err.contains('3'), "{err}");
    }

    #[test]
    fn baseline_and_masked_sites_resolve_to_exact() {
        let p = SketchPolicy::exact();
        assert!(p.resolve(3).unwrap().iter().all(|s| s.is_none()));
        let p = SketchPolicy {
            method: "l1".into(),
            budget: 0.2,
            location: "last".into(),
            schedule: None,
        };
        let r = p.resolve(3).unwrap();
        assert!(r[0].is_none() && r[1].is_none());
        assert_eq!(r[2].as_ref().unwrap().method, "l1");
    }

    #[test]
    fn unknown_method_is_rejected() {
        let p = SketchPolicy {
            method: "rcs".into(),
            budget: 0.2,
            location: "all".into(),
            schedule: None,
        };
        assert!(p.resolve(2).is_err());
    }

    #[test]
    fn mlp_stack_counts_sites_and_slots() {
        let m = models::mlp(&[5, 4, 3], 0);
        assert_eq!(m.num_layers(), 3); // lin relu lin (relu only between)
        assert_eq!(m.sketch_sites(), vec![0, 2]);
        assert_eq!(m.num_sites(), 2);
        assert_eq!(m.num_slots(), 4);
        assert_eq!(m.num_params(), 5 * 4 + 4 + 4 * 3 + 3);
    }

    #[test]
    fn workspace_arenas_match_layer_shapes() {
        let m = models::mlp(&[5, 4, 3], 0);
        let ws = m.workspace(6, 5);
        assert_eq!(ws.dims, vec![5, 4, 4, 3]);
        // ping-pong buffers hold the widest output, not one mat per depth
        for f in ws.flow.iter().chain(&ws.gflow) {
            assert!(f.data.capacity() >= 6 * 4);
        }
        assert_eq!(ws.stash.len(), 3);
        assert!(ws.stash.iter().all(|s| matches!(s, Stash::None)));
        assert_eq!(ws.grad_slots.slots.len(), m.num_slots());
        assert_eq!(ws.grad_slots.slots[0].len(), 5 * 4);
        assert_eq!(ws.grad_slots.slots[1].len(), 4);
    }

    #[test]
    fn plan_resolves_act_sites_per_input_need() {
        let m = models::mlp(&[4, 6, 3], 1);
        // exact mode: every value/sign consumer stashes a full copy
        let p = m.plan(&SketchPolicy::exact(), &ActivationPolicy::exact()).unwrap();
        assert_eq!(p.act, vec![ActSite::Full, ActSite::Full, ActSite::Full]);
        // kept mode over gated sites: linears keep gathered columns at the
        // activation budget, the relu drops to a sign bitset
        let sk = SketchPolicy {
            method: "l1".into(),
            budget: 0.4,
            location: "all".into(),
            schedule: None,
        };
        let p = m.plan(&sk, &ActivationPolicy::kept(0.25)).unwrap();
        assert_eq!(p.act[0], ActSite::Kept { budget: 0.25 });
        assert_eq!(p.act[1], ActSite::Mask);
        assert_eq!(p.act[2], ActSite::Kept { budget: 0.25 });
        // a 0.0 activation budget inherits each site's sketch budget
        let p = m.plan(&sk, &ActivationPolicy::kept(0.0)).unwrap();
        assert_eq!(p.act[0], ActSite::Kept { budget: 0.4 });
        // kept mode over an exact backward: no gated site, so values fall
        // back to full stashes (kept columns without the rescaling
        // backward would be biased)
        let p = m.plan(&SketchPolicy::exact(), &ActivationPolicy::kept(0.25)).unwrap();
        assert_eq!(p.act[0], ActSite::Full);
        assert_eq!(p.act[1], ActSite::Mask);
        assert_eq!(p.act[2], ActSite::Full);
    }

    #[test]
    fn workspace_bytes_accounts_every_arena() {
        let m = models::mlp(&[4, 6, 3], 1);
        let ws = m.workspace(5, 4);
        let wb = ws.workspace_bytes();
        assert_eq!(
            wb.total,
            wb.flow + wb.gflow + wb.stash + wb.caches + wb.grad_slots
                + wb.planning
        );
        assert!(wb.flow >= 2 * 5 * 6 * 4, "two buffers at the widest act");
        assert_eq!(wb.stash, 0, "nothing stashed before the first step");
    }

    #[test]
    fn workspace_steps_are_reusable_and_deterministic() {
        use crate::native::loss::{loss_and_grad_into, LossKind};
        use crate::rng::Pcg64;
        use crate::tensor::Mat;
        let m = models::mlp(&[4, 6, 3], 5);
        let mut rng = Pcg64::new(6, 0);
        let x = Mat::from_fn(5, 4, |_, _| rng.gaussian() as f32);
        let y = vec![0i32, 1, 2, 0, 1];
        let plan = m
            .plan(
                &SketchPolicy {
                    method: "l1".into(),
                    budget: 0.4,
                    location: "all".into(),
                    schedule: None,
                },
                &ActivationPolicy::kept(0.5),
            )
            .unwrap();
        let run = |ws: &mut Workspace| {
            let mut act_rng = Pcg64::new(50, 1);
            m.forward_train(&x, ws, &plan, &mut act_rng);
            let (logits, gout) = ws.loss_io();
            loss_and_grad_into(LossKind::CrossEntropy, logits, &y, gout);
            let mut rng = Pcg64::new(77, 0);
            m.backward(ws, &plan, &mut rng);
            ws.grad_slots.flatten()
        };
        let mut ws = m.workspace(5, 4);
        let first = run(&mut ws);
        // second pass through the SAME (now dirty) workspace must agree —
        // every buffer is fully overwritten, never accumulated into
        let second = run(&mut ws);
        assert_eq!(first, second);
        // and a fresh workspace agrees too
        let mut ws2 = m.workspace(5, 4);
        assert_eq!(first, run(&mut ws2));
    }

    #[test]
    fn masked_off_layers_consume_no_rng() {
        use crate::native::loss::{loss_and_grad_into, LossKind};
        use crate::rng::Pcg64;
        use crate::tensor::Mat;
        let m = models::mlp(&[4, 6, 3], 5);
        let mut rng = Pcg64::new(6, 0);
        let x = Mat::from_fn(5, 4, |_, _| rng.gaussian() as f32);
        let y = vec![0i32, 1, 2, 0, 1];
        let masked = SketchPolicy {
            method: "l1".into(),
            budget: 0.3,
            location: "none".into(),
            schedule: None,
        };
        // one rng drives BOTH sweeps: an exact activation policy must not
        // consume stash randomness either
        let grads_under = |policy: &SketchPolicy, rng: &mut Pcg64| {
            let mut ws = m.workspace(5, 4);
            let plan = m.plan(policy, &ActivationPolicy::exact()).unwrap();
            m.forward_train(&x, &mut ws, &plan, rng);
            let (logits, gout) = ws.loss_io();
            loss_and_grad_into(LossKind::CrossEntropy, logits, &y, gout);
            m.backward(&mut ws, &plan, rng);
            ws.grad_slots.flatten()
        };
        let mut r1 = Pcg64::new(77, 0);
        let g1 = grads_under(&masked, &mut r1);
        let mut r2 = Pcg64::new(77, 0);
        let g2 = grads_under(&SketchPolicy::exact(), &mut r2);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-5);
        }
        // and the rng stream was untouched by the masked run
        assert_eq!(r1.next_u64(), Pcg64::new(77, 0).next_u64());
    }
}
