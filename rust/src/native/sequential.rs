//! [`Sequential`]: the container that owns the layer stack, and
//! [`Workspace`]: the preallocated arenas one training step runs in.
//! [`SketchPolicy`] is the per-layer sketch configuration that replaces
//! the old single global `SketchSpec`.
//!
//! Since the view-based kernel redesign (DESIGN.md §7.2) the container is
//! destination-passing end to end: [`Sequential::workspace`] sizes one
//! activation buffer, one gradient buffer and one layer [`Cache`] per
//! depth — plus the flat parameter-gradient slots and the column-planning
//! scratch — once at build, and [`Sequential::forward`] /
//! [`Sequential::backward`] stream every step through those arenas. A
//! steady-state optimizer step therefore performs no heap allocation.
//!
//! Sketch *sites* are the layers reporting [`Layer::sketchable`], numbered
//! in forward order; [`SketchPolicy::resolve`] maps the config's
//! `location` mask (`all|first|last|none`) and optional per-depth budget
//! schedule onto those sites. Exact sites consume no gate randomness, so
//! a `location="none"` run is bit-identical to the baseline.

use crate::pool;
use crate::rng::Pcg64;
use crate::sketch::SketchScratch;
use crate::tensor::kernels;
use crate::tensor::Mat;
use anyhow::{bail, Result};

use super::layer::{Cache, Grads, Layer, SiteSketch, SketchCtx, NATIVE_METHODS};
use super::optim::Optim;

/// Per-layer sketch configuration: one method, a default budget, the
/// `location` site mask, and an optional per-site budget schedule (the
/// Fig. 3-style depth sweeps).
#[derive(Clone, Debug)]
pub struct SketchPolicy {
    /// One of [`NATIVE_METHODS`]; `"baseline"` means exact everywhere.
    pub method: String,
    /// Default kept-column budget p ∈ (0, 1] for every gated site.
    pub budget: f64,
    /// Which sites are gated: `"all" | "first" | "last" | "none"`.
    pub location: String,
    /// Optional per-site budgets (forward order); when set, its length
    /// must equal the model's site count and it overrides `budget`.
    pub schedule: Option<Vec<f64>>,
}

impl SketchPolicy {
    /// The exact-backward policy.
    pub fn exact() -> SketchPolicy {
        SketchPolicy {
            method: "baseline".into(),
            budget: 1.0,
            location: "none".into(),
            schedule: None,
        }
    }

    /// Policy from a run config (`method` / `budget` / `location` /
    /// `budget_schedule` fields).
    pub fn from_config(cfg: &crate::config::TrainConfig) -> SketchPolicy {
        SketchPolicy {
            method: cfg.method.clone(),
            budget: cfg.budget,
            location: cfg.location.clone(),
            schedule: if cfg.budget_schedule.is_empty() {
                None
            } else {
                Some(cfg.budget_schedule.clone())
            },
        }
    }

    /// True when no sketching happens regardless of the site mask.
    pub fn is_exact(&self) -> bool {
        self.method == "baseline"
    }

    /// Per-site gate mask from a `location` string over `n` sites.
    pub fn site_mask(location: &str, n: usize) -> Result<Vec<bool>> {
        let mut m = vec![false; n];
        match location {
            "all" => m.iter_mut().for_each(|v| *v = true),
            "first" | "last" if n == 0 => {
                bail!("location {location} needs at least one sketchable layer")
            }
            "first" => m[0] = true,
            "last" => m[n - 1] = true,
            "none" => {}
            other => bail!(
                "unknown sketch location {other} (want all|first|last|none)"
            ),
        }
        Ok(m)
    }

    /// Resolve into one decision per site (forward order): `None` for
    /// exact sites, the method + per-site budget otherwise.
    pub fn resolve(&self, n_sites: usize) -> Result<Vec<Option<SiteSketch>>> {
        if !NATIVE_METHODS.contains(&self.method.as_str()) {
            bail!(
                "native backend does not implement method {} (supported: {})",
                self.method,
                NATIVE_METHODS.join(" ")
            );
        }
        let mask = Self::site_mask(&self.location, n_sites)?;
        if let Some(s) = &self.schedule {
            if s.len() != n_sites {
                bail!(
                    "budget schedule has {} entries but the model has {} \
                     sketchable layers",
                    s.len(),
                    n_sites
                );
            }
        }
        Ok((0..n_sites)
            .map(|i| {
                if !mask[i] || self.is_exact() {
                    return None;
                }
                let budget =
                    self.schedule.as_ref().map_or(self.budget, |s| s[i]);
                Some(SiteSketch { method: self.method.clone(), budget })
            })
            .collect())
    }
}

/// The preallocated arenas one training step runs in: per-depth activation
/// and gradient buffers, per-layer caches, the flat parameter-gradient
/// slots, and the column-planning scratch. Built once by
/// [`Sequential::workspace`] for a fixed `(batch, in_dim)`; every buffer
/// is overwritten each step (never read before written), so reuse across
/// steps is safe and steady-state training allocates nothing.
///
/// Lifetime rules: a workspace is only valid for the stack that built it
/// (buffer shapes are per-layer) and for inputs of exactly `batch × in_dim`.
/// After [`Sequential::forward`], `acts[i]` holds layer i's output —
/// `backward` reads those as the layers' saved inputs, so the workspace
/// must not be touched between the two sweeps of one step.
pub struct Workspace {
    /// Batch size every buffer is sized for.
    pub batch: usize,
    /// Input width the stack was sized for.
    pub in_dim: usize,
    /// `acts[i]` = output of layer i (`batch × out_dim(i)`).
    pub acts: Vec<Mat>,
    /// `grads[i]` = gradient w.r.t. `acts[i]` (same shapes). The loss
    /// writes `dL/d(output)` into the last entry before `backward`.
    pub grads: Vec<Mat>,
    /// Per-layer scratch ([`Layer::cache_shapes`]).
    pub caches: Vec<Cache>,
    /// Flat parameter-gradient slots, global slot order.
    pub grad_slots: Grads,
    /// `slot_offsets[i]..slot_offsets[i+1]` = layer i's slot range (so the
    /// backward walk never rebuilds the parameter registry).
    pub slot_offsets: Vec<usize>,
    /// Reused column-planning buffers for the sketched sites.
    pub scratch: SketchScratch,
    /// Handle to the pack-buffer pool the SIMD kernels draw from. The
    /// pool is process-wide (`PackArena::global()` — kernels reach it
    /// directly, not through this field); the workspace holds a handle
    /// after pre-warming it at build for this model's worst-case panel
    /// sizes, so callers can extend the reserve or inspect pooling, and
    /// so the first step packs without allocating (`--kernel simd`; the
    /// pool recycles, so steady state never allocates regardless).
    pub pack: kernels::PackArena,
}

impl Workspace {
    /// The stack output (logits) after a [`Sequential::forward`].
    pub fn output(&self) -> &Mat {
        self.acts.last().expect("stack is never empty")
    }

    /// The loss-gradient destination read by [`Sequential::backward`].
    pub fn grad_out_mut(&mut self) -> &mut Mat {
        self.grads.last_mut().expect("stack is never empty")
    }
}

/// A stack of [`Layer`]s applied in order; owns the layers and the flat
/// parameter registry. Per-step state lives in a caller-owned
/// [`Workspace`].
pub struct Sequential {
    /// The layers, input to output.
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    /// Wrap an ordered layer stack.
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Sequential {
        assert!(!layers.is_empty(), "need at least one layer");
        Sequential { layers }
    }

    /// Number of layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Indices of the sketchable layers, forward order.
    pub fn sketch_sites(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.sketchable())
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of sketch sites.
    pub fn num_sites(&self) -> usize {
        self.layers.iter().filter(|l| l.sketchable()).count()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    /// Number of parameter tensors (the optimizer slot count).
    pub fn num_slots(&self) -> usize {
        self.layers.iter().map(|l| l.params().len()).sum()
    }

    /// Allocate every arena one training step needs for `batch × in_dim`
    /// inputs: activations, gradients and caches per depth
    /// ([`Layer::out_dim`] / [`Layer::cache_shapes`] size them), the
    /// parameter-gradient slots, and the sketch scratch.
    pub fn workspace(&self, batch: usize, in_dim: usize) -> Workspace {
        let mut acts = Vec::with_capacity(self.layers.len());
        let mut caches = Vec::with_capacity(self.layers.len());
        let mut din = in_dim;
        for layer in &self.layers {
            let dout = layer.out_dim(din);
            acts.push(Mat::zeros(batch, dout));
            caches.push(Cache::for_layer(layer.as_ref(), batch, din));
            din = dout;
        }
        let grads = acts.iter().map(|a| Mat::zeros(a.rows, a.cols)).collect();
        let mut slots = Vec::with_capacity(self.num_slots());
        let mut slot_offsets = Vec::with_capacity(self.layers.len() + 1);
        slot_offsets.push(0);
        for layer in &self.layers {
            for p in layer.params() {
                slots.push(vec![0.0f32; p.len()]);
            }
            slot_offsets.push(slots.len());
        }
        // Pre-warm the pack arena: a packed GEMM takes one B panel plus
        // one A panel per worker, each bounded by the largest operand this
        // stack can hand a kernel (activations/gradients or a parameter
        // tensor) plus micro-tile padding. Best-effort — the arena grows
        // on demand — but it makes the *first* step's packing
        // allocation-free too.
        let pack = kernels::PackArena::global();
        let max_act = acts
            .iter()
            .map(|a| a.data.len())
            .max()
            .unwrap_or(0)
            .max(batch * in_dim);
        let max_param = slots.iter().map(|s| s.len()).max().unwrap_or(0);
        let panel = max_act.max(max_param);
        pack.reserve(pool::threads() + 1, panel + panel / 4 + 1024);
        Workspace {
            batch,
            in_dim,
            acts,
            grads,
            caches,
            grad_slots: Grads { slots },
            slot_offsets,
            scratch: SketchScratch::new(),
            pack,
        }
    }

    /// Forward sweep: stream `x` through every layer, writing each output
    /// into `ws.acts[i]`. The final activation is the stack output
    /// ([`Workspace::output`]).
    pub fn forward(&self, x: &Mat, ws: &mut Workspace) {
        assert_eq!(
            (x.rows, x.cols),
            (ws.batch, ws.in_dim),
            "workspace sized for a different input shape"
        );
        for i in 0..self.layers.len() {
            let (prev, cur) = ws.acts.split_at_mut(i);
            let input = if i == 0 { x } else { &prev[i - 1] };
            self.layers[i].forward(input, &mut cur[0], &mut ws.caches[i]);
        }
    }

    /// Reverse sweep under a per-layer `plan` from [`Sequential::plan`],
    /// starting from the loss gradient the caller wrote into
    /// `ws.grads.last()` ([`Workspace::grad_out_mut`]). Parameter
    /// gradients land in `ws.grad_slots`; exact layers consume no
    /// randomness from `rng`. `x` must be the same batch the forward saw.
    pub fn backward(
        &self,
        x: &Mat,
        ws: &mut Workspace,
        plan: &[Option<SiteSketch>],
        rng: &mut Pcg64,
    ) {
        let n = self.layers.len();
        assert_eq!(plan.len(), n, "plan length");
        for i in (0..n).rev() {
            let (slot_start, slot_end) =
                (ws.slot_offsets[i], ws.slot_offsets[i + 1]);
            let (gprev, gcur) = ws.grads.split_at_mut(i);
            let gy: &Mat = &gcur[0];
            let gx = if i > 0 { Some(&mut gprev[i - 1]) } else { None };
            let input = if i == 0 { x } else { &ws.acts[i - 1] };
            let mut ctx = SketchCtx {
                sketch: plan[i].as_ref(),
                rng: &mut *rng,
                scratch: &mut ws.scratch,
            };
            self.layers[i].backward(
                gy,
                input,
                &mut ws.caches[i],
                &mut ctx,
                gx,
                &mut ws.grad_slots.slots[slot_start..slot_end],
            );
        }
    }

    /// Resolve a policy into one decision per *layer* (`None` everywhere
    /// except gated sketch sites).
    pub fn plan(&self, policy: &SketchPolicy) -> Result<Vec<Option<SiteSketch>>> {
        let sites = self.sketch_sites();
        let per_site = policy.resolve(sites.len())?;
        let mut plan: Vec<Option<SiteSketch>> = vec![None; self.layers.len()];
        for (site, layer_idx) in sites.into_iter().enumerate() {
            plan[layer_idx] = per_site[site].clone();
        }
        Ok(plan)
    }

    /// One optimizer update over every parameter tensor, global slot order
    /// (allocation-free: walks [`Layer::visit_params_mut`]).
    pub fn apply_grads(&mut self, opt: &mut Optim, grads: &Grads, lr: f64) {
        let mut slot = 0;
        for layer in &mut self.layers {
            layer.visit_params_mut(&mut |p| {
                opt.update(slot, p, &grads.slots[slot], lr);
                slot += 1;
            });
        }
        debug_assert_eq!(slot, grads.slots.len(), "grad slot count");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::models;

    #[test]
    fn policy_masks_resolve_per_site() {
        let m = SketchPolicy::site_mask("all", 3).unwrap();
        assert_eq!(m, vec![true, true, true]);
        assert_eq!(
            SketchPolicy::site_mask("first", 3).unwrap(),
            vec![true, false, false]
        );
        assert_eq!(
            SketchPolicy::site_mask("last", 3).unwrap(),
            vec![false, false, true]
        );
        assert_eq!(
            SketchPolicy::site_mask("none", 2).unwrap(),
            vec![false, false]
        );
        assert!(SketchPolicy::site_mask("middle", 3).is_err());
        assert!(SketchPolicy::site_mask("first", 0).is_err());
    }

    #[test]
    fn policy_resolves_budget_schedule() {
        let p = SketchPolicy {
            method: "l1".into(),
            budget: 0.5,
            location: "all".into(),
            schedule: Some(vec![0.5, 0.25, 0.1]),
        };
        let r = p.resolve(3).unwrap();
        assert_eq!(r[0].as_ref().unwrap().budget, 0.5);
        assert_eq!(r[1].as_ref().unwrap().budget, 0.25);
        assert_eq!(r[2].as_ref().unwrap().budget, 0.1);
        // wrong length errors with both counts in the message
        let bad = SketchPolicy { schedule: Some(vec![0.5]), ..p.clone() };
        let err = format!("{}", bad.resolve(3).unwrap_err());
        assert!(err.contains("1 entries") && err.contains('3'), "{err}");
    }

    #[test]
    fn baseline_and_masked_sites_resolve_to_exact() {
        let p = SketchPolicy::exact();
        assert!(p.resolve(3).unwrap().iter().all(|s| s.is_none()));
        let p = SketchPolicy {
            method: "l1".into(),
            budget: 0.2,
            location: "last".into(),
            schedule: None,
        };
        let r = p.resolve(3).unwrap();
        assert!(r[0].is_none() && r[1].is_none());
        assert_eq!(r[2].as_ref().unwrap().method, "l1");
    }

    #[test]
    fn unknown_method_is_rejected() {
        let p = SketchPolicy {
            method: "rcs".into(),
            budget: 0.2,
            location: "all".into(),
            schedule: None,
        };
        assert!(p.resolve(2).is_err());
    }

    #[test]
    fn mlp_stack_counts_sites_and_slots() {
        let m = models::mlp(&[5, 4, 3], 0);
        assert_eq!(m.num_layers(), 3); // lin relu lin (relu only between)
        assert_eq!(m.sketch_sites(), vec![0, 2]);
        assert_eq!(m.num_sites(), 2);
        assert_eq!(m.num_slots(), 4);
        assert_eq!(m.num_params(), 5 * 4 + 4 + 4 * 3 + 3);
    }

    #[test]
    fn workspace_arenas_match_layer_shapes() {
        let m = models::mlp(&[5, 4, 3], 0);
        let ws = m.workspace(6, 5);
        assert_eq!(ws.acts.len(), 3);
        assert_eq!((ws.acts[0].rows, ws.acts[0].cols), (6, 4));
        assert_eq!((ws.acts[2].rows, ws.acts[2].cols), (6, 3));
        for (a, g) in ws.acts.iter().zip(&ws.grads) {
            assert_eq!((a.rows, a.cols), (g.rows, g.cols));
        }
        assert_eq!(ws.grad_slots.slots.len(), m.num_slots());
        assert_eq!(ws.grad_slots.slots[0].len(), 5 * 4);
        assert_eq!(ws.grad_slots.slots[1].len(), 4);
    }

    #[test]
    fn workspace_steps_are_reusable_and_deterministic() {
        use crate::native::loss::{loss_and_grad_into, LossKind};
        use crate::rng::Pcg64;
        use crate::tensor::Mat;
        let m = models::mlp(&[4, 6, 3], 5);
        let mut rng = Pcg64::new(6, 0);
        let x = Mat::from_fn(5, 4, |_, _| rng.gaussian() as f32);
        let y = vec![0i32, 1, 2, 0, 1];
        let plan = m
            .plan(&SketchPolicy {
                method: "l1".into(),
                budget: 0.4,
                location: "all".into(),
                schedule: None,
            })
            .unwrap();
        let run = |ws: &mut Workspace| {
            m.forward(&x, ws);
            loss_and_grad_into(
                LossKind::CrossEntropy,
                ws.acts.last().unwrap(),
                &y,
                ws.grads.last_mut().unwrap(),
            );
            let mut rng = Pcg64::new(77, 0);
            m.backward(&x, ws, &plan, &mut rng);
            ws.grad_slots.flatten()
        };
        let mut ws = m.workspace(5, 4);
        let first = run(&mut ws);
        // second pass through the SAME (now dirty) workspace must agree —
        // every buffer is fully overwritten, never accumulated into
        let second = run(&mut ws);
        assert_eq!(first, second);
        // and a fresh workspace agrees too
        let mut ws2 = m.workspace(5, 4);
        assert_eq!(first, run(&mut ws2));
    }

    #[test]
    fn masked_off_layers_consume_no_rng() {
        use crate::native::loss::{loss_and_grad_into, LossKind};
        use crate::rng::Pcg64;
        use crate::tensor::Mat;
        let m = models::mlp(&[4, 6, 3], 5);
        let mut rng = Pcg64::new(6, 0);
        let x = Mat::from_fn(5, 4, |_, _| rng.gaussian() as f32);
        let y = vec![0i32, 1, 2, 0, 1];
        let masked = SketchPolicy {
            method: "l1".into(),
            budget: 0.3,
            location: "none".into(),
            schedule: None,
        };
        let grads_under = |policy: &SketchPolicy, rng: &mut Pcg64| {
            let mut ws = m.workspace(5, 4);
            m.forward(&x, &mut ws);
            loss_and_grad_into(
                LossKind::CrossEntropy,
                ws.acts.last().unwrap(),
                &y,
                ws.grads.last_mut().unwrap(),
            );
            m.backward(&x, &mut ws, &m.plan(policy).unwrap(), rng);
            ws.grad_slots.flatten()
        };
        let mut r1 = Pcg64::new(77, 0);
        let g1 = grads_under(&masked, &mut r1);
        let mut r2 = Pcg64::new(77, 0);
        let g2 = grads_under(&SketchPolicy::exact(), &mut r2);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-5);
        }
        // and the rng stream was untouched by the masked run
        assert_eq!(r1.next_u64(), Pcg64::new(77, 0).next_u64());
    }
}
