//! Versioned binary checkpoints: the artifact training leaves behind and
//! serving loads (DESIGN.md §7.5).
//!
//! A checkpoint is the flat parameter registry serialized in global slot
//! order — exactly the tensors [`Layer::params`] exposes, in the order
//! [`Sequential`] walks them — behind a small self-describing header. The
//! format is endian-explicit (every integer and float is little-endian on
//! the wire via `to_le_bytes`/`from_le_bytes`, so files move between
//! hosts) and versioned (readers reject formats they don't speak instead
//! of misparsing them). Layout, all offsets in bytes:
//!
//! | field        | size | contents                                      |
//! |--------------|------|-----------------------------------------------|
//! | magic        | 8    | `b"UAVJPCKP"`                                 |
//! | version      | 4    | u32, currently [`CKPT_VERSION`]               |
//! | key length   | 4    | u32 `n`, length of the registry key           |
//! | registry key | n    | UTF-8 model name ([`models::REGISTRY`])       |
//! | seed         | 8    | u64 init seed the architecture was built with |
//! | arch digest  | 8    | u64 FNV-1a over key + slot count + slot lens  |
//! | slot count   | 4    | u32 number of parameter tensors               |
//! | slots        | —    | per slot: u64 length, then `len` f32 values   |
//! | checksum     | 8    | u64 FNV-1a over every preceding byte          |
//!
//! Loading re-parses defensively and returns a typed [`CkptError`] (never
//! a panic) for every failure class: short or oversized files, foreign
//! magic, unknown versions, payload corruption (trailing checksum), a
//! registry key this build doesn't know, or an architecture drift between
//! writer and reader (the digest pins the slot-length vector, so a model
//! whose code changed shape since the save is rejected instead of
//! silently misloaded). Round-tripping is bit-exact: `f32` bits pass
//! through `to_le_bytes`/`from_le_bytes` unchanged (NaN payloads
//! included), so a loaded model's forward is bitwise identical to the
//! trainer's in-process eval (`tests/checkpoint.rs` pins this for every
//! registry model × kernel kind).
//!
//! To add a header field: append it to the layout *after* `arch digest`
//! (readers locate slots via the cursor, not fixed offsets), bump
//! [`CKPT_VERSION`], and teach [`load_bytes`] both versions — old readers
//! then reject new files loudly ([`CkptError::UnsupportedVersion`])
//! instead of misreading them.

use std::path::Path;

use super::layer::Layer;
use super::models;
use super::sequential::Sequential;

/// File magic: the first 8 bytes of every checkpoint.
pub const CKPT_MAGIC: [u8; 8] = *b"UAVJPCKP";

/// Current wire-format version (see the module docs for the bump recipe).
pub const CKPT_VERSION: u32 = 1;

/// Typed checkpoint failure. Implements [`std::error::Error`], so `?`
/// converts into `anyhow::Result` at CLI call sites while tests match on
/// the precise variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CkptError {
    /// Filesystem failure (path + OS message).
    Io(String),
    /// The file ends before the structure it declares (`need` bytes to
    /// continue parsing, `have` in the file).
    Truncated { need: usize, have: usize },
    /// The first 8 bytes are not [`CKPT_MAGIC`] — not a checkpoint.
    BadMagic,
    /// The file declares a format version this reader doesn't speak.
    UnsupportedVersion { found: u32 },
    /// The registry key is not valid UTF-8.
    BadKey,
    /// Bytes remain after the declared structure + checksum trailer.
    TrailingBytes { extra: usize },
    /// The trailing FNV-1a checksum doesn't match the payload.
    ChecksumMismatch,
    /// The registry key names a model this build doesn't register.
    UnknownModel(String),
    /// The stored arch digest disagrees with the freshly built registry
    /// model — the model code changed shape since the save.
    ArchMismatch { expected: u64, found: u64 },
    /// Slot-count disagreement between file and rebuilt model.
    SlotCount { expected: usize, found: usize },
    /// One slot's length disagrees with the rebuilt model's tensor.
    SlotLen { slot: usize, expected: usize, found: usize },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(msg) => write!(f, "checkpoint io: {msg}"),
            CkptError::Truncated { need, have } => write!(
                f,
                "checkpoint truncated: needs {need} bytes, file has {have}"
            ),
            CkptError::BadMagic => {
                write!(f, "not a checkpoint (bad magic; want {CKPT_MAGIC:?})")
            }
            CkptError::UnsupportedVersion { found } => write!(
                f,
                "checkpoint format v{found} unsupported (this build reads \
                 v{CKPT_VERSION})"
            ),
            CkptError::BadKey => write!(f, "registry key is not UTF-8"),
            CkptError::TrailingBytes { extra } => {
                write!(f, "{extra} unexpected bytes after checkpoint trailer")
            }
            CkptError::ChecksumMismatch => {
                write!(f, "checkpoint corrupt: trailing checksum mismatch")
            }
            CkptError::UnknownModel(name) => {
                write!(f, "checkpoint is for unregistered model {name:?}")
            }
            CkptError::ArchMismatch { expected, found } => write!(
                f,
                "architecture drift: registry model digest {expected:#x} != \
                 stored {found:#x}"
            ),
            CkptError::SlotCount { expected, found } => write!(
                f,
                "slot count mismatch: model has {expected}, file has {found}"
            ),
            CkptError::SlotLen { slot, expected, found } => write!(
                f,
                "slot {slot} length mismatch: model wants {expected}, file \
                 has {found}"
            ),
        }
    }
}

impl std::error::Error for CkptError {}

/// FNV-1a 64-bit hash — the checkpoint's arch digest and trailer
/// checksum. Public so tests can re-stamp a deliberately altered payload.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Digest pinning the writer's architecture: the registry key plus the
/// slot-length vector (count and each length as 8 LE bytes). Any change
/// to a registered model's parameter shapes changes this.
pub fn arch_digest(model_name: &str, slot_lens: &[usize]) -> u64 {
    let mut bytes = Vec::with_capacity(model_name.len() + 8 * (slot_lens.len() + 1));
    bytes.extend_from_slice(model_name.as_bytes());
    bytes.extend_from_slice(&(slot_lens.len() as u64).to_le_bytes());
    for &len in slot_lens {
        bytes.extend_from_slice(&(len as u64).to_le_bytes());
    }
    fnv1a(&bytes)
}

/// A parsed checkpoint: everything needed to rebuild the model in a fresh
/// process ([`Checkpoint::build_model`]).
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Registry key the architecture is rebuilt from.
    pub model_name: String,
    /// Init seed the writer built the architecture with (loaded params
    /// overwrite the init, so this only has to rebuild the same shapes).
    pub seed: u64,
    /// The stored arch digest, verified against the rebuilt model.
    pub arch_digest: u64,
    /// Flat parameter tensors, global slot order.
    pub slots: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Rebuild the registry model and fill its parameters from the slots,
    /// in global slot order through [`Layer::params_mut`]. Verifies the
    /// registry key, the arch digest, and every slot shape.
    pub fn build_model(&self) -> Result<Sequential, CkptError> {
        if !models::is_supported(&self.model_name) {
            return Err(CkptError::UnknownModel(self.model_name.clone()));
        }
        let mut model = models::build(&self.model_name, self.seed)
            .map_err(|_| CkptError::UnknownModel(self.model_name.clone()))?;
        let lens: Vec<usize> = model
            .layers
            .iter()
            .flat_map(|l| l.params())
            .map(|p| p.len())
            .collect();
        let expected = arch_digest(&self.model_name, &lens);
        if expected != self.arch_digest {
            return Err(CkptError::ArchMismatch {
                expected,
                found: self.arch_digest,
            });
        }
        if lens.len() != self.slots.len() {
            return Err(CkptError::SlotCount {
                expected: lens.len(),
                found: self.slots.len(),
            });
        }
        let mut slot = 0usize;
        for layer in &mut model.layers {
            for p in layer.params_mut() {
                let src = &self.slots[slot];
                if src.len() != p.len() {
                    return Err(CkptError::SlotLen {
                        slot,
                        expected: p.len(),
                        found: src.len(),
                    });
                }
                p.copy_from_slice(src);
                slot += 1;
            }
        }
        Ok(model)
    }
}

/// Serialize a model's flat parameter registry (see the module docs for
/// the layout). `model_name` must be the registry key that rebuilds this
/// architecture at `seed`.
pub fn save_bytes(model_name: &str, seed: u64, model: &Sequential) -> Vec<u8> {
    let slots: Vec<&[f32]> =
        model.layers.iter().flat_map(|l| l.params()).collect();
    let payload: usize = slots.iter().map(|s| 8 + 4 * s.len()).sum();
    let mut out = Vec::with_capacity(44 + model_name.len() + payload);
    out.extend_from_slice(&CKPT_MAGIC);
    out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
    out.extend_from_slice(&(model_name.len() as u32).to_le_bytes());
    out.extend_from_slice(model_name.as_bytes());
    out.extend_from_slice(&seed.to_le_bytes());
    let lens: Vec<usize> = slots.iter().map(|s| s.len()).collect();
    out.extend_from_slice(&arch_digest(model_name, &lens).to_le_bytes());
    out.extend_from_slice(&(slots.len() as u32).to_le_bytes());
    for s in &slots {
        out.extend_from_slice(&(s.len() as u64).to_le_bytes());
        for v in s.iter() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Bounds-checked little-endian reader over a checkpoint byte buffer.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CkptError> {
        let end = self.pos.checked_add(n).ok_or(CkptError::Truncated {
            need: usize::MAX,
            have: self.buf.len(),
        })?;
        if end > self.buf.len() {
            return Err(CkptError::Truncated { need: end, have: self.buf.len() });
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32, CkptError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, CkptError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Parse checkpoint bytes. Check order: magic, version, structure
/// (bounds-checked field by field), trailer presence, then the checksum
/// over the whole body — so a version bump reads as
/// [`CkptError::UnsupportedVersion`], a cut-off file as
/// [`CkptError::Truncated`], and a flipped payload byte as
/// [`CkptError::ChecksumMismatch`].
pub fn load_bytes(buf: &[u8]) -> Result<Checkpoint, CkptError> {
    let mut cur = Cursor { buf, pos: 0 };
    if cur.take(8)? != CKPT_MAGIC {
        return Err(CkptError::BadMagic);
    }
    let version = cur.u32()?;
    if version != CKPT_VERSION {
        return Err(CkptError::UnsupportedVersion { found: version });
    }
    let key_len = cur.u32()? as usize;
    let model_name = std::str::from_utf8(cur.take(key_len)?)
        .map_err(|_| CkptError::BadKey)?
        .to_string();
    let seed = cur.u64()?;
    let arch = cur.u64()?;
    let slot_count = cur.u32()? as usize;
    let mut slots = Vec::with_capacity(slot_count.min(1 << 16));
    for _ in 0..slot_count {
        let len = usize::try_from(cur.u64()?).map_err(|_| {
            CkptError::Truncated { need: usize::MAX, have: buf.len() }
        })?;
        let nbytes =
            len.checked_mul(4).ok_or(CkptError::Truncated {
                need: usize::MAX,
                have: buf.len(),
            })?;
        let raw = cur.take(nbytes)?;
        let mut slot = Vec::with_capacity(len);
        for chunk in raw.chunks_exact(4) {
            slot.push(f32::from_le_bytes(chunk.try_into().expect("4 bytes")));
        }
        slots.push(slot);
    }
    match cur.remaining() {
        8 => {}
        r if r < 8 => {
            return Err(CkptError::Truncated {
                need: cur.pos + 8,
                have: buf.len(),
            })
        }
        r => return Err(CkptError::TrailingBytes { extra: r - 8 }),
    }
    let stored = u64::from_le_bytes(
        buf[buf.len() - 8..].try_into().expect("8 bytes"),
    );
    if fnv1a(&buf[..buf.len() - 8]) != stored {
        return Err(CkptError::ChecksumMismatch);
    }
    Ok(Checkpoint { model_name, seed, arch_digest: arch, slots })
}

/// Serialize to a file. See [`save_bytes`].
pub fn save(
    path: &Path,
    model_name: &str,
    seed: u64,
    model: &Sequential,
) -> Result<(), CkptError> {
    std::fs::write(path, save_bytes(model_name, seed, model))
        .map_err(|e| CkptError::Io(format!("{}: {e}", path.display())))
}

/// Read + parse a checkpoint file. See [`load_bytes`].
pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
    let bytes = std::fs::read(path)
        .map_err(|e| CkptError::Io(format!("{}: {e}", path.display())))?;
    load_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_tracks_name_and_shapes() {
        let a = arch_digest("mlp", &[10, 4]);
        assert_eq!(a, arch_digest("mlp", &[10, 4]));
        assert_ne!(a, arch_digest("vit", &[10, 4]));
        assert_ne!(a, arch_digest("mlp", &[10, 5]));
        assert_ne!(a, arch_digest("mlp", &[10, 4, 0]));
    }

    #[test]
    fn header_errors_are_typed() {
        assert_eq!(
            load_bytes(&[1, 2, 3]).unwrap_err(),
            CkptError::Truncated { need: 8, have: 3 }
        );
        assert_eq!(load_bytes(&[0u8; 16]).unwrap_err(), CkptError::BadMagic);
        let mut buf = Vec::new();
        buf.extend_from_slice(&CKPT_MAGIC);
        buf.extend_from_slice(&7u32.to_le_bytes());
        assert_eq!(
            load_bytes(&buf).unwrap_err(),
            CkptError::UnsupportedVersion { found: 7 }
        );
    }

    #[test]
    fn bytes_roundtrip_bit_exact() {
        let model = models::build("mlp", 3).unwrap();
        let bytes = save_bytes("mlp", 3, &model);
        let ckpt = load_bytes(&bytes).unwrap();
        assert_eq!(ckpt.model_name, "mlp");
        assert_eq!(ckpt.seed, 3);
        let flat: Vec<&[f32]> =
            model.layers.iter().flat_map(|l| l.params()).collect();
        assert_eq!(ckpt.slots.len(), flat.len());
        for (a, b) in ckpt.slots.iter().zip(&flat) {
            assert_eq!(a.as_slice(), *b);
        }
        let rebuilt = ckpt.build_model().unwrap();
        let flat2: Vec<&[f32]> =
            rebuilt.layers.iter().flat_map(|l| l.params()).collect();
        for (a, b) in flat.iter().zip(&flat2) {
            assert_eq!(*a, *b);
        }
    }

    #[test]
    fn corruption_and_mismatches_are_typed() {
        let model = models::build("mlp", 0).unwrap();
        let good = save_bytes("mlp", 0, &model);
        // flipped payload byte → checksum
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert_eq!(load_bytes(&bad).unwrap_err(), CkptError::ChecksumMismatch);
        // truncated mid-slot
        let cut = &good[..good.len() - 20];
        assert!(matches!(
            load_bytes(cut).unwrap_err(),
            CkptError::Truncated { .. }
        ));
        // key for an unregistered model
        let ckpt = load_bytes(&save_bytes("resnet", 0, &model)).unwrap();
        assert_eq!(
            ckpt.build_model().unwrap_err(),
            CkptError::UnknownModel("resnet".into())
        );
        // registered key over the wrong architecture → digest drift
        let ckpt = load_bytes(&save_bytes("bagnet", 0, &model)).unwrap();
        assert!(matches!(
            ckpt.build_model().unwrap_err(),
            CkptError::ArchMismatch { .. }
        ));
    }
}
