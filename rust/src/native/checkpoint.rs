//! Versioned binary checkpoints: the artifact training leaves behind and
//! serving loads (DESIGN.md §7.5).
//!
//! A checkpoint is the flat parameter registry serialized in global slot
//! order — exactly the tensors [`Layer::params`] exposes, in the order
//! [`Sequential`] walks them — behind a small self-describing header. The
//! format is endian-explicit (every integer and float is little-endian on
//! the wire via `to_le_bytes`/`from_le_bytes`, so files move between
//! hosts) and versioned (readers reject formats they don't speak instead
//! of misparsing them). Layout, all offsets in bytes:
//!
//! | field        | size | contents                                      |
//! |--------------|------|-----------------------------------------------|
//! | magic        | 8    | `b"UAVJPCKP"`                                 |
//! | version      | 4    | u32, currently [`CKPT_VERSION`]               |
//! | key length   | 4    | u32 `n`, length of the registry key           |
//! | registry key | n    | UTF-8 model name ([`models::REGISTRY`])       |
//! | seed         | 8    | u64 init seed the architecture was built with |
//! | arch digest  | 8    | u64 FNV-1a over key + slot count + slot lens  |
//! | slot count   | 4    | u32 number of parameter tensors               |
//! | slots        | —    | per slot: u64 length, then `len` f32 values   |
//! | checksum     | 8    | u64 FNV-1a over every preceding byte          |
//!
//! **Version 2** (what `--ckpt-every` and the trainer's save hook write)
//! inserts a [`TrainState`] block between `arch digest` and `slot count`
//! — everything `train --resume` needs to continue bit-identically:
//!
//! | field             | size | contents                                  |
//! |-------------------|------|-------------------------------------------|
//! | step              | 8    | u64 steps already executed                |
//! | steps skipped     | 8    | u64 non-finite-gradient skips so far      |
//! | consecutive skips | 4    | u32 current skip streak                   |
//! | optimizer kind    | 1    | u8: 0 = sgd, 1 = momentum, 2 = adam       |
//! | opt t             | —    | u32 count, then count f64-bit u64 values  |
//! | opt m             | —    | slot-vec (sgd/momentum velocity, adam m)  |
//! | opt v             | —    | slot-vec (adam v; empty for sgd)          |
//! | sk / act / fault  | 96   | 3 × 4 u64 raw PCG64 words per stream      |
//! | lane count        | 1    | u8: 0 (plain) or 8 (replicated)           |
//! | lane streams      | —    | per lane: sk + act raw words (8 u64)      |
//!
//! where *slot-vec* is a u32 count followed by per-entry u64 length +
//! f32 values. [`save_bytes`] still emits version 1 (param-only, what
//! `serve` needs), so pre-existing artifacts stay bit-identical; version
//! 1 files load with `train: None`.
//!
//! Loading re-parses defensively and returns a typed [`CkptError`] (never
//! a panic) for every failure class: short or oversized files, foreign
//! magic, unknown versions, payload corruption (trailing checksum), a
//! registry key this build doesn't know, or an architecture drift between
//! writer and reader (the digest pins the slot-length vector, so a model
//! whose code changed shape since the save is rejected instead of
//! silently misloaded). Round-tripping is bit-exact: `f32` bits pass
//! through `to_le_bytes`/`from_le_bytes` unchanged (NaN payloads
//! included), so a loaded model's forward is bitwise identical to the
//! trainer's in-process eval (`tests/checkpoint.rs` pins this for every
//! registry model × kernel kind).
//!
//! To add a header field: append it to the layout *after* `arch digest`
//! (readers locate slots via the cursor, not fixed offsets), bump
//! [`CKPT_VERSION`], and teach [`load_bytes`] both versions — old readers
//! then reject new files loudly ([`CkptError::UnsupportedVersion`])
//! instead of misreading them.

use std::path::{Path, PathBuf};

use super::layer::Layer;
use super::models;
use super::sequential::Sequential;

/// File magic: the first 8 bytes of every checkpoint.
pub const CKPT_MAGIC: [u8; 8] = *b"UAVJPCKP";

/// Current wire-format version (see the module docs for the bump recipe).
/// Readers speak every version in `1..=CKPT_VERSION`.
pub const CKPT_VERSION: u32 = 2;

/// Typed checkpoint failure. Implements [`std::error::Error`], so `?`
/// converts into `anyhow::Result` at CLI call sites while tests match on
/// the precise variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CkptError {
    /// Filesystem failure (path + OS message).
    Io(String),
    /// The file ends before the structure it declares (`need` bytes to
    /// continue parsing, `have` in the file).
    Truncated { need: usize, have: usize },
    /// The first 8 bytes are not [`CKPT_MAGIC`] — not a checkpoint.
    BadMagic,
    /// The file declares a format version this reader doesn't speak.
    UnsupportedVersion { found: u32 },
    /// The registry key is not valid UTF-8.
    BadKey,
    /// Bytes remain after the declared structure + checksum trailer.
    TrailingBytes { extra: usize },
    /// The trailing FNV-1a checksum doesn't match the payload.
    ChecksumMismatch,
    /// The registry key names a model this build doesn't register.
    UnknownModel(String),
    /// The stored arch digest disagrees with the freshly built registry
    /// model — the model code changed shape since the save.
    ArchMismatch { expected: u64, found: u64 },
    /// Slot-count disagreement between file and rebuilt model.
    SlotCount { expected: usize, found: usize },
    /// One slot's length disagrees with the rebuilt model's tensor.
    SlotLen { slot: usize, expected: usize, found: usize },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(msg) => write!(f, "checkpoint io: {msg}"),
            CkptError::Truncated { need, have } => write!(
                f,
                "checkpoint truncated: needs {need} bytes, file has {have}"
            ),
            CkptError::BadMagic => {
                write!(f, "not a checkpoint (bad magic; want {CKPT_MAGIC:?})")
            }
            CkptError::UnsupportedVersion { found } => write!(
                f,
                "checkpoint format v{found} unsupported (this build reads \
                 v1..=v{CKPT_VERSION})"
            ),
            CkptError::BadKey => write!(f, "registry key is not UTF-8"),
            CkptError::TrailingBytes { extra } => {
                write!(f, "{extra} unexpected bytes after checkpoint trailer")
            }
            CkptError::ChecksumMismatch => {
                write!(f, "checkpoint corrupt: trailing checksum mismatch")
            }
            CkptError::UnknownModel(name) => {
                write!(f, "checkpoint is for unregistered model {name:?}")
            }
            CkptError::ArchMismatch { expected, found } => write!(
                f,
                "architecture drift: registry model digest {expected:#x} != \
                 stored {found:#x}"
            ),
            CkptError::SlotCount { expected, found } => write!(
                f,
                "slot count mismatch: model has {expected}, file has {found}"
            ),
            CkptError::SlotLen { slot, expected, found } => write!(
                f,
                "slot {slot} length mismatch: model wants {expected}, file \
                 has {found}"
            ),
        }
    }
}

impl std::error::Error for CkptError {}

/// FNV-1a 64-bit hash — the checkpoint's arch digest and trailer
/// checksum. Public so tests can re-stamp a deliberately altered payload.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Digest pinning the writer's architecture: the registry key plus the
/// slot-length vector (count and each length as 8 LE bytes). Any change
/// to a registered model's parameter shapes changes this.
pub fn arch_digest(model_name: &str, slot_lens: &[usize]) -> u64 {
    let mut bytes = Vec::with_capacity(model_name.len() + 8 * (slot_lens.len() + 1));
    bytes.extend_from_slice(model_name.as_bytes());
    bytes.extend_from_slice(&(slot_lens.len() as u64).to_le_bytes());
    for &len in slot_lens {
        bytes.extend_from_slice(&(len as u64).to_le_bytes());
    }
    fnv1a(&bytes)
}

/// Mid-run training state, the version-2 payload: step counters,
/// optimizer slots and the raw PCG64 words of every RNG stream, so
/// `train --resume` continues the interrupted trajectory bit-for-bit
/// (DESIGN.md §7.7). Plain data — the trainer re-validates everything
/// against its own config before applying it.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TrainState {
    /// Steps already executed (resume starts at this step index).
    pub step: u64,
    /// Non-finite-gradient steps skipped so far.
    pub steps_skipped: u64,
    /// Current consecutive-skip streak.
    pub consecutive_skips: u32,
    /// Optimizer kind tag: 0 = sgd, 1 = momentum, 2 = adam.
    pub opt_kind: u8,
    /// Adam per-slot timestep counters (empty for sgd/momentum).
    pub opt_t: Vec<f64>,
    /// First optimizer moment: sgd/momentum velocity, adam `m`.
    pub opt_m: Vec<Vec<f32>>,
    /// Second optimizer moment: adam `v` (empty for sgd/momentum).
    pub opt_v: Vec<Vec<f32>>,
    /// Backward-gate stream ([`crate::rng::Pcg64::state_words`]).
    pub sk: [u64; 4],
    /// Activation-gate stream.
    pub act: [u64; 4],
    /// Fault-injection stream.
    pub fault: [u64; 4],
    /// Per-lane (sk, act) stream pairs; empty for plain runs, one entry
    /// per lane of the fixed 8-lane grid for replicated runs.
    pub lanes: Vec<[[u64; 4]; 2]>,
}

/// A parsed checkpoint: everything needed to rebuild the model in a fresh
/// process ([`Checkpoint::build_model`]).
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Registry key the architecture is rebuilt from.
    pub model_name: String,
    /// Init seed the writer built the architecture with (loaded params
    /// overwrite the init, so this only has to rebuild the same shapes).
    pub seed: u64,
    /// The stored arch digest, verified against the rebuilt model.
    pub arch_digest: u64,
    /// Flat parameter tensors, global slot order.
    pub slots: Vec<Vec<f32>>,
    /// Mid-run training state (version ≥ 2 files only; `build_model`
    /// ignores it, so serving never pays for it).
    pub train: Option<TrainState>,
}

impl Checkpoint {
    /// Rebuild the registry model and fill its parameters from the slots,
    /// in global slot order through [`Layer::params_mut`]. Verifies the
    /// registry key, the arch digest, and every slot shape.
    pub fn build_model(&self) -> Result<Sequential, CkptError> {
        if !models::is_supported(&self.model_name) {
            return Err(CkptError::UnknownModel(self.model_name.clone()));
        }
        let mut model = models::build(&self.model_name, self.seed)
            .map_err(|_| CkptError::UnknownModel(self.model_name.clone()))?;
        let lens: Vec<usize> = model
            .layers
            .iter()
            .flat_map(|l| l.params())
            .map(|p| p.len())
            .collect();
        let expected = arch_digest(&self.model_name, &lens);
        if expected != self.arch_digest {
            return Err(CkptError::ArchMismatch {
                expected,
                found: self.arch_digest,
            });
        }
        if lens.len() != self.slots.len() {
            return Err(CkptError::SlotCount {
                expected: lens.len(),
                found: self.slots.len(),
            });
        }
        let mut slot = 0usize;
        for layer in &mut model.layers {
            for p in layer.params_mut() {
                let src = &self.slots[slot];
                if src.len() != p.len() {
                    return Err(CkptError::SlotLen {
                        slot,
                        expected: p.len(),
                        found: src.len(),
                    });
                }
                p.copy_from_slice(src);
                slot += 1;
            }
        }
        Ok(model)
    }
}

/// Serialize a model's flat parameter registry as a **version 1**
/// (param-only) checkpoint — everything `serve` needs, and bit-identical
/// to what this crate has always written. `model_name` must be the
/// registry key that rebuilds this architecture at `seed`.
pub fn save_bytes(model_name: &str, seed: u64, model: &Sequential) -> Vec<u8> {
    save_impl(model_name, seed, model, None)
}

/// Serialize a **version 2** checkpoint: the parameter registry plus the
/// mid-run [`TrainState`] `train --resume` replays from.
pub fn save_state_bytes(
    model_name: &str,
    seed: u64,
    model: &Sequential,
    train: &TrainState,
) -> Vec<u8> {
    save_impl(model_name, seed, model, Some(train))
}

/// Append a slot-vec (u32 count, per entry u64 length + f32 LE values).
fn put_slot_vec(out: &mut Vec<u8>, slots: &[Vec<f32>]) {
    out.extend_from_slice(&(slots.len() as u32).to_le_bytes());
    for s in slots {
        out.extend_from_slice(&(s.len() as u64).to_le_bytes());
        for v in s {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Append raw PCG64 words.
fn put_pcg(out: &mut Vec<u8>, words: &[u64; 4]) {
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

fn save_impl(
    model_name: &str,
    seed: u64,
    model: &Sequential,
    train: Option<&TrainState>,
) -> Vec<u8> {
    let slots: Vec<&[f32]> =
        model.layers.iter().flat_map(|l| l.params()).collect();
    let payload: usize = slots.iter().map(|s| 8 + 4 * s.len()).sum();
    let version: u32 = if train.is_some() { 2 } else { 1 };
    let mut out = Vec::with_capacity(44 + model_name.len() + payload);
    out.extend_from_slice(&CKPT_MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(model_name.len() as u32).to_le_bytes());
    out.extend_from_slice(model_name.as_bytes());
    out.extend_from_slice(&seed.to_le_bytes());
    let lens: Vec<usize> = slots.iter().map(|s| s.len()).collect();
    out.extend_from_slice(&arch_digest(model_name, &lens).to_le_bytes());
    if let Some(t) = train {
        out.extend_from_slice(&t.step.to_le_bytes());
        out.extend_from_slice(&t.steps_skipped.to_le_bytes());
        out.extend_from_slice(&t.consecutive_skips.to_le_bytes());
        out.push(t.opt_kind);
        out.extend_from_slice(&(t.opt_t.len() as u32).to_le_bytes());
        for v in &t.opt_t {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        put_slot_vec(&mut out, &t.opt_m);
        put_slot_vec(&mut out, &t.opt_v);
        put_pcg(&mut out, &t.sk);
        put_pcg(&mut out, &t.act);
        put_pcg(&mut out, &t.fault);
        out.push(t.lanes.len() as u8);
        for lane in &t.lanes {
            put_pcg(&mut out, &lane[0]);
            put_pcg(&mut out, &lane[1]);
        }
    }
    out.extend_from_slice(&(slots.len() as u32).to_le_bytes());
    for s in &slots {
        out.extend_from_slice(&(s.len() as u64).to_le_bytes());
        for v in s.iter() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Bounds-checked little-endian reader over a checkpoint byte buffer.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CkptError> {
        let end = self.pos.checked_add(n).ok_or(CkptError::Truncated {
            need: usize::MAX,
            have: self.buf.len(),
        })?;
        if end > self.buf.len() {
            return Err(CkptError::Truncated { need: end, have: self.buf.len() });
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, CkptError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CkptError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, CkptError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn pcg(&mut self) -> Result<[u64; 4], CkptError> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }

    /// One f32 slot: u64 length then the values.
    fn slot(&mut self) -> Result<Vec<f32>, CkptError> {
        let len = usize::try_from(self.u64()?).map_err(|_| {
            CkptError::Truncated { need: usize::MAX, have: self.buf.len() }
        })?;
        let nbytes = len.checked_mul(4).ok_or(CkptError::Truncated {
            need: usize::MAX,
            have: self.buf.len(),
        })?;
        let raw = self.take(nbytes)?;
        let mut out = Vec::with_capacity(len);
        for chunk in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().expect("4 bytes")));
        }
        Ok(out)
    }

    /// A slot-vec: u32 count then that many slots.
    fn slot_vec(&mut self) -> Result<Vec<Vec<f32>>, CkptError> {
        let count = self.u32()? as usize;
        let mut out = Vec::with_capacity(count.min(1 << 16));
        for _ in 0..count {
            out.push(self.slot()?);
        }
        Ok(out)
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Parse checkpoint bytes. Check order: magic, version, structure
/// (bounds-checked field by field), trailer presence, then the checksum
/// over the whole body — so a version bump reads as
/// [`CkptError::UnsupportedVersion`], a cut-off file as
/// [`CkptError::Truncated`], and a flipped payload byte as
/// [`CkptError::ChecksumMismatch`].
pub fn load_bytes(buf: &[u8]) -> Result<Checkpoint, CkptError> {
    let mut cur = Cursor { buf, pos: 0 };
    if cur.take(8)? != CKPT_MAGIC {
        return Err(CkptError::BadMagic);
    }
    let version = cur.u32()?;
    if !(1..=CKPT_VERSION).contains(&version) {
        return Err(CkptError::UnsupportedVersion { found: version });
    }
    let key_len = cur.u32()? as usize;
    let model_name = std::str::from_utf8(cur.take(key_len)?)
        .map_err(|_| CkptError::BadKey)?
        .to_string();
    let seed = cur.u64()?;
    let arch = cur.u64()?;
    let train = if version >= 2 {
        let step = cur.u64()?;
        let steps_skipped = cur.u64()?;
        let consecutive_skips = cur.u32()?;
        let opt_kind = cur.u8()?;
        let t_count = cur.u32()? as usize;
        let mut opt_t = Vec::with_capacity(t_count.min(1 << 16));
        for _ in 0..t_count {
            opt_t.push(f64::from_bits(cur.u64()?));
        }
        let opt_m = cur.slot_vec()?;
        let opt_v = cur.slot_vec()?;
        let sk = cur.pcg()?;
        let act = cur.pcg()?;
        let fault = cur.pcg()?;
        let lane_count = cur.u8()? as usize;
        let mut lanes = Vec::with_capacity(lane_count);
        for _ in 0..lane_count {
            lanes.push([cur.pcg()?, cur.pcg()?]);
        }
        Some(TrainState {
            step,
            steps_skipped,
            consecutive_skips,
            opt_kind,
            opt_t,
            opt_m,
            opt_v,
            sk,
            act,
            fault,
            lanes,
        })
    } else {
        None
    };
    let slot_count = cur.u32()? as usize;
    let mut slots = Vec::with_capacity(slot_count.min(1 << 16));
    for _ in 0..slot_count {
        slots.push(cur.slot()?);
    }
    match cur.remaining() {
        8 => {}
        r if r < 8 => {
            return Err(CkptError::Truncated {
                need: cur.pos + 8,
                have: buf.len(),
            })
        }
        r => return Err(CkptError::TrailingBytes { extra: r - 8 }),
    }
    let stored = u64::from_le_bytes(
        buf[buf.len() - 8..].try_into().expect("8 bytes"),
    );
    if fnv1a(&buf[..buf.len() - 8]) != stored {
        return Err(CkptError::ChecksumMismatch);
    }
    Ok(Checkpoint { model_name, seed, arch_digest: arch, slots, train })
}

/// The sibling staging path atomic writes go through: `<path>.tmp`.
/// Public so fault injection can tear a write at exactly the real
/// staging location.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Atomic file write: stage the full payload at [`tmp_path`], then
/// rename over `path`. A kill mid-write leaves at worst a stale `.tmp`
/// next to the previous checkpoint, never a torn checkpoint
/// (`tests/checkpoint.rs` pins this).
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), CkptError> {
    let tmp = tmp_path(path);
    std::fs::write(&tmp, bytes)
        .map_err(|e| CkptError::Io(format!("{}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| CkptError::Io(format!("{}: {e}", path.display())))
}

/// Serialize to a file (version 1, atomic write). See [`save_bytes`].
pub fn save(
    path: &Path,
    model_name: &str,
    seed: u64,
    model: &Sequential,
) -> Result<(), CkptError> {
    write_atomic(path, &save_bytes(model_name, seed, model))
}

/// Serialize a resumable checkpoint to a file (version 2, atomic
/// write). See [`save_state_bytes`].
pub fn save_with_state(
    path: &Path,
    model_name: &str,
    seed: u64,
    model: &Sequential,
    train: &TrainState,
) -> Result<(), CkptError> {
    write_atomic(path, &save_state_bytes(model_name, seed, model, train))
}

/// Read + parse a checkpoint file. See [`load_bytes`].
pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
    let bytes = std::fs::read(path)
        .map_err(|e| CkptError::Io(format!("{}: {e}", path.display())))?;
    load_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_tracks_name_and_shapes() {
        let a = arch_digest("mlp", &[10, 4]);
        assert_eq!(a, arch_digest("mlp", &[10, 4]));
        assert_ne!(a, arch_digest("vit", &[10, 4]));
        assert_ne!(a, arch_digest("mlp", &[10, 5]));
        assert_ne!(a, arch_digest("mlp", &[10, 4, 0]));
    }

    #[test]
    fn header_errors_are_typed() {
        assert_eq!(
            load_bytes(&[1, 2, 3]).unwrap_err(),
            CkptError::Truncated { need: 8, have: 3 }
        );
        assert_eq!(load_bytes(&[0u8; 16]).unwrap_err(), CkptError::BadMagic);
        let mut buf = Vec::new();
        buf.extend_from_slice(&CKPT_MAGIC);
        buf.extend_from_slice(&7u32.to_le_bytes());
        assert_eq!(
            load_bytes(&buf).unwrap_err(),
            CkptError::UnsupportedVersion { found: 7 }
        );
    }

    #[test]
    fn bytes_roundtrip_bit_exact() {
        let model = models::build("mlp", 3).unwrap();
        let bytes = save_bytes("mlp", 3, &model);
        let ckpt = load_bytes(&bytes).unwrap();
        assert_eq!(ckpt.model_name, "mlp");
        assert_eq!(ckpt.seed, 3);
        let flat: Vec<&[f32]> =
            model.layers.iter().flat_map(|l| l.params()).collect();
        assert_eq!(ckpt.slots.len(), flat.len());
        for (a, b) in ckpt.slots.iter().zip(&flat) {
            assert_eq!(a.as_slice(), *b);
        }
        let rebuilt = ckpt.build_model().unwrap();
        let flat2: Vec<&[f32]> =
            rebuilt.layers.iter().flat_map(|l| l.params()).collect();
        for (a, b) in flat.iter().zip(&flat2) {
            assert_eq!(*a, *b);
        }
    }

    #[test]
    fn v2_train_state_roundtrips_and_v1_loads_without_it() {
        let model = models::build("mlp", 3).unwrap();
        let state = TrainState {
            step: 41,
            steps_skipped: 2,
            consecutive_skips: 1,
            opt_kind: 2,
            opt_t: vec![40.0, 41.0],
            opt_m: vec![vec![0.5f32, -1.25], vec![f32::MIN_POSITIVE]],
            opt_v: vec![vec![2.0f32], vec![]],
            sk: [1, 2, 3, 4],
            act: [5, 6, 7, 8],
            fault: [9, 10, 11, 12],
            lanes: vec![[[13, 14, 15, 16], [17, 18, 19, 20]]; 8],
        };
        let bytes = save_state_bytes("mlp", 3, &model, &state);
        assert_eq!(bytes[8..12], 2u32.to_le_bytes());
        let ckpt = load_bytes(&bytes).unwrap();
        assert_eq!(ckpt.train.as_ref(), Some(&state));
        // the train block is transparent to serving: params round-trip
        // and the model rebuilds exactly as from a v1 file
        let v1 = load_bytes(&save_bytes("mlp", 3, &model)).unwrap();
        assert!(v1.train.is_none());
        assert_eq!(ckpt.slots, v1.slots);
        ckpt.build_model().unwrap();
        // a flipped byte inside the train block still trips the checksum
        let mut bad = bytes.clone();
        bad[40] ^= 0x10;
        assert_eq!(load_bytes(&bad).unwrap_err(), CkptError::ChecksumMismatch);
    }

    #[test]
    fn corruption_and_mismatches_are_typed() {
        let model = models::build("mlp", 0).unwrap();
        let good = save_bytes("mlp", 0, &model);
        // flipped payload byte → checksum
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert_eq!(load_bytes(&bad).unwrap_err(), CkptError::ChecksumMismatch);
        // truncated mid-slot
        let cut = &good[..good.len() - 20];
        assert!(matches!(
            load_bytes(cut).unwrap_err(),
            CkptError::Truncated { .. }
        ));
        // key for an unregistered model
        let ckpt = load_bytes(&save_bytes("resnet", 0, &model)).unwrap();
        assert_eq!(
            ckpt.build_model().unwrap_err(),
            CkptError::UnknownModel("resnet".into())
        );
        // registered key over the wrong architecture → digest drift
        let ckpt = load_bytes(&save_bytes("bagnet", 0, &model)).unwrap();
        assert!(matches!(
            ckpt.build_model().unwrap_err(),
            CkptError::ArchMismatch { .. }
        ));
    }
}
