//! Optimizers for the native trainer: SGD (± momentum) and Adam, plus
//! global-norm gradient clipping — the recipes of §5 / Appendix B.2.
//!
//! The per-slot update loops run through [`crate::tensor::kernels::vec`]:
//! under `--kernel scalar` those helpers replicate the legacy loops
//! bit-for-bit (including the f64 learning-rate products of the SGD
//! paths); under `--kernel simd` they run 8-wide lanes.

use crate::tensor::kernels::vec;

use super::layer::Grads;

/// First-order optimizer with per-slot state (slot = one parameter tensor;
/// the trainer uses `2·layer` for weights and `2·layer + 1` for biases).
pub enum Optim {
    /// SGD; `momentum = 0` is plain gradient descent.
    Sgd {
        /// Momentum coefficient µ (heavy-ball: v ← µv + g, p ← p − lr·v).
        momentum: f64,
        /// Velocity buffers, lazily sized per slot.
        vel: Vec<Vec<f32>>,
    },
    /// Adam with bias correction (weight decay 0).
    Adam {
        /// First-moment decay β₁.
        beta1: f64,
        /// Second-moment decay β₂.
        beta2: f64,
        /// Denominator fuzz ε.
        eps: f64,
        /// Per-slot step counts (bias correction stays right no matter
        /// what order callers update slots in).
        t: Vec<f64>,
        /// First-moment buffers per slot.
        m: Vec<Vec<f32>>,
        /// Second-moment buffers per slot.
        v: Vec<Vec<f32>>,
    },
}

impl Optim {
    /// Plain SGD (the paper's MLP recipe).
    pub fn sgd() -> Optim {
        Optim::Sgd { momentum: 0.0, vel: Vec::new() }
    }

    /// Heavy-ball momentum SGD.
    pub fn momentum(mu: f64) -> Optim {
        Optim::Sgd { momentum: mu, vel: Vec::new() }
    }

    /// Adam with the usual (0.9, 0.999, 1e-8) constants.
    pub fn adam() -> Optim {
        Optim::Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: Vec::new(),
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Parse an optimizer name from the config (`sgd|momentum|adam`).
    pub fn parse(name: &str) -> anyhow::Result<Optim> {
        match name {
            "sgd" => Ok(Optim::sgd()),
            "momentum" => Ok(Optim::momentum(0.9)),
            "adam" | "adamw" => Ok(Optim::adam()),
            other => anyhow::bail!("unknown optimizer {other} (want sgd|momentum|adam)"),
        }
    }

    fn slot_buffer(bufs: &mut Vec<Vec<f32>>, slot: usize, len: usize) -> &mut Vec<f32> {
        if bufs.len() <= slot {
            bufs.resize_with(slot + 1, Vec::new);
        }
        let buf = &mut bufs[slot];
        if buf.len() != len {
            buf.clear();
            buf.resize(len, 0.0);
        }
        buf
    }

    /// Apply one update to the parameter tensor registered at `slot`.
    pub fn update(&mut self, slot: usize, param: &mut [f32], grad: &[f32], lr: f64) {
        assert_eq!(param.len(), grad.len(), "param/grad length mismatch");
        match self {
            Optim::Sgd { momentum, vel } => {
                if *momentum == 0.0 {
                    vec::sgd_step(param, grad, lr);
                } else {
                    let mu = *momentum as f32;
                    let v = Self::slot_buffer(vel, slot, param.len());
                    vec::momentum_step(param, v, grad, mu, lr);
                }
            }
            Optim::Adam { beta1, beta2, eps, t, m, v } => {
                if t.len() <= slot {
                    t.resize(slot + 1, 0.0);
                }
                t[slot] += 1.0;
                let tcur = t[slot];
                let (b1, b2, e) = (*beta1 as f32, *beta2 as f32, *eps as f32);
                let bc1 = (1.0 - beta1.powf(tcur)) as f32;
                let bc2 = (1.0 - beta2.powf(tcur)) as f32;
                let lrf = lr as f32;
                vec::ema(Self::slot_buffer(m, slot, param.len()), grad, b1);
                vec::ema_sq(Self::slot_buffer(v, slot, param.len()), grad, b2);
                vec::adam_apply(param, &m[slot], &v[slot], bc1, bc2, lrf, e);
            }
        }
    }
}

/// Scale `grads` so the global ℓ2 norm is at most `max_norm` (no-op when
/// `max_norm <= 0`). Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut Grads, max_norm: f64) -> f64 {
    let norm = grads.global_norm();
    if max_norm > 0.0 && norm > max_norm {
        grads.scale((max_norm / norm.max(1e-12)) as f32);
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step_is_lr_times_grad() {
        let mut o = Optim::sgd();
        let mut p = vec![1.0f32, 2.0];
        o.update(0, &mut p, &[0.5, -1.0], 0.1);
        assert!((p[0] - 0.95).abs() < 1e-6);
        assert!((p[1] - 2.1).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut o = Optim::momentum(0.5);
        let mut p = vec![0.0f32];
        o.update(0, &mut p, &[1.0], 1.0); // v=1, p=-1
        o.update(0, &mut p, &[1.0], 1.0); // v=1.5, p=-2.5
        assert!((p[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        let mut o = Optim::adam();
        let mut p = vec![0.0f32];
        o.update(0, &mut p, &[3.0], 0.01);
        // bias-corrected first step ≈ lr · sign(g)
        assert!((p[0] + 0.01).abs() < 1e-4, "{}", p[0]);
    }

    #[test]
    fn adam_separate_slots_independent() {
        let mut o = Optim::adam();
        let mut a = vec![0.0f32];
        let mut b = vec![0.0f32, 0.0];
        o.update(0, &mut a, &[1.0], 0.1);
        o.update(1, &mut b, &[1.0, -1.0], 0.1);
        assert!(a[0] < 0.0 && b[0] < 0.0 && b[1] > 0.0);
    }

    #[test]
    fn adam_out_of_order_slots_stay_finite() {
        // per-slot step counts: updating slot 1 before slot 0 must not
        // divide by a zero bias correction
        let mut o = Optim::adam();
        let mut p = vec![0.0f32];
        o.update(1, &mut p, &[2.0], 0.01);
        assert!(p[0].is_finite() && (p[0] + 0.01).abs() < 1e-4, "{}", p[0]);
    }

    #[test]
    fn clip_caps_norm() {
        let mut g = Grads { slots: vec![vec![3.0, 4.0], vec![0.0]] };
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-9);
        assert!((g.global_norm() - 1.0).abs() < 1e-6);
        // under the cap: untouched
        let pre2 = clip_global_norm(&mut g, 10.0);
        assert!((pre2 - 1.0).abs() < 1e-6);
        assert!((g.global_norm() - 1.0).abs() < 1e-6);
    }
}
