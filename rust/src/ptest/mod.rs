//! Mini property-testing harness (`proptest` is not in the offline crate
//! set — DESIGN.md §6).
//!
//! Provides the 80% that matters here: seeded case generation from simple
//! strategies, a fixed case budget, and greedy input shrinking on failure.
//! Used by the coordinator/sketch/pipeline invariant tests.

use crate::rng::Pcg64;

/// A generated case that knows how to shrink itself.
pub trait Shrink: Clone + std::fmt::Debug {
    /// Candidate smaller versions of `self` (tried in order).
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for f64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl Shrink for Vec<f32> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
        }
        if self.iter().any(|&x| x != 0.0) {
            out.push(self.iter().map(|&x| x / 2.0).collect());
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` on `cases` generated inputs; on failure, shrink greedily and
/// panic with the minimal counterexample.
pub fn check<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Shrink,
    G: Fn(&mut Pcg64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = crate::rng::streams::ptest(seed);
    for case_idx in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case_idx}, seed {seed}):\n  input: {:?}\n  error: {}",
                min_input, min_msg
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> Result<(), String>>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    let mut budget = 200;
    'outer: while budget > 0 {
        for cand in cur.shrinks() {
            budget -= 1;
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    (cur, msg)
}

/// Strategy helpers.
pub mod gen {
    use crate::rng::Pcg64;

    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        lo + rng.f64() * (hi - lo)
    }

    pub fn vec_f32(rng: &mut Pcg64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.gaussian() as f32 * scale).collect()
    }

    pub fn vec_f32_pos(rng: &mut Pcg64, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| (rng.gaussian() as f32).abs() + 1e-3)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            1,
            50,
            |rng| {
                let n = gen::usize_in(rng, 1, 20);
                gen::vec_f32_pos(rng, n)
            },
            |v| {
                if v.iter().all(|&x| x > 0.0) {
                    Ok(())
                } else {
                    Err("nonpositive".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        check(
            2,
            50,
            |rng| gen::usize_in(rng, 10, 100),
            |&n| {
                if n < 10 {
                    Ok(())
                } else {
                    Err(format!("{n} too big"))
                }
            },
        );
    }

    #[test]
    fn shrink_reaches_small_vec() {
        let v = vec![1.0f32; 64];
        let (min, _) = shrink_loop(v, "err".into(), &|v: &Vec<f32>| {
            if v.len() >= 4 {
                Err("len>=4".into())
            } else {
                Ok(())
            }
        });
        assert!(min.len() >= 4 && min.len() <= 7, "len {}", min.len());
    }
}
