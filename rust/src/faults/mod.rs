//! Deterministic fault injection (DESIGN.md §7.7).
//!
//! A [`FaultPlan`] is parsed once from `--fault-spec` (or the
//! `UAVJP_FAULTS` env var, the CI hook) and drives every injection point
//! in the stack from its **own** PCG64 stream ([`FaultPlan::stream`]), so
//! a chaos run replays bit-for-bit and never perturbs the training
//! streams: a run whose spec arms no stochastic fault consumes zero
//! fault-stream draws and is byte-identical to a run with no spec at all.
//!
//! Grammar: comma-separated `name@key=value` terms, each kind at most
//! once —
//!
//! | term | injection point |
//! |---|---|
//! | `lane_drop@p=P` | each of the 8 reduce lanes is dropped i.i.d. with probability `P` every step; survivors are `1/(1-P)`-rescaled ([`crate::replicate`]) |
//! | `nan_grad@step=K` | poison the reduced gradient with a NaN at step `K` (one step) |
//! | `nan_grad@from=K` | poison every step ≥ `K` (drives the consecutive-skip bail) |
//! | `ckpt_truncate@step=K` | the periodic checkpoint at step `K` tears mid-write: half the bytes land in `<path>.tmp`, no rename |
//! | `kill@step=K` | the trainer exits with a typed [`InjectedKill`] after executing step `K` (and its periodic save, if scheduled) |
//! | `worker_panic@step=K` | replica 0's step closure panics at step `K` (exercises `catch_unwind` + degraded reduce end to end) |

use crate::replicate::LANES;
use crate::rng::Pcg64;
use anyhow::{bail, Result};
use std::fmt;

/// Typed error for a gradient that stayed non-finite for
/// [`MAX_CONSECUTIVE_SKIPS`] consecutive steps: the trainer skips
/// non-finite updates, but a persistent one means the run has diverged
/// and silent spinning would only burn the step budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NonFiniteLoss {
    /// Step at which the bail triggered.
    pub step: usize,
    /// Consecutive skipped steps at that point.
    pub skips: u32,
}

impl fmt::Display for NonFiniteLoss {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "non-finite gradient for {} consecutive steps (last at step {}): \
             run has diverged",
            self.skips, self.step
        )
    }
}

impl std::error::Error for NonFiniteLoss {}

/// Skipped-step budget before [`NonFiniteLoss`] aborts the run.
pub const MAX_CONSECUTIVE_SKIPS: u32 = 5;

/// Typed error for an injected `kill@step=K`: the trainer stops after
/// step `K` exactly where a real SIGKILL would land (post-step, after
/// any periodic checkpoint), so CI can assert `--resume` reconstructs
/// the uninterrupted trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedKill {
    /// Last step that executed before the kill.
    pub step: usize,
}

impl fmt::Display for InjectedKill {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected kill after step {}", self.step)
    }
}

impl std::error::Error for InjectedKill {}

/// Parsed, validated fault schedule. `Default` is the no-fault plan.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Per-lane i.i.d. drop probability (`0` disarms lane dropout).
    pub lane_drop_p: f64,
    /// Poison the gradient at exactly this step.
    pub nan_grad_step: Option<usize>,
    /// Poison the gradient at every step ≥ this.
    pub nan_grad_from: Option<usize>,
    /// Tear the periodic checkpoint written at this step.
    pub ckpt_truncate_step: Option<usize>,
    /// Bail with [`InjectedKill`] after this step.
    pub kill_step: Option<usize>,
    /// Panic replica 0's worker closure at this step.
    pub worker_panic_step: Option<usize>,
}

impl FaultPlan {
    /// Parse a `--fault-spec` string. Empty spec → the no-fault plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        let mut seen: Vec<&str> = Vec::new();
        for term in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (name, kv) = term.split_once('@').unwrap_or((term, ""));
            let (key, val) = kv.split_once('=').unwrap_or((kv, ""));
            if seen.contains(&name) {
                bail!("fault spec repeats `{name}` (each kind at most once)");
            }
            let step = || -> Result<usize> {
                if key != "step" {
                    bail!("fault `{name}` wants `@step=K`, got `{term}`");
                }
                val.parse().map_err(|_| {
                    anyhow::anyhow!("fault `{name}`: bad step `{val}` in `{term}`")
                })
            };
            match name {
                "lane_drop" => {
                    if key != "p" {
                        bail!("fault `lane_drop` wants `@p=P`, got `{term}`");
                    }
                    let p: f64 = val.parse().map_err(|_| {
                        anyhow::anyhow!("fault `lane_drop`: bad p `{val}`")
                    })?;
                    if !(0.0..1.0).contains(&p) {
                        bail!("fault `lane_drop`: p={p} out of [0,1)");
                    }
                    plan.lane_drop_p = p;
                }
                "nan_grad" => match key {
                    "step" => plan.nan_grad_step = Some(step()?),
                    "from" => {
                        plan.nan_grad_from = Some(val.parse().map_err(|_| {
                            anyhow::anyhow!("fault `nan_grad`: bad from `{val}`")
                        })?)
                    }
                    _ => bail!(
                        "fault `nan_grad` wants `@step=K` or `@from=K`, \
                         got `{term}`"
                    ),
                },
                "ckpt_truncate" => plan.ckpt_truncate_step = Some(step()?),
                "kill" => plan.kill_step = Some(step()?),
                "worker_panic" => plan.worker_panic_step = Some(step()?),
                other => bail!(
                    "unknown fault `{other}` (want \
                     lane_drop@p=|nan_grad@step=|nan_grad@from=|\
                     ckpt_truncate@step=|kill@step=|worker_panic@step=)"
                ),
            }
            seen.push(name);
        }
        Ok(plan)
    }

    /// Resolve a config's `fault_spec`, falling back to the
    /// `UAVJP_FAULTS` env var when the config carries none (the same
    /// idiom `UAVJP_ACTPOLICY` uses for the CI matrix).
    pub fn from_config(spec: &str) -> Result<FaultPlan> {
        let env = std::env::var("UAVJP_FAULTS").ok();
        Self::from_spec_or_env(spec, env.as_deref())
    }

    /// [`FaultPlan::from_config`] with the env value injected — the
    /// testable seam (tests never mutate process-global env).
    pub fn from_spec_or_env(spec: &str, env: Option<&str>) -> Result<FaultPlan> {
        if !spec.is_empty() {
            Self::parse(spec)
        } else {
            Self::parse(env.unwrap_or(""))
        }
    }

    /// The dedicated fault stream: disjoint from every training stream
    /// (gate `seed^0x9e3779b9`, act `seed^0x51ac7`, batch `seed+77`).
    pub fn stream(seed: u64) -> Pcg64 {
        crate::rng::streams::faults(seed)
    }

    /// Whether any fault is armed at all (a disarmed plan lets the
    /// trainer skip the fault bookkeeping entirely).
    pub fn is_armed(&self) -> bool {
        *self != FaultPlan::default()
    }

    /// Inverse inclusion probability for injected lane dropout. Applied
    /// on **every** step while `lane_drop` is armed — rescaling only the
    /// steps that happen to drop a lane would bias the estimator.
    pub fn lane_gain(&self) -> f32 {
        if self.lane_drop_p > 0.0 {
            (1.0 / (1.0 - self.lane_drop_p)) as f32
        } else {
            1.0
        }
    }

    /// Draw this step's lane-drop mask: 8 i.i.d. Bernoulli draws when
    /// armed, **zero** draws when not — so arming an unrelated fault
    /// never shifts the stream.
    pub fn draw_lane_drops(&self, rng: &mut Pcg64) -> [bool; LANES] {
        let mut drops = [false; LANES];
        if self.lane_drop_p > 0.0 {
            for d in drops.iter_mut() {
                *d = rng.bernoulli(self.lane_drop_p);
            }
        }
        drops
    }

    /// Should this step's reduced gradient be poisoned with a NaN?
    pub fn nan_grad_at(&self, step: usize) -> bool {
        self.nan_grad_step == Some(step)
            || self.nan_grad_from.is_some_and(|k| step >= k)
    }

    /// Should the periodic checkpoint at this step tear mid-write?
    pub fn truncate_ckpt_at(&self, step: usize) -> bool {
        self.ckpt_truncate_step == Some(step)
    }

    /// Should the trainer die after executing this step?
    pub fn kill_after(&self, step: usize) -> bool {
        self.kill_step == Some(step)
    }

    /// Replica whose worker closure panics at this step (always 0: one
    /// deterministic victim is enough to exercise the unwind path).
    pub fn worker_panic_at(&self, step: usize) -> Option<usize> {
        (self.worker_panic_step == Some(step)).then_some(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse(
            "lane_drop@p=0.1, ckpt_truncate@step=40, nan_grad@step=25, \
             kill@step=60, worker_panic@step=3",
        )
        .unwrap();
        assert_eq!(p.lane_drop_p, 0.1);
        assert_eq!(p.nan_grad_step, Some(25));
        assert_eq!(p.ckpt_truncate_step, Some(40));
        assert_eq!(p.kill_step, Some(60));
        assert_eq!(p.worker_panic_step, Some(3));
        assert!(p.is_armed());
        assert!(!FaultPlan::parse("").unwrap().is_armed());
        let from = FaultPlan::parse("nan_grad@from=7").unwrap();
        assert!(!from.nan_grad_at(6));
        assert!(from.nan_grad_at(7) && from.nan_grad_at(99));
    }

    #[test]
    fn bad_specs_fail_loudly() {
        for (spec, needle) in [
            ("lane_drop@p=1.5", "out of [0,1)"),
            ("lane_drop@step=3", "wants `@p=P`"),
            ("nan_grad@p=0.1", "wants `@step=K` or `@from=K`"),
            ("kill@step=x", "bad step"),
            ("kill@step=1,kill@step=2", "repeats"),
            ("gamma_ray@step=1", "unknown fault"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(format!("{err}").contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn env_fallback_only_fills_an_empty_spec() {
        let p = FaultPlan::from_spec_or_env("", Some("kill@step=9")).unwrap();
        assert_eq!(p.kill_step, Some(9));
        let p =
            FaultPlan::from_spec_or_env("kill@step=1", Some("kill@step=9"))
                .unwrap();
        assert_eq!(p.kill_step, Some(1));
        assert!(!FaultPlan::from_spec_or_env("", None).unwrap().is_armed());
    }

    #[test]
    fn lane_draws_are_deterministic_and_gated_on_p() {
        let plan = FaultPlan::parse("lane_drop@p=0.5").unwrap();
        let mut a = FaultPlan::stream(7);
        let mut b = FaultPlan::stream(7);
        let masks: Vec<[bool; LANES]> =
            (0..50).map(|_| plan.draw_lane_drops(&mut a)).collect();
        assert_eq!(
            masks,
            (0..50).map(|_| plan.draw_lane_drops(&mut b)).collect::<Vec<_>>()
        );
        assert!(masks.iter().flatten().any(|&d| d));
        assert!(masks.iter().flatten().any(|&d| !d));
        // a disarmed (or lane_drop-free) plan consumes zero draws
        let quiet = FaultPlan::parse("kill@step=3").unwrap();
        let mut c = FaultPlan::stream(7);
        assert_eq!(quiet.draw_lane_drops(&mut c), [false; LANES]);
        assert_eq!(c.next_u64(), FaultPlan::stream(7).next_u64());
        assert_eq!(quiet.lane_gain(), 1.0);
        assert_eq!(plan.lane_gain(), 2.0);
    }

    #[test]
    fn typed_errors_render_their_context() {
        let e = NonFiniteLoss { step: 12, skips: 5 };
        assert!(format!("{e}").contains("5 consecutive"));
        let k = InjectedKill { step: 40 };
        assert!(format!("{k}").contains("step 40"));
    }
}
