//! # uavjp — Unbiased Approximate Vector-Jacobian Products
//!
//! Rust+JAX+Pallas reproduction of *"Unbiased Approximate Vector-Jacobian
//! Products for Efficient Backpropagation"* (Bakong, Massoulié, Oyallon,
//! Scaman, 2026).
//!
//! Layering (DESIGN.md §1):
//! * **L1/L2 (python, build-time only)** — Pallas sketched-backward kernels
//!   and JAX model/train graphs, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — the training coordinator, with two execution
//!   backends behind one dispatch trait (DESIGN.md §7):
//!   - [`native`] — CPU-native training over a composable `Layer` module
//!     API (MLP, BagNet-lite, ViT-lite) whose hand-written backwards run
//!     the paper's sketched VJPs on real kept-column kernels; needs
//!     nothing on disk and is the default.
//!   - [`runtime`] — PJRT execution of the AOT artifacts (cargo feature
//!     `pjrt`; the offline build links a type-only stub).
//!
//!   Around them: data generation ([`data`]), LR/budget sweeps and the
//!   paper's experiments ([`coordinator`]), inference serving over saved
//!   checkpoints ([`serve`]), pipeline-parallel gradient compression
//!   ([`pipeline`]), data-parallel replica groups with sketch-compressed
//!   gradient all-reduce ([`replicate`]), deterministic fault injection
//!   and recovery ([`faults`]), and the offline substrates
//!   ([`json`], [`rng`], [`tensor`], [`sketch`], [`pool`], [`config`],
//!   [`metrics`], [`ptest`], [`cli`]).

// Unsafe hygiene for the SIMD kernels (`tensor::kernels`): every unsafe
// op inside an `unsafe fn` needs its own block, and every block needs a
// `// SAFETY:` comment (enforced in CI via `clippy -D warnings`).
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod analyze;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod faults;
pub mod json;
pub mod metrics;
pub mod native;
pub mod pipeline;
pub mod pool;
pub mod ptest;
pub mod replicate;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod tensor;
