//! # uavjp — Unbiased Approximate Vector-Jacobian Products
//!
//! Rust+JAX+Pallas reproduction of *"Unbiased Approximate Vector-Jacobian
//! Products for Efficient Backpropagation"* (Bakong, Massoulié, Oyallon,
//! Scaman, 2026).
//!
//! Layering (DESIGN.md):
//! * **L1/L2 (python, build-time only)** — Pallas sketched-backward kernels
//!   and JAX model/train graphs, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — the training coordinator: loads artifacts via
//!   PJRT ([`runtime`]), generates data ([`data`]), orchestrates LR/budget
//!   sweeps and the paper's experiments ([`coordinator`]), simulates
//!   pipeline-parallel gradient compression ([`pipeline`]), and provides
//!   the offline substrates ([`json`], [`rng`], [`tensor`], [`pool`],
//!   [`config`], [`metrics`], [`ptest`], [`cli`], [`sketch`]).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod metrics;
pub mod pipeline;
pub mod pool;
pub mod ptest;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod tensor;
