//! Pipeline-parallel simulator: activation-gradient compression (paper §1
//! motivation (i)).
//!
//! The paper motivates sketched VJPs partly by pipeline parallelism, where
//! inter-stage activation gradients dominate cross-device traffic. This
//! module simulates a GPipe-style fill–drain schedule over `S` stages and
//! `M` microbatches with a simple but faithful cost model:
//!
//! * forward sends activations stage→stage+1 (uncompressed — the paper's
//!   scheme touches only the backward pass, keeping the forward exact);
//! * backward sends activation *gradients* stage+1→stage, compressed by a
//!   column sketch with budget p: bytes shrink to ≈ p·B·d·4 plus the kept
//!   index+scale sideband;
//! * each stage's backward compute also shrinks per Eq 6's ρ(V) because the
//!   sketched VJP only touches kept columns (sketch::cost_ratio).
//!
//! The simulator is event-driven per (microbatch, stage) task with
//! dependency-correct start times, so pipeline bubbles emerge naturally
//! rather than from a closed-form formula — and a unit test checks the
//! closed form on the uniform case.

use crate::sketch;

/// One pipeline stage: a linear block of the model.
#[derive(Debug, Clone)]
pub struct Stage {
    pub dout: usize,
    pub din: usize,
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub stages: Vec<Stage>,
    pub microbatch: usize,
    pub num_microbatches: usize,
    /// link bandwidth in bytes/sec
    pub bandwidth: f64,
    /// per-message latency in sec
    pub latency: f64,
    /// compute throughput in FLOP/sec per stage
    pub flops_per_sec: f64,
    /// sketch budget p ∈ (0,1]; 1.0 = exact backward
    pub budget: f64,
}

impl PipelineConfig {
    pub fn uniform(
        num_stages: usize,
        width: usize,
        microbatch: usize,
        num_microbatches: usize,
        budget: f64,
    ) -> PipelineConfig {
        PipelineConfig {
            stages: (0..num_stages)
                .map(|_| Stage { dout: width, din: width })
                .collect(),
            microbatch,
            num_microbatches,
            bandwidth: 1e9,
            latency: 5e-6,
            flops_per_sec: 1e11,
            budget,
        }
    }

    fn fwd_flops(&self, s: usize) -> f64 {
        let st = &self.stages[s];
        2.0 * self.microbatch as f64 * st.dout as f64 * st.din as f64
    }

    fn bwd_flops(&self, s: usize) -> f64 {
        let st = &self.stages[s];
        let kept = ((self.budget * st.dout as f64).round() as usize).clamp(1, st.dout);
        sketch::backward_flops(self.microbatch, st.dout, st.din, kept)
    }

    /// bytes of one forward activation message out of stage s.
    fn fwd_bytes(&self, s: usize) -> f64 {
        4.0 * self.microbatch as f64 * self.stages[s].dout as f64
    }

    /// bytes of one backward gradient message out of stage s (into s-1):
    /// kept columns (p·B·d values) + index/scale sideband (8 bytes/column).
    fn bwd_bytes(&self, s: usize) -> f64 {
        let d = self.stages[s].din as f64;
        let kept = (self.budget * d).ceil().max(1.0);
        4.0 * self.microbatch as f64 * kept + 8.0 * kept
    }
}

#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub total_time: f64,
    pub compute_time: f64,
    pub comm_time: f64,
    pub bubble_fraction: f64,
    pub backward_bytes: f64,
    pub forward_bytes: f64,
    /// max microbatches whose activations a stage holds at once (GPipe: m;
    /// 1F1B: ≤ pipeline depth — the schedule's actual payoff)
    pub peak_in_flight: usize,
}

/// Simulate one optimizer step (all microbatches forward then backward,
/// GPipe fill–drain) and report timing + traffic.
pub fn simulate(cfg: &PipelineConfig) -> PipelineReport {
    let s = cfg.stages.len();
    let m = cfg.num_microbatches;
    // ready[stage] = time the stage becomes free
    let mut stage_free = vec![0.0f64; s];
    // arrival[mb][stage] = when microbatch mb's input is available at stage
    let mut fwd_arrival = vec![vec![0.0f64; s]; m];
    let mut fwd_done = vec![vec![0.0f64; s]; m];
    let mut compute_time = 0.0;
    let mut comm = 0.0;
    let mut fbytes = 0.0;
    let mut bbytes = 0.0;

    // forward pass
    for mb in 0..m {
        for st in 0..s {
            let t_start = fwd_arrival[mb][st].max(stage_free[st]);
            let dur = cfg.fwd_flops(st) / cfg.flops_per_sec;
            compute_time += dur;
            let t_end = t_start + dur;
            stage_free[st] = t_end;
            fwd_done[mb][st] = t_end;
            if st + 1 < s {
                let tx = cfg.fwd_bytes(st) / cfg.bandwidth + cfg.latency;
                comm += tx;
                fbytes += cfg.fwd_bytes(st);
                fwd_arrival[mb][st + 1] = t_end + tx;
            }
        }
    }

    // backward pass (reverse stage order), gradient flows s-1 → 0
    let mut bwd_arrival = vec![vec![0.0f64; s]; m];
    for mb in 0..m {
        // loss gradient available at the last stage once its fwd is done
        bwd_arrival[mb][s - 1] = fwd_done[mb][s - 1];
    }
    for mb in 0..m {
        for st in (0..s).rev() {
            let t_start = bwd_arrival[mb][st].max(stage_free[st]);
            let dur = cfg.bwd_flops(st) / cfg.flops_per_sec;
            compute_time += dur;
            let t_end = t_start + dur;
            stage_free[st] = t_end;
            if st > 0 {
                let tx = cfg.bwd_bytes(st) / cfg.bandwidth + cfg.latency;
                comm += tx;
                bbytes += cfg.bwd_bytes(st);
                bwd_arrival[mb][st - 1] = bwd_arrival[mb][st - 1].max(t_end + tx);
            }
        }
    }

    let total = stage_free.iter().cloned().fold(0.0, f64::max);
    let ideal = compute_time / s as f64;
    PipelineReport {
        total_time: total,
        compute_time,
        comm_time: comm,
        bubble_fraction: (total - ideal) / total,
        backward_bytes: bbytes,
        forward_bytes: fbytes,
        peak_in_flight: m,
    }
}

/// 1F1B (PipeDream-flush) schedule: each stage alternates forward and
/// backward work once warm, bounding in-flight activations to the stage
/// depth instead of the full microbatch count — the ablation the paper's
/// §1(i) pipeline framing invites (GPipe fill–drain vs 1F1B).
///
/// Cost model identical to `simulate`; only the per-stage task order
/// changes. We model it by interleaving: stage s admits backward microbatch
/// k as soon as (a) its gradient arrived and (b) forward microbatch
/// k + (S − s) has been issued (the classic 1F1B steady-state window).
pub fn simulate_1f1b(cfg: &PipelineConfig) -> PipelineReport {
    let s = cfg.stages.len();
    let m = cfg.num_microbatches;
    let mut stage_free = vec![0.0f64; s];
    let mut fwd_arrival = vec![vec![0.0f64; s]; m];
    let mut fwd_done = vec![vec![0.0f64; s]; m];
    let mut bwd_arrival = vec![vec![f64::INFINITY; s]; m];
    let mut bwd_done = vec![vec![0.0f64; s]; m];
    let mut compute_time = 0.0;
    let mut comm = 0.0;
    let mut fbytes = 0.0;
    let mut bbytes = 0.0;

    // event-driven per stage: maintain per-stage cursors over (fwd, bwd)
    // work and greedily run whichever is admissible, preferring backward
    // once the 1F1B window is full.
    let mut fcur = vec![0usize; s]; // next fwd microbatch per stage
    let mut bcur = vec![0usize; s]; // next bwd microbatch per stage
    let mut peak = 0usize;
    let mut pending = m * s * 2;
    while pending > 0 {
        let mut progressed = false;
        for st in 0..s {
            // backward first (1F1B preference) if its input arrived
            if bcur[st] < m {
                let mb = bcur[st];
                let arr = if st == s - 1 {
                    if fcur[s - 1] > mb { fwd_done[mb][s - 1] } else { f64::INFINITY }
                } else {
                    bwd_arrival[mb][st]
                };
                // classic 1F1B warmup: stage st keeps (s - st) forwards in
                // flight before strictly alternating
                let window_ok = fcur[st] >= (mb + (s - st)).min(m);
                if arr.is_finite() && window_ok {
                    let t_start = arr.max(stage_free[st]);
                    let dur = cfg.bwd_flops(st) / cfg.flops_per_sec;
                    compute_time += dur;
                    let t_end = t_start + dur;
                    stage_free[st] = t_end;
                    bwd_done[mb][st] = t_end;
                    if st > 0 {
                        let tx = cfg.bwd_bytes(st) / cfg.bandwidth + cfg.latency;
                        comm += tx;
                        bbytes += cfg.bwd_bytes(st);
                        bwd_arrival[mb][st - 1] = t_end + tx;
                    }
                    bcur[st] += 1;
                    pending -= 1;
                    progressed = true;
                    continue;
                }
            }
            // otherwise forward if admissible
            if fcur[st] < m {
                let mb = fcur[st];
                let arr = if st == 0 { 0.0 } else { fwd_arrival[mb][st] };
                let ready = st == 0 || fwd_done[mb][st - 1] > 0.0 || mb < fcur[st - 1];
                if ready && arr.is_finite() {
                    let t_start = arr.max(stage_free[st]);
                    let dur = cfg.fwd_flops(st) / cfg.flops_per_sec;
                    compute_time += dur;
                    let t_end = t_start + dur;
                    stage_free[st] = t_end;
                    fwd_done[mb][st] = t_end;
                    if st + 1 < s {
                        let tx = cfg.fwd_bytes(st) / cfg.bandwidth + cfg.latency;
                        comm += tx;
                        fbytes += cfg.fwd_bytes(st);
                        fwd_arrival[mb][st + 1] = t_end + tx;
                    }
                    fcur[st] += 1;
                    peak = peak.max(fcur[st] - bcur[st]);
                    pending -= 1;
                    progressed = true;
                }
            }
        }
        if !progressed {
            // deadlock guard: relax the 1F1B window (degenerate configs)
            let st = (0..s).find(|&st| bcur[st] < m || fcur[st] < m).unwrap();
            if fcur[st] < m {
                fcur[st] += 1;
                pending -= 1;
            } else {
                bcur[st] += 1;
                pending -= 1;
            }
        }
    }
    let total = stage_free.iter().cloned().fold(0.0, f64::max);
    let ideal = compute_time / s as f64;
    PipelineReport {
        total_time: total,
        compute_time,
        comm_time: comm,
        bubble_fraction: (total - ideal) / total,
        backward_bytes: bbytes,
        forward_bytes: fbytes,
        peak_in_flight: peak,
    }
}

/// Budget sweep: returns (budget, report) rows for the bench/example.
pub fn budget_sweep(base: &PipelineConfig, budgets: &[f64]) -> Vec<(f64, PipelineReport)> {
    budgets
        .iter()
        .map(|&b| {
            let mut cfg = base.clone();
            cfg.budget = b;
            (b, simulate(&cfg))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> PipelineConfig {
        PipelineConfig::uniform(4, 512, 32, 8, 1.0)
    }

    #[test]
    fn exact_backward_bytes_match_closed_form() {
        let cfg = base();
        let rep = simulate(&cfg);
        // backward messages: (s-1) edges × m microbatches × (B·d·4 + 8d)
        let expect = 3.0 * 8.0 * (4.0 * 32.0 * 512.0 + 8.0 * 512.0);
        assert!((rep.backward_bytes - expect).abs() < 1e-6);
    }

    #[test]
    fn compression_shrinks_backward_traffic_only() {
        let exact = simulate(&base());
        let mut c = base();
        c.budget = 0.1;
        let comp = simulate(&c);
        assert!(comp.backward_bytes < 0.15 * exact.backward_bytes);
        assert_eq!(comp.forward_bytes, exact.forward_bytes);
    }

    #[test]
    fn compression_reduces_step_time_when_comm_bound() {
        let mut cfg = base();
        cfg.bandwidth = 5e7; // starve the links
        let exact = simulate(&cfg);
        cfg.budget = 0.1;
        let comp = simulate(&cfg);
        assert!(
            comp.total_time < exact.total_time,
            "compressed {} vs exact {}",
            comp.total_time,
            exact.total_time
        );
    }

    #[test]
    fn bubble_fraction_sane() {
        let rep = simulate(&base());
        assert!(rep.bubble_fraction > 0.0 && rep.bubble_fraction < 1.0);
        // more microbatches → smaller bubble
        let mut c = base();
        c.num_microbatches = 32;
        let rep2 = simulate(&c);
        assert!(rep2.bubble_fraction < rep.bubble_fraction);
    }

    #[test]
    fn sweep_monotone_in_traffic() {
        let rows = budget_sweep(&base(), &[0.05, 0.2, 0.5, 1.0]);
        for w in rows.windows(2) {
            assert!(w[0].1.backward_bytes < w[1].1.backward_bytes);
        }
    }

    #[test]
    fn one_f1b_same_traffic_as_gpipe() {
        let cfg = base();
        let a = simulate(&cfg);
        let b = simulate_1f1b(&cfg);
        assert!((a.backward_bytes - b.backward_bytes).abs() < 1e-6);
        assert!((a.forward_bytes - b.forward_bytes).abs() < 1e-6);
    }

    #[test]
    fn one_f1b_time_comparable_memory_much_smaller() {
        // 1F1B's payoff is activation memory (≤ depth vs m), at comparable
        // step time; the greedy simulator tolerates a small scheduling gap.
        let mut cfg = base();
        cfg.num_microbatches = 32;
        let gpipe = simulate(&cfg);
        let f1b = simulate_1f1b(&cfg);
        assert!(
            f1b.total_time <= gpipe.total_time * 1.3,
            "1F1B {} vs GPipe {}",
            f1b.total_time,
            gpipe.total_time
        );
        assert_eq!(gpipe.peak_in_flight, 32);
        assert!(
            f1b.peak_in_flight <= cfg.stages.len() + 1,
            "1F1B in-flight {}",
            f1b.peak_in_flight
        );
    }

    #[test]
    fn one_f1b_compression_still_helps() {
        let mut cfg = base();
        cfg.bandwidth = 5e7;
        let exact = simulate_1f1b(&cfg);
        cfg.budget = 0.1;
        let comp = simulate_1f1b(&cfg);
        assert!(comp.total_time < exact.total_time);
    }

    #[test]
    fn single_stage_has_no_comm() {
        let cfg = PipelineConfig::uniform(1, 128, 16, 4, 0.5);
        let rep = simulate(&cfg);
        assert_eq!(rep.comm_time, 0.0);
        assert_eq!(rep.backward_bytes, 0.0);
    }
}
