//! Synthetic dataset substrate (DESIGN.md §6 substitutions).
//!
//! The paper trains on MNIST and CIFAR-10; neither ships with this offline
//! box, so we generate structured stand-ins that exercise the identical code
//! paths and preserve what the experiments measure — *relative* degradation
//! of training under randomized VJPs:
//!
//! * **synth-MNIST** — 10 classes, 784-dim. Each class has a deterministic
//!   anchor "digit" pattern (coarse 7×7 stroke layout upsampled to 28×28);
//!   samples add Gaussian pixel noise, per-sample brightness jitter and a
//!   small random translation. Linearly-separable-ish but noisy, like MNIST.
//! * **synth-CIFAR** — 10 classes, 32×32×3. Class anchors are colored
//!   multi-scale blob/stripe textures with spatially-correlated noise
//!   (box-filtered), so nearby pixels co-vary as in natural images.
//!
//! Everything is deterministic given (seed, split).

use crate::rng::{streams, Pcg64};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    SynthMnist,
    SynthCifar,
}

impl DatasetKind {
    /// Dataset a model family trains on; errors on an unknown model so a
    /// typo'd `--model` exits cleanly instead of unwinding.
    pub fn for_model(model: &str) -> anyhow::Result<DatasetKind> {
        match model {
            "mlp" => Ok(DatasetKind::SynthMnist),
            "vit" | "bagnet" | "vit_deep" | "bagnet_deep" => {
                Ok(DatasetKind::SynthCifar)
            }
            other => anyhow::bail!(
                "no dataset for model {other} (want {})",
                crate::config::KNOWN_MODELS.join("|")
            ),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            DatasetKind::SynthMnist => 784,
            DatasetKind::SynthCifar => 32 * 32 * 3,
        }
    }
}

/// An in-memory dataset: row-major features + integer labels.
pub struct Dataset {
    pub kind: DatasetKind,
    pub x: Vec<f32>, // n * dim
    pub y: Vec<i32>,
    pub n: usize,
    pub dim: usize,
}

pub const NUM_CLASSES: usize = 10;

/// Generate `n` samples. `split` decouples train/test streams.
pub fn generate(kind: DatasetKind, n: usize, seed: u64, split: &str) -> Dataset {
    let stream = match split {
        "train" => 1,
        "test" => 2,
        other => panic!("unknown split {other}"),
    };
    let mut rng = streams::data_split(seed, stream);
    let dim = kind.dim();
    let anchors = match kind {
        DatasetKind::SynthMnist => mnist_anchors(seed),
        DatasetKind::SynthCifar => cifar_anchors(seed),
    };
    let mut x = vec![0.0f32; n * dim];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let cls = rng.below(NUM_CLASSES);
        y[i] = cls as i32;
        let row = &mut x[i * dim..(i + 1) * dim];
        match kind {
            DatasetKind::SynthMnist => sample_mnist(row, &anchors[cls], &mut rng),
            DatasetKind::SynthCifar => sample_cifar(row, &anchors[cls], &mut rng),
        }
    }
    Dataset { kind, x, y, n, dim }
}

// ---------------------------------------------------------------------------
// synth-MNIST
// ---------------------------------------------------------------------------
fn mnist_anchors(seed: u64) -> Vec<Vec<f32>> {
    // Deterministic per-class coarse stroke patterns on a 7×7 grid,
    // upsampled to 28×28. Classes differ by which cells are "ink".
    let mut anchors = Vec::with_capacity(NUM_CLASSES);
    for cls in 0..NUM_CLASSES {
        let mut rng = streams::mnist_anchor(seed, cls as u64);
        let mut coarse = [0.0f32; 49];
        // each class draws a distinct connected stroke: random walk of 12 cells
        let mut pos = (rng.below(7), rng.below(7));
        for _ in 0..12 {
            coarse[pos.0 * 7 + pos.1] = 1.0;
            let dir = rng.below(4);
            pos = match dir {
                0 => ((pos.0 + 1).min(6), pos.1),
                1 => (pos.0.saturating_sub(1), pos.1),
                2 => (pos.0, (pos.1 + 1).min(6)),
                _ => (pos.0, pos.1.saturating_sub(1)),
            };
        }
        let mut img = vec![0.0f32; 784];
        for r in 0..28 {
            for c in 0..28 {
                img[r * 28 + c] = coarse[(r / 4) * 7 + (c / 4)];
            }
        }
        anchors.push(img);
    }
    anchors
}

fn sample_mnist(out: &mut [f32], anchor: &[f32], rng: &mut Pcg64) {
    let bright = 0.8 + 0.4 * rng.f32();
    let (dr, dc) = (rng.below(5) as i32 - 2, rng.below(5) as i32 - 2);
    for r in 0..28i32 {
        for c in 0..28i32 {
            let (sr, sc) = (r - dr, c - dc);
            let base = if (0..28).contains(&sr) && (0..28).contains(&sc) {
                anchor[(sr * 28 + sc) as usize]
            } else {
                0.0
            };
            let noise = rng.gaussian() as f32 * 0.25;
            out[(r * 28 + c) as usize] = (base * bright + noise).clamp(-0.5, 1.5);
        }
    }
}

// ---------------------------------------------------------------------------
// synth-CIFAR
// ---------------------------------------------------------------------------
fn cifar_anchors(seed: u64) -> Vec<Vec<f32>> {
    let mut anchors = Vec::with_capacity(NUM_CLASSES);
    for cls in 0..NUM_CLASSES {
        let mut rng = streams::cifar_anchor(seed, cls as u64);
        let mut img = vec![0.0f32; 32 * 32 * 3];
        // class-specific color palette + texture frequency
        let color = [rng.f32(), rng.f32(), rng.f32()];
        let (fx, fy) = (
            1.0 + rng.below(4) as f32,
            1.0 + rng.below(4) as f32,
        );
        let phase = rng.f32() * 6.28;
        // 3 random blobs per class
        let blobs: Vec<(f32, f32, f32)> = (0..3)
            .map(|_| (rng.f32() * 32.0, rng.f32() * 32.0, 4.0 + rng.f32() * 6.0))
            .collect();
        for r in 0..32 {
            for c in 0..32 {
                let stripes = ((fx * r as f32 / 32.0 + fy * c as f32 / 32.0)
                    * 6.28
                    + phase)
                    .sin()
                    * 0.3;
                let mut blob = 0.0f32;
                for &(br, bc, rad) in &blobs {
                    let d2 = (r as f32 - br).powi(2) + (c as f32 - bc).powi(2);
                    blob += (-d2 / (rad * rad)).exp();
                }
                for ch in 0..3 {
                    img[(r * 32 + c) * 3 + ch] =
                        color[ch] * (0.4 + blob).min(1.2) + stripes;
                }
            }
        }
        anchors.push(img);
    }
    anchors
}

fn sample_cifar(out: &mut [f32], anchor: &[f32], rng: &mut Pcg64) {
    // spatially-correlated noise: white noise box-filtered once (3×3)
    let mut white = vec![0.0f32; 32 * 32];
    for v in white.iter_mut() {
        *v = rng.gaussian() as f32;
    }
    let flip = rng.bernoulli(0.5);
    let bright = 0.85 + 0.3 * rng.f32();
    for r in 0..32usize {
        for c in 0..32usize {
            let mut acc = 0.0f32;
            let mut cnt = 0.0f32;
            for dr in -1i32..=1 {
                for dc in -1i32..=1 {
                    let rr = r as i32 + dr;
                    let cc = c as i32 + dc;
                    if (0..32).contains(&rr) && (0..32).contains(&cc) {
                        acc += white[(rr * 32 + cc) as usize];
                        cnt += 1.0;
                    }
                }
            }
            let noise = acc / cnt * 0.35;
            let src_c = if flip { 31 - c } else { c };
            for ch in 0..3 {
                out[(r * 32 + c) * 3 + ch] =
                    (anchor[(r * 32 + src_c) * 3 + ch] * bright + noise)
                        .clamp(-1.0, 2.0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Batching
// ---------------------------------------------------------------------------
/// Epoch iterator: shuffles indices each epoch, yields fixed-size batches
/// (drops the ragged tail, as the AOT artifacts have a baked batch size).
pub struct BatchIter<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, rng: &mut Pcg64) -> Self {
        let mut order: Vec<usize> = (0..ds.n).collect();
        rng.shuffle(&mut order);
        BatchIter { ds, order, batch, cursor: 0 }
    }

    /// Copy the next batch into caller-provided staging buffers.
    pub fn next_into(&mut self, x: &mut [f32], y: &mut [i32]) -> bool {
        if self.cursor + self.batch > self.ds.n {
            return false;
        }
        let dim = self.ds.dim;
        for (bi, &idx) in
            self.order[self.cursor..self.cursor + self.batch].iter().enumerate()
        {
            x[bi * dim..(bi + 1) * dim]
                .copy_from_slice(&self.ds.x[idx * dim..(idx + 1) * dim]);
            y[bi] = self.ds.y[idx];
        }
        self.cursor += self.batch;
        true
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.ds.n / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = generate(DatasetKind::SynthMnist, 16, 7, "train");
        let b = generate(DatasetKind::SynthMnist, 16, 7, "train");
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn splits_differ() {
        let a = generate(DatasetKind::SynthMnist, 16, 7, "train");
        let b = generate(DatasetKind::SynthMnist, 16, 7, "test");
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn all_classes_present() {
        let d = generate(DatasetKind::SynthMnist, 400, 3, "train");
        let mut seen = [false; NUM_CLASSES];
        for &y in &d.y {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn cifar_dims_and_range() {
        let d = generate(DatasetKind::SynthCifar, 8, 5, "train");
        assert_eq!(d.dim, 3072);
        assert_eq!(d.x.len(), 8 * 3072);
        assert!(d.x.iter().all(|&v| (-1.0..=2.0).contains(&v)));
    }

    #[test]
    fn classes_are_distinguishable() {
        // nearest-anchor classification on clean anchors must beat chance by a lot
        let d = generate(DatasetKind::SynthMnist, 300, 11, "train");
        let anchors = mnist_anchors(11);
        let mut correct = 0;
        for i in 0..d.n {
            let row = &d.x[i * 784..(i + 1) * 784];
            let mut best = (f32::MAX, 0usize);
            for (cls, a) in anchors.iter().enumerate() {
                let dist: f32 =
                    row.iter().zip(a).map(|(x, y)| (x - y) * (x - y)).sum();
                if dist < best.0 {
                    best = (dist, cls);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n as f64;
        assert!(acc > 0.5, "nearest-anchor acc {acc}");
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let d = generate(DatasetKind::SynthMnist, 64, 1, "train");
        let mut rng = Pcg64::new(0, 0);
        let mut it = BatchIter::new(&d, 16, &mut rng);
        assert_eq!(it.batches_per_epoch(), 4);
        let mut x = vec![0.0f32; 16 * 784];
        let mut y = vec![0i32; 16];
        let mut count = 0;
        while it.next_into(&mut x, &mut y) {
            count += 1;
        }
        assert_eq!(count, 4);
    }
}
