//! Typed experiment configuration with `ci` / `paper` presets.
//!
//! The `paper` preset mirrors §5's protocol (dataset sizes, epochs, LR
//! grids); `ci` is the scaled protocol this single-core box actually runs
//! for EXPERIMENTS.md (DESIGN.md §6). Configs can be loaded from / saved to
//! JSON so runs are reproducible artifacts. The [`Backend`] enum selects
//! which execution engine a run uses (DESIGN.md §7). All parsers return
//! `Result` with a usage hint — a typo'd flag exits cleanly instead of
//! unwinding.

use crate::json::{self, Value};
use anyhow::{bail, Result};

/// Model families every preset knows a recipe for. Whether a *backend*
/// can train one is a separate question — `TrainBackend::supports_model`
/// queries the native model registry (`crate::native::models`).
pub const KNOWN_MODELS: &[&str] =
    &["mlp", "bagnet", "vit", "bagnet_deep", "vit_deep"];

/// Which engine executes training steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// CPU-native module stacks + sketched backward ([`crate::native`]);
    /// needs no artifacts and is the default everywhere.
    #[default]
    Native,
    /// PJRT execution of AOT-compiled JAX graphs ([`crate::runtime`]);
    /// requires the `pjrt` cargo feature and a built `artifacts/` dir.
    Pjrt,
}

impl Backend {
    /// Parse `"native"` / `"pjrt"`.
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => bail!("unknown backend {other} (want native|pjrt)"),
        }
    }

    /// Canonical name, inverse of [`Backend::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// One serving session (`serve` subcommand / `serve_throughput` bench):
/// the dynamic-batching policy plus the synthetic client discipline
/// (`crate::serve::run_server` documents open vs closed loop).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Largest coalesced batch (`--max-batch`); serving engines size
    /// their arenas to it.
    pub max_batch: usize,
    /// Batching deadline in microseconds (`--max-wait-us`): how long a
    /// queued request may wait for co-riders before dispatching anyway.
    pub max_wait_us: u64,
    /// Serving worker threads, each with its own engine
    /// (`--serve-workers`).
    pub workers: usize,
    /// Total synthetic requests to serve (`--requests`).
    pub requests: usize,
    /// Offered load in requests/second (`--offered-load`): `> 0` runs the
    /// open-loop client, `0` the closed-loop client.
    pub offered_load: f64,
    /// In-flight requests under the closed-loop client (`--concurrency`).
    pub concurrency: usize,
    /// Admission-control bound on queued requests (`--queue-cap`): a
    /// submit that would grow the queue past this is rejected with a
    /// typed error instead of waiting. `0` = unbounded (the default).
    pub queue_cap: usize,
    /// Per-request deadline in microseconds (`--request-timeout-us`): a
    /// queued request older than this is resolved with a typed
    /// `DeadlineExceeded` instead of being served. `0` = no deadline
    /// (the default).
    pub request_timeout_us: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait_us: 200,
            workers: 1,
            requests: 256,
            offered_load: 0.0,
            concurrency: 4,
            queue_cap: 0,
            request_timeout_us: 0,
        }
    }
}

/// One fully-specified training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model family, one of [`KNOWN_MODELS`].
    pub model: String,
    /// Sketch method (`"baseline"` = exact VJPs everywhere).
    pub method: String,
    /// Kept-column budget p ∈ (0, 1].
    pub budget: f64,
    /// Base learning rate (see [`TrainConfig::lr_at`] for the schedule).
    pub lr: f64,
    /// Run seed: init, batch order and sketch gates all derive from it.
    pub seed: u64,
    /// Training-set size (synthetic generator, shared across methods).
    pub train_size: usize,
    /// Test-set size.
    pub test_size: usize,
    /// Number of optimizer steps.
    pub steps: usize,
    /// Evaluate on the test set every this many steps.
    pub eval_every: usize,
    /// which sketched layers are active: "all" | "first" | "last" | "none"
    pub location: String,
    /// cosine decay to lr*0.01 over `steps` when true (bagnet/vit recipe)
    pub cosine: bool,
    /// Linear LR warmup steps before the schedule proper.
    pub warmup_steps: usize,
    /// Execution engine for this run.
    pub backend: Backend,
    /// Optimizer: "sgd" | "momentum" | "adam" (native backend; PJRT bakes
    /// the recipe into the artifact).
    pub optimizer: String,
    /// Loss head: "ce" | "mse" (native backend).
    pub loss: String,
    /// Batch size (PJRT artifacts bake 128; native follows the config).
    pub batch: usize,
    /// Optional per-depth budget schedule: one budget per sketch site
    /// (forward order), overriding `budget` when non-empty. The native
    /// `SketchPolicy` validates its length against the model's site count.
    pub budget_schedule: Vec<f64>,
    /// Intra-op worker count for the native tensor kernels (`--threads`);
    /// `0` inherits the process default (auto on explicit `--threads 0`).
    /// Results are bit-identical at every setting — pure wall-clock knob.
    pub threads: usize,
    /// Compute-kernel kind for the native tensor ops (`--kernel`):
    /// `"auto" | "scalar" | "simd"`. `"auto"` inherits the process
    /// setting (`UAVJP_KERNEL` env, else hardware detection). Within a
    /// kind results are bit-identical across runs and thread counts;
    /// kinds differ in the last ulps (DESIGN.md §7.3).
    pub kernel: String,
    /// Activation stash policy (`--act-policy`): `"auto" | "exact" |
    /// "kept"`. `"exact"` keeps full input copies (bit-identical to the
    /// pre-policy trainer); `"kept"` compacts sketched sites to kept
    /// columns and ReLU inputs to sign bitsets (DESIGN.md §7.4);
    /// `"auto"` reads `UAVJP_ACTPOLICY`, defaulting to `"exact"`.
    pub act_policy: String,
    /// Default kept-column budget for activation stashes under the kept
    /// policy; `0.0` inherits each site's sketch budget.
    pub act_budget: f64,
    /// Optional per-site activation budgets (forward order, like
    /// `budget_schedule`); when non-empty its length must equal the
    /// model's site count and it overrides `act_budget`.
    pub act_schedule: Vec<f64>,
    /// Data-parallel replica count (`--replicas`): `0` (the default) runs
    /// the plain single-stream trainer; `≥ 1` runs the replica group
    /// (DESIGN.md §7.6), whose fixed 8-lane grid requires a divisor of 8
    /// and keeps trajectories bit-identical at every valid value.
    pub replicas: usize,
    /// Gradient-exchange mode under `--replicas` (`--reduce`):
    /// `"dense" | "sparse"` (kept-column union-merge). Trajectories
    /// match; the modeled wire bytes differ.
    pub reduce: String,
    /// Gradient staleness under `--replicas` (`--stale`): `1` applies
    /// each step's reduced gradient one step late (communication-hiding
    /// model), `0` synchronously.
    pub stale: usize,
    /// Fault-injection spec (`--fault-spec`, DESIGN.md §7.7): comma-
    /// separated `name@key=value` terms parsed by
    /// `crate::faults::FaultPlan`. Empty (the default) falls back to the
    /// `UAVJP_FAULTS` env var, then to the no-fault plan.
    pub fault_spec: String,
    /// Write a resumable (version-2) checkpoint to `ckpt_path` every this
    /// many steps (`--ckpt-every`); `0` (the default) disables periodic
    /// checkpointing.
    pub ckpt_every: usize,
    /// Destination of periodic checkpoints (the CLI wires `--save-ckpt`
    /// here); must be non-empty when `ckpt_every > 0`.
    pub ckpt_path: String,
    /// Resume from this checkpoint (`--resume`): restore parameters,
    /// optimizer state, step counter and every RNG stream, then continue
    /// the interrupted trajectory bit-identically. Empty = fresh run.
    pub resume: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "mlp".into(),
            method: "baseline".into(),
            budget: 1.0,
            lr: 0.1,
            seed: 0,
            train_size: 4096,
            test_size: 1024,
            steps: 600,
            eval_every: 150,
            location: "all".into(),
            cosine: false,
            warmup_steps: 0,
            backend: Backend::Native,
            optimizer: "sgd".into(),
            loss: "ce".into(),
            batch: 128,
            budget_schedule: Vec::new(),
            threads: 0,
            kernel: "auto".into(),
            act_policy: "auto".into(),
            act_budget: 0.0,
            act_schedule: Vec::new(),
            replicas: 0,
            reduce: "dense".into(),
            stale: 0,
            fault_spec: String::new(),
            ckpt_every: 0,
            ckpt_path: String::new(),
            resume: String::new(),
        }
    }
}

impl TrainConfig {
    /// Learning rate at `step` (cosine schedule + linear warmup).
    pub fn lr_at(&self, step: usize) -> f64 {
        let mut lr = self.lr;
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return lr * (step + 1) as f64 / self.warmup_steps as f64;
        }
        if self.cosine {
            let t = (step.saturating_sub(self.warmup_steps)) as f64
                / (self.steps.saturating_sub(self.warmup_steps)).max(1) as f64;
            let floor = 0.01 * self.lr;
            lr = floor + (lr - floor) * 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        }
        lr
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("model", Value::str(&self.model)),
            ("method", Value::str(&self.method)),
            ("budget", Value::num(self.budget)),
            ("lr", Value::num(self.lr)),
            ("seed", Value::num(self.seed as f64)),
            ("train_size", Value::num(self.train_size as f64)),
            ("test_size", Value::num(self.test_size as f64)),
            ("steps", Value::num(self.steps as f64)),
            ("eval_every", Value::num(self.eval_every as f64)),
            ("location", Value::str(&self.location)),
            ("cosine", Value::Bool(self.cosine)),
            ("warmup_steps", Value::num(self.warmup_steps as f64)),
            ("backend", Value::str(self.backend.as_str())),
            ("optimizer", Value::str(&self.optimizer)),
            ("loss", Value::str(&self.loss)),
            ("batch", Value::num(self.batch as f64)),
            ("budget_schedule", Value::arr_f64(&self.budget_schedule)),
            ("threads", Value::num(self.threads as f64)),
            ("kernel", Value::str(&self.kernel)),
            ("act_policy", Value::str(&self.act_policy)),
            ("act_budget", Value::num(self.act_budget)),
            ("act_schedule", Value::arr_f64(&self.act_schedule)),
            ("replicas", Value::num(self.replicas as f64)),
            ("reduce", Value::str(&self.reduce)),
            ("stale", Value::num(self.stale as f64)),
            ("fault_spec", Value::str(&self.fault_spec)),
            ("ckpt_every", Value::num(self.ckpt_every as f64)),
            ("ckpt_path", Value::str(&self.ckpt_path)),
            ("resume", Value::str(&self.resume)),
        ])
    }

    /// Parse a config object; missing keys fall back to defaults, but a
    /// *present* key with an invalid value (unknown backend, non-numeric
    /// budget-schedule entry) is a clean error rather than a silent
    /// fallback.
    pub fn from_json(v: &Value) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let backend = match v.get("backend").as_str() {
            Some(s) => Backend::parse(s)?,
            None => d.backend,
        };
        let sched_of = |key: &'static str| -> Result<Vec<f64>> {
            match v.get(key).as_arr() {
                Some(xs) => xs
                    .iter()
                    .map(|x| {
                        x.as_f64().ok_or_else(|| {
                            anyhow::anyhow!("{key} entries must be numbers")
                        })
                    })
                    .collect::<Result<Vec<f64>>>(),
                None => Ok(Vec::new()),
            }
        };
        let budget_schedule = sched_of("budget_schedule")?;
        let act_schedule = sched_of("act_schedule")?;
        Ok(TrainConfig {
            model: v.get("model").as_str().unwrap_or(&d.model).to_string(),
            method: v.get("method").as_str().unwrap_or(&d.method).to_string(),
            budget: v.get("budget").as_f64().unwrap_or(d.budget),
            lr: v.get("lr").as_f64().unwrap_or(d.lr),
            seed: v.get("seed").as_f64().unwrap_or(0.0) as u64,
            train_size: v.get("train_size").as_usize().unwrap_or(d.train_size),
            test_size: v.get("test_size").as_usize().unwrap_or(d.test_size),
            steps: v.get("steps").as_usize().unwrap_or(d.steps),
            eval_every: v.get("eval_every").as_usize().unwrap_or(d.eval_every),
            location: v.get("location").as_str().unwrap_or(&d.location).to_string(),
            cosine: v.get("cosine").as_bool().unwrap_or(d.cosine),
            warmup_steps: v.get("warmup_steps").as_usize().unwrap_or(0),
            backend,
            optimizer: v.get("optimizer").as_str().unwrap_or(&d.optimizer).to_string(),
            loss: v.get("loss").as_str().unwrap_or(&d.loss).to_string(),
            batch: v.get("batch").as_usize().unwrap_or(d.batch),
            budget_schedule,
            threads: v.get("threads").as_usize().unwrap_or(d.threads),
            kernel: v.get("kernel").as_str().unwrap_or(&d.kernel).to_string(),
            act_policy: v
                .get("act_policy")
                .as_str()
                .unwrap_or(&d.act_policy)
                .to_string(),
            act_budget: v.get("act_budget").as_f64().unwrap_or(d.act_budget),
            act_schedule,
            replicas: v.get("replicas").as_usize().unwrap_or(d.replicas),
            reduce: v.get("reduce").as_str().unwrap_or(&d.reduce).to_string(),
            stale: v.get("stale").as_usize().unwrap_or(d.stale),
            fault_spec: v
                .get("fault_spec")
                .as_str()
                .unwrap_or(&d.fault_spec)
                .to_string(),
            ckpt_every: v.get("ckpt_every").as_usize().unwrap_or(d.ckpt_every),
            ckpt_path: v
                .get("ckpt_path")
                .as_str()
                .unwrap_or(&d.ckpt_path)
                .to_string(),
            resume: v.get("resume").as_str().unwrap_or(&d.resume).to_string(),
        })
    }
}

/// Experiment-scale preset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// Minutes-scale: 1 seed, 1–2 LR points, short runs. What a laptop CI
    /// job (or this single-core box) uses to regenerate figure *shapes*.
    Smoke,
    Ci,
    Paper,
}

impl Preset {
    /// Parse `"smoke"` / `"ci"` / `"paper"`.
    pub fn parse(s: &str) -> Result<Preset> {
        match s {
            "smoke" => Ok(Preset::Smoke),
            "ci" => Ok(Preset::Ci),
            "paper" => Ok(Preset::Paper),
            other => bail!("unknown preset {other} (want smoke|ci|paper)"),
        }
    }

    /// Base config for a model under this preset; errors on a model no
    /// preset has a recipe for (see [`KNOWN_MODELS`]).
    pub fn base(self, model: &str) -> Result<TrainConfig> {
        if !KNOWN_MODELS.contains(&model) {
            bail!("unknown model {model} (want {})", KNOWN_MODELS.join("|"));
        }
        if self == Preset::Smoke {
            let mut c = Preset::Ci.base(model)?;
            match model {
                "mlp" => {
                    c.train_size = 2048;
                    c.test_size = 512;
                    c.steps = 256;
                    c.eval_every = 128;
                }
                _ => {
                    c.train_size = 512;
                    c.test_size = 128;
                    c.steps = 96;
                    c.eval_every = 48;
                    c.warmup_steps = c.warmup_steps.min(8);
                }
            }
            return Ok(c);
        }
        // Deep variants train under their shallow family's recipe (LR,
        // schedule, optimizer); only the model name differs.
        let recipe = match model {
            "bagnet_deep" => "bagnet",
            "vit_deep" => "vit",
            m => m,
        };
        let mut c = TrainConfig { model: model.to_string(), ..Default::default() };
        match (self, recipe) {
            (Preset::Ci, "mlp") => {
                c.train_size = 4096;
                c.test_size = 1024;
                c.steps = 640; // 20 epochs of 32 batches
                c.eval_every = 160;
                c.lr = 0.1;
            }
            (Preset::Paper, "mlp") => {
                c.train_size = 60000;
                c.test_size = 10000;
                c.steps = 50 * (60000 / 128); // 50 epochs
                c.eval_every = 60000 / 128;
                c.lr = 0.1;
            }
            (Preset::Ci, "bagnet") => {
                c.train_size = 2048;
                c.test_size = 512;
                c.steps = 384;
                c.eval_every = 96;
                c.lr = 0.032; // 10^-1.5, §B.2
                c.cosine = true;
            }
            (Preset::Paper, "bagnet") => {
                c.train_size = 50000;
                c.test_size = 10000;
                c.steps = 100 * (50000 / 64);
                c.eval_every = 50000 / 64;
                c.lr = 0.032;
                c.cosine = true;
            }
            (Preset::Ci, "vit") => {
                c.train_size = 2048;
                c.test_size = 512;
                c.steps = 384;
                c.eval_every = 96;
                c.lr = 1e-3;
                c.cosine = true;
                c.warmup_steps = 32;
            }
            (Preset::Paper, "vit") => {
                c.train_size = 50000;
                c.test_size = 10000;
                c.steps = 100 * (50000 / 64);
                c.eval_every = 50000 / 64;
                c.lr = 3e-4;
                c.cosine = true;
                c.warmup_steps = 10 * (50000 / 64);
            }
            _ => unreachable!("KNOWN_MODELS is checked above"),
        }
        // optimizer recipes per model (§5 / App B.2); the PJRT artifacts
        // bake these in, the native backend reads them from the config
        c.optimizer = match recipe {
            "mlp" => "sgd",
            "bagnet" => "momentum",
            _ => "adam",
        }
        .into();
        Ok(c)
    }

    /// LR cross-validation grid around the base LR. The paper uses 13 points
    /// for MLP (10^{-0.25 i}) and 5 log-spaced points for the larger nets;
    /// `ci` trims both.
    pub fn lr_grid(self, model: &str) -> Result<Vec<f64>> {
        let base = self.base(model)?.lr;
        Ok(match self {
            // smoke: 2-point grid (the sketched variants often need the
            // cooler LR — momentum+no-clip BagNet diverges at the recipe LR
            // under small budgets); ViT/AdamW is LR-robust, 1 point suffices
            Preset::Smoke if model == "vit" => vec![base],
            Preset::Smoke => vec![base * 0.32, base],
            Preset::Ci => vec![base * 0.32, base, base * 3.2],
            Preset::Paper => {
                if model == "mlp" {
                    (0..13).map(|i| 10f64.powf(-0.25 * i as f64)).collect()
                } else {
                    vec![base * 0.1, base * 0.32, base, base * 3.2, base * 10.0]
                }
            }
        })
    }

    pub fn seeds(self) -> Vec<u64> {
        match self {
            Preset::Smoke => vec![0],
            Preset::Ci => vec![0, 1],
            Preset::Paper => vec![0, 1, 2, 3, 4],
        }
    }

    pub fn budgets(self) -> Vec<f64> {
        match self {
            // paper sweeps p ∈ {0.05, 0.1, 0.2, 0.5} for Fig 3 and a denser
            // grid for the MLP figures
            Preset::Smoke => vec![0.05, 0.2, 0.5],
            Preset::Ci => vec![0.05, 0.1, 0.2, 0.5],
            Preset::Paper => vec![0.02, 0.05, 0.1, 0.2, 0.35, 0.5, 0.75],
        }
    }
}

/// Load a JSON config file into a TrainConfig.
pub fn load_config(path: &str) -> Result<TrainConfig> {
    let text = std::fs::read_to_string(path)?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    TrainConfig::from_json(&v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = TrainConfig::default();
        c.method = "l1".into();
        c.budget = 0.2;
        c.cosine = true;
        c.budget_schedule = vec![0.5, 0.25, 0.1];
        let v = c.to_json();
        let c2 = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c2.method, "l1");
        assert_eq!(c2.budget, 0.2);
        assert!(c2.cosine);
        assert_eq!(c2.steps, c.steps);
        assert_eq!(c2.budget_schedule, vec![0.5, 0.25, 0.1]);
    }

    #[test]
    fn presets_scale() {
        let ci = Preset::Ci.base("mlp").unwrap();
        let paper = Preset::Paper.base("mlp").unwrap();
        assert!(paper.steps > 10 * ci.steps);
        assert_eq!(Preset::Paper.lr_grid("mlp").unwrap().len(), 13);
        assert_eq!(Preset::Ci.lr_grid("mlp").unwrap().len(), 3);
    }

    #[test]
    fn cosine_schedule_decays() {
        let mut c = Preset::Ci.base("vit").unwrap();
        c.steps = 100;
        c.warmup_steps = 10;
        let warm = c.lr_at(0);
        let mid = c.lr_at(50);
        let end = c.lr_at(99);
        assert!(warm < c.lr, "warmup starts low");
        assert!(mid < c.lr && end < mid);
    }

    #[test]
    fn flat_schedule_for_mlp() {
        let c = Preset::Ci.base("mlp").unwrap();
        assert_eq!(c.lr_at(0), c.lr);
        assert_eq!(c.lr_at(500), c.lr);
    }

    #[test]
    fn bad_preset_and_model_error_with_hint() {
        let err = format!("{}", Preset::parse("warp").unwrap_err());
        assert!(err.contains("smoke|ci|paper"), "{err}");
        let err = format!("{}", Preset::Ci.base("resnet").unwrap_err());
        assert!(err.contains("mlp|bagnet|vit"), "{err}");
        assert!(Preset::Ci.lr_grid("resnet").is_err());
    }

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert_eq!(Backend::default(), Backend::Native);
        for b in [Backend::Native, Backend::Pjrt] {
            assert_eq!(Backend::parse(b.as_str()).unwrap(), b);
        }
    }

    #[test]
    fn bad_backend_errors_with_hint() {
        let err = format!("{}", Backend::parse("tpu").unwrap_err());
        assert!(err.contains("native|pjrt"), "{err}");
    }

    #[test]
    fn new_fields_roundtrip_and_default() {
        let mut c = TrainConfig::default();
        assert_eq!(c.backend, Backend::Native);
        assert_eq!(c.batch, 128);
        assert!(c.budget_schedule.is_empty());
        assert_eq!(c.threads, 0);
        assert_eq!(c.kernel, "auto");
        c.kernel = "simd".into();
        c.backend = Backend::Pjrt;
        c.optimizer = "adam".into();
        c.loss = "mse".into();
        c.batch = 64;
        c.threads = 3;
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.backend, Backend::Pjrt);
        assert_eq!(c2.optimizer, "adam");
        assert_eq!(c2.loss, "mse");
        assert_eq!(c2.batch, 64);
        assert_eq!(c2.threads, 3);
        assert_eq!(c2.kernel, "simd");
        // configs without the new keys fall back to defaults
        let legacy = crate::json::parse(r#"{"model":"mlp","method":"l1"}"#).unwrap();
        let c3 = TrainConfig::from_json(&legacy).unwrap();
        assert_eq!(c3.backend, Backend::Native);
        assert_eq!(c3.optimizer, "sgd");
        assert_eq!(c3.batch, 128);
        assert!(c3.budget_schedule.is_empty());
        assert_eq!(c3.kernel, "auto");
        // present-but-invalid values are loud errors, not silent fallbacks
        let bad = crate::json::parse(r#"{"backend":"pjtr"}"#).unwrap();
        assert!(TrainConfig::from_json(&bad).is_err());
        let bad =
            crate::json::parse(r#"{"budget_schedule":[0.5,"x"]}"#).unwrap();
        assert!(TrainConfig::from_json(&bad).is_err());
    }

    #[test]
    fn preset_optimizer_recipes() {
        assert_eq!(Preset::Ci.base("mlp").unwrap().optimizer, "sgd");
        assert_eq!(Preset::Ci.base("bagnet").unwrap().optimizer, "momentum");
        assert_eq!(Preset::Smoke.base("vit").unwrap().optimizer, "adam");
    }

    #[test]
    fn act_policy_fields_roundtrip_and_default() {
        let mut c = TrainConfig::default();
        assert_eq!(c.act_policy, "auto");
        assert_eq!(c.act_budget, 0.0);
        assert!(c.act_schedule.is_empty());
        c.act_policy = "kept".into();
        c.act_budget = 0.25;
        c.act_schedule = vec![0.5, 0.25, 0.1];
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.act_policy, "kept");
        assert_eq!(c2.act_budget, 0.25);
        assert_eq!(c2.act_schedule, vec![0.5, 0.25, 0.1]);
        // configs without the new keys fall back to defaults
        let legacy = crate::json::parse(r#"{"model":"mlp"}"#).unwrap();
        let c3 = TrainConfig::from_json(&legacy).unwrap();
        assert_eq!(c3.act_policy, "auto");
        assert_eq!(c3.act_budget, 0.0);
        assert!(c3.act_schedule.is_empty());
        // present-but-invalid entries are loud errors
        let bad = crate::json::parse(r#"{"act_schedule":[0.5,"x"]}"#).unwrap();
        assert!(TrainConfig::from_json(&bad).is_err());
    }

    #[test]
    fn dp_fields_roundtrip_and_default() {
        let mut c = TrainConfig::default();
        assert_eq!(c.replicas, 0);
        assert_eq!(c.reduce, "dense");
        assert_eq!(c.stale, 0);
        c.replicas = 4;
        c.reduce = "sparse".into();
        c.stale = 1;
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.replicas, 4);
        assert_eq!(c2.reduce, "sparse");
        assert_eq!(c2.stale, 1);
        // configs without the new keys fall back to defaults
        let legacy = crate::json::parse(r#"{"model":"mlp"}"#).unwrap();
        let c3 = TrainConfig::from_json(&legacy).unwrap();
        assert_eq!(c3.replicas, 0);
        assert_eq!(c3.reduce, "dense");
        assert_eq!(c3.stale, 0);
        // serve admission control: default unbounded
        assert_eq!(ServeConfig::default().queue_cap, 0);
    }

    #[test]
    fn fault_fields_roundtrip_and_default() {
        let mut c = TrainConfig::default();
        assert!(c.fault_spec.is_empty());
        assert_eq!(c.ckpt_every, 0);
        assert!(c.ckpt_path.is_empty());
        assert!(c.resume.is_empty());
        c.fault_spec = "lane_drop@p=0.1,kill@step=20".into();
        c.ckpt_every = 20;
        c.ckpt_path = "results/chaos.ckpt".into();
        c.resume = "results/chaos.ckpt".into();
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.fault_spec, "lane_drop@p=0.1,kill@step=20");
        assert_eq!(c2.ckpt_every, 20);
        assert_eq!(c2.ckpt_path, "results/chaos.ckpt");
        assert_eq!(c2.resume, "results/chaos.ckpt");
        // configs without the new keys fall back to defaults
        let legacy = crate::json::parse(r#"{"model":"mlp"}"#).unwrap();
        let c3 = TrainConfig::from_json(&legacy).unwrap();
        assert!(c3.fault_spec.is_empty());
        assert_eq!(c3.ckpt_every, 0);
        assert!(c3.resume.is_empty());
        // serve deadline: default disabled
        assert_eq!(ServeConfig::default().request_timeout_us, 0);
    }

    #[test]
    fn deep_models_inherit_shallow_recipes() {
        let d = Preset::Ci.base("bagnet_deep").unwrap();
        let s = Preset::Ci.base("bagnet").unwrap();
        assert_eq!(d.model, "bagnet_deep");
        assert_eq!(d.lr, s.lr);
        assert_eq!(d.optimizer, "momentum");
        let d = Preset::Smoke.base("vit_deep").unwrap();
        assert_eq!(d.model, "vit_deep");
        assert_eq!(d.optimizer, "adam");
        assert!(d.cosine);
    }
}
