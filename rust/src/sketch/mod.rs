//! Native mirror of the paper's sketching algorithms (§3–§4).
//!
//! The rust coordinator needs these outside the AOT graphs: the pipeline
//! simulator compresses inter-stage gradients with them, the eq6 bench
//! drives the sparse GEMMs from them, and the property-test suite checks the
//! same invariants the python oracle suite checks — so the two language
//! implementations cross-validate through `rust/tests/integration_pjrt.rs`
//! against the `micro_*` artifacts.

use crate::rng::Pcg64;
use crate::tensor::{Mat, MatView};

/// Reusable buffers for the per-site column-planning pipeline
/// (scores → waterfilling → gates → kept list). One instance lives in a
/// training `Workspace` and is threaded through every sketched backward
/// via `SketchCtx`, so a steady-state step plans its columns without
/// heap allocation. The value-returning functions below remain as thin
/// allocating wrappers for tests, benches and one-off callers.
#[derive(Default)]
pub struct SketchScratch {
    abs: Vec<f64>,
    sq: Vec<f64>,
    sum: Vec<f64>,
    sort: Vec<(f64, usize)>,
    suffix: Vec<f64>,
    /// Column scores of the last planned site.
    pub scores: Vec<f32>,
    /// Waterfilled keep-probabilities of the last planned site.
    pub p: Vec<f32>,
    /// Gate draws of the last planned site.
    pub z: Vec<bool>,
    /// Kept-column list (index, 1/pᵢ) of the last planned site.
    pub kept: Vec<(usize, f32)>,
    /// Compact dW staging buffer for the kept-input backward
    /// (`[d_out, m]` where m = kept input columns); taken with
    /// `std::mem::take` around planning so it can coexist with the
    /// borrowed kept list.
    pub dwg: Vec<f32>,
    /// When armed (see [`SketchScratch::begin_kept_log`]), every
    /// `plan_columns` call appends a copy of its kept list here, in call
    /// order. The data-parallel sparse reducer replays this log to know
    /// which gradient rows each gated GEMM actually populated, without
    /// re-running the gates. Off by default — the activation-stash path
    /// also plans columns during the forward, and only backward plans
    /// describe gradient sparsity.
    log_on: bool,
    log_len: usize,
    log: Vec<Vec<(usize, f32)>>,
}

impl SketchScratch {
    pub fn new() -> SketchScratch {
        SketchScratch::default()
    }

    /// Bytes currently held by the planning buffers (capacities, not
    /// lengths — what the allocator actually reserves). Feeds the
    /// workspace-byte accounting.
    pub fn bytes(&self) -> usize {
        use std::mem::size_of;
        self.abs.capacity() * size_of::<f64>()
            + self.sq.capacity() * size_of::<f64>()
            + self.sum.capacity() * size_of::<f64>()
            + self.sort.capacity() * size_of::<(f64, usize)>()
            + self.suffix.capacity() * size_of::<f64>()
            + self.scores.capacity() * size_of::<f32>()
            + self.p.capacity() * size_of::<f32>()
            + self.z.capacity() * size_of::<bool>()
            + self.kept.capacity() * size_of::<(usize, f32)>()
            + self.dwg.capacity() * size_of::<f32>()
            + self
                .log
                .iter()
                .map(|l| l.capacity() * size_of::<(usize, f32)>())
                .sum::<usize>()
    }

    /// Arm the kept-list log and reset its cursor. The entry buffers are
    /// reused across steps (clear + refill), so a steady-state logged
    /// backward allocates nothing once warm.
    pub fn begin_kept_log(&mut self) {
        self.log_on = true;
        self.log_len = 0;
    }

    /// Disarm the kept-list log (entries stay readable until the next
    /// [`SketchScratch::begin_kept_log`]).
    pub fn end_kept_log(&mut self) {
        self.log_on = false;
    }

    /// Kept lists recorded since the last `begin_kept_log`, one per
    /// `plan_columns` call, in call order.
    pub fn kept_log(&self) -> &[Vec<(usize, f32)>] {
        &self.log[..self.log_len]
    }

    /// Run the full pipeline for one backward site on the output gradient
    /// `g`: column scores (or the uniform `per_column` probabilities),
    /// waterfilling, correlated or independent gates (chosen by the method
    /// name, consuming the site's RNG in the same order as always), and
    /// the kept list. Returns the kept columns; `self.p` holds the
    /// probabilities they were drawn with.
    pub fn plan_columns(
        &mut self,
        method: &str,
        budget: f64,
        g: MatView<'_>,
        w_mat: Option<&Mat>,
        rng: &mut Pcg64,
    ) -> &[(usize, f32)] {
        let dout = g.cols;
        if method == "per_column" {
            self.p.clear();
            self.p.resize(dout, budget.clamp(1e-6, 1.0) as f32);
        } else {
            self.column_scores_into(method, g, w_mat);
            self.pstar_into(budget * dout as f64);
        }
        let independent = method == "per_column" || method.ends_with("_ind");
        if independent {
            independent_bernoulli_into(rng, &self.p, &mut self.z);
        } else {
            correlated_bernoulli_into(rng, &self.p, &mut self.z);
        }
        kept_columns_into(&self.z, &self.p, &mut self.kept);
        if self.log_on {
            if self.log_len == self.log.len() {
                self.log.push(Vec::new());
            }
            let entry = &mut self.log[self.log_len];
            entry.clear();
            entry.extend_from_slice(&self.kept);
            self.log_len += 1;
        }
        &self.kept
    }

    /// Column scores for the coordinate methods (§4.2) into `self.scores`.
    pub fn column_scores_into(
        &mut self,
        method: &str,
        g: MatView<'_>,
        w_mat: Option<&Mat>,
    ) {
        let (b, dout) = (g.rows, g.cols);
        self.abs.clear();
        self.abs.resize(dout, 0.0);
        self.sq.clear();
        self.sq.resize(dout, 0.0);
        self.sum.clear();
        self.sum.resize(dout, 0.0);
        // Per-column f64 moment accumulation; vectorized across columns
        // under `--kernel simd` (bitwise identical to the scalar loop —
        // each column's op order is unchanged).
        for i in 0..b {
            crate::tensor::kernels::vec::accum_scores(
                g.row(i),
                &mut self.abs,
                &mut self.sq,
                &mut self.sum,
            );
        }
        let (abs, sq, sum) = (&self.abs, &self.sq, &self.sum);
        let var = |j: usize| {
            (sq[j] / b as f64 - (sum[j] / b as f64).powi(2)).max(0.0)
        };
        self.scores.clear();
        self.scores.extend((0..dout).map(|j| {
            (match method {
                "l1" | "l1_ind" => abs[j] * abs[j],
                "l1_sq" => (abs[j] * abs[j]).powi(2),
                "l2" => sq[j],
                "l2_sq" => sq[j] * sq[j],
                "var" => var(j),
                "var_sq" => var(j) * var(j),
                "ds" => {
                    let wm = w_mat.expect("ds needs W");
                    let row_sq: f64 = wm
                        .row(j)
                        .iter()
                        .map(|&x| (x as f64) * (x as f64))
                        .sum();
                    (sq[j] / b as f64) * row_sq
                }
                other => panic!("unknown coordinate method {other}"),
            }) as f32
        }));
    }

    /// Algorithm 1 — waterfilling `self.scores` under budget `r` into
    /// `self.p`: minimize Σ wᵢ/pᵢ s.t. Σ pᵢ = r, 0 < pᵢ ≤ 1.
    ///
    /// KKT gives pᵢ* = min(1, √wᵢ / √λ); we find the saturation split
    /// exactly by scanning candidate counts of saturated coordinates
    /// (sorted order), which matches the thresholding construction in the
    /// paper's Appendix A.2. The sort is unstable (no allocation); ties
    /// carry equal scores, hence equal pᵢ, so the output is
    /// order-independent.
    pub fn pstar_into(&mut self, r: f64) {
        let w = &self.scores;
        let n = w.len();
        self.p.clear();
        if r >= n as f64 {
            self.p.resize(n, 1.0);
            return;
        }
        self.sort.clear();
        self.sort.extend(
            w.iter()
                .enumerate()
                .map(|(i, &wi)| ((wi.max(0.0) as f64).sqrt(), i)),
        );
        self.sort
            .sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let t = &self.sort;
        let total_t: f64 = t.iter().map(|x| x.0).sum();
        if total_t <= 0.0 {
            self.p.resize(n, (r / n as f64).clamp(1e-6, 1.0) as f32);
            return;
        }
        // suffix sums of sorted t
        self.suffix.clear();
        self.suffix.resize(n + 1, 0.0);
        for k in (0..n).rev() {
            self.suffix[k] = self.suffix[k + 1] + t[k].0;
        }
        let suffix = &self.suffix;
        let mut lam_sqrt = suffix[0] / r; // k = 0 candidate
        for k in 0..n {
            let rem = r - k as f64;
            if rem <= 0.0 {
                break;
            }
            let cand = suffix[k] / rem;
            let prev_ok = k == 0 || t[k - 1].0 >= cand - 1e-12;
            let cur_ok = t[k].0 <= cand + 1e-12;
            if prev_ok && cur_ok {
                lam_sqrt = cand;
                break;
            }
        }
        self.p.resize(n, 0.0);
        for (tv, i) in t {
            self.p[*i] = ((tv / lam_sqrt).min(1.0)).clamp(1e-6, 1.0) as f32;
        }
    }
}

/// Algorithm 1 — waterfilling (allocating wrapper over
/// [`SketchScratch::pstar_into`]).
pub fn pstar_from_weights(w: &[f32], r: f64) -> Vec<f32> {
    let mut s = SketchScratch::new();
    s.scores.extend_from_slice(w);
    s.pstar_into(r);
    s.p
}

/// Algorithm 2 — correlated exact-r sampling (systematic sampling) into a
/// reused gate buffer.
///
/// Draw u ~ U(0,1]; index i is selected iff some u+ℓ lies in the cumulative
/// interval (C_{i-1}, C_i]. Marginals are exactly pᵢ and the number of
/// selected indices equals Σpᵢ (up to the integer boundary) almost surely.
pub fn correlated_bernoulli_into(rng: &mut Pcg64, p: &[f32], out: &mut Vec<bool>) {
    let u = rng.f64().max(1e-12);
    out.clear();
    let mut c_prev = 0.0f64;
    for &pi in p {
        let c = c_prev + pi as f64;
        let lo = (c_prev - u).floor();
        let hi = (c - u).floor();
        out.push(hi > lo);
        c_prev = c;
    }
}

/// Algorithm 2 — correlated sampling (allocating wrapper).
pub fn correlated_bernoulli(rng: &mut Pcg64, p: &[f32]) -> Vec<bool> {
    let mut out = Vec::with_capacity(p.len());
    correlated_bernoulli_into(rng, p, &mut out);
    out
}

/// Independent Bernoulli(pᵢ) gates (Lemma 3.4 sampling model) into a
/// reused gate buffer.
pub fn independent_bernoulli_into(rng: &mut Pcg64, p: &[f32], out: &mut Vec<bool>) {
    out.clear();
    out.extend(p.iter().map(|&pi| rng.bernoulli(pi as f64)));
}

/// Independent Bernoulli gates (allocating wrapper).
pub fn independent_bernoulli(rng: &mut Pcg64, p: &[f32]) -> Vec<bool> {
    let mut out = Vec::with_capacity(p.len());
    independent_bernoulli_into(rng, p, &mut out);
    out
}

/// Kept-column list (index, 1/pᵢ) for the sparse backward kernels, into a
/// reused buffer.
pub fn kept_columns_into(z: &[bool], p: &[f32], out: &mut Vec<(usize, f32)>) {
    out.clear();
    out.extend(
        z.iter()
            .zip(p)
            .enumerate()
            .filter(|(_, (&zi, _))| zi)
            .map(|(i, (_, &pi))| (i, 1.0 / pi)),
    );
}

/// Kept-column list (allocating wrapper).
pub fn kept_columns(z: &[bool], p: &[f32]) -> Vec<(usize, f32)> {
    let mut out = Vec::new();
    kept_columns_into(z, p, &mut out);
    out
}

/// Column importance weights for the coordinate methods (§4.2) on a native
/// gradient matrix (allocating wrapper over
/// [`SketchScratch::column_scores_into`]). Mirrors python
/// `sketching.column_scores`.
pub fn column_scores(method: &str, g: &Mat, w_mat: Option<&Mat>) -> Vec<f32> {
    let mut s = SketchScratch::new();
    s.column_scores_into(method, g.view(), w_mat);
    s.scores
}

/// Analytic FLOP model for one sketched linear backward (Eq. 6's ρ(V)).
///
/// Exact backward: 2·B·d_out·d_in (dX) + 2·B·d_out·d_in (dW).
/// Sketched with r kept columns: both GEMMs shrink by r/d_out, plus the
/// score pass (B·d_out) and the waterfilling sort (d_out log d_out).
pub fn backward_flops(batch: usize, dout: usize, din: usize, kept: usize) -> f64 {
    let gemm = 4.0 * batch as f64 * kept as f64 * din as f64;
    let scores = 2.0 * batch as f64 * dout as f64;
    let sort = dout as f64 * (dout.max(2) as f64).log2();
    gemm + scores + sort
}

/// ρ(V) cost ratio of a sketched step vs exact for one layer (Eq. 6).
pub fn cost_ratio(batch: usize, dout: usize, din: usize, budget: f64) -> f64 {
    let kept = ((budget * dout as f64).round() as usize).clamp(1, dout);
    backward_flops(batch, dout, din, kept)
        / backward_flops(batch, dout, din, dout)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pstar_budget_met() {
        let w: Vec<f32> = (1..=32).map(|i| (i * i) as f32).collect();
        for r in [2.0, 8.0, 20.0] {
            let p = pstar_from_weights(&w, r);
            let s: f64 = p.iter().map(|&x| x as f64).sum();
            assert!((s - r).abs() < 0.05, "sum {s} != r {r}");
            assert!(p.iter().all(|&x| x > 0.0 && x <= 1.0));
        }
    }

    #[test]
    fn pstar_proportional_below_saturation() {
        // with a tight budget and mild weights: p_i ∝ √w_i
        let w = [1.0f32, 4.0, 9.0, 16.0];
        let p = pstar_from_weights(&w, 1.0);
        for i in 1..4 {
            let ratio = p[i] / p[0];
            let expect = ((w[i] / w[0]) as f64).sqrt() as f32;
            assert!((ratio - expect).abs() < 1e-3, "{ratio} vs {expect}");
        }
    }

    #[test]
    fn pstar_saturation() {
        let w = [1000.0f32, 1.0, 1.0, 1.0];
        let p = pstar_from_weights(&w, 2.0);
        assert!((p[0] - 1.0).abs() < 1e-6);
        let tail: f64 = p[1..].iter().map(|&x| x as f64).sum();
        assert!((tail - 1.0).abs() < 0.02);
    }

    #[test]
    fn correlated_count_is_exact() {
        let mut rng = Pcg64::new(5, 0);
        let w: Vec<f32> = (1..=64).map(|i| i as f32).collect();
        let p = pstar_from_weights(&w, 12.0);
        for _ in 0..100 {
            let z = correlated_bernoulli(&mut rng, &p);
            let count = z.iter().filter(|&&b| b).count();
            assert!((count as i64 - 12).abs() <= 1, "count {count}");
        }
    }

    #[test]
    fn correlated_marginals() {
        let p = [0.9f32, 0.5, 0.25, 0.25, 0.1];
        let mut rng = Pcg64::new(6, 0);
        let mut freq = [0.0f64; 5];
        let trials = 20000;
        for _ in 0..trials {
            let z = correlated_bernoulli(&mut rng, &p);
            for (f, &zi) in freq.iter_mut().zip(&z) {
                if zi {
                    *f += 1.0;
                }
            }
        }
        for (f, &pi) in freq.iter().zip(&p) {
            assert!((f / trials as f64 - pi as f64).abs() < 0.02);
        }
    }

    #[test]
    fn scores_match_definitions() {
        let g = Mat::from_rows(vec![vec![1.0, -2.0], vec![3.0, 0.0]]);
        let l1 = column_scores("l1", &g, None);
        assert!((l1[0] - 16.0).abs() < 1e-5); // (|1|+|3|)²
        assert!((l1[1] - 4.0).abs() < 1e-5);
        let l2 = column_scores("l2", &g, None);
        assert!((l2[0] - 10.0).abs() < 1e-5);
        let w = Mat::from_rows(vec![vec![2.0, 0.0], vec![0.0, 1.0]]);
        let ds = column_scores("ds", &g, Some(&w));
        // Γ_00 = (1+9)/2 = 5, row0 ‖·‖² = 4 → 20
        assert!((ds[0] - 20.0).abs() < 1e-4, "{ds:?}");
    }

    #[test]
    fn cost_ratio_monotone() {
        let r05 = cost_ratio(128, 64, 64, 0.05);
        let r20 = cost_ratio(128, 64, 64, 0.2);
        let r100 = cost_ratio(128, 64, 64, 1.0);
        assert!(r05 < r20 && r20 < 1.01 && (r100 - 1.0).abs() < 0.05);
    }

    #[test]
    fn kept_columns_inverse_prob() {
        let z = [true, false, true];
        let p = [0.5f32, 0.9, 0.25];
        let kept = kept_columns(&z, &p);
        assert_eq!(kept, vec![(0, 2.0), (2, 4.0)]);
    }
}
