//! PCG64 pseudo-random number generator substrate.
//!
//! Offline build: no `rand` crate — this is a from-scratch PCG-XSL-RR 128/64
//! (O'Neill 2014) with the helpers the coordinator needs: uniforms,
//! gaussians (Box–Muller), Fisher–Yates shuffles, Bernoulli gates and
//! categorical draws. Deterministic given a seed + stream id, which is what
//! makes every experiment in EXPERIMENTS.md replayable.
//!
//! Production code never calls [`Pcg64::new`] directly: every stream
//! derivation routes through the named constructors in [`streams`], whose
//! registry proves the (seed-mix, stream-range) pairs disjoint.
//! `uavjp-analyze` enforces this (DESIGN.md §7.8).

pub mod streams;

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 1) | 1) ^ 0xda3e39cb94b95bdb;
        let mut rng = Pcg64 { state: 0, inc: (inc << 1) | 1 };
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent generator (for per-run / per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift rejection for unbiased bounded ints.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal (Box–Muller; one value per call, no caching).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Raw generator state as 4 little-endian u64 words
    /// `[state_lo, state_hi, inc_lo, inc_hi]` — the checkpoint layer
    /// persists these so `train --resume` can fast-forward every stream
    /// to exactly where the interrupted run left it.
    pub fn state_words(&self) -> [u64; 4] {
        [
            self.state as u64,
            (self.state >> 64) as u64,
            self.inc as u64,
            (self.inc >> 64) as u64,
        ]
    }

    /// Rebuild a generator from [`Pcg64::state_words`]; the next draw
    /// continues the saved sequence bit-for-bit.
    pub fn from_state_words(w: [u64; 4]) -> Pcg64 {
        Pcg64 {
            state: (w[0] as u128) | ((w[1] as u128) << 64),
            inc: (w[2] as u128) | ((w[3] as u128) << 64),
        }
    }

    /// Categorical draw from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_dependent() {
        let a: Vec<u64> = (0..4).map(|_| 0).collect::<Vec<_>>();
        let _ = a;
        let mut r1 = Pcg64::new(42, 0);
        let mut r2 = Pcg64::new(42, 0);
        let mut r3 = Pcg64::new(42, 1);
        let s1: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let s2: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        let s3: Vec<u64> = (0..8).map(|_| r3.next_u64()).collect();
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg64::new(7, 0);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(9, 3);
        let n = 40000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_unbiased() {
        let mut r = Pcg64::new(11, 0);
        let mut counts = [0usize; 5];
        for _ in 0..50000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(13, 0);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_words_roundtrip_resumes_the_sequence() {
        let mut r = Pcg64::new(23, 5);
        for _ in 0..17 {
            r.next_u64();
        }
        let mut resumed = Pcg64::from_state_words(r.state_words());
        let a: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| resumed.next_u64()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn categorical_prefers_heavy() {
        let mut r = Pcg64::new(17, 0);
        let mut c = [0usize; 3];
        for _ in 0..30000 {
            c[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(c[2] > c[1] && c[1] > c[0]);
        assert!((c[2] as f64 / 30000.0 - 0.7).abs() < 0.03);
    }
}
