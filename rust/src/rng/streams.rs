//! Central registry of every production PCG64 stream derivation.
//!
//! Every unbiasedness and bitwise-replay guarantee in this repo (sketch
//! gates, activation gates, fault injection, per-lane replica streams —
//! DESIGN.md §7.4–§7.7) rests on the PCG64 streams being *provably
//! disjoint*: a silent collision would correlate gate draws with data or
//! fault draws and quietly bias gradients. Historically each module
//! derived its streams with an ad-hoc `Pcg64::new(seed ^ 0x…, stream)`
//! literal; this module replaces those literals with named constructors
//! backed by a declarative [`REGISTRY`], and `uavjp-analyze`
//! (DESIGN.md §7.8) lints the tree so no undeclared derivation can creep
//! back in.
//!
//! Disjointness rule: two registry entries *collide* iff they share the
//! same [`SeedMix`] (same variant **and** same constant) and their
//! stream-id ranges overlap. Entries with different mixes may reuse
//! stream ids — the PCG64 increment is derived from the stream id, but
//! the seed mix keeps the state trajectories decorrelated — while
//! same-mix entries must keep disjoint ranges ([`check_disjoint`] is
//! asserted by the analyzer's own test suite).
//!
//! Adding a stream (the §7.8 recipe):
//! 1. add a [`StreamSpec`] row to [`REGISTRY`] with a fresh
//!    (mix, range) pair — `cargo test rng::streams` fails on overlap;
//! 2. add a named constructor below that asserts its ids into the range;
//! 3. route the call site through the constructor — a raw
//!    `Pcg64::new` outside `src/rng/` fails `cargo run --bin
//!    uavjp-analyze`;
//! 4. add the row to the DESIGN.md §7.8 stream table.

use super::Pcg64;

/// How a constructor folds the user seed before it reaches
/// [`Pcg64::new`]. The mix constant is part of the identity: two entries
/// with different xor constants are distinct families even when their
/// stream ranges overlap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedMix {
    /// `Pcg64::new(seed, stream)` — the seed passes through untouched.
    Raw,
    /// `Pcg64::new(seed ^ c, stream)`.
    Xor(u64),
    /// `Pcg64::new(seed.wrapping_add(c), stream)`.
    Add(u64),
    /// `Pcg64::new(c, stream)` — seed-independent (draw-free probes).
    Fixed(u64),
}

/// One declared stream family: a seed mix plus an inclusive stream-id
/// range, with owner/purpose docs that the DESIGN.md table mirrors.
#[derive(Clone, Copy, Debug)]
pub struct StreamSpec {
    /// Stable kebab-case name (referenced by diagnostics and docs).
    pub name: &'static str,
    /// Seed transformation applied before [`Pcg64::new`].
    pub mix: SeedMix,
    /// First stream id of the family (inclusive).
    pub lo: u64,
    /// Last stream id of the family (inclusive).
    pub hi: u64,
    /// Owning module — where the constructor is called from.
    pub owner: &'static str,
    /// What the draws decide.
    pub purpose: &'static str,
}

impl StreamSpec {
    /// True when `other` draws from the same seed-mix family and the
    /// stream ranges overlap — the collision the registry exists to
    /// prevent.
    pub fn collides(&self, other: &StreamSpec) -> bool {
        self.mix == other.mix && self.lo <= other.hi && other.lo <= self.hi
    }
}

/// Every production stream derivation in the tree. `uavjp-analyze`
/// checks each non-test `Pcg64::new` call site against this table, and
/// [`check_disjoint`] proves the table itself is collision-free.
pub const REGISTRY: &[StreamSpec] = &[
    StreamSpec {
        name: "data-split",
        mix: SeedMix::Raw,
        lo: 1,
        hi: 2,
        owner: "data::Dataset",
        purpose: "synthetic train (1) / test (2) split generation",
    },
    StreamSpec {
        name: "train-batch",
        mix: SeedMix::Add(77),
        lo: 3,
        hi: 3,
        owner: "native::trainer, coordinator::trainer",
        purpose: "minibatch index sampling (native and PJRT loops share it)",
    },
    StreamSpec {
        name: "sketch-gates",
        mix: SeedMix::Xor(0x9e37_79b9),
        lo: 11,
        hi: 11,
        owner: "native::trainer",
        purpose: "per-step sketch sign/gate draws for the VJP estimator",
    },
    StreamSpec {
        name: "act-gates",
        mix: SeedMix::Xor(0x5_1ac7),
        lo: 13,
        hi: 13,
        owner: "native::trainer",
        purpose: "activation-policy kept-column gate draws",
    },
    StreamSpec {
        name: "faults",
        mix: SeedMix::Xor(0xfa_0175),
        lo: 17,
        hi: 17,
        owner: "faults::FaultPlan",
        purpose: "deterministic fault-injection schedule",
    },
    StreamSpec {
        name: "mnist-anchor",
        mix: SeedMix::Xor(0xa17c),
        lo: 100,
        hi: 109,
        owner: "data (mnist-like)",
        purpose: "per-class anchor images, stream 100 + class",
    },
    StreamSpec {
        name: "cifar-anchor",
        mix: SeedMix::Xor(0xc1fa),
        lo: 200,
        hi: 209,
        owner: "data (cifar-like)",
        purpose: "per-class anchor images, stream 200 + class",
    },
    StreamSpec {
        name: "layer-init",
        mix: SeedMix::Xor(0x1e57),
        lo: 300,
        hi: 999,
        owner: "native::layer, native::attention",
        purpose: "He/embedding weight init, one stream per tensor",
    },
    StreamSpec {
        name: "lane-sketch-gates",
        mix: SeedMix::Xor(0x9e37_79b9),
        lo: 1100,
        hi: 1107,
        owner: "replicate::ReplicaGroup",
        purpose: "per-lane sketch gates, stream 1100 + lane",
    },
    StreamSpec {
        name: "lane-act-gates",
        mix: SeedMix::Xor(0x5_1ac7),
        lo: 1300,
        hi: 1307,
        owner: "replicate::ReplicaGroup",
        purpose: "per-lane activation gates, stream 1300 + lane",
    },
    StreamSpec {
        name: "variance-trial",
        mix: SeedMix::Xor(0xabcd),
        lo: 0,
        hi: 4095,
        owner: "coordinator::variance",
        purpose: "per-trial probe streams for σ² estimation",
    },
    StreamSpec {
        name: "null",
        mix: SeedMix::Fixed(0),
        lo: 0,
        hi: 0,
        owner: "coordinator::variance",
        purpose: "draw-free placeholder for exact (non-stochastic) plans",
    },
    StreamSpec {
        name: "ptest",
        mix: SeedMix::Raw,
        lo: 0x9e37,
        hi: 0x9e37,
        owner: "ptest",
        purpose: "property-test case generation",
    },
];

/// Look up a registry entry by name (panics on a typo — registry names
/// are compile-time constants at every call site below).
fn spec(name: &str) -> &'static StreamSpec {
    REGISTRY
        .iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("unknown rng stream family {name:?}"))
}

/// Construct the generator for `spec`, asserting `stream` into the
/// declared range and applying the declared seed mix.
fn make(name: &str, seed: u64, stream: u64) -> Pcg64 {
    let s = spec(name);
    assert!(
        (s.lo..=s.hi).contains(&stream),
        "stream {stream} outside registered range {}..={} for {name}",
        s.lo,
        s.hi,
    );
    let mixed = match s.mix {
        SeedMix::Raw => seed,
        SeedMix::Xor(c) => seed ^ c,
        SeedMix::Add(c) => seed.wrapping_add(c),
        SeedMix::Fixed(c) => c,
    };
    Pcg64::new(mixed, stream)
}

/// `data-split`: synthetic dataset generation, `stream` ∈ {1 train,
/// 2 test}.
pub fn data_split(seed: u64, stream: u64) -> Pcg64 {
    make("data-split", seed, stream)
}

/// `train-batch`: the minibatch sampling stream both training loops use.
pub fn train_batch(seed: u64) -> Pcg64 {
    make("train-batch", seed, 3)
}

/// `sketch-gates`: the single-trainer sketch gate stream.
pub fn sketch_gates(seed: u64) -> Pcg64 {
    make("sketch-gates", seed, 11)
}

/// `act-gates`: the single-trainer activation-policy gate stream.
pub fn act_gates(seed: u64) -> Pcg64 {
    make("act-gates", seed, 13)
}

/// `faults`: the fault-injection schedule stream.
pub fn faults(seed: u64) -> Pcg64 {
    make("faults", seed, 17)
}

/// `mnist-anchor`: per-class anchor image stream, `cls` ∈ 0..10.
pub fn mnist_anchor(seed: u64, cls: u64) -> Pcg64 {
    make("mnist-anchor", seed, 100 + cls)
}

/// `cifar-anchor`: per-class anchor image stream, `cls` ∈ 0..10.
pub fn cifar_anchor(seed: u64, cls: u64) -> Pcg64 {
    make("cifar-anchor", seed, 200 + cls)
}

/// `layer-init`: weight-init stream for one tensor; `stream` is the
/// layer-unique id models assign from 300 upward.
pub fn layer_init(seed: u64, stream: u64) -> Pcg64 {
    make("layer-init", seed, stream)
}

/// `lane-sketch-gates`: replica `lane`'s sketch gate stream.
pub fn lane_sketch_gates(seed: u64, lane: u64) -> Pcg64 {
    make("lane-sketch-gates", seed, 1100 + lane)
}

/// `lane-act-gates`: replica `lane`'s activation gate stream.
pub fn lane_act_gates(seed: u64, lane: u64) -> Pcg64 {
    make("lane-act-gates", seed, 1300 + lane)
}

/// `variance-trial`: probe stream for σ²-estimation trial `t`.
pub fn variance_trial(seed: u64, t: u64) -> Pcg64 {
    make("variance-trial", seed, t)
}

/// `null`: a fixed generator for plans that never draw (exact VJP
/// probes) — keeps the draw-free invariant visible at the type level.
pub fn null() -> Pcg64 {
    make("null", 0, 0)
}

/// `ptest`: the property-test harness stream.
pub fn ptest(seed: u64) -> Pcg64 {
    make("ptest", seed, 0x9e37)
}

/// Verify the registry is pairwise collision-free. Returns the offending
/// pair of names on failure; the analyzer test suite asserts `Ok`.
pub fn check_disjoint() -> Result<(), (&'static str, &'static str)> {
    for (i, a) in REGISTRY.iter().enumerate() {
        for b in &REGISTRY[i + 1..] {
            if a.collides(b) {
                return Err((a.name, b.name));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_pairwise_disjoint() {
        assert_eq!(check_disjoint(), Ok(()));
    }

    #[test]
    fn constructors_match_legacy_derivations() {
        // Each named constructor must reproduce the pre-registry ad-hoc
        // derivation bit-for-bit, or every seeded experiment in
        // EXPERIMENTS.md silently changes.
        let seed = 0xdead_beef_u64;
        let pairs: Vec<(Pcg64, Pcg64)> = vec![
            (data_split(seed, 1), Pcg64::new(seed, 1)),
            (train_batch(seed), Pcg64::new(seed.wrapping_add(77), 3)),
            (sketch_gates(seed), Pcg64::new(seed ^ 0x9e37_79b9, 11)),
            (act_gates(seed), Pcg64::new(seed ^ 0x5_1ac7, 13)),
            (faults(seed), Pcg64::new(seed ^ 0xfa_0175, 17)),
            (mnist_anchor(seed, 4), Pcg64::new(seed ^ 0xa17c, 104)),
            (cifar_anchor(seed, 9), Pcg64::new(seed ^ 0xc1fa, 209)),
            (layer_init(seed, 302), Pcg64::new(seed ^ 0x1e57, 302)),
            (lane_sketch_gates(seed, 5), Pcg64::new(seed ^ 0x9e37_79b9, 1105)),
            (lane_act_gates(seed, 5), Pcg64::new(seed ^ 0x5_1ac7, 1305)),
            (variance_trial(seed, 7), Pcg64::new(seed ^ 0xabcd, 7)),
            (null(), Pcg64::new(0, 0)),
            (ptest(seed), Pcg64::new(seed, 0x9e37)),
        ];
        for (mut a, mut b) in pairs {
            for _ in 0..4 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
    }

    #[test]
    #[should_panic(expected = "outside registered range")]
    fn out_of_range_stream_panics() {
        layer_init(1, 7);
    }
}
