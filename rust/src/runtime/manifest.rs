//! Artifact manifest (artifacts/manifest.json) parsing.

use super::DType;
use crate::json::{self, Value};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(v: &Value) -> Result<TensorSpec> {
        let name = v
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("tensor spec missing name"))?
            .to_string();
        let dtype = DType::parse(
            v.get("dtype").as_str().ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        let shape = v
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?;
        Ok(TensorSpec { name, dtype, shape })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Value,
}

impl ArtifactSpec {
    /// Index of the input named `name`.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("{}: no input named {name}", self.name))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .as_usize()
            .ok_or_else(|| anyhow!("{}: meta key {key} missing", self.name))
    }
}

#[derive(Debug)]
pub struct Manifest {
    by_name: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let arts = root
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut by_name = BTreeMap::new();
        for a in arts {
            let name = a
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?;
            let outputs = a
                .get("outputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?;
            by_name.insert(
                name.clone(),
                ArtifactSpec { name, file, inputs, outputs, meta: a.get("meta").clone() },
            );
        }
        Ok(Manifest { by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.by_name.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "t1",
          "file": "t1.hlo.txt",
          "inputs": [
            {"name": "x", "dtype": "f32", "shape": [2, 3]},
            {"name": "y", "dtype": "s32", "shape": [2]}
          ],
          "outputs": [{"name": "loss", "dtype": "f32", "shape": []}],
          "meta": {"batch": 2, "model": "mlp"}
        }
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("t1").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].numel(), 6);
        assert_eq!(a.inputs[1].dtype, DType::S32);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.input_index("y").unwrap(), 1);
        assert!(a.input_index("z").is_err());
        assert_eq!(a.meta_usize("batch").unwrap(), 2);
        assert_eq!(a.meta.get("model").as_str(), Some("mlp"));
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("[1,2]").is_err());
        assert!(Manifest::parse(r#"{"artifacts":[{"file":"x"}]}"#).is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // integration-ish: if the repo's artifacts exist, they must parse
        let p = Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.get("train_mlp_l1").is_some());
            assert!(m.get("eval_mlp").is_some());
        }
    }
}
