//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT): one process-wide
//! client, an executable cache keyed by artifact name, and typed host
//! tensors (`HostTensor`) that mirror the manifest dtypes. Everything that
//! touches `xla` sits behind the `pjrt` cargo feature (DESIGN.md §7); the
//! manifest parser, [`hlo_stats`] and the [`HostTensor`] container stay
//! available in every build.
//!
//! Interchange is HLO **text** — `HloModuleProto::from_text_file` reassigns
//! instruction ids, which is what makes jax≥0.5 modules loadable on this
//! runtime (64-bit-id protos are rejected; see DESIGN.md §2).
//!
//! Outputs: the lowered entry computations are tuple-rooted and this PJRT
//! build returns the tuple as a *single* buffer, so `run` synchronizes to a
//! host literal and decomposes it. Training state therefore lives host-side
//! as `xla::Literal`s between steps; at the model sizes used here the
//! per-step host↔device copies are <3 MB and dwarfed by compute (measured
//! in EXPERIMENTS.md §Perf).

pub mod hlo_stats;
mod manifest;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};
#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

/// dtype tags used by the manifest (subset we actually emit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "s32" => DType::S32,
            "u32" => DType::U32,
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// A typed host tensor (row-major).
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    S32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn zeros(spec: &TensorSpec) -> HostTensor {
        let n: usize = spec.shape.iter().product();
        match spec.dtype {
            DType::F32 => HostTensor::F32(vec![0.0; n], spec.shape.clone()),
            DType::S32 => HostTensor::S32(vec![0; n], spec.shape.clone()),
            DType::U32 => HostTensor::U32(vec![0; n], spec.shape.clone()),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::S32(_, s) | HostTensor::U32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::S32(d, _) => d.len(),
            HostTensor::U32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn f32_scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elems", d.len());
        }
        Ok(d[0])
    }

    /// Byte size of one element of this dtype (4 for every supported one).
    pub fn elem_bytes(&self) -> usize {
        4
    }
}

/// Literal conversions (device interchange) — PJRT builds only.
#[cfg(feature = "pjrt")]
impl HostTensor {
    fn dims_i64(shape: &[usize]) -> Vec<i64> {
        shape.iter().map(|&d| d as i64).collect()
    }

    /// Convert to an `xla::Literal` for execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(d, s) => {
                xla::Literal::vec1(d).reshape(&Self::dims_i64(s))?
            }
            HostTensor::S32(d, s) => {
                xla::Literal::vec1(d).reshape(&Self::dims_i64(s))?
            }
            HostTensor::U32(d, s) => {
                xla::Literal::vec1(d).reshape(&Self::dims_i64(s))?
            }
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(match shape.ty() {
            xla::ElementType::F32 => HostTensor::F32(lit.to_vec::<f32>()?, dims),
            xla::ElementType::S32 => HostTensor::S32(lit.to_vec::<i32>()?, dims),
            xla::ElementType::U32 => HostTensor::U32(lit.to_vec::<u32>()?, dims),
            other => bail!("unsupported element type {other:?}"),
        })
    }
}

// NOTE: the xla crate's PjRtClient is Rc-backed (not Send/Sync), so each
// Runtime owns its client and everything PJRT stays on one thread. Sweeps
// are sequential on this single-core testbed anyway; the `pool` substrate is
// used only for CPU-native work (pipeline sim, tensor benches).

/// A compiled artifact ready to run.
#[cfg(feature = "pjrt")]
pub struct Executable {
    /// The manifest entry this executable was compiled from.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with host tensors; returns one HostTensor per manifest output.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_literals(&lits)
    }

    /// Execute with pre-built literals, decoding outputs to host tensors.
    pub fn run_literals(&self, lits: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = self.run_refs(&refs)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute returning raw literals (no host decode) — training loops
    /// chain these across steps without converting params to Vec<f32>.
    pub fn run_literals_raw(&self, lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with borrowed literals — the hot path: lets the training loop
    /// pass carried state by reference (zero host copies of params).
    pub fn run_refs(&self, lits: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if lits.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                lits.len()
            );
        }
        let res = self.exe.execute::<&xla::Literal>(lits)?;
        let buf = &res[0][0];
        let root = buf.to_literal_sync()?;
        // single-output computations lower to a bare array root; multi-output
        // ones to a tuple the PJRT build returns as one buffer.
        if self.spec.outputs.len() == 1 && root.array_shape().is_ok() {
            return Ok(vec![root]);
        }
        let outs = root.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Artifact loader + executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    /// Artifact directory this runtime loads from.
    pub dir: PathBuf,
    /// Parsed `manifest.json`.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open an artifacts directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { dir, manifest, client, cache: RefCell::new(HashMap::new()) })
    }

    /// The PJRT client backing this runtime.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Default artifacts dir: $UAVJP_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("UAVJP_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Runtime::open(dir)
    }

    /// Load (compile) an artifact by name; cached for the runtime lifetime.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        eprintln!(
            "[runtime] compiled {name} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let e = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Names of loaded (compiled) artifacts.
    pub fn loaded(&self) -> Vec<String> {
        self.cache.borrow().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn host_tensor_roundtrip_f32() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let t2 = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t2.shape(), &[2, 3]);
        assert_eq!(t2.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn host_tensor_roundtrip_ints() {
        let t = HostTensor::S32(vec![-1, 2, 7], vec![3]);
        let t2 = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        match t2 {
            HostTensor::S32(d, s) => {
                assert_eq!(d, vec![-1, 2, 7]);
                assert_eq!(s, vec![3]);
            }
            _ => panic!("wrong dtype"),
        }
        let u = HostTensor::U32(vec![5, 6], vec![2]);
        let u2 = HostTensor::from_literal(&u.to_literal().unwrap()).unwrap();
        match u2 {
            HostTensor::U32(d, _) => assert_eq!(d, vec![5, 6]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn scalar_helpers() {
        let s = HostTensor::scalar_f32(0.25);
        assert_eq!(s.f32_scalar().unwrap(), 0.25);
        assert!(s.shape().is_empty());
        assert_eq!(s.len(), 1);
        assert!(!s.is_empty());
        assert_eq!(s.elem_bytes(), 4);
    }

    #[test]
    fn zeros_matches_spec() {
        let spec = TensorSpec {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![4, 2],
        };
        let t = HostTensor::zeros(&spec);
        assert_eq!(t.len(), 8);
        assert_eq!(t.shape(), &[4, 2]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert!(DType::parse("f64").is_err());
    }
}
