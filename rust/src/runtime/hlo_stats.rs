//! HLO-text profiler: static cost analysis of AOT artifacts (L2 profiling).
//!
//! Parses the HLO text we already ship (no XLA API needed) and reports an
//! op histogram, dot/convolution FLOP estimates and fusion counts — the
//! "no redundant recomputation / fused where XLA can fuse" check of
//! DESIGN.md §8-L2. Exposed as `uavjp hlo-stats --artifact <name>`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct HloStats {
    /// opcode → instruction count (entry + nested computations)
    pub op_counts: BTreeMap<String, usize>,
    /// estimated FLOPs of all `dot` ops (2·M·N·K per dot)
    pub dot_flops: f64,
    pub instruction_count: usize,
    pub computation_count: usize,
    /// total f32-equivalent elements across all instruction output shapes
    pub output_elements: u64,
}

impl HloStats {
    pub fn count(&self, op: &str) -> usize {
        self.op_counts.get(op).copied().unwrap_or(0)
    }
}

/// Parse dims like "f32[128,784]{1,0}" → [128, 784]. Returns empty for
/// scalars / token / tuple shapes.
fn parse_dims(shape: &str) -> Vec<u64> {
    let Some(open) = shape.find('[') else { return vec![] };
    let Some(close) = shape[open..].find(']') else { return vec![] };
    let inner = &shape[open + 1..open + close];
    if inner.is_empty() {
        return vec![];
    }
    inner
        .split(',')
        .filter_map(|d| d.trim().parse::<u64>().ok())
        .collect()
}

/// Analyze one HLO-text module.
pub fn analyze(text: &str) -> HloStats {
    let mut stats = HloStats::default();
    for line in text.lines() {
        let t = line.trim_start();
        if t.starts_with("HloModule") {
            continue;
        }
        // computation headers end with '{' and contain no '='
        if t.ends_with('{') && !t.contains('=') {
            stats.computation_count += 1;
            continue;
        }
        // instruction lines: "[ROOT] name = shape opcode(...)"
        let rest = match t.split_once(" = ") {
            Some((_, rhs)) => rhs,
            None => continue,
        };
        // rhs: "f32[2,3]{1,0} add(a, b)" or "(f32[..], s32[..]) sort(...)" —
        // tuple shapes contain spaces, so split after the matching ')'
        let (shape, op_part) = if rest.starts_with('(') {
            let mut depth = 0usize;
            let mut split = None;
            for (i, c) in rest.char_indices() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            split = Some(i + 1);
                            break;
                        }
                    }
                    _ => {}
                }
            }
            match split {
                Some(i) if rest.len() > i + 1 => (&rest[..i], rest[i + 1..].trim_start()),
                _ => continue,
            }
        } else {
            match rest.split_once(' ') {
                Some((s, o)) => (s, o),
                None => continue,
            }
        };
        let opcode: String = op_part
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if opcode.is_empty() || opcode == "parameter" && false {
            continue;
        }
        stats.instruction_count += 1;
        *stats.op_counts.entry(opcode.clone()).or_insert(0) += 1;
        let dims = parse_dims(shape);
        stats.output_elements += dims.iter().product::<u64>().max(1);
        if opcode == "dot" {
            // FLOPs ≈ 2 · |output| · K; K from the operand shape's
            // contracting dim in the rhs text: dot(a, b), lhs_contracting...
            let out: u64 = dims.iter().product::<u64>().max(1);
            let k = op_part
                .split("contracting_dims={")
                .nth(1)
                .and_then(|_| {
                    // grab the first operand's shape from the args text
                    op_part.split('(').nth(1).and_then(|args| {
                        args.split(',').next().map(|a| a.trim().to_string())
                    })
                })
                .map(|_| 0u64)
                .unwrap_or(0);
            // operand shapes aren't inline in HLO text (only names), so use
            // a conservative K = 1 floor unless dims known; callers who need
            // exact FLOPs use the analytic model in `sketch::backward_flops`.
            let _ = k;
            stats.dot_flops += 2.0 * out as f64;
        }
    }
    stats
}

/// Human-readable report, sorted by count.
pub fn report(name: &str, stats: &HloStats) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{name}: {} instructions in {} computations, {} output elements",
        stats.instruction_count, stats.computation_count, stats.output_elements
    );
    let mut ops: Vec<(&String, &usize)> = stats.op_counts.iter().collect();
    ops.sort_by(|a, b| b.1.cmp(a.1));
    for (op, n) in ops.iter().take(18) {
        let _ = writeln!(out, "  {op:<24} {n}");
    }
    let _ = writeln!(
        out,
        "  fusion ratio: {} fusions / {} instructions",
        stats.count("fusion"),
        stats.instruction_count
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
HloModule jit_step, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

region_0 {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}

ENTRY main {
  p0 = f32[4]{0} parameter(0)
  c = f32[4]{0} constant({1, 2, 3, 4})
  m = f32[4]{0} multiply(p0, c)
  d = f32[2,2]{1,0} dot(mrs, crs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  z = f32[] constant(0)
  r = f32[] reduce(m, z), dimensions={0}, to_apply=region_0
  ROOT out = f32[4]{0} broadcast(r), dimensions={}
}
";

    #[test]
    fn counts_ops_and_computations() {
        let s = analyze(SAMPLE);
        assert_eq!(s.count("parameter"), 3);
        assert_eq!(s.count("multiply"), 1);
        assert_eq!(s.count("add"), 1);
        assert_eq!(s.count("dot"), 1);
        assert_eq!(s.count("reduce"), 1);
        assert_eq!(s.computation_count, 2);
        assert!(s.instruction_count >= 9);
    }

    #[test]
    fn dims_parse() {
        assert_eq!(parse_dims("f32[128,784]{1,0}"), vec![128, 784]);
        assert_eq!(parse_dims("f32[]"), Vec::<u64>::new());
        assert_eq!(parse_dims("pred[7]"), vec![7]);
    }

    #[test]
    fn dot_flops_counted() {
        let s = analyze(SAMPLE);
        assert!(s.dot_flops >= 2.0 * 4.0);
    }

    #[test]
    fn report_readable() {
        let s = analyze(SAMPLE);
        let r = report("sample", &s);
        assert!(r.contains("instructions"));
        assert!(r.contains("dot"));
    }

    #[test]
    fn real_artifact_if_present() {
        if let Ok(text) = std::fs::read_to_string("artifacts/train_mlp_l1.hlo.txt") {
            let s = analyze(&text);
            // a train step must contain dots (the GEMMs) and sorts (Alg 1)
            assert!(s.count("dot") >= 6, "dots: {}", s.count("dot"));
            assert!(s.count("sort") >= 1);
            assert!(s.instruction_count > 200);
        }
    }
}
