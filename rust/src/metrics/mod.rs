//! Metrics substrate: training curves, summaries, CSV/markdown emitters.
//! Backend-neutral — both the native and the PJRT trainer emit [`RunCurve`],
//! which is what keeps sweeps and experiments engine-agnostic.

use crate::json::Value;
use std::fmt::Write as _;

/// One training run's time series.
#[derive(Debug, Clone, Default)]
pub struct RunCurve {
    /// Step index of every recorded training loss.
    pub steps: Vec<usize>,
    /// Training loss per recorded step.
    pub losses: Vec<f64>,
    /// Periodic test evaluations as (step, eval_loss, eval_acc).
    pub evals: Vec<(usize, f64, f64)>,
}

impl RunCurve {
    /// Append one training-loss sample.
    pub fn record_loss(&mut self, step: usize, loss: f64) {
        self.steps.push(step);
        self.losses.push(loss);
    }

    /// Append one test evaluation.
    pub fn record_eval(&mut self, step: usize, loss: f64, acc: f64) {
        self.evals.push((step, loss, acc));
    }

    /// Test accuracy of the last evaluation, if any.
    pub fn final_acc(&self) -> Option<f64> {
        self.evals.last().map(|e| e.2)
    }

    /// Best test accuracy over all evaluations.
    pub fn best_acc(&self) -> Option<f64> {
        self.evals
            .iter()
            .map(|e| e.2)
            .fold(None, |acc, x| Some(acc.map_or(x, |a: f64| a.max(x))))
    }

    /// Last recorded training loss.
    pub fn final_loss(&self) -> Option<f64> {
        self.losses.last().copied()
    }

    /// Mean loss over the last `k` recorded steps (smoother signal for LR
    /// cross-validation than the single final step).
    pub fn tail_loss(&self, k: usize) -> Option<f64> {
        if self.losses.is_empty() {
            return None;
        }
        let n = self.losses.len();
        let tail = &self.losses[n.saturating_sub(k)..];
        Some(tail.iter().sum::<f64>() / tail.len() as f64)
    }

    /// Serialize the curve for run-record JSON files.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "steps",
                Value::Arr(self.steps.iter().map(|&s| Value::Num(s as f64)).collect()),
            ),
            ("losses", Value::arr_f64(&self.losses)),
            (
                "evals",
                Value::Arr(
                    self.evals
                        .iter()
                        .map(|(s, l, a)| {
                            Value::Arr(vec![
                                Value::Num(*s as f64),
                                Value::Num(*l),
                                Value::Num(*a),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Mean and (population) std of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Markdown table builder for EXPERIMENTS.md output.
pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    /// Start a table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        MdTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render to GitHub-flavored markdown.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }
}

/// CSV emitter (for figure data series).
pub fn to_csv(header: &[&str], rows: &[Vec<f64>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{}", header.join(","));
    for r in rows {
        let cells: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
        let _ = writeln!(out, "{}", cells.join(","));
    }
    out
}

/// Format `x` with a fixed number of decimal digits.
pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_accessors() {
        let mut c = RunCurve::default();
        c.record_loss(0, 2.0);
        c.record_loss(1, 1.0);
        c.record_eval(1, 0.9, 0.55);
        c.record_eval(2, 0.8, 0.60);
        assert_eq!(c.final_acc(), Some(0.60));
        assert_eq!(c.best_acc(), Some(0.60));
        assert_eq!(c.final_loss(), Some(1.0));
        assert_eq!(c.tail_loss(2), Some(1.5));
        assert_eq!(c.tail_loss(10), Some(1.5));
    }

    #[test]
    fn curve_json_roundtrip() {
        let mut c = RunCurve::default();
        c.record_loss(0, 2.5);
        c.record_eval(0, 2.0, 0.1);
        let v = c.to_json();
        let txt = crate::json::to_string_pretty(&v);
        let v2 = crate::json::parse(&txt).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[1.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m, _) = mean_std(&[]);
        assert!(m.is_nan());
    }

    #[test]
    fn md_table_shape() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| a | b |"));
        assert!(s.contains("|---|---|"));
        assert!(s.contains("| 1 | 2 |"));
    }

    #[test]
    fn csv_shape() {
        let s = to_csv(&["x", "y"], &[vec![1.0, 2.0], vec![3.0, 4.5]]);
        assert_eq!(s, "x,y\n1,2\n3,4.5\n");
    }
}
