//! `uavjp` — leader binary: train, sweep, and regenerate the paper's
//! figures/tables on the native backend (default) or from AOT artifacts
//! (`--backend pjrt`, cargo feature `pjrt`).

use anyhow::Result;
use uavjp::cli::Args;
use uavjp::config::{Backend, Preset, ServeConfig, TrainConfig};
use uavjp::coordinator::{backend, experiments, serving, sweeps, TrainBackend};
use uavjp::json;
use uavjp::pipeline;
use uavjp::runtime::Manifest;

const USAGE: &str = "\
uavjp — Unbiased Approximate VJPs for Efficient Backpropagation (repro)

USAGE: uavjp <command> [flags]

commands:
  train       one training run
              --model mlp|bagnet|vit|bagnet_deep|vit_deep
              --method <m> --budget <p> --lr <f>
              --steps <n> --seed <n> --location all|first|last|none
              --budget-schedule p1,p2,..  (one budget per sketch site)
              --act-policy auto|exact|kept  (activation stash policy;
                kept stores only the gated backward's kept columns)
              --act-budget <p>  (kept-stash budget; 0 = inherit sketch)
              --act-schedule p1,p2,..  (one act budget per sketch site)
              --optimizer sgd|momentum|adam --loss ce|mse --batch <n>
              --replicas <n>  (data-parallel replica group, n in 1|2|4|8;
                trajectories are bit-identical at every n for a seed)
              --reduce dense|sparse  (gradient exchange under --replicas:
                sparse union-merges the gated GEMMs' kept columns)
              --stale 0|1  (apply each reduced gradient one step late)
              --fault-spec s  (deterministic fault injection, e.g.
                lane_drop@p=0.1,kill@step=20; env UAVJP_FAULTS when unset)
              --ckpt-every <n>  (write a resumable checkpoint to the
                --save-ckpt path every n steps; atomic tmp+rename)
              --resume <ckpt>  (continue an interrupted run bit-identically
                from a resumable checkpoint)
              [--preset smoke|ci|paper] [--out run.json]
              [--save-ckpt model.ckpt]  (native backend: save the final
                parameters as a versioned checkpoint `serve` can load)
  serve       measured inference serving over a saved checkpoint
              --ckpt model.ckpt  (from train --save-ckpt)
              --requests <n> --max-batch <n> --max-wait-us <n>
              --serve-workers <n>
              --offered-load <qps>  (open-loop arrivals; 0 = closed loop
                at --concurrency in-flight requests)
              --queue-cap <n>  (reject submits past n queued; 0 = unbounded)
              --request-timeout-us <n>  (expire requests still queued after
                n µs with a typed DeadlineExceeded; 0 = no deadline)
              [--out serve_report.json]
  sweep       budget sweep for one method (LR cross-validated)
              --model <m> --method <m> [--budgets 0.05,0.1,...] [--preset ..]
  fig1a|fig1b|fig2a|fig2b|fig3|fig4|variance|eq6
              regenerate a paper figure/table into results/
              [--preset ci|paper] [--budgets ...] [--out-dir results]
  all         run every experiment in sequence
  pipeline-sim  pipeline-parallel compression model
              [--stages 4 --width 512 --microbatch 32 --mb-count 8
               --bandwidth 1e9 --budgets 0.05,0.1,0.2,0.5,1.0]
  hlo-stats   static op histogram / fusion report for one artifact
  exec-bench  compile+execute latency for one artifact [--hlo-override f]
              (requires --features pjrt)
  list        list available artifacts
  methods     list sketch methods and models per backend

flags:
  --backend native|pjrt   execution engine (default: native; pjrt needs the
                          `pjrt` cargo feature and a built artifacts dir)
  --threads N       intra-op worker count for the native tensor kernels
                    (0 = auto; results are bit-identical at any value)
  --kernel K        compute-kernel kind: auto|scalar|simd (auto = AVX2+FMA
                    SIMD when detected, else scalar; UAVJP_KERNEL env
                    override; per-kind results are bit-identical)
  --artifacts DIR   artifact directory (default: artifacts or $UAVJP_ARTIFACTS)
  --verbose         chatty sweeps
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let sub = match args.subcommand.as_deref() {
        Some(s) => s.to_string(),
        None => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    let artifacts = args.str_or("artifacts", "artifacts");
    if args.str_opt("threads").is_some() {
        uavjp::pool::set_threads(args.usize_or("threads", 0)?);
    }
    if let Some(kind) = args.str_opt("kernel") {
        uavjp::tensor::kernels::set_kernel(uavjp::tensor::kernels::KernelKind::parse(kind)?);
    }

    match sub.as_str() {
        "exec-bench" => cmd_exec_bench(&args, &artifacts),
        "hlo-stats" => cmd_hlo_stats(&args, &artifacts),
        "train" => cmd_train(&args, &artifacts),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args, &artifacts),
        "pipeline-sim" => cmd_pipeline(&args),
        "list" => cmd_list(&artifacts),
        "methods" => {
            println!("native methods: {}", uavjp::native::NATIVE_METHODS.join(" "));
            println!("native models (registry):");
            for e in uavjp::native::models::REGISTRY {
                println!("  {:<8} {}", e.name, e.about);
            }
            println!("pjrt mlp: baseline per_element per_column per_sample l1 l1_sq l2 l2_sq var var_sq ds l1_ind gsv gsv_sq rcs");
            println!("pjrt vit/bagnet: baseline per_element per_column per_sample l1 l1_sq var ds");
            Ok(())
        }
        "all" => {
            let be = open_backend(&args, &artifacts)?;
            let ctx = ctx_from(&args, &*be)?;
            for id in experiments::ALL_EXPERIMENTS {
                experiments::run(&ctx, id)?;
            }
            Ok(())
        }
        id if experiments::ALL_EXPERIMENTS.contains(&id) || id == "fig3" => {
            let be = open_backend(&args, &artifacts)?;
            let ctx = ctx_from(&args, &*be)?;
            experiments::run(&ctx, id)
        }
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Open the engine named by `--backend` (default native).
fn open_backend(args: &Args, artifacts: &str) -> Result<Box<dyn TrainBackend>> {
    backend::open(Backend::parse(&args.str_or("backend", "native"))?, artifacts)
}

fn ctx_from<'be>(
    args: &Args,
    be: &'be dyn TrainBackend,
) -> Result<experiments::ExperimentCtx<'be>> {
    let budgets = match args.str_opt("budgets") {
        Some(_) => Some(args.f64_list_or("budgets", &[])?),
        None => None,
    };
    Ok(experiments::ExperimentCtx {
        be,
        preset: Preset::parse(&args.str_or("preset", "ci"))?,
        out_dir: args.str_or("out-dir", "results"),
        verbose: args.has("verbose"),
        budgets,
    })
}

/// Static HLO cost analysis of an artifact (L2 profiling, DESIGN.md §8).
/// Pure text analysis — works without the `pjrt` feature.
fn cmd_hlo_stats(args: &Args, artifacts: &str) -> Result<()> {
    let manifest =
        Manifest::load(std::path::Path::new(&format!("{artifacts}/manifest.json")))?;
    let name = args.str_or("artifact", "train_mlp_l1");
    let spec = manifest
        .get(&name)
        .ok_or_else(|| anyhow::anyhow!("no artifact {name}"))?;
    let text = std::fs::read_to_string(format!("{artifacts}/{}", spec.file))?;
    let stats = uavjp::runtime::hlo_stats::analyze(&text);
    print!("{}", uavjp::runtime::hlo_stats::report(&name, &stats));
    Ok(())
}

/// Compile+execute latency for one artifact, optionally with an alternative
/// HLO file sharing the same signature (A/B perf comparisons, §Perf).
#[cfg(feature = "pjrt")]
fn cmd_exec_bench(args: &Args, artifacts: &str) -> Result<()> {
    use uavjp::runtime::{HostTensor, Runtime};
    let rt = Runtime::open(artifacts)?;
    let name = args.str_or("artifact", "train_mlp_l1");
    let spec = rt
        .manifest
        .get(&name)
        .ok_or_else(|| anyhow::anyhow!("no artifact {name}"))?
        .clone();
    let hlo_path = args
        .str_opt("hlo-override")
        .map(|s| s.to_string())
        .unwrap_or_else(|| format!("{artifacts}/{}", spec.file));
    let proto = xla::HloModuleProto::from_text_file(&hlo_path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let t0 = std::time::Instant::now();
    let exe = rt.client().compile(&comp)?;
    println!("compile: {:.2}s ({hlo_path})", t0.elapsed().as_secs_f64());
    let lits: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|t| HostTensor::zeros(t).to_literal())
        .collect::<Result<_>>()?;
    let reps = args.usize_or("reps", 5)?;
    // warmup
    let _ = exe.execute::<xla::Literal>(&lits)?;
    let mut times = Vec::new();
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let res = exe.execute::<xla::Literal>(&lits)?;
        let _ = res[0][0].to_literal_sync()?;
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "exec median: {:.1} ms over {reps} reps",
        times[times.len() / 2] * 1e3
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_exec_bench(_args: &Args, _artifacts: &str) -> Result<()> {
    anyhow::bail!(
        "exec-bench executes AOT artifacts; rebuild with `--features pjrt` \
         (see DESIGN.md §7). The native backend's equivalent is \
         `cargo bench native_bwd`."
    )
}

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    let be = open_backend(args, artifacts)?;
    let preset = Preset::parse(&args.str_or("preset", "ci"))?;
    let model = args.str_or("model", "mlp");
    let mut cfg: TrainConfig = preset.base(&model)?;
    cfg.backend = Backend::parse(&args.str_or("backend", "native"))?;
    cfg.method = args.str_or("method", "baseline");
    cfg.budget = args.f64_or("budget", 0.2)?;
    cfg.lr = args.f64_or("lr", cfg.lr)?;
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every)?;
    cfg.seed = args.usize_or("seed", 0)? as u64;
    cfg.location = args.str_or("location", "all");
    cfg.train_size = args.usize_or("train-size", cfg.train_size)?;
    cfg.test_size = args.usize_or("test-size", cfg.test_size)?;
    cfg.optimizer = args.str_or("optimizer", &cfg.optimizer);
    cfg.loss = args.str_or("loss", &cfg.loss);
    cfg.batch = args.usize_or("batch", cfg.batch)?;
    cfg.budget_schedule = args.f64_list_or("budget-schedule", &[])?;
    cfg.threads = args.usize_or("threads", cfg.threads)?;
    cfg.kernel = args.str_or("kernel", &cfg.kernel);
    cfg.act_policy = args.str_or("act-policy", &cfg.act_policy);
    cfg.act_budget = args.f64_or("act-budget", cfg.act_budget)?;
    cfg.act_schedule = args.f64_list_or("act-schedule", &[])?;
    cfg.replicas = args.usize_or("replicas", cfg.replicas)?;
    cfg.reduce = args.str_or("reduce", &cfg.reduce);
    cfg.stale = args.usize_or("stale", cfg.stale)?;
    cfg.fault_spec = args.str_or("fault-spec", &cfg.fault_spec);
    cfg.ckpt_every = args.usize_or("ckpt-every", cfg.ckpt_every)?;
    cfg.resume = args.str_or("resume", &cfg.resume);
    if let Some(path) = args.str_opt("save-ckpt") {
        cfg.ckpt_path = path.to_string();
    }
    // Reject nonsense DP flags here with the usage hint rather than deep
    // in the trainer: an *explicit* `--replicas 0` is a contradiction
    // (0 means "no replica group", which is the absence of the flag).
    if args.str_opt("replicas").is_some() && cfg.replicas == 0 {
        anyhow::bail!(
            "--replicas 0 makes no sense; pass 1|2|4|8 or drop the flag \
             (run with no arguments for usage)"
        );
    }
    uavjp::replicate::ReduceMode::parse(&cfg.reduce)?;
    if cfg.stale > 1 {
        anyhow::bail!(
            "--stale {} out of range (want 0|1; run with no arguments for \
             usage)",
            cfg.stale
        );
    }
    if cfg.replicas > 0 && cfg.backend != Backend::Native {
        anyhow::bail!("--replicas runs on the native backend only");
    }
    if cfg.backend != Backend::Native
        && (!cfg.fault_spec.is_empty()
            || cfg.ckpt_every > 0
            || !cfg.resume.is_empty())
    {
        anyhow::bail!(
            "--fault-spec/--ckpt-every/--resume run on the native backend \
             only"
        );
    }

    eprintln!(
        "[train:{}] {} / {} p={} lr={} steps={}",
        be.name(),
        cfg.model,
        cfg.method,
        cfg.budget,
        cfg.lr,
        cfg.steps
    );
    let t0 = std::time::Instant::now();
    let mut exchange: Option<uavjp::replicate::ExchangeStats> = None;
    let mut steps_skipped = 0u64;
    // Runs that checkpoint, resume, inject faults, or reduce across
    // replicas drive the native trainer directly so the exchange byte
    // accounting and fault counters survive the run.
    let direct = cfg.replicas > 0
        || !cfg.ckpt_path.is_empty()
        || cfg.ckpt_every > 0
        || !cfg.resume.is_empty()
        || !cfg.fault_spec.is_empty();
    let curve = if direct {
        if cfg.backend != Backend::Native {
            anyhow::bail!(
                "--save-ckpt needs --backend native (checkpoints hold the \
                 native flat parameter registry)"
            );
        }
        let mut t = uavjp::native::NativeTrainer::new(cfg.clone())?;
        let run = t.run();
        exchange = t.exchange_stats();
        steps_skipped = t.steps_skipped();
        let curve = run?;
        if !cfg.ckpt_path.is_empty() {
            t.save_checkpoint(std::path::Path::new(&cfg.ckpt_path))?;
            eprintln!("saved checkpoint to {}", cfg.ckpt_path);
        }
        curve
    } else {
        be.train(&cfg)?
    };
    let dt = t0.elapsed().as_secs_f64();
    let (el, ea, _) = curve.evals.last().copied().unwrap_or((0, f64::NAN, f64::NAN));
    println!(
        "final: step={} eval_loss={:.4} eval_acc={:.4}  ({:.1}s, {:.1} steps/s)",
        el, ea, curve.final_acc().unwrap_or(f64::NAN), dt,
        curve.losses.len() as f64 / dt
    );
    if let Some(s) = exchange {
        println!(
            "exchange[{}]: dense {:.1} KB/step, sparse {:.1} KB/step \
             ({:.1}% of dense)",
            cfg.reduce,
            s.dense_per_step() / 1024.0,
            s.sparse_per_step() / 1024.0,
            100.0 * s.ratio()
        );
        if s.lanes_dropped > 0 {
            println!(
                "faults: {} lanes dropped over {} degraded steps \
                 (unbiased inverse-probability compensation applied)",
                s.lanes_dropped, s.steps_degraded
            );
        }
    }
    if steps_skipped > 0 {
        println!("faults: {steps_skipped} non-finite optimizer steps skipped");
    }
    if let Some(out) = args.str_opt("out") {
        let mut fields = vec![
            ("config", cfg.to_json()),
            ("curve", curve.to_json()),
            ("wall_seconds", json::Value::num(dt)),
            ("steps_skipped", json::Value::num(steps_skipped as f64)),
        ];
        if let Some(s) = exchange {
            fields.push((
                "exchange",
                json::Value::obj(vec![
                    ("steps", json::Value::num(s.steps as f64)),
                    ("dense_bytes", json::Value::num(s.dense_bytes as f64)),
                    ("sparse_bytes", json::Value::num(s.sparse_bytes as f64)),
                    (
                        "lanes_dropped",
                        json::Value::num(s.lanes_dropped as f64),
                    ),
                    (
                        "steps_degraded",
                        json::Value::num(s.steps_degraded as f64),
                    ),
                ]),
            ));
        }
        let v = json::Value::obj(fields);
        std::fs::write(out, json::to_string_pretty(&v))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

/// Measured inference serving over a saved checkpoint: load, rebuild the
/// registry model, and run open- or closed-loop synthetic clients against
/// the dynamic-batched engine (`crate::serve`).
fn cmd_serve(args: &Args) -> Result<()> {
    if Backend::parse(&args.str_or("backend", "native"))? != Backend::Native {
        anyhow::bail!(
            "serve runs on the native backend (checkpoints hold the native \
             flat parameter registry)"
        );
    }
    let ckpt = args.str_opt("ckpt").ok_or_else(|| {
        anyhow::anyhow!(
            "serve needs --ckpt <path> (write one with train --save-ckpt)"
        )
    })?;
    let d = ServeConfig::default();
    let cfg = ServeConfig {
        max_batch: args.usize_or("max-batch", d.max_batch)?,
        max_wait_us: args.usize_or("max-wait-us", d.max_wait_us as usize)? as u64,
        workers: args.usize_or("serve-workers", d.workers)?,
        requests: args.usize_or("requests", d.requests)?,
        offered_load: args.f64_or("offered-load", d.offered_load)?,
        concurrency: args.usize_or("concurrency", d.concurrency)?,
        queue_cap: args.usize_or("queue-cap", d.queue_cap)?,
        request_timeout_us: args
            .usize_or("request-timeout-us", d.request_timeout_us as usize)?
            as u64,
    };
    let report = serving::serve_checkpoint(std::path::Path::new(ckpt), &cfg)?;
    println!(
        "served {} requests in {:.2}s ({} rejected, {} timed out): \
         {:.1} qps sustained, p50 {:.3} ms, p99 {:.3} ms, mean batch {:.2}",
        report.completed,
        report.wall_seconds,
        report.rejected,
        report.timed_out,
        report.throughput_qps,
        report.p50_ms,
        report.p99_ms,
        report.mean_batch
    );
    if let Some(out) = args.str_opt("out") {
        if let Some(dir) = std::path::Path::new(out).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(out, json::to_string_pretty(&report.to_json()))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args, artifacts: &str) -> Result<()> {
    let be = open_backend(args, artifacts)?;
    let preset = Preset::parse(&args.str_or("preset", "ci"))?;
    let model = args.str_or("model", "mlp");
    let method = args.str_or("method", "l1");
    let budgets = args.f64_list_or("budgets", &preset.budgets())?;
    let pts = sweeps::budget_sweep(
        &*be,
        preset,
        &model,
        &method,
        &budgets,
        &args.str_or("location", "all"),
        args.has("verbose"),
    )?;
    println!("budget,acc_mean,acc_std,best_lr");
    for p in pts {
        println!("{},{:.4},{:.4},{}", p.budget, p.acc_mean, p.acc_std, p.best_lr);
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let width = args.usize_or("width", 512)?;
    let cfg = pipeline::PipelineConfig {
        stages: (0..args.usize_or("stages", 4)?)
            .map(|_| pipeline::Stage { dout: width, din: width })
            .collect(),
        microbatch: args.usize_or("microbatch", 32)?,
        num_microbatches: args.usize_or("mb-count", 8)?,
        bandwidth: args.f64_or("bandwidth", 1e9)?,
        latency: args.f64_or("latency", 5e-6)?,
        flops_per_sec: args.f64_or("flops", 1e11)?,
        budget: 1.0,
    };
    let budgets = args.f64_list_or("budgets", &[0.05, 0.1, 0.2, 0.5, 1.0])?;
    println!("budget,step_time_s,bubble,backward_MB,speedup_vs_exact");
    let exact = pipeline::simulate(&cfg);
    for (b, rep) in pipeline::budget_sweep(&cfg, &budgets) {
        println!(
            "{},{:.6},{:.3},{:.3},{:.2}",
            b,
            rep.total_time,
            rep.bubble_fraction,
            rep.backward_bytes / 1e6,
            exact.total_time / rep.total_time
        );
    }
    Ok(())
}

fn cmd_list(artifacts: &str) -> Result<()> {
    let manifest =
        Manifest::load(std::path::Path::new(&format!("{artifacts}/manifest.json")))?;
    for name in manifest.names() {
        let a = manifest.get(name).unwrap();
        println!(
            "{name}: {} inputs, {} outputs ({})",
            a.inputs.len(),
            a.outputs.len(),
            a.file
        );
    }
    Ok(())
}
