//! JSON writer with stable formatting (2-space indent, sorted keys).

use super::Value;

pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            // compact for scalar-only arrays, expanded otherwise
            let scalar = a
                .iter()
                .all(|x| !matches!(x, Value::Arr(_) | Value::Obj(_)));
            if scalar {
                out.push('[');
                for (i, x) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    write_value(x, indent, out);
                }
                out.push(']');
            } else {
                out.push_str("[\n");
                for (i, x) in a.iter().enumerate() {
                    pad(indent + 1, out);
                    write_value(x, indent + 1, out);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(indent, out);
                out.push(']');
            }
        }
        Value::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, x)) in o.iter().enumerate() {
                pad(indent + 1, out);
                write_str(k, out);
                out.push_str(": ");
                write_value(x, indent + 1, out);
                if i + 1 < o.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(indent, out);
            out.push('}');
        }
    }
}

fn pad(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; clamp to null (documented substrate limit)
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}
