//! Recursive-descent JSON parser.

use super::Value;
use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: must be followed by \uXXXX
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.i += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.i += 1;
                                let lo = self.hex4()?;
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // fast path: bulk-copy the run of bytes up to the next
                    // quote/backslash and validate it once (the naive
                    // char-at-a-time loop re-validated the whole tail per
                    // character — O(n²); see EXPERIMENTS.md §Perf #6)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}
