//! Minimal JSON substrate (parser + writer).
//!
//! The build environment is fully offline and `serde`/`serde_json` are not in
//! the vendored crate set, so the manifest, experiment configs and run
//! outputs flow through this hand-rolled implementation. It supports the full
//! JSON grammar minus exotic number forms; strings handle the standard escape
//! set plus `\uXXXX` (including surrogate pairs).

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::to_string_pretty;

use std::collections::BTreeMap;

/// A JSON document node. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — handy for golden tests and diffable run records.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object member access; `Value::Null` for anything that isn't there.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }
    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
    }
    pub fn arr_f32(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Num(*x as f64)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let v2 = parse(&to_string_pretty(&v)).unwrap();
            assert_eq!(v, v2, "roundtrip {src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null,"c":[true,false]}],"d":{"e":"f g"}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("d").get("e").as_str(), Some("f g"));
        let v2 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\"b\\c\n\tAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\n\tAé"));
        let v2 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        for src in ["{", "[1,", "tru", "\"", "{\"a\" 1}", "1 2", "{,}"] {
            assert!(parse(src).is_err(), "should reject {src}");
        }
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "b": true, "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("n").as_i64(), Some(3));
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 1);
        assert_eq!(v.get("missing").as_str(), None);
    }

    #[test]
    fn numbers_precise() {
        let v = parse("[0.25, 1048576, -3.5e-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(0.25));
        assert_eq!(a[1].as_f64(), Some(1048576.0));
        assert!((a[2].as_f64().unwrap() + 0.035).abs() < 1e-12);
    }
}
