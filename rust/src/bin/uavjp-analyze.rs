//! `uavjp-analyze` — repo-invariant static analysis entry point.
//!
//! Scans `rust/src` and `rust/tests` for violations of the repo's
//! machine-checked contracts (DESIGN.md §7.8): RNG stream hygiene,
//! unsafe discipline, determinism lints and hot-path allocation lints.
//! Prints `file:line: [pass] message` diagnostics sorted by location and
//! exits nonzero when anything fires, so CI can gate on it.
//!
//! Usage: `cargo run --release --bin uavjp-analyze [crate-root]`
//! (the crate root defaults to this crate's own source tree).

use std::path::PathBuf;
use std::process::ExitCode;

use uavjp::analyze;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let report = match analyze::analyze_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("uavjp-analyze: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for f in &report.findings {
        println!("{f}");
    }
    if report.is_clean() {
        println!(
            "uavjp-analyze: clean — {} files scanned, waivers: {}",
            report.files_scanned,
            report.allow_summary(),
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "uavjp-analyze: {} finding(s) across {} files (waivers: {})",
            report.findings.len(),
            report.files_scanned,
            report.allow_summary(),
        );
        ExitCode::FAILURE
    }
}
