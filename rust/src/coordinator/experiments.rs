//! Experiment registry: one entry per figure/table of the paper (§5, App B).
//!
//! Every experiment writes three files under `results/`:
//! `<id>.csv` (the figure's data series), `<id>.md` (markdown table for
//! EXPERIMENTS.md) and `<id>.json` (full run records). The *shape* of each
//! figure — method orderings, degradation trends — is the reproduction
//! target (DESIGN.md §6).

use crate::config::Preset;
use crate::json::{self, Value};
use crate::metrics::{to_csv, MdTable};
use anyhow::Result;
use std::path::Path;

use super::backend::TrainBackend;
use super::sweeps::{self, SweepPoint};
use super::variance;

// ordered cheap→expensive so partial `all` runs still cover most figures
// (fig2b's spectral methods pay an O(n³)-matmul Jacobi eigh per layer step)
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1a", "fig1b", "fig2a", "fig4", "variance", "eq6", "fig3", "fig2b",
];

/// Everything an experiment needs: the engine plus protocol knobs.
pub struct ExperimentCtx<'be> {
    /// The training engine (native or PJRT) all runs go through.
    pub be: &'be dyn TrainBackend,
    /// Scale preset (smoke / ci / paper).
    pub preset: Preset,
    /// Output directory for the CSV/markdown/JSON triples.
    pub out_dir: String,
    /// Chatty sweep logging.
    pub verbose: bool,
    /// optional budget override (smaller grids for smoke runs)
    pub budgets: Option<Vec<f64>>,
}

impl<'be> ExperimentCtx<'be> {
    fn budgets(&self) -> Vec<f64> {
        self.budgets.clone().unwrap_or_else(|| self.preset.budgets())
    }

    /// True when the backend implements `method`; logs the skip otherwise.
    fn method_supported(&self, id: &str, method: &str) -> bool {
        let ok = self.be.supports_method(method);
        if !ok {
            eprintln!(
                "[{id}] skipping {method}: not implemented by the {} backend",
                self.be.name()
            );
        }
        ok
    }

    fn emit(
        &self,
        id: &str,
        csv: String,
        md: String,
        jsonv: Value,
    ) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let base = Path::new(&self.out_dir);
        std::fs::write(base.join(format!("{id}.csv")), csv)?;
        std::fs::write(base.join(format!("{id}.md")), md)?;
        std::fs::write(
            base.join(format!("{id}.json")),
            json::to_string_pretty(&jsonv),
        )?;
        eprintln!("[{id}] wrote results to {}/", self.out_dir);
        Ok(())
    }

    fn methods_table(
        &self,
        id: &str,
        title: &str,
        model: &str,
        methods: &[(&str, &str)], // (method, location)
    ) -> Result<()> {
        if !self.be.supports_model(model) {
            eprintln!(
                "[{id}] skipping entirely: model {model} not implemented by the {} backend",
                self.be.name()
            );
            return Ok(());
        }
        let budgets = self.budgets();
        let baseline = sweeps::baseline_point(self.be, self.preset, model, self.verbose)?;
        let methods: Vec<(&str, &str)> = methods
            .iter()
            .filter(|(m, _)| self.method_supported(id, m))
            .copied()
            .collect();
        let mut all: Vec<(String, Vec<SweepPoint>)> = Vec::new();
        for (method, location) in &methods {
            let pts = sweeps::budget_sweep(
                self.be,
                self.preset,
                model,
                method,
                &budgets,
                location,
                self.verbose,
            )?;
            let label = if *location == "all" {
                method.to_string()
            } else {
                format!("{method}@{location}")
            };
            all.push((label, pts));
        }
        // CSV: budget, <method1>, <method1>_std, ...
        let mut header: Vec<String> = vec!["budget".into()];
        for (label, _) in &all {
            header.push(label.clone());
            header.push(format!("{label}_std"));
        }
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut rows = Vec::new();
        for (bi, &b) in budgets.iter().enumerate() {
            let mut row = vec![b];
            for (_, pts) in &all {
                row.push(pts[bi].acc_mean);
                row.push(pts[bi].acc_std);
            }
            rows.push(row);
        }
        let csv = to_csv(&header_refs, &rows);

        let mut md = MdTable::new(
            &std::iter::once("budget p")
                .chain(all.iter().map(|(l, _)| l.as_str()))
                .collect::<Vec<_>>(),
        );
        for (bi, &b) in budgets.iter().enumerate() {
            let mut cells = vec![format!("{b}")];
            for (_, pts) in &all {
                cells.push(format!(
                    "{:.3} ± {:.3}",
                    pts[bi].acc_mean, pts[bi].acc_std
                ));
            }
            md.row(cells);
        }
        let md_text = format!(
            "### {id}: {title}\n\nbaseline (exact VJP): {:.3} ± {:.3}\n\n{}",
            baseline.acc_mean,
            baseline.acc_std,
            md.render()
        );

        let jsonv = Value::obj(vec![
            ("id", Value::str(id)),
            ("title", Value::str(title)),
            ("model", Value::str(model)),
            ("baseline_acc", Value::num(baseline.acc_mean)),
            ("baseline_std", Value::num(baseline.acc_std)),
            ("budgets", Value::arr_f64(&budgets)),
            (
                "series",
                Value::Arr(
                    all.iter()
                        .map(|(label, pts)| {
                            Value::obj(vec![
                                ("label", Value::str(label)),
                                (
                                    "acc_mean",
                                    Value::arr_f64(
                                        &pts.iter().map(|p| p.acc_mean).collect::<Vec<_>>(),
                                    ),
                                ),
                                (
                                    "acc_std",
                                    Value::arr_f64(
                                        &pts.iter().map(|p| p.acc_std).collect::<Vec<_>>(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        self.emit(id, csv, md_text, jsonv)
    }
}

/// Fig 1a — correlated vs independent Bernoulli sampling (ℓ1 scores, MLP).
pub fn fig1a(ctx: &ExperimentCtx) -> Result<()> {
    ctx.methods_table(
        "fig1a",
        "Correlated (systematic) vs independent Bernoulli sampling",
        "mlp",
        &[("l1", "all"), ("l1_ind", "all")],
    )
}

/// Fig 1b — uniform masking vs data-dependent sketching (MLP).
pub fn fig1b(ctx: &ExperimentCtx) -> Result<()> {
    ctx.methods_table(
        "fig1b",
        "Masking vs sketching methods",
        "mlp",
        &[
            ("per_element", "all"),
            ("per_column", "all"),
            ("per_sample", "all"),
            ("l1", "all"),
            ("ds", "all"),
        ],
    )
}

/// Fig 2a — simple weight proxies (MLP).
pub fn fig2a(ctx: &ExperimentCtx) -> Result<()> {
    ctx.methods_table(
        "fig2a",
        "Weight-proxy comparison (ℓ1, ℓ2, Var and squares)",
        "mlp",
        &[
            ("l1", "all"),
            ("l1_sq", "all"),
            ("l2", "all"),
            ("l2_sq", "all"),
            ("var", "all"),
            ("var_sq", "all"),
        ],
    )
}

/// Fig 2b — spectral vs coordinate methods (MLP).
pub fn fig2b(ctx: &ExperimentCtx) -> Result<()> {
    ctx.methods_table(
        "fig2b",
        "Spectral (RCS, G-SV) vs coordinate-based methods",
        "mlp",
        &[
            ("rcs", "all"),
            ("gsv", "all"),
            ("gsv_sq", "all"),
            ("l1", "all"),
            ("ds", "all"),
        ],
    )
}

/// Fig 3 — larger architectures (BagNet & ViT on synth-CIFAR).
pub fn fig3(ctx: &ExperimentCtx) -> Result<()> {
    let methods: &[(&str, &str)] = &[
        ("per_column", "all"),
        ("per_sample", "all"),
        ("l1", "all"),
        ("l1_sq", "all"),
        ("var", "all"),
        ("ds", "all"),
    ];
    ctx.methods_table("fig3_bagnet", "Sketching on BagNet", "bagnet", methods)?;
    ctx.methods_table("fig3_vit", "Sketching on ViT", "vit", methods)
}

/// Fig 4 — VJP approximation location ablation (first/last/all, MLP).
pub fn fig4(ctx: &ExperimentCtx) -> Result<()> {
    ctx.methods_table(
        "fig4",
        "Impact of VJP approximation location",
        "mlp",
        &[
            ("l1", "all"),
            ("l1", "first"),
            ("l1", "last"),
            ("per_column", "all"),
            ("per_column", "first"),
            ("per_column", "last"),
        ],
    )
}

/// Prop 2.2 validation: unbiasedness + variance-vs-budget per method.
pub fn variance_exp(ctx: &ExperimentCtx) -> Result<()> {
    let methods: Vec<&str> = ["per_column", "per_sample", "l1", "ds", "rcs"]
        .into_iter()
        .filter(|m| ctx.method_supported("variance", m))
        .collect();
    let budgets = ctx.budgets();
    let trials = match ctx.preset {
        Preset::Smoke => 32,
        Preset::Ci => 64,
        Preset::Paper => 256,
    };
    let mut rows = Vec::new();
    let mut md = MdTable::new(&[
        "method",
        "budget p",
        "rel bias",
        "MC noise floor",
        "bias/floor",
        "V = E‖ĝ−g‖²",
        "V/‖g‖²",
    ]);
    let mut records = Vec::new();
    for method in methods {
        for &b in &budgets {
            let rep = ctx.be.grad_probe(method, b, trials, 5)?;
            // the Monte-Carlo mean of an estimator with relative variance v
            // deviates by ~sqrt(v/trials) even at zero bias; report it so
            // "rel bias ≈ floor" reads as consistent-with-unbiased.
            let floor = (rep.rel_variance() / trials as f64).sqrt();
            eprintln!(
                "[variance] {method} p={b}: bias {:.4} (floor {:.4}) V {:.4e}",
                rep.bias_rel, floor, rep.variance,
            );
            rows.push(vec![
                b,
                rep.bias_rel,
                floor,
                rep.bias_rel / floor,
                rep.variance,
                rep.rel_variance(),
            ]);
            md.row(vec![
                method.to_string(),
                format!("{b}"),
                format!("{:.4}", rep.bias_rel),
                format!("{:.4}", floor),
                format!("{:.2}", rep.bias_rel / floor),
                format!("{:.4e}", rep.variance),
                format!("{:.3}", rep.rel_variance()),
            ]);
            records.push(Value::obj(vec![
                ("method", Value::str(method)),
                ("budget", Value::num(b)),
                ("bias_rel", Value::num(rep.bias_rel)),
                ("variance", Value::num(rep.variance)),
                ("rel_variance", Value::num(rep.rel_variance())),
                ("trials", Value::num(rep.trials as f64)),
            ]));
        }
    }
    let csv = to_csv(
        &["budget", "bias_rel", "mc_floor", "bias_over_floor", "variance", "rel_variance"],
        &rows,
    );
    let md_text = format!(
        "### variance: Prop 2.2 — unbiasedness & injected variance\n\n\
         `bias/floor` ≈ 1 means the measured deviation of the MC mean is \
         fully explained by sampling noise — i.e. consistent with exact \
         unbiasedness (Prop 2.2 i).\n\n{}",
        md.render()
    );
    ctx.emit("variance", csv, md_text, Value::Arr(records))
}

/// Eq 6 — variance-efficiency trade-off table.
pub fn eq6(ctx: &ExperimentCtx) -> Result<()> {
    let trials = match ctx.preset {
        Preset::Smoke => 24,
        Preset::Ci => 48,
        Preset::Paper => 192,
    };
    let s2 = ctx.be.sigma2(trials)?;
    eprintln!("[eq6] measured σ² = {s2:.4e}");
    let methods = ["per_column", "l1", "ds"];
    let budgets = ctx.budgets();
    let mut md = MdTable::new(&[
        "method",
        "budget p",
        "ρ(V)",
        "V",
        "ρ(V)(σ²+V)",
        "net win vs ρ(0)σ²",
    ]);
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for method in methods {
        for &b in &budgets {
            let (rho, v, net, s2m) = variance::eq6_row(ctx.be, method, b, s2, trials)?;
            let win = s2m / net;
            md.row(vec![
                method.to_string(),
                format!("{b}"),
                format!("{rho:.3}"),
                format!("{v:.3e}"),
                format!("{net:.3e}"),
                format!("{win:.2}×"),
            ]);
            rows.push(vec![b, rho, v, net, win]);
            records.push(Value::obj(vec![
                ("method", Value::str(method)),
                ("budget", Value::num(b)),
                ("rho", Value::num(rho)),
                ("variance", Value::num(v)),
                ("net_cost", Value::num(net)),
                ("win", Value::num(win)),
                ("sigma2", Value::num(s2)),
            ]));
        }
    }
    let csv = to_csv(&["budget", "rho", "variance", "net_cost", "win"], &rows);
    let md_text = format!(
        "### eq6: variance-efficiency trade-off (σ² = {s2:.3e})\n\nNet win > 1 ⇒ sketched training is cheaper per unit progress (Eq 6 satisfied).\n\n{}",
        md.render()
    );
    ctx.emit("eq6", csv, md_text, Value::Arr(records))
}

/// Dispatch by experiment id.
pub fn run(ctx: &ExperimentCtx, id: &str) -> Result<()> {
    match id {
        "fig1a" => fig1a(ctx),
        "fig1b" => fig1b(ctx),
        "fig2a" => fig2a(ctx),
        "fig2b" => fig2b(ctx),
        "fig3" => fig3(ctx),
        "fig4" => fig4(ctx),
        "variance" => variance_exp(ctx),
        "eq6" => eq6(ctx),
        other => anyhow::bail!("unknown experiment {other} (see ALL_EXPERIMENTS)"),
    }
}
