//! Backend dispatch: one trait the sweeps / experiments / CLI drive, two
//! engines behind it (DESIGN.md §7).
//!
//! [`NativeBackend`] is always available and needs nothing on disk.
//! `PjrtBackend` wraps the AOT-artifact runtime and only exists under the
//! `pjrt` cargo feature; without it, [`open`] returns a helpful error
//! instead.

use crate::config::{Backend, TrainConfig};
use crate::metrics::RunCurve;
use crate::native::NativeTrainer;
use anyhow::Result;

use super::variance::{self, VarianceReport};

/// A training engine: everything the coordinator needs to run the paper's
/// protocol (training runs plus the Prop 2.2 / Eq 6 gradient probes).
pub trait TrainBackend {
    /// Short name for logs ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// Whether this engine implements a sketch method (experiments skip
    /// unsupported series instead of failing the whole figure).
    fn supports_method(&self, method: &str) -> bool;

    /// Whether this engine can train a model family (experiments skip
    /// unsupported models, so `uavjp all` completes on every backend).
    fn supports_model(&self, model: &str) -> bool;

    /// Execute one full training run.
    fn train(&self, cfg: &TrainConfig) -> Result<RunCurve>;

    /// Monte-Carlo gradient bias/variance at a fixed parameter point and
    /// batch (Prop 2.2 validation).
    fn grad_probe(
        &self,
        method: &str,
        budget: f64,
        trials: usize,
        seed: u64,
    ) -> Result<VarianceReport>;

    /// Minibatch gradient variance σ² at the same point (Eq 6's σ²).
    fn sigma2(&self, trials: usize) -> Result<f64>;
}

/// The CPU-native engine ([`crate::native`]).
pub struct NativeBackend;

impl TrainBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_method(&self, method: &str) -> bool {
        crate::native::NATIVE_METHODS.contains(&method)
    }

    fn supports_model(&self, model: &str) -> bool {
        crate::native::models::is_supported(model)
    }

    fn train(&self, cfg: &TrainConfig) -> Result<RunCurve> {
        NativeTrainer::new(cfg.clone())?.run()
    }

    fn grad_probe(
        &self,
        method: &str,
        budget: f64,
        trials: usize,
        seed: u64,
    ) -> Result<VarianceReport> {
        variance::measure_native(method, budget, trials, seed)
    }

    fn sigma2(&self, trials: usize) -> Result<f64> {
        variance::sigma2_native(trials)
    }
}

/// The PJRT/AOT-artifact engine ([`crate::runtime`]).
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    /// The artifact runtime this backend executes through.
    pub rt: crate::runtime::Runtime,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Open an artifacts directory (expects `manifest.json` inside).
    pub fn open(artifacts: &str) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: crate::runtime::Runtime::open(artifacts)? })
    }
}

#[cfg(feature = "pjrt")]
impl TrainBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supports_method(&self, _method: &str) -> bool {
        // the artifact set covers every method; a missing artifact still
        // errors with its name at load time
        true
    }

    fn supports_model(&self, _model: &str) -> bool {
        true
    }

    fn train(&self, cfg: &TrainConfig) -> Result<RunCurve> {
        super::trainer::Trainer::new(&self.rt, cfg.clone())?.run()
    }

    fn grad_probe(
        &self,
        method: &str,
        budget: f64,
        trials: usize,
        seed: u64,
    ) -> Result<VarianceReport> {
        variance::measure(&self.rt, method, budget, trials, seed)
    }

    fn sigma2(&self, trials: usize) -> Result<f64> {
        variance::sigma2(&self.rt, trials)
    }
}

/// Open the engine selected by `backend`. `artifacts` is the AOT directory
/// the PJRT engine loads from (ignored by the native engine).
pub fn open(backend: Backend, artifacts: &str) -> Result<Box<dyn TrainBackend>> {
    match backend {
        Backend::Native => {
            let _ = artifacts;
            Ok(Box::new(NativeBackend))
        }
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => Ok(Box::new(PjrtBackend::open(artifacts)?)),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => anyhow::bail!(
            "backend pjrt requires rebuilding with `--features pjrt` \
             (and a built {artifacts}/ directory); the default build is \
             native-only (DESIGN.md §7)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    #[test]
    fn native_backend_trains() {
        let mut cfg = Preset::Smoke.base("mlp").unwrap();
        cfg.method = "l1".into();
        cfg.budget = 0.5;
        cfg.train_size = 128;
        cfg.test_size = 64;
        cfg.steps = 4;
        cfg.eval_every = 4;
        cfg.batch = 32;
        let be = open(Backend::Native, "artifacts").unwrap();
        assert_eq!(be.name(), "native");
        let curve = be.train(&cfg).unwrap();
        assert_eq!(curve.losses.len(), 4);
        assert_eq!(curve.evals.len(), 1);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_needs_feature() {
        let err = open(Backend::Pjrt, "artifacts").unwrap_err();
        assert!(format!("{err}").contains("--features pjrt"));
    }
}
