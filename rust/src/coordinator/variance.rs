//! Gradient-variance measurement (Prop 2.2 validation + Eq 6 trade-off).
//!
//! A fixed parameter point and a fixed batch, repeated with fresh sketch
//! keys, give Monte-Carlo estimates of E[ĝ], E‖ĝ − g‖² and per-coordinate
//! spread — the quantities §2's theory reasons about. Both backends expose
//! the probe: the native path runs the registry MLP
//! ([`crate::native::models::mlp`]) backwards directly; the PJRT path
//! (feature `pjrt`) drives the `grads_mlp_<method>` artifacts.

use crate::data::{self, DatasetKind};
#[cfg(feature = "pjrt")]
use crate::runtime::{HostTensor, Runtime};
use anyhow::Result;

/// Monte-Carlo summary of one (method, budget) gradient estimator.
#[derive(Debug, Clone)]
pub struct VarianceReport {
    /// Sketch method measured.
    pub method: String,
    /// Kept-column budget p.
    pub budget: f64,
    /// ‖mean_k ĝ_k − g‖ / ‖g‖ — should → 0 (unbiasedness, Prop 2.2 i)
    pub bias_rel: f64,
    /// E‖ĝ − g‖² (the V of §2.2)
    pub variance: f64,
    /// ‖g‖² for normalization
    pub grad_norm_sq: f64,
    /// Monte-Carlo trial count behind the estimates.
    pub trials: usize,
}

impl VarianceReport {
    /// Relative variance V / ‖g‖².
    pub fn rel_variance(&self) -> f64 {
        self.variance / self.grad_norm_sq
    }
}

/// Accumulate bias/variance statistics from per-trial gradient estimates.
fn summarize(
    method: &str,
    budget: f64,
    g: &[f32],
    trials: usize,
    mut ghat_of: impl FnMut(usize) -> Result<Vec<f32>>,
) -> Result<VarianceReport> {
    let dim = g.len();
    let gnorm2: f64 = g.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let mut mean = vec![0.0f64; dim];
    let mut sq_err = 0.0f64;
    for t in 0..trials {
        let ghat = ghat_of(t)?;
        debug_assert_eq!(ghat.len(), dim);
        let mut err = 0.0f64;
        for i in 0..dim {
            let d = ghat[i] as f64 - g[i] as f64;
            err += d * d;
            mean[i] += ghat[i] as f64;
        }
        sq_err += err;
    }
    let mut bias2 = 0.0f64;
    for i in 0..dim {
        let b = mean[i] / trials as f64 - g[i] as f64;
        bias2 += b * b;
    }
    Ok(VarianceReport {
        method: method.to_string(),
        budget,
        bias_rel: (bias2 / gnorm2.max(1e-30)).sqrt(),
        variance: sq_err / trials as f64,
        grad_norm_sq: gnorm2,
        trials,
    })
}

// ---------------------------------------------------------------------------
// Native probes
// ---------------------------------------------------------------------------

/// The probe's fixed setup: standard MLP at a seeded init + one fixed batch.
fn native_probe_setup(
    seed: u64,
) -> (crate::native::Sequential, crate::tensor::Mat, Vec<i32>) {
    use crate::native::models;
    use crate::tensor::Mat;
    let batch = 128usize;
    let model = models::mlp(models::MLP_DIMS, seed);
    let ds = data::generate(DatasetKind::SynthMnist, batch, 99, "train");
    let x = Mat { rows: batch, cols: ds.dim, data: ds.x.clone() };
    (model, x, ds.y)
}

/// One flattened gradient through a caller-provided workspace and
/// pre-resolved plan (both reused across Monte-Carlo trials so the probe
/// loop stays allocation-light).
fn native_grad(
    model: &crate::native::Sequential,
    ws: &mut crate::native::Workspace,
    x: &crate::tensor::Mat,
    y: &[i32],
    plan: &crate::native::StepPlan,
    rng: &mut crate::rng::Pcg64,
) -> Vec<f32> {
    use crate::native::{loss_and_grad_into, LossKind};
    // One rng drives both sweeps: the probe plans use the exact
    // activation policy, whose full stashes consume no randomness, so the
    // G-gate stream is exactly what it was before stashing existed.
    model.forward_train(x, ws, plan, rng);
    let (logits, gout) = ws.loss_io();
    loss_and_grad_into(LossKind::CrossEntropy, logits, y, gout);
    model.backward(ws, plan, rng);
    ws.grad_slots.flatten()
}

/// Measure gradient bias/variance for one (method, budget) on the native
/// backend (fixed init + batch, fresh sketch randomness per trial).
pub fn measure_native(
    method: &str,
    budget: f64,
    trials: usize,
    seed: u64,
) -> Result<VarianceReport> {
    use crate::native::{ActivationPolicy, SketchPolicy};
    use crate::rng::streams;
    if !crate::native::NATIVE_METHODS.contains(&method) {
        anyhow::bail!("native variance probe: unsupported method {method}");
    }
    let (model, x, y) = native_probe_setup(seed);
    let mut ws = model.workspace(x.rows, x.cols);
    let mut exact_rng = streams::null();
    let exact_plan =
        model.plan(&SketchPolicy::exact(), &ActivationPolicy::exact())?;
    let g = native_grad(&model, &mut ws, &x, &y, &exact_plan, &mut exact_rng);
    let plan = model.plan(
        &SketchPolicy {
            method: method.to_string(),
            budget,
            location: "all".into(),
            schedule: None,
        },
        &ActivationPolicy::exact(),
    )?;
    summarize(method, budget, &g, trials, |t| {
        let mut rng = streams::variance_trial(seed, t as u64);
        Ok(native_grad(&model, &mut ws, &x, &y, &plan, &mut rng))
    })
}

/// Minibatch gradient variance σ² at the probe's parameter point: resample
/// batches, exact gradients (native backend).
pub fn sigma2_native(trials: usize) -> Result<f64> {
    use crate::native::{models, ActivationPolicy, SketchPolicy};
    use crate::rng::streams;
    use crate::tensor::Mat;
    let batch = 128usize;
    let model = models::mlp(models::MLP_DIMS, 5);
    let mut ws = model.workspace(batch, models::MLP_DIMS[0]);
    let plan = model.plan(&SketchPolicy::exact(), &ActivationPolicy::exact())?;
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(trials);
    for t in 0..trials {
        let ds = data::generate(DatasetKind::SynthMnist, batch, 500 + t as u64, "train");
        let x = Mat { rows: batch, cols: ds.dim, data: ds.x.clone() };
        let mut rng = streams::null();
        grads.push(native_grad(&model, &mut ws, &x, &ds.y, &plan, &mut rng));
    }
    Ok(spread(&grads))
}

/// Mean over samples of ‖g − ḡ‖² for a set of flattened gradients.
fn spread(grads: &[Vec<f32>]) -> f64 {
    let trials = grads.len();
    let dim = grads[0].len();
    let mut mean = vec![0.0f64; dim];
    for g in grads {
        for i in 0..dim {
            mean[i] += g[i] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= trials as f64;
    }
    let mut var = 0.0f64;
    for g in grads {
        for i in 0..dim {
            let d = g[i] as f64 - mean[i];
            var += d * d;
        }
    }
    var / trials as f64
}

// ---------------------------------------------------------------------------
// Eq 6 (backend-agnostic)
// ---------------------------------------------------------------------------

/// Eq 6 check: net-cost comparison ρ(V)(σ²+V) vs ρ(0)σ² for the MLP layers.
///
/// σ² (minibatch gradient variance) comes from the backend's exact-gradient
/// resampling; V from its sketch probe; ρ from the analytic FLOP model in
/// [`crate::sketch::cost_ratio`] over the MLP's sketched layers. Returns
/// (ρ, V, net cost, σ²).
pub fn eq6_row(
    be: &dyn super::backend::TrainBackend,
    method: &str,
    budget: f64,
    sigma2: f64,
    trials: usize,
) -> Result<(f64, f64, f64, f64)> {
    let rep = be.grad_probe(method, budget, trials, 5)?;
    // MLP sketched layers (dout, din): 784→64, 64→64, 64→10 at batch 128
    let layers = [(64usize, 784usize), (64, 64), (10, 64)];
    let total: f64 = layers
        .iter()
        .map(|&(o, i)| 4.0 * 128.0 * o as f64 * i as f64)
        .sum();
    let cost: f64 = layers
        .iter()
        .map(|&(o, i)| {
            crate::sketch::cost_ratio(128, o, i, budget)
                * 4.0
                * 128.0
                * o as f64
                * i as f64
        })
        .sum();
    let rho = cost / total;
    let v = rep.variance;
    let net = rho * (sigma2 + v);
    Ok((rho, v, net, sigma2))
}

// ---------------------------------------------------------------------------
// PJRT probes (feature `pjrt`)
// ---------------------------------------------------------------------------

/// Measure gradient bias/variance for one (method, budget) on a fixed batch
/// through the `grads_mlp_<method>` artifacts.
#[cfg(feature = "pjrt")]
pub fn measure(
    rt: &Runtime,
    method: &str,
    budget: f64,
    trials: usize,
    seed: u64,
) -> Result<VarianceReport> {
    let grads_exe = rt.load(&format!("grads_mlp_{method}"))?;
    let base_exe = rt.load("grads_mlp_baseline")?;
    let init_exe = rt.load("init_mlp")?;
    let n_params = grads_exe.spec.meta_usize("num_params")?;
    let batch = grads_exe.spec.meta_usize("batch")?;
    let num_sketched = grads_exe.spec.meta_usize("num_sketched")?;

    // parameter point: fresh init, lightly trained state not needed — the
    // variance mechanics are identical anywhere; seed fixes the point.
    let key = HostTensor::U32(vec![seed as u32, 0x1217], vec![2]).to_literal()?;
    let state = init_exe.run_refs(&[&key])?;
    let params = &state[..n_params];

    let ds = data::generate(DatasetKind::SynthMnist, batch, 99, "train");
    let x = HostTensor::F32(ds.x.clone(), vec![batch, ds.dim]).to_literal()?;
    let y = HostTensor::S32(ds.y.clone(), vec![batch]).to_literal()?;
    let pb = HostTensor::scalar_f32(budget as f32).to_literal()?;
    let lm = HostTensor::F32(vec![1.0; num_sketched], vec![num_sketched]).to_literal()?;

    // exact gradient
    let lm0 = HostTensor::F32(vec![0.0; num_sketched], vec![num_sketched]).to_literal()?;
    let k0 = HostTensor::U32(vec![7, 7], vec![2]).to_literal()?;
    let pb1 = HostTensor::scalar_f32(1.0).to_literal()?;
    let mut refs: Vec<&xla::Literal> = params.iter().collect();
    refs.extend([&x, &y, &k0, &pb1, &lm0]);
    let g_exact = base_exe.run_refs(&refs)?;
    let g = HostTensor::from_literal(&g_exact[0])?;
    let g = g.as_f32()?.to_vec();

    summarize(method, budget, &g, trials, |t| {
        let kt = HostTensor::U32(vec![seed as u32 ^ 0xabcd, t as u32], vec![2])
            .to_literal()?;
        let mut refs: Vec<&xla::Literal> = params.iter().collect();
        refs.extend([&x, &y, &kt, &pb, &lm]);
        let out = grads_exe.run_refs(&refs)?;
        Ok(HostTensor::from_literal(&out[0])?.as_f32()?.to_vec())
    })
}

/// Minibatch gradient variance σ² at the same parameter point: resample
/// batches, exact gradients (PJRT backend).
#[cfg(feature = "pjrt")]
pub fn sigma2(rt: &Runtime, trials: usize) -> Result<f64> {
    let base_exe = rt.load("grads_mlp_baseline")?;
    let init_exe = rt.load("init_mlp")?;
    let n_params = base_exe.spec.meta_usize("num_params")?;
    let batch = base_exe.spec.meta_usize("batch")?;
    let num_sketched = base_exe.spec.meta_usize("num_sketched")?;
    let key = HostTensor::U32(vec![5, 0x1217], vec![2]).to_literal()?;
    let state = init_exe.run_refs(&[&key])?;
    let params = &state[..n_params];
    let lm0 =
        HostTensor::F32(vec![0.0; num_sketched], vec![num_sketched]).to_literal()?;
    let k0 = HostTensor::U32(vec![7, 7], vec![2]).to_literal()?;
    let pb1 = HostTensor::scalar_f32(1.0).to_literal()?;

    let mut grads: Vec<Vec<f32>> = Vec::new();
    for t in 0..trials {
        let ds = data::generate(DatasetKind::SynthMnist, batch, 500 + t as u64, "train");
        let x = HostTensor::F32(ds.x.clone(), vec![batch, ds.dim]).to_literal()?;
        let y = HostTensor::S32(ds.y.clone(), vec![batch]).to_literal()?;
        let mut refs: Vec<&xla::Literal> = params.iter().collect();
        refs.extend([&x, &y, &k0, &pb1, &lm0]);
        let out = base_exe.run_refs(&refs)?;
        grads.push(HostTensor::from_literal(&out[0])?.as_f32()?.to_vec());
    }
    Ok(spread(&grads))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_probe_unbiased_and_variance_scales() {
        let lo = measure_native("l1", 0.3, 48, 5).unwrap();
        let hi = measure_native("l1", 0.8, 48, 5).unwrap();
        // Monte-Carlo mean deviation consistent with sampling noise
        let floor_lo = (lo.rel_variance() / lo.trials as f64).sqrt();
        assert!(
            lo.bias_rel < 5.0 * floor_lo.max(1e-3),
            "bias {} vs floor {floor_lo}",
            lo.bias_rel
        );
        // more budget → less injected variance
        assert!(hi.variance < lo.variance, "{} !< {}", hi.variance, lo.variance);
        assert!(lo.grad_norm_sq > 0.0);
    }

    #[test]
    fn native_probe_baseline_is_exact() {
        let rep = measure_native("baseline", 1.0, 3, 1).unwrap();
        assert!(rep.bias_rel < 1e-6);
        assert!(rep.variance < 1e-10);
    }

    #[test]
    fn native_probe_rejects_unknown_method() {
        assert!(measure_native("rcs", 0.2, 2, 0).is_err());
    }

    #[test]
    fn sigma2_native_positive() {
        let s2 = sigma2_native(6).unwrap();
        assert!(s2 > 0.0);
    }
}
