//! Gradient-variance measurement (Prop 2.2 validation + Eq 6 trade-off).
//!
//! Uses the `grads_mlp_<method>` artifacts: a fixed parameter point and a
//! fixed batch, repeated with fresh sketch keys, give Monte-Carlo estimates
//! of E[ĝ], E‖ĝ − g‖² and per-coordinate spread — the quantities §2's
//! theory reasons about.

use crate::data::{self, DatasetKind};
use crate::runtime::{HostTensor, Runtime};
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct VarianceReport {
    pub method: String,
    pub budget: f64,
    /// ‖mean_k ĝ_k − g‖ / ‖g‖ — should → 0 (unbiasedness, Prop 2.2 i)
    pub bias_rel: f64,
    /// E‖ĝ − g‖² (the V of §2.2)
    pub variance: f64,
    /// ‖g‖² for normalization
    pub grad_norm_sq: f64,
    pub trials: usize,
}

impl VarianceReport {
    /// Relative variance V / ‖g‖².
    pub fn rel_variance(&self) -> f64 {
        self.variance / self.grad_norm_sq
    }
}

/// Measure gradient bias/variance for one (method, budget) on a fixed batch.
pub fn measure(
    rt: &Runtime,
    method: &str,
    budget: f64,
    trials: usize,
    seed: u64,
) -> Result<VarianceReport> {
    let grads_exe = rt.load(&format!("grads_mlp_{method}"))?;
    let base_exe = rt.load("grads_mlp_baseline")?;
    let init_exe = rt.load("init_mlp")?;
    let n_params = grads_exe.spec.meta_usize("num_params")?;
    let batch = grads_exe.spec.meta_usize("batch")?;
    let num_sketched = grads_exe.spec.meta_usize("num_sketched")?;

    // parameter point: fresh init, lightly trained state not needed — the
    // variance mechanics are identical anywhere; seed fixes the point.
    let key = HostTensor::U32(vec![seed as u32, 0x1217], vec![2]).to_literal()?;
    let state = init_exe.run_refs(&[&key])?;
    let params = &state[..n_params];

    let ds = data::generate(DatasetKind::SynthMnist, batch, 99, "train");
    let x = HostTensor::F32(ds.x.clone(), vec![batch, ds.dim]).to_literal()?;
    let y = HostTensor::S32(ds.y.clone(), vec![batch]).to_literal()?;
    let pb = HostTensor::scalar_f32(budget as f32).to_literal()?;
    let lm = HostTensor::F32(vec![1.0; num_sketched], vec![num_sketched]).to_literal()?;

    // exact gradient
    let lm0 = HostTensor::F32(vec![0.0; num_sketched], vec![num_sketched]).to_literal()?;
    let k0 = HostTensor::U32(vec![7, 7], vec![2]).to_literal()?;
    let pb1 = HostTensor::scalar_f32(1.0).to_literal()?;
    let mut refs: Vec<&xla::Literal> = params.iter().collect();
    refs.extend([&x, &y, &k0, &pb1, &lm0]);
    let g_exact = base_exe.run_refs(&refs)?;
    let g = HostTensor::from_literal(&g_exact[0])?;
    let g = g.as_f32()?.to_vec();
    let gnorm2: f64 = g.iter().map(|&v| (v as f64) * (v as f64)).sum();

    let dim = g.len();
    let mut mean = vec![0.0f64; dim];
    let mut sq_err = 0.0f64;
    for t in 0..trials {
        let kt = HostTensor::U32(vec![seed as u32 ^ 0xabcd, t as u32], vec![2])
            .to_literal()?;
        let mut refs: Vec<&xla::Literal> = params.iter().collect();
        refs.extend([&x, &y, &kt, &pb, &lm]);
        let out = grads_exe.run_refs(&refs)?;
        let ghat = HostTensor::from_literal(&out[0])?;
        let ghat = ghat.as_f32()?;
        let mut err = 0.0f64;
        for i in 0..dim {
            let d = ghat[i] as f64 - g[i] as f64;
            err += d * d;
            mean[i] += ghat[i] as f64;
        }
        sq_err += err;
    }
    let mut bias2 = 0.0f64;
    for i in 0..dim {
        let b = mean[i] / trials as f64 - g[i] as f64;
        bias2 += b * b;
    }
    Ok(VarianceReport {
        method: method.to_string(),
        budget,
        bias_rel: (bias2 / gnorm2.max(1e-30)).sqrt(),
        variance: sq_err / trials as f64,
        grad_norm_sq: gnorm2,
        trials,
    })
}

/// Eq 6 check: net-cost comparison ρ(V)(σ²+V) vs ρ(0)σ² for the MLP layers.
///
/// σ² (minibatch gradient variance) is measured by resampling batches with
/// the exact gradient; V comes from `measure`; ρ from the analytic FLOP
/// model in `sketch::cost_ratio` over the MLP's sketched layers.
pub fn eq6_row(
    rt: &Runtime,
    method: &str,
    budget: f64,
    sigma2: f64,
    trials: usize,
) -> Result<(f64, f64, f64, f64)> {
    let rep = measure(rt, method, budget, trials, 5)?;
    // MLP sketched layers (dout, din): 784→64, 64→64, 64→10 at batch 128
    let layers = [(64usize, 784usize), (64, 64), (10, 64)];
    let total: f64 = layers
        .iter()
        .map(|&(o, i)| 4.0 * 128.0 * o as f64 * i as f64)
        .sum();
    let cost: f64 = layers
        .iter()
        .map(|&(o, i)| {
            crate::sketch::cost_ratio(128, o, i, budget)
                * 4.0
                * 128.0
                * o as f64
                * i as f64
        })
        .sum();
    let rho = cost / total;
    let v = rep.variance;
    let net = rho * (sigma2 + v);
    Ok((rho, v, net, sigma2))
}

/// Minibatch gradient variance σ² at the same parameter point: resample
/// batches, exact gradients.
pub fn sigma2(rt: &Runtime, trials: usize) -> Result<f64> {
    let base_exe = rt.load("grads_mlp_baseline")?;
    let init_exe = rt.load("init_mlp")?;
    let n_params = base_exe.spec.meta_usize("num_params")?;
    let batch = base_exe.spec.meta_usize("batch")?;
    let num_sketched = base_exe.spec.meta_usize("num_sketched")?;
    let key = HostTensor::U32(vec![5, 0x1217], vec![2]).to_literal()?;
    let state = init_exe.run_refs(&[&key])?;
    let params = &state[..n_params];
    let lm0 =
        HostTensor::F32(vec![0.0; num_sketched], vec![num_sketched]).to_literal()?;
    let k0 = HostTensor::U32(vec![7, 7], vec![2]).to_literal()?;
    let pb1 = HostTensor::scalar_f32(1.0).to_literal()?;

    let mut grads: Vec<Vec<f32>> = Vec::new();
    for t in 0..trials {
        let ds = data::generate(DatasetKind::SynthMnist, batch, 500 + t as u64, "train");
        let x = HostTensor::F32(ds.x.clone(), vec![batch, ds.dim]).to_literal()?;
        let y = HostTensor::S32(ds.y.clone(), vec![batch]).to_literal()?;
        let mut refs: Vec<&xla::Literal> = params.iter().collect();
        refs.extend([&x, &y, &k0, &pb1, &lm0]);
        let out = base_exe.run_refs(&refs)?;
        grads.push(HostTensor::from_literal(&out[0])?.as_f32()?.to_vec());
    }
    let dim = grads[0].len();
    let mut mean = vec![0.0f64; dim];
    for g in &grads {
        for i in 0..dim {
            mean[i] += g[i] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= trials as f64;
    }
    let mut var = 0.0f64;
    for g in &grads {
        for i in 0..dim {
            let d = g[i] as f64 - mean[i];
            var += d * d;
        }
    }
    Ok(var / trials as f64)
}
