//! The train → save → serve pipeline: glue between the training
//! coordinator, the checkpoint layer ([`crate::native::checkpoint`]) and
//! the serving subsystem ([`crate::serve`]).
//!
//! [`train_and_save`] backs the `train --save-ckpt <path>` CLI flag;
//! [`serve_checkpoint`] backs the `serve` subcommand: it rehydrates the
//! registry model from the checkpoint in a fresh process and drives a
//! measured serving session over synthetic test-split inputs — the same
//! generator the trainer evaluates on, so served logits can be compared
//! bitwise against an in-process forward (`tests/serve.rs`).

use std::path::Path;
use std::sync::Arc;

use crate::config::{ServeConfig, TrainConfig};
use crate::data::{self, DatasetKind};
use crate::metrics::RunCurve;
use crate::native::{checkpoint, NativeTrainer};
use crate::serve::{run_server, ServeReport};
use crate::tensor::Mat;
use anyhow::Result;

/// Run one native training session and persist the final parameters as a
/// versioned checkpoint at `path`.
pub fn train_and_save(cfg: &TrainConfig, path: &Path) -> Result<RunCurve> {
    let mut trainer = NativeTrainer::new(cfg.clone())?;
    let curve = trainer.run()?;
    trainer.save_checkpoint(path)?;
    Ok(curve)
}

/// Load the checkpoint at `path`, rebuild its registry model, and run one
/// measured serving session under `cfg`, cycling requests from the
/// model's synthetic test split (up to 512 distinct rows).
pub fn serve_checkpoint(path: &Path, cfg: &ServeConfig) -> Result<ServeReport> {
    let ckpt = checkpoint::load(path)?;
    let model = Arc::new(ckpt.build_model()?);
    let kind = DatasetKind::for_model(&ckpt.model_name)?;
    let ds = data::generate(kind, cfg.requests.clamp(1, 512), 1234, "test");
    let mut inputs = Mat::zeros(ds.n, ds.dim);
    inputs.data.copy_from_slice(&ds.x);
    Ok(run_server(&model, ds.dim, &inputs, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;

    #[test]
    fn train_save_serve_pipeline_smokes() {
        let mut cfg: TrainConfig = Preset::Smoke.base("mlp").unwrap();
        cfg.steps = 4;
        cfg.eval_every = 4;
        cfg.train_size = 128;
        cfg.test_size = 32;
        let dir = std::env::temp_dir();
        let path = dir.join("uavjp_serving_smoke.ckpt");
        let curve = train_and_save(&cfg, &path).unwrap();
        assert!(!curve.losses.is_empty());
        let scfg = ServeConfig {
            requests: 16,
            concurrency: 2,
            max_batch: 4,
            max_wait_us: 50,
            ..ServeConfig::default()
        };
        let report = serve_checkpoint(&path, &scfg).unwrap();
        assert_eq!(report.completed, 16);
        assert!(report.p99_ms >= report.p50_ms);
        let _ = std::fs::remove_file(&path);
    }
}
