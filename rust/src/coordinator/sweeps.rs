//! Sweep orchestration: LR cross-validation and (method × budget × seed)
//! grids — the protocol behind every accuracy-vs-budget figure in §5.

use crate::config::{Preset, TrainConfig};
use crate::metrics::{mean_std, RunCurve};
use crate::runtime::Runtime;
use anyhow::Result;

use super::trainer::Trainer;

/// Result of one fully-specified training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub cfg: TrainConfig,
    pub curve: RunCurve,
}

impl RunRecord {
    pub fn final_acc(&self) -> f64 {
        self.curve.final_acc().unwrap_or(0.0)
    }
}

/// Train once under `cfg`.
pub fn run_one(rt: &Runtime, cfg: TrainConfig) -> Result<RunRecord> {
    let t = Trainer::new(rt, cfg.clone())?;
    let curve = t.run()?;
    Ok(RunRecord { cfg, curve })
}

/// Cross-validate the learning rate over `grid`, as the paper does per seed:
/// train at every LR, keep the best final test accuracy.
pub fn best_over_lr(
    rt: &Runtime,
    base: &TrainConfig,
    grid: &[f64],
    verbose: bool,
) -> Result<RunRecord> {
    let mut best: Option<RunRecord> = None;
    for &lr in grid {
        let mut cfg = base.clone();
        cfg.lr = lr;
        let rec = run_one(rt, cfg)?;
        if verbose {
            eprintln!(
                "    lr={lr:.4}: acc={:.3} loss={:.3}",
                rec.final_acc(),
                rec.curve.tail_loss(20).unwrap_or(f64::NAN)
            );
        }
        let better = match &best {
            None => true,
            Some(b) => rec.final_acc() > b.final_acc(),
        };
        if better {
            best = Some(rec);
        }
    }
    Ok(best.expect("empty LR grid"))
}

/// One point of an accuracy-vs-budget curve: mean ± std over seeds of the
/// LR-cross-validated final accuracy.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub method: String,
    pub budget: f64,
    pub acc_mean: f64,
    pub acc_std: f64,
    pub accs: Vec<f64>,
    pub best_lr: f64,
}

/// Sweep a method over budgets × seeds with per-seed LR cross-validation.
pub fn budget_sweep(
    rt: &Runtime,
    preset: Preset,
    model: &str,
    method: &str,
    budgets: &[f64],
    location: &str,
    verbose: bool,
) -> Result<Vec<SweepPoint>> {
    let mut points = Vec::new();
    let grid = preset.lr_grid(model);
    for &budget in budgets {
        let mut accs = Vec::new();
        let mut best_lr = 0.0;
        for &seed in &preset.seeds() {
            let mut base = preset.base(model);
            base.method = method.to_string();
            base.budget = budget;
            base.seed = seed;
            base.location = location.to_string();
            if verbose {
                eprintln!("  [{method}] p={budget} seed={seed}");
            }
            let rec = best_over_lr(rt, &base, &grid, verbose)?;
            accs.push(rec.final_acc());
            best_lr = rec.cfg.lr;
        }
        let (m, s) = mean_std(&accs);
        points.push(SweepPoint {
            method: method.to_string(),
            budget,
            acc_mean: m,
            acc_std: s,
            accs,
            best_lr,
        });
        eprintln!(
            "[{model}/{method}] p={budget}: acc {:.3} ± {:.3}",
            m, s
        );
    }
    Ok(points)
}

/// Baseline (exact VJP) accuracy for a model under the preset.
pub fn baseline_point(
    rt: &Runtime,
    preset: Preset,
    model: &str,
    verbose: bool,
) -> Result<SweepPoint> {
    let pts = budget_sweep(rt, preset, model, "baseline", &[1.0], "none", verbose)?;
    Ok(pts.into_iter().next().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_point_shape() {
        let p = SweepPoint {
            method: "l1".into(),
            budget: 0.1,
            acc_mean: 0.8,
            acc_std: 0.01,
            accs: vec![0.79, 0.81],
            best_lr: 0.1,
        };
        assert_eq!(p.accs.len(), 2);
    }
}
