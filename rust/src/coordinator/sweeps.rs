//! Sweep orchestration: LR cross-validation and (method × budget × seed)
//! grids — the protocol behind every accuracy-vs-budget figure in §5.
//! Backend-agnostic: everything runs through [`TrainBackend`], so the same
//! sweep drives native or PJRT training.

use crate::config::{Preset, TrainConfig};
use crate::metrics::{mean_std, RunCurve};
use anyhow::Result;

use super::backend::TrainBackend;

/// Result of one fully-specified training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// The configuration that produced this run.
    pub cfg: TrainConfig,
    /// The loss/eval time series.
    pub curve: RunCurve,
}

impl RunRecord {
    /// Final test accuracy (0.0 when the run recorded no evals).
    pub fn final_acc(&self) -> f64 {
        self.curve.final_acc().unwrap_or(0.0)
    }
}

/// Train once under `cfg`.
pub fn run_one(be: &dyn TrainBackend, cfg: TrainConfig) -> Result<RunRecord> {
    let curve = be.train(&cfg)?;
    Ok(RunRecord { cfg, curve })
}

/// Cross-validate the learning rate over `grid`, as the paper does per seed:
/// train at every LR, keep the best final test accuracy.
pub fn best_over_lr(
    be: &dyn TrainBackend,
    base: &TrainConfig,
    grid: &[f64],
    verbose: bool,
) -> Result<RunRecord> {
    let mut best: Option<RunRecord> = None;
    for &lr in grid {
        let mut cfg = base.clone();
        cfg.lr = lr;
        let rec = run_one(be, cfg)?;
        if verbose {
            eprintln!(
                "    lr={lr:.4}: acc={:.3} loss={:.3}",
                rec.final_acc(),
                rec.curve.tail_loss(20).unwrap_or(f64::NAN)
            );
        }
        let better = match &best {
            None => true,
            Some(b) => rec.final_acc() > b.final_acc(),
        };
        if better {
            best = Some(rec);
        }
    }
    Ok(best.expect("empty LR grid"))
}

/// One point of an accuracy-vs-budget curve: mean ± std over seeds of the
/// LR-cross-validated final accuracy.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Sketch method of this series.
    pub method: String,
    /// Kept-column budget p.
    pub budget: f64,
    /// Mean final accuracy over seeds.
    pub acc_mean: f64,
    /// Std of final accuracy over seeds.
    pub acc_std: f64,
    /// Per-seed accuracies behind the mean.
    pub accs: Vec<f64>,
    /// LR the cross-validation picked for the last seed.
    pub best_lr: f64,
}

/// Sweep a method over budgets × seeds with per-seed LR cross-validation.
pub fn budget_sweep(
    be: &dyn TrainBackend,
    preset: Preset,
    model: &str,
    method: &str,
    budgets: &[f64],
    location: &str,
    verbose: bool,
) -> Result<Vec<SweepPoint>> {
    let mut points = Vec::new();
    let grid = preset.lr_grid(model)?;
    for &budget in budgets {
        let mut accs = Vec::new();
        let mut best_lr = 0.0;
        for &seed in &preset.seeds() {
            let mut base = preset.base(model)?;
            base.method = method.to_string();
            base.budget = budget;
            base.seed = seed;
            base.location = location.to_string();
            if verbose {
                eprintln!("  [{method}] p={budget} seed={seed}");
            }
            let rec = best_over_lr(be, &base, &grid, verbose)?;
            accs.push(rec.final_acc());
            best_lr = rec.cfg.lr;
        }
        let (m, s) = mean_std(&accs);
        points.push(SweepPoint {
            method: method.to_string(),
            budget,
            acc_mean: m,
            acc_std: s,
            accs,
            best_lr,
        });
        eprintln!(
            "[{model}/{method}] p={budget}: acc {:.3} ± {:.3}",
            m, s
        );
    }
    Ok(points)
}

/// Baseline (exact VJP) accuracy for a model under the preset.
pub fn baseline_point(
    be: &dyn TrainBackend,
    preset: Preset,
    model: &str,
    verbose: bool,
) -> Result<SweepPoint> {
    let pts = budget_sweep(be, preset, model, "baseline", &[1.0], "none", verbose)?;
    Ok(pts.into_iter().next().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;

    #[test]
    fn sweep_point_shape() {
        let p = SweepPoint {
            method: "l1".into(),
            budget: 0.1,
            acc_mean: 0.8,
            acc_std: 0.01,
            accs: vec![0.79, 0.81],
            best_lr: 0.1,
        };
        assert_eq!(p.accs.len(), 2);
    }

    #[test]
    fn best_over_lr_picks_better_run() {
        let mut base = Preset::Smoke.base("mlp").unwrap();
        base.method = "baseline".into();
        base.train_size = 128;
        base.test_size = 64;
        base.steps = 16;
        base.eval_every = 16;
        base.batch = 32;
        // lr 0 cannot learn; a sane lr must win the cross-validation
        let rec = best_over_lr(&NativeBackend, &base, &[0.0, 0.1], false).unwrap();
        assert_eq!(rec.cfg.lr, 0.1);
    }
}
