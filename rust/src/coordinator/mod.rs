//! L3 coordinator: the training orchestrator.
//!
//! This is where the paper's protocol lives: backend selection and dispatch
//! ([`backend`]), single-run training loops over AOT-compiled step artifacts
//! ([`trainer`], feature `pjrt`), learning-rate cross-validation and
//! (method × budget × seed) sweeps ([`sweeps`]), gradient-variance
//! measurement for the Prop 2.2 / Eq 6 analyses ([`variance`]), and the
//! per-figure experiment registry ([`experiments`]) that regenerates every
//! figure/table of §5 as CSV + markdown under `results/`, and the
//! train → save → serve pipeline ([`serving`]) behind the `serve`
//! subcommand. Sweeps,
//! experiments and variance probes are backend-agnostic: they drive
//! [`backend::TrainBackend`], so `--backend native` runs the whole protocol
//! without artifacts (DESIGN.md §7).

pub mod backend;
pub mod experiments;
pub mod serving;
pub mod sweeps;
pub mod trainer;
pub mod variance;

pub use backend::{NativeBackend, TrainBackend};
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
