//! L3 coordinator: the training orchestrator.
//!
//! This is where the paper's protocol lives: single-run training loops over
//! AOT-compiled step artifacts ([`trainer`]), learning-rate cross-validation
//! and (method × budget × seed) sweeps ([`sweeps`]), gradient-variance
//! measurement for the Prop 2.2 / Eq 6 analyses ([`variance`]), and the
//! per-figure experiment registry ([`experiments`]) that regenerates every
//! figure/table of §5 as CSV + markdown under `results/`.

pub mod experiments;
pub mod sweeps;
pub mod trainer;
pub mod variance;

pub use trainer::Trainer;
