//! Single-run training loop over AOT step artifacts (feature `pjrt`).
//!
//! The trainer owns no python: it executes `init_<model>`,
//! `train_<model>_<method>` and `eval_<model>` artifacts through the PJRT
//! runtime, feeding batches from the synthetic dataset generators and
//! threading (params, opt_state) as raw `xla::Literal`s between steps.
//! The artifact-free counterpart is [`crate::native::NativeTrainer`];
//! [`layer_mask`] is shared by both.

#[cfg(feature = "pjrt")]
use crate::config::TrainConfig;
#[cfg(feature = "pjrt")]
use crate::data::{self, BatchIter, Dataset, DatasetKind};
#[cfg(feature = "pjrt")]
use crate::metrics::RunCurve;
#[cfg(feature = "pjrt")]
use crate::rng::{streams, Pcg64};
#[cfg(feature = "pjrt")]
use crate::runtime::{Executable, HostTensor, Runtime};
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

/// Per-layer sketch gate from the config's `location` field, as the f32
/// mask vector the PJRT artifacts take. Delegates to the native
/// [`crate::native::SketchPolicy`] site-mask so both backends agree on the
/// location grammar; errors (instead of panicking) on an unknown location.
pub fn layer_mask(location: &str, num_sketched: usize) -> anyhow::Result<Vec<f32>> {
    let mask = crate::native::SketchPolicy::site_mask(location, num_sketched)?;
    Ok(mask.into_iter().map(|on| if on { 1.0 } else { 0.0 }).collect())
}

/// PJRT training-loop driver over one model/method artifact triple.
#[cfg(feature = "pjrt")]
pub struct Trainer<'rt> {
    /// The artifact runtime executing the steps.
    pub rt: &'rt Runtime,
    /// The run configuration.
    pub cfg: TrainConfig,
    train_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
    init_exe: Rc<Executable>,
    n_state: usize, // params + opt leaves carried between steps
    n_params: usize,
    batch: usize,
    num_sketched: usize,
}

#[cfg(feature = "pjrt")]
impl<'rt> Trainer<'rt> {
    /// Load the `train_/eval_/init_` artifacts for `cfg.model` / `cfg.method`.
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig) -> Result<Trainer<'rt>> {
        let train_name = format!("train_{}_{}", cfg.model, cfg.method);
        let train_exe = rt
            .load(&train_name)
            .with_context(|| format!("loading {train_name}"))?;
        let eval_exe = rt.load(&format!("eval_{}", cfg.model))?;
        let init_exe = rt.load(&format!("init_{}", cfg.model))?;
        let n_params = train_exe.spec.meta_usize("num_params")?;
        let n_opt = train_exe.spec.meta_usize("num_opt")?;
        let batch = train_exe.spec.meta_usize("batch")?;
        let num_sketched = train_exe.spec.meta_usize("num_sketched")?;
        Ok(Trainer {
            rt,
            cfg,
            train_exe,
            eval_exe,
            init_exe,
            n_state: n_params + n_opt,
            n_params,
            batch,
            num_sketched,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Initialize (params, opt_state) literals from the model's init artifact.
    pub fn init_state(&self) -> Result<Vec<xla::Literal>> {
        let key = HostTensor::U32(
            vec![(self.cfg.seed >> 32) as u32 ^ 0x5eed, self.cfg.seed as u32],
            vec![2],
        );
        let outs = self.train_literals(&self.init_exe, &[key.to_literal()?])?;
        if outs.len() != self.n_state {
            bail!("init returned {} leaves, expected {}", outs.len(), self.n_state);
        }
        Ok(outs)
    }

    fn train_literals(
        &self,
        exe: &Executable,
        lits: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        exe.run_literals_raw(lits)
    }

    /// Generate this run's datasets.
    pub fn datasets(&self) -> Result<(Dataset, Dataset)> {
        let kind = DatasetKind::for_model(&self.cfg.model)?;
        // dataset contents are shared across methods/seeds (generator seed
        // fixed) so comparisons are paired; batch order varies with cfg.seed.
        let train = data::generate(kind, self.cfg.train_size, 1234, "train");
        let test = data::generate(kind, self.cfg.test_size, 1234, "test");
        Ok((train, test))
    }

    /// Full training run; returns the loss/eval curve.
    pub fn run(&self) -> Result<RunCurve> {
        let (train_ds, test_ds) = self.datasets()?;
        let mut state = self.init_state()?;
        let mut curve = RunCurve::default();
        let mut rng = streams::train_batch(self.cfg.seed);

        let dim = train_ds.dim;
        let mut xbuf = vec![0.0f32; self.batch * dim];
        let mut ybuf = vec![0i32; self.batch];
        let mask = layer_mask(&self.cfg.location, self.num_sketched)?;
        let x_shape = self.train_exe.spec.inputs[self.n_state].shape.clone();

        let mut step = 0usize;
        'outer: loop {
            let mut iter = BatchIter::new(&train_ds, self.batch, &mut rng);
            while iter.next_into(&mut xbuf, &mut ybuf) {
                if step >= self.cfg.steps {
                    break 'outer;
                }
                let loss = self.step(&mut state, &xbuf, &ybuf, &x_shape, &mask, step)?;
                if !loss.is_finite() {
                    // diverged (bad LR) — record and stop early
                    curve.record_loss(step, f64::INFINITY);
                    break 'outer;
                }
                curve.record_loss(step, loss);
                step += 1;
                if step % self.cfg.eval_every == 0 || step == self.cfg.steps {
                    let (el, ea) = self.evaluate(&state, &test_ds)?;
                    curve.record_eval(step, el, ea);
                }
            }
            if step >= self.cfg.steps {
                break;
            }
        }
        if curve.evals.is_empty() {
            let (el, ea) = self.evaluate(&state, &test_ds)?;
            curve.record_eval(step, el, ea);
        }
        Ok(curve)
    }

    /// One optimizer step; `state` is updated in place.
    pub fn step(
        &self,
        state: &mut Vec<xla::Literal>,
        x: &[f32],
        y: &[i32],
        x_shape: &[usize],
        mask: &[f32],
        step: usize,
    ) -> Result<f64> {
        let xt = HostTensor::F32(x.to_vec(), x_shape.to_vec());
        let yt = HostTensor::S32(y.to_vec(), vec![self.batch]);
        let key = HostTensor::U32(
            vec![self.cfg.seed as u32 ^ 0x9e3779b9, step as u32],
            vec![2],
        );
        let pb = HostTensor::scalar_f32(self.cfg.budget as f32);
        let lm = HostTensor::F32(mask.to_vec(), vec![mask.len()]);
        let lr = HostTensor::scalar_f32(self.cfg.lr_at(step) as f32);

        let locals: Vec<xla::Literal> = [&xt, &yt, &key, &pb, &lm, &lr]
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(self.n_state + 6);
        refs.extend(state.iter());
        refs.extend(locals.iter());
        let mut outs = self.train_exe.run_refs(&refs)?;
        let loss_lit = outs.pop().expect("loss output");
        let loss = HostTensor::from_literal(&loss_lit)?.f32_scalar()? as f64;
        *state = outs;
        Ok(loss)
    }

    /// Evaluate on the full test set; returns (mean loss, accuracy).
    pub fn evaluate(
        &self,
        state: &[xla::Literal],
        test: &Dataset,
    ) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        let dim = test.dim;
        let x_shape = self.eval_exe.spec.inputs[self.n_params].shape.clone();
        let mut xbuf = vec![0.0f32; self.batch * dim];
        let mut ybuf = vec![0i32; self.batch];
        let nb = test.n / self.batch;
        for b in 0..nb {
            for (bi, idx) in (b * self.batch..(b + 1) * self.batch).enumerate() {
                xbuf[bi * dim..(bi + 1) * dim]
                    .copy_from_slice(&test.x[idx * dim..(idx + 1) * dim]);
                ybuf[bi] = test.y[idx];
            }
            let xl = HostTensor::F32(xbuf.clone(), x_shape.clone()).to_literal()?;
            let yl = HostTensor::S32(ybuf.clone(), vec![self.batch]).to_literal()?;
            let mut refs: Vec<&xla::Literal> = Vec::with_capacity(self.n_params + 2);
            refs.extend(state[..self.n_params].iter());
            refs.push(&xl);
            refs.push(&yl);
            let outs = self.eval_exe.run_refs(&refs)?;
            loss_sum += HostTensor::from_literal(&outs[0])?.f32_scalar()? as f64;
            correct += HostTensor::from_literal(&outs[1])?.f32_scalar()? as f64;
            seen += self.batch;
        }
        if seen == 0 {
            bail!("test set smaller than one batch");
        }
        Ok((loss_sum / seen as f64, correct / seen as f64))
    }
}

/// Copy a literal (xla::Literal has no Clone; reshape to same dims copies).
#[cfg(feature = "pjrt")]
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    Ok(l.reshape(shape.dims())?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_mask_variants() {
        assert_eq!(layer_mask("all", 3).unwrap(), vec![1.0, 1.0, 1.0]);
        assert_eq!(layer_mask("first", 3).unwrap(), vec![1.0, 0.0, 0.0]);
        assert_eq!(layer_mask("last", 3).unwrap(), vec![0.0, 0.0, 1.0]);
        assert_eq!(layer_mask("none", 2).unwrap(), vec![0.0, 0.0]);
    }

    #[test]
    fn layer_mask_bad_location_errors() {
        let err = format!("{}", layer_mask("middle", 3).unwrap_err());
        assert!(err.contains("all|first|last|none"), "{err}");
    }
}
