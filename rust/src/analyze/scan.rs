//! Line/token-level Rust source scanner for `uavjp-analyze`.
//!
//! No external parser crates (the repo's vendored-shim ethos): the
//! scanner splits each line into a (code, comment) pair with string and
//! char literal *contents* blanked — a token inside a literal can never
//! trigger a lint, which is also what lets the analyzer scan its own
//! sources and fixtures without tripping over them. On top of that it
//! offers brace-depth tracking, `#[cfg(test)] mod` region detection and
//! named-fn body extraction, which is all the passes in
//! [`crate::analyze::passes`] need.
//!
//! Semantics are mirrored one-for-one by `python/tools/analyze_mirror.py`
//! (used to pre-verify tree-wide results); keep the two in sync.

/// Per-line split of a source file: `code[i]` is line `i` with comments
/// removed and literal contents blanked (quotes kept as markers);
/// `comment[i]` is the comment text of line `i` (kept aside so
/// `SAFETY:` and allow-waiver detection still work).
pub struct Lines {
    pub code: Vec<String>,
    pub comment: Vec<String>,
}

enum Mode {
    Normal,
    LineComment,
    BlockComment,
    Str,
    RawStr,
}

/// Split `text` into sanitized code/comment lines (see [`Lines`]).
/// Handles nested block comments, raw strings (`r#"…"#`), char literals
/// vs. lifetime ticks, and escaped-newline string continuations (the
/// line break is still emitted so diagnostics keep true line numbers).
pub fn sanitize(text: &str) -> Lines {
    let cs: Vec<char> = text.chars().collect();
    let n = cs.len();
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Normal;
    let mut block_depth = 0i32;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        let nxt = if i + 1 < n { cs[i + 1] } else { '\0' };
        if c == '\n' {
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut comment));
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Normal;
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment => {
                comment.push(c);
                if c == '/' && nxt == '*' {
                    block_depth += 1;
                    comment.push(nxt);
                    i += 2;
                } else if c == '*' && nxt == '/' {
                    block_depth -= 1;
                    comment.push(nxt);
                    i += 2;
                    if block_depth == 0 {
                        mode = Mode::Normal;
                    }
                } else {
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    if nxt == '\n' {
                        // escaped-newline continuation: the literal spans
                        // the break, but the diagnostic line count must
                        // not drift — emit the line boundary.
                        code_lines.push(std::mem::take(&mut code));
                        comment_lines.push(std::mem::take(&mut comment));
                    }
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr => {
                let closes = c == '"'
                    && i + raw_hashes < n
                    && cs[i + 1..i + 1 + raw_hashes].iter().all(|&h| h == '#');
                if closes {
                    code.push('"');
                    mode = Mode::Normal;
                    i += 1 + raw_hashes;
                } else {
                    if c == '\n' {
                        // raw strings may span lines; keep line numbers
                        code_lines.push(std::mem::take(&mut code));
                        comment_lines.push(std::mem::take(&mut comment));
                    }
                    i += 1;
                }
            }
            Mode::Normal => {
                if c == '/' && nxt == '/' {
                    comment.push_str("//");
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && nxt == '*' {
                    comment.push_str("/*");
                    mode = Mode::BlockComment;
                    block_depth = 1;
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if c == 'r'
                    && (nxt == '"' || nxt == '#')
                    && !code
                        .chars()
                        .last()
                        .map(|p| p.is_alphanumeric() || p == '_')
                        .unwrap_or(false)
                {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        code.push_str("r\"");
                        raw_hashes = h;
                        mode = Mode::RawStr;
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal ('x' or '\x…') vs. lifetime tick
                    if let Some(len) = char_literal_len(&cs[i..]) {
                        code.push_str("' '");
                        i += len;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        code_lines.push(code);
        comment_lines.push(comment);
    }
    Lines { code: code_lines, comment: comment_lines }
}

/// Length (in chars, including both quotes) of a char literal starting
/// at `cs[0] == '\''`, or `None` when this tick is a lifetime.
fn char_literal_len(cs: &[char]) -> Option<usize> {
    if cs.len() < 3 {
        return None;
    }
    if cs[1] == '\\' {
        // '\x…': backslash, one escaped char, then anything up to the
        // closing quote
        let mut k = 3;
        while k < cs.len() && cs[k] != '\'' {
            k += 1;
        }
        if k < cs.len() {
            return Some(k + 1);
        }
        None
    } else if cs[1] != '\'' && cs[2] == '\'' {
        Some(3)
    } else {
        None
    }
}

/// Brace depth *before* each line.
pub fn depths(code: &[String]) -> Vec<i32> {
    let mut out = Vec::with_capacity(code.len());
    let mut d = 0i32;
    for ln in code {
        out.push(d);
        d += brace_delta(ln);
    }
    out
}

fn brace_delta(ln: &str) -> i32 {
    let mut d = 0i32;
    for ch in ln.chars() {
        if ch == '{' {
            d += 1;
        } else if ch == '}' {
            d -= 1;
        }
    }
    d
}

/// True when the line carries a `#[cfg(test)]` / `#[cfg(all(test, …))]`
/// attribute.
fn has_cfg_test(ln: &str) -> bool {
    let Some(p) = ln.find("#[cfg(") else { return false };
    let rest = ln[p + 6..].trim_start();
    let rest = match rest.strip_prefix("all(") {
        Some(r) => r.trim_start(),
        None => rest,
    };
    rest.starts_with("test")
}

/// True when the trimmed line opens a `mod` / `pub mod` declaration.
fn is_mod_decl(ln: &str) -> bool {
    let t = ln.trim_start();
    let t = match t.strip_prefix("pub") {
        Some(r) if r.starts_with(char::is_whitespace) => r.trim_start(),
        Some(_) => return false,
        None => t,
    };
    match t.strip_prefix("mod") {
        Some(r) => r.chars().next().map(|c| !c.is_alphanumeric() && c != '_').unwrap_or(true),
        None => false,
    }
}

/// Bool per line: inside a `#[cfg(test)] mod …` region (or the single
/// item a bare `#[cfg(test)]` attribute guards).
pub fn test_regions(code: &[String]) -> Vec<bool> {
    let n = code.len();
    let mut is_test = vec![false; n];
    let dep = depths(code);
    let mut i = 0usize;
    while i < n {
        if has_cfg_test(&code[i]) {
            let mut j = i + 1;
            while j < n
                && (code[j].trim().is_empty() || code[j].trim().starts_with("#["))
            {
                j += 1;
            }
            if j < n && is_mod_decl(&code[j]) {
                let d0 = dep[j];
                let mut k = j;
                while k < n {
                    is_test[k] = true;
                    let d = dep[k] + brace_delta(&code[k]);
                    if (k > j || code[k].contains('{'))
                        && d <= d0
                        && code[j..=k].iter().any(|l| l.contains('{'))
                    {
                        break;
                    }
                    k += 1;
                }
                i = k + 1;
                continue;
            } else if j < n {
                is_test[j] = true;
                i = j + 1;
                continue;
            }
        }
        i += 1;
    }
    is_test
}

/// First `fn <name>` declared on the line, if any.
fn fn_name(ln: &str) -> Option<&str> {
    let bytes = ln.as_bytes();
    let mut start = 0usize;
    while let Some(p) = ln[start..].find("fn") {
        let p = start + p;
        let pre_ok = p == 0
            || !(bytes[p - 1].is_ascii_alphanumeric() || bytes[p - 1] == b'_');
        let after = &ln[p + 2..];
        if pre_ok && after.starts_with(char::is_whitespace) {
            let name = after.trim_start();
            let end = name
                .find(|c: char| !(c.is_alphanumeric() || c == '_'))
                .unwrap_or(name.len());
            if end > 0 {
                return Some(&name[..end]);
            }
        }
        start = p + 2;
    }
    None
}

/// Bool per line: inside the body (declaration through closing brace) of
/// a fn whose name is in `names`.
pub fn fn_regions(code: &[String], names: &[&str]) -> Vec<bool> {
    let n = code.len();
    let mut hot = vec![false; n];
    for i in 0..n {
        let Some(name) = fn_name(&code[i]) else { continue };
        if !names.contains(&name) {
            continue;
        }
        let mut d = 0i32;
        let mut opened = false;
        let mut k = i;
        while k < n {
            for ch in code[k].chars() {
                if ch == '{' {
                    d += 1;
                    opened = true;
                } else if ch == '}' {
                    d -= 1;
                }
            }
            hot[k] = true;
            if opened && d <= 0 {
                break;
            }
            k += 1;
        }
    }
    hot
}

/// Whole-word occurrence of `tok` in `line` (word chars: `[A-Za-z0-9_]`).
pub fn word_in(tok: &str, line: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0usize;
    while let Some(p) = line[start..].find(tok) {
        let p = start + p;
        let pre_ok = p == 0
            || !(bytes[p - 1].is_ascii_alphanumeric() || bytes[p - 1] == b'_');
        let q = p + tok.len();
        let post_ok = q >= bytes.len()
            || !(bytes[q].is_ascii_alphanumeric() || bytes[q] == b'_');
        if pre_ok && post_ok {
            return true;
        }
        start = p + tok.len().max(1);
    }
    false
}

/// Parse a well-formed allow waiver — `analyze:` followed by
/// `allow(<kind>, <reason>)` — out of one comment line, returning the
/// kind. The grammar requires a non-empty reason; [`allow_intent`]
/// spots attempts that fail this parse.
pub fn allow_in(comment: &str) -> Option<&str> {
    let p = comment.find("analyze:")?;
    let rest = comment[p + 8..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let kind_end = rest
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .unwrap_or(rest.len());
    if kind_end == 0 {
        return None;
    }
    let (kind, rest) = rest.split_at(kind_end);
    let rest = rest.strip_prefix(',')?;
    let body_end = rest.find(')')?;
    if rest[..body_end].trim().is_empty() {
        return None;
    }
    Some(kind)
}

/// True when the comment *tries* to be an allow annotation (`analyze:`
/// followed by `allow(`) — used to flag malformed attempts instead of
/// silently ignoring them.
pub fn allow_intent(comment: &str) -> bool {
    if let Some(p) = comment.find("analyze:") {
        comment[p + 8..].trim_start().starts_with("allow(")
    } else {
        false
    }
}

/// Does an allow annotation of `kind` cover line `i`?
/// An allow comment covers its own line (trailing form) and, when placed
/// on its own line, the remainder of the statement that follows it: the
/// walk back from the finding stops at the first earlier line ending in
/// a statement/block terminator (`;`, `{`, `}`), capped at 12 lines.
pub fn has_allow(kind: &str, code: &[String], comment: &[String], i: usize) -> bool {
    let lo = i.saturating_sub(12);
    for j in (lo..=i).rev() {
        if allow_in(&comment[j]) == Some(kind) {
            return true;
        }
        if j < i {
            if let Some(last) = code[j].trim_end().chars().last() {
                if last == ';' || last == '{' || last == '}' {
                    return false;
                }
            }
        }
    }
    false
}

/// Split `s` on top-level commas (brackets of any kind nest).
pub fn split_top(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut d = 0i32;
    for ch in s.chars() {
        match ch {
            '(' | '[' | '{' => d += 1,
            ')' | ']' | '}' => d -= 1,
            _ => {}
        }
        if ch == ',' && d == 0 {
            parts.push(std::mem::take(&mut cur));
        } else {
            cur.push(ch);
        }
    }
    parts.push(cur);
    parts
}

/// Balanced-paren argument text of a call whose `(` sits at char column
/// `col` of code line `i`; spans lines (joined with a space). `None` if
/// the call never closes.
pub fn extract_call(code: &[String], i: usize, col: usize) -> Option<String> {
    let mut buf = String::new();
    let mut d = 0i32;
    let mut k = i;
    let mut pos = col;
    while k < code.len() {
        let ln: Vec<char> = code[k].chars().collect();
        while pos < ln.len() {
            let ch = ln[pos];
            if ch == '(' {
                d += 1;
                if d == 1 {
                    pos += 1;
                    continue;
                }
            } else if ch == ')' {
                d -= 1;
                if d == 0 {
                    return Some(buf);
                }
            }
            if d >= 1 {
                buf.push(ch);
            }
            pos += 1;
        }
        buf.push(' ');
        k += 1;
        pos = 0;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_blanks_literals_and_keeps_line_numbers() {
        let src = concat!(
            "let a = \"Vec::new inside\"; // trailing\n",
            "let b = 'x';\n",
            "let c = \"two \\\n line\";\n",
        );
        let l = sanitize(src);
        assert_eq!(l.code.len(), 4);
        assert!(!l.code[0].contains("Vec::new"));
        assert!(l.comment[0].contains("trailing"));
        assert_eq!(l.code[1], "let b = ' ';");
        // escaped-newline continuation still emits the line boundary
        assert!(l.code[2].starts_with("let c = \""));
        assert!(l.code[3].contains('"'));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"unsafe vec! HashMap\"#;\n";
        let l = sanitize(src);
        assert!(!l.code[0].contains("vec!"));
        assert!(!l.code[0].contains("HashMap"));
    }

    #[test]
    fn test_region_covers_cfg_test_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let l = sanitize(src);
        let t = test_regions(&l.code);
        assert_eq!(t, vec![false, false, true, true, true, false]);
    }

    #[test]
    fn fn_region_tracks_named_body() {
        let src = "fn cold() {\n    x();\n}\nfn step() {\n    y();\n}\n";
        let l = sanitize(src);
        let h = fn_regions(&l.code, &["step"]);
        assert_eq!(h, vec![false, false, false, true, true, true]);
    }

    #[test]
    fn allow_grammar() {
        assert_eq!(allow_in("// analyze: allow(alloc, small table)"), Some("alloc"));
        assert_eq!(allow_in("// analyze: allow(alloc)"), None);
        assert!(allow_intent("// analyze: allow(alloc)"));
        assert!(!allow_intent("// analyze::passes docs"));
    }

    #[test]
    fn multi_line_call_extraction() {
        let l = sanitize("f(\n    a,\n    b,\n);\n");
        let args = extract_call(&l.code, 0, 1).unwrap();
        let parts = split_top(&args);
        assert_eq!(parts.len(), 3); // trailing comma leaves an empty part
        assert_eq!(parts[0].trim(), "a");
        assert_eq!(parts[1].trim(), "b");
        assert_eq!(parts[2].trim(), "");
    }
}
