//! `uavjp-analyze` — repo-invariant static analysis (DESIGN.md §7.8).
//!
//! A zero-dependency, line/token-level analyzer (no external parser
//! crates, matching the repo's vendored-shim ethos) that turns the
//! correctness contracts DESIGN.md documents into machine-checked,
//! regression-proof properties:
//!
//! 1. **RNG stream hygiene** ([`passes::rng_pass`]) — every non-test
//!    `Pcg64::new` outside `src/rng/` is flagged; production streams
//!    must route through the named constructors of
//!    [`crate::rng::streams`], whose registry the analyzer reads
//!    directly (no mirrored table to drift).
//! 2. **Unsafe discipline** ([`passes::unsafe_pass`]) — `unsafe` stays
//!    confined to the kernel-file allowlist and every use carries a
//!    `// SAFETY:` justification (§7.3).
//! 3. **Determinism** ([`passes::det_pass`]) — no `HashMap`/`HashSet`,
//!    wall-clock reads, or unordered reductions in the deterministic
//!    compute modules (§7.4–§7.7).
//! 4. **Hot-path allocation** ([`passes::alloc_pass`]) — the declared
//!    steady-state functions may not allocate (§7.2); justified
//!    exceptions carry `analyze:`-prefixed `allow(alloc, reason)`
//!    waivers, which are counted and reported.
//!
//! Run it with `cargo run --release --bin uavjp-analyze`; CI fails on
//! any finding. Diagnostics are `file:line: [pass] message`, sorted and
//! deterministic.

pub mod fixtures;
pub mod passes;
pub mod scan;

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// Which lint pass produced a finding. The slug is part of the stable
/// diagnostic format (golden-tested in `tests/analyze_lints.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    RngStream,
    Unsafe,
    Determinism,
    HotAlloc,
    AllowGrammar,
}

impl Pass {
    pub fn slug(self) -> &'static str {
        match self {
            Pass::RngStream => "rng-stream",
            Pass::Unsafe => "unsafe",
            Pass::Determinism => "determinism",
            Pass::HotAlloc => "hot-alloc",
            Pass::AllowGrammar => "allow-grammar",
        }
    }
}

/// One diagnostic: `{file}:{line}: [{pass}] {message}`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub pass: Pass,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(pass: Pass, file: &str, line: usize, message: String) -> Finding {
        Finding { pass, file: file.to_string(), line, message }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.pass.slug(), self.message)
    }
}

/// Result of analyzing one file or a whole tree: sorted findings plus
/// the per-kind count of well-formed `analyze: allow` waivers.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub allows: BTreeMap<&'static str, usize>,
    pub files_scanned: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable waiver summary, e.g. `alloc: 6, nondet: 1`.
    pub fn allow_summary(&self) -> String {
        let mut parts: Vec<String> =
            self.allows.iter().map(|(k, v)| format!("{k}: {v}")).collect();
        if parts.is_empty() {
            parts.push("none".to_string());
        }
        parts.join(", ")
    }

    fn sort(&mut self) {
        self.findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    }
}

/// Analyze one file's source text under its repo-relative path
/// (`src/...` or `tests/...` — the path decides pass applicability).
pub fn analyze_source(relpath: &str, text: &str) -> Report {
    let mut rep = Report { files_scanned: 1, ..Report::default() };
    rep.findings = passes::analyze_file(relpath, text, &mut rep.allows);
    rep.sort();
    rep
}

/// Analyze every `.rs` file under `<root>/src` and `<root>/tests`
/// (`root` is the crate dir, e.g. `rust/`). Traversal is sorted, so the
/// report is deterministic.
pub fn analyze_tree(root: &Path) -> std::io::Result<Report> {
    let mut rep = Report::default();
    for base in ["src", "tests"] {
        let dir = root.join(base);
        if !dir.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs(&dir, &mut files)?;
        files.sort();
        for path in files {
            let rel = path.strip_prefix(root).unwrap_or(&path).to_string_lossy();
            let rel = rel.replace('\\', "/");
            let text = std::fs::read_to_string(&path)?;
            rep.findings.extend(passes::analyze_file(&rel, &text, &mut rep.allows));
            rep.files_scanned += 1;
        }
    }
    rep.sort();
    Ok(rep)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}
