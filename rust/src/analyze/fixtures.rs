//! Inline source fixtures for the analyzer's own test suite
//! (`tests/analyze_lints.rs`): one snippet per lint pass that must fire
//! exactly once, a clean snippet that must fire nothing, and
//! allow-comment snippets for the waiver grammar. Everything lives in
//! string literals, so the analyzer scanning its own tree blanks them.

/// Fires nothing under any pass (analyzed as `src/native/clean.rs`).
pub const CLEAN: &str = r#"
use crate::tensor::Mat;

pub fn double(m: &mut Mat) {
    for v in m.data.iter_mut() {
        *v *= 2.0;
    }
}
"#;

/// Exactly one `rng-stream` finding: an undeclared (xor, stream) pair
/// (analyzed as `src/native/clean.rs` — any non-`src/rng/` source path).
pub const RNG_UNDECLARED: &str = r#"
use crate::rng::Pcg64;

fn make(seed: u64) -> Pcg64 {
    Pcg64::new(seed ^ 0xbeef, 4242)
}
"#;

/// Exactly one `rng-stream` finding: an ad-hoc derivation of the
/// *declared* `sketch-gates` stream that should route through
/// `rng::streams::sketch_gates`.
pub const RNG_ADHOC_DECLARED: &str = r#"
use crate::rng::Pcg64;

fn make(seed: u64) -> Pcg64 {
    Pcg64::new(seed ^ 0x9e3779b9, 11)
}
"#;

/// Exactly one `unsafe` finding when analyzed under a non-allowlisted
/// path such as `src/serve/engine.rs`.
pub const UNSAFE_OUTSIDE: &str = r#"
fn poke(p: *mut f32) {
    unsafe { *p = 1.0 };
}
"#;

/// Exactly one `unsafe` finding (missing `// SAFETY:`) when analyzed
/// under an allowlisted path such as `src/tensor/kernels/vec.rs`.
pub const UNSAFE_NO_SAFETY: &str = r#"
fn poke(p: *mut f32) {
    unsafe { *p = 1.0 };
}
"#;

/// Zero findings: allowlisted path and a `SAFETY:` justification.
pub const UNSAFE_JUSTIFIED: &str = r#"
fn poke(p: *mut f32) {
    // SAFETY: caller guarantees p is valid and exclusively owned.
    unsafe { *p = 1.0 };
}
"#;

/// Exactly one `determinism` finding when analyzed under a deterministic
/// module path such as `src/native/clean.rs`.
pub const DET_HASHMAP: &str = r#"
use std::collections::HashMap;

pub fn tally(xs: &[u32]) -> usize {
    xs.len()
}
"#;

/// Exactly one `determinism` finding: unordered float reduction.
pub const DET_UNORDERED_SUM: &str = r#"
pub fn total(m: &std::collections::BTreeMap<u32, f32>) -> f32 {
    m.values().copied().sum()
}
"#;

/// Exactly one `hot-alloc` finding when analyzed as
/// `src/native/trainer.rs` (whose declared steady-state fn is `step`).
pub const ALLOC_IN_STEP: &str = r#"
pub fn step(out: &mut [f32]) {
    let tmp = vec![0.0f32; out.len()];
    out.copy_from_slice(&tmp);
}

pub fn evaluate(out: &mut [f32]) {
    let tmp = vec![1.0f32; out.len()];
    out.copy_from_slice(&tmp);
}
"#;

/// Zero findings, one counted `alloc` waiver: the same allocation with a
/// well-formed allow comment.
pub const ALLOC_ALLOWED: &str = r#"
pub fn step(out: &mut [f32]) {
    // analyze: allow(alloc, fixture waiver exercising the grammar)
    let tmp = vec![0.0f32; out.len()];
    out.copy_from_slice(&tmp);
}
"#;

/// Exactly one `allow-grammar` finding: waiver missing its reason.
pub const ALLOW_MALFORMED: &str = r#"
pub fn step(out: &mut [f32]) {
    // analyze: allow(alloc)
    let tmp = vec![0.0f32; out.len()];
    out.copy_from_slice(&tmp);
}
"#;
