//! The four lint passes of `uavjp-analyze` (DESIGN.md §7.8).
//!
//! Each pass walks the sanitized lines of one file (see
//! [`crate::analyze::scan`]) and emits [`Finding`]s. Pass applicability
//! is path-driven: the constants below declare which files are
//! deterministic compute modules, which may contain `unsafe`, and which
//! functions are steady-state hot paths. The RNG pass checks call sites
//! against the *live* [`crate::rng::streams::REGISTRY`] — the analyzer
//! and the production constructors read the same table, so they cannot
//! drift apart.

use crate::rng::streams::{SeedMix, REGISTRY};

use super::scan::{
    self, extract_call, fn_regions, has_allow, split_top, test_regions, word_in,
    Lines,
};
use super::{Finding, Pass};

/// Files allowed to contain `unsafe` at all (each use still needs a
/// `// SAFETY:` justification). Everything else must stay safe Rust —
/// DESIGN.md §7.3 confines SIMD intrinsics to the kernel files, and the
/// allocation-discipline harness needs its counting global allocator.
const UNSAFE_ALLOWLIST: &[&str] = &[
    "src/tensor/kernels/gemm.rs",
    "src/tensor/kernels/vec.rs",
    "src/tensor/kernels/lane.rs",
    "src/lib.rs",
    "tests/alloc_discipline.rs",
];

/// Module prefixes whose non-test code must stay bitwise deterministic
/// (replay and replica-count-invariance contracts, §7.4–§7.7). Serve
/// timing and the CLI are deliberately outside this list.
const DET_MODULES: &[&str] = &[
    "src/tensor/",
    "src/native/",
    "src/sketch/",
    "src/replicate/",
    "src/data/",
    "src/rng/",
    "src/faults/",
    "src/pool/",
];

/// Tokens banned in deterministic modules: unordered iteration
/// (`HashMap`/`HashSet`) and wall-clock reads.
const DET_BANNED: &[&str] = &["HashMap", "HashSet", "Instant", "SystemTime"];

/// Files that are hot path in their entirety (every non-test line).
const HOT_FILES: &[&str] = &[
    "src/tensor/kernels/gemm.rs",
    "src/tensor/kernels/vec.rs",
    "src/tensor/kernels/lane.rs",
];

/// Declared steady-state functions per file: their bodies may not touch
/// the heap (§7.2) — `tests/alloc_discipline.rs` verifies the same
/// contract at runtime with a counting global allocator.
const HOT_FNS: &[(&str, &[&str])] = &[
    ("src/native/trainer.rs", &["step"]),
    (
        "src/native/sequential.rs",
        &["forward", "forward_train", "backward", "apply_grads", "retarget_batch"],
    ),
    ("src/replicate/mod.rs", &["step", "step_faulted", "reduce_into", "accumulate_stats"]),
    ("src/serve/engine.rs", &["infer_batch", "infer_staged", "infer_one"]),
    ("src/native/loss.rs", &["loss_and_grad_into", "loss_and_grad_scaled_into"]),
    ("src/tensor/mod.rs", &["gemm_into", "sparse_dx_into", "sparse_dw_into"]),
];

/// Allocation/owning-conversion tokens denied on hot paths.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec!",
    "with_capacity",
    "to_vec",
    ".clone(",
    ".push(",
    "Box::new",
    "format!",
    "to_string",
    "String::new",
    ".collect(",
    "to_owned",
];

/// The allow-comment kinds the grammar accepts.
pub const ALLOW_KINDS: &[&str] = &["rng", "unsafe", "nondet", "alloc"];

fn path_matches(relpath: &str, entry: &str) -> bool {
    relpath == entry || relpath.ends_with(entry)
}

/// Seed-mix + stream id parsed out of a raw `Pcg64::new(seed, stream)`
/// call site's argument text. `None` components mean unparseable.
fn parse_rng_args(args: &str) -> (Option<SeedMix>, Option<u64>) {
    let mut parts = split_top(args);
    if parts.len() > 1 && parts.last().map(|p| p.trim().is_empty()).unwrap_or(false) {
        parts.pop(); // trailing comma in a multi-line call
    }
    if parts.len() != 2 {
        return (None, None);
    }
    let seed = parts[0].trim();
    let stream = parts[1].trim();
    let mix = if let Some(p) = seed.rfind('^') {
        parse_num(seed[p + 1..].trim()).map(SeedMix::Xor)
    } else if let Some(c) = wrapping_add_const(seed) {
        Some(SeedMix::Add(c))
    } else if let Some(c) = parse_num(seed) {
        Some(SeedMix::Fixed(c))
    } else if !seed.is_empty()
        && seed.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '.')
    {
        Some(SeedMix::Raw)
    } else {
        None
    };
    let sid = parse_num(stream).or_else(|| leading_num_before_plus(stream));
    (mix, sid)
}

/// `0x…` (underscores allowed) or decimal literal.
fn parse_num(s: &str) -> Option<u64> {
    if let Some(hex) = s.strip_prefix("0x") {
        let clean: String = hex.chars().filter(|&c| c != '_').collect();
        if clean.is_empty() || !hex.chars().all(|c| c.is_ascii_hexdigit() || c == '_') {
            return None;
        }
        u64::from_str_radix(&clean, 16).ok()
    } else if !s.is_empty() && s.chars().all(|c| c.is_ascii_digit()) {
        s.parse().ok()
    } else {
        None
    }
}

/// `<expr>.wrapping_add(<decimal>)` suffix form.
fn wrapping_add_const(seed: &str) -> Option<u64> {
    let inner = seed.strip_suffix(')')?;
    let p = inner.rfind(".wrapping_add(")?;
    let digits = &inner[p + ".wrapping_add(".len()..];
    if digits.is_empty() || !digits.chars().all(|c| c.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// `<decimal> + <expr>` base form (`100 + cls as u64`).
fn leading_num_before_plus(stream: &str) -> Option<u64> {
    let end = stream.find(|c: char| !c.is_ascii_digit())?;
    if end == 0 {
        return None;
    }
    if stream[end..].trim_start().starts_with('+') {
        stream[..end].parse().ok()
    } else {
        None
    }
}

/// Name of the registry entry a parsed (mix, stream) pair falls into.
fn registry_match(mix: Option<SeedMix>, sid: Option<u64>) -> Option<&'static str> {
    let (mix, sid) = (mix?, sid?);
    REGISTRY
        .iter()
        .find(|s| s.mix == mix && (s.lo..=s.hi).contains(&sid))
        .map(|s| s.name)
}

/// Pass 1 — RNG stream hygiene: every non-test `Pcg64::new` outside
/// `src/rng/` is ad-hoc; declared derivations must route through their
/// `rng::streams` constructor and undeclared ones must be registered.
pub fn rng_pass(relpath: &str, l: &Lines, in_test: &[bool], out: &mut Vec<Finding>) {
    if !relpath.starts_with("src/") || relpath.starts_with("src/rng/") {
        return;
    }
    let needle = ["Pcg64", "::new"].concat(); // not a literal: the analyzer scans itself
    for (i, ln) in l.code.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        let Some(p) = ln.find(&needle) else { continue };
        let bytes = ln.as_bytes();
        if p > 0 && (bytes[p - 1].is_ascii_alphanumeric() || bytes[p - 1] == b'_') {
            continue;
        }
        let after = &ln[p + needle.len()..];
        let ws = after.len() - after.trim_start().len();
        if !after.trim_start().starts_with('(') {
            continue;
        }
        if has_allow("rng", &l.code, &l.comment, i) {
            continue;
        }
        let col = ln[..p + needle.len() + ws].chars().count();
        let args = extract_call(&l.code, i, col).unwrap_or_default();
        let (mix, sid) = parse_rng_args(&args);
        let msg = match registry_match(mix, sid) {
            Some(hit) => format!(
                "ad-hoc derivation of declared stream `{hit}` — route through rng::streams"
            ),
            None => "undeclared RNG stream derivation — declare it in rng::streams \
                     and route through its constructor"
                .to_string(),
        };
        out.push(Finding::new(Pass::RngStream, relpath, i + 1, msg));
    }
}

/// Pass 2 — unsafe discipline: `unsafe` only in allowlisted files, and
/// every use carries a `// SAFETY:` justification (tests included —
/// intrinsics are intrinsics wherever they run).
pub fn unsafe_pass(relpath: &str, l: &Lines, out: &mut Vec<Finding>) {
    let kw = ["un", "safe"].concat(); // not a literal: the analyzer scans itself
    let allowed = UNSAFE_ALLOWLIST.iter().any(|a| path_matches(relpath, a));
    for (i, ln) in l.code.iter().enumerate() {
        if !word_in(&kw, ln) {
            continue;
        }
        if has_allow("unsafe", &l.code, &l.comment, i) {
            continue;
        }
        if !allowed {
            out.push(Finding::new(
                Pass::Unsafe,
                relpath,
                i + 1,
                format!("`{kw}` outside the kernel-file allowlist"),
            ));
            continue;
        }
        // need a SAFETY: comment on the line or within 6 lines above
        // (attribute lines don't break the chain)
        let mut ok = false;
        for j in (i.saturating_sub(6)..=i).rev() {
            if l.comment[j].contains("SAFETY:") || l.comment[j].contains("# Safety") {
                ok = true;
                break;
            }
            if j < i {
                let t = l.code[j].trim();
                if !t.is_empty() && !t.starts_with("#[") {
                    break;
                }
            }
        }
        if !ok {
            out.push(Finding::new(
                Pass::Unsafe,
                relpath,
                i + 1,
                format!("`{kw}` without a `// SAFETY:` justification"),
            ));
        }
    }
}

/// Pass 3 — determinism: no unordered containers, wall-clock reads or
/// order-sensitive parallel reductions in the deterministic modules.
pub fn det_pass(relpath: &str, l: &Lines, in_test: &[bool], out: &mut Vec<Finding>) {
    if !relpath.starts_with("src/") || !DET_MODULES.iter().any(|m| relpath.starts_with(m)) {
        return;
    }
    for (i, ln) in l.code.iter().enumerate() {
        if in_test[i] || has_allow("nondet", &l.code, &l.comment, i) {
            continue;
        }
        if let Some(tok) = DET_BANNED.iter().find(|t| word_in(t, ln)) {
            out.push(Finding::new(
                Pass::Determinism,
                relpath,
                i + 1,
                format!("`{tok}` in a deterministic compute module"),
            ));
            continue;
        }
        if unordered_reduction(ln) || word_in("par_iter", ln) {
            out.push(Finding::new(
                Pass::Determinism,
                relpath,
                i + 1,
                "unordered reduction in a deterministic compute module".to_string(),
            ));
        }
    }
}

/// `.values()`/`.keys()` feeding `.sum()`/`.fold()`/`.product()` with
/// only simple chain characters between — iteration order leaks into an
/// order-sensitive float reduction.
fn unordered_reduction(ln: &str) -> bool {
    for src in [".values()", ".keys()"] {
        let mut start = 0usize;
        while let Some(p) = ln[start..].find(src) {
            let rest = &ln[start + p + src.len()..];
            let chain_end = rest
                .find(|c: char| {
                    !(c.is_alphanumeric()
                        || c == '_'
                        || c.is_whitespace()
                        || c == '('
                        || c == ')'
                        || c == '.')
                })
                .unwrap_or(rest.len());
            let chain = &rest[..chain_end];
            for sink in ["sum", "fold", "product"] {
                let mut s2 = 0usize;
                while let Some(q) = chain[s2..].find(sink) {
                    let q = s2 + q;
                    let pre = chain[..q].trim_end();
                    if pre.ends_with('.') {
                        let post = &chain[q + sink.len()..];
                        let post_ok = post
                            .chars()
                            .next()
                            .map(|c| !(c.is_alphanumeric() || c == '_'))
                            .unwrap_or(true);
                        if post_ok {
                            return true;
                        }
                    }
                    s2 = q + sink.len();
                }
            }
            start += p + src.len();
        }
    }
    false
}

/// Pass 4 — hot-path allocations: the declared steady-state functions
/// (and the kernel files wholesale) may not allocate; justified
/// exceptions carry an `analyze:`-prefixed `allow(alloc, reason)`
/// waiver and are counted.
pub fn alloc_pass(relpath: &str, l: &Lines, in_test: &[bool], out: &mut Vec<Finding>) {
    let hot: Vec<bool> = if HOT_FILES.iter().any(|h| path_matches(relpath, h)) {
        in_test.iter().map(|t| !t).collect()
    } else if let Some((_, names)) =
        HOT_FNS.iter().find(|(f, _)| path_matches(relpath, f))
    {
        let mut hot = fn_regions(&l.code, names);
        for (h, t) in hot.iter_mut().zip(in_test) {
            if *t {
                *h = false;
            }
        }
        hot
    } else {
        return;
    };
    for (i, ln) in l.code.iter().enumerate() {
        if !hot[i] {
            continue;
        }
        if let Some(tok) = ALLOC_TOKENS.iter().find(|t| ln.contains(*t)) {
            if !has_allow("alloc", &l.code, &l.comment, i) {
                out.push(Finding::new(
                    Pass::HotAlloc,
                    relpath,
                    i + 1,
                    format!("`{tok}` in a steady-state function"),
                ));
            }
        }
    }
}

/// Allow-comment audit: counts well-formed waivers per kind and flags
/// malformed attempts (wrong kind, missing reason) as findings — a
/// waiver that silently fails to parse would otherwise *look* like
/// suppression while suppressing nothing.
pub fn allow_audit(
    relpath: &str,
    l: &Lines,
    counts: &mut std::collections::BTreeMap<&'static str, usize>,
    out: &mut Vec<Finding>,
) {
    for (i, com) in l.comment.iter().enumerate() {
        if !scan::allow_intent(com) {
            continue;
        }
        match scan::allow_in(com) {
            Some(kind) => {
                if let Some(k) = ALLOW_KINDS.iter().find(|k| **k == kind) {
                    *counts.entry(*k).or_insert(0) += 1;
                } else {
                    out.push(Finding::new(
                        Pass::AllowGrammar,
                        relpath,
                        i + 1,
                        format!("unknown allow kind `{kind}` — expected one of {ALLOW_KINDS:?}"),
                    ));
                }
            }
            None => out.push(Finding::new(
                Pass::AllowGrammar,
                relpath,
                i + 1,
                "malformed allow comment — grammar is `analyze: allow(<kind>, <reason>)`"
                    .to_string(),
            )),
        }
    }
}

/// Run every pass over one file's source text.
pub fn analyze_file(
    relpath: &str,
    text: &str,
    counts: &mut std::collections::BTreeMap<&'static str, usize>,
) -> Vec<Finding> {
    let l = scan::sanitize(text);
    let mut in_test = test_regions(&l.code);
    if relpath.starts_with("tests/") {
        in_test = vec![true; l.code.len()];
    }
    let mut out = Vec::new();
    rng_pass(relpath, &l, &in_test, &mut out);
    unsafe_pass(relpath, &l, &mut out);
    det_pass(relpath, &l, &in_test, &mut out);
    alloc_pass(relpath, &l, &in_test, &mut out);
    allow_audit(relpath, &l, counts, &mut out);
    out
}
