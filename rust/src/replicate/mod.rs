//! Data-parallel training with sketch-compressed gradient exchange
//! (DESIGN.md §7.6).
//!
//! A [`ReplicaGroup`] owns N model replicas and runs each optimizer step
//! as: broadcast the master parameters, shard the global batch, run
//! forward/backward per shard concurrently ([`crate::pool::run_replicas`]
//! — one OS thread per replica, each still row-chunking its GEMMs on the
//! intra-op pool), and reduce the per-shard gradients through the flat
//! slot registry into the trainer's master gradient slots.
//!
//! **The determinism contract** — bit-identical trajectories at any
//! `--replicas` for a fixed seed — is carried by a *fixed lane grid*: the
//! global batch is always cut into [`LANES`] micro-shards ("lanes"),
//! independent of the replica count. Each lane owns a persistent
//! workspace and two persistent RNG streams derived disjointly from the
//! seed (`1100 + lane` for backward gates, `1300 + lane` for activation
//! gates), and the reduction is a flat fold over lanes in ascending lane
//! index — the same accumulation tree no matter how lanes are packed onto
//! replicas. `--replicas R` only chooses how many OS threads *execute*
//! the lanes (replica r runs lanes `r·8/R .. (r+1)·8/R` serially), so R
//! must divide [`LANES`]. This is the replica-axis analogue of the
//! `--threads` invariance the GEMM row-chunking guarantees, and
//! `tests/replicate.rs` pins it the same way `tests/gemm_kernels.rs` pins
//! thread-invariance.
//!
//! **Exchange modes.** `dense` folds full slots. `sparse` exploits the
//! paper's estimator structure: a gated GEMM's dW/db are *exactly zero*
//! outside the kept columns (the 1/pᵢ-rescaled kept-column gradients are
//! already an unbiased compressed representation), so the reducer
//! union-merges the lanes' kept-column indices — replayed from the
//! [`crate::sketch::SketchScratch`] kept log, attributed to slots via
//! [`Layer::sketch_gemm_slots`] — and scatter-accumulates only those rows
//! into the dense master slot. Both modes use the same ascending-lane
//! per-element fold, so they produce the same trajectories (up to signed
//! zeros, which no downstream op distinguishes), and both are R-invariant.
//! [`ExchangeStats`] models what each mode would put on a wire.

use crate::config::TrainConfig;
use crate::data::DatasetKind;
use crate::pool;
use crate::rng::{streams, Pcg64};
use crate::tensor::kernels::vec;
use crate::tensor::Mat;
use anyhow::{bail, Result};

use crate::native::layer::Grads;
use crate::native::loss::{loss_and_grad_scaled_into, LossKind};
use crate::native::models;
use crate::native::policy::{ActivationPolicy, StepPlan};
use crate::native::sequential::{Sequential, SketchPolicy, Workspace};

/// Number of fixed micro-shards ("lanes") every global batch is cut into,
/// independent of `--replicas`. The reduction tree folds lanes in
/// ascending index, so any replica count that divides this executes the
/// identical computation.
pub const LANES: usize = 8;

/// How per-lane gradients are merged into the master slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceMode {
    /// Baseline: fold every slot densely (full tensors on the wire).
    Dense,
    /// Union-merge kept-column indices of gated GEMMs with their
    /// 1/pᵢ-rescaled values; scatter-accumulate only those rows. Ungated
    /// slots still fold densely.
    Sparse,
}

impl ReduceMode {
    /// Parse `"dense"` / `"sparse"`.
    pub fn parse(s: &str) -> Result<ReduceMode> {
        match s {
            "dense" => Ok(ReduceMode::Dense),
            "sparse" => Ok(ReduceMode::Sparse),
            other => bail!("unknown reduce mode {other} (want dense|sparse)"),
        }
    }

    /// Canonical config string.
    pub fn as_str(&self) -> &'static str {
        match self {
            ReduceMode::Dense => "dense",
            ReduceMode::Sparse => "sparse",
        }
    }
}

/// Modeled wire traffic of the gradient exchange, accumulated over steps.
/// Both modes are accounted on every step regardless of which one the run
/// reduces with, so one run yields the full comparison. The wire unit is
/// the *lane* payload (the all-reduce participant is a lane; replicas are
/// executors): dense ships each lane's full flat gradient; sparse ships,
/// per gated GEMM, a u32 row count plus `(u32 index, f32 bias entry,
/// d_in × f32 weight row)` per kept row, and full tensors for ungated
/// slots. Lane-framed payloads keep the numbers replica-count-invariant —
/// like the trajectories themselves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExchangeStats {
    /// Steps accumulated.
    pub steps: u64,
    /// Total bytes the dense exchange would move.
    pub dense_bytes: u64,
    /// Total bytes the sparse exchange would move.
    pub sparse_bytes: u64,
    /// Lanes excluded from the reduce (injected dropout ∪ worker
    /// panics), summed over steps. Injected drops come from the
    /// trainer's fault stream, so they are replica-count-invariant like
    /// everything else; panic drops are executor events and are not.
    pub lanes_dropped: u64,
    /// Steps on which at least one lane was excluded.
    pub steps_degraded: u64,
}

impl ExchangeStats {
    /// Dense bytes per step.
    pub fn dense_per_step(&self) -> f64 {
        self.dense_bytes as f64 / self.steps.max(1) as f64
    }

    /// Sparse bytes per step.
    pub fn sparse_per_step(&self) -> f64 {
        self.sparse_bytes as f64 / self.steps.max(1) as f64
    }

    /// sparse / dense byte ratio (1.0 when nothing was accumulated).
    pub fn ratio(&self) -> f64 {
        if self.dense_bytes == 0 {
            1.0
        } else {
            self.sparse_bytes as f64 / self.dense_bytes as f64
        }
    }
}

/// One gated GEMM's reduction metadata: where its weight/bias gradients
/// live in the global slot registry and the weight row width. Entry k
/// corresponds to the k-th kept list in every lane's per-step kept log
/// (backward layer order, each layer's `sketch_gemm_slots` order within).
struct GemmSite {
    w_slot: usize,
    b_slot: usize,
    din: usize,
}

/// Per-lane persistent state: the lane's workspace, its staged batch
/// shard, its two disjoint RNG streams, and the last step's loss partial.
struct LaneState {
    ws: Workspace,
    stage_x: Mat,
    stage_y: Vec<i32>,
    sk_rng: Pcg64,
    act_rng: Pcg64,
    loss_partial: f64,
}

/// One executor: an owned model copy (refreshed from the master every
/// step) plus the contiguous run of lanes it executes serially. `token`
/// records the last step this worker *completed* — a worker whose token
/// lags the group's after a step panicked mid-flight, and its lanes are
/// excluded from the reduce (DESIGN.md §7.7).
struct ReplicaWorker {
    model: Sequential,
    lanes: Vec<LaneState>,
    token: u64,
}

/// Faults injected into one data-parallel step (DESIGN.md §7.7). The
/// trainer derives this per step from its [`crate::faults::FaultPlan`];
/// [`StepFaults::default`] is the fault-free step [`ReplicaGroup::step`]
/// runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepFaults {
    /// Lanes whose gradient contribution is dropped on the wire (the
    /// forward still runs, so the reported loss stays exact).
    pub drops: [bool; LANES],
    /// Inverse-inclusion-probability rescale `1/(1-p)` applied to the
    /// surviving lanes' reduced gradient — on **every** step while lane
    /// dropout is armed, which is what makes the estimator unbiased:
    /// E[Σ_{survivors} g_l / (1-p)] = Σ_l g_l.
    pub gain: f32,
    /// Replica whose worker closure panics this step (exercises the
    /// `catch_unwind` isolation end to end).
    pub panic_replica: Option<usize>,
}

impl Default for StepFaults {
    fn default() -> Self {
        StepFaults { drops: [false; LANES], gain: 1.0, panic_replica: None }
    }
}

/// N-replica data-parallel step engine. See the module docs for the lane
/// grid, the determinism contract and the exchange modes.
pub struct ReplicaGroup {
    replicas: usize,
    lanes_per_replica: usize,
    reduce: ReduceMode,
    stale: bool,
    loss_kind: LossKind,
    batch: usize,
    lane_rows: usize,
    out_cols: usize,
    plan: StepPlan,
    workers: Vec<ReplicaWorker>,
    gemm_map: Vec<GemmSite>,
    slot_lens: Vec<usize>,
    /// Bytes of the slots sparse mode still ships densely.
    dense_extra_bytes: u64,
    /// Bytes of one lane's full flat gradient.
    lane_dense_bytes: u64,
    /// `--stale 1`: last step's reduced gradients (applied this step) and
    /// a spare buffer the current reduction lands in.
    prev: Grads,
    spare: Grads,
    stats: ExchangeStats,
    /// Monotonic step token workers stamp on completion (panic detection).
    step_token: u64,
}

impl ReplicaGroup {
    /// Validate the data-parallel knobs of `cfg` and build the group.
    /// `master` is the trainer's model — replicas are rebuilt from the
    /// registry (same architecture; parameters are re-broadcast from the
    /// master every step, so initial values are irrelevant).
    pub fn new(cfg: &TrainConfig, master: &Sequential) -> Result<ReplicaGroup> {
        let r = cfg.replicas;
        if r == 0 || LANES % r != 0 {
            bail!(
                "--replicas {r} must be a divisor of the {LANES}-lane grid \
                 (1|2|4|8); the fixed grid is what keeps trajectories \
                 bit-identical at every replica count"
            );
        }
        if cfg.batch % LANES != 0 {
            bail!(
                "--replicas needs --batch divisible by the {LANES}-lane \
                 grid, got batch {}",
                cfg.batch
            );
        }
        if cfg.stale > 1 {
            bail!("--stale {} out of range (want 0|1)", cfg.stale);
        }
        let reduce = ReduceMode::parse(&cfg.reduce)?;
        let loss_kind = LossKind::parse(&cfg.loss)?;
        let in_dim = DatasetKind::for_model(&cfg.model)?.dim();
        let plan = master.plan(
            &SketchPolicy::from_config(cfg),
            &ActivationPolicy::from_config(cfg)?,
        )?;
        let lane_rows = cfg.batch / LANES;
        let lanes_per_replica = LANES / r;

        // Flat slot registry metadata from the master stack.
        let slot_lens: Vec<usize> = master
            .layers
            .iter()
            .flat_map(|l| l.params().iter().map(|p| p.len()).collect::<Vec<_>>())
            .collect();
        let mut slot_offsets = Vec::with_capacity(master.layers.len() + 1);
        slot_offsets.push(0usize);
        for layer in &master.layers {
            slot_offsets.push(slot_offsets.last().unwrap() + layer.params().len());
        }
        // Gated-GEMM map in kept-log order: the backward walks layers in
        // reverse, and each gated layer plans once per entry of its
        // `sketch_gemm_slots` (in that order).
        let mut gemm_map = Vec::new();
        for i in (0..master.layers.len()).rev() {
            if plan.sketch[i].is_none() {
                continue;
            }
            for (wl, bl) in master.layers[i].sketch_gemm_slots() {
                let w_slot = slot_offsets[i] + wl;
                let b_slot = slot_offsets[i] + bl;
                gemm_map.push(GemmSite {
                    w_slot,
                    b_slot,
                    din: slot_lens[w_slot] / slot_lens[b_slot],
                });
            }
        }
        let mut is_gemm = vec![false; slot_lens.len()];
        for s in &gemm_map {
            is_gemm[s.w_slot] = true;
            is_gemm[s.b_slot] = true;
        }
        let dense_extra_bytes: u64 = slot_lens
            .iter()
            .zip(&is_gemm)
            .filter(|(_, &g)| !g)
            .map(|(&l, _)| (l * 4) as u64)
            .sum();
        let lane_dense_bytes: u64 =
            slot_lens.iter().map(|&l| (l * 4) as u64).sum();

        let mut out_cols = in_dim;
        for layer in &master.layers {
            out_cols = layer.out_dim(out_cols);
        }

        let mut workers = Vec::with_capacity(r);
        for rep in 0..r {
            let model = models::build(&cfg.model, cfg.seed)?;
            let rep_lens: Vec<usize> = model
                .layers
                .iter()
                .flat_map(|l| {
                    l.params().iter().map(|p| p.len()).collect::<Vec<_>>()
                })
                .collect();
            if rep_lens != slot_lens {
                bail!(
                    "--replicas needs a registry-built model: the trainer's \
                     stack does not match registry model {}",
                    cfg.model
                );
            }
            let lanes = (0..lanes_per_replica)
                .map(|li| {
                    let lane = rep * lanes_per_replica + li;
                    LaneState {
                        ws: model.workspace(lane_rows, in_dim),
                        stage_x: Mat::zeros(lane_rows, in_dim),
                        stage_y: vec![0i32; lane_rows],
                        sk_rng: streams::lane_sketch_gates(cfg.seed, lane as u64),
                        act_rng: streams::lane_act_gates(cfg.seed, lane as u64),
                        loss_partial: 0.0,
                    }
                })
                .collect();
            workers.push(ReplicaWorker { model, lanes, token: 0 });
        }

        let zero_grads = || Grads {
            slots: slot_lens.iter().map(|&l| vec![0.0f32; l]).collect(),
        };
        Ok(ReplicaGroup {
            replicas: r,
            lanes_per_replica,
            reduce,
            stale: cfg.stale == 1,
            loss_kind,
            batch: cfg.batch,
            lane_rows,
            out_cols,
            plan,
            workers,
            gemm_map,
            slot_lens,
            dense_extra_bytes,
            lane_dense_bytes,
            prev: zero_grads(),
            spare: zero_grads(),
            stats: ExchangeStats::default(),
            step_token: 0,
        })
    }

    /// Replica count.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Active exchange mode.
    pub fn reduce_mode(&self) -> ReduceMode {
        self.reduce
    }

    /// Accumulated wire-traffic model.
    pub fn stats(&self) -> ExchangeStats {
        self.stats
    }

    /// Raw PCG64 words of every lane's (backward-gate, activation-gate)
    /// streams, ascending lane index — what the resumable checkpoint
    /// persists. Lane-framed, so a run resumed at a different
    /// `--replicas` continues bit-identically.
    pub fn lane_stream_words(&self) -> Vec<[[u64; 4]; 2]> {
        self.workers
            .iter()
            .flat_map(|w| w.lanes.iter())
            .map(|l| [l.sk_rng.state_words(), l.act_rng.state_words()])
            .collect()
    }

    /// Restore every lane's streams from [`ReplicaGroup::lane_stream_words`]
    /// output (one entry per lane of the fixed grid).
    pub fn restore_lane_streams(&mut self, lanes: &[[[u64; 4]; 2]]) -> Result<()> {
        if lanes.len() != LANES {
            bail!(
                "checkpoint stores {} lane streams, the grid has {LANES}",
                lanes.len()
            );
        }
        for (lane, words) in
            self.workers.iter_mut().flat_map(|w| w.lanes.iter_mut()).zip(lanes)
        {
            lane.sk_rng = Pcg64::from_state_words(words[0]);
            lane.act_rng = Pcg64::from_state_words(words[1]);
        }
        Ok(())
    }

    /// One data-parallel step: broadcast `master`'s parameters, run every
    /// lane's forward/backward (replicas in parallel, each lane on its
    /// own RNG streams), and reduce the per-lane gradients into `out`
    /// (the trainer's master gradient slots). Returns the global-batch
    /// mean training loss. Under `--stale 1`, `out` receives the
    /// *previous* step's reduced gradients (zeros on the first step)
    /// while this step's reduction is held back one step; the returned
    /// loss is always the current step's.
    pub fn step(
        &mut self,
        master: &Sequential,
        x: &Mat,
        y: &[i32],
        out: &mut Grads,
    ) -> f64 {
        self.step_faulted(master, x, y, out, &StepFaults::default())
            .expect("a fault-free step cannot fail")
    }

    /// [`ReplicaGroup::step`] with injected faults: lanes in
    /// `faults.drops` are excluded from the reduce and the survivors
    /// rescaled by `faults.gain`; `faults.panic_replica`'s closure
    /// panics, is caught at the worker boundary
    /// ([`crate::pool::try_run_replicas`]), and its lanes join the drop
    /// set with a mean-preserving `LANES/survivors` rescale of gradient
    /// and loss. Errors only when every replica panicked (the typed
    /// [`crate::pool::WorkerPanicked`] message surfaces in the chain).
    pub fn step_faulted(
        &mut self,
        master: &Sequential,
        x: &Mat,
        y: &[i32],
        out: &mut Grads,
        faults: &StepFaults,
    ) -> Result<f64> {
        assert_eq!(
            (x.rows, x.cols),
            (self.batch, self.workers[0].lanes[0].ws.in_dim),
            "global batch shape"
        );
        assert_eq!(y.len(), self.batch, "label batch size");
        // analyze: allow(alloc, per-step slot pointer table is O(layers) not O(params); master borrow is per-call)
        let master_slots: Vec<&[f32]> =
            master.layers.iter().flat_map(|l| l.params()).collect();
        assert_eq!(master_slots.len(), self.slot_lens.len(), "master slots");
        let (dim, lane_rows, lanes_per, batch) =
            (x.cols, self.lane_rows, self.lanes_per_replica, self.batch);
        let (plan, loss_kind) = (&self.plan, self.loss_kind);
        self.step_token += 1;
        let token = self.step_token;
        let run = pool::try_run_replicas(&mut self.workers, |rep, w| {
            if faults.panic_replica == Some(rep) {
                panic!("injected worker panic (replica {rep})");
            }
            // broadcast: replica models mirror the master bit-for-bit
            let mut s = 0usize;
            for layer in &mut w.model.layers {
                layer.visit_params_mut(&mut |p| {
                    p.copy_from_slice(master_slots[s]);
                    s += 1;
                });
            }
            for (li, lane) in w.lanes.iter_mut().enumerate() {
                let r0 = (rep * lanes_per + li) * lane_rows;
                lane.stage_x
                    .data
                    .copy_from_slice(&x.data[r0 * dim..(r0 + lane_rows) * dim]);
                lane.stage_y.copy_from_slice(&y[r0..r0 + lane_rows]);
                w.model.forward_train(
                    &lane.stage_x,
                    &mut lane.ws,
                    plan,
                    &mut lane.act_rng,
                );
                let (logits, gout) = lane.ws.loss_io();
                lane.loss_partial = loss_and_grad_scaled_into(
                    loss_kind,
                    logits,
                    &lane.stage_y,
                    gout,
                    batch,
                );
                // arm the kept log around the backward only — the kept
                // activation policy also plans columns during the forward
                lane.ws.scratch.begin_kept_log();
                w.model.backward(&mut lane.ws, plan, &mut lane.sk_rng);
                lane.ws.scratch.end_kept_log();
            }
            w.token = token;
        });

        // degraded mode: a panicking replica's lanes hold stale data —
        // fold them out of gradient *and* loss, rescaled mean-preserving
        // over the surviving lanes. `token` catches every victim even if
        // several replicas die at once.
        let mut drops = faults.drops;
        let mut panicked = [false; LANES];
        let mut n_panic_lanes = 0usize;
        if let Err(ref e) = run {
            for (rep, w) in self.workers.iter().enumerate() {
                if w.token != token {
                    for li in 0..lanes_per {
                        panicked[rep * lanes_per + li] = true;
                        n_panic_lanes += 1;
                    }
                }
            }
            if n_panic_lanes == LANES {
                bail!("every replica panicked, no surviving lanes: {e}");
            }
            for (d, &p) in drops.iter_mut().zip(&panicked) {
                *d |= p;
            }
        }
        let panic_gain = LANES as f64 / (LANES - n_panic_lanes) as f64;
        let scale = faults.gain * panic_gain as f32;

        self.accumulate_stats(&drops);
        if self.stale {
            // analyze: allow(alloc, Vec::new is capacity-0 and never touches the heap)
            let mut cur =
                std::mem::replace(&mut self.spare, Grads { slots: Vec::new() });
            self.reduce_into(&mut cur, &drops, scale);
            for (o, p) in out.slots.iter_mut().zip(&self.prev.slots) {
                o.copy_from_slice(p);
            }
            self.spare = std::mem::replace(&mut self.prev, cur);
        } else {
            self.reduce_into(out, &drops, scale);
        }

        // global-batch mean loss: unnormalized lane partials folded in
        // ascending lane order, divided by the global count — replica-
        // count-invariant like the gradients. Injected drops only cut
        // the gradient wire (their forward ran), so only panicked lanes
        // leave the loss.
        let mut sum = 0.0f64;
        for (lane_ix, lane) in
            self.workers.iter().flat_map(|w| w.lanes.iter()).enumerate()
        {
            if !panicked[lane_ix] {
                sum += lane.loss_partial;
            }
        }
        sum *= panic_gain;
        Ok(match self.loss_kind {
            LossKind::CrossEntropy => sum / self.batch as f64,
            LossKind::Mse => sum / (self.batch * self.out_cols) as f64,
        })
    }

    /// Flat ascending-lane fold of every lane's gradient slots into
    /// `out`, skipping dropped lanes and rescaling the survivors by
    /// `scale` (1.0 on the fault-free path, which then touches no value
    /// — bit-identity preserved). Dense mode folds full slots; sparse
    /// mode scatter-accumulates only the kept rows of gated GEMMs
    /// (everything else in those slots is exactly zero) and folds
    /// ungated slots densely. Both accumulate each element in the
    /// identical ascending-lane order, for any replica count.
    fn reduce_into(&self, out: &mut Grads, drops: &[bool; LANES], scale: f32) {
        assert_eq!(out.slots.len(), self.slot_lens.len(), "slot registry");
        // analyze: allow(alloc, fixed 8-entry lane pointer table per step)
        let lanes: Vec<&LaneState> =
            self.workers.iter().flat_map(|w| w.lanes.iter()).collect();
        // analyze: allow(alloc, at most 8 surviving-lane pointers per step)
        let survivors: Vec<&LaneState> = lanes
            .iter()
            .zip(drops)
            .filter(|(_, &d)| !d)
            .map(|(l, _)| *l)
            .collect();
        let sparse_slot = |s: usize| {
            self.reduce == ReduceMode::Sparse
                && self.gemm_map.iter().any(|g| g.w_slot == s || g.b_slot == s)
        };
        for (s, dst) in out.slots.iter_mut().enumerate() {
            if sparse_slot(s) || survivors.is_empty() {
                dst.fill(0.0);
            } else {
                dst.copy_from_slice(&survivors[0].ws.grad_slots.slots[s]);
                for lane in &survivors[1..] {
                    vec::add_assign(dst, &lane.ws.grad_slots.slots[s]);
                }
            }
        }
        if self.reduce == ReduceMode::Sparse {
            for (g_ix, site) in self.gemm_map.iter().enumerate() {
                for lane in &survivors {
                    let log = lane.ws.scratch.kept_log();
                    assert_eq!(
                        log.len(),
                        self.gemm_map.len(),
                        "kept log entries per lane"
                    );
                    let lw = &lane.ws.grad_slots.slots[site.w_slot];
                    let lb = &lane.ws.grad_slots.slots[site.b_slot];
                    // split the two destination slots out of `out`
                    let (lo, hi) = (
                        site.w_slot.min(site.b_slot),
                        site.w_slot.max(site.b_slot),
                    );
                    let (head, tail) = out.slots.split_at_mut(hi);
                    let (a, b) = (&mut head[lo], &mut tail[0]);
                    let (dw, db) = if site.w_slot < site.b_slot {
                        (a, b)
                    } else {
                        (b, a)
                    };
                    for &(j, _) in &log[g_ix] {
                        let d = site.din;
                        vec::add_assign(
                            &mut dw[j * d..(j + 1) * d],
                            &lw[j * d..(j + 1) * d],
                        );
                        db[j] += lb[j];
                    }
                }
            }
        }
        if scale != 1.0 {
            for dst in out.slots.iter_mut() {
                vec::scale(dst, scale);
            }
        }
    }

    /// Accumulate both modes' modeled wire bytes for the step just run
    /// (reads the lanes' kept logs; call before the logs are re-armed).
    /// Dropped lanes ship nothing, and the drop counters feed the train
    /// report's `lanes_dropped`/`steps_degraded`.
    fn accumulate_stats(&mut self, drops: &[bool; LANES]) {
        let n_dropped = drops.iter().filter(|&&d| d).count();
        let mut sparse: u64 = 0;
        for (lane_ix, lane) in
            self.workers.iter().flat_map(|w| w.lanes.iter()).enumerate()
        {
            if drops[lane_ix] {
                continue;
            }
            let log = lane.ws.scratch.kept_log();
            for (g_ix, site) in self.gemm_map.iter().enumerate() {
                let kept = log.get(g_ix).map_or(0, |l| l.len()) as u64;
                // u32 count + per row: u32 index, f32 bias, din f32s
                sparse += 4 + kept * (4 + 4 * (site.din as u64 + 1));
            }
            sparse += self.dense_extra_bytes;
        }
        self.stats.steps += 1;
        self.stats.dense_bytes +=
            (LANES - n_dropped) as u64 * self.lane_dense_bytes;
        self.stats.sparse_bytes += sparse;
        self.stats.lanes_dropped += n_dropped as u64;
        self.stats.steps_degraded += (n_dropped > 0) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;
    use crate::native::models;

    fn dp_cfg(replicas: usize) -> TrainConfig {
        let mut cfg = Preset::Smoke.base("mlp").unwrap();
        cfg.batch = 32;
        cfg.replicas = replicas;
        cfg.method = "l1".into();
        cfg.budget = 0.25;
        cfg
    }

    #[test]
    fn parse_reduce_modes() {
        assert_eq!(ReduceMode::parse("dense").unwrap(), ReduceMode::Dense);
        assert_eq!(ReduceMode::parse("sparse").unwrap(), ReduceMode::Sparse);
        let err = format!("{}", ReduceMode::parse("topk").unwrap_err());
        assert!(err.contains("dense|sparse"), "{err}");
    }

    #[test]
    fn rejects_bad_replica_grid_and_batch() {
        let master = models::build("mlp", 0).unwrap();
        for bad in [3usize, 5, 6, 7, 16] {
            let cfg = dp_cfg(bad);
            let err = format!("{}", ReplicaGroup::new(&cfg, &master).unwrap_err());
            assert!(err.contains("divisor"), "r={bad}: {err}");
        }
        let mut cfg = dp_cfg(2);
        cfg.batch = 36;
        let err = format!("{}", ReplicaGroup::new(&cfg, &master).unwrap_err());
        assert!(err.contains("divisible"), "{err}");
        let mut cfg = dp_cfg(2);
        cfg.stale = 2;
        let err = format!("{}", ReplicaGroup::new(&cfg, &master).unwrap_err());
        assert!(err.contains("0|1"), "{err}");
        let mut cfg = dp_cfg(2);
        cfg.reduce = "topk".into();
        assert!(ReplicaGroup::new(&cfg, &master).is_err());
    }

    #[test]
    fn gemm_map_covers_every_gated_site_in_backward_order() {
        // vit: Patchify, PatchConv, PosEmbed, Attention, LayerNorm,
        // FfnBlock, LayerNorm, PatchMeanPool, Linear — gated GEMMs under
        // location=all: Linear(1) + FfnBlock(2) + Attention(4) +
        // PatchConv(1) = 8 kept-log entries, reverse layer order.
        let master = models::build("vit", 0).unwrap();
        let mut cfg = dp_cfg(2);
        cfg.model = "vit".into();
        let g = ReplicaGroup::new(&cfg, &master).unwrap();
        assert_eq!(g.gemm_map.len(), 8);
        // every mapped slot pair is (dout·din, dout)-shaped
        for site in &g.gemm_map {
            assert_eq!(
                g.slot_lens[site.w_slot],
                site.din * g.slot_lens[site.b_slot]
            );
        }
        // location=none → nothing gated → empty map, sparse == dense
        let mut cfg = dp_cfg(2);
        cfg.model = "vit".into();
        cfg.location = "none".into();
        let g = ReplicaGroup::new(&cfg, &master).unwrap();
        assert!(g.gemm_map.is_empty());
    }
}
