//! Dense matrix substrate + column-sparse GEMMs, as a view-based,
//! destination-passing kernel layer (DESIGN.md §7.2).
//!
//! This is the CPU-native half of the paper's efficiency story: interpret-
//! mode XLA cannot *skip* masked columns, so the wall-clock mechanism behind
//! Eq. (6) (per-iteration cost ρ(V) shrinking with the sketch budget) is
//! demonstrated here with real kernels — a blocked, multi-threaded dense
//! GEMM baseline ([`gemm_into`]) and the two sketched backward products
//! that only touch the kept columns ([`sparse_dx_into`] /
//! [`sparse_dw_into`]). `cargo bench gemm_scaling` measures both.
//!
//! Three API rules hold for every kernel here:
//!
//! 1. **Views in, destinations out.** Kernels read [`MatView`]s and write
//!    caller-provided [`MatViewMut`]s; nothing allocates. Transposition is
//!    a flag on [`gemm_into`], not a materialized copy, and `[B, P·d]` ↔
//!    `[B·P, d]` reinterpretation is [`Mat::reshape`] (row-major buffers
//!    coincide).
//! 2. **No data-dependent branches.** The dense kernels never skip
//!    zero-valued operands, so dense-vs-sketched bench ratios are not
//!    skewed by ReLU-induced zeros in G — the pitfall XConv warns about.
//! 3. **Thread-count invariance.** Multi-threading partitions output rows
//!    ([`crate::pool::run_row_chunks`]); each element's accumulation order
//!    is fixed, so results are bit-identical for every `--threads` value
//!    (and to the pre-view value-returning API — `tests/gemm_kernels.rs`
//!    pins both).
//!
//! Every kernel here additionally dispatches on the process-wide
//! [`kernels::KernelKind`] (`--kernel {auto,scalar,simd}`): the *scalar*
//! kind is the plain-loop code in this file — the bitwise oracle the
//! parity suites pin — and the *simd* kind routes the same contracts
//! through the packed micro-kernel GEMM in [`kernels`] (panel packing +
//! 6×16 register tiles over runtime-detected AVX2/FMA lanes, portable
//! lanes elsewhere). Within a kind, results remain bit-identical across
//! thread counts; across kinds they differ in the last ulps
//! (`tests/simd_kernels.rs` bounds it).

pub mod kernels;

use crate::pool;

/// Row-major f32 matrix (owning).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// Borrowed read-only view of a row-major matrix.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

/// Borrowed mutable view of a row-major matrix (a kernel destination).
#[derive(Debug)]
pub struct MatViewMut<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a mut [f32],
}

impl<'a> MatView<'a> {
    /// View over a raw row-major slice; `data.len()` must be `rows·cols`.
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> MatView<'a> {
        assert_eq!(data.len(), rows * cols, "view size mismatch");
        MatView { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

impl<'a> MatViewMut<'a> {
    /// Mutable view over a raw row-major slice (e.g. a parameter-gradient
    /// slot); `data.len()` must be `rows·cols`.
    pub fn new(rows: usize, cols: usize, data: &'a mut [f32]) -> MatViewMut<'a> {
        assert_eq!(data.len(), rows * cols, "view size mismatch");
        MatViewMut { rows, cols, data }
    }

    /// Read-only alias of this destination.
    pub fn as_view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, data: self.data }
    }

    /// Reborrow as a shorter-lived destination (hand to a kernel while
    /// keeping this view usable afterwards).
    pub fn rb(&mut self) -> MatViewMut<'_> {
        MatViewMut { rows: self.rows, cols: self.cols, data: &mut *self.data }
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// Build from row vectors. `vec![]` yields the empty `0 × 0` matrix;
    /// rows of zero width yield `r × 0` — both round-trip through
    /// [`Mat::transpose`], [`gemm_into`] and [`Mat::frob_sq`].
    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow as a read-only view.
    #[inline]
    pub fn view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, data: &self.data }
    }

    /// Borrow as a kernel destination.
    #[inline]
    pub fn view_mut(&mut self) -> MatViewMut<'_> {
        MatViewMut { rows: self.rows, cols: self.cols, data: &mut self.data }
    }

    /// Zero-copy reinterpretation of the row-major buffer under different
    /// dimensions (`rows·cols` must match) — how `[B, P·d]` batches become
    /// `[B·P, d]` token/patch stacks without touching memory.
    pub fn reshape(&self, rows: usize, cols: usize) -> MatView<'_> {
        MatView::new(rows, cols, &self.data)
    }

    /// Mutable zero-copy reinterpretation (see [`Mat::reshape`]).
    pub fn reshape_mut(&mut self, rows: usize, cols: usize) -> MatViewMut<'_> {
        MatViewMut::new(rows, cols, &mut self.data)
    }

    /// Re-dimension in place, preserving the buffer's capacity: the flow
    /// and scratch arenas are retargeted to each layer's shape every step,
    /// and after the first pass through a stack no call allocates.
    /// Contents are unspecified afterwards (callers fully overwrite —
    /// the same dirty-buffer contract every workspace arena has).
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

/// k-dimension block size for the dense kernels: one block of B rows
/// (`KB × n` floats) stays hot in L2 while a chunk of C rows streams over
/// it. Blocking never reorders any element's accumulation (k blocks are
/// visited in ascending order), so it is invisible to the results.
const GEMM_KB: usize = 64;

/// Below this many multiply-adds a GEMM runs single-threaded. There is no
/// persistent worker pool — the threaded path spawns scoped OS threads per
/// call (tens of µs) — so the cut-off sits where a call's work comfortably
/// amortizes the spawn (~4M MACs ≈ milliseconds single-threaded). Small
/// layers therefore never pay spawn overhead; results are identical either
/// way. Public so benches/tests can tell which cases actually engage the
/// threaded path.
pub const GEMM_PAR_MIN_FLOPS: usize = 1 << 22;

/// General destination-passing GEMM with transpose flags:
/// `C = α·op(A)·op(B) + β·C`, `op(M) = Mᵀ` when the flag is set.
///
/// * `β = 0` overwrites `C` without reading it (safe on dirty buffers);
///   `β = 1` accumulates.
/// * Row-chunk multi-threaded over C's rows ([`crate::pool::threads`]
///   workers); every element accumulates in ascending-k order regardless
///   of blocking or thread count, so results are bit-identical across
///   `--threads` values.
/// * No data-dependent skips: zeros in A/G cost the same as any value,
///   keeping dense-baseline timings honest.
/// * Degenerate shapes (`m`, `n` or `k` = 0) are well-defined: the output
///   is `β·C` (empty when `C` is empty).
pub fn gemm_into(
    alpha: f32,
    a: MatView<'_>,
    ta: bool,
    b: MatView<'_>,
    tb: bool,
    beta: f32,
    c: MatViewMut<'_>,
) {
    let (m, ka) = if ta { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let (kb, n) = if tb { (b.cols, b.rows) } else { (b.rows, b.cols) };
    assert_eq!(ka, kb, "gemm_into inner dimension: {ka} vs {kb}");
    assert_eq!((c.rows, c.cols), (m, n), "gemm_into output shape");
    let kernel = kernels::active();
    if kernel.is_simd() {
        kernels::gemm_packed(kernel, alpha, a, ta, b, tb, beta, c);
        return;
    }
    let k = ka;
    let workers = if m * n * k.max(1) < GEMM_PAR_MIN_FLOPS {
        1
    } else {
        pool::threads()
    };
    pool::run_row_chunks(workers, m, n, c.data, |i0, chunk| {
        // β pass first; the accumulation below then only ever adds.
        if beta == 0.0 {
            chunk.fill(0.0);
        } else if beta != 1.0 {
            for v in chunk.iter_mut() {
                *v *= beta;
            }
        }
        if k == 0 {
            return;
        }
        let rows = chunk.len() / n;
        match (ta, tb) {
            (false, false) => gemm_chunk_nn(alpha, &a, &b, i0, rows, n, k, chunk),
            (false, true) => gemm_chunk_nt(alpha, &a, &b, i0, rows, n, k, chunk),
            (true, false) => gemm_chunk_tn(alpha, &a, &b, i0, rows, n, k, chunk),
            (true, true) => gemm_chunk_tt(alpha, &a, &b, i0, rows, n, k, chunk),
        }
    });
}

/// C += α·A·B over C rows `i0..i0+rows` (ikj, k-blocked: the B block stays
/// in cache while the chunk's rows stream over it).
#[allow(clippy::too_many_arguments)]
fn gemm_chunk_nn(
    alpha: f32,
    a: &MatView<'_>,
    b: &MatView<'_>,
    i0: usize,
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    let mut kb0 = 0;
    while kb0 < k {
        let kb1 = (kb0 + GEMM_KB).min(k);
        for li in 0..rows {
            let arow = a.row(i0 + li);
            let crow = &mut c[li * n..(li + 1) * n];
            for kk in kb0..kb1 {
                let aik = alpha * arow[kk];
                let brow = b.row(kk);
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
        kb0 = kb1;
    }
}

/// C += α·A·Bᵀ: per output element a dot of two row streams, four columns
/// at a time for ILP (each element's own accumulator still runs ascending
/// k).
#[allow(clippy::too_many_arguments)]
fn gemm_chunk_nt(
    alpha: f32,
    a: &MatView<'_>,
    b: &MatView<'_>,
    i0: usize,
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    for li in 0..rows {
        let arow = a.row(i0 + li);
        let crow = &mut c[li * n..(li + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let (b0, b1, b2, b3) =
                (b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
            for kk in 0..k {
                let av = arow[kk];
                s0 += av * b0[kk];
                s1 += av * b1[kk];
                s2 += av * b2[kk];
                s3 += av * b3[kk];
            }
            crow[j] += alpha * s0;
            crow[j + 1] += alpha * s1;
            crow[j + 2] += alpha * s2;
            crow[j + 3] += alpha * s3;
            j += 4;
        }
        while j < n {
            let brow = b.row(j);
            let mut s = 0.0f32;
            for kk in 0..k {
                s += arow[kk] * brow[kk];
            }
            crow[j] += alpha * s;
            j += 1;
        }
    }
}

/// C += α·Aᵀ·B: k-blocked rank-1 updates; each C row accumulates the
/// block's B rows in ascending k.
#[allow(clippy::too_many_arguments)]
fn gemm_chunk_tn(
    alpha: f32,
    a: &MatView<'_>,
    b: &MatView<'_>,
    i0: usize,
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    let mut kb0 = 0;
    while kb0 < k {
        let kb1 = (kb0 + GEMM_KB).min(k);
        for li in 0..rows {
            let crow = &mut c[li * n..(li + 1) * n];
            for kk in kb0..kb1 {
                let aik = alpha * a.at(kk, i0 + li);
                let brow = b.row(kk);
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
        kb0 = kb1;
    }
}

/// C += α·Aᵀ·Bᵀ (both operands strided — rare; correctness path).
#[allow(clippy::too_many_arguments)]
fn gemm_chunk_tt(
    alpha: f32,
    a: &MatView<'_>,
    b: &MatView<'_>,
    i0: usize,
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    for li in 0..rows {
        let crow = &mut c[li * n..(li + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = b.row(j);
            let mut s = 0.0f32;
            for kk in 0..k {
                s += a.at(kk, i0 + li) * brow[kk];
            }
            *cv += alpha * s;
        }
    }
}

/// Dense C = A · B (value-returning convenience over [`gemm_into`]).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_into(1.0, a.view(), false, b.view(), false, 0.0, c.view_mut());
    c
}

/// Frozen replica of the pre-view-API dense GEMM (the PR-2 `matmul`):
/// naive single-threaded ikj with the data-dependent `aik == 0` skip.
/// Not used by any product path — kept as the one shared oracle for the
/// bitwise-parity tests (`tests/gemm_kernels.rs`) and the `gemm_scaling`
/// bench baseline, so both compare against the same kernel. Do not
/// "improve" it; its value is staying byte-for-byte what PR-2 shipped.
pub fn matmul_pr2_reference(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
        for k in 0..a.cols {
            let aik = a.data[i * a.cols + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * b.cols..(k + 1) * b.cols];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// dX = Ĝ·W touching only the kept columns of G (the paper's FLOP saving),
/// written into `dx` (overwritten, no read).
///
/// `kept` lists the surviving column indices j with their rescale 1/p_j;
/// cost is O(B · |kept| · d_in) instead of O(B · d_out · d_in). Batch rows
/// are independent, so the kernel row-chunk threads exactly like
/// [`gemm_into`] (bit-identical for every worker count).
pub fn sparse_dx_into(
    g: MatView<'_>,
    kept: &[(usize, f32)],
    w: MatView<'_>,
    dx: MatViewMut<'_>,
) {
    let (bsz, din) = (g.rows, w.cols);
    assert_eq!((dx.rows, dx.cols), (bsz, din), "sparse_dx output shape");
    let kernel = kernels::active();
    if kernel.is_simd() {
        kernels::sparse_dx_packed(kernel, g, kept, w, dx);
        return;
    }
    let workers = if bsz * din * kept.len().max(1) < GEMM_PAR_MIN_FLOPS {
        1
    } else {
        pool::threads()
    };
    pool::run_row_chunks(workers, bsz, din, dx.data, |i0, chunk| {
        for (li, dxrow) in chunk.chunks_mut(din).enumerate() {
            dxrow.fill(0.0);
            let grow = g.row(i0 + li);
            for &(j, inv) in kept {
                let gij = grow[j] * inv;
                let wrow = w.row(j);
                for (dv, wv) in dxrow.iter_mut().zip(wrow) {
                    *dv += gij * wv;
                }
            }
        }
    });
}

/// dX = Ĝ·W (value-returning convenience over [`sparse_dx_into`]).
pub fn sparse_dx(g: &Mat, kept: &[(usize, f32)], w: &Mat) -> Mat {
    let mut dx = Mat::zeros(g.rows, w.cols);
    sparse_dx_into(g.view(), kept, w.view(), dx.view_mut());
    dx
}

/// One kept row of dW: `dw_row += Σ_i g[i,j]·inv · x[i,:]` (ascending i —
/// the same per-element order as the dense TN kernel).
#[inline]
fn accum_dw_row(
    j: usize,
    inv: f32,
    g: &MatView<'_>,
    x: &MatView<'_>,
    dwrow: &mut [f32],
) {
    for i in 0..g.rows {
        let gij = g.at(i, j) * inv;
        let xrow = x.row(i);
        for (dv, xv) in dwrow.iter_mut().zip(xrow) {
            *dv += gij * xv;
        }
    }
}

/// dW = Ĝᵀ·X restricted to the kept rows of dW (same saving, other GEMM),
/// written into `dw` (fully overwritten: dropped rows are zeroed).
///
/// Threading partitions the kept list into *more chunks than workers*
/// (dynamic chunking over [`crate::pool::run_dynamic`]): chunk row counts
/// round unevenly and waterfilling budgets skew which chunks exist at
/// all, so a static one-chunk-per-worker split can leave most workers
/// idle behind one straggler. Each chunk owns whole dW rows (kept
/// indices are strictly increasing, hence disjoint spans), and each kept
/// row's accumulation order is fixed, so the result is bit-identical for
/// every worker count and schedule.
pub fn sparse_dw_into(
    g: MatView<'_>,
    kept: &[(usize, f32)],
    x: MatView<'_>,
    dw: MatViewMut<'_>,
) {
    let (bsz, din, dout) = (g.rows, x.cols, g.cols);
    assert_eq!((dw.rows, dw.cols), (dout, din), "sparse_dw output shape");
    dw.data.fill(0.0);
    if din == 0 || kept.is_empty() {
        return;
    }
    // Input contract, checked on every path so behavior is uniform across
    // thread counts: strictly increasing indices (what `kept_columns`
    // produces) make the threaded workers' row spans disjoint, and every
    // index must address a real dW row.
    assert!(
        kept.windows(2).all(|p| p[0].0 < p[1].0),
        "sparse_dw_into: kept indices must be strictly increasing"
    );
    assert!(
        kept.last().expect("non-empty").0 < dout,
        "sparse_dw_into: kept index out of range"
    );
    let kernel = kernels::active();
    let workers = if bsz * din * kept.len() < GEMM_PAR_MIN_FLOPS {
        1
    } else {
        pool::threads().min(kept.len())
    };
    if workers <= 1 {
        if kernel.is_simd() {
            let arena = kernels::PackArena::global();
            let mut xbuf = arena.take(0);
            let mut abuf = arena.take(0);
            {
                let xp = kernels::sparse_dw_pack_x(x, &mut xbuf);
                kernels::sparse_dw_tiles(kernel, g, kept, xp, din, 0, dw.data, &mut abuf);
            }
            arena.put(xbuf);
            arena.put(abuf);
        } else {
            for &(j, inv) in kept {
                accum_dw_row(j, inv, &g, &x, &mut dw.data[j * din..(j + 1) * din]);
            }
        }
        return;
    }
    // Carve the kept list into contiguous chunks (4 per worker) whose dW
    // row spans are ordered and disjoint, so the buffer splits with safe
    // progressive split_at_mut — no raw pointers. Chunk descriptors and
    // worker scratch live in fixed stack arrays (§7.2: no heap on the
    // steady-state path), sized by the MAX_WORKER_STATES clamp above.
    struct DwItem<'a> {
        part: &'a [(usize, f32)],
        span: &'a mut [f32],
        first: usize,
    }
    const MAX_DW_CHUNKS: usize = 4 * kernels::MAX_WORKER_STATES;
    let workers = workers.min(kernels::MAX_WORKER_STATES);
    let target = (workers * 4).min(kept.len());
    let chunk = kept.len().div_ceil(target);
    let mut items: [Option<DwItem<'_>>; MAX_DW_CHUNKS] =
        std::array::from_fn(|_| None);
    let mut nitems = 0usize;
    {
        let mut rest: &mut [f32] = dw.data;
        let mut consumed_rows = 0usize;
        for part in kept.chunks(chunk) {
            let first = part[0].0;
            let last = part[part.len() - 1].0;
            let r = std::mem::take(&mut rest);
            let (_skip, tail) = r.split_at_mut((first - consumed_rows) * din);
            let (span, tail) = tail.split_at_mut((last - first + 1) * din);
            rest = tail;
            consumed_rows = last + 1;
            items[nitems] = Some(DwItem { part, span, first });
            nitems += 1;
        }
    }
    debug_assert_eq!(
        items[..nitems]
            .iter()
            .map(|it| it.as_ref().expect("filled").part.len())
            .sum::<usize>(),
        kept.len(),
        "dw chunking must cover every kept row exactly once"
    );
    // ceil(n / ceil(n/target)) ≤ target, so every chunk found a slot.
    let drain = items[..nitems]
        .iter_mut()
        .map(|it| it.take().expect("filled"));
    if kernel.is_simd() {
        let arena = kernels::PackArena::global();
        let mut xbuf = arena.take(0);
        // analyze: allow(alloc, Vec::new is capacity-0 and never touches the heap)
        let mut abufs: [Vec<f32>; kernels::MAX_WORKER_STATES] =
            std::array::from_fn(|_| Vec::new());
        for ab in abufs.iter_mut().take(workers) {
            *ab = arena.take(0);
        }
        {
            let xp = kernels::sparse_dw_pack_x(x, &mut xbuf);
            pool::run_dynamic(drain, &mut abufs[..workers], |it, abuf| {
                let DwItem { part, span, first } = it;
                kernels::sparse_dw_tiles(kernel, g, part, xp, din, first, span, abuf);
            });
        }
        for ab in abufs.iter_mut().take(workers) {
            arena.put(std::mem::take(ab));
        }
        arena.put(xbuf);
    } else {
        let mut states = [(); kernels::MAX_WORKER_STATES];
        pool::run_dynamic(drain, &mut states[..workers], |it, _| {
            let DwItem { part, span, first } = it;
            for &(j, inv) in part {
                let off = (j - first) * din;
                accum_dw_row(j, inv, &g, &x, &mut span[off..off + din]);
            }
        });
    }
}

/// dW = Ĝᵀ·X (value-returning convenience over [`sparse_dw_into`]).
pub fn sparse_dw(g: &Mat, kept: &[(usize, f32)], x: &Mat) -> Mat {
    let mut dw = Mat::zeros(g.cols, x.cols);
    sparse_dw_into(g.view(), kept, x.view(), dw.view_mut());
    dw
}

/// Exact backward (dense baseline): (dX, dW) = (G·W, Gᵀ·X). Convenience
/// for benches/tests; the training path writes into workspace buffers via
/// [`gemm_into`] directly.
pub fn dense_backward(g: &Mat, x: &Mat, w: &Mat) -> (Mat, Mat) {
    let mut dx = Mat::zeros(g.rows, w.cols);
    gemm_into(1.0, g.view(), false, w.view(), false, 0.0, dx.view_mut());
    let mut dw = Mat::zeros(g.cols, x.cols);
    gemm_into(1.0, g.view(), true, x.view(), false, 0.0, dw.view_mut());
    (dx, dw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(1, 0);
        let a = randmat(7, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gemm_transpose_flags_match_materialized_transposes() {
        let mut rng = Pcg64::new(8, 0);
        let a = randmat(5, 7, &mut rng);
        let b = randmat(7, 4, &mut rng);
        let want = matmul(&a, &b);
        for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
            let am = if ta { a.transpose() } else { a.clone() };
            let bm = if tb { b.transpose() } else { b.clone() };
            let mut c = Mat::zeros(5, 4);
            gemm_into(1.0, am.view(), ta, bm.view(), tb, 0.0, c.view_mut());
            for (got, expect) in c.data.iter().zip(&want.data) {
                assert!((got - expect).abs() < 1e-4, "ta={ta} tb={tb}");
            }
        }
    }

    #[test]
    fn gemm_beta_accumulates_and_alpha_scales() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0]]);
        let b = Mat::from_rows(vec![vec![3.0], vec![4.0]]);
        let mut c = Mat::from_rows(vec![vec![10.0]]);
        // c = 2·(1·3 + 2·4) + 0.5·10 = 27
        gemm_into(2.0, a.view(), false, b.view(), false, 0.5, c.view_mut());
        assert!((c.data[0] - 27.0).abs() < 1e-6);
        // beta = 0 ignores (even non-finite) destination contents
        c.data[0] = f32::NAN;
        gemm_into(1.0, a.view(), false, b.view(), false, 0.0, c.view_mut());
        assert_eq!(c.data[0], 11.0);
    }

    #[test]
    fn gemm_ignores_relu_zeros_without_skipping() {
        // zeros in A must cost like any value AND not perturb results
        let a = Mat::from_rows(vec![vec![0.0, 2.0, 0.0], vec![1.0, 0.0, -1.0]]);
        let b = Mat::from_rows(vec![
            vec![-1.0, 5.0],
            vec![2.0, -3.0],
            vec![4.0, 0.5],
        ]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![4.0, -6.0, -5.0, 4.5]);
    }

    #[test]
    fn degenerate_shapes_round_trip() {
        // 0×0 from an empty row list
        let e = Mat::from_rows(vec![]);
        assert_eq!((e.rows, e.cols), (0, 0));
        assert_eq!(e.transpose().rows, 0);
        assert_eq!(e.frob_sq(), 0.0);
        // rows of zero width
        let z = Mat::from_rows(vec![vec![], vec![]]);
        assert_eq!((z.rows, z.cols), (2, 0));
        let zt = z.transpose();
        assert_eq!((zt.rows, zt.cols), (0, 2));
        assert_eq!(z.frob_sq(), 0.0);
        // every transpose combination over empty inner/outer dims
        for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
            // k = 0: C = β·C
            let a = if ta { Mat::zeros(0, 3) } else { Mat::zeros(3, 0) };
            let b = if tb { Mat::zeros(4, 0) } else { Mat::zeros(0, 4) };
            let mut c = Mat::from_fn(3, 4, |i, j| (i + j) as f32);
            gemm_into(1.0, a.view(), ta, b.view(), tb, 0.0, c.view_mut());
            assert!(c.data.iter().all(|&v| v == 0.0), "ta={ta} tb={tb}");
            // m = n = 0: empty output, no panic
            let a = Mat::zeros(0, 0);
            let b = Mat::zeros(0, 0);
            let mut c = Mat::zeros(0, 0);
            gemm_into(1.0, a.view(), ta, b.view(), tb, 1.0, c.view_mut());
            assert!(c.data.is_empty());
        }
        // sparse kernels on empty kept lists / empty batches
        let g = Mat::zeros(2, 3);
        let w = Mat::zeros(3, 4);
        let mut dx = Mat::from_fn(2, 4, |_, _| 7.0);
        sparse_dx_into(g.view(), &[], w.view(), dx.view_mut());
        assert!(dx.data.iter().all(|&v| v == 0.0));
        let x = Mat::zeros(2, 4);
        let mut dw = Mat::from_fn(3, 4, |_, _| 7.0);
        sparse_dw_into(g.view(), &[], x.view(), dw.view_mut());
        assert!(dw.data.iter().all(|&v| v == 0.0));
        let eg = Mat::zeros(0, 3);
        let ex = Mat::zeros(0, 4);
        let mut dw = Mat::zeros(3, 4);
        sparse_dw_into(eg.view(), &[(1, 2.0)], ex.view(), dw.view_mut());
        assert!(dw.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn reshape_is_zero_copy_reinterpretation() {
        let m = Mat::from_fn(2, 6, |i, j| (i * 6 + j) as f32);
        let v = m.reshape(4, 3);
        assert_eq!(v.at(2, 1), 7.0);
        assert_eq!(v.row(3), &[9.0, 10.0, 11.0]);
    }

    #[test]
    fn sparse_matches_dense_when_all_kept() {
        let mut rng = Pcg64::new(2, 0);
        let g = randmat(9, 6, &mut rng);
        let x = randmat(9, 4, &mut rng);
        let w = randmat(6, 4, &mut rng);
        let kept: Vec<(usize, f32)> = (0..6).map(|j| (j, 1.0)).collect();
        let (dx, dw) = dense_backward(&g, &x, &w);
        let sdx = sparse_dx(&g, &kept, &w);
        let sdw = sparse_dw(&g, &kept, &x);
        for (a, b) in dx.data.iter().zip(&sdx.data) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dw.data.iter().zip(&sdw.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sparse_ignores_dropped_columns() {
        let mut rng = Pcg64::new(3, 0);
        let g = randmat(5, 8, &mut rng);
        let x = randmat(5, 3, &mut rng);
        let w = randmat(8, 3, &mut rng);
        let kept = vec![(2usize, 2.0f32), (5, 4.0)];
        // equivalent dense computation with a masked+rescaled G
        let mut gm = Mat::zeros(5, 8);
        for i in 0..5 {
            gm.data[i * 8 + 2] = g.at(i, 2) * 2.0;
            gm.data[i * 8 + 5] = g.at(i, 5) * 4.0;
        }
        let (dx, dw) = dense_backward(&gm, &x, &w);
        let sdx = sparse_dx(&g, &kept, &w);
        let sdw = sparse_dw(&g, &kept, &x);
        for (a, b) in dx.data.iter().zip(&sdx.data) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dw.data.iter().zip(&sdw.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sparse_into_reuses_dirty_buffers() {
        let mut rng = Pcg64::new(4, 0);
        let g = randmat(5, 8, &mut rng);
        let x = randmat(5, 3, &mut rng);
        let w = randmat(8, 3, &mut rng);
        let kept = vec![(1usize, 2.0f32), (6, 1.5)];
        let clean_dx = sparse_dx(&g, &kept, &w);
        let clean_dw = sparse_dw(&g, &kept, &x);
        let mut dx = Mat::from_fn(5, 3, |_, _| f32::NAN);
        let mut dw = Mat::from_fn(8, 3, |_, _| f32::NAN);
        sparse_dx_into(g.view(), &kept, w.view(), dx.view_mut());
        sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
        assert_eq!(dx.data, clean_dx.data);
        assert_eq!(dw.data, clean_dw.data);
    }

    #[test]
    fn frob_and_sub() {
        let a = Mat::from_rows(vec![vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![0.0, 0.0]]);
        assert_eq!(a.sub(&b), a);
        assert!((a.frob_sq() - 25.0).abs() < 1e-9);
    }
}
