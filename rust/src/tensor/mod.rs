//! Dense matrix substrate + column-sparse GEMMs.
//!
//! This is the CPU-native half of the paper's efficiency story: interpret-
//! mode XLA cannot *skip* masked columns, so the wall-clock mechanism behind
//! Eq. (6) (per-iteration cost ρ(V) shrinking with the sketch budget) is
//! demonstrated here with real kernels — a dense row-major GEMM baseline and
//! the two sketched backward products that only touch the kept columns.
//! `cargo bench eq6` measures both.

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

/// Dense C = A · B (row-major, ikj loop order for cache-friendly streaming).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
        for k in 0..a.cols {
            let aik = a.data[i * a.cols + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * b.cols..(k + 1) * b.cols];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// dX = Ĝ·W touching only the kept columns of G (the paper's FLOP saving).
///
/// `kept` lists the surviving column indices j with their rescale 1/p_j;
/// cost is O(B · |kept| · d_in) instead of O(B · d_out · d_in).
pub fn sparse_dx(g: &Mat, kept: &[(usize, f32)], w: &Mat) -> Mat {
    let (b, din) = (g.rows, w.cols);
    let mut dx = Mat::zeros(b, din);
    for i in 0..b {
        let grow = g.row(i);
        let dxrow = &mut dx.data[i * din..(i + 1) * din];
        for &(j, inv) in kept {
            let gij = grow[j] * inv;
            if gij == 0.0 {
                continue;
            }
            let wrow = &w.data[j * din..(j + 1) * din];
            for (dv, wv) in dxrow.iter_mut().zip(wrow) {
                *dv += gij * wv;
            }
        }
    }
    dx
}

/// dW = Ĝᵀ·X restricted to the kept rows of dW (same saving, other GEMM).
pub fn sparse_dw(g: &Mat, kept: &[(usize, f32)], x: &Mat) -> Mat {
    let (b, din, dout) = (g.rows, x.cols, g.cols);
    let mut dw = Mat::zeros(dout, din);
    for i in 0..b {
        let grow = g.row(i);
        let xrow = x.row(i);
        for &(j, inv) in kept {
            let gij = grow[j] * inv;
            if gij == 0.0 {
                continue;
            }
            let dwrow = &mut dw.data[j * din..(j + 1) * din];
            for (dv, xv) in dwrow.iter_mut().zip(xrow) {
                *dv += gij * xv;
            }
        }
    }
    dw
}

/// Exact backward (dense baseline): (dX, dW).
pub fn dense_backward(g: &Mat, x: &Mat, w: &Mat) -> (Mat, Mat) {
    (matmul(g, w), matmul(&g.transpose(), x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(1, 0);
        let a = randmat(7, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn sparse_matches_dense_when_all_kept() {
        let mut rng = Pcg64::new(2, 0);
        let g = randmat(9, 6, &mut rng);
        let x = randmat(9, 4, &mut rng);
        let w = randmat(6, 4, &mut rng);
        let kept: Vec<(usize, f32)> = (0..6).map(|j| (j, 1.0)).collect();
        let (dx, dw) = dense_backward(&g, &x, &w);
        let sdx = sparse_dx(&g, &kept, &w);
        let sdw = sparse_dw(&g, &kept, &x);
        for (a, b) in dx.data.iter().zip(&sdx.data) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dw.data.iter().zip(&sdw.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sparse_ignores_dropped_columns() {
        let mut rng = Pcg64::new(3, 0);
        let g = randmat(5, 8, &mut rng);
        let x = randmat(5, 3, &mut rng);
        let w = randmat(8, 3, &mut rng);
        let kept = vec![(2usize, 2.0f32), (5, 4.0)];
        // equivalent dense computation with a masked+rescaled G
        let mut gm = Mat::zeros(5, 8);
        for i in 0..5 {
            gm.data[i * 8 + 2] = g.at(i, 2) * 2.0;
            gm.data[i * 8 + 5] = g.at(i, 5) * 4.0;
        }
        let (dx, dw) = dense_backward(&gm, &x, &w);
        let sdx = sparse_dx(&g, &kept, &w);
        let sdw = sparse_dw(&g, &kept, &x);
        for (a, b) in dx.data.iter().zip(&sdx.data) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dw.data.iter().zip(&sdw.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn frob_and_sub() {
        let a = Mat::from_rows(vec![vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![0.0, 0.0]]);
        assert_eq!(a.sub(&b), a);
        assert!((a.frob_sq() - 25.0).abs() < 1e-9);
    }
}
