//! The [`SimdLane`] abstraction: one 8-wide f32 vector register, written
//! once and instantiated per backend.
//!
//! Two backends implement it:
//!
//! * [`PortableLane`] — a `[f32; 8]` computed with plain scalar ops. Always
//!   compiled, fully safe, and the correctness oracle the AVX2 backend is
//!   property-tested against. Its `mul_add` is an unfused `a·b + c` (two
//!   roundings), so results can differ from the FMA backend in the last
//!   ulp — never more (see the parity tests).
//! * [`Avx2Lane`] — `__m256` via `core::arch::x86_64` intrinsics with true
//!   FMA. Only compiled on x86_64; only *executed* behind a successful
//!   `is_x86_feature_detected!("avx2") && ("fma")` check (the resolved
//!   [`crate::tensor::kernels::Kernel`] carries that proof).
//!
//! # Invariants every backend must uphold
//!
//! * **Lane width is exactly [`LANE`] = 8.** The micro-kernel geometry
//!   (6×16 tiles = 6 rows × 2 lanes) and every packed-panel layout assume
//!   it; a future NEON backend of width 4 would wrap two registers per
//!   lane rather than change `LANE` (DESIGN.md §7.3).
//! * **Elementwise ops are IEEE-754 exact per slot** (`add`/`sub`/`mul`/
//!   `div`/`sqrt`/`max` round-to-nearest like the scalar f32 ops), so any
//!   lane computation that avoids `mul_add` and horizontal reductions is
//!   bit-identical across backends.
//! * **Horizontal reductions use one fixed order** — fold the high half
//!   onto the low half, then halve again, then combine the final pair:
//!   `(l0+l4)+(l2+l6) … ` exactly as [`PortableLane::hsum`] spells out.
//!   Both backends implement the same tree, which is what makes a kernel
//!   *kind* deterministic across runs and thread counts.
//! * **No data-dependent branching** inside lane ops (`relu` and
//!   `zero_where_nonpos` are branchless selects on AVX2 and must match the
//!   scalar `if` semantics bit-for-bit, including `-0.0` handling).

/// Lane width in f32 slots shared by every backend.
pub const LANE: usize = 8;

/// One 8-wide f32 SIMD register. See the module docs for the invariants
/// implementations must uphold; all ops are safe — backends that wrap
/// intrinsics discharge their safety obligations internally (the
/// intrinsics used are plain register/`loadu`/`storeu` ops that are sound
/// whenever the instruction set is available, which construction of the
/// dispatching [`crate::tensor::kernels::Kernel`] guarantees).
pub trait SimdLane: Copy {
    /// All-zero lane.
    fn zero() -> Self;
    /// Broadcast `v` into every slot.
    fn splat(v: f32) -> Self;
    /// Load 8 contiguous f32s.
    fn load(src: &[f32; LANE]) -> Self;
    /// Store 8 contiguous f32s.
    fn store(self, dst: &mut [f32; LANE]);
    /// Slotwise `self + o`.
    fn add(self, o: Self) -> Self;
    /// Slotwise `self - o`.
    fn sub(self, o: Self) -> Self;
    /// Slotwise `self * o`.
    fn mul(self, o: Self) -> Self;
    /// Slotwise `self / o`.
    fn div(self, o: Self) -> Self;
    /// Slotwise square root.
    fn sqrt(self) -> Self;
    /// Slotwise `if o > self { o } else { self }` (keeps `self` on ties —
    /// the same update rule as the scalar running-max loops).
    fn max(self, o: Self) -> Self;
    /// Slotwise `self * m + a`. Fused (one rounding) on AVX2, unfused on
    /// the portable backend — the one op where backends may differ in the
    /// last ulp.
    fn mul_add(self, m: Self, a: Self) -> Self;
    /// Slotwise `if self < 0.0 { 0.0 } else { self }` (keeps `-0.0`, like
    /// the scalar ReLU).
    fn relu(self) -> Self;
    /// Slotwise `if gate <= 0.0 { 0.0 } else { self }` — the ReLU backward
    /// mask.
    fn zero_where_nonpos(self, gate: Self) -> Self;
    /// Horizontal sum in the fixed documented order.
    fn hsum(self) -> f32;
    /// Horizontal max (same tree as [`SimdLane::hsum`], exact anyway).
    fn hmax(self) -> f32;
}

/// Safe scalar-emulated backend: `[f32; 8]` with plain f32 arithmetic.
#[derive(Clone, Copy, Debug)]
pub struct PortableLane(pub [f32; LANE]);

impl PortableLane {
    #[inline(always)]
    fn map2(self, o: Self, f: impl Fn(f32, f32) -> f32) -> Self {
        let mut out = [0.0f32; LANE];
        for i in 0..LANE {
            out[i] = f(self.0[i], o.0[i]);
        }
        PortableLane(out)
    }
}

impl SimdLane for PortableLane {
    #[inline(always)]
    fn zero() -> Self {
        PortableLane([0.0; LANE])
    }

    #[inline(always)]
    fn splat(v: f32) -> Self {
        PortableLane([v; LANE])
    }

    #[inline(always)]
    fn load(src: &[f32; LANE]) -> Self {
        PortableLane(*src)
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32; LANE]) {
        *dst = self.0;
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        self.map2(o, |a, b| a + b)
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        self.map2(o, |a, b| a - b)
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        self.map2(o, |a, b| a * b)
    }

    #[inline(always)]
    fn div(self, o: Self) -> Self {
        self.map2(o, |a, b| a / b)
    }

    #[inline(always)]
    fn sqrt(self) -> Self {
        let mut out = self.0;
        for v in out.iter_mut() {
            *v = v.sqrt();
        }
        PortableLane(out)
    }

    #[inline(always)]
    fn max(self, o: Self) -> Self {
        self.map2(o, |a, b| if b > a { b } else { a })
    }

    #[inline(always)]
    fn mul_add(self, m: Self, a: Self) -> Self {
        // deliberately unfused: two roundings, like plain scalar code
        let mut out = [0.0f32; LANE];
        for i in 0..LANE {
            out[i] = self.0[i] * m.0[i] + a.0[i];
        }
        PortableLane(out)
    }

    #[inline(always)]
    fn relu(self) -> Self {
        let mut out = self.0;
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        PortableLane(out)
    }

    #[inline(always)]
    fn zero_where_nonpos(self, gate: Self) -> Self {
        self.map2(gate, |v, g| if g <= 0.0 { 0.0 } else { v })
    }

    #[inline(always)]
    fn hsum(self) -> f32 {
        // THE canonical reduction order: fold the high half onto the low
        // half, halve again, combine the final pair. Avx2Lane must match.
        let l = self.0;
        let q = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
        let p = [q[0] + q[2], q[1] + q[3]];
        p[0] + p[1]
    }

    #[inline(always)]
    fn hmax(self) -> f32 {
        let m = |a: f32, b: f32| if b > a { b } else { a };
        let l = self.0;
        let q = [m(l[0], l[4]), m(l[1], l[5]), m(l[2], l[6]), m(l[3], l[7])];
        let p = [m(q[0], q[2]), m(q[1], q[3])];
        m(p[0], p[1])
    }
}

#[cfg(target_arch = "x86_64")]
pub use avx2::Avx2Lane;

/// AVX2+FMA backend (`__m256`). Compiled only on x86_64; run only behind
/// runtime feature detection.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{SimdLane, LANE};
    use core::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_andnot_ps, _mm256_castps256_ps128,
        _mm256_cmp_ps, _mm256_div_ps, _mm256_extractf128_ps,
        _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_max_ps, _mm256_mul_ps,
        _mm256_set1_ps, _mm256_setzero_ps, _mm256_sqrt_ps, _mm256_storeu_ps,
        _mm256_sub_ps, _mm_add_ps, _mm_add_ss, _mm_cvtss_f32, _mm_max_ps,
        _mm_max_ss, _mm_movehdup_ps, _mm_movehl_ps, _CMP_LE_OQ, _CMP_LT_OQ,
    };

    /// One `__m256` register of 8 f32 slots.
    ///
    /// Every method lowers to a single VEX instruction (plus unaligned
    /// load/store, which carry no alignment obligation). The intrinsics
    /// themselves are `unsafe` only because executing AVX instructions on
    /// a CPU without them is undefined; the kernels module never
    /// constructs a dispatch path to this type without a successful
    /// `is_x86_feature_detected!` probe, and every call chain is wrapped
    /// in a `#[target_feature(enable = "avx2,fma")]` function.
    #[derive(Clone, Copy)]
    pub struct Avx2Lane(__m256);

    impl SimdLane for Avx2Lane {
        #[inline(always)]
        fn zero() -> Self {
            // SAFETY: register-only AVX op; reachable only behind the
            // runtime avx2+fma probe (see type docs).
            Avx2Lane(unsafe { _mm256_setzero_ps() })
        }

        #[inline(always)]
        fn splat(v: f32) -> Self {
            // SAFETY: register-only AVX op behind the runtime probe.
            Avx2Lane(unsafe { _mm256_set1_ps(v) })
        }

        #[inline(always)]
        fn load(src: &[f32; LANE]) -> Self {
            // SAFETY: `src` is a valid &[f32; 8], so reading 32 bytes from
            // its address is in-bounds; `loadu` has no alignment
            // requirement. AVX availability per the type docs.
            Avx2Lane(unsafe { _mm256_loadu_ps(src.as_ptr()) })
        }

        #[inline(always)]
        fn store(self, dst: &mut [f32; LANE]) {
            // SAFETY: `dst` is a valid &mut [f32; 8]; 32-byte unaligned
            // store is in-bounds. AVX availability per the type docs.
            unsafe { _mm256_storeu_ps(dst.as_mut_ptr(), self.0) }
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            // SAFETY: register-only AVX op behind the runtime probe.
            Avx2Lane(unsafe { _mm256_add_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            // SAFETY: register-only AVX op behind the runtime probe.
            Avx2Lane(unsafe { _mm256_sub_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            // SAFETY: register-only AVX op behind the runtime probe.
            Avx2Lane(unsafe { _mm256_mul_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            // SAFETY: register-only AVX op behind the runtime probe.
            Avx2Lane(unsafe { _mm256_div_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            // SAFETY: register-only AVX op behind the runtime probe.
            Avx2Lane(unsafe { _mm256_sqrt_ps(self.0) })
        }

        #[inline(always)]
        fn max(self, o: Self) -> Self {
            // `maxps(a, b)` computes `a > b ? a : b` — it returns the
            // *second* operand on ties (and when either input is NaN), so
            // the operands go in as `(o, self)` to reproduce the scalar
            // rule `if o > self { o } else { self }` exactly, including
            // `-0.0` ties and NaN propagation.
            // SAFETY: register-only AVX op behind the runtime probe.
            Avx2Lane(unsafe { _mm256_max_ps(o.0, self.0) })
        }

        #[inline(always)]
        fn mul_add(self, m: Self, a: Self) -> Self {
            // SAFETY: register-only FMA op behind the runtime probe (the
            // dispatch functions enable both "avx2" and "fma").
            Avx2Lane(unsafe { _mm256_fmadd_ps(self.0, m.0, a.0) })
        }

        #[inline(always)]
        fn relu(self) -> Self {
            // mask = (self < 0); out = !mask & self — keeps -0.0 exactly
            // like the scalar `if v < 0.0 { 0.0 } else { v }`.
            // SAFETY: register-only AVX ops behind the runtime probe.
            unsafe {
                let mask = _mm256_cmp_ps::<_CMP_LT_OQ>(self.0, _mm256_setzero_ps());
                Avx2Lane(_mm256_andnot_ps(mask, self.0))
            }
        }

        #[inline(always)]
        fn zero_where_nonpos(self, gate: Self) -> Self {
            // mask = (gate <= 0); out = !mask & self.
            // SAFETY: register-only AVX ops behind the runtime probe.
            unsafe {
                let mask = _mm256_cmp_ps::<_CMP_LE_OQ>(gate.0, _mm256_setzero_ps());
                Avx2Lane(_mm256_andnot_ps(mask, self.0))
            }
        }

        #[inline(always)]
        fn hsum(self) -> f32 {
            // Matches PortableLane::hsum exactly: high half + low half
            // gives (l0+l4, l1+l5, l2+l6, l3+l7); movehl then adds slots
            // (0,2) and (1,3); movehdup pairs the final two.
            // SAFETY: register-only SSE/AVX ops behind the runtime probe.
            unsafe {
                let hi = _mm256_extractf128_ps::<1>(self.0);
                let lo = _mm256_castps256_ps128(self.0);
                let q = _mm_add_ps(lo, hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
                let p = _mm_add_ps(q, _mm_movehl_ps(q, q)); // [q0+q2, q1+q3, ..]
                _mm_cvtss_f32(_mm_add_ss(p, _mm_movehdup_ps(p)))
            }
        }

        #[inline(always)]
        fn hmax(self) -> f32 {
            // Same tree as hsum; `maxps` returns its second operand on
            // ties, so the earlier (lower-index) value goes second at
            // every level to match PortableLane's `if b > a { b } else
            // { a }` fold exactly on signed-zero ties.
            // SAFETY: register-only SSE/AVX ops behind the runtime probe.
            unsafe {
                let hi = _mm256_extractf128_ps::<1>(self.0);
                let lo = _mm256_castps256_ps128(self.0);
                let q = _mm_max_ps(hi, lo);
                let p = _mm_max_ps(_mm_movehl_ps(q, q), q);
                _mm_cvtss_f32(_mm_max_ss(_mm_movehdup_ps(p), p))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(seed: f32) -> [f32; LANE] {
        let mut a = [0.0f32; LANE];
        for (i, v) in a.iter_mut().enumerate() {
            *v = seed + i as f32 * 0.37 - 1.2;
        }
        a
    }

    #[test]
    fn portable_elementwise_ops_match_scalar() {
        let a = PortableLane::load(&arr(1.0));
        let b = PortableLane::load(&arr(-0.5));
        let mut got = [0.0f32; LANE];
        a.add(b).store(&mut got);
        for i in 0..LANE {
            assert_eq!(got[i], arr(1.0)[i] + arr(-0.5)[i]);
        }
        a.mul(b).store(&mut got);
        for i in 0..LANE {
            assert_eq!(got[i], arr(1.0)[i] * arr(-0.5)[i]);
        }
        a.mul_add(b, PortableLane::splat(0.25)).store(&mut got);
        for i in 0..LANE {
            assert_eq!(got[i], arr(1.0)[i] * arr(-0.5)[i] + 0.25);
        }
    }

    #[test]
    fn portable_relu_and_mask_keep_scalar_semantics() {
        let x = PortableLane::load(&[-1.0, -0.0, 0.0, 2.0, -3.0, 4.0, -5.0, 6.0]);
        let mut got = [0.0f32; LANE];
        x.relu().store(&mut got);
        // -0.0 is NOT < 0.0, so it survives with its sign, like scalar code
        assert_eq!(got[0], 0.0);
        assert!(got[1] == 0.0 && got[1].is_sign_negative());
        assert_eq!(got[3], 2.0);
        let g = PortableLane::splat(1.0);
        g.zero_where_nonpos(x).store(&mut got);
        assert_eq!(got, [0.0, 0.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn portable_hsum_uses_documented_order() {
        // values chosen so different summation orders give different f32
        // results; the documented tree must be reproduced exactly
        let l = [1e8f32, 1.0, -1e8, 1.0, -1e8, 1.0, 1e8, 1.0];
        let got = PortableLane::load(&l).hsum();
        let q = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
        let p = [q[0] + q[2], q[1] + q[3]];
        assert_eq!(got, p[0] + p[1]);
        assert_eq!(got, 4.0); // halves cancel exactly in this order
    }

    #[test]
    fn portable_hmax_and_max_tie_rule() {
        let l = [-3.0f32, 7.0, 2.0, -1.0, 7.0, 0.0, -9.0, 6.5];
        assert_eq!(PortableLane::load(&l).hmax(), 7.0);
        // max keeps self on ties (matters only for signed zero)
        let a = PortableLane::splat(-0.0);
        let b = PortableLane::splat(0.0);
        let mut got = [1.0f32; LANE];
        a.max(b).store(&mut got);
        assert!(got[0] == 0.0 && got[0].is_sign_negative());
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_matches_portable_on_exact_ops() {
        if !std::arch::is_x86_feature_detected!("avx2")
            || !std::arch::is_x86_feature_detected!("fma")
        {
            return;
        }
        // SAFETY: avx2+fma verified present immediately above.
        unsafe { avx2_vs_portable() }
    }

    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: caller must have verified avx2+fma (the test guard above).
    unsafe fn avx2_vs_portable() {
        let xs = [-1.5f32, -0.0, 0.0, 2.25, 1e8, 1.0, -1e8, 0.125];
        let ys = [0.5f32, 3.0, -2.0, 1.0, 1.0, -1e8, 1e8, 8.0];
        let (pa, pb) = (PortableLane::load(&xs), PortableLane::load(&ys));
        let (va, vb) = (Avx2Lane::load(&xs), Avx2Lane::load(&ys));
        let mut p = [0.0f32; LANE];
        let mut v = [0.0f32; LANE];
        pa.add(pb).store(&mut p);
        va.add(vb).store(&mut v);
        assert_eq!(p, v, "add");
        pa.sub(pb).store(&mut p);
        va.sub(vb).store(&mut v);
        assert_eq!(p, v, "sub");
        pa.mul(pb).store(&mut p);
        va.mul(vb).store(&mut v);
        assert_eq!(p, v, "mul");
        pa.div(pb).store(&mut p);
        va.div(vb).store(&mut v);
        assert_eq!(p, v, "div");
        pa.max(pb).store(&mut p);
        va.max(vb).store(&mut v);
        assert_eq!(p, v, "max");
        // signed-zero ties: both backends must keep `self` (the tie rule)
        let pz = PortableLane::load(&[-0.0; LANE]).max(PortableLane::splat(0.0));
        let vz = Avx2Lane::load(&[-0.0; LANE]).max(Avx2Lane::splat(0.0));
        pz.store(&mut p);
        vz.store(&mut v);
        for i in 0..LANE {
            assert!(p[i].is_sign_negative(), "portable max tie slot {i}");
            assert!(v[i].is_sign_negative(), "avx2 max tie slot {i}");
        }
        // all-signed-zero input: the hmax result's sign is decided purely
        // by the tie rule at every tree level — must agree bitwise
        let zt = [-0.0f32, 0.0, -0.0, -0.0, 0.0, -0.0, 0.0, -0.0];
        assert_eq!(
            PortableLane::load(&zt).hmax().to_bits(),
            Avx2Lane::load(&zt).hmax().to_bits(),
            "hmax signed-zero tie"
        );
        pa.relu().store(&mut p);
        va.relu().store(&mut v);
        assert_eq!(p, v, "relu");
        assert!(p[1] == 0.0 && p[1].is_sign_negative(), "-0.0 preserved");
        pa.zero_where_nonpos(pb).store(&mut p);
        va.zero_where_nonpos(vb).store(&mut v);
        assert_eq!(p, v, "mask");
        // horizontal reductions share one fixed tree → bitwise equal
        assert_eq!(pa.hsum(), va.hsum(), "hsum order");
        assert_eq!(pa.hmax(), va.hmax(), "hmax");
        // fma differs from mul+add by at most one rounding
        pa.mul_add(pb, PortableLane::splat(0.75)).store(&mut p);
        va.mul_add(vb, Avx2Lane::splat(0.75)).store(&mut v);
        for i in 0..LANE {
            let tol = 2.0 * f32::EPSILON * (1.0 + p[i].abs());
            assert!((p[i] - v[i]).abs() <= tol, "fma slot {i}: {} vs {}", p[i], v[i]);
        }
    }
}
