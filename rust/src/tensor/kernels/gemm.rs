//! The packed, register-tiled GEMM and its kept-column (sparse) variants.
//!
//! # Shape of the computation (BLIS-style, single panel level)
//!
//! For `C = α·op(A)·op(B) + β·C` with `C: m × n` and inner dim `k`:
//!
//! 1. **Pack B** once into NR-column panels: panel `p` holds columns
//!    `[p·NR, p·NR+NR)` in k-major order (`bp[p·k·NR + kk·NR + l]`),
//!    zero-padded past `n`. Transposition is absorbed here — the micro-
//!    kernel always reads contiguous panels.
//! 2. **Pack A** per row-chunk into MR-row micro-panels in k-major order
//!    (`ap[t·MR·k + kk·MR + r]`), zero-padded past the chunk's rows.
//! 3. **Micro-kernel**: each `MR × NR` tile of C is computed in
//!    `MR × 2` lane registers ([`super::lane::SimdLane`], 6×16 with 8-wide
//!    lanes — 12 accumulators + 2 B lanes + 1 broadcast fits the 16
//!    AVX2 registers), one `mul_add` chain per element over ascending k.
//!
//! At the shapes this crate trains (k ≤ ~1024) a B panel is ≤ 64 KiB and
//! an A micro-panel ≤ 24 KiB, so both stream from L1/L2 without a second
//! (KC/MC) blocking level; see DESIGN.md §7.3 for when and how to add one.
//!
//! # Determinism
//!
//! Every output element is one register chain over ascending k, scaled by
//! α once, then combined with `β·C` — a fixed op sequence per element that
//! does not depend on tile position, chunk boundaries or worker count
//! (zero-padded pack slots only ever feed *discarded* accumulator slots).
//! The full-tile lane store and the edge-tile scalar store compute the
//! same `β·c + α·acc` expression with the same associativity, so results
//! are bit-identical however the work is partitioned — the property
//! `tests/simd_kernels.rs` pins.
//!
//! The kept-column variants fold the unbiased `1/pᵢ` rescale into the
//! packed A values (`ĝ = g·inv`, same product order as the scalar
//! kernels), gather only kept columns/rows while packing, and then run
//! the *identical* micro-kernel — which is how the sketched backward
//! vectorizes exactly as well as the dense baseline.

use crate::pool;
use crate::tensor::{MatView, MatViewMut, GEMM_PAR_MIN_FLOPS};

use super::lane::{PortableLane, SimdLane, LANE};
#[cfg(target_arch = "x86_64")]
use super::lane::Avx2Lane;
use super::{aligned_slice, Kernel, PackArena, MAX_WORKER_STATES};

/// Micro-tile rows (register-tile height).
pub(crate) const MR: usize = 6;
/// Micro-tile columns = two lanes (register-tile width).
pub(crate) const NR: usize = 2 * LANE;

/// Pack rows `[i0, i0+rows)` of `op(A)` (k-major MR-panels, zero-padded).
fn pack_a(a: &MatView<'_>, ta: bool, i0: usize, rows: usize, k: usize, out: &mut [f32]) {
    let tiles = rows.div_ceil(MR);
    for t in 0..tiles {
        let base = t * MR * k;
        for r in 0..MR {
            let li = t * MR + r;
            if li < rows {
                let i = i0 + li;
                if ta {
                    for kk in 0..k {
                        out[base + kk * MR + r] = a.at(kk, i);
                    }
                } else {
                    let row = a.row(i);
                    for kk in 0..k {
                        out[base + kk * MR + r] = row[kk];
                    }
                }
            } else {
                for kk in 0..k {
                    out[base + kk * MR + r] = 0.0;
                }
            }
        }
    }
}

/// Pack all of `op(B)` (k × n) into NR-column panels (zero-padded).
fn pack_b(b: &MatView<'_>, tb: bool, n: usize, k: usize, out: &mut [f32]) {
    let panels = n.div_ceil(NR);
    for p in 0..panels {
        let base = p * k * NR;
        let j0 = p * NR;
        let take = NR.min(n - j0);
        for kk in 0..k {
            let dst = &mut out[base + kk * NR..base + (kk + 1) * NR];
            if tb {
                for (l, d) in dst.iter_mut().enumerate() {
                    let j = j0 + l;
                    *d = if j < n { b.at(j, kk) } else { 0.0 };
                }
            } else {
                let row = b.row(kk);
                dst[..take].copy_from_slice(&row[j0..j0 + take]);
                for d in dst[take..].iter_mut() {
                    *d = 0.0;
                }
            }
        }
    }
}

/// Pack the kept rows of `W` as the B operand of dX = Ĝ·W (k = |kept|).
fn pack_b_kept_rows(w: &MatView<'_>, kept: &[(usize, f32)], n: usize, out: &mut [f32]) {
    let k = kept.len();
    let panels = n.div_ceil(NR);
    for p in 0..panels {
        let base = p * k * NR;
        let j0 = p * NR;
        let take = NR.min(n - j0);
        for (kk, &(j, _)) in kept.iter().enumerate() {
            let dst = &mut out[base + kk * NR..base + (kk + 1) * NR];
            let row = w.row(j);
            dst[..take].copy_from_slice(&row[j0..j0 + take]);
            for d in dst[take..].iter_mut() {
                *d = 0.0;
            }
        }
    }
}

/// Pack batch rows `[i0, i0+rows)` of Ĝ restricted to the kept columns,
/// with the `1/pⱼ` rescale folded in — the A operand of dX = Ĝ·W.
fn pack_a_kept_cols(
    g: &MatView<'_>,
    kept: &[(usize, f32)],
    i0: usize,
    rows: usize,
    out: &mut [f32],
) {
    let k = kept.len();
    let tiles = rows.div_ceil(MR);
    for t in 0..tiles {
        let base = t * MR * k;
        for r in 0..MR {
            let li = t * MR + r;
            if li < rows {
                let row = g.row(i0 + li);
                for (kk, &(j, inv)) in kept.iter().enumerate() {
                    out[base + kk * MR + r] = row[j] * inv;
                }
            } else {
                for kk in 0..k {
                    out[base + kk * MR + r] = 0.0;
                }
            }
        }
    }
}

/// Pack `part`'s kept columns of Ĝ as *rows* of the A operand of
/// dW = Ĝᵀ·X (k = batch), rescale folded in.
fn pack_a_dw(g: &MatView<'_>, part: &[(usize, f32)], out: &mut [f32]) {
    let k = g.rows;
    let tiles = part.len().div_ceil(MR);
    for t in 0..tiles {
        let base = t * MR * k;
        for r in 0..MR {
            let li = t * MR + r;
            if li < part.len() {
                let (j, inv) = part[li];
                for kk in 0..k {
                    out[base + kk * MR + r] = g.at(kk, j) * inv;
                }
            } else {
                for kk in 0..k {
                    out[base + kk * MR + r] = 0.0;
                }
            }
        }
    }
}

/// One `MR × NR` register tile: `acc[r] = Σ_k a[k][r] · b[k][:]`, one
/// ascending-k `mul_add` chain per element.
#[inline(always)]
fn micro_tile<L: SimdLane>(k: usize, ap: &[f32], bp: &[f32]) -> [[L; 2]; MR] {
    let mut acc = [[L::zero(); 2]; MR];
    for (brow, arow) in bp.chunks_exact(NR).take(k).zip(ap.chunks_exact(MR)) {
        let b0 = L::load((&brow[..LANE]).try_into().expect("lane width"));
        let b1 = L::load((&brow[LANE..]).try_into().expect("lane width"));
        for r in 0..MR {
            let av = L::splat(arow[r]);
            acc[r][0] = av.mul_add(b0, acc[r][0]);
            acc[r][1] = av.mul_add(b1, acc[r][1]);
        }
    }
    acc
}

/// Combine one already-scaled lane with `β·dst` and store. The three β
/// cases spell out the exact expression the edge path replicates.
#[inline(always)]
fn write_lane<L: SimdLane>(scaled: L, beta: f32, dst: &mut [f32; LANE]) {
    let out = if beta == 0.0 {
        scaled // never reads dst (safe on dirty/NaN buffers)
    } else if beta == 1.0 {
        L::load(dst).add(scaled)
    } else {
        L::load(dst).mul(L::splat(beta)).add(scaled)
    };
    out.store(dst);
}

/// Store one tile row (`acc0 ‖ acc1`) into `dst` (`dst.len()` ≤ NR):
/// `dst = β·dst + α·acc`. Full rows go through lanes; edge rows spill the
/// accumulators and apply the *same* per-element expression scalar-wise,
/// so an element's bits never depend on which path its tile took.
#[inline(always)]
fn store_row<L: SimdLane>(acc0: L, acc1: L, alpha: f32, beta: f32, dst: &mut [f32]) {
    if dst.len() == NR {
        let al = L::splat(alpha);
        write_lane::<L>(
            acc0.mul(al),
            beta,
            (&mut dst[..LANE]).try_into().expect("lane width"),
        );
        write_lane::<L>(
            acc1.mul(al),
            beta,
            (&mut dst[LANE..]).try_into().expect("lane width"),
        );
    } else {
        let mut tmp = [0.0f32; NR];
        acc0.store((&mut tmp[..LANE]).try_into().expect("lane width"));
        acc1.store((&mut tmp[LANE..]).try_into().expect("lane width"));
        for (d, &t) in dst.iter_mut().zip(&tmp) {
            *d = if beta == 0.0 {
                alpha * t
            } else if beta == 1.0 {
                *d + alpha * t
            } else {
                beta * *d + alpha * t
            };
        }
    }
}

/// Dense tile sweep over one packed row-chunk: `c = β·c + α·(Ap · Bp)`.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn gemm_chunk<L: SimdLane>(
    alpha: f32,
    beta: f32,
    ap: &[f32],
    bp: &[f32],
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    let tiles_m = rows.div_ceil(MR);
    let panels_n = n.div_ceil(NR);
    for t in 0..tiles_m {
        let rows_v = MR.min(rows - t * MR);
        let apt = &ap[t * MR * k..(t + 1) * MR * k];
        for p in 0..panels_n {
            let bpp = &bp[p * k * NR..(p + 1) * k * NR];
            let acc = micro_tile::<L>(k, apt, bpp);
            let j0 = p * NR;
            let cols_v = NR.min(n - j0);
            for (r, acc_r) in acc.iter().enumerate().take(rows_v) {
                let off = (t * MR + r) * n + j0;
                store_row::<L>(acc_r[0], acc_r[1], alpha, beta, &mut c[off..off + cols_v]);
            }
        }
    }
}

/// AVX2 instantiation of [`gemm_chunk`] (the `target_feature` boundary the
/// inlined lane intrinsics compile under).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
// SAFETY: caller must have verified avx2+fma at runtime (the
// `Kernel::SimdAvx2` dispatch arm in `run_chunk` is the only caller).
unsafe fn gemm_chunk_avx2(
    alpha: f32,
    beta: f32,
    ap: &[f32],
    bp: &[f32],
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    gemm_chunk::<Avx2Lane>(alpha, beta, ap, bp, rows, n, k, c);
}

/// Dispatch one packed row-chunk to the resolved lane backend.
#[allow(clippy::too_many_arguments)]
fn run_chunk(
    kernel: Kernel,
    alpha: f32,
    beta: f32,
    ap: &[f32],
    bp: &[f32],
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Kernel::SimdAvx2` is only ever constructed after
        // `is_x86_feature_detected!("avx2") && ("fma")` succeeded
        // (`kernels::detect_simd`), so the required instruction sets are
        // present on this CPU.
        Kernel::SimdAvx2 => unsafe {
            gemm_chunk_avx2(alpha, beta, ap, bp, rows, n, k, c)
        },
        _ => gemm_chunk::<PortableLane>(alpha, beta, ap, bp, rows, n, k, c),
    }
}

/// Apply the β pass alone (the k = 0 degenerate case: C = β·C).
fn beta_only(beta: f32, c: &mut [f32]) {
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
}

/// Packed-path implementation of [`crate::tensor::gemm_into`] — same
/// contract (shapes pre-validated by the caller), dispatched to `kernel`'s
/// lane backend, row-chunk threaded like the scalar path.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed(
    kernel: Kernel,
    alpha: f32,
    a: MatView<'_>,
    ta: bool,
    b: MatView<'_>,
    tb: bool,
    beta: f32,
    c: MatViewMut<'_>,
) {
    let (m, n) = (c.rows, c.cols);
    let k = if ta { a.rows } else { a.cols };
    let workers = if m * n * k.max(1) < GEMM_PAR_MIN_FLOPS {
        1
    } else {
        pool::threads()
    };
    gemm_packed_workers(kernel, workers, alpha, a, ta, b, tb, beta, c);
}

/// [`gemm_packed`] with an explicit worker count (bit-identical for every
/// value; split out so tests can sweep it without the process-global
/// thread knob).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_packed_workers(
    kernel: Kernel,
    workers: usize,
    alpha: f32,
    a: MatView<'_>,
    ta: bool,
    b: MatView<'_>,
    tb: bool,
    beta: f32,
    c: MatViewMut<'_>,
) {
    let (m, n) = (c.rows, c.cols);
    let k = if ta { a.rows } else { a.cols };
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        beta_only(beta, c.data);
        return;
    }
    let arena = PackArena::global();
    let blen = n.div_ceil(NR) * NR * k;
    let mut bbuf = arena.take(blen);
    let bp: &[f32] = {
        let s = aligned_slice(&mut bbuf, blen);
        pack_b(&b, tb, n, k, s);
        s
    };
    // Worker A-panel buffers live in a fixed stack array: the steady-state
    // path may not touch the heap (DESIGN.md §7.2), so no collected Vec.
    let workers = workers.clamp(1, m).min(MAX_WORKER_STATES);
    let chunk_rows = m.div_ceil(workers);
    let nchunks = m.div_ceil(chunk_rows);
    let alen = chunk_rows.div_ceil(MR) * MR * k;
    // analyze: allow(alloc, Vec::new is capacity-0 and never touches the heap)
    let mut abufs: [Vec<f32>; MAX_WORKER_STATES] = std::array::from_fn(|_| Vec::new());
    for ab in abufs.iter_mut().take(nchunks) {
        *ab = arena.take(alen);
    }
    pool::run_row_chunks_with(workers, m, n, c.data, &mut abufs[..nchunks], |i0, chunk, abuf| {
        let rows = chunk.len() / n;
        let ap = aligned_slice(abuf, rows.div_ceil(MR) * MR * k);
        pack_a(&a, ta, i0, rows, k, ap);
        run_chunk(kernel, alpha, beta, ap, bp, rows, n, k, chunk);
    });
    for ab in abufs.iter_mut().take(nchunks) {
        arena.put(std::mem::take(ab));
    }
    arena.put(bbuf);
}

/// Packed-path implementation of [`crate::tensor::sparse_dx_into`]:
/// dX = Ĝ·W over kept columns only, same threading/threshold as the
/// scalar path, rescale folded into the A pack.
pub fn sparse_dx_packed(
    kernel: Kernel,
    g: MatView<'_>,
    kept: &[(usize, f32)],
    w: MatView<'_>,
    dx: MatViewMut<'_>,
) {
    let workers = if dx.rows * dx.cols * kept.len().max(1) < GEMM_PAR_MIN_FLOPS {
        1
    } else {
        pool::threads()
    };
    sparse_dx_packed_workers(kernel, workers, g, kept, w, dx);
}

/// [`sparse_dx_packed`] with an explicit worker count (tests).
pub(crate) fn sparse_dx_packed_workers(
    kernel: Kernel,
    workers: usize,
    g: MatView<'_>,
    kept: &[(usize, f32)],
    w: MatView<'_>,
    dx: MatViewMut<'_>,
) {
    let (m, n, k) = (dx.rows, dx.cols, kept.len());
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        dx.data.fill(0.0);
        return;
    }
    let arena = PackArena::global();
    let blen = n.div_ceil(NR) * NR * k;
    let mut bbuf = arena.take(blen);
    let bp: &[f32] = {
        let s = aligned_slice(&mut bbuf, blen);
        pack_b_kept_rows(&w, kept, n, s);
        s
    };
    // Same stack-array scratch discipline as gemm_packed_workers (§7.2).
    let workers = workers.clamp(1, m).min(MAX_WORKER_STATES);
    let chunk_rows = m.div_ceil(workers);
    let nchunks = m.div_ceil(chunk_rows);
    let alen = chunk_rows.div_ceil(MR) * MR * k;
    // analyze: allow(alloc, Vec::new is capacity-0 and never touches the heap)
    let mut abufs: [Vec<f32>; MAX_WORKER_STATES] = std::array::from_fn(|_| Vec::new());
    for ab in abufs.iter_mut().take(nchunks) {
        *ab = arena.take(alen);
    }
    pool::run_row_chunks_with(workers, m, n, dx.data, &mut abufs[..nchunks], |i0, chunk, abuf| {
        let rows = chunk.len() / n;
        let ap = aligned_slice(abuf, rows.div_ceil(MR) * MR * k);
        pack_a_kept_cols(&g, kept, i0, rows, ap);
        run_chunk(kernel, 1.0, 0.0, ap, bp, rows, n, k, chunk);
    });
    for ab in abufs.iter_mut().take(nchunks) {
        arena.put(std::mem::take(ab));
    }
    arena.put(bbuf);
}

/// Pack X as the shared B operand of dW = Ĝᵀ·X (done once per call; every
/// worker chunk reads it). Returns the aligned packed panel view.
pub fn sparse_dw_pack_x<'b>(x: MatView<'_>, buf: &'b mut Vec<f32>) -> &'b [f32] {
    let len = x.cols.div_ceil(NR) * NR * x.rows;
    let s = aligned_slice(buf, len);
    pack_b(&x, false, x.cols, x.rows, s);
    s
}

/// Scatter tile sweep for one dW chunk: compute the kept rows listed in
/// `part` (a contiguous slice of the kept list) into `span`, the caller's
/// mutable window over dW rows `[first, last]`. `xp` is the packed X from
/// [`sparse_dw_pack_x`]. Dropped rows inside the window are untouched
/// (the caller pre-zeroed dW).
#[allow(clippy::too_many_arguments)]
pub fn sparse_dw_tiles(
    kernel: Kernel,
    g: MatView<'_>,
    part: &[(usize, f32)],
    xp: &[f32],
    din: usize,
    first: usize,
    span: &mut [f32],
    abuf: &mut Vec<f32>,
) {
    let k = g.rows;
    let ap = aligned_slice(abuf, part.len().div_ceil(MR) * MR * k);
    pack_a_dw(&g, part, ap);
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Kernel::SimdAvx2` proves the runtime avx2+fma probe
        // succeeded (see `run_chunk`).
        Kernel::SimdAvx2 => unsafe { dw_chunk_avx2(ap, xp, part, din, k, first, span) },
        _ => dw_chunk::<PortableLane>(ap, xp, part, din, k, first, span),
    }
}

/// Tile sweep with scattered destination rows (dW rows are the kept
/// indices, not consecutive). β = 0 semantics: kept rows fully
/// overwritten.
#[inline(always)]
fn dw_chunk<L: SimdLane>(
    ap: &[f32],
    xp: &[f32],
    part: &[(usize, f32)],
    din: usize,
    k: usize,
    first: usize,
    span: &mut [f32],
) {
    let tiles_m = part.len().div_ceil(MR);
    let panels_n = din.div_ceil(NR);
    for t in 0..tiles_m {
        let rows_v = MR.min(part.len() - t * MR);
        let apt = &ap[t * MR * k..(t + 1) * MR * k];
        for p in 0..panels_n {
            let bpp = &xp[p * k * NR..(p + 1) * k * NR];
            let acc = micro_tile::<L>(k, apt, bpp);
            let j0 = p * NR;
            let cols_v = NR.min(din - j0);
            for (r, acc_r) in acc.iter().enumerate().take(rows_v) {
                let row = part[t * MR + r].0;
                let off = (row - first) * din + j0;
                store_row::<L>(acc_r[0], acc_r[1], 1.0, 0.0, &mut span[off..off + cols_v]);
            }
        }
    }
}

/// AVX2 instantiation of [`dw_chunk`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
// SAFETY: caller must have verified avx2+fma at runtime (the
// `Kernel::SimdAvx2` dispatch arm in `sparse_dw_tiles` is the only caller).
unsafe fn dw_chunk_avx2(
    ap: &[f32],
    xp: &[f32],
    part: &[(usize, f32)],
    din: usize,
    k: usize,
    first: usize,
    span: &mut [f32],
) {
    dw_chunk::<Avx2Lane>(ap, xp, part, din, k, first, span);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::Mat;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
    }

    /// Backends available on this host (portable always; AVX2 when live).
    fn backends() -> Vec<Kernel> {
        let mut v = vec![Kernel::SimdPortable];
        if super::super::detect_simd() == Kernel::SimdAvx2 {
            v.push(Kernel::SimdAvx2);
        }
        v
    }

    fn reference_f64(
        alpha: f32,
        a: &Mat,
        ta: bool,
        b: &Mat,
        tb: bool,
        beta: f32,
        c0: &Mat,
    ) -> (Vec<f64>, Vec<f64>) {
        let m = if ta { a.cols } else { a.rows };
        let k = if ta { a.rows } else { a.cols };
        let n = if tb { b.rows } else { b.cols };
        let mut out = vec![0.0f64; m * n];
        let mut mag = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                let mut t = 0.0f64;
                for kk in 0..k {
                    let av = if ta { a.at(kk, i) } else { a.at(i, kk) } as f64;
                    let bv = if tb { b.at(j, kk) } else { b.at(kk, j) } as f64;
                    s += av * bv;
                    t += (av * bv).abs();
                }
                out[i * n + j] = alpha as f64 * s + beta as f64 * c0.at(i, j) as f64;
                mag[i * n + j] = (alpha as f64 * t).abs()
                    + (beta as f64 * c0.at(i, j) as f64).abs();
            }
        }
        (out, mag)
    }

    fn assert_ulp_close(got: &[f32], want: &[f64], mag: &[f64], k: usize, tag: &str) {
        for (i, (&g, (&w, &m))) in got.iter().zip(want.iter().zip(mag)).enumerate() {
            let tol = (k as f64 + 8.0) * f32::EPSILON as f64 * (m + 1e-30);
            assert!(
                (g as f64 - w).abs() <= tol,
                "{tag} idx {i}: got {g} want {w} (tol {tol})"
            );
        }
    }

    #[test]
    fn packed_gemm_matches_f64_reference_over_remainder_shapes() {
        // m, n, k deliberately off the 6/16/lane grid, plus exact-grid and
        // degenerate sizes
        let ms = [1usize, 5, 6, 7, 13];
        let ns = [1usize, 15, 16, 17, 33];
        let ks = [1usize, 2, 9, 64];
        let mut rng = Pcg64::new(31, 0);
        let combos = [(false, false), (false, true), (true, false), (true, true)];
        for kernel in backends() {
            for &m in &ms {
                for &n in &ns {
                    for &k in &ks {
                        for (ta, tb) in combos {
                            let a = if ta {
                                randmat(k, m, &mut rng)
                            } else {
                                randmat(m, k, &mut rng)
                            };
                            let b = if tb {
                                randmat(n, k, &mut rng)
                            } else {
                                randmat(k, n, &mut rng)
                            };
                            let c0 = randmat(m, n, &mut rng);
                            let (alpha, beta) = (0.7f32, -0.4f32);
                            let (want, mag) =
                                reference_f64(alpha, &a, ta, &b, tb, beta, &c0);
                            let mut c = c0.clone();
                            gemm_packed_workers(
                                kernel,
                                1,
                                alpha,
                                a.view(),
                                ta,
                                b.view(),
                                tb,
                                beta,
                                c.view_mut(),
                            );
                            assert_ulp_close(
                                &c.data,
                                &want,
                                &mag,
                                k,
                                &format!("{kernel:?} m{m} n{n} k{k} ta{ta} tb{tb}"),
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn packed_gemm_beta_zero_ignores_dirty_destination() {
        let mut rng = Pcg64::new(5, 0);
        let a = randmat(7, 10, &mut rng);
        let b = randmat(10, 18, &mut rng);
        for kernel in backends() {
            let mut c = Mat::from_fn(7, 18, |_, _| f32::NAN);
            gemm_packed_workers(
                kernel,
                1,
                1.0,
                a.view(),
                false,
                b.view(),
                false,
                0.0,
                c.view_mut(),
            );
            assert!(c.data.iter().all(|v| v.is_finite()), "{kernel:?}");
        }
    }

    #[test]
    fn packed_gemm_is_worker_count_invariant_bitwise() {
        let mut rng = Pcg64::new(9, 0);
        let a = randmat(23, 37, &mut rng);
        let b = randmat(37, 29, &mut rng);
        let c0 = randmat(23, 29, &mut rng);
        for kernel in backends() {
            let mut base = c0.clone();
            gemm_packed_workers(
                kernel,
                1,
                0.9,
                a.view(),
                false,
                b.view(),
                false,
                0.5,
                base.view_mut(),
            );
            for workers in [2usize, 3, 5, 64] {
                let mut c = c0.clone();
                gemm_packed_workers(
                    kernel,
                    workers,
                    0.9,
                    a.view(),
                    false,
                    b.view(),
                    false,
                    0.5,
                    c.view_mut(),
                );
                assert_eq!(c.data, base.data, "{kernel:?} workers={workers}");
            }
        }
    }

    #[test]
    fn packed_degenerate_shapes_match_scalar_semantics() {
        for kernel in backends() {
            // k = 0 → pure β pass
            let a = Mat::zeros(3, 0);
            let b = Mat::zeros(0, 4);
            let mut c = Mat::from_fn(3, 4, |i, j| (i + j) as f32 + 1.0);
            gemm_packed_workers(
                kernel,
                1,
                1.0,
                a.view(),
                false,
                b.view(),
                false,
                0.5,
                c.view_mut(),
            );
            for (i, &v) in c.data.iter().enumerate() {
                let want = ((i / 4 + i % 4) as f32 + 1.0) * 0.5;
                assert_eq!(v, want, "{kernel:?}");
            }
            // m = n = 0 → no-op on the empty buffer
            let z = Mat::zeros(0, 0);
            let mut e = Mat::zeros(0, 0);
            gemm_packed_workers(
                kernel,
                4,
                1.0,
                z.view(),
                false,
                z.view(),
                false,
                1.0,
                e.view_mut(),
            );
            assert!(e.data.is_empty());
        }
    }

    #[test]
    fn pack_layouts_are_k_major_and_zero_padded() {
        // A: 2×3, rows [0,2): panel holds a[i][k] at kk*MR + r
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let mut ap = vec![f32::NAN; MR * 3];
        pack_a(&a.view(), false, 0, 2, 3, &mut ap);
        for kk in 0..3 {
            assert_eq!(ap[kk * MR], a.at(0, kk));
            assert_eq!(ap[kk * MR + 1], a.at(1, kk));
            for r in 2..MR {
                assert_eq!(ap[kk * MR + r], 0.0, "padded row");
            }
        }
        // transposed read: same panel from the 3×2 transpose
        let at = a.transpose();
        let mut apt = vec![f32::NAN; MR * 3];
        pack_a(&at.view(), true, 0, 2, 3, &mut apt);
        assert_eq!(ap, apt);
        // B: 2×3 packed as one NR panel, columns past n zeroed
        let b = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let mut bp = vec![f32::NAN; NR * 2];
        pack_b(&b.view(), false, 3, 2, &mut bp);
        for kk in 0..2 {
            for l in 0..3 {
                assert_eq!(bp[kk * NR + l], b.at(kk, l));
            }
            for l in 3..NR {
                assert_eq!(bp[kk * NR + l], 0.0, "padded col");
            }
        }
        let bt = b.transpose();
        let mut bpt = vec![f32::NAN; NR * 2];
        pack_b(&bt.view(), true, 3, 2, &mut bpt);
        assert_eq!(bp, bpt);
    }

    #[test]
    fn sparse_dx_packed_matches_masked_dense_reference() {
        let mut rng = Pcg64::new(13, 0);
        let (bsz, dout, din) = (9usize, 14, 11);
        let g = randmat(bsz, dout, &mut rng);
        let w = randmat(dout, din, &mut rng);
        let kept = vec![(1usize, 2.0f32), (5, 1.5), (6, 4.0), (13, 1.25)];
        // dense reference: masked+rescaled G times W, in f64
        let mut want = vec![0.0f64; bsz * din];
        for i in 0..bsz {
            for jj in 0..din {
                let mut s = 0.0f64;
                for &(j, inv) in &kept {
                    s += (g.at(i, j) * inv) as f64 * w.at(j, jj) as f64;
                }
                want[i * din + jj] = s;
            }
        }
        for kernel in backends() {
            for workers in [1usize, 3] {
                let mut dx = Mat::from_fn(bsz, din, |_, _| f32::NAN);
                sparse_dx_packed_workers(
                    kernel,
                    workers,
                    g.view(),
                    &kept,
                    w.view(),
                    dx.view_mut(),
                );
                for (got, wantv) in dx.data.iter().zip(&want) {
                    assert!(
                        (*got as f64 - wantv).abs() < 1e-4,
                        "{kernel:?} w{workers}: {got} vs {wantv}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_dw_tiles_scatter_only_kept_rows() {
        let mut rng = Pcg64::new(17, 0);
        let (bsz, dout, din) = (7usize, 10, 19);
        let g = randmat(bsz, dout, &mut rng);
        let x = randmat(bsz, din, &mut rng);
        let part = vec![(2usize, 3.0f32), (3, 0.5), (7, 2.0)];
        for kernel in backends() {
            let arena = PackArena::global();
            let mut xbuf = arena.take(0);
            let mut abuf = arena.take(0);
            let mut dw = Mat::zeros(dout, din);
            {
                let xp = sparse_dw_pack_x(x.view(), &mut xbuf);
                // whole dW as the span (first = 0)
                sparse_dw_tiles(
                    kernel,
                    g.view(),
                    &part,
                    xp,
                    din,
                    0,
                    &mut dw.data,
                    &mut abuf,
                );
            }
            arena.put(xbuf);
            arena.put(abuf);
            for j in 0..dout {
                let row = &dw.data[j * din..(j + 1) * din];
                match part.iter().find(|&&(pj, _)| pj == j) {
                    None => assert!(row.iter().all(|&v| v == 0.0), "{kernel:?} row {j}"),
                    Some(&(_, inv)) => {
                        for (jj, &got) in row.iter().enumerate() {
                            let mut s = 0.0f64;
                            for i in 0..bsz {
                                s += (g.at(i, j) * inv) as f64 * x.at(i, jj) as f64;
                            }
                            assert!(
                                (got as f64 - s).abs() < 1e-4,
                                "{kernel:?} ({j},{jj}): {got} vs {s}"
                            );
                        }
                    }
                }
            }
        }
    }
}
