//! Runtime-dispatched compute kernels: the BLIS-style packed micro-kernel
//! GEMM, its SIMD lane abstraction, the pack-buffer arena, and the
//! vectorized elementwise/reduction ops (DESIGN.md §7.3).
//!
//! # Kernel kinds
//!
//! Every hot loop in the crate runs in one of two *kinds*, selected at
//! process level ([`set_kernel`], the `--kernel` CLI flag /
//! `TrainConfig::kernel`, or the `UAVJP_KERNEL` env override for CI):
//!
//! * **`scalar`** — the pre-existing plain-f32 loops in [`crate::tensor`]
//!   and the layer/optimizer code, untouched. This kind is the *bitwise
//!   oracle*: its results are pinned (down to the bit) by the PR-2/PR-3
//!   trajectory-parity suites, and every SIMD path is property-tested
//!   against it to ulp tolerance.
//! * **`simd`** — panel-packed, register-tiled kernels written against
//!   [`SimdLane`] (8-wide f32). On x86_64 with AVX2+FMA detected at
//!   runtime the [`lane::Avx2Lane`] backend runs; anywhere else the safe
//!   [`lane::PortableLane`] backend runs the *same* tiled code, so the
//!   packed path never rots on non-AVX hosts.
//! * **`auto`** (default) — `simd` when AVX2+FMA is detected, else
//!   `scalar` (the plain loops auto-vectorize well enough that portable
//!   emulated lanes buy nothing on unknown hardware).
//!
//! # Determinism contract
//!
//! Within one kind on one machine, every kernel is bit-identical across
//! runs and `--threads` values: each output element's accumulation order
//! is a pure function of the operand shapes (ascending k in one register
//! chain for the tiled kernels; the documented fixed tree for horizontal
//! reductions), never of the tiling, chunking or worker count. Across
//! kinds results differ in the last ulps (FMA fuses roundings, lane sums
//! reassociate) — `tests/simd_kernels.rs` bounds the difference.
//!
//! # Memory
//!
//! Packing writes into buffers recycled through a [`PackArena`] — a
//! process-wide pool the training [`crate::native::Workspace`] pre-warms —
//! so steady-state packing performs no heap allocation.

pub mod gemm;
pub mod lane;
pub mod vec;

pub use gemm::{gemm_packed, sparse_dw_pack_x, sparse_dw_tiles, sparse_dx_packed};
#[cfg(target_arch = "x86_64")]
pub use lane::Avx2Lane;
pub use lane::{PortableLane, SimdLane, LANE};

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// User-facing kernel selector (`--kernel` / `TrainConfig::kernel` /
/// `UAVJP_KERNEL`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Resolve from `UAVJP_KERNEL` if set, else hardware detection.
    Auto,
    /// The plain-loop oracle kernels, always available.
    Scalar,
    /// The packed micro-kernel path (AVX2 lanes when detected, portable
    /// lanes otherwise).
    Simd,
}

impl KernelKind {
    /// Parse `"auto"` / `"scalar"` / `"simd"`.
    pub fn parse(s: &str) -> anyhow::Result<KernelKind> {
        match s {
            "auto" => Ok(KernelKind::Auto),
            "scalar" => Ok(KernelKind::Scalar),
            "simd" => Ok(KernelKind::Simd),
            other => anyhow::bail!(
                "unknown kernel kind {other} (want auto|scalar|simd)"
            ),
        }
    }

    /// Canonical name, inverse of [`KernelKind::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
        }
    }
}

/// The resolved kernel a call actually dispatches to. `SimdAvx2` exists
/// only after a successful `is_x86_feature_detected!("avx2") && ("fma")`
/// probe — holding a value of that variant is the proof the AVX2 code
/// paths rely on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Plain-loop oracle kernels.
    Scalar,
    /// Packed micro-kernel over [`lane::PortableLane`].
    SimdPortable,
    /// Packed micro-kernel over [`lane::Avx2Lane`] (probe succeeded).
    SimdAvx2,
}

impl Kernel {
    /// Whether this kernel routes through the packed micro-kernel path.
    pub fn is_simd(self) -> bool {
        !matches!(self, Kernel::Scalar)
    }

    /// The kind this kernel reports as (`"scalar"` / `"simd"`).
    pub fn kind_name(self) -> &'static str {
        if self.is_simd() {
            "simd"
        } else {
            "scalar"
        }
    }
}

/// Process-global resolved kernel; 0 = not yet resolved.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn encode(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => 1,
        Kernel::SimdPortable => 2,
        Kernel::SimdAvx2 => 3,
    }
}

fn decode(v: u8) -> Kernel {
    match v {
        1 => Kernel::Scalar,
        2 => Kernel::SimdPortable,
        _ => Kernel::SimdAvx2,
    }
}

/// `SimdAvx2` when the CPU advertises AVX2+FMA, else `SimdPortable`.
fn detect_simd() -> Kernel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Kernel::SimdAvx2;
        }
    }
    Kernel::SimdPortable
}

/// Resolve an explicit kind (no env consultation — `Auto` means hardware).
fn resolve_hw(kind: KernelKind) -> Kernel {
    match kind {
        KernelKind::Scalar => Kernel::Scalar,
        KernelKind::Simd => detect_simd(),
        KernelKind::Auto => {
            if detect_simd() == Kernel::SimdAvx2 {
                Kernel::SimdAvx2
            } else {
                Kernel::Scalar
            }
        }
    }
}

/// Resolution used for `Auto`: the `UAVJP_KERNEL` env override (how CI
/// pins each of its two test passes) wins over hardware detection.
/// Factored over the env *value* so tests can cover it without mutating
/// process env.
fn resolve_env(env: Option<&str>) -> Kernel {
    match env {
        None => resolve_hw(KernelKind::Auto),
        Some(s) => match KernelKind::parse(s) {
            Ok(k) => resolve_hw(k),
            Err(_) => {
                eprintln!(
                    "warning: ignoring invalid UAVJP_KERNEL={s} \
                     (want auto|scalar|simd)"
                );
                resolve_hw(KernelKind::Auto)
            }
        },
    }
}

/// Set the process-wide kernel. `Auto` re-resolves from `UAVJP_KERNEL`
/// then hardware; explicit kinds are taken literally (`Simd` on a
/// non-AVX2 host runs the portable lane backend). Like
/// [`crate::pool::set_threads`], this is a startup knob: results are
/// deterministic per kind, so flipping it mid-run only changes *which*
/// deterministic stream you are on.
pub fn set_kernel(kind: KernelKind) {
    let resolved = match kind {
        KernelKind::Auto => {
            resolve_env(std::env::var("UAVJP_KERNEL").ok().as_deref())
        }
        k => resolve_hw(k),
    };
    ACTIVE.store(encode(resolved), Ordering::Relaxed);
}

/// The resolved kernel current calls dispatch to (resolving
/// `UAVJP_KERNEL`/hardware on first use).
pub fn active() -> Kernel {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let k = resolve_env(std::env::var("UAVJP_KERNEL").ok().as_deref());
            ACTIVE.store(encode(k), Ordering::Relaxed);
            k
        }
        v => decode(v),
    }
}

/// Float-count of alignment slack each arena buffer carries so a 64-byte
/// aligned window always fits.
const ALIGN_SLACK: usize = 16;

/// Recycling pool of pack buffers (cloneable handle; all clones share one
/// pool). The packed kernels [`take`](PackArena::take) a buffer per panel,
/// write through a 64-byte-aligned window ([`aligned_slice`]), and
/// [`put`](PackArena::put) it back — so after warm-up, packing allocates
/// nothing. [`crate::native::Sequential::workspace`] pre-warms the global
/// pool for its model's worst-case panel sizes, which makes even the first
/// training step allocation-free inside the kernels.
#[derive(Clone, Default)]
pub struct PackArena {
    shared: Arc<Mutex<Vec<Vec<f32>>>>,
}

/// The shared process pool behind [`PackArena::global`].
static GLOBAL_ARENA: OnceLock<PackArena> = OnceLock::new();

/// Upper bound on the per-call worker scratch slots the tensor ops keep
/// on the stack. Steady-state ops may not touch the heap (DESIGN.md
/// §7.2), so worker state tables are fixed-size stack arrays rather than
/// collected `Vec`s; the thread count is clamped to this bound at the
/// call sites (far above the ROADMAP's single-digit-core testbed).
pub const MAX_WORKER_STATES: usize = 64;

impl PackArena {
    /// A fresh, empty pool (tests; product code shares
    /// [`PackArena::global`]).
    pub fn new() -> PackArena {
        PackArena::default()
    }

    /// Handle to the process-wide pool the kernels draw from.
    pub fn global() -> PackArena {
        GLOBAL_ARENA.get_or_init(PackArena::new).clone()
    }

    /// Take a buffer able to hold `len` floats plus alignment slack,
    /// preferring the largest pooled buffer (so one steady-state buffer
    /// serves every panel size seen so far).
    pub fn take(&self, len: usize) -> Vec<f32> {
        let need = len + ALIGN_SLACK;
        let mut pool = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        let best = pool
            .iter()
            .enumerate()
            .max_by_key(|(_, b)| b.len())
            .map(|(i, _)| i);
        let mut buf = match best {
            Some(i) => pool.swap_remove(i),
            None => Vec::new(),
        };
        drop(pool);
        if buf.len() < need {
            buf.resize(need, 0.0);
        }
        buf
    }

    /// Return a buffer to the pool (dropped if the pool is already full).
    pub fn put(&self, buf: Vec<f32>) {
        let mut pool = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        if pool.len() < 32 {
            pool.push(buf);
        }
    }

    /// Pre-warm: ensure the pool holds at least `count` buffers of at
    /// least `len` floats (plus slack) each.
    pub fn reserve(&self, count: usize, len: usize) {
        let need = len + ALIGN_SLACK;
        let mut pool = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        let have = pool.iter().filter(|b| b.len() >= need).count();
        for _ in have..count {
            pool.push(vec![0.0; need]);
        }
    }

    /// Number of pooled buffers (tests / introspection).
    pub fn pooled(&self) -> usize {
        self.shared.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// A 64-byte-aligned `len`-float window into an arena buffer (safe: pure
/// offset arithmetic on the Vec's base address; buffers carry
/// `ALIGN_SLACK` floats of headroom).
pub fn aligned_slice(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    let need = len + ALIGN_SLACK;
    if buf.len() < need {
        buf.resize(need, 0.0);
    }
    let addr = buf.as_ptr() as usize;
    let off = (addr.next_multiple_of(64) - addr) / std::mem::size_of::<f32>();
    &mut buf[off..off + len]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip_and_errors() {
        for k in [KernelKind::Auto, KernelKind::Scalar, KernelKind::Simd] {
            assert_eq!(KernelKind::parse(k.as_str()).unwrap(), k);
        }
        let err = format!("{}", KernelKind::parse("sse2").unwrap_err());
        assert!(err.contains("auto|scalar|simd"), "{err}");
    }

    #[test]
    fn env_resolution_prefers_env_over_hardware() {
        assert_eq!(resolve_env(Some("scalar")), Kernel::Scalar);
        let simd = resolve_env(Some("simd"));
        assert!(simd.is_simd());
        // bad values fall back to auto (with a warning), never panic
        let fallback = resolve_env(Some("warp-drive"));
        assert_eq!(fallback, resolve_hw(KernelKind::Auto));
        assert_eq!(resolve_env(None), resolve_hw(KernelKind::Auto));
    }

    #[test]
    fn auto_is_avx2_or_scalar_never_portable() {
        // the portable lane backend is reachable only by explicit request
        assert_ne!(resolve_hw(KernelKind::Auto), Kernel::SimdPortable);
        assert!(resolve_hw(KernelKind::Simd).is_simd());
        assert_eq!(resolve_hw(KernelKind::Scalar), Kernel::Scalar);
    }

    #[test]
    fn arena_recycles_and_aligns() {
        let arena = PackArena::new();
        let b = arena.take(100);
        assert!(b.len() >= 100);
        let addr0 = b.as_ptr() as usize;
        arena.put(b);
        assert_eq!(arena.pooled(), 1);
        // steady state: the same allocation comes back
        let b2 = arena.take(90);
        assert_eq!(b2.as_ptr() as usize, addr0);
        arena.put(b2);
        let mut b3 = arena.take(64);
        let s = aligned_slice(&mut b3, 64);
        assert_eq!(s.len(), 64);
        assert_eq!(s.as_ptr() as usize % 64, 0, "64-byte aligned window");
        arena.put(b3);
    }

    #[test]
    fn arena_reserve_prewarms() {
        let arena = PackArena::new();
        arena.reserve(3, 256);
        assert_eq!(arena.pooled(), 3);
        // taking reuses the reserved buffers, no growth needed
        let b = arena.take(256);
        assert!(b.len() >= 256);
        assert_eq!(arena.pooled(), 2);
        arena.put(b);
        // reserve is idempotent for already-satisfied sizes
        arena.reserve(3, 128);
        assert_eq!(arena.pooled(), 3);
    }
}
