//! Inference serving subsystem (DESIGN.md §7.5): load a
//! [`crate::native::checkpoint`] into a forward-only [`InferenceEngine`],
//! coalesce single-sample requests into GEMM-friendly batches with the
//! dynamic [`batcher`], and drive it all from synthetic clients measuring
//! throughput and latency quantiles.
//!
//! The pieces compose left to right:
//!
//! - [`engine`] — [`InferenceEngine`]: one worker's forward executor over
//!   an `Arc<Sequential>`, preallocated inference arenas, no allocation
//!   in steady state, batch-invariant by construction.
//! - [`batcher`] — [`RequestQueue`]: clients submit rows, serving workers
//!   pull coalesced batches under a `max_batch`/`max_wait` policy through
//!   [`crate::pool::run_source`].
//! - [`run_server`] — the measurement driver behind the `serve` CLI
//!   subcommand and the `serve_throughput` bench group: open-loop clients
//!   submit at a fixed offered load (qps) while closed-loop clients keep
//!   a fixed concurrency, and the [`ServeReport`] carries sustained qps
//!   plus p50/p99 request latency.
//!
//! Batching here is a latency/throughput knob only: every engine forward
//! computes each row with a fixed per-element accumulation order, so a
//! request's logits are bitwise identical whether it was served solo or
//! coalesced (`tests/serve.rs` pins this).

pub mod batcher;
pub mod engine;

pub use batcher::{
    BatcherConfig, DeadlineExceeded, QueueFull, Reply, Request, RequestQueue,
    Response,
};
pub use engine::InferenceEngine;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::json::Value;
use crate::native::Sequential;
use crate::pool;
use crate::tensor::Mat;

/// What one serving run measured; `to_json` flattens it (config included)
/// into the record the `serve` CLI writes and CI asserts on.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Requests served to completion.
    pub completed: usize,
    /// Requests the queue's admission control turned away
    /// ([`QueueFull`]; always 0 when `cfg.queue_cap == 0`).
    pub rejected: usize,
    /// Requests that out-waited their per-request deadline in the queue
    /// ([`DeadlineExceeded`]; always 0 when `cfg.request_timeout_us == 0`).
    pub timed_out: usize,
    /// First submission → last reply, seconds.
    pub wall_seconds: f64,
    /// `completed / wall_seconds` — the sustained rate (under open loop,
    /// compare against the offered load to spot saturation).
    pub throughput_qps: f64,
    /// Median queue-entry → completion latency, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile latency, milliseconds (nearest-rank).
    pub p99_ms: f64,
    /// Mean coalesced batch size over completed requests — how well the
    /// batcher amortized the forward sweeps.
    pub mean_batch: f64,
    /// The configuration the run executed under.
    pub cfg: ServeConfig,
}

impl ServeReport {
    /// Flatten the report (metrics + the config that produced them) into
    /// one JSON object.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("completed", Value::num(self.completed as f64)),
            ("rejected", Value::num(self.rejected as f64)),
            ("timed_out", Value::num(self.timed_out as f64)),
            ("wall_seconds", Value::num(self.wall_seconds)),
            ("throughput_qps", Value::num(self.throughput_qps)),
            ("p50_ms", Value::num(self.p50_ms)),
            ("p99_ms", Value::num(self.p99_ms)),
            ("mean_batch", Value::num(self.mean_batch)),
            ("max_batch", Value::num(self.cfg.max_batch as f64)),
            ("max_wait_us", Value::num(self.cfg.max_wait_us as f64)),
            ("workers", Value::num(self.cfg.workers as f64)),
            ("requests", Value::num(self.cfg.requests as f64)),
            ("offered_load", Value::num(self.cfg.offered_load)),
            ("concurrency", Value::num(self.cfg.concurrency as f64)),
            ("queue_cap", Value::num(self.cfg.queue_cap as f64)),
            (
                "request_timeout_us",
                Value::num(self.cfg.request_timeout_us as f64),
            ),
        ])
    }
}

/// Nearest-rank quantile over ascending latencies, in milliseconds
/// (0.0 for an empty run).
pub fn quantile_ms(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1].as_secs_f64() * 1e3
}

/// One serving worker's loop body: stage the coalesced batch, run one
/// forward sweep, and deliver each request's logits row to its reply slot.
fn serve_batch(batch: Vec<Request>, engine: &mut InferenceEngine) {
    let n = batch.len();
    let out_dim = engine.out_dim();
    let logits =
        engine.infer_staged(n, |r, dst| dst.copy_from_slice(&batch[r].x));
    for (r, req) in batch.iter().enumerate() {
        req.reply.fill(Response {
            id: req.id,
            logits: logits.data[r * out_dim..(r + 1) * out_dim].to_vec(),
            latency: req.enqueued.elapsed(),
            batch_size: n,
        });
    }
}

/// Run one measured serving session over `model`: a server thread pulls
/// coalesced batches off a [`RequestQueue`] into `cfg.workers` engines
/// (via [`pool::run_source`]) while synthetic clients submit
/// `cfg.requests` rows cycled from `inputs`.
///
/// Client discipline:
/// - `cfg.offered_load > 0` — **open loop**: request `i` is submitted at
///   `t0 + i / offered_load` regardless of completions, so queueing delay
///   shows up in the latency quantiles once the engine saturates.
/// - otherwise — **closed loop**: `cfg.concurrency` clients each submit,
///   wait for the reply, and repeat; the system sees a fixed number of
///   requests in flight.
///
/// `cfg.requests == 0` is a valid no-op run (empty-queue shutdown path):
/// the report comes back with `completed == 0` and zeroed quantiles.
pub fn run_server(
    model: &Arc<Sequential>,
    in_dim: usize,
    inputs: &Mat,
    cfg: &ServeConfig,
) -> ServeReport {
    assert_eq!(inputs.cols, in_dim, "request width");
    assert!(
        cfg.requests == 0 || inputs.rows > 0,
        "need at least one input row to cycle requests from"
    );
    let queue = RequestQueue::new(BatcherConfig {
        max_batch: cfg.max_batch,
        max_wait: Duration::from_micros(cfg.max_wait_us),
        queue_cap: cfg.queue_cap,
        timeout: Duration::from_micros(cfg.request_timeout_us),
    });
    let n = cfg.requests;
    let replies: Vec<Reply> = (0..n).map(|_| Reply::new()).collect();
    // admission control can turn a submit away (`QueueFull`); a rejected
    // request's reply is never filled, so the final collection sweep must
    // know to skip it
    let turned_away: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let next_req = AtomicUsize::new(0);
    let workers = cfg.workers.max(1);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let queue = &queue;
        let server = scope.spawn(move || {
            let mut engines: Vec<InferenceEngine> = (0..workers)
                .map(|_| {
                    InferenceEngine::new(Arc::clone(model), in_dim, cfg.max_batch)
                })
                .collect();
            pool::run_source(|| queue.next_batch(), &mut engines, serve_batch);
        });
        if cfg.offered_load > 0.0 {
            // open loop: a single submitter paces the arrival process
            for (i, reply) in replies.iter().enumerate() {
                let due =
                    t0 + Duration::from_secs_f64(i as f64 / cfg.offered_load);
                if let Some(wait) = due.checked_duration_since(Instant::now()) {
                    std::thread::sleep(wait);
                }
                let mut req =
                    Request::new(i as u64, inputs.row(i % inputs.rows).to_vec());
                req.reply = reply.clone();
                if queue.submit(req).is_err() {
                    turned_away[i].store(true, Ordering::Relaxed);
                }
            }
        } else {
            // closed loop: fixed in-flight concurrency
            let clients = cfg.concurrency.max(1).min(n.max(1));
            let handles: Vec<_> = (0..clients)
                .map(|_| {
                    scope.spawn(|| loop {
                        let i = next_req.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let mut req = Request::new(
                            i as u64,
                            inputs.row(i % inputs.rows).to_vec(),
                        );
                        req.reply = replies[i].clone();
                        if queue.submit(req).is_err() {
                            // no reply is coming; move on to the next id
                            turned_away[i].store(true, Ordering::Relaxed);
                            continue;
                        }
                        let _ = replies[i].wait();
                    })
                })
                .collect();
            // clients must finish submitting before the queue closes
            for h in handles {
                h.join().unwrap();
            }
        }
        queue.close();
        server.join().unwrap();
    });
    let wall = t0.elapsed().as_secs_f64();
    // every admitted request's reply is resolved by now — served, or
    // expired with `DeadlineExceeded` (the server drained the queue
    // before exiting) — so these waits never block; rejected requests
    // have no reply coming and are skipped
    let mut latencies = Vec::with_capacity(n);
    let mut batch_sum = 0usize;
    let mut rejected = 0usize;
    let mut timed_out = 0usize;
    for (i, reply) in replies.iter().enumerate() {
        if turned_away[i].load(Ordering::Relaxed) {
            rejected += 1;
            continue;
        }
        match reply.wait() {
            Ok(resp) => {
                latencies.push(resp.latency);
                batch_sum += resp.batch_size;
            }
            Err(_) => timed_out += 1,
        }
    }
    latencies.sort();
    let completed = latencies.len();
    ServeReport {
        completed,
        rejected,
        timed_out,
        wall_seconds: wall,
        throughput_qps: if wall > 0.0 { completed as f64 / wall } else { 0.0 },
        p50_ms: quantile_ms(&latencies, 0.50),
        p99_ms: quantile_ms(&latencies, 0.99),
        mean_batch: if completed > 0 {
            batch_sum as f64 / completed as f64
        } else {
            0.0
        },
        cfg: cfg.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::models;

    #[test]
    fn quantiles_use_nearest_rank() {
        let ms: Vec<Duration> =
            (1..=100).map(Duration::from_millis).collect();
        assert_eq!(quantile_ms(&ms, 0.50), 50.0);
        assert_eq!(quantile_ms(&ms, 0.99), 99.0);
        assert_eq!(quantile_ms(&ms, 1.0), 100.0);
        assert_eq!(quantile_ms(&[], 0.5), 0.0);
        let one = [Duration::from_millis(7)];
        assert_eq!(quantile_ms(&one, 0.5), 7.0);
        assert_eq!(quantile_ms(&one, 0.99), 7.0);
    }

    #[test]
    fn closed_loop_serves_every_request() {
        let model = Arc::new(models::build("mlp", 3).unwrap());
        let inputs = Mat::from_fn(4, 784, |r, c| ((r * 31 + c) % 17) as f32 * 0.1);
        let cfg = ServeConfig {
            requests: 24,
            concurrency: 3,
            max_batch: 4,
            max_wait_us: 100,
            workers: 2,
            offered_load: 0.0,
            queue_cap: 0,
            request_timeout_us: 0,
        };
        let report = run_server(&model, 784, &inputs, &cfg);
        assert_eq!(report.completed, 24);
        assert_eq!(report.rejected, 0, "unbounded queue never rejects");
        assert_eq!(report.timed_out, 0, "no deadline armed");
        assert!(report.p50_ms > 0.0);
        assert!(report.p99_ms >= report.p50_ms);
        assert!(report.mean_batch >= 1.0);
        let j = report.to_json();
        assert_eq!(j.get("completed").as_usize(), Some(24));
        assert_eq!(j.get("rejected").as_usize(), Some(0));
        assert_eq!(j.get("max_batch").as_usize(), Some(4));
        assert_eq!(j.get("queue_cap").as_usize(), Some(0));
        assert_eq!(j.get("timed_out").as_usize(), Some(0));
        assert_eq!(j.get("request_timeout_us").as_usize(), Some(0));
    }

    #[test]
    fn bounded_queue_run_completes_and_counts_rejections() {
        // open loop far above the engine's drain rate with a 1-deep queue
        // and a long batching deadline: most submits land while the queue
        // is occupied and are turned away, yet the run terminates and
        // accounts for every request either way
        let model = Arc::new(models::build("mlp", 3).unwrap());
        let inputs = Mat::from_fn(4, 784, |r, c| ((r * 31 + c) % 17) as f32 * 0.1);
        let cfg = ServeConfig {
            requests: 64,
            offered_load: 1e6,
            max_batch: 1,
            max_wait_us: 2_000,
            workers: 1,
            concurrency: 4,
            queue_cap: 1,
            request_timeout_us: 0,
        };
        let report = run_server(&model, 784, &inputs, &cfg);
        assert_eq!(report.completed + report.rejected, 64);
        assert!(report.completed >= 1, "admitted head of the burst");
        let j = report.to_json();
        assert_eq!(
            j.get("rejected").as_usize(),
            Some(report.rejected),
            "report JSON carries the rejection count"
        );
    }

    #[test]
    fn zero_request_run_is_a_clean_noop() {
        let model = Arc::new(models::build("mlp", 3).unwrap());
        let inputs = Mat::zeros(0, 784);
        let cfg = ServeConfig {
            requests: 0,
            offered_load: 400.0,
            ..ServeConfig::default()
        };
        let report = run_server(&model, 784, &inputs, &cfg);
        assert_eq!(report.completed, 0);
        assert_eq!(report.p50_ms, 0.0);
        assert_eq!(report.mean_batch, 0.0);
    }
}
