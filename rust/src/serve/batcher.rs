//! Dynamic request batching: coalesce queued single-sample requests into
//! GEMM-friendly batches under a latency deadline.
//!
//! Clients [`RequestQueue::submit`] individual rows; serving workers loop
//! on [`RequestQueue::next_batch`] (the [`crate::pool::run_source`]
//! source), which blocks until a batch is ready under the dispatch policy
//! and returns `None` only after [`RequestQueue::close`] with the queue
//! drained. Because every engine forward is batch-invariant
//! (`serve::engine`), how requests get coalesced changes latency only —
//! each request's logits are bitwise identical solo or in any batch.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batcher knobs (`--max-batch`, `--max-wait-us`, `--queue-cap`,
/// `--request-timeout-us`).
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Largest coalesced batch (engine workspaces are sized to this).
    pub max_batch: usize,
    /// Longest a queued request may wait for co-riders before its batch
    /// dispatches anyway — the bound on added queueing latency at low
    /// offered load.
    pub max_wait: Duration,
    /// Admission control: a submit that would grow the queue past this
    /// many pending requests is rejected with [`QueueFull`] instead of
    /// queueing unboundedly. `0` = unbounded.
    pub queue_cap: usize,
    /// Per-request deadline: a request still *queued* after this long is
    /// resolved with [`DeadlineExceeded`] instead of served (once
    /// dispatched into a batch it always completes). Zero = no deadline.
    pub timeout: Duration,
}

/// Typed rejection from [`RequestQueue::submit`] under admission control:
/// the queue already held `queue_cap` pending requests. The request was
/// not enqueued and its reply will never be filled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull {
    /// The bound the queue enforced when it rejected.
    pub cap: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request queue full (cap {})", self.cap)
    }
}

impl std::error::Error for QueueFull {}

/// Typed resolution for a request that out-waited its deadline in the
/// queue (`timeout` in [`BatcherConfig`]): the serving worker expired it
/// instead of serving it, and [`Reply::wait`] returns this error. The
/// waiter is released — an expired request never wedges the batcher or
/// its client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadlineExceeded {
    /// The expired request's id.
    pub id: u64,
    /// How long it had been queued when it expired.
    pub waited: Duration,
}

impl std::fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "request {} exceeded its deadline after {:?} queued",
            self.id, self.waited
        )
    }
}

impl std::error::Error for DeadlineExceeded {}

/// One queued inference request.
pub struct Request {
    /// Caller-assigned id, echoed in the [`Response`].
    pub id: u64,
    /// The input row (`in_dim` features).
    pub x: Vec<f32>,
    /// When the request entered the queue (latency origin).
    pub enqueued: Instant,
    /// Where the serving worker delivers the result.
    pub reply: Reply,
}

impl Request {
    /// Package a request now (stamps the queue-entry time and allocates a
    /// fresh reply slot).
    pub fn new(id: u64, x: Vec<f32>) -> Request {
        Request { id, x, enqueued: Instant::now(), reply: Reply::new() }
    }
}

/// One served result.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// The model's output row (`out_dim` logits).
    pub logits: Vec<f32>,
    /// Queue-entry → completion latency.
    pub latency: Duration,
    /// How many requests shared the coalesced batch (telemetry).
    pub batch_size: usize,
}

/// A one-shot completion slot: the serving worker [`Reply::fill`]s it
/// (or [`Reply::expire`]s it past its deadline — first write wins), any
/// number of readers block on [`Reply::wait`] (the resolution is cloned
/// out, not taken, so a closed-loop client and the driver's final
/// collection sweep can both read it).
#[derive(Clone, Default)]
pub struct Reply(Arc<(Mutex<Option<Result<Response, DeadlineExceeded>>>, Condvar)>);

impl Reply {
    /// An empty slot.
    pub fn new() -> Reply {
        Reply::default()
    }

    /// Deliver the response and wake every waiter.
    pub fn fill(&self, r: Response) {
        self.resolve(Ok(r));
    }

    /// Expire the request and wake every waiter.
    pub fn expire(&self, e: DeadlineExceeded) {
        self.resolve(Err(e));
    }

    /// First write wins: a request served right at its deadline keeps
    /// whichever resolution landed first.
    fn resolve(&self, r: Result<Response, DeadlineExceeded>) {
        let (slot, cv) = &*self.0;
        let mut guard = slot.lock().unwrap();
        if guard.is_none() {
            *guard = Some(r);
        }
        cv.notify_all();
    }

    /// Block until the request is resolved — with its response, or with
    /// [`DeadlineExceeded`] if it expired in the queue.
    pub fn wait(&self) -> Result<Response, DeadlineExceeded> {
        let (slot, cv) = &*self.0;
        let mut guard = slot.lock().unwrap();
        loop {
            if let Some(r) = guard.as_ref() {
                return r.clone();
            }
            guard = cv.wait(guard).unwrap();
        }
    }
}

/// The shared submission queue between clients and serving workers.
pub struct RequestQueue {
    cfg: BatcherConfig,
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    pending: VecDeque<Request>,
    closed: bool,
    timed_out: usize,
}

impl RequestQueue {
    /// An open queue under the given batching policy.
    pub fn new(cfg: BatcherConfig) -> RequestQueue {
        assert!(cfg.max_batch > 0, "batcher needs max_batch >= 1");
        RequestQueue {
            cfg,
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                closed: false,
                timed_out: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// The batching policy this queue dispatches under.
    pub fn config(&self) -> BatcherConfig {
        self.cfg
    }

    /// Enqueue one request (clients). Rejects with [`QueueFull`] when
    /// `queue_cap > 0` and that many requests are already pending (the
    /// request is dropped, not queued). Panics if the queue is closed —
    /// drivers close only after every client finished submitting.
    pub fn submit(&self, req: Request) -> Result<(), QueueFull> {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "submit after close");
        if self.cfg.queue_cap > 0 && st.pending.len() >= self.cfg.queue_cap {
            return Err(QueueFull { cap: self.cfg.queue_cap });
        }
        st.pending.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Close the queue: no new submissions; workers drain what's pending
    /// and then observe `None` (terminal, per the [`crate::pool::run_source`]
    /// contract).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Requests currently queued (telemetry).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    /// Requests expired with [`DeadlineExceeded`] so far (telemetry; the
    /// serve report's `timed_out`).
    pub fn timed_out(&self) -> usize {
        self.state.lock().unwrap().timed_out
    }

    /// Expire every pending request past its deadline. FIFO order makes
    /// the expired set a queue *prefix* (enqueue times are monotone), so
    /// this pops from the front until the first survivor. Each expired
    /// request's reply resolves with [`DeadlineExceeded`] — its waiter is
    /// released, never wedged. No-op when `timeout` is zero.
    fn expire_prefix(&self, st: &mut QueueState) {
        if self.cfg.timeout.is_zero() {
            return;
        }
        while let Some(front) = st.pending.front() {
            let waited = front.enqueued.elapsed();
            if waited < self.cfg.timeout {
                break;
            }
            let req = st.pending.pop_front().expect("front exists");
            req.reply.expire(DeadlineExceeded { id: req.id, waited });
            st.timed_out += 1;
        }
    }

    /// Dequeue the next coalesced batch (serving workers; blocking).
    ///
    /// Dispatch policy, checked in order under the queue lock:
    /// 0. requests past their per-request deadline (`timeout > 0`) are
    ///    expired with [`DeadlineExceeded`] and leave the queue;
    /// 1. `max_batch` requests pending → dispatch a full batch now;
    /// 2. queue closed → drain up to `max_batch`, or `None` when empty
    ///    (worker shutdown);
    /// 3. the *oldest* pending request has waited ≥ `max_wait` →
    ///    dispatch whatever is pending (≤ `max_batch`);
    /// 4. otherwise sleep until a submit/close wakes the worker, the
    ///    oldest request's batching deadline expires, or its request
    ///    deadline does.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        loop {
            self.expire_prefix(&mut st);
            if st.pending.len() >= self.cfg.max_batch {
                return Some(drain(&mut st.pending, self.cfg.max_batch));
            }
            if st.closed {
                if st.pending.is_empty() {
                    return None;
                }
                return Some(drain(&mut st.pending, self.cfg.max_batch));
            }
            let waited = st.pending.front().map(|r| r.enqueued.elapsed());
            match waited {
                Some(w) if w >= self.cfg.max_wait => {
                    return Some(drain(&mut st.pending, self.cfg.max_batch));
                }
                Some(w) => {
                    let mut sleep = self.cfg.max_wait - w;
                    if !self.cfg.timeout.is_zero() {
                        sleep = sleep.min(self.cfg.timeout.saturating_sub(w));
                    }
                    st = self.cv.wait_timeout(st, sleep).unwrap().0;
                }
                None => st = self.cv.wait(st).unwrap(),
            }
        }
    }
}

/// Pop up to `n` requests off the queue front, FIFO order.
fn drain(q: &mut VecDeque<Request>, n: usize) -> Vec<Request> {
    let take = n.min(q.len());
    q.drain(..take).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![id as f32])
    }

    fn queue(max_batch: usize, max_wait: Duration) -> RequestQueue {
        RequestQueue::new(BatcherConfig {
            max_batch,
            max_wait,
            queue_cap: 0,
            timeout: Duration::ZERO,
        })
    }

    #[test]
    fn full_batches_dispatch_immediately_and_fifo() {
        let q = queue(3, Duration::from_secs(60));
        for id in 0..7 {
            q.submit(req(id)).unwrap();
        }
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let b2 = q.next_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4, 5]);
        assert_eq!(q.depth(), 1);
        // the tail is under the (long) deadline; close drains it
        q.close();
        let b3 = q.next_batch().unwrap();
        assert_eq!(b3.len(), 1);
        assert!(q.next_batch().is_none());
        assert!(q.next_batch().is_none(), "None is terminal");
    }

    #[test]
    fn deadline_dispatches_partial_batches() {
        // zero deadline: any pending request dispatches without co-riders
        let q = queue(8, Duration::from_micros(0));
        q.submit(req(0)).unwrap();
        q.submit(req(1)).unwrap();
        let b = q.next_batch().unwrap();
        assert_eq!(b.len(), 2, "drains everything pending at deadline");
        q.close();
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn bounded_queue_rejects_when_full_and_admits_after_drain() {
        let q = RequestQueue::new(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(60),
            queue_cap: 3,
            timeout: Duration::ZERO,
        });
        q.submit(req(0)).unwrap();
        q.submit(req(1)).unwrap();
        q.submit(req(2)).unwrap();
        let err = q.submit(req(3)).unwrap_err();
        assert_eq!(err, QueueFull { cap: 3 });
        assert!(format!("{err}").contains("cap 3"));
        assert_eq!(q.depth(), 3, "rejected request was not enqueued");
        // draining a batch frees capacity again
        assert_eq!(q.next_batch().unwrap().len(), 2);
        q.submit(req(4)).unwrap();
        q.close();
        let tail = q.next_batch().unwrap();
        assert_eq!(tail.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
    }

    #[test]
    fn close_with_empty_queue_terminates_workers() {
        let q = queue(4, Duration::from_secs(60));
        q.close();
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn reply_slot_delivers_to_every_waiter() {
        let r = Reply::new();
        let resp = Response {
            id: 9,
            logits: vec![1.0, 2.0],
            latency: Duration::from_millis(1),
            batch_size: 4,
        };
        r.fill(resp);
        assert_eq!(r.wait().unwrap().id, 9);
        // cloned out, not taken: a second reader sees it too
        assert_eq!(r.wait().unwrap().logits, vec![1.0, 2.0]);
        // first write wins: a late expiry cannot claw back a served reply
        r.expire(DeadlineExceeded { id: 9, waited: Duration::from_secs(1) });
        assert_eq!(r.wait().unwrap().id, 9);
    }

    #[test]
    fn expired_requests_resolve_typed_without_wedging_the_queue() {
        let q = RequestQueue::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(60),
            queue_cap: 0,
            timeout: Duration::from_millis(50),
        });
        let stale = req(0);
        let stale_reply = stale.reply.clone();
        q.submit(stale).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        // a fresh co-rider: the expired prefix stops at it
        let fresh = req(1);
        let fresh_reply = fresh.reply.clone();
        q.submit(fresh).unwrap();
        q.close();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(q.timed_out(), 1);
        // the expired waiter was released with the typed error…
        let err = stale_reply.wait().unwrap_err();
        assert_eq!(err.id, 0);
        assert!(err.waited >= Duration::from_millis(50));
        assert!(format!("{err}").contains("deadline"), "{err}");
        // …and the batcher still serves what it dispatched
        fresh_reply.fill(Response {
            id: 1,
            logits: vec![],
            latency: Duration::ZERO,
            batch_size: 1,
        });
        assert_eq!(fresh_reply.wait().unwrap().id, 1);
        assert!(q.next_batch().is_none(), "queue drained clean");
    }

    #[test]
    fn blocked_worker_wakes_on_submit() {
        let q = queue(1, Duration::from_secs(60));
        std::thread::scope(|s| {
            let h = s.spawn(|| q.next_batch());
            std::thread::sleep(Duration::from_millis(10));
            q.submit(req(5)).unwrap();
            let b = h.join().unwrap().unwrap();
            assert_eq!(b[0].id, 5);
        });
    }
}
