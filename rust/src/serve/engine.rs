//! [`InferenceEngine`]: the forward-only executor serving workers run.

use std::sync::Arc;

use crate::native::checkpoint::Checkpoint;
use crate::native::{CkptError, Sequential, Workspace, WorkspaceBytes};
use crate::tensor::Mat;

/// A forward-only executor for one model: owns an inference
/// [`Workspace`] sized at `max_batch` plus a staging batch buffer, and
/// shares the (immutable) model stack via `Arc` so every serving worker
/// runs the same parameters ([`crate::native::Layer`] is `Send + Sync`).
///
/// Steady-state contract: no entry point allocates. Batches at or below
/// `max_batch` re-point the preallocated arenas
/// ([`Sequential::retarget_batch`] — `Mat::resize_to` keeps capacity),
/// and the SIMD kernels draw pack buffers from the process-wide pool the
/// workspace pre-warmed. Batch 0 is valid and yields empty logits.
///
/// Determinism: every layer's forward computes per sample with a fixed
/// per-element accumulation order (DESIGN.md §7.3), so each row's logits
/// are bitwise independent of which other rows share the batch — the
/// batch-invariance the dynamic batcher relies on (`tests/serve.rs`).
pub struct InferenceEngine {
    model: Arc<Sequential>,
    ws: Workspace,
    staging: Mat,
    in_dim: usize,
    out_dim: usize,
    max_batch: usize,
}

impl InferenceEngine {
    /// Engine serving batches of up to `max_batch` rows of `in_dim`
    /// features each.
    pub fn new(model: Arc<Sequential>, in_dim: usize, max_batch: usize) -> InferenceEngine {
        assert!(max_batch > 0, "engine needs max_batch >= 1");
        let ws = model.inference_workspace(max_batch, in_dim);
        let out_dim = *ws.dims.last().expect("non-empty stack");
        InferenceEngine {
            staging: Mat::zeros(max_batch, in_dim),
            ws,
            in_dim,
            out_dim,
            max_batch,
            model,
        }
    }

    /// Engine over a loaded checkpoint: rebuilds the registry model and
    /// refills its parameters bit-for-bit ([`Checkpoint::build_model`]).
    pub fn from_checkpoint(
        ckpt: &Checkpoint,
        in_dim: usize,
        max_batch: usize,
    ) -> Result<InferenceEngine, CkptError> {
        Ok(InferenceEngine::new(Arc::new(ckpt.build_model()?), in_dim, max_batch))
    }

    /// Largest batch this engine's arenas serve without allocating.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Input width per request row.
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Logits width per request row.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// The shared model stack.
    pub fn model(&self) -> &Arc<Sequential> {
        &self.model
    }

    /// Arena accounting of the inference workspace (no gradient arenas —
    /// the `serve_throughput` bench's memory column).
    pub fn workspace_bytes(&self) -> WorkspaceBytes {
        self.ws.workspace_bytes()
    }

    /// Batched entry point: forward `x` (`x.rows ≤ max_batch`) and return
    /// the logits (`x.rows × out_dim`). `x.rows == 0` cleanly yields an
    /// empty logits matrix.
    pub fn infer_batch(&mut self, x: &Mat) -> &Mat {
        assert!(
            x.rows <= self.max_batch,
            "batch {} exceeds engine cap {}",
            x.rows,
            self.max_batch
        );
        assert_eq!(x.cols, self.in_dim, "request width");
        self.model.retarget_batch(&mut self.ws, x.rows);
        self.model.forward(x, &mut self.ws);
        self.ws.output()
    }

    /// Coalescing entry point: stage `rows` request payloads (the batcher
    /// holds them as individual vectors) by calling `fill(r, dst)` once
    /// per row, then forward the staged batch. Returns the logits
    /// (`rows × out_dim`).
    pub fn infer_staged<F>(&mut self, rows: usize, mut fill: F) -> &Mat
    where
        F: FnMut(usize, &mut [f32]),
    {
        assert!(
            rows <= self.max_batch,
            "batch {rows} exceeds engine cap {}",
            self.max_batch
        );
        self.staging.resize_to(rows, self.in_dim);
        for r in 0..rows {
            fill(r, &mut self.staging.data[r * self.in_dim..(r + 1) * self.in_dim]);
        }
        self.model.retarget_batch(&mut self.ws, rows);
        self.model.forward(&self.staging, &mut self.ws);
        self.ws.output()
    }

    /// Single-sample entry point: logits for one request row, written
    /// into `out` (`out_dim` long).
    pub fn infer_one(&mut self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.in_dim, "request width");
        assert_eq!(out.len(), self.out_dim, "logits width");
        let logits = self.infer_staged(1, |_, dst| dst.copy_from_slice(x));
        out.copy_from_slice(logits.row(0));
    }
}
