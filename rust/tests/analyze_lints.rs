//! `uavjp-analyze` lint-pass suite (DESIGN.md §7.8): every pass fires
//! exactly once on its seeded fixture, the clean fixture fires nothing,
//! the diagnostic format is golden-pinned, the waiver grammar counts
//! well-formed allows and flags malformed ones, the RNG stream registry
//! is pairwise disjoint, and — the acceptance bar — the analyzer runs
//! **clean on the real repo tree**, so CI's `analyze` leg stays green by
//! construction.
//!
//! Fixtures live in `uavjp::analyze::fixtures` as string literals: the
//! analyzer blanks literals when it scans its own sources, so the seeded
//! violations are invisible to the self-scan.

use std::path::Path;

use uavjp::analyze::{analyze_source, analyze_tree, fixtures, Pass};
use uavjp::rng::streams;

/// Analyze a fixture under a pretend repo path and return the report.
fn run(path: &str, src: &str) -> uavjp::analyze::Report {
    analyze_source(path, src)
}

/// Assert exactly one finding of `pass` at `line`, message containing
/// `needle`.
fn assert_single(rep: &uavjp::analyze::Report, pass: Pass, line: usize, needle: &str) {
    assert_eq!(rep.findings.len(), 1, "expected exactly one finding, got: {:?}", rep.findings);
    let f = &rep.findings[0];
    assert_eq!(f.pass, pass, "wrong pass: {f}");
    assert_eq!(f.line, line, "wrong line: {f}");
    assert!(f.message.contains(needle), "message {:?} missing {needle:?}", f.message);
}

#[test]
fn clean_fixture_fires_nothing() {
    let rep = run("src/native/clean.rs", fixtures::CLEAN);
    assert!(rep.is_clean(), "clean fixture flagged: {:?}", rep.findings);
    assert!(rep.allows.is_empty());
}

#[test]
fn rng_pass_flags_undeclared_stream() {
    let rep = run("src/native/clean.rs", fixtures::RNG_UNDECLARED);
    assert_single(&rep, Pass::RngStream, 5, "undeclared RNG stream");
}

#[test]
fn rng_pass_names_the_declared_stream_it_matches() {
    let rep = run("src/native/clean.rs", fixtures::RNG_ADHOC_DECLARED);
    assert_single(&rep, Pass::RngStream, 5, "sketch-gates");
    assert!(rep.findings[0].message.contains("route through rng::streams"), "{}", rep.findings[0]);
}

#[test]
fn rng_pass_skips_the_registry_module_itself() {
    let rep = run("src/rng/streams.rs", fixtures::RNG_UNDECLARED);
    assert!(rep.is_clean(), "src/rng/ must be exempt: {:?}", rep.findings);
}

#[test]
fn unsafe_pass_confines_to_allowlist() {
    let rep = run("src/serve/engine.rs", fixtures::UNSAFE_OUTSIDE);
    assert_single(&rep, Pass::Unsafe, 3, "outside the kernel-file allowlist");
}

#[test]
fn unsafe_pass_requires_safety_comment() {
    let rep = run("src/tensor/kernels/vec.rs", fixtures::UNSAFE_NO_SAFETY);
    assert_single(&rep, Pass::Unsafe, 3, "SAFETY");
    let ok = run("src/tensor/kernels/vec.rs", fixtures::UNSAFE_JUSTIFIED);
    assert!(ok.is_clean(), "justified unsafe flagged: {:?}", ok.findings);
}

#[test]
fn det_pass_bans_hashmap_in_deterministic_modules() {
    let rep = run("src/native/clean.rs", fixtures::DET_HASHMAP);
    assert_single(&rep, Pass::Determinism, 2, "HashMap");
    // the same source outside the deterministic modules is fine
    let out = run("src/serve/engine.rs", fixtures::DET_HASHMAP);
    assert!(out.is_clean(), "serve is not a det module: {:?}", out.findings);
}

#[test]
fn det_pass_flags_unordered_reductions() {
    let rep = run("src/native/clean.rs", fixtures::DET_UNORDERED_SUM);
    assert_single(&rep, Pass::Determinism, 3, "unordered reduction");
}

#[test]
fn alloc_pass_fires_only_inside_declared_hot_fns() {
    // `step` is declared hot for src/native/trainer.rs; `evaluate` is not,
    // so only the first vec! fires.
    let rep = run("src/native/trainer.rs", fixtures::ALLOC_IN_STEP);
    assert_single(&rep, Pass::HotAlloc, 3, "steady-state function");
    assert!(rep.findings[0].message.contains("vec!"), "{}", rep.findings[0]);
}

#[test]
fn allow_comment_suppresses_and_is_counted() {
    let rep = run("src/native/trainer.rs", fixtures::ALLOC_ALLOWED);
    assert!(rep.is_clean(), "waived alloc flagged: {:?}", rep.findings);
    assert_eq!(rep.allows.get("alloc"), Some(&1), "waiver not counted");
    assert_eq!(rep.allow_summary(), "alloc: 1");
}

#[test]
fn malformed_allow_is_a_finding() {
    let rep = run("src/native/clean.rs", fixtures::ALLOW_MALFORMED);
    assert_single(&rep, Pass::AllowGrammar, 3, "malformed allow comment");
    assert!(rep.allows.is_empty(), "malformed waiver must not count");
}

/// Golden diagnostic format: `{file}:{line}: [{slug}] {message}` — the
/// CI log contract.
#[test]
fn diagnostic_format_is_stable() {
    let rep = run("src/serve/engine.rs", fixtures::UNSAFE_OUTSIDE);
    assert_eq!(
        rep.findings[0].to_string(),
        "src/serve/engine.rs:3: [unsafe] `unsafe` outside the kernel-file allowlist"
    );
    for (pass, slug) in [
        (Pass::RngStream, "rng-stream"),
        (Pass::Unsafe, "unsafe"),
        (Pass::Determinism, "determinism"),
        (Pass::HotAlloc, "hot-alloc"),
        (Pass::AllowGrammar, "allow-grammar"),
    ] {
        assert_eq!(pass.slug(), slug);
    }
}

/// The RNG stream registry's (mix, stream-range) pairs are pairwise
/// disjoint — the property that makes "route everything through the
/// registry" a collision-freedom proof rather than a convention.
#[test]
fn stream_registry_is_pairwise_disjoint() {
    assert_eq!(streams::check_disjoint(), Ok(()));
}

/// Acceptance bar: the analyzer runs clean on the real tree. Every
/// production `Pcg64::new` routes through `rng::streams`, `unsafe`
/// stays justified inside the allowlist, the deterministic modules stay
/// free of banned tokens, and the declared steady-state functions only
/// allocate under counted waivers.
#[test]
fn repo_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let rep = analyze_tree(root).expect("scan repo tree");
    assert!(
        rep.is_clean(),
        "uavjp-analyze found violations in the repo tree:\n{}",
        rep.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
    assert!(rep.files_scanned > 30, "suspiciously few files scanned");
    // the tree's waivers are all well-formed and counted
    assert!(rep.allows.get("alloc").copied().unwrap_or(0) >= 1);
}
