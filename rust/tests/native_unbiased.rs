//! Unbiasedness of the native sketched backward (Prop 2.2 i on real
//! kernels): the Monte-Carlo mean of sketched dX / dW / db over fresh gate
//! draws must match the exact backward, for both correlated (systematic)
//! and independent Bernoulli gates, across methods and budgets.
//!
//! Tolerances were calibrated against the estimator's own MC noise: with
//! p_i ≳ 0.15 and ~3000 trials the relative Frobenius deviation of the mean
//! sits near 1.5–3%, so the 12% bar gives ≳4× headroom while still catching
//! any systematic bias (a missing 1/p rescale shows up at O(1)).

use uavjp::native::sketched_linear_backward;
use uavjp::ptest::{check, gen};
use uavjp::rng::Pcg64;
use uavjp::tensor::{dense_backward, Mat};

fn mc_mean_matches_exact(
    method: &str,
    budget: f64,
    b: usize,
    dout: usize,
    din: usize,
    trials: usize,
    data_seed: u64,
) -> Result<(), String> {
    let mut rng = Pcg64::new(data_seed, 0);
    let g = Mat::from_fn(b, dout, |_, _| rng.gaussian() as f32);
    let x = Mat::from_fn(b, din, |_, _| rng.gaussian() as f32);
    let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32);
    let (dx_exact, dw_exact) = dense_backward(&g, &x, &w);
    let db_exact: Vec<f64> = (0..dout)
        .map(|j| (0..b).map(|i| g.at(i, j) as f64).sum())
        .collect();

    let mut acc_dx = vec![0.0f64; b * din];
    let mut acc_dw = vec![0.0f64; dout * din];
    let mut acc_db = vec![0.0f64; dout];
    let mut gate_rng = Pcg64::new(data_seed ^ 0x5eed, 1);
    for _ in 0..trials {
        let (dw, db, dx) = sketched_linear_backward(
            &g, &x, &w, method, budget, &mut gate_rng, true,
        );
        for (a, v) in acc_dw.iter_mut().zip(&dw.data) {
            *a += *v as f64;
        }
        for (a, v) in acc_db.iter_mut().zip(&db) {
            *a += *v as f64;
        }
        for (a, v) in acc_dx.iter_mut().zip(&dx.expect("asked for dx").data) {
            *a += *v as f64;
        }
    }
    let t = trials as f64;
    let rel = |acc: &[f64], exact: &[f64]| -> f64 {
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for (a, e) in acc.iter().zip(exact) {
            let d = a / t - e;
            err += d * d;
            norm += e * e;
        }
        (err / norm.max(1e-12)).sqrt()
    };
    let dw64: Vec<f64> = dw_exact.data.iter().map(|&v| v as f64).collect();
    let dx64: Vec<f64> = dx_exact.data.iter().map(|&v| v as f64).collect();
    let (edw, edx, edb) = (
        rel(&acc_dw, &dw64),
        rel(&acc_dx, &dx64),
        rel(&acc_db, &db_exact),
    );
    let tol = 0.12;
    if edw > tol || edx > tol || edb > tol {
        return Err(format!(
            "{method} p={budget}: MC mean deviates — dW {edw:.4}, dX {edx:.4}, db {edb:.4} (tol {tol})"
        ));
    }
    Ok(())
}

#[test]
fn correlated_gates_unbiased_l1() {
    mc_mean_matches_exact("l1", 0.4, 8, 12, 6, 3000, 3).unwrap();
}

#[test]
fn independent_gates_unbiased_l1_ind() {
    mc_mean_matches_exact("l1_ind", 0.4, 8, 12, 6, 3000, 4).unwrap();
}

#[test]
fn independent_gates_unbiased_per_column() {
    // uniform keep-probability p = budget, independent gates
    mc_mean_matches_exact("per_column", 0.5, 8, 12, 6, 3000, 5).unwrap();
}

#[test]
fn correlated_gates_unbiased_ds_scores() {
    mc_mean_matches_exact("ds", 0.5, 8, 12, 6, 3000, 6).unwrap();
}

#[test]
fn unbiased_across_random_shapes_and_budgets() {
    // property-style: random small layer shapes and budgets, fewer trials,
    // both gate families via the method name
    check(
        7,
        4,
        |rng| {
            let b = gen::usize_in(rng, 4, 10);
            let dout = gen::usize_in(rng, 6, 16);
            (b, dout)
        },
        |&(b, dout)| {
            let din = 5usize;
            for (method, budget) in [("l1", 0.45), ("l1_ind", 0.45)] {
                mc_mean_matches_exact(
                    method,
                    budget,
                    b,
                    dout,
                    din,
                    2500,
                    (b * 31 + dout) as u64,
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn sketched_mean_differs_from_exact_without_rescale_sanity() {
    // negative control for the tolerance: a deliberately biased estimator
    // (keep columns but skip the 1/p rescale) must FAIL the same bar,
    // proving the test has teeth.
    let (b, dout, din, trials) = (8usize, 12usize, 6usize, 1500usize);
    let mut rng = Pcg64::new(9, 0);
    let g = Mat::from_fn(b, dout, |_, _| rng.gaussian() as f32);
    let x = Mat::from_fn(b, din, |_, _| rng.gaussian() as f32);
    let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32);
    let (_, dw_exact) = dense_backward(&g, &x, &w);
    let mut acc = vec![0.0f64; dout * din];
    let mut gate_rng = Pcg64::new(10, 1);
    for _ in 0..trials {
        let (dw, _, _) = sketched_linear_backward(
            &g, &x, &w, "l1", 0.4, &mut gate_rng, false,
        );
        // undo the rescale imperfectly: halve (simulates a biased estimator)
        for (a, v) in acc.iter_mut().zip(&dw.data) {
            *a += (*v as f64) * 0.5;
        }
    }
    let t = trials as f64;
    let mut err = 0.0f64;
    let mut norm = 0.0f64;
    for (a, e) in acc.iter().zip(&dw_exact.data) {
        let d = a / t - *e as f64;
        err += d * d;
        norm += (*e as f64) * (*e as f64);
    }
    let rel = (err / norm).sqrt();
    assert!(rel > 0.12, "biased control passed the bar: {rel}");
}
