//! Fault-injection and fault-tolerance suite (DESIGN.md §7.7).
//!
//! The headline contracts: (1) chaos runs are **deterministic** — the
//! fault plan draws from its own PCG64 stream, so a spec replays
//! bit-for-bit and stays replica-count invariant; (2) injected lane
//! dropout is **unbiased** — survivors rescaled by `1/(1-p)` every armed
//! step reproduce the exact reduce in MC mean (the unrescaled control
//! fails the same bar); (3) a run killed at step k and `--resume`d from
//! its periodic checkpoint reconstructs the uninterrupted trajectory
//! **bitwise** (params, optimizer slots and every RNG stream restore;
//! the batch stream fast-forwards by replay); (4) torn checkpoint
//! writes never corrupt the live file (atomic tmp+rename); (5) poisoned
//! gradients are skipped, then bail typed after five in a row; (6) a
//! panicking replica worker degrades the reduce instead of taking the
//! run down; (7) serve-side deadlines expire queued requests with a
//! typed error without wedging the batcher.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use uavjp::config::{Preset, ServeConfig, TrainConfig};
use uavjp::faults::FaultPlan;
use uavjp::native::{checkpoint, models, NativeTrainer, Sequential};
use uavjp::replicate::{ReplicaGroup, StepFaults};
use uavjp::rng::Pcg64;
use uavjp::serve::run_server;
use uavjp::tensor::kernels::{self, Kernel, KernelKind};
use uavjp::tensor::Mat;

/// `set_kernel` / `set_threads` are process-global knobs; tests that pin
/// a kernel kind for bitwise comparisons hold this lock (same discipline
/// as `tests/replicate.rs`).
static THREAD_KNOB: Mutex<()> = Mutex::new(());

/// Pin the kernel knob; the guard restores the previous resolution on
/// drop, including on panic.
fn pin_kernel(kind: KernelKind) -> KernelGuard {
    let prev = kernels::active();
    kernels::set_kernel(kind);
    KernelGuard(match prev {
        Kernel::Scalar => KernelKind::Scalar,
        _ => KernelKind::Simd,
    })
}

struct KernelGuard(KernelKind);

impl Drop for KernelGuard {
    fn drop(&mut self) {
        kernels::set_kernel(self.0);
    }
}

/// Unique-per-test temp path (tests share one process).
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("uavjp_fault_{}_{name}", std::process::id()))
}

/// Short run sized for trajectory comparison: 12 steps, batch 32 (4 rows
/// per lane on the 8-lane grid when replicated).
fn chaos_cfg(model: &str, spec: &str) -> TrainConfig {
    let mut cfg = Preset::Smoke.base(model).unwrap();
    cfg.method = "l1".into();
    cfg.budget = 0.25;
    cfg.act_policy = "exact".into(); // decouple from the UAVJP_ACTPOLICY env
    cfg.train_size = 256;
    cfg.test_size = 64;
    cfg.batch = 32;
    cfg.steps = 12;
    cfg.eval_every = 12;
    cfg.fault_spec = spec.into();
    cfg
}

fn losses_of(cfg: TrainConfig) -> Vec<f64> {
    NativeTrainer::new(cfg).unwrap().run().unwrap().losses
}

/// One inference forward over the model's synthetic test split, logits
/// flattened out — the bitwise fingerprint resume comparisons use.
fn final_logits(trainer: &NativeTrainer) -> Vec<f32> {
    let (_, test) = trainer.datasets();
    let n = 5usize.min(test.n);
    let mut x = Mat::zeros(n, test.dim);
    x.data.copy_from_slice(&test.x[..n * test.dim]);
    let model = trainer.model();
    let mut ws = model.inference_workspace(n, test.dim);
    model.forward(&x, &mut ws);
    ws.output().data.clone()
}

#[test]
fn chaos_runs_replay_bit_identically_and_stay_replica_invariant() {
    // the fault stream is disjoint from every training stream, so a
    // lane-drop spec is a pure function of (seed, spec): same losses on
    // a repeat run and at every replica count — while still actually
    // changing the trajectory relative to the fault-free run
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let with = |r: usize, spec: &str| {
        let mut cfg = chaos_cfg("mlp", spec);
        cfg.replicas = r;
        losses_of(cfg)
    };
    let chaos = with(1, "lane_drop@p=0.2");
    assert!(chaos.iter().all(|l| l.is_finite()), "chaos run diverged");
    assert_eq!(chaos, with(1, "lane_drop@p=0.2"), "replay drifts");
    assert_eq!(chaos, with(2, "lane_drop@p=0.2"), "replica count leaks in");
    assert_eq!(chaos, with(4, "lane_drop@p=0.2"), "replica count leaks in");
    let clean = with(1, "");
    // dropped lanes never touch the loss (the forward ran; only the
    // gradient wire dropped): step 0 sees identical params either way
    assert_eq!(chaos[0], clean[0], "lane drops must not perturb the loss");
    assert_ne!(chaos, clean, "armed lane dropout must change the trajectory");
}

#[test]
fn injected_lane_dropout_compensation_is_unbiased() {
    // MC mean of the lane-dropped, 1/(1-p)-rescaled reduce over fresh
    // drop masks must match the exact (fault-free) reduce. Margin
    // calibration via python/tools/native_sim.py: with the mlp at init
    // on this batch, Σ‖g_l‖²/‖g‖² ≈ 0.93 (lane gradients are near
    // orthogonal), so at p=0.3, T=400 the expected relative deviation
    // is sqrt(p/(1-p)·0.93/400) ≈ 0.032 and 0.10 is a ≈3σ bar — while
    // the unrescaled control sits at ≈ p = 0.3, failing it decisively.
    let mut cfg = chaos_cfg("mlp", "");
    cfg.replicas = 4;
    cfg.location = "none".into(); // no gate noise: the exact reduce is fixed
    let master = models::build("mlp", 0).unwrap();
    let mut ws = master.workspace(cfg.batch, 784);

    let mut rng = Pcg64::new(41, 7);
    let x = Mat::from_fn(cfg.batch, 784, |_, _| rng.gaussian() as f32);
    let y: Vec<i32> =
        (0..cfg.batch).map(|_| (rng.next_u64() % 10) as i32).collect();

    let mut group = ReplicaGroup::new(&cfg, &master).unwrap();
    group.step(&master, &x, &y, &mut ws.grad_slots);
    let exact: Vec<f64> = ws
        .grad_slots
        .slots
        .iter()
        .flat_map(|s| s.iter().map(|&v| v as f64).collect::<Vec<_>>())
        .collect();

    let plan = FaultPlan::parse("lane_drop@p=0.3").unwrap();
    let mut frng = FaultPlan::stream(0);
    let trials = 400usize;
    let mut acc = vec![0.0f64; exact.len()];
    for _ in 0..trials {
        let faults = StepFaults {
            drops: plan.draw_lane_drops(&mut frng),
            gain: plan.lane_gain(),
            panic_replica: None,
        };
        group
            .step_faulted(&master, &x, &y, &mut ws.grad_slots, &faults)
            .unwrap();
        let mut k = 0usize;
        for slot in &ws.grad_slots.slots {
            for &v in slot {
                acc[k] += v as f64;
                k += 1;
            }
        }
    }
    let rel_of = |scale: f64| -> f64 {
        let t = trials as f64;
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for (a, e) in acc.iter().zip(&exact) {
            let d = scale * a / t - e;
            err += d * d;
            norm += e * e;
        }
        (err / norm.max(1e-12)).sqrt()
    };
    let rel = rel_of(1.0);
    assert!(rel < 0.10, "compensated lane dropout deviates: {rel}");
    // negative control: an estimator missing the 1/(1-p) rescale keeps
    // only the surviving (1-p) fraction in expectation; simulate it by
    // scaling the compensated mean back down — it must fail the same
    // bar, proving the margin has teeth
    let biased = rel_of(1.0 - 0.3);
    assert!(biased > 0.10, "unrescaled control passed the bar: {biased}");
}

#[test]
fn killed_and_resumed_runs_match_uninterrupted_bitwise() {
    // kill@step=7 executes steps 0..=7; --ckpt-every 4 leaves a step-8
    // checkpoint (saved before the kill fires); resuming it replays the
    // batch stream past step 8 and restores params / optimizer slots /
    // every RNG stream — so the tail losses, the final eval and the
    // final logits are all bitwise identical to the uninterrupted run.
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    for kind in [KernelKind::Scalar, KernelKind::Simd] {
        let _restore = pin_kernel(kind);
        for (model, optimizer) in [("mlp", "momentum"), ("vit", "adam")] {
            let base = || {
                let mut cfg = chaos_cfg(model, "");
                cfg.optimizer = optimizer.into();
                cfg
            };
            let mut control = NativeTrainer::new(base()).unwrap();
            let control_curve = control.run().unwrap();

            let path = tmp(&format!("resume_{model}_{kind:?}"));
            let mut cfg = base();
            cfg.fault_spec = "kill@step=7".into();
            cfg.ckpt_every = 4;
            cfg.ckpt_path = path.to_str().unwrap().into();
            let err =
                NativeTrainer::new(cfg).unwrap().run().unwrap_err();
            assert!(
                format!("{err}").contains("injected kill after step 7"),
                "{model}/{kind:?}: {err}"
            );

            let mut cfg = base();
            cfg.resume = path.to_str().unwrap().into();
            let mut resumed = NativeTrainer::new(cfg).unwrap();
            assert_eq!(resumed.start_step(), 8, "{model}/{kind:?}");
            let resumed_curve = resumed.run().unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(
                resumed_curve.losses,
                control_curve.losses[8..],
                "{model}/{kind:?}: resumed tail losses drift"
            );
            assert_eq!(
                resumed_curve.evals, control_curve.evals,
                "{model}/{kind:?}: resumed final eval drifts"
            );
            assert_eq!(
                final_logits(&resumed),
                final_logits(&control),
                "{model}/{kind:?}: resumed parameters drift"
            );
        }
    }
}

#[test]
fn resume_is_bitwise_under_replicas_and_armed_lane_dropout() {
    // the stochastic case: lane dropout stays armed across the kill, so
    // the resumed run's fault stream must restart mid-sequence (raw-word
    // restore), and the per-lane gate streams must restore onto the
    // lane-framed grid — both replica-count independent
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let base = |spec: &str| {
        let mut cfg = chaos_cfg("mlp", spec);
        cfg.replicas = 2;
        cfg
    };
    let mut control = NativeTrainer::new(base("lane_drop@p=0.2")).unwrap();
    let control_curve = control.run().unwrap();

    let path = tmp("resume_dp");
    let mut cfg = base("lane_drop@p=0.2,kill@step=7");
    cfg.ckpt_every = 4;
    cfg.ckpt_path = path.to_str().unwrap().into();
    NativeTrainer::new(cfg).unwrap().run().unwrap_err();

    let mut cfg = base("lane_drop@p=0.2");
    cfg.resume = path.to_str().unwrap().into();
    cfg.replicas = 4; // lane-framed state resumes at any replica count
    let mut resumed = NativeTrainer::new(cfg).unwrap();
    let resumed_curve = resumed.run().unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(resumed_curve.losses, control_curve.losses[8..]);
    assert_eq!(final_logits(&resumed), final_logits(&control));
}

#[test]
fn torn_periodic_checkpoint_never_corrupts_resume() {
    // ckpt_truncate@step=4 tears the step-4 periodic save mid-write
    // (half the bytes land in `<path>.tmp`, no rename) and kill@step=3
    // dies right after — exactly a crash during checkpointing. The live
    // file still holds the intact step-2 checkpoint, and resuming it
    // reconstructs the uninterrupted run bitwise.
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let mut control = NativeTrainer::new(chaos_cfg("mlp", "")).unwrap();
    let control_curve = control.run().unwrap();

    let path = tmp("torn");
    let mut cfg = chaos_cfg("mlp", "ckpt_truncate@step=4,kill@step=3");
    cfg.ckpt_every = 2;
    cfg.ckpt_path = path.to_str().unwrap().into();
    let err = NativeTrainer::new(cfg).unwrap().run().unwrap_err();
    assert!(format!("{err}").contains("injected kill"), "{err}");

    // the torn tmp file is on disk and truncated; the live file is not
    let torn = checkpoint::tmp_path(&path);
    assert!(matches!(
        checkpoint::load(&torn).unwrap_err(),
        checkpoint::CkptError::Truncated { .. }
    ));
    std::fs::remove_file(&torn).unwrap();
    let ckpt = checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.train.as_ref().unwrap().step, 2, "surviving save");

    let mut cfg = chaos_cfg("mlp", "");
    cfg.resume = path.to_str().unwrap().into();
    let mut resumed = NativeTrainer::new(cfg).unwrap();
    assert_eq!(resumed.start_step(), 2);
    let resumed_curve = resumed.run().unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(resumed_curve.losses, control_curve.losses[2..]);
    assert_eq!(final_logits(&resumed), final_logits(&control));
}

#[test]
fn resume_rejects_mismatched_checkpoints_loudly() {
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    // a param-only (v1) checkpoint has no train state to resume
    let v1 = tmp("v1");
    let model = models::build("mlp", 0).unwrap();
    checkpoint::save(&v1, "mlp", 0, &model).unwrap();
    let mut cfg = chaos_cfg("mlp", "");
    cfg.resume = v1.to_str().unwrap().into();
    let err = NativeTrainer::new(cfg).unwrap_err();
    assert!(format!("{err}").contains("param-only"), "{err}");
    std::fs::remove_file(&v1).unwrap();

    // a resumable checkpoint written under one optimizer cannot silently
    // seed another's slots
    let v2 = tmp("v2");
    let mut cfg = chaos_cfg("mlp", "");
    cfg.optimizer = "momentum".into();
    cfg.steps = 2;
    cfg.eval_every = 2;
    let mut t = NativeTrainer::new(cfg).unwrap();
    t.run().unwrap();
    t.save_checkpoint(&v2).unwrap();
    let mut cfg = chaos_cfg("mlp", "");
    cfg.optimizer = "adam".into();
    cfg.resume = v2.to_str().unwrap().into();
    let err = NativeTrainer::new(cfg).unwrap_err();
    assert!(format!("{err}").contains("optimizer mismatch"), "{err}");
    // ... nor can it resume a different registry model
    let mut cfg = chaos_cfg("vit", "");
    cfg.resume = v2.to_str().unwrap().into();
    let err = NativeTrainer::new(cfg).unwrap_err();
    assert!(format!("{err}").contains("this run trains"), "{err}");
    // ... and a plain-run checkpoint cannot restore lane streams
    let mut cfg = chaos_cfg("mlp", "");
    cfg.optimizer = "momentum".into();
    cfg.resume = v2.to_str().unwrap().into();
    cfg.replicas = 2;
    let err = NativeTrainer::new(cfg).unwrap_err();
    assert!(format!("{err}").contains("plain run"), "{err}");
    std::fs::remove_file(&v2).unwrap();
}

#[test]
fn poisoned_gradients_are_skipped_then_bail_typed() {
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    // one poisoned step: the update is skipped (counted), the loss stays
    // finite, and the run completes — diverging from the clean
    // trajectory only after the skipped step
    let clean = losses_of(chaos_cfg("mlp", ""));
    let mut t =
        NativeTrainer::new(chaos_cfg("mlp", "nan_grad@step=3")).unwrap();
    let curve = t.run().unwrap();
    assert_eq!(t.steps_skipped(), 1);
    assert!(curve.losses.iter().all(|l| l.is_finite()));
    assert_eq!(curve.losses[..=3], clean[..=3], "loss precedes the poison");
    assert_ne!(curve.losses[4..], clean[4..], "a skipped step must show");

    // persistent poison: five consecutive skips bail with the typed
    // NonFiniteLoss instead of silently burning the step budget
    let mut t =
        NativeTrainer::new(chaos_cfg("mlp", "nan_grad@from=2")).unwrap();
    let err = t.run().unwrap_err();
    assert_eq!(t.steps_skipped(), 5);
    let msg = format!("{err}");
    assert!(
        msg.contains("5 consecutive steps") && msg.contains("diverged"),
        "{msg}"
    );
}

#[test]
fn a_panicking_replica_degrades_the_step_instead_of_the_run() {
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let mut cfg = chaos_cfg("mlp", "worker_panic@step=3");
    cfg.replicas = 2;
    let mut t = NativeTrainer::new(cfg).unwrap();
    let curve = t.run().unwrap();
    assert!(curve.losses.iter().all(|l| l.is_finite()));
    let stats = t.exchange_stats().unwrap();
    // replica 0 owns 4 of the 8 lanes at --replicas 2; its panic drops
    // exactly those, on exactly one step
    assert_eq!(stats.lanes_dropped, 4);
    assert_eq!(stats.steps_degraded, 1);
}

#[test]
fn serve_deadlines_expire_typed_without_wedging_the_batcher() {
    // max_batch 16 with 4 in flight and a 50 ms coalesce window means no
    // dispatch trigger fires before the 1 µs deadline: every request
    // expires in the queue with a typed DeadlineExceeded, the closed
    // loop keeps cycling (no wedge), and the report accounts for every
    // request exactly once
    let model = Arc::new(models::build("mlp", 2).unwrap());
    let x = {
        let mut rng = Pcg64::new(5, 9);
        Mat::from_fn(4, 784, |_, _| rng.gaussian() as f32)
    };
    let cfg = ServeConfig {
        requests: 12,
        concurrency: 4,
        max_batch: 16,
        max_wait_us: 50_000,
        workers: 1,
        offered_load: 0.0,
        queue_cap: 0,
        request_timeout_us: 1,
    };
    let report = run_server(&model, 784, &x, &cfg);
    assert!(report.timed_out > 0, "no request expired");
    assert_eq!(
        report.completed + report.timed_out + report.rejected,
        12,
        "every request must resolve exactly once"
    );
    assert_eq!(
        report.to_json().get("timed_out").as_usize(),
        Some(report.timed_out)
    );
}

fn model_forward_fingerprint(model: &Sequential, x: &Mat) -> Vec<f32> {
    let mut ws = model.inference_workspace(x.rows, x.cols);
    model.forward(x, &mut ws);
    ws.output().data.clone()
}

#[test]
fn periodic_checkpoints_stay_serveable() {
    // the v2 train state rides behind the v1 payload: a periodic
    // checkpoint loads as a serving artifact too, and rebuilds a model
    // whose forward matches the trainer's at the save point
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let path = tmp("serveable");
    let mut cfg = chaos_cfg("mlp", "kill@step=7");
    cfg.ckpt_every = 8;
    cfg.ckpt_path = path.to_str().unwrap().into();
    let mut t = NativeTrainer::new(cfg).unwrap();
    t.run().unwrap_err(); // the injected kill, right after the step-8 save
    let ckpt = checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let served = ckpt.build_model().unwrap();
    let (_, test) = t.datasets();
    let mut x = Mat::zeros(4, test.dim);
    x.data.copy_from_slice(&test.x[..4 * test.dim]);
    assert_eq!(
        model_forward_fingerprint(&served, &x),
        model_forward_fingerprint(t.model(), &x),
        "a periodic checkpoint must serve the params it froze"
    );
}
