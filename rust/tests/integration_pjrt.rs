//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These cross-validate the two language implementations: the rust-native
//! sketch math (rust/src/sketch) must agree with the jax implementation
//! compiled into the `micro_*` artifacts, and the full train/eval/init
//! artifacts must compose into a working training loop.
//!
//! All tests skip gracefully when `artifacts/` hasn't been built (run
//! `make artifacts` first); CI treats missing artifacts as a failure via
//! `make test`. The whole file is gated on the `pjrt` cargo feature — the
//! default (native-only) build compiles none of it (DESIGN.md §7).

#![cfg(feature = "pjrt")]

use uavjp::config::{Preset, TrainConfig};
use uavjp::coordinator::trainer::layer_mask;
use uavjp::coordinator::Trainer;
use uavjp::runtime::{HostTensor, Runtime};
use uavjp::sketch;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts not built — skipping integration test");
        return None;
    }
    Some(Runtime::open("artifacts").expect("open runtime"))
}

#[test]
fn micro_pstar_matches_native() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("micro_pstar").expect("load micro_pstar");
    let w: Vec<f32> = (1..=64).map(|i| (i * i) as f32).collect();
    for r in [4.0f32, 12.0, 40.0] {
        let out = exe
            .run(&[
                HostTensor::F32(w.clone(), vec![64]),
                HostTensor::scalar_f32(r),
            ])
            .expect("run");
        let jax_p = out[0].as_f32().unwrap();
        let native_p = sketch::pstar_from_weights(&w, r as f64);
        for (a, b) in jax_p.iter().zip(&native_p) {
            assert!(
                (a - b).abs() < 5e-3,
                "pstar mismatch at r={r}: jax {a} vs native {b}"
            );
        }
    }
}

#[test]
fn micro_corr_sample_exact_count_and_unbiased() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("micro_corr_sample").expect("load");
    let p = vec![0.25f32; 64]; // Σp = 16
    let trials = 200;
    let mut freq = vec![0.0f64; 64];
    for t in 0..trials {
        let out = exe
            .run(&[
                HostTensor::U32(vec![11, t as u32], vec![2]),
                HostTensor::F32(p.clone(), vec![64]),
            ])
            .expect("run");
        let z = out[0].as_f32().unwrap();
        let count: f32 = z.iter().sum();
        assert!(
            (count - 16.0).abs() <= 1.0,
            "trial {t}: selected {count}, want 16"
        );
        for (f, &zi) in freq.iter_mut().zip(z) {
            *f += zi as f64;
        }
    }
    for f in &freq {
        let emp = f / trials as f64;
        assert!((emp - 0.25).abs() < 0.12, "marginal {emp} far from 0.25");
    }
}

#[test]
fn micro_sketch_bwd_matches_native_tensor_math() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("micro_sketch_bwd").expect("load");
    let (b, dout, din) = (32usize, 64usize, 48usize);
    let mut rng = uavjp::rng::Pcg64::new(3, 0);
    let g: Vec<f32> = (0..b * dout).map(|_| rng.gaussian() as f32).collect();
    let x: Vec<f32> = (0..b * din).map(|_| rng.gaussian() as f32).collect();
    let w: Vec<f32> = (0..dout * din).map(|_| rng.gaussian() as f32).collect();
    let colinv: Vec<f32> = (0..dout).map(|_| rng.f32() + 0.5).collect();
    let rowinv: Vec<f32> = (0..b).map(|_| rng.f32() + 0.5).collect();
    let out = exe
        .run(&[
            HostTensor::F32(g.clone(), vec![b, dout]),
            HostTensor::F32(colinv.clone(), vec![dout]),
            HostTensor::F32(rowinv.clone(), vec![b]),
            HostTensor::F32(x.clone(), vec![b, din]),
            HostTensor::F32(w.clone(), vec![dout, din]),
        ])
        .expect("run");
    // native reference with the tensor substrate
    let gm = uavjp::tensor::Mat { rows: b, cols: dout, data: g };
    let mut ghat = gm.clone();
    for i in 0..b {
        for j in 0..dout {
            ghat.data[i * dout + j] *= colinv[j] * rowinv[i];
        }
    }
    let xm = uavjp::tensor::Mat { rows: b, cols: din, data: x };
    let wm = uavjp::tensor::Mat { rows: dout, cols: din, data: w };
    let (dx, dw) = uavjp::tensor::dense_backward(&ghat, &xm, &wm);
    let kdx = out[0].as_f32().unwrap();
    let kdw = out[1].as_f32().unwrap();
    let kdb = out[2].as_f32().unwrap();
    for (a, b_) in kdx.iter().zip(&dx.data) {
        assert!((a - b_).abs() < 1e-3, "dx mismatch {a} vs {b_}");
    }
    for (a, b_) in kdw.iter().zip(&dw.data) {
        assert!((a - b_).abs() < 1e-3, "dw mismatch {a} vs {b_}");
    }
    for j in 0..dout {
        let db_j: f32 = (0..b).map(|i| ghat.data[i * dout + j]).sum();
        assert!((kdb[j] - db_j).abs() < 1e-3);
    }
}

#[test]
fn training_reduces_loss_mlp_l1() {
    let Some(rt) = runtime() else { return };
    let mut cfg: TrainConfig = Preset::Smoke.base("mlp").unwrap();
    cfg.method = "l1".into();
    cfg.budget = 0.2;
    cfg.steps = 60;
    cfg.eval_every = 60;
    let trainer = Trainer::new(&rt, cfg).expect("trainer");
    let curve = trainer.run().expect("run");
    let first = curve.losses[0];
    let last = curve.tail_loss(10).unwrap();
    assert!(last < first * 0.8, "loss {first} → {last} did not decrease");
    assert!(curve.final_acc().unwrap() > 0.3, "acc too low");
}

#[test]
fn disabled_sketch_matches_baseline_trajectory() {
    // location="none" must make any sketched artifact numerically follow
    // the baseline artifact exactly (same seed ⇒ same batches ⇒ same loss).
    let Some(rt) = runtime() else { return };
    let mut cfg: TrainConfig = Preset::Smoke.base("mlp").unwrap();
    cfg.steps = 12;
    cfg.eval_every = 12;
    cfg.method = "per_column".into();
    cfg.budget = 0.1;
    cfg.location = "none".into();
    let sketched = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
    cfg.method = "baseline".into();
    let baseline = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    for (a, b) in sketched.losses.iter().zip(&baseline.losses) {
        assert!(
            (a - b).abs() < 1e-4 * (1.0 + a.abs()),
            "trajectories diverged: {a} vs {b}"
        );
    }
}

#[test]
fn determinism_same_seed_same_curve() {
    let Some(rt) = runtime() else { return };
    let mut cfg: TrainConfig = Preset::Smoke.base("mlp").unwrap();
    cfg.method = "l1".into();
    cfg.budget = 0.2;
    cfg.steps = 10;
    cfg.eval_every = 10;
    let c1 = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
    let c2 = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert_eq!(c1.losses, c2.losses, "same seed must give identical curves");
}

#[test]
fn eval_artifact_counts_correctly() {
    let Some(rt) = runtime() else { return };
    let mut cfg: TrainConfig = Preset::Smoke.base("mlp").unwrap();
    cfg.method = "baseline".into();
    cfg.test_size = 256;
    let trainer = Trainer::new(&rt, cfg).unwrap();
    let state = trainer.init_state().unwrap();
    let (_, test) = trainer.datasets().unwrap();
    let (loss, acc) = trainer.evaluate(&state, &test).unwrap();
    // fresh random init on 10 classes: acc near chance, loss near ln(10)
    assert!(acc < 0.35, "untrained acc suspicious: {acc}");
    assert!((loss - 2.302).abs() < 1.0, "untrained loss suspicious: {loss}");
}

#[test]
fn fig4_layer_masks_affect_only_selected_layers() {
    let Some(rt) = runtime() else { return };
    // first-layer-only sketching must differ from all-layer sketching
    let mut cfg: TrainConfig = Preset::Smoke.base("mlp").unwrap();
    cfg.method = "per_column".into();
    cfg.budget = 0.05;
    cfg.steps = 15;
    cfg.eval_every = 15;
    cfg.location = "first".into();
    let first = Trainer::new(&rt, cfg.clone()).unwrap().run().unwrap();
    cfg.location = "all".into();
    let all = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert_ne!(first.losses, all.losses);
    let _ = layer_mask("first", 3).unwrap();
}

#[test]
fn manifest_covers_every_figure_dependency() {
    let Some(rt) = runtime() else { return };
    // every artifact the experiment registry references must exist
    let needed = [
        "train_mlp_l1",
        "train_mlp_l1_ind",
        "train_mlp_per_element",
        "train_mlp_per_column",
        "train_mlp_per_sample",
        "train_mlp_l2",
        "train_mlp_var",
        "train_mlp_ds",
        "train_mlp_rcs",
        "train_mlp_gsv",
        "train_mlp_gsv_sq",
        "train_vit_l1",
        "train_vit_ds",
        "train_bagnet_l1",
        "train_bagnet_ds",
        "grads_mlp_baseline",
        "grads_mlp_l1",
        "grads_mlp_rcs",
        "eval_mlp",
        "eval_vit",
        "eval_bagnet",
        "init_mlp",
        "init_vit",
        "init_bagnet",
    ];
    for name in needed {
        assert!(rt.manifest.get(name).is_some(), "missing artifact {name}");
    }
}
