//! Runtime verification of the §7.2 zero-steady-state-allocation
//! contract, the dynamic half of what `uavjp-analyze`'s `hot-alloc` pass
//! checks statically: a counting `#[global_allocator]` wraps the system
//! allocator and pins **zero** heap allocations in
//!
//! 1. a steady-state plain train step (after warmup), under both the
//!    scalar and the simd kernel, on a sketched kept-policy config so
//!    the sparse backward kernels and the kept-column activation stash
//!    are on the measured path, and
//! 2. a steady-state `InferenceEngine::infer_batch` call,
//!
//! with an intentionally-allocating negative control proving the counter
//! has teeth. Allocation counts are tracked per thread (the test harness
//! runs other suites concurrently in the same process), so the measured
//! runs pin `threads = 1`: every kernel-pool primitive then executes
//! inline on the caller thread and nothing on the hot path escapes the
//! counter.
//!
//! Warmup is what makes the contract meaningful: the first steps grow
//! the `PackArena` pools, the optimizer slot buffers and the reused gate
//! buffers to their high-water marks. The correlated gate sampler keeps
//! exactly `round(budget · dout)` columns every draw (systematic
//! sampling with an integer target), so steady-state buffer lengths are
//! constant and the post-warmup assertion is deterministic.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::{Arc, Mutex};

use uavjp::config::Preset;
use uavjp::data::{self, DatasetKind};
use uavjp::native::{models, NativeTrainer};
use uavjp::serve::InferenceEngine;
use uavjp::tensor::kernels::{self, KernelKind};
use uavjp::tensor::Mat;

// ---------------------------------------------------------------------------
// Counting allocator
// ---------------------------------------------------------------------------

/// Forwards every call to [`System`], bumping a thread-local counter
/// while the current thread is armed. Thread-local (rather than global)
/// counting keeps concurrent test threads from polluting the measurement.
struct CountingAlloc;

thread_local! {
    static ARMED: Cell<bool> = const { Cell::new(false) };
    static COUNT: Cell<usize> = const { Cell::new(0) };
}

/// `try_with` so late allocator calls during thread teardown (after TLS
/// destruction) degrade to "not armed" instead of panicking inside the
/// allocator.
fn bump_if_armed() {
    let _ = ARMED.try_with(|a| {
        if a.get() {
            let _ = COUNT.try_with(|c| c.set(c.get() + 1));
        }
    });
}

// SAFETY: every method is a pure pass-through to `System` (which upholds
// the GlobalAlloc contract); the only addition is a thread-local counter
// bump, which itself never allocates (const-init `Cell`, no destructor).
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller contract is forwarded verbatim to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump_if_armed();
        // SAFETY: same layout, forwarded verbatim to the System
        // allocator, which upholds the GlobalAlloc contract.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: caller contract is forwarded verbatim to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr` was returned by `self.alloc`, which is a pure
        // pass-through to System with the same layout.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: caller contract is forwarded verbatim to `System.realloc`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump_if_armed();
        // SAFETY: contract is inherited unchanged from the caller; the
        // original allocation came from System via `self.alloc`.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f` with the counter armed; returns (allocations, result).
fn count_allocs<R>(f: impl FnOnce() -> R) -> (usize, R) {
    COUNT.with(|c| c.set(0));
    ARMED.with(|a| a.set(true));
    let r = f();
    ARMED.with(|a| a.set(false));
    (COUNT.with(|c| c.get()), r)
}

/// `set_kernel` / `pool::set_threads` are process-wide knobs: serialize
/// every measured run so another test body cannot flip them mid-count.
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

// ---------------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------------

/// A sketched kept-policy MLP config: exercises the sparse dX/dW
/// kernels, the kept-column activation stash and the packed-gemm arena —
/// every §7.2 surface — on the plain (replicas = 0) trainer path.
///
/// Budget 0.5 over dims [784, 256, 64, 10] makes every site's kept
/// target an integer (128 / 32 / 5), so the correlated sampler keeps a
/// *constant* column count per site and steady-state buffer lengths
/// never exceed their warmup high-water mark.
fn steady_cfg(kernel: &str) -> uavjp::config::TrainConfig {
    let mut cfg = Preset::Smoke.base("mlp").unwrap();
    cfg.method = "l1".into();
    cfg.location = "all".into();
    cfg.budget = 0.5;
    cfg.act_policy = "kept".into();
    cfg.kernel = kernel.into();
    cfg.threads = 1;
    cfg.train_size = 64;
    cfg.test_size = 32;
    cfg.steps = 8;
    cfg.eval_every = 8;
    cfg.batch = 16;
    cfg
}

/// One fixed training batch from the MLP's synthetic train split.
fn train_batch(batch: usize) -> (Mat, Vec<i32>) {
    let kind = DatasetKind::for_model("mlp").unwrap();
    let ds = data::generate(kind, batch, 7, "train");
    let mut x = Mat::zeros(ds.n, ds.dim);
    x.data.copy_from_slice(&ds.x);
    (x, ds.y)
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

/// Negative control: the counter must see an ordinary allocation, or the
/// zero assertions below would be vacuous.
#[test]
fn counter_sees_allocations() {
    let (n, v) = count_allocs(|| std::hint::black_box(vec![0u8; 256]));
    assert!(n > 0, "counting allocator missed a fresh Vec");
    drop(v);
    // and stays quiet on allocation-free work
    let (n, s) = count_allocs(|| std::hint::black_box(1.0f64).sqrt());
    assert_eq!(n, 0, "counter fired on pure arithmetic (s = {s})");
}

/// §7.2, training half: after warmup, a plain train step performs zero
/// heap allocations — under both kernel kinds, on the sketched
/// kept-policy path.
#[test]
fn steady_state_train_step_does_not_allocate() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for kernel in ["scalar", "simd"] {
        kernels::set_kernel(KernelKind::parse(kernel).unwrap());
        let mut trainer =
            NativeTrainer::with_dims(steady_cfg(kernel), &[784, 256, 64, 10]).unwrap();
        let (x, y) = train_batch(16);
        // Warmup: grows the pack-arena pools, optimizer slot buffers and
        // gate/kept buffers to their (constant) steady-state sizes.
        for step in 0..3 {
            trainer.step(&x, &y, step).unwrap();
        }
        for step in 3..5 {
            let (n, res) = count_allocs(|| trainer.step(&x, &y, step));
            let loss = res.unwrap();
            assert!(loss.is_finite(), "{kernel}: non-finite loss {loss}");
            assert_eq!(
                n, 0,
                "{kernel}: steady-state step {step} performed {n} heap \
                 allocation(s); §7.2 pins zero"
            );
        }
    }
    kernels::set_kernel(KernelKind::Auto);
}

/// §7.2, serving half: after a warmup call, `infer_batch` at a fixed
/// batch shape performs zero heap allocations.
#[test]
fn steady_state_infer_batch_does_not_allocate() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    uavjp::pool::set_threads(1);
    let model = Arc::new(models::build("mlp", 3).unwrap());
    let (x, _) = train_batch(8);
    let mut engine = InferenceEngine::new(Arc::clone(&model), x.cols, 8);
    let out_dim = engine.out_dim();
    engine.infer_batch(&x); // warmup: sizes the engine workspace
    for round in 0..2 {
        let (n, len) = count_allocs(|| engine.infer_batch(&x).data.len());
        assert_eq!(len, 8 * out_dim);
        assert_eq!(
            n, 0,
            "round {round}: steady-state infer_batch performed {n} heap \
             allocation(s); §7.2 pins zero"
        );
    }
    uavjp::pool::set_threads(0);
}
