//! Serving integration suite (DESIGN.md §7.5): batch invariance (a
//! request's logits are bitwise identical solo, chunked, or coalesced by
//! the dynamic batcher), empty-batch/empty-run handling, and the
//! train → save → serve end-to-end pipeline.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use uavjp::config::{Preset, ServeConfig};
use uavjp::coordinator::serving;
use uavjp::data::{self, DatasetKind};
use uavjp::native::{checkpoint, models, NativeTrainer, Sequential};
use uavjp::pool;
use uavjp::serve::{
    run_server, BatcherConfig, InferenceEngine, Request, RequestQueue,
    Response,
};
use uavjp::tensor::kernels::{self, KernelKind};
use uavjp::tensor::Mat;

/// `set_kernel` is a process-wide knob and the test harness runs tests
/// concurrently: every test that compares two forwards bit-for-bit takes
/// this lock so the kernel cannot flip mid-comparison.
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

/// A small batch from the MLP's synthetic test split (784-wide).
fn mlp_inputs(n: usize) -> Mat {
    let kind = DatasetKind::for_model("mlp").unwrap();
    let ds = data::generate(kind, n, 99, "test");
    let mut x = Mat::zeros(ds.n, ds.dim);
    x.data.copy_from_slice(&ds.x);
    x
}

/// One inference forward sweep, logits flattened out.
fn forward_logits(model: &Sequential, x: &Mat) -> Vec<f32> {
    let mut ws = model.inference_workspace(x.rows, x.cols);
    model.forward(x, &mut ws);
    ws.output().data.clone()
}

/// Batch invariance at the engine level, under both kernel kinds: a full
/// batch, row-at-a-time serving, and a 3+5 chunking all produce bitwise
/// identical logits per row — and agree with a plain `Sequential`
/// forward. This is the property that makes dynamic batching a pure
/// latency/throughput knob.
#[test]
fn engine_batches_are_bitwise_invariant_per_row() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for kernel in ["scalar", "simd"] {
        kernels::set_kernel(KernelKind::parse(kernel).unwrap());
        let model = Arc::new(models::build("mlp", 3).unwrap());
        let x = mlp_inputs(8);
        let mut engine = InferenceEngine::new(Arc::clone(&model), 784, 8);
        let out_dim = engine.out_dim();
        let full = engine.infer_batch(&x).data.clone();
        assert_eq!(full.len(), 8 * out_dim);
        // solo: each row served alone matches its slice of the full batch
        let mut one = vec![0.0f32; out_dim];
        for r in 0..8 {
            engine.infer_one(x.row(r), &mut one);
            assert_eq!(
                one.as_slice(),
                &full[r * out_dim..(r + 1) * out_dim],
                "row {r} drifts solo under {kernel}"
            );
        }
        // coalesced differently: a 3-batch then a 5-batch
        let head = engine
            .infer_staged(3, |r, dst| dst.copy_from_slice(x.row(r)))
            .data
            .clone();
        assert_eq!(head.as_slice(), &full[..3 * out_dim], "{kernel}");
        let tail = engine
            .infer_staged(5, |r, dst| dst.copy_from_slice(x.row(3 + r)))
            .data
            .clone();
        assert_eq!(tail.as_slice(), &full[3 * out_dim..], "{kernel}");
        // and the engine agrees with a plain forward sweep
        assert_eq!(full, forward_logits(&model, &x), "{kernel}");
    }
    kernels::set_kernel(KernelKind::Auto);
}

/// End-to-end through the dynamic batcher: many requests submitted at
/// once, coalesced into batches of up to 4 across two racing workers —
/// every reply's logits are bitwise identical to the reference forward of
/// that request's row, regardless of which batch served it.
#[test]
fn dynamic_batcher_delivers_bitwise_identical_logits() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let model = Arc::new(models::build("mlp", 5).unwrap());
    let x = mlp_inputs(6);
    let reference = forward_logits(&model, &x);
    let out_dim = reference.len() / 6;
    let queue = RequestQueue::new(BatcherConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(100),
        queue_cap: 0,
        timeout: Duration::ZERO,
    });
    let n = 18usize;
    let mut handles = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let queue = &queue;
        let server = scope.spawn(|| {
            let mut engines: Vec<InferenceEngine> = (0..2)
                .map(|_| InferenceEngine::new(Arc::clone(&model), 784, 4))
                .collect();
            pool::run_source(
                || queue.next_batch(),
                &mut engines,
                |batch: Vec<Request>, engine: &mut InferenceEngine| {
                    let bsz = batch.len();
                    let logits = engine
                        .infer_staged(bsz, |r, dst| dst.copy_from_slice(&batch[r].x));
                    for (r, req) in batch.iter().enumerate() {
                        req.reply.fill(Response {
                            id: req.id,
                            logits: logits.data
                                [r * out_dim..(r + 1) * out_dim]
                                .to_vec(),
                            latency: req.enqueued.elapsed(),
                            batch_size: bsz,
                        });
                    }
                },
            );
        });
        for i in 0..n {
            let req = Request::new(i as u64, x.row(i % 6).to_vec());
            handles.push(req.reply.clone());
            queue.submit(req).unwrap();
        }
        queue.close();
        server.join().unwrap();
    });
    for (i, handle) in handles.iter().enumerate() {
        let resp = handle.wait().unwrap();
        assert_eq!(resp.id, i as u64);
        let row = i % 6;
        assert_eq!(
            resp.logits.as_slice(),
            &reference[row * out_dim..(row + 1) * out_dim],
            "request {i} (row {row}) drifts when coalesced"
        );
        assert!((1..=4).contains(&resp.batch_size));
    }
}

/// Batch size 0 and request count 0 are clean no-ops: empty logits, no
/// panic, and the engine keeps serving afterwards.
#[test]
fn empty_batches_and_empty_runs_are_clean() {
    let model = Arc::new(models::build("mlp", 1).unwrap());
    let mut engine = InferenceEngine::new(Arc::clone(&model), 784, 4);
    let out_dim = engine.out_dim();
    let shape = {
        let out = engine.infer_batch(&Mat::zeros(0, 784));
        (out.rows, out.cols)
    };
    assert_eq!(shape, (0, out_dim), "empty batch yields empty logits");
    // a normal batch still works after the empty one
    let x = mlp_inputs(2);
    assert_eq!(engine.infer_batch(&x).rows, 2);
    // a zero-request serving session reports a clean zeroed summary
    let cfg = ServeConfig { requests: 0, ..ServeConfig::default() };
    let report = run_server(&model, 784, &Mat::zeros(0, 784), &cfg);
    assert_eq!(report.completed, 0);
    assert_eq!(report.p50_ms, 0.0);
}

/// The full pipeline: train a few steps, save a checkpoint, serve it back
/// through the coordinator (as the CLI would from a fresh process), and
/// pin that a checkpoint-loaded engine's logits are bitwise identical to
/// the in-process trainer model's forward.
#[test]
fn train_save_serve_end_to_end() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = Preset::Smoke.base("mlp").unwrap();
    cfg.steps = 8;
    cfg.eval_every = 8;
    cfg.train_size = 128;
    cfg.test_size = 32;
    let path = std::env::temp_dir()
        .join(format!("uavjp_serve_e2e_{}.ckpt", std::process::id()));
    let mut trainer = NativeTrainer::new(cfg).unwrap();
    trainer.run().unwrap();
    trainer.save_checkpoint(&path).unwrap();
    let scfg = ServeConfig {
        requests: 32,
        concurrency: 4,
        max_batch: 8,
        max_wait_us: 100,
        workers: 2,
        offered_load: 0.0,
        queue_cap: 0,
        request_timeout_us: 0,
    };
    let report = serving::serve_checkpoint(&path, &scfg).unwrap();
    assert_eq!(report.completed, 32);
    assert_eq!(report.rejected, 0);
    assert!(report.p50_ms > 0.0);
    assert!(report.p99_ms >= report.p50_ms);
    // checkpoint-loaded engine == in-process eval, bit for bit
    let ckpt = checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let mut engine = InferenceEngine::from_checkpoint(&ckpt, 784, 8).unwrap();
    let x = mlp_inputs(5);
    assert_eq!(
        engine.infer_batch(&x).data.clone(),
        forward_logits(trainer.model(), &x),
        "served logits must match in-process eval bitwise"
    );
}
