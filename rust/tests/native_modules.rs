//! Tests of the composable module API's new layers.
//!
//! Three families, mirroring `native_unbiased.rs` for the conv path:
//!
//! * finite-difference gradient checks of the exact backwards of
//!   `PatchConv`, `Attention` and `LayerNorm` against a random-projection
//!   loss (bars pre-verified with python/tools/module_sim.py, which sees
//!   worst-case relative deviations ≲ 2e-5 at these shapes/eps);
//! * Monte-Carlo unbiasedness of the *sketched* `PatchConv` backward with
//!   correlated (systematic) and independent Bernoulli gates — the §4.2
//!   estimator on the lowered [B·P, d_out] gradient (MC noise at these
//!   trial counts sits near 1.5–3.5%, so the 12% bar has ≳3× headroom);
//! * end-to-end convergence of the BagNet-lite and ViT-lite models with
//!   both exact and l1 @ 0.25 backwards (margins calibrated on 3-seed
//!   simulations: sketched tail/first ratios 0.59–0.65 bagnet / 0.47–0.52
//!   vit, accuracies 0.44–0.73 / 0.38–0.63; chance accuracy is 0.1).

use uavjp::config::{Preset, TrainConfig};
use uavjp::native::{
    run_layer_backward, run_layer_forward, Attention, FfnBlock, Layer,
    LayerNorm, NativeTrainer, PatchConv, SiteSketch,
};
use uavjp::rng::Pcg64;
use uavjp::tensor::Mat;

fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
}

/// Projection loss L = Σ out ⊙ R (f64 accumulation) — its gradient w.r.t.
/// the layer output is exactly R, so `backward(R, …)` yields analytic
/// dL/dparam and dL/dx to compare against central differences.
fn proj_loss(layer: &dyn Layer, x: &Mat, r: &Mat) -> f64 {
    let (y, _) = run_layer_forward(layer, x);
    y.data
        .iter()
        .zip(&r.data)
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

/// Central-difference check of a layer's exact backward at a few
/// coordinates of every parameter tensor and of the input.
fn fd_check(layer: &mut dyn Layer, x: &mut Mat, seed: u64, tol: f64) {
    let mut rng = Pcg64::new(seed, 9);
    let (y, mut cache) = run_layer_forward(layer, x);
    let r = randmat(y.rows, y.cols, &mut rng);
    let mut gate = Pcg64::new(0, 0);
    let (gx, pgrads) =
        run_layer_backward(layer, &r, x, &mut cache, None, &mut gate, true);
    let gx = gx.expect("need_gx");
    let eps = 1e-2f32;

    // input gradient
    let n = x.data.len();
    for idx in [0, n / 3, n - 1] {
        let orig = x.data[idx];
        x.data[idx] = orig + eps;
        let lp = proj_loss(layer, x, &r);
        x.data[idx] = orig - eps;
        let lm = proj_loss(layer, x, &r);
        x.data[idx] = orig;
        let fd = (lp - lm) / (2.0 * eps as f64);
        let an = gx.data[idx] as f64;
        assert!(
            (fd - an).abs() < tol * (1.0 + fd.abs()),
            "{} input idx {idx}: fd {fd} vs analytic {an}",
            layer.name()
        );
    }

    // parameter gradients, tensor by tensor
    let num_tensors = pgrads.len();
    for ti in 0..num_tensors {
        let len = pgrads[ti].len();
        for idx in [0, len / 2, len - 1] {
            let orig = layer.params()[ti][idx];
            layer.params_mut()[ti][idx] = orig + eps;
            let lp = proj_loss(layer, x, &r);
            layer.params_mut()[ti][idx] = orig - eps;
            let lm = proj_loss(layer, x, &r);
            layer.params_mut()[ti][idx] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = pgrads[ti][idx] as f64;
            assert!(
                (fd - an).abs() < tol * (1.0 + fd.abs()),
                "{} tensor {ti} idx {idx}: fd {fd} vs analytic {an}",
                layer.name()
            );
        }
    }
}

#[test]
fn patch_conv_backward_matches_finite_differences() {
    let mut layer = PatchConv::he(4, 6, 5, 1, 300);
    let mut rng = Pcg64::new(2, 0);
    let mut x = randmat(3, 24, &mut rng);
    fd_check(&mut layer, &mut x, 11, 1e-2);
}

#[test]
fn layer_norm_backward_matches_finite_differences() {
    let mut layer = LayerNorm::new(6);
    let mut rng = Pcg64::new(3, 0);
    let mut x = randmat(3, 24, &mut rng); // 12 token rows of width 6
    fd_check(&mut layer, &mut x, 12, 1e-2);
}

#[test]
fn attention_backward_matches_finite_differences() {
    let mut layer = Attention::new(4, 8, 2, 1, 302);
    let mut rng = Pcg64::new(4, 0);
    let mut x = randmat(2, 32, &mut rng);
    for v in &mut x.data {
        *v *= 0.5; // keep softmax away from saturation for a clean FD
    }
    fd_check(&mut layer, &mut x, 13, 1e-2);
}

#[test]
fn ffn_block_backward_matches_finite_differences() {
    let mut layer = FfnBlock::he(6, 10, 1, 306);
    let mut rng = Pcg64::new(5, 0);
    let mut x = randmat(2, 24, &mut rng);
    fd_check(&mut layer, &mut x, 14, 1e-2);
}

#[test]
fn ffn_block_residual_is_identity_at_zero_weights() {
    let mut layer = FfnBlock::he(4, 6, 1, 306);
    for t in layer.params_mut() {
        for v in t.iter_mut() {
            *v = 0.0;
        }
    }
    let mut rng = Pcg64::new(6, 0);
    let x = randmat(3, 8, &mut rng);
    let (y, _) = run_layer_forward(&layer, &x);
    assert_eq!(y.data, x.data);
}

// ---------------------------------------------------------------------------
// Monte-Carlo unbiasedness of the sketched PatchConv backward
// ---------------------------------------------------------------------------

/// E[sketched backward] must match the exact backward for dW, db and dX.
fn patchconv_mc_mean_matches_exact(method: &str, budget: f64, data_seed: u64) {
    let trials = 2500usize;
    let layer = PatchConv::he(4, 6, 12, data_seed, 300);
    let mut rng = Pcg64::new(data_seed, 0);
    let x = randmat(4, 24, &mut rng);
    let (y, mut cache) = run_layer_forward(&layer, &x);
    let gy = randmat(y.rows, y.cols, &mut rng);

    let mut gate = Pcg64::new(0, 0);
    let (gx_e, pg_e) =
        run_layer_backward(&layer, &gy, &x, &mut cache, None, &mut gate, true);
    let gx_e = gx_e.unwrap();

    let site = SiteSketch { method: method.into(), budget };
    let mut acc_dw = vec![0.0f64; pg_e[0].len()];
    let mut acc_db = vec![0.0f64; pg_e[1].len()];
    let mut acc_gx = vec![0.0f64; gx_e.data.len()];
    let mut gate_rng = Pcg64::new(data_seed ^ 0x5eed, 1);
    for _ in 0..trials {
        let (gx, pg) = run_layer_backward(
            &layer,
            &gy,
            &x,
            &mut cache,
            Some(&site),
            &mut gate_rng,
            true,
        );
        for (a, v) in acc_dw.iter_mut().zip(&pg[0]) {
            *a += *v as f64;
        }
        for (a, v) in acc_db.iter_mut().zip(&pg[1]) {
            *a += *v as f64;
        }
        for (a, v) in acc_gx.iter_mut().zip(&gx.unwrap().data) {
            *a += *v as f64;
        }
    }
    let t = trials as f64;
    let rel = |acc: &[f64], exact: &[f32]| -> f64 {
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for (a, &e) in acc.iter().zip(exact) {
            let d = a / t - e as f64;
            err += d * d;
            norm += (e as f64) * (e as f64);
        }
        (err / norm.max(1e-12)).sqrt()
    };
    let (edw, edb, egx) = (
        rel(&acc_dw, &pg_e[0]),
        rel(&acc_db, &pg_e[1]),
        rel(&acc_gx, &gx_e.data),
    );
    let tol = 0.12;
    assert!(
        edw < tol && edb < tol && egx < tol,
        "{method} p={budget}: MC mean deviates — dW {edw:.4}, db {edb:.4}, \
         dX {egx:.4} (tol {tol})"
    );
}

#[test]
fn patch_conv_correlated_gates_unbiased_l1() {
    patchconv_mc_mean_matches_exact("l1", 0.45, 3);
}

#[test]
fn patch_conv_independent_gates_unbiased_l1_ind() {
    patchconv_mc_mean_matches_exact("l1_ind", 0.45, 4);
}

#[test]
fn patch_conv_independent_gates_unbiased_per_column() {
    patchconv_mc_mean_matches_exact("per_column", 0.5, 5);
}

// ---------------------------------------------------------------------------
// End-to-end convergence: BagNet-lite and ViT-lite, exact + l1 @ 0.25
// ---------------------------------------------------------------------------

fn model_cfg(model: &str, method: &str, budget: f64) -> TrainConfig {
    let mut cfg = Preset::Smoke.base(model).unwrap();
    cfg.method = method.into();
    cfg.budget = budget;
    cfg.location = if method == "baseline" { "none".into() } else { "all".into() };
    cfg.train_size = 256;
    cfg.test_size = 128;
    cfg.batch = 32;
    cfg.steps = if model == "bagnet" { 60 } else { 80 };
    cfg.eval_every = cfg.steps;
    cfg
}

/// Train and return (first loss, tail loss, final accuracy).
fn converge(model: &str, method: &str, budget: f64) -> (f64, f64, f64) {
    let mut t = NativeTrainer::new(model_cfg(model, method, budget)).unwrap();
    let curve = t.run().unwrap();
    (
        curve.losses[0],
        curve.tail_loss(8).unwrap(),
        curve.final_acc().unwrap(),
    )
}

#[test]
fn bagnet_converges_exact_and_sketched() {
    let (first, tail, acc) = converge("bagnet", "baseline", 1.0);
    assert!(tail < 0.5 * first, "bagnet baseline: {first:.3} → {tail:.3}");
    assert!(acc > 0.65, "bagnet baseline acc {acc:.3}");
    let (first, tail, acc) = converge("bagnet", "l1", 0.25);
    assert!(tail < 0.85 * first, "bagnet l1@0.25: {first:.3} → {tail:.3}");
    assert!(acc > 0.25, "bagnet l1@0.25 acc {acc:.3}");
}

#[test]
fn vit_converges_exact_and_sketched() {
    let (first, tail, acc) = converge("vit", "baseline", 1.0);
    assert!(tail < 0.4 * first, "vit baseline: {first:.3} → {tail:.3}");
    assert!(acc > 0.75, "vit baseline acc {acc:.3}");
    let (first, tail, acc) = converge("vit", "l1", 0.25);
    assert!(tail < 0.85 * first, "vit l1@0.25: {first:.3} → {tail:.3}");
    assert!(acc > 0.2, "vit l1@0.25 acc {acc:.3}");
}

#[test]
fn vit_location_none_matches_baseline_exactly() {
    // exact sites consume no gate randomness even in the transformer stack
    let mut cfg = model_cfg("vit", "l1", 0.1);
    cfg.steps = 12;
    cfg.eval_every = 12;
    cfg.location = "none".into();
    let sketched = NativeTrainer::new(cfg.clone()).unwrap().run().unwrap();
    cfg.method = "baseline".into();
    cfg.location = "all".into();
    let baseline = NativeTrainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(sketched.losses, baseline.losses);
}

#[test]
fn bagnet_budget_schedule_runs_per_depth_budgets() {
    let mut cfg = model_cfg("bagnet", "l1", 0.25);
    cfg.steps = 12;
    cfg.eval_every = 12;
    cfg.budget_schedule = vec![0.5, 0.25, 1.0]; // 3 sketch sites
    let curve = NativeTrainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(curve.losses.len(), 12);
    assert!(curve.losses.iter().all(|l| l.is_finite()));
}
