//! Checkpoint integration suite (DESIGN.md §7.5): bit-exact
//! save → load → forward round-trips for every registry model under both
//! kernel kinds, the trainer's `--save-ckpt` hook, and typed errors for
//! every file-level failure class (wrong magic, truncation, version bump,
//! trailing garbage, registry-key mismatch, missing file).

use std::path::PathBuf;
use std::sync::Mutex;

use uavjp::config::Preset;
use uavjp::data::{self, DatasetKind};
use uavjp::native::checkpoint::{
    self, fnv1a, load, save_bytes, CkptError, CKPT_VERSION,
};
use uavjp::native::{models, NativeTrainer, Sequential};
use uavjp::tensor::kernels::{self, KernelKind};
use uavjp::tensor::Mat;

/// `set_kernel` is a process-wide knob and the test harness runs tests
/// concurrently: every test that compares two forwards bit-for-bit takes
/// this lock so the kernel cannot flip mid-comparison.
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

/// Every registry model the round-trip suite must cover.
const ALL_MODELS: &[&str] = &["mlp", "bagnet", "vit", "bagnet_deep", "vit_deep"];

/// A small batch from the model's synthetic test split.
fn test_inputs(model: &str, n: usize) -> Mat {
    let kind = DatasetKind::for_model(model).unwrap();
    let ds = data::generate(kind, n, 99, "test");
    let mut x = Mat::zeros(ds.n, ds.dim);
    x.data.copy_from_slice(&ds.x);
    x
}

/// One inference forward sweep, logits flattened out.
fn forward_logits(model: &Sequential, x: &Mat) -> Vec<f32> {
    let mut ws = model.inference_workspace(x.rows, x.cols);
    model.forward(x, &mut ws);
    ws.output().data.clone()
}

/// Unique-per-test temp path (tests share one process).
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("uavjp_ckpt_{}_{name}", std::process::id()))
}

/// The headline acceptance bar: for every registry model × kernel kind,
/// a checkpoint loaded back from disk rebuilds a model whose forward is
/// bitwise identical to the original's.
#[test]
fn save_load_forward_roundtrip_every_model_and_kernel() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for kernel in ["scalar", "simd"] {
        kernels::set_kernel(KernelKind::parse(kernel).unwrap());
        for name in ALL_MODELS {
            let model = models::build(name, 7).unwrap();
            let path = tmp(&format!("rt_{kernel}_{name}"));
            checkpoint::save(&path, name, 7, &model).unwrap();
            let ckpt = checkpoint::load(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(ckpt.model_name, *name);
            assert_eq!(ckpt.seed, 7);
            let loaded = ckpt.build_model().unwrap();
            let x = test_inputs(name, 3);
            assert_eq!(
                forward_logits(&model, &x),
                forward_logits(&loaded, &x),
                "round-trip drift for {kernel}/{name}"
            );
        }
    }
    kernels::set_kernel(KernelKind::Auto);
}

/// The trainer's save hook writes a checkpoint whose rebuilt model serves
/// the *trained* parameters: its forward is bitwise identical to the
/// in-process trainer model's.
#[test]
fn trainer_save_hook_roundtrips_trained_params() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = Preset::Smoke.base("mlp").unwrap();
    cfg.steps = 6;
    cfg.eval_every = 6;
    cfg.train_size = 128;
    cfg.test_size = 32;
    let mut trainer = NativeTrainer::new(cfg).unwrap();
    trainer.run().unwrap();
    let path = tmp("trained");
    trainer.save_checkpoint(&path).unwrap();
    let ckpt = checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(ckpt.model_name, "mlp");
    let loaded = ckpt.build_model().unwrap();
    let x = test_inputs("mlp", 5);
    assert_eq!(
        forward_logits(trainer.model(), &x),
        forward_logits(&loaded, &x),
        "loaded model must serve the trained parameters bit-for-bit"
    );
}

/// Every file-level failure class comes back as its typed [`CkptError`]
/// variant — never a panic, never a misparse.
#[test]
fn file_level_failures_are_typed() {
    let model = models::build("mlp", 0).unwrap();
    let good = save_bytes("mlp", 0, &model);
    let path = tmp("neg");

    // foreign magic: not a checkpoint at all
    let mut bad = good.clone();
    bad[0] ^= 0xff;
    std::fs::write(&path, &bad).unwrap();
    assert_eq!(load(&path).unwrap_err(), CkptError::BadMagic);

    // cut mid-payload: structural truncation
    std::fs::write(&path, &good[..good.len() - 9]).unwrap();
    assert!(matches!(
        load(&path).unwrap_err(),
        CkptError::Truncated { .. }
    ));

    // future format version with a *valid* checksum: rejected loudly as
    // unsupported, not misread and not reported as corruption
    let mut v2 = good.clone();
    v2[8..12].copy_from_slice(&(CKPT_VERSION + 1).to_le_bytes());
    let body = v2.len() - 8;
    let sum = fnv1a(&v2[..body]);
    v2[body..].copy_from_slice(&sum.to_le_bytes());
    std::fs::write(&path, &v2).unwrap();
    assert_eq!(
        load(&path).unwrap_err(),
        CkptError::UnsupportedVersion { found: CKPT_VERSION + 1 }
    );

    // bytes past the trailer
    let mut padded = good.clone();
    padded.extend_from_slice(&[0u8; 5]);
    std::fs::write(&path, &padded).unwrap();
    assert_eq!(
        load(&path).unwrap_err(),
        CkptError::TrailingBytes { extra: 5 }
    );

    // a registered key over the wrong architecture: the parse succeeds
    // (the file is well-formed) but rebuilding trips the arch digest
    std::fs::write(&path, save_bytes("bagnet", 0, &model)).unwrap();
    assert!(matches!(
        load(&path).unwrap().build_model().unwrap_err(),
        CkptError::ArchMismatch { .. }
    ));

    // missing file surfaces as Io with the path in the message
    std::fs::remove_file(&path).unwrap();
    match load(&path).unwrap_err() {
        CkptError::Io(msg) => assert!(msg.contains("uavjp_ckpt"), "{msg}"),
        other => panic!("want Io, got {other:?}"),
    }
}

/// Atomic-write discipline: a crash mid-save leaves at most a dangling
/// `<path>.tmp` — the live checkpoint at `<path>` is only ever replaced by
/// a complete rename, so a truncated tmp file never shadows or corrupts
/// it.
#[test]
fn truncated_tmp_file_never_corrupts_the_live_checkpoint() {
    let model = models::build("mlp", 3).unwrap();
    let path = tmp("atomic");
    checkpoint::save(&path, "mlp", 3, &model).unwrap();
    assert!(
        !checkpoint::tmp_path(&path).exists(),
        "a completed save must not leave its tmp file behind"
    );

    // simulate a crash mid-write: half the bytes land in the tmp file and
    // the rename never happens
    let bytes = save_bytes("mlp", 3, &model);
    std::fs::write(checkpoint::tmp_path(&path), &bytes[..bytes.len() / 2])
        .unwrap();

    // the live checkpoint is untouched and still loads clean
    let ckpt = checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.model_name, "mlp");
    ckpt.build_model().unwrap();

    // while the torn tmp file itself is structurally truncated
    assert!(matches!(
        load(&checkpoint::tmp_path(&path)).unwrap_err(),
        CkptError::Truncated { .. }
    ));
    std::fs::remove_file(checkpoint::tmp_path(&path)).unwrap();
    std::fs::remove_file(&path).unwrap();
}
