//! Data-parallel replica-group suite (DESIGN.md §7.6).
//!
//! The headline contract mirrors `tests/gemm_kernels.rs`'s thread
//! invariance, one axis up: for a fixed seed, training trajectories are
//! **bit-identical at every `--replicas` value** (the group always shards
//! onto the fixed 8-lane grid and reduces lanes in ascending index, so
//! the replica count only chooses executors), and the `sparse`
//! kept-column union-reduce is **lossless** against `dense` (a gated
//! GEMM's gradient is exactly zero outside its kept columns). On top:
//! Monte-Carlo unbiasedness of the reduced gradient against the exact
//! reduce, the modeled exchange-byte accounting, and loud config errors.

use std::sync::Mutex;

use uavjp::config::{Preset, TrainConfig};
use uavjp::native::{models, Layer, NativeTrainer};
use uavjp::replicate::{ReplicaGroup, LANES};
use uavjp::rng::Pcg64;
use uavjp::tensor::kernels::{self, Kernel, KernelKind};
use uavjp::tensor::Mat;

/// `pool::set_threads` / `set_kernel` are process-global knobs; tests
/// that pin a kernel kind for bitwise comparisons hold this lock (same
/// discipline as `tests/gemm_kernels.rs`).
static THREAD_KNOB: Mutex<()> = Mutex::new(());

/// Pin the kernel knob; the guard restores the previous resolution on
/// drop, including on panic.
fn pin_kernel(kind: KernelKind) -> KernelGuard {
    let prev = kernels::active();
    kernels::set_kernel(kind);
    KernelGuard(match prev {
        Kernel::Scalar => KernelKind::Scalar,
        _ => KernelKind::Simd,
    })
}

struct KernelGuard(KernelKind);

impl Drop for KernelGuard {
    fn drop(&mut self) {
        kernels::set_kernel(self.0);
    }
}

/// Short sketched run sized for trajectory comparison: 10 steps, batch 32
/// (4 rows per lane on the 8-lane grid).
fn dp_cfg(model: &str, replicas: usize, reduce: &str) -> TrainConfig {
    let mut cfg = Preset::Smoke.base(model).unwrap();
    cfg.method = "l1".into();
    cfg.budget = 0.25;
    cfg.train_size = 256;
    cfg.test_size = 64;
    cfg.batch = 32;
    cfg.steps = 10;
    cfg.eval_every = 10;
    cfg.replicas = replicas;
    cfg.reduce = reduce.into();
    cfg
}

fn losses_of(cfg: TrainConfig) -> Vec<f64> {
    NativeTrainer::new(cfg).unwrap().run().unwrap().losses
}

#[test]
fn trajectories_are_replica_count_invariant_and_sparse_is_lossless() {
    // the tentpole guarantee, per kernel kind and model family: dense
    // trajectories agree bitwise at --replicas 1|2|4, and the sparse
    // union-reduce reproduces them bitwise as well (at 2 and 4 replicas)
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    for kind in [KernelKind::Scalar, KernelKind::Simd] {
        let _restore = pin_kernel(kind);
        for model in ["mlp", "bagnet", "vit"] {
            let dense1 = losses_of(dp_cfg(model, 1, "dense"));
            assert!(
                dense1.iter().all(|l| l.is_finite()),
                "{model} diverged under the replica group"
            );
            for r in [2usize, 4] {
                assert_eq!(
                    dense1,
                    losses_of(dp_cfg(model, r, "dense")),
                    "{model}/{kind:?}: dense trajectory drifts at --replicas {r}"
                );
                assert_eq!(
                    dense1,
                    losses_of(dp_cfg(model, r, "sparse")),
                    "{model}/{kind:?}: sparse reduce drifts at --replicas {r}"
                );
            }
        }
    }
}

#[test]
fn sparse_reduce_with_no_gated_sites_falls_back_to_dense() {
    // --location none leaves no gated GEMM: the sparse reducer has no
    // kept columns to merge and must degrade to the dense fold, not error
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let mut dense = dp_cfg("mlp", 2, "dense");
    dense.location = "none".into();
    let mut sparse = dp_cfg("mlp", 2, "sparse");
    sparse.location = "none".into();
    assert_eq!(losses_of(dense), losses_of(sparse));
}

#[test]
fn stale_gradient_mode_is_replica_invariant_and_trains() {
    // --stale 1 applies each reduced gradient one step late; that delay
    // is part of the trajectory, so it must itself be replica-invariant
    // (and differ from the synchronous trajectory after step 0)
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let stale_of = |r: usize| {
        let mut cfg = dp_cfg("mlp", r, "sparse");
        cfg.stale = 1;
        losses_of(cfg)
    };
    let s1 = stale_of(1);
    assert!(s1.iter().all(|l| l.is_finite()), "stale run diverged");
    assert_eq!(s1, stale_of(2));
    assert_eq!(s1, stale_of(4));
    let sync = losses_of(dp_cfg("mlp", 1, "sparse"));
    // step 0 sees identical params either way; the schedules separate after
    assert_eq!(s1[0], sync[0]);
    assert_ne!(s1, sync, "one-step delay must change the trajectory");
}

#[test]
fn exchange_byte_model_tracks_the_budget_and_is_replica_invariant() {
    let stats_of = |r: usize| {
        let mut t = NativeTrainer::new(dp_cfg("mlp", r, "sparse")).unwrap();
        t.run().unwrap();
        t.exchange_stats().expect("replica runs accumulate stats")
    };
    let s = stats_of(2);
    assert_eq!(s.steps, 10);
    // dense wire model: every lane ships the full flat gradient
    let params: usize = models::build("mlp", 0)
        .unwrap()
        .layers
        .iter()
        .flat_map(|l| l.params().iter().map(|p| p.len()).collect::<Vec<_>>())
        .sum();
    assert_eq!(s.dense_bytes, (10 * LANES * params * 4) as u64);
    // sparse wire model: kept-column payloads only (every mlp slot is a
    // gated GEMM under --location all). l1 waterfilling keeps ~budget·dout
    // columns per site, so the byte ratio sits near the 0.25 budget plus
    // per-row index overhead — far under dense, and never trivially zero.
    let ratio = s.ratio();
    assert!(
        (0.08..=0.45).contains(&ratio),
        "sparse/dense byte ratio {ratio} strays from the 0.25 budget"
    );
    // the wire model is lane-framed, so it cannot depend on the replica
    // count either
    assert_eq!(s, stats_of(1));
    assert_eq!(s, stats_of(4));
    // plain (non-replicated) runs accumulate nothing
    let mut cfg = dp_cfg("mlp", 0, "dense");
    cfg.replicas = 0;
    let mut t = NativeTrainer::new(cfg).unwrap();
    t.run().unwrap();
    assert!(t.exchange_stats().is_none());
}

#[test]
fn sparse_union_reduce_mc_mean_matches_exact_reduce() {
    // Prop 2.2 i, one level up: the MC mean of the group's sparse-reduced
    // gradient over fresh gate draws must match the exact (ungated) dense
    // reduce of the same batch. Margin calibration follows
    // tests/native_unbiased.rs: a single site's MC mean deviates a few
    // percent (relative Frobenius) at a couple thousand trials; here gate
    // noise compounds across the mlp's 3 sketched sites (the first
    // layer's dW crosses two downstream gate stages), so at 1200 trials
    // the deviation sits near 0.05–0.12 and 0.20 keeps real headroom —
    // while a missing 1/p rescale lands near 0.5 (the negative control
    // below), so the bar still has teeth.
    let mut cfg = dp_cfg("mlp", 4, "sparse");
    cfg.budget = 0.5;
    cfg.act_policy = "exact".into(); // decouple from the UAVJP_ACTPOLICY env
    let master = models::build("mlp", 0).unwrap();
    let mut ws = master.workspace(cfg.batch, 784);

    let mut rng = Pcg64::new(41, 7);
    let x = Mat::from_fn(cfg.batch, 784, |_, _| rng.gaussian() as f32);
    let y: Vec<i32> = (0..cfg.batch).map(|_| (rng.next_u64() % 10) as i32).collect();

    // exact reference: same lanes, no gated sites, dense reduce
    let mut exact_cfg = cfg.clone();
    exact_cfg.location = "none".into();
    exact_cfg.reduce = "dense".into();
    let mut exact_group = ReplicaGroup::new(&exact_cfg, &master).unwrap();
    exact_group.step(&master, &x, &y, &mut ws.grad_slots);
    let exact: Vec<f64> = ws
        .grad_slots
        .slots
        .iter()
        .flat_map(|s| s.iter().map(|&v| v as f64).collect::<Vec<_>>())
        .collect();

    let trials = 1200usize;
    let mut group = ReplicaGroup::new(&cfg, &master).unwrap();
    let mut acc = vec![0.0f64; exact.len()];
    for _ in 0..trials {
        // each step consumes fresh gate randomness from the persistent
        // lane streams; parameters are never applied, so the batch's
        // exact gradient is the fixed MC target
        group.step(&master, &x, &y, &mut ws.grad_slots);
        let mut k = 0usize;
        for slot in &ws.grad_slots.slots {
            for &v in slot {
                acc[k] += v as f64;
                k += 1;
            }
        }
    }
    let rel_of = |scale: f64| -> f64 {
        let t = trials as f64;
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for (a, e) in acc.iter().zip(&exact) {
            let d = scale * a / t - e;
            err += d * d;
            norm += e * e;
        }
        (err / norm.max(1e-12)).sqrt()
    };
    let rel = rel_of(1.0);
    assert!(rel < 0.20, "sparse union-reduce MC mean deviates: {rel}");
    // negative control: an estimator missing the 1/pᵢ rescale shrinks
    // kept contributions by ~the keep probability; simulate it in
    // aggregate by scaling the mean with the 0.5 budget — it must fail
    // the same bar, proving the margin has teeth
    let biased = rel_of(cfg.budget);
    assert!(biased > 0.20, "unrescaled control passed the bar: {biased}");
}

#[test]
fn bad_dp_configs_fail_loudly() {
    // replica counts off the 8-lane grid
    for r in [3usize, 5, 7, 9, 16] {
        let err = NativeTrainer::new(dp_cfg("mlp", r, "dense")).unwrap_err();
        assert!(format!("{err}").contains("divisor"), "r={r}: {err}");
    }
    // batch not divisible into lanes
    let mut cfg = dp_cfg("mlp", 2, "dense");
    cfg.batch = 36;
    let err = NativeTrainer::new(cfg).unwrap_err();
    assert!(format!("{err}").contains("divisible"), "{err}");
    // unknown exchange mode
    let err = NativeTrainer::new(dp_cfg("mlp", 2, "topk")).unwrap_err();
    assert!(format!("{err}").contains("dense|sparse"), "{err}");
    // staleness beyond one step
    let mut cfg = dp_cfg("mlp", 2, "dense");
    cfg.stale = 3;
    let err = NativeTrainer::new(cfg).unwrap_err();
    assert!(format!("{err}").contains("0|1"), "{err}");
    // non-registry stacks cannot be replicated (replicas rebuild from the
    // registry; a with_dims stack has different slot shapes)
    let cfg = dp_cfg("mlp", 2, "dense");
    let err = NativeTrainer::with_dims(cfg, &[784, 16, 10]).unwrap_err();
    assert!(format!("{err}").contains("registry"), "{err}");
}
