//! End-to-end native training: the sketched run must track the exact run
//! (the ISSUE's acceptance bar: l1 @ budget 0.25 within 10% of the exact
//! final eval loss, plus a small absolute slack because both runs plateau
//! near zero on the synthetic task), and the backend plumbing must hold up
//! (determinism, backend trait dispatch, probe sanity).

use uavjp::config::{Preset, TrainConfig};
use uavjp::coordinator::backend::{open, NativeBackend};
use uavjp::coordinator::TrainBackend;
use uavjp::native::NativeTrainer;

fn parity_cfg(method: &str, budget: f64) -> TrainConfig {
    let mut cfg = Preset::Smoke.base("mlp").unwrap();
    cfg.method = method.into();
    cfg.budget = budget;
    cfg.location = if method == "baseline" { "none".into() } else { "all".into() };
    // This suite pins the G-sketch parity axis in isolation, so the
    // activation policy is fixed to full caches regardless of the CI
    // UAVJP_ACTPOLICY matrix leg: dual gating @0.25/0.25 costs ~0.07 extra
    // eval loss (sim-measured), which is outside this bar's 10% slack by
    // design. The doubly-gated quality bar lives in tests/act_policy.rs
    // (mlp_parity_bar_survives_kept_caching).
    cfg.act_policy = "exact".into();
    cfg.train_size = 1024;
    cfg.test_size = 512;
    cfg.steps = 320;
    cfg.eval_every = 160;
    cfg.batch = 64;
    cfg
}

fn final_eval_loss(cfg: TrainConfig, dims: &[usize]) -> (f64, f64) {
    let curve = NativeTrainer::with_dims(cfg, dims)
        .expect("trainer")
        .run()
        .expect("run");
    let (_, loss, acc) = *curve.evals.last().expect("eval recorded");
    (loss, acc)
}

#[test]
fn sketched_l1_budget_quarter_tracks_exact() {
    // config + margins pre-verified against a bit-exact simulation of this
    // trainer (same PCG64 streams): seed 0 lands at exact ≈ 0.049 vs
    // sketched ≈ 0.058, acc ≈ 0.99/0.98 — comfortably inside the bar
    let dims = [784usize, 64, 10];
    let (exact, exact_acc) = final_eval_loss(parity_cfg("baseline", 1.0), &dims);
    let (sketched, sk_acc) = final_eval_loss(parity_cfg("l1", 0.25), &dims);
    // acceptance bar: within 10% of the exact run (+0.05 absolute slack for
    // the near-zero plateau this easy synthetic task reaches)
    assert!(
        sketched <= exact * 1.10 + 0.05,
        "sketched eval loss {sketched:.4} not within 10% of exact {exact:.4}"
    );
    // and both actually learned
    assert!(exact_acc > 0.8, "exact acc {exact_acc}");
    assert!(sk_acc > 0.8, "sketched acc {sk_acc}");
}

#[test]
fn backend_trait_runs_native_training() {
    let be = open(uavjp::config::Backend::Native, "artifacts").unwrap();
    let mut cfg = parity_cfg("l1", 0.5);
    cfg.train_size = 256;
    cfg.test_size = 128;
    cfg.steps = 30;
    cfg.eval_every = 30;
    cfg.batch = 32;
    let curve = be.train(&cfg).unwrap();
    assert_eq!(curve.losses.len(), 30);
    let first = curve.losses[0];
    let last = curve.tail_loss(8).unwrap();
    assert!(last < first, "loss {first} → {last}");
}

#[test]
fn backend_probe_is_unbiased_within_mc_noise() {
    let be = NativeBackend;
    let rep = be.grad_probe("l1", 0.4, 64, 3).unwrap();
    let floor = (rep.rel_variance() / rep.trials as f64).sqrt();
    assert!(
        rep.bias_rel < 5.0 * floor.max(1e-3),
        "bias {} vs MC floor {floor}",
        rep.bias_rel
    );
}

#[test]
fn backend_method_and_model_support_split() {
    let be = NativeBackend;
    assert!(be.supports_method("l1"));
    assert!(be.supports_method("per_column"));
    assert!(!be.supports_method("rcs"));
    assert!(!be.supports_method("per_element"));
    // the model registry now answers support queries: all three paper
    // architectures train natively
    assert!(be.supports_model("mlp"));
    assert!(be.supports_model("bagnet"));
    assert!(be.supports_model("vit"));
    assert!(!be.supports_model("resnet"));
}
