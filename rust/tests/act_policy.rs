//! Activation-policy suite (DESIGN.md §7.4): four pillars.
//!
//! 1. **Bit-exact parity** — `act_policy=exact` and the kept policy with
//!    no gated sites (baseline sketch) produce byte-identical training
//!    curves, across model families, `--kernel scalar|simd` and
//!    `--threads 1|4`. The sign-bitset ReLU stash is exercised on the
//!    kept side, so its bit-for-bit masking claim is pinned end to end.
//! 2. **MC unbiasedness** — the doubly-gated kept-column backward
//!    (forward X-gates × backward G-gates) has the exact gradient as its
//!    Monte-Carlo mean, for correlated and independent G-gates, at the
//!    kernel level and through a whole model; a deliberately unrescaled
//!    estimator fails the same bar (the tolerance has teeth).
//! 3. **Memory regression** — `workspace_bytes()` stash accounting
//!    shrinks monotonically with the activation budget, never exceeds
//!    the exact baseline, and the ISSUE's acceptance bar holds: a 2×
//!    deeper BagNet under the kept policy fits inside the *shallow*
//!    exact model's workspace footprint. Degenerate inputs (tiny
//!    budgets, empty kept lists) stay safe.
//! 4. **Convergence smoke** — the 2–3× deeper registry models train
//!    (loss decreases) under `--act-policy kept` at budget 0.25, and the
//!    mlp parity setup stays inside a sim-calibrated quality envelope.
//!    Margins pre-verified against the python simulation
//!    (`python/tools/module_sim.py act`).
//!
//! Tolerances for (2) follow `tests/native_unbiased.rs` and were measured
//! in the simulation at these exact shapes/budgets/trial counts: rel
//! Frobenius deviation of the doubly-gated MC mean ≈ 0.027 (l1 G-gates),
//! ≈ 0.038 (l1_ind), while the unrescaled negative control lands at
//! ≈ 0.47 — so the 12% bar gives ≥3× headroom and a missing rescale
//! overshoots it ~4×.

use uavjp::config::{Preset, TrainConfig};
use uavjp::native::{
    kept_linear_backward_into, models, ActivationPolicy, NativeTrainer,
    SketchPolicy, Stash,
};
use uavjp::rng::Pcg64;
use uavjp::sketch::SketchScratch;
use uavjp::tensor::kernels::{set_kernel, KernelKind};
use uavjp::tensor::{dense_backward, Mat};

/// `set_kernel` is a process-wide knob and the test harness runs tests
/// concurrently: every test that compares two runs bit-for-bit takes this
/// lock so the kernel cannot flip mid-comparison. (Statistical and
/// byte-accounting tests are kernel-independent and skip it.)
static KERNEL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

// ---------------------------------------------------------------------------
// Shared config helpers
// ---------------------------------------------------------------------------

/// A short run of `model` under an explicit activation policy. Never uses
/// `act_policy = "auto"` so the suite is invariant to the CI matrix's
/// `UAVJP_ACTPOLICY` environment knob.
fn short_cfg(model: &str, act_policy: &str) -> TrainConfig {
    let mut cfg = Preset::Smoke.base(model).unwrap();
    cfg.act_policy = act_policy.into();
    cfg.train_size = 64;
    cfg.test_size = 32;
    cfg.steps = 6;
    cfg.eval_every = 6;
    cfg.batch = 16;
    cfg
}

// ---------------------------------------------------------------------------
// 1. Bit-exact parity
// ---------------------------------------------------------------------------

/// The exact policy must be bit-identical to the kept policy when no site
/// is gated (baseline sketch): values stash full either way and ReLU's
/// sign bitset replays `mask_nonpos` bit for bit. One test holds the
/// whole model × kernel × thread matrix because `set_kernel` is a
/// process-wide knob — running the pairs sequentially keeps every
/// comparison under one stable kernel.
#[test]
fn exact_and_kept_baseline_parity_across_models_kernels_threads() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for kernel in ["scalar", "simd"] {
        for threads in [1usize, 4] {
            for model in ["mlp", "bagnet", "vit"] {
                let mut exact = short_cfg(model, "exact");
                exact.method = "baseline".into();
                exact.location = "none".into();
                exact.kernel = kernel.into();
                exact.threads = threads;
                let mut kept = exact.clone();
                kept.act_policy = "kept".into();

                let mut ta = NativeTrainer::new(exact).unwrap();
                let ca = ta.run().unwrap();
                let mut tb = NativeTrainer::new(kept).unwrap();
                let cb = tb.run().unwrap();
                assert_eq!(
                    ca.losses, cb.losses,
                    "{model}/{kernel}/t{threads}: kept-baseline curve \
                     diverged from exact"
                );
                assert_eq!(ca.evals, cb.evals, "{model}/{kernel}/t{threads}");
                // identical bits from a no-larger stash: kept-baseline
                // replaces ReLU full-value copies with bitsets — strictly
                // smaller wherever the model has a standalone ReLU (the
                // ViT has none, so there the arenas tie exactly)
                let (wa, wb) = (ta.workspace_bytes(), tb.workspace_bytes());
                assert!(
                    wb.stash <= wa.stash,
                    "{model}: kept-baseline stash {} > exact stash {}",
                    wb.stash,
                    wa.stash
                );
                if model != "vit" {
                    assert!(wb.stash < wa.stash, "{model}: bitset not used");
                }
            }
        }
    }
    set_kernel(KernelKind::Auto);
}

/// Sketched training under the kept policy is deterministic given the
/// seed — the act-gate stream is part of the run's reproducible state.
#[test]
fn kept_policy_runs_are_deterministic() {
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = short_cfg("mlp", "kept");
    cfg.method = "l1".into();
    cfg.budget = 0.25;
    cfg.steps = 12;
    cfg.eval_every = 12;
    let c1 = NativeTrainer::with_dims(cfg.clone(), &[784, 24, 10])
        .unwrap()
        .run()
        .unwrap();
    let c2 = NativeTrainer::with_dims(cfg, &[784, 24, 10])
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(c1.losses, c2.losses);
    assert_eq!(c1.evals, c2.evals);
}

// ---------------------------------------------------------------------------
// 2. MC unbiasedness of the doubly-gated kept-column backward
// ---------------------------------------------------------------------------

/// Relative Frobenius distance between an accumulated MC sum (over `t`
/// trials) and an exact reference.
fn rel_err(acc: &[f64], exact: &[f64], t: f64) -> f64 {
    let mut err = 0.0f64;
    let mut norm = 0.0f64;
    for (a, e) in acc.iter().zip(exact) {
        let d = a / t - e;
        err += d * d;
        norm += e * e;
    }
    (err / norm.max(1e-12)).sqrt()
}

/// Drive `kept_linear_backward_into` the way the training loop does: each
/// trial draws fresh X-gates (l2 scores, correlated — the activation
/// policy's fixed scheme) from one stream and fresh G-gates (the site's
/// method) from an independent stream, and the MC mean of (dW, db, dX)
/// must match the dense backward. `rescale = false` drops the 1/pₓ column
/// rescale — the negative control.
fn kept_mc_rel_errs(
    g_method: &str,
    g_budget: f64,
    x_budget: f64,
    trials: usize,
    rescale: bool,
    data_seed: u64,
) -> (f64, f64, f64) {
    let (b, dout, din) = (8usize, 12usize, 6usize);
    let mut rng = Pcg64::new(data_seed, 0);
    let g = Mat::from_fn(b, dout, |_, _| rng.gaussian() as f32);
    let x = Mat::from_fn(b, din, |_, _| rng.gaussian() as f32);
    let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32);
    let (dx_exact, dw_exact) = dense_backward(&g, &x, &w);
    let db_exact: Vec<f64> = (0..dout)
        .map(|j| (0..b).map(|i| g.at(i, j) as f64).sum())
        .collect();

    let mut scratch = SketchScratch::new();
    let mut act_rng = Pcg64::new(data_seed ^ 0x51ac7, 13);
    let mut g_rng = Pcg64::new(data_seed ^ 0x9e3779b9, 11);
    let mut acc_dw = vec![0.0f64; dout * din];
    let mut acc_db = vec![0.0f64; dout];
    let mut acc_dx = vec![0.0f64; b * din];
    let mut dw = Mat::zeros(dout, din);
    let mut db = vec![0.0f32; dout];
    let mut dx = Mat::zeros(b, din);
    for _ in 0..trials {
        // forward side: gather the kept input columns (what stash_input
        // does under ActSite::Kept)
        let mut kept: Vec<(usize, f32)> = scratch
            .plan_columns("l2", x_budget, x.view(), None, &mut act_rng)
            .to_vec();
        if !rescale {
            for k in kept.iter_mut() {
                k.1 = 1.0;
            }
        }
        let m = kept.len();
        let mut xg = Mat::zeros(b, m);
        for r in 0..b {
            for (c, &(j, _)) in kept.iter().enumerate() {
                xg.data[r * m + c] = x.at(r, j);
            }
        }
        // backward side: the doubly-gated estimator
        kept_linear_backward_into(
            g.view(),
            xg.view(),
            &kept,
            din,
            &w,
            g_method,
            g_budget,
            &mut g_rng,
            &mut scratch,
            dw.view_mut(),
            &mut db,
            Some(dx.view_mut()),
        );
        for (a, v) in acc_dw.iter_mut().zip(&dw.data) {
            *a += *v as f64;
        }
        for (a, v) in acc_db.iter_mut().zip(&db) {
            *a += *v as f64;
        }
        for (a, v) in acc_dx.iter_mut().zip(&dx.data) {
            *a += *v as f64;
        }
    }
    let t = trials as f64;
    let dw64: Vec<f64> = dw_exact.data.iter().map(|&v| v as f64).collect();
    let dx64: Vec<f64> = dx_exact.data.iter().map(|&v| v as f64).collect();
    (
        rel_err(&acc_dw, &dw64, t),
        rel_err(&acc_db, &db_exact, t),
        rel_err(&acc_dx, &dx64, t),
    )
}

#[test]
fn kept_stash_backward_unbiased_correlated_g_gates() {
    let (edw, edb, edx) = kept_mc_rel_errs("l1", 0.4, 0.5, 4000, true, 21);
    assert!(edw < 0.12, "dW MC mean off by {edw:.4}");
    assert!(edb < 0.12, "db MC mean off by {edb:.4}");
    assert!(edx < 0.12, "dX MC mean off by {edx:.4}");
}

#[test]
fn kept_stash_backward_unbiased_independent_g_gates() {
    let (edw, edb, edx) = kept_mc_rel_errs("l1_ind", 0.4, 0.5, 4000, true, 22);
    assert!(edw < 0.12, "dW MC mean off by {edw:.4}");
    assert!(edb < 0.12, "db MC mean off by {edb:.4}");
    assert!(edx < 0.12, "dX MC mean off by {edx:.4}");
}

#[test]
fn unrescaled_kept_stash_fails_the_bar() {
    // negative control: skipping the X-side 1/pₓ rescale biases dW by
    // roughly the keep probability (~2× at budget 0.5); db and dX never
    // touch the stash, so only dW must blow the tolerance.
    let (edw, edb, edx) = kept_mc_rel_errs("l1", 0.4, 0.5, 1500, false, 23);
    assert!(edw > 0.12, "biased control passed the dW bar: {edw:.4}");
    assert!(edb < 0.12 && edx < 0.12, "db/dX should stay unbiased");
}

/// Whole-model unbiasedness: MC mean of every parameter gradient under
/// the kept policy (doubly-gated linears + bitset ReLU stash + sketched
/// dX chain) matches the exact-plan gradient. Fresh independent act/G
/// streams per trial, like fresh seeds across runs.
#[test]
fn full_model_grads_unbiased_under_kept_policy() {
    use uavjp::native::loss::{loss_and_grad_into, LossKind};
    let m = models::mlp(&[4, 6, 3], 5);
    let mut rng = Pcg64::new(6, 0);
    let x = Mat::from_fn(5, 4, |_, _| rng.gaussian() as f32);
    let y = vec![0i32, 1, 2, 0, 1];
    let sk = SketchPolicy {
        method: "l1".into(),
        budget: 0.5,
        location: "all".into(),
        schedule: None,
    };
    let run = |plan: &uavjp::native::StepPlan,
               act_rng: &mut Pcg64,
               g_rng: &mut Pcg64| {
        let mut ws = m.workspace(5, 4);
        m.forward_train(&x, &mut ws, plan, act_rng);
        let (logits, gout) = ws.loss_io();
        loss_and_grad_into(LossKind::CrossEntropy, logits, &y, gout);
        m.backward(&mut ws, plan, g_rng);
        ws.grad_slots.flatten()
    };
    let exact_plan =
        m.plan(&SketchPolicy::exact(), &ActivationPolicy::exact()).unwrap();
    let exact: Vec<f64> = run(
        &exact_plan,
        &mut Pcg64::new(1, 0),
        &mut Pcg64::new(2, 0),
    )
    .iter()
    .map(|&v| v as f64)
    .collect();

    let kept_plan = m.plan(&sk, &ActivationPolicy::kept(0.5)).unwrap();
    let trials = 3000usize;
    let mut acc = vec![0.0f64; exact.len()];
    for t in 0..trials {
        let grads = run(
            &kept_plan,
            &mut Pcg64::new(900 + t as u64, 1),
            &mut Pcg64::new(5000 + t as u64, 2),
        );
        for (a, v) in acc.iter_mut().zip(&grads) {
            *a += *v as f64;
        }
    }
    let e = rel_err(&acc, &exact, trials as f64);
    assert!(e < 0.12, "model-level MC mean off by {e:.4}");
}

// ---------------------------------------------------------------------------
// 3. Memory regression
// ---------------------------------------------------------------------------

/// Train a few steps and return the steady-state workspace accounting.
fn bytes_after_steps(cfg: TrainConfig) -> uavjp::native::WorkspaceBytes {
    let mut t = NativeTrainer::new(cfg).expect("trainer");
    t.run().expect("run");
    t.workspace_bytes()
}

/// The stash arena shrinks monotonically with the activation budget and
/// never exceeds the exact baseline; every other arena is
/// policy-independent.
#[test]
fn stash_bytes_shrink_with_budget_and_never_exceed_exact() {
    let mk = |policy: &str, act_budget: f64| {
        let mut cfg = short_cfg("bagnet", policy);
        cfg.method = "l1".into();
        cfg.budget = 0.5;
        cfg.location = "all".into();
        cfg.act_budget = act_budget;
        cfg.steps = 2;
        cfg.eval_every = 2;
        bytes_after_steps(cfg)
    };
    let exact = mk("exact", 0.0);
    let kept_half = mk("kept", 0.5);
    let kept_quarter = mk("kept", 0.25);
    assert!(
        kept_quarter.stash < kept_half.stash,
        "stash not monotone: kept@0.25 {} !< kept@0.5 {}",
        kept_quarter.stash,
        kept_half.stash
    );
    assert!(
        kept_half.stash < exact.stash,
        "kept@0.5 stash {} !< exact stash {}",
        kept_half.stash,
        exact.stash
    );
    // the policy only moves the stash arena
    for (k, name) in [(&kept_half, "kept@0.5"), (&kept_quarter, "kept@0.25")] {
        assert_eq!(k.flow, exact.flow, "{name} flow");
        assert_eq!(k.gflow, exact.gflow, "{name} gflow");
        assert_eq!(k.caches, exact.caches, "{name} caches");
        assert_eq!(k.grad_slots, exact.grad_slots, "{name} grad_slots");
    }
    // and the breakdown always sums
    for wb in [&exact, &kept_half, &kept_quarter] {
        assert_eq!(
            wb.total,
            wb.flow + wb.gflow + wb.stash + wb.caches + wb.grad_slots
                + wb.planning
        );
    }
}

/// The ISSUE's acceptance bar: BagNet at 2× depth under the kept policy
/// trains inside the *shallow* exact model's workspace footprint (same
/// batch), because the per-depth cost collapsed to compact stashes.
#[test]
fn deep_bagnet_kept_fits_in_shallow_exact_footprint() {
    let mut shallow = short_cfg("bagnet", "exact");
    shallow.method = "baseline".into();
    shallow.location = "none".into();
    shallow.steps = 2;
    shallow.eval_every = 2;
    let mut deep = short_cfg("bagnet_deep", "kept");
    deep.method = "l1".into();
    deep.budget = 0.25;
    deep.location = "all".into();
    deep.steps = 2;
    deep.eval_every = 2;
    let (ws_shallow, ws_deep) =
        (bytes_after_steps(shallow), bytes_after_steps(deep));
    assert!(
        ws_deep.total <= ws_shallow.total,
        "deep-kept workspace {} B exceeds shallow-exact {} B \
         (deep: {ws_deep:?}, shallow: {ws_shallow:?})",
        ws_deep.total,
        ws_shallow.total
    );
}

/// Within one (deep) architecture the kept policy strictly beats exact.
#[test]
fn deep_vit_kept_strictly_below_its_exact_baseline() {
    let mk = |policy: &str| {
        let mut cfg = short_cfg("vit_deep", policy);
        cfg.method = "l1".into();
        cfg.budget = 0.25;
        cfg.location = "all".into();
        cfg.steps = 2;
        cfg.eval_every = 2;
        bytes_after_steps(cfg)
    };
    let (exact, kept) = (mk("exact"), mk("kept"));
    assert!(
        kept.stash < exact.stash,
        "vit_deep kept stash {} !< exact stash {}",
        kept.stash,
        exact.stash
    );
    assert!(kept.total < exact.total);
}

/// Degenerate budgets stay safe: a tiny activation budget still trains
/// with finite losses (the waterfilling keeps at least the top column).
#[test]
fn tiny_act_budget_trains_safely() {
    let mut cfg = short_cfg("mlp", "kept");
    cfg.method = "l1".into();
    cfg.budget = 0.5;
    cfg.act_budget = 0.02;
    cfg.steps = 4;
    cfg.eval_every = 4;
    let mut t = NativeTrainer::with_dims(cfg, &[784, 16, 10]).unwrap();
    let curve = t.run().unwrap();
    assert!(curve.losses.iter().all(|l| l.is_finite()));
}

/// An empty kept list (nothing stashed survived the gates) must not
/// panic: dW collapses to zero while db and dX stay exact estimators.
#[test]
fn empty_kept_list_is_safe() {
    let (b, dout, din) = (4usize, 5usize, 3usize);
    let mut rng = Pcg64::new(31, 0);
    let g = Mat::from_fn(b, dout, |_, _| rng.gaussian() as f32);
    let w = Mat::from_fn(dout, din, |_, _| rng.gaussian() as f32);
    let xg = Mat::zeros(b, 0);
    let kept: Vec<(usize, f32)> = Vec::new();
    let mut scratch = SketchScratch::new();
    let mut dw = Mat::from_fn(dout, din, |_, _| 7.0); // dirty, must be overwritten
    let mut db = vec![7.0f32; dout];
    let mut dx = Mat::zeros(b, din);
    let mut g_rng = Pcg64::new(32, 1);
    kept_linear_backward_into(
        g.view(),
        xg.view(),
        &kept,
        din,
        &w,
        "l1",
        0.5,
        &mut g_rng,
        &mut scratch,
        dw.view_mut(),
        &mut db,
        Some(dx.view_mut()),
    );
    assert!(dw.data.iter().all(|&v| v == 0.0), "dW must zero out");
    assert!(db.iter().all(|v| v.is_finite()));
    assert!(dx.data.iter().all(|v| v.is_finite()));
    // the zero-width stash also has a zero-byte footprint
    let stash = Stash::Kept { xg, kept, cols: din };
    assert_eq!(stash.bytes(), 0);
}

// ---------------------------------------------------------------------------
// 4. Deep-model convergence under the kept policy
// ---------------------------------------------------------------------------

/// The configs the memory bar unlocks actually train: both deep registry
/// models converge under `--act-policy kept` with l1 @ 0.25 gating
/// everywhere. Margins pre-verified against the python simulation
/// (`module_sim.py act`, same streams): at 48 steps the mean of the last
/// 8 losses lands at 2.17 vs a 2.35 first loss for bagnet_deep and 2.06
/// vs 2.46 for vit_deep.
#[test]
fn deep_models_train_under_kept_policy() {
    for model in ["bagnet_deep", "vit_deep"] {
        let mut cfg = short_cfg(model, "kept");
        cfg.method = "l1".into();
        cfg.budget = 0.25;
        cfg.location = "all".into();
        cfg.train_size = 256;
        cfg.test_size = 64;
        cfg.steps = 48;
        cfg.eval_every = 48;
        cfg.batch = 16;
        let mut t = NativeTrainer::new(cfg).unwrap();
        let curve = t.run().unwrap();
        let first = curve.losses[0];
        let last = curve.tail_loss(8).unwrap();
        assert!(
            last < first,
            "{model}: kept-policy loss {first:.4} → {last:.4} did not \
             decrease"
        );
        assert!(curve.losses.iter().all(|l| l.is_finite()), "{model}");
    }
}

/// Quality cost of the kept policy on the mlp parity setup: the
/// doubly-gated run (G l1 @ 0.25 × X l2 @ 0.25) stays within a widened
/// eval-loss envelope of the exact run and still reaches high accuracy.
/// The sketch-only suite (native_train.rs) pins `act_policy = "exact"`
/// because dual gating deliberately trades some loss for memory; this
/// test owns that axis. Sim-calibrated (`module_sim.py act`): exact eval
/// ≈ 0.049, singly-gated ≈ 0.058, doubly-gated ≈ 0.128 acc 0.965 — the
/// `1.10x + 0.12` bar (≈ 0.174) keeps ~35% headroom.
#[test]
fn mlp_parity_bar_survives_kept_caching() {
    let dims = [784usize, 64, 10];
    let run = |act_policy: &str, method: &str, budget: f64| {
        let mut cfg = short_cfg("mlp", act_policy);
        cfg.method = method.into();
        cfg.budget = budget;
        cfg.location = if method == "baseline" {
            "none".into()
        } else {
            "all".into()
        };
        cfg.train_size = 1024;
        cfg.test_size = 512;
        cfg.steps = 320;
        cfg.eval_every = 160;
        cfg.batch = 64;
        let curve = NativeTrainer::with_dims(cfg, &dims)
            .expect("trainer")
            .run()
            .expect("run");
        *curve.evals.last().expect("eval recorded")
    };
    let (_, exact, _) = run("exact", "baseline", 1.0);
    let (_, kept, kept_acc) = run("kept", "l1", 0.25);
    assert!(
        kept <= exact * 1.10 + 0.12,
        "doubly-gated eval loss {kept:.4} outside the widened envelope of \
         exact {exact:.4}"
    );
    assert!(kept_acc > 0.9, "doubly-gated acc {kept_acc}");
}
