//! Property-based tests over the coordinator-side invariants, using the
//! from-scratch `ptest` harness (DESIGN.md §6: proptest is unavailable
//! offline). These mirror the paper's §3 guarantees on the rust-native
//! implementations.

use uavjp::ptest::{check, gen};
use uavjp::rng::Pcg64;
use uavjp::sketch::{
    backward_flops, correlated_bernoulli, cost_ratio, independent_bernoulli,
    kept_columns, pstar_from_weights,
};

#[test]
fn prop_pstar_budget_and_bounds() {
    check(
        1,
        200,
        |rng| {
            let n = gen::usize_in(rng, 2, 128);
            let w = gen::vec_f32_pos(rng, n);
            let r = gen::f64_in(rng, 1.0, n as f64 - 0.5);
            (w, r)
        },
        |(w, r)| {
            let p = pstar_from_weights(w, *r);
            if p.len() != w.len() {
                return Err("length mismatch".into());
            }
            if !p.iter().all(|&x| x > 0.0 && x <= 1.0) {
                return Err(format!("out of range: {p:?}"));
            }
            let sum: f64 = p.iter().map(|&x| x as f64).sum();
            if (sum - r).abs() > 0.05 * r.max(1.0) {
                return Err(format!("budget violated: Σp = {sum}, r = {r}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pstar_is_monotone_in_weights() {
    // heavier coordinates must never get smaller probabilities
    check(
        2,
        150,
        |rng| {
            let n = gen::usize_in(rng, 3, 64);
            let w = gen::vec_f32_pos(rng, n);
            let r = gen::f64_in(rng, 1.0, n as f64 * 0.8);
            (w, r)
        },
        |(w, r)| {
            let p = pstar_from_weights(w, *r);
            let mut idx: Vec<usize> = (0..w.len()).collect();
            idx.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());
            for pair in idx.windows(2) {
                if p[pair[0]] < p[pair[1]] - 1e-5 {
                    return Err(format!(
                        "w[{}]={} ≥ w[{}]={} but p {} < {}",
                        pair[0], w[pair[0]], pair[1], w[pair[1]],
                        p[pair[0]], p[pair[1]]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_correlated_sampling_count_fixed() {
    check(
        3,
        150,
        |rng| {
            let n = gen::usize_in(rng, 4, 96);
            let w = gen::vec_f32_pos(rng, n);
            let r = gen::f64_in(rng, 1.0, (n as f64 - 1.0).max(1.5));
            (w, r)
        },
        |(w, r)| {
            let p = pstar_from_weights(w, *r);
            let total: f64 = p.iter().map(|&x| x as f64).sum();
            let mut rng = Pcg64::new(17, 0);
            for _ in 0..20 {
                let z = correlated_bernoulli(&mut rng, &p);
                let count = z.iter().filter(|&&b| b).count() as f64;
                // systematic sampling: count ∈ {⌊Σp⌋, ⌈Σp⌉}
                if count < total.floor() - 1e-9 || count > total.ceil() + 1e-9 {
                    return Err(format!("count {count} outside [{}] Σp={total}",
                        total));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mask_rescale_unbiased() {
    // E[z_i/p_i] = 1 for both sampling schemes (Monte-Carlo check)
    check(
        4,
        8,
        |rng| {
            let n = gen::usize_in(rng, 4, 24);
            let w = gen::vec_f32_pos(rng, n);
            (w, 0.0f64)
        },
        |(w, _)| {
            let r = (w.len() as f64 / 3.0).max(1.0);
            let p = pstar_from_weights(w, r);
            let mut rng = Pcg64::new(23, 1);
            let trials = 6000;
            let mut acc = vec![0.0f64; w.len()];
            for _ in 0..trials {
                let z = correlated_bernoulli(&mut rng, &p);
                for (a, (zi, pi)) in acc.iter_mut().zip(z.iter().zip(&p)) {
                    if *zi {
                        *a += 1.0 / *pi as f64;
                    }
                }
            }
            for (i, a) in acc.iter().enumerate() {
                let mean = a / trials as f64;
                // wide tolerance for small p_i (heavy-tailed estimator)
                let tol = 0.1 + 0.7 * (1.0 - p[i] as f64);
                if (mean - 1.0).abs() > tol {
                    return Err(format!(
                        "coordinate {i}: E[z/p] = {mean:.3} (p={})", p[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_independent_sampling_marginals() {
    check(
        5,
        6,
        |rng| {
            let n = gen::usize_in(rng, 3, 16);
            (gen::vec_f32_pos(rng, n), 0.0f64)
        },
        |(w, _)| {
            let p = pstar_from_weights(w, (w.len() / 2).max(1) as f64);
            let mut rng = Pcg64::new(29, 2);
            let trials = 5000;
            let mut freq = vec![0.0f64; p.len()];
            for _ in 0..trials {
                let z = independent_bernoulli(&mut rng, &p);
                for (f, zi) in freq.iter_mut().zip(z) {
                    if zi {
                        *f += 1.0;
                    }
                }
            }
            for (f, &pi) in freq.iter().zip(&p) {
                if (f / trials as f64 - pi as f64).abs() > 0.05 {
                    return Err(format!("marginal {} vs p {}", f / trials as f64, pi));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kept_columns_consistent() {
    check(
        6,
        200,
        |rng| {
            let n = gen::usize_in(rng, 2, 64);
            (gen::vec_f32_pos(rng, n), 0.0f64)
        },
        |(w, _)| {
            let r = (w.len() as f64 * 0.3).max(1.0);
            let p = pstar_from_weights(w, r);
            let mut rng = Pcg64::new(31, 3);
            let z = correlated_bernoulli(&mut rng, &p);
            let kept = kept_columns(&z, &p);
            if kept.len() != z.iter().filter(|&&b| b).count() {
                return Err("kept length mismatch".into());
            }
            for &(j, inv) in &kept {
                if !z[j] {
                    return Err(format!("index {j} not selected"));
                }
                if (inv - 1.0 / p[j]).abs() > 1e-6 {
                    return Err("bad rescale".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cost_model_monotone_and_bounded() {
    check(
        7,
        200,
        |rng| {
            let b = gen::usize_in(rng, 1, 256);
            let d = gen::usize_in(rng, 2, 512);
            (b, d)
        },
        |&(b, d)| {
            let full = backward_flops(b, d, d, d);
            let mut prev = 0.0;
            for kept in [1, d / 4 + 1, d / 2 + 1, d] {
                let f = backward_flops(b, d, d, kept.min(d));
                if f < prev {
                    return Err("flops not monotone in kept".into());
                }
                prev = f;
                if f > full + 1.0 {
                    return Err("sketched flops exceed dense".into());
                }
            }
            let r = cost_ratio(b, d, d, 0.1);
            if !(0.0 < r && r <= 1.0 + 1e-9) {
                return Err(format!("cost ratio {r} out of (0,1]"));
            }
            Ok(())
        },
    );
}
