//! Contract tests of the view-based kernel layer (DESIGN.md §7.2):
//!
//! * **Reference parity** — `gemm_into` with all four transpose-flag
//!   combinations and `β ≠ 0` accumulation matches a scalar f64 reference
//!   matmul (property-tested over random shapes/scalars).
//! * **Pre-redesign bitwise parity** — against a literal port of the PR-2
//!   value-returning `matmul` (naive ikj **with** the data-dependent zero
//!   skip), the new kernels produce bit-identical f32 results even on
//!   ReLU-sparsified inputs. Removing the skip only ever adds `±0.0`
//!   terms to chains that start at `+0.0`, which IEEE-754 round-to-nearest
//!   cannot flip — this is the invariant that keeps MLP/BagNet/ViT
//!   training trajectories bit-identical to the pre-view-API code.
//! * **Thread invariance** — every kernel is bit-identical for every
//!   `--threads` value (row partitioning never reorders an element's
//!   accumulation), checked per-kernel and end-to-end through full
//!   training runs.

use std::sync::Mutex;

use uavjp::config::{Preset, TrainConfig};
use uavjp::native::NativeTrainer;
use uavjp::pool;
use uavjp::ptest::{check, gen};
use uavjp::rng::Pcg64;
use uavjp::sketch::{correlated_bernoulli, kept_columns, pstar_from_weights};
use uavjp::tensor::kernels::{self, Kernel, KernelKind};
use uavjp::tensor::{
    gemm_into, matmul_pr2_reference, sparse_dw_into, sparse_dx_into, Mat,
};

/// `pool::set_threads` is process-global; the tests that sweep it hold
/// this lock so one test's single-thread baseline can't be silently
/// rewritten to multi-threaded by a concurrently running test. (A race
/// could not cause a false failure — results are thread-invariant — but
/// it would erode what the baselines actually cover.)
static THREAD_KNOB: Mutex<()> = Mutex::new(());

/// Pin the kernel knob to `kind`; the returned guard restores the
/// previous resolution on drop — including on panic, so one failing test
/// can't leave the rest of the binary pinned to the wrong kind (callers
/// hold [`THREAD_KNOB`]). The PR-2 bitwise-parity invariant below is a
/// *scalar-kind* contract — `--kernel simd` is ulp-equivalent, not
/// bit-equivalent (`tests/simd_kernels.rs` bounds it).
fn pin_kernel(kind: KernelKind) -> KernelGuard {
    let prev = kernels::active();
    kernels::set_kernel(kind);
    KernelGuard(match prev {
        Kernel::Scalar => KernelKind::Scalar,
        _ => KernelKind::Simd,
    })
}

struct KernelGuard(KernelKind);

impl Drop for KernelGuard {
    fn drop(&mut self) {
        kernels::set_kernel(self.0);
    }
}

fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
}

/// ReLU-like sparsification: exact zeros at data-dependent positions.
fn sparsify(m: &mut Mat, rng: &mut Pcg64, frac: f64) {
    for v in m.data.iter_mut() {
        if rng.f64() < frac {
            *v = 0.0;
        }
    }
}

/// Scalar f64 reference: C = α·op(A)·op(B) + β·C₀.
fn reference_gemm(
    alpha: f32,
    a: &Mat,
    ta: bool,
    b: &Mat,
    tb: bool,
    beta: f32,
    c0: &Mat,
) -> Vec<f64> {
    let m = if ta { a.cols } else { a.rows };
    let k = if ta { a.rows } else { a.cols };
    let n = if tb { b.rows } else { b.cols };
    let mut out = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f64;
            for kk in 0..k {
                let av = if ta { a.at(kk, i) } else { a.at(i, kk) } as f64;
                let bv = if tb { b.at(j, kk) } else { b.at(kk, j) } as f64;
                s += av * bv;
            }
            out[i * n + j] =
                alpha as f64 * s + beta as f64 * c0.at(i, j) as f64;
        }
    }
    out
}

#[test]
fn gemm_matches_reference_all_flags_and_betas() {
    // property: random shapes (crossing the k-block size), random α and
    // β ∈ {0, ±} — every transpose combination tracks the f64 reference
    check(
        42,
        24,
        |rng| {
            let m = gen::usize_in(rng, 1, 9);
            let k = gen::usize_in(rng, 1, 140); // crosses GEMM_KB = 64
            (m, k)
        },
        |&(m, k)| {
            let mut rng = Pcg64::new((m * 1000 + k) as u64, 5);
            let n = 7usize;
            for (ta, tb) in
                [(false, false), (false, true), (true, false), (true, true)]
            {
                for (alpha, beta) in
                    [(1.0f32, 0.0f32), (0.7, 1.0), (-1.3, 0.4), (2.0, -0.9)]
                {
                    let a = if ta {
                        randmat(k, m, &mut rng)
                    } else {
                        randmat(m, k, &mut rng)
                    };
                    let b = if tb {
                        randmat(n, k, &mut rng)
                    } else {
                        randmat(k, n, &mut rng)
                    };
                    let c0 = randmat(m, n, &mut rng);
                    let want =
                        reference_gemm(alpha, &a, ta, &b, tb, beta, &c0);
                    let mut c = c0.clone();
                    gemm_into(
                        alpha,
                        a.view(),
                        ta,
                        b.view(),
                        tb,
                        beta,
                        c.view_mut(),
                    );
                    for (idx, (&got, &expect)) in
                        c.data.iter().zip(&want).enumerate()
                    {
                        let err = (got as f64 - expect).abs();
                        if err > 1e-3 * (1.0 + expect.abs()) {
                            return Err(format!(
                                "ta={ta} tb={tb} α={alpha} β={beta} \
                                 m={m} k={k} idx={idx}: {got} vs {expect}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gemm_bitwise_matches_pr2_matmul_on_relu_sparse_data() {
    // the trajectory-parity invariant: under the scalar kernel kind, the
    // training path's three GEMM configurations (β = 0, α = 1; NN for dX,
    // NT for the affine forward, TN for dW) are bit-identical to the PR-2
    // kernel — including on inputs with exact ReLU zeros, where the old
    // kernel skipped terms
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = pin_kernel(KernelKind::Scalar);
    let mut rng = Pcg64::new(9, 0);
    for trial in 0..20 {
        let (m, k, n) = (5usize, 70usize, 6usize);
        let mut a = randmat(m, k, &mut rng);
        let mut b = randmat(k, n, &mut rng);
        sparsify(&mut a, &mut rng, 0.4);
        sparsify(&mut b, &mut rng, 0.3);
        let want = matmul_pr2_reference(&a, &b);
        // NN
        let mut c = Mat::from_fn(m, n, |_, _| f32::NAN);
        gemm_into(1.0, a.view(), false, b.view(), false, 0.0, c.view_mut());
        assert_eq!(c.data, want.data, "NN trial {trial}");
        // NT: op(B) = (Bᵀ)ᵀ — same product, transposed operand layout
        let bt = b.transpose();
        gemm_into(1.0, a.view(), false, bt.view(), true, 0.0, c.view_mut());
        assert_eq!(c.data, want.data, "NT trial {trial}");
        // TN: op(A) = (Aᵀ)ᵀ
        let at = a.transpose();
        gemm_into(1.0, at.view(), true, b.view(), false, 0.0, c.view_mut());
        assert_eq!(c.data, want.data, "TN trial {trial}");
    }
}

#[test]
fn gemm_threaded_bitwise_matches_single_thread() {
    // row partitioning must never change results: every transpose combo,
    // shapes with remainder rows, workers beyond the row count. The shape
    // is sized above GEMM_PAR_MIN_FLOPS so the threaded path really runs.
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let saved = pool::threads();
    let mut rng = Pcg64::new(17, 0);
    for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)]
    {
        let (m, k, n) = (41usize, 300usize, 401usize);
        let a = if ta { randmat(k, m, &mut rng) } else { randmat(m, k, &mut rng) };
        let b = if tb { randmat(n, k, &mut rng) } else { randmat(k, n, &mut rng) };
        let c0 = randmat(m, n, &mut rng);
        pool::set_threads(1);
        let mut base = c0.clone();
        gemm_into(0.9, a.view(), ta, b.view(), tb, 0.5, base.view_mut());
        for threads in [2usize, 3, 5, 64] {
            pool::set_threads(threads);
            let mut c = c0.clone();
            gemm_into(0.9, a.view(), ta, b.view(), tb, 0.5, c.view_mut());
            assert_eq!(
                c.data, base.data,
                "ta={ta} tb={tb} threads={threads}"
            );
        }
    }
    pool::set_threads(saved);
}

#[test]
fn sparse_kernels_threaded_bitwise_match_single_thread() {
    // sized above GEMM_PAR_MIN_FLOPS so the threaded path really runs
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let saved = pool::threads();
    let mut rng = Pcg64::new(23, 0);
    let (bsz, dout, din) = (128usize, 256usize, 384usize);
    let mut g = randmat(bsz, dout, &mut rng);
    sparsify(&mut g, &mut rng, 0.5);
    let x = randmat(bsz, din, &mut rng);
    let w = randmat(dout, din, &mut rng);
    let scores = uavjp::sketch::column_scores("l1", &g, None);
    let p = pstar_from_weights(&scores, 0.45 * dout as f64);
    let z = correlated_bernoulli(&mut rng, &p);
    let kept = kept_columns(&z, &p);
    assert!(!kept.is_empty());
    pool::set_threads(1);
    let mut dx1 = Mat::zeros(bsz, din);
    let mut dw1 = Mat::zeros(dout, din);
    sparse_dx_into(g.view(), &kept, w.view(), dx1.view_mut());
    sparse_dw_into(g.view(), &kept, x.view(), dw1.view_mut());
    for threads in [2usize, 3, 7] {
        pool::set_threads(threads);
        let mut dx = Mat::from_fn(bsz, din, |_, _| f32::NAN);
        let mut dw = Mat::from_fn(dout, din, |_, _| f32::NAN);
        sparse_dx_into(g.view(), &kept, w.view(), dx.view_mut());
        sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
        assert_eq!(dx.data, dx1.data, "sparse_dx threads={threads}");
        assert_eq!(dw.data, dw1.data, "sparse_dw threads={threads}");
    }
    pool::set_threads(saved);
}

fn short_cfg(model: &str, method: &str, budget: f64) -> TrainConfig {
    let mut cfg = Preset::Smoke.base(model).unwrap();
    cfg.method = method.into();
    cfg.budget = budget;
    cfg.location = if method == "baseline" { "none".into() } else { "all".into() };
    cfg.train_size = 256;
    cfg.test_size = 64;
    cfg.batch = 32;
    cfg.steps = 10;
    cfg.eval_every = 10;
    cfg
}

#[test]
fn training_trajectories_are_thread_count_invariant() {
    // end-to-end: the whole stack (affine forwards, exact + sketched
    // backwards, loss, optimizer) is bit-identical across --threads values
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    for (model, method, budget) in [
        ("mlp", "baseline", 1.0),
        ("mlp", "l1", 0.25),
        ("vit", "l1", 0.25),
        ("bagnet", "baseline", 1.0),
    ] {
        let losses_at = |threads: usize| {
            let mut cfg = short_cfg(model, method, budget);
            cfg.threads = threads;
            NativeTrainer::new(cfg).unwrap().run().unwrap().losses
        };
        let one = losses_at(1);
        let four = losses_at(4);
        assert_eq!(one, four, "{model}/{method} diverged across threads");
    }
    pool::set_threads(1);
}
