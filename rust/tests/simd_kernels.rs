//! Contract tests of the SIMD kernel kind (DESIGN.md §7.3):
//!
//! * **Ulp-bounded scalar parity** — `--kernel simd` results track the
//!   `--kernel scalar` oracle within an accumulation-length-scaled ulp
//!   bound, property-swept over shapes that are *not* multiples of the
//!   6×16 tile or 8-wide lane geometry (including 0-dim and 1×1 edges),
//!   all four transpose combos, and β ∉ {0, 1}.
//! * **Thread invariance** — within the simd kind, dense and kept-column
//!   kernels are bit-identical for every `--threads` value (each element
//!   is one ascending-k register chain regardless of chunking).
//! * **End-to-end** — training runs under `--kernel simd` are
//!   deterministic and converge like the scalar runs.
//!
//! Every test here pins the process-global kernel knob under one mutex,
//! so the suite passes identically under `UAVJP_KERNEL=scalar` and
//! `UAVJP_KERNEL=simd` (the two CI passes).

use std::sync::Mutex;

use uavjp::config::Preset;
use uavjp::native::NativeTrainer;
use uavjp::pool;
use uavjp::rng::Pcg64;
use uavjp::sketch::{correlated_bernoulli, kept_columns, pstar_from_weights};
use uavjp::tensor::kernels::{self, Kernel, KernelKind};
use uavjp::tensor::{gemm_into, sparse_dw_into, sparse_dx_into, Mat};

/// Serializes every mutation of the process-global kernel/thread knobs
/// across this binary's tests (same discipline as `tests/gemm_kernels.rs`).
static KNOB: Mutex<()> = Mutex::new(());

fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.gaussian() as f32)
}

/// Run `f` under a pinned kernel kind, restoring the previous resolution
/// on the way out — including on panic, so one failing assertion can't
/// leave the rest of the binary pinned to the wrong kind. Callers must
/// hold [`KNOB`].
fn with_kernel<R>(kind: KernelKind, f: impl FnOnce() -> R) -> R {
    struct Guard(KernelKind);
    impl Drop for Guard {
        fn drop(&mut self) {
            kernels::set_kernel(self.0);
        }
    }
    let prev = kernels::active();
    kernels::set_kernel(kind);
    let _restore = Guard(match prev {
        Kernel::Scalar => KernelKind::Scalar,
        _ => KernelKind::Simd,
    });
    f()
}

/// Per-element ulp bound for a k-term f32 accumulation: reassociating or
/// fusing a sum of k products moves the result by at most O(k) ulps of
/// the absolute-value sum.
fn assert_ulp_close(got: &[f32], want: &[f32], mag: &[f64], k: usize, tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag} length");
    for (i, (&g, (&w, &m))) in got.iter().zip(want.iter().zip(mag)).enumerate() {
        let tol = (k as f64 + 8.0) * f32::EPSILON as f64 * (m + 1e-30);
        assert!(
            (g as f64 - w as f64).abs() <= tol,
            "{tag} idx {i}: simd {g} vs scalar {w} (tol {tol})"
        );
    }
}

/// |α|·|op(A)|·|op(B)| + |β·C₀| per element — the magnitude the ulp bound
/// scales with.
fn mag_f64(alpha: f32, a: &Mat, ta: bool, b: &Mat, tb: bool, beta: f32, c0: &Mat) -> Vec<f64> {
    let m = if ta { a.cols } else { a.rows };
    let k = if ta { a.rows } else { a.cols };
    let n = if tb { b.rows } else { b.cols };
    let mut out = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut t = 0.0f64;
            for kk in 0..k {
                let av = if ta { a.at(kk, i) } else { a.at(i, kk) } as f64;
                let bv = if tb { b.at(j, kk) } else { b.at(kk, j) } as f64;
                t += (av * bv).abs();
            }
            out[i * n + j] =
                (alpha as f64 * t).abs() + (beta as f64 * c0.at(i, j) as f64).abs();
        }
    }
    out
}

#[test]
fn simd_gemm_tracks_scalar_oracle_over_remainder_shapes() {
    let _knob = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg64::new(71, 0);
    // off-grid on every axis: m crosses the 6-row tile, n the 16-col panel
    // and 8-wide lane, k the accumulation chain; plus exact-grid and
    // degenerate sizes
    for &m in &[1usize, 5, 6, 7, 13] {
        for &n in &[1usize, 8, 15, 16, 17, 33] {
            for &k in &[0usize, 1, 2, 9, 64, 130] {
                for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
                    let a = if ta { randmat(k, m, &mut rng) } else { randmat(m, k, &mut rng) };
                    let b = if tb { randmat(n, k, &mut rng) } else { randmat(k, n, &mut rng) };
                    let c0 = randmat(m, n, &mut rng);
                    let (alpha, beta) = (0.7f32, -0.4f32);
                    let mag = mag_f64(alpha, &a, ta, &b, tb, beta, &c0);
                    let scalar = with_kernel(KernelKind::Scalar, || {
                        let mut c = c0.clone();
                        gemm_into(alpha, a.view(), ta, b.view(), tb, beta, c.view_mut());
                        c
                    });
                    let simd = with_kernel(KernelKind::Simd, || {
                        let mut c = c0.clone();
                        gemm_into(alpha, a.view(), ta, b.view(), tb, beta, c.view_mut());
                        c
                    });
                    assert_ulp_close(
                        &simd.data,
                        &scalar.data,
                        &mag,
                        k,
                        &format!("m{m} n{n} k{k} ta{ta} tb{tb}"),
                    );
                }
            }
        }
    }
}

#[test]
fn simd_gemm_beta_accumulation_and_nan_safety() {
    let _knob = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg64::new(73, 0);
    let a = randmat(7, 20, &mut rng);
    let b = randmat(20, 17, &mut rng);
    with_kernel(KernelKind::Simd, || {
        // β = 0 never reads the (NaN-poisoned) destination
        let mut c = Mat::from_fn(7, 17, |_, _| f32::NAN);
        gemm_into(1.0, a.view(), false, b.view(), false, 0.0, c.view_mut());
        assert!(c.data.iter().all(|v| v.is_finite()));
        // β = 1 accumulates: C = A·B + A·B == 2·(A·B) exactly
        let base = c.clone();
        gemm_into(1.0, a.view(), false, b.view(), false, 1.0, c.view_mut());
        for (twice, once) in c.data.iter().zip(&base.data) {
            assert_eq!(*twice, 2.0 * once);
        }
    });
}

#[test]
fn simd_kernels_are_thread_count_invariant_bitwise() {
    let _knob = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let saved = pool::threads();
    let mut rng = Pcg64::new(77, 0);
    with_kernel(KernelKind::Simd, || {
        // sized above GEMM_PAR_MIN_FLOPS so the threaded path really runs
        for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
            let (m, k, n) = (41usize, 300usize, 401usize);
            let a = if ta { randmat(k, m, &mut rng) } else { randmat(m, k, &mut rng) };
            let b = if tb { randmat(n, k, &mut rng) } else { randmat(k, n, &mut rng) };
            let c0 = randmat(m, n, &mut rng);
            pool::set_threads(1);
            let mut base = c0.clone();
            gemm_into(0.9, a.view(), ta, b.view(), tb, 0.5, base.view_mut());
            for threads in [2usize, 3, 5, 64] {
                pool::set_threads(threads);
                let mut c = c0.clone();
                gemm_into(0.9, a.view(), ta, b.view(), tb, 0.5, c.view_mut());
                assert_eq!(c.data, base.data, "ta={ta} tb={tb} threads={threads}");
            }
        }
    });
    pool::set_threads(saved);
}

#[test]
fn sparse_kernels_simd_match_scalar_and_thread_invariant() {
    let _knob = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let saved = pool::threads();
    let mut rng = Pcg64::new(79, 0);
    // large enough that bsz·din·|kept| crosses the threading threshold,
    // with a real waterfilling-skewed kept list
    let (bsz, dout, din) = (96usize, 256usize, 384usize);
    let g = randmat(bsz, dout, &mut rng);
    let x = randmat(bsz, din, &mut rng);
    let w = randmat(dout, din, &mut rng);
    let scores = uavjp::sketch::column_scores("l1", &g, None);
    let p = pstar_from_weights(&scores, 0.5 * dout as f64);
    let z = correlated_bernoulli(&mut rng, &p);
    let kept = kept_columns(&z, &p);
    assert!(kept.len() > 64, "want a kept list that engages threading");
    pool::set_threads(1);
    let (sdx, sdw) = with_kernel(KernelKind::Scalar, || {
        let mut dx = Mat::zeros(bsz, din);
        let mut dw = Mat::zeros(dout, din);
        sparse_dx_into(g.view(), &kept, w.view(), dx.view_mut());
        sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
        (dx, dw)
    });
    let (vdx1, vdw1) = with_kernel(KernelKind::Simd, || {
        let mut dx = Mat::from_fn(bsz, din, |_, _| f32::NAN);
        let mut dw = Mat::from_fn(dout, din, |_, _| f32::NAN);
        sparse_dx_into(g.view(), &kept, w.view(), dx.view_mut());
        sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
        (dx, dw)
    });
    // ulp parity vs the scalar oracle (k = |kept| resp. batch terms),
    // scaled by the true absolute-value term sums
    let mut magdx = vec![0.0f64; bsz * din];
    for i in 0..bsz {
        for jj in 0..din {
            let mut t = 0.0f64;
            for &(j, inv) in &kept {
                t += ((g.at(i, j) * inv) as f64 * w.at(j, jj) as f64).abs();
            }
            magdx[i * din + jj] = t;
        }
    }
    assert_ulp_close(&vdx1.data, &sdx.data, &magdx, kept.len(), "sparse_dx");
    let mut magdw = vec![0.0f64; dout * din];
    for &(j, inv) in &kept {
        for jj in 0..din {
            let mut t = 0.0f64;
            for i in 0..bsz {
                t += ((g.at(i, j) * inv) as f64 * x.at(i, jj) as f64).abs();
            }
            magdw[j * din + jj] = t;
        }
    }
    assert_ulp_close(&vdw1.data, &sdw.data, &magdw, bsz, "sparse_dw");
    // dropped dW rows are exactly zero in both kinds
    for j in 0..dout {
        if !kept.iter().any(|&(kj, _)| kj == j) {
            assert!(vdw1.data[j * din..(j + 1) * din].iter().all(|&v| v == 0.0));
        }
    }
    // thread invariance of the simd sparse path (dynamic chunking included)
    with_kernel(KernelKind::Simd, || {
        for threads in [2usize, 3, 7] {
            pool::set_threads(threads);
            let mut dx = Mat::from_fn(bsz, din, |_, _| f32::NAN);
            let mut dw = Mat::from_fn(dout, din, |_, _| f32::NAN);
            sparse_dx_into(g.view(), &kept, w.view(), dx.view_mut());
            sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
            assert_eq!(dx.data, vdx1.data, "sparse_dx threads={threads}");
            assert_eq!(dw.data, vdw1.data, "sparse_dw threads={threads}");
        }
    });
    pool::set_threads(saved);
}

#[test]
fn sparse_dw_skewed_chunks_cover_all_rows() {
    let _knob = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let saved = pool::threads();
    let mut rng = Pcg64::new(83, 0);
    // kept lists with awkward sizes around worker multiples (the static
    // split used to leave workers idle here); debug builds also assert
    // full coverage inside sparse_dw_into
    let (bsz, dout, din) = (128usize, 128usize, 1024usize);
    let g = randmat(bsz, dout, &mut rng);
    let x = randmat(bsz, din, &mut rng);
    for kind in [KernelKind::Scalar, KernelKind::Simd] {
        with_kernel(kind, || {
            // 33 and 127 cross the threading threshold (128·1024·33 > 2²²)
            // and land on awkward worker multiples
            for kept_n in [1usize, 2, 5, 9, 33, 127] {
                let kept: Vec<(usize, f32)> =
                    (0..kept_n).map(|i| (i * (dout / kept_n.max(1)), 1.5f32)).collect();
                pool::set_threads(1);
                let mut base = Mat::zeros(dout, din);
                sparse_dw_into(g.view(), &kept, x.view(), base.view_mut());
                pool::set_threads(4);
                let mut dw = Mat::from_fn(dout, din, |_, _| f32::NAN);
                sparse_dw_into(g.view(), &kept, x.view(), dw.view_mut());
                assert_eq!(dw.data, base.data, "{kind:?} kept={kept_n}");
            }
        });
    }
    pool::set_threads(saved);
}

#[test]
fn training_under_simd_kernel_is_deterministic_and_converges() {
    let _knob = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let run = |kernel: &str| {
        let mut cfg = Preset::Smoke.base("mlp").unwrap();
        cfg.method = "l1".into();
        cfg.budget = 0.25;
        cfg.train_size = 256;
        cfg.test_size = 64;
        cfg.steps = 24;
        cfg.eval_every = 24;
        cfg.batch = 32;
        cfg.kernel = kernel.into();
        NativeTrainer::with_dims(cfg, &[784, 16, 10])
            .unwrap()
            .run()
            .unwrap()
            .losses
    };
    let simd1 = run("simd");
    let simd2 = run("simd");
    assert_eq!(simd1, simd2, "simd training must be run-to-run deterministic");
    assert!(
        *simd1.last().unwrap() < simd1[0],
        "simd loss {} → {} did not decrease",
        simd1[0],
        simd1.last().unwrap()
    );
    // the scalar trajectory differs in bits but lands in the same regime
    let scalar = run("scalar");
    assert!(*scalar.last().unwrap() < scalar[0]);
    // restore ambient resolution for any later test in this binary
    kernels::set_kernel(KernelKind::Auto);
}
