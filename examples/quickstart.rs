//! Quickstart: load the AOT artifacts, train a sketched MLP for a handful of
//! steps, and compare against the exact-VJP baseline.
//!
//! Run with:  cargo run --release --example quickstart
//! (requires `make artifacts` first)

use anyhow::Result;
use uavjp::config::{Preset, TrainConfig};
use uavjp::coordinator::Trainer;
use uavjp::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("loaded manifest with {} artifacts", rt.manifest.len());

    let mut base: TrainConfig = Preset::Smoke.base("mlp");
    base.steps = 400;
    base.eval_every = 100;

    for (method, budget) in [("baseline", 1.0), ("l1", 0.15)] {
        let mut cfg = base.clone();
        cfg.method = method.to_string();
        cfg.budget = budget;
        cfg.location = if method == "baseline" { "none".into() } else { "all".into() };
        let trainer = Trainer::new(&rt, cfg)?;
        let t0 = std::time::Instant::now();
        let curve = trainer.run()?;
        println!(
            "{method:>9} (p={budget}): loss {:.3} → {:.3}, test acc {:.3}  [{:.1}s]",
            curve.losses.first().copied().unwrap_or(f64::NAN),
            curve.tail_loss(10).unwrap_or(f64::NAN),
            curve.final_acc().unwrap_or(f64::NAN),
            t0.elapsed().as_secs_f64(),
        );
    }
    println!("\nThe ℓ1 sketch keeps 15% of backward columns yet trains close to baseline —");
    println!("the paper's headline effect. See `uavjp fig1b` for the full comparison.");
    Ok(())
}
